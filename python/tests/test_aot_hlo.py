"""AOT HLO-text interchange regressions.

Two gotchas bit the rust consumer (xla_extension 0.5.1) and are pinned
here so they never come back:

* the default HLO printer ELIDES constants above ~10 elements as
  ``constant({...})``, which the consumer-side text parser silently
  parses as zeros — wiping the baked-in model weights;
* modern metadata attributes (``source_end_line``) are rejected by the
  0.5.1 text parser outright.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot


@pytest.fixture(scope="module")
def lowered_with_big_constant():
    w = jnp.asarray(np.arange(210, dtype=np.float32).reshape(21, 10))
    fn = lambda x: (x @ w,)
    return jax.jit(fn).lower(jax.ShapeDtypeStruct((4, 21), jnp.float32))


def test_no_elided_constants(lowered_with_big_constant):
    txt = aot.to_hlo_text(lowered_with_big_constant)
    assert "{...}" not in txt
    # A distinctive weight value must literally appear in the text.
    assert "209" in txt


def test_no_modern_metadata(lowered_with_big_constant):
    txt = aot.to_hlo_text(lowered_with_big_constant)
    assert "source_end_line" not in txt
    assert "metadata=" not in txt


def test_entry_layout_present(lowered_with_big_constant):
    txt = aot.to_hlo_text(lowered_with_big_constant)
    assert txt.startswith("HloModule")
    assert "f32[4,21]" in txt and "f32[4,10]" in txt
    # return_tuple=True: output is a 1-tuple.
    assert "(f32[4,10]" in txt
