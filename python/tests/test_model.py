"""L2 model tests: quantised forward (Pallas vs oracle, bit-exact), float
reference sanity, heads, serialisation roundtrip, accuracy degradation."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import datasets as dsets
from compile import model as M
from compile import quant


def tiny_mlp(head="argmax", n_classes=3, k=6, seed=0):
    rng = np.random.default_rng(seed)
    out = n_classes if head == "argmax" else 1
    layers = [
        M.DenseLayer(
            w=rng.normal(scale=0.8, size=(k, 4)).astype(np.float32),
            b=rng.normal(scale=0.2, size=4).astype(np.float32),
            relu=True,
        ),
        M.DenseLayer(
            w=rng.normal(scale=0.8, size=(4, out)).astype(np.float32),
            b=rng.normal(scale=0.2, size=out).astype(np.float32),
            relu=False,
        ),
    ]
    m = M.Model(
        name="tiny",
        dataset="synth",
        task="classification" if head == "argmax" else "regression",
        head=head,
        layers=layers,
        calib=[1.0, 4.0, 6.0],
        n_classes=n_classes,
        label_offset=0 if head == "argmax" else 3,
    )
    return m


def tiny_svm_ovo(seed=1):
    rng = np.random.default_rng(seed)
    pairs = [(0, 1), (0, 2), (1, 2)]
    layers = [
        M.DenseLayer(
            w=rng.normal(scale=0.5, size=(5, 3)).astype(np.float32),
            b=rng.normal(scale=0.1, size=3).astype(np.float32),
            relu=False,
        )
    ]
    return M.Model(
        name="tiny_svm",
        dataset="synth",
        task="classification",
        head="ovo_vote",
        layers=layers,
        calib=[1.0, 3.0],
        n_classes=3,
        label_offset=0,
        ovo_pairs=pairs,
    )


@pytest.mark.parametrize("n", M.PRECISIONS)
def test_quantized_pallas_equals_oracle(n):
    m = tiny_mlp()
    x = jnp.asarray(np.random.default_rng(2).uniform(0, 1, size=(37, 6)).astype(np.float32))
    a = np.asarray(M.quantized_forward(m, x, n, use_pallas=True))
    b = np.asarray(M.quantized_forward(m, x, n, use_pallas=False))
    np.testing.assert_array_equal(a, b)


def test_quantized32_close_to_float():
    m = tiny_mlp()
    x = jnp.asarray(np.random.default_rng(3).uniform(0, 1, size=(64, 6)).astype(np.float32))
    f = np.asarray(M.float_forward(m, x))
    q = np.asarray(M.quantized_forward(m, x, 32, use_pallas=False))
    np.testing.assert_allclose(q, f, atol=1e-3)


def test_quantization_error_grows_as_precision_drops():
    m = tiny_mlp()
    x = jnp.asarray(np.random.default_rng(4).uniform(0, 1, size=(128, 6)).astype(np.float32))
    f = np.asarray(M.float_forward(m, x))
    errs = []
    for n in (32, 16, 8, 4):
        q = np.asarray(M.quantized_forward(m, x, n, use_pallas=False))
        errs.append(float(np.mean(np.abs(q - f))))
    assert errs[0] <= errs[1] <= errs[2] <= errs[3]
    assert errs[0] < 1e-3 and errs[3] > errs[0]


def test_ovo_vote_head():
    m = tiny_svm_ovo()
    # Hand-crafted raw pair decisions: [+, +, +] => class0 beats 1 and 2,
    # class1 beats 2 => votes [2, 1, 0].
    raw = jnp.asarray(np.array([[1.0, 1.0, 1.0]], dtype=np.float32))
    votes = np.asarray(M._head_scores(m, raw))
    np.testing.assert_array_equal(votes, [[2.0, 1.0, 0.0]])
    # [-, -, -]: 1 beats 0, 2 beats 0, 2 beats 1 => [0, 1, 2].
    votes = np.asarray(M._head_scores(m, -raw))
    np.testing.assert_array_equal(votes, [[0.0, 1.0, 2.0]])


def test_ovo_tie_break_boundary():
    m = tiny_svm_ovo()
    # Zero decision counts as ">= 0" => vote for the first class of the pair.
    raw = jnp.zeros((1, 3), dtype=jnp.float32)
    votes = np.asarray(M._head_scores(m, raw))
    np.testing.assert_array_equal(votes, [[2.0, 1.0, 0.0]])


def test_predict_round_head_clamps():
    m = tiny_mlp(head="round")
    m.n_classes, m.label_offset = 6, 3  # wine quality 3..8
    scores = np.array([[2.2], [3.4], [5.5], [9.7], [7.49]])
    pred = M.predict_from_scores(m, scores)
    np.testing.assert_array_equal(pred, [3, 3, 6, 8, 7])


def test_predict_argmax_offset():
    m = tiny_mlp(head="argmax")
    m.label_offset = 1
    pred = M.predict_from_scores(m, np.array([[0.1, 0.9, 0.3]]))
    np.testing.assert_array_equal(pred, [2])


def test_accuracy_metric():
    m = tiny_mlp(head="argmax")
    scores = np.array([[1, 0, 0], [0, 1, 0], [0, 0, 1], [1, 0, 0]], dtype=np.float32)
    labels = np.array([0, 1, 2, 2])
    assert M.accuracy(m, scores, labels) == pytest.approx(0.75)


def test_json_roundtrip():
    m = tiny_svm_ovo()
    m.float_accuracy = 0.87
    d = M.to_json_dict(m)
    m2 = M.from_json_dict(d)
    assert m2.name == m.name and m2.head == m.head
    assert m2.ovo_pairs == m.ovo_pairs
    np.testing.assert_allclose(m2.layers[0].w, m.layers[0].w)
    np.testing.assert_allclose(m2.calib, m.calib)
    assert m2.float_accuracy == pytest.approx(0.87)


def test_layer_quants_derivation():
    m = tiny_mlp()
    for n in M.PRECISIONS:
        lqs = m.layer_quants(n)
        assert len(lqs) == 2
        for lq in lqs:
            assert lq.shift >= 0
            lq.check_no_overflow()


def test_quantized_forward_batch_one():
    m = tiny_mlp()
    x = jnp.asarray(np.random.default_rng(5).uniform(0, 1, size=(1, 6)).astype(np.float32))
    a = np.asarray(M.quantized_forward(m, x, 16, use_pallas=True))
    b = np.asarray(M.quantized_forward(m, x, 16, use_pallas=False))
    np.testing.assert_array_equal(a, b)
    assert a.shape == (1, 3)
