"""Training + AOT lowering smoke tests (kept light: full training of all
six models happens once in `make artifacts`)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, datasets as dsets, model as M, train


@pytest.fixture(scope="module")
def redwine():
    return dsets.generate(dsets.SPECS["redwine"])


@pytest.fixture(scope="module")
def cardio():
    return dsets.generate(dsets.SPECS["cardio"])


@pytest.fixture(scope="module")
def svm_r(redwine):
    return train.train_svm_regressor(redwine)


def test_svm_regressor_learns(redwine, svm_r):
    """A linear model on the ordinal synthetic wine data must beat the
    majority-class baseline."""
    counts = np.bincount(redwine.y_test - redwine.spec.label_offset)
    majority = counts.max() / counts.sum()
    assert svm_r.float_accuracy > majority + 0.03
    assert svm_r.head == "round"
    assert svm_r.arch == [11, 1]


def test_calibration_covers_activations(redwine, svm_r):
    scores = np.asarray(M.float_forward(svm_r, jnp.asarray(redwine.x_test)))
    assert np.max(np.abs(scores)) <= svm_r.calib[-1] + 1e-3


def test_svm_classifier_ovo_structure(cardio):
    m = train.train_svm_classifier(cardio)
    assert m.head == "ovo_vote"
    assert m.ovo_pairs == [(0, 1), (0, 2), (1, 2)]
    assert m.arch == [21, 3]
    assert m.float_accuracy > 0.75


def test_mlp_classifier_learns(cardio):
    m = train.train_mlp_classifier(cardio)
    assert m.arch == [21, train.HIDDEN, 3]
    assert m.float_accuracy > 0.80


def test_quantized_16bit_no_accuracy_loss(redwine, svm_r):
    """Paper Table I: P16 loses no accuracy (params are 16-bit)."""
    acc16 = aot.eval_quantized(svm_r, redwine, 16)
    assert abs(acc16 - svm_r.float_accuracy) < 0.01


def test_lower_model_emits_hlo(redwine, svm_r):
    txt = aot.lower_model(svm_r, 16)
    assert txt.startswith("HloModule")
    assert f"f32[{aot.BATCH},11]" in txt  # input shape baked in
    txt_f = aot.lower_model(svm_r, None)
    assert "f32" in txt_f and txt_f.startswith("HloModule")


def test_lower_mac_unit_emits_hlo():
    txt = aot.lower_mac_unit(8)
    assert txt.startswith("HloModule")
    assert f"s32[{aot.MAC_UNIT_WORDS}]" in txt


def test_quantized_layer_export_consistent(svm_r):
    exp = aot.quantized_layer_export(svm_r, 8)
    assert len(exp) == 1
    lq = svm_r.layer_quants(8)[0]
    assert exp[0]["fx"] == lq.fx and exp[0]["fw"] == lq.fw
    assert exp[0]["shift"] == lq.fx + lq.fw - lq.fy
    qw = np.asarray(exp[0]["qw"])
    assert qw.shape == (11, 1)
    assert qw.min() >= -128 and qw.max() <= 127
