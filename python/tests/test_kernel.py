"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp oracle vs the
plain-numpy contract.  Hypothesis sweeps shapes, precisions and value
ranges; every comparison is bit-exact (assert_array_equal), because the
kernels model integer hardware."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import quant
from compile.kernels import ref as kref
from compile.kernels import simd_mac

PRECISIONS = [32, 16, 8, 4]


def _rand_q(rng, shape, n):
    qmin, qmax = quant.qlimits(min(n, 16))  # operand magnitudes per contract
    return rng.integers(qmin, qmax + 1, size=shape).astype(np.int32)


# ---------------------------------------------------------------------------
# dense_acc: Pallas kernel vs jnp oracle vs numpy
# ---------------------------------------------------------------------------


@given(
    b=st.integers(min_value=1, max_value=200),
    k=st.integers(min_value=1, max_value=33),
    m=st.integers(min_value=1, max_value=9),
    n=st.sampled_from(PRECISIONS),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_dense_acc_matches_ref(b, k, m, n, seed):
    rng = np.random.default_rng(seed)
    qx = _rand_q(rng, (b, k), n)
    qw = _rand_q(rng, (k, m), n)
    acc_dtype = jnp.int64 if n == 32 else jnp.int32
    qb = rng.integers(-(2**20), 2**20, size=m).astype(np.int32)

    got = simd_mac.dense_acc(jnp.asarray(qx), jnp.asarray(qw), jnp.asarray(qb), acc_dtype)
    want = kref.dense_acc_ref(jnp.asarray(qx), jnp.asarray(qw), jnp.asarray(qb), acc_dtype)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # And against plain numpy, wrapped to the accumulator width (the dense
    # path wraps exactly like the hardware register when operands are
    # unconstrained; quant.layer_quant guarantees real models never wrap).
    np_exact = qx.astype(np.int64) @ qw.astype(np.int64) + qb[None, :].astype(np.int64)
    if acc_dtype == jnp.int32:
        np_exact = ((np_exact + 2**31) % 2**32 - 2**31).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(want, dtype=np.int64), np_exact.astype(np.int64))


def test_dense_acc_blocking_boundaries():
    """Shapes straddling the BlockSpec grid (127/128/129...) must agree."""
    rng = np.random.default_rng(7)
    for b in (127, 128, 129, 256, 257):
        qx = _rand_q(rng, (b, 21), 16)
        qw = _rand_q(rng, (21, 5), 16)
        qb = np.zeros(5, dtype=np.int32)
        got = simd_mac.dense_acc(jnp.asarray(qx), jnp.asarray(qw), jnp.asarray(qb))
        want = qx.astype(np.int64) @ qw.astype(np.int64)
        want = ((want + 2**31) % 2**32 - 2**31).astype(np.int32)
        np.testing.assert_array_equal(np.asarray(got), want)


def test_dense_acc_wide_n():
    """N wider than one block tile."""
    rng = np.random.default_rng(8)
    qx = _rand_q(rng, (16, 8), 8)
    qw = _rand_q(rng, (8, 300), 8)
    qb = rng.integers(-100, 100, size=300).astype(np.int32)
    got = simd_mac.dense_acc(jnp.asarray(qx), jnp.asarray(qw), jnp.asarray(qb))
    want = qx.astype(np.int64) @ qw.astype(np.int64) + qb[None, :]
    want = ((want + 2**31) % 2**32 - 2**31).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(got), want)


# ---------------------------------------------------------------------------
# packed_simd_mac: Pallas kernel vs jnp oracle vs numpy lane contract
# ---------------------------------------------------------------------------


@given(
    n=st.sampled_from(PRECISIONS),
    m=st.integers(min_value=1, max_value=128),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_packed_simd_mac_matches_ref(n, m, seed):
    rng = np.random.default_rng(seed)
    wa = rng.integers(-(2**31), 2**31, size=m).astype(np.int64).astype(np.int32)
    wb = rng.integers(-(2**31), 2**31, size=m).astype(np.int64).astype(np.int32)

    got = simd_mac.packed_simd_mac(jnp.asarray(wa), jnp.asarray(wb), n)
    want = kref.packed_simd_mac_ref(jnp.asarray(wa), jnp.asarray(wb), n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    # Numpy lane contract: unpack lanes, multiply, wrapping-i32 accumulate.
    la = quant.unpack_lanes(wa, n)
    lb = quant.unpack_lanes(wb, n)
    acc = np.sum(
        (la * lb).astype(np.int64), axis=0
    )  # exact, then wrap to i32:
    acc = ((acc + 2**31) % 2**32 - 2**31).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(got), acc)


def test_packed_simd_mac_lane_isolation():
    """A value in lane i must not perturb lane j accumulators."""
    for n in (16, 8, 4):
        L = quant.lanes(n)
        for i in range(L):
            lanes_a = np.zeros((3, L), dtype=np.int64)
            lanes_a[:, i] = [1, 2, 3]
            lanes_b = np.zeros((3, L), dtype=np.int64)
            lanes_b[:, i] = [4, 5, 6]
            wa = quant.pack_lanes(lanes_a, n).astype(np.int32)
            wb = quant.pack_lanes(lanes_b, n).astype(np.int32)
            got = np.asarray(simd_mac.packed_simd_mac(jnp.asarray(wa), jnp.asarray(wb), n))
            want = np.zeros(L, dtype=np.int32)
            want[i] = 1 * 4 + 2 * 5 + 3 * 6
            np.testing.assert_array_equal(got, want)


def test_packed_simd_mac_negative_lanes():
    """Sign extension across lanes (the ^sign - sign identity)."""
    n = 8
    lanes_a = np.array([[-128, -1, 127, -5]], dtype=np.int64)
    lanes_b = np.array([[127, -1, -128, 5]], dtype=np.int64)
    wa = quant.pack_lanes(lanes_a, n).astype(np.int32)
    wb = quant.pack_lanes(lanes_b, n).astype(np.int32)
    got = np.asarray(simd_mac.packed_simd_mac(jnp.asarray(wa), jnp.asarray(wb), n))
    np.testing.assert_array_equal(got, [-128 * 127, 1, 127 * -128, -25])


def test_packed_simd_mac_wraps_like_hardware():
    """32-bit accumulator wrap-around, as in the printed unit."""
    n = 16
    big = 32767
    m = 5000  # 5000 * 32767^2 ≈ 5.4e12 >> 2^31: must wrap
    lanes_a = np.full((m, 2), big, dtype=np.int64)
    lanes_b = np.full((m, 2), big, dtype=np.int64)
    wa = quant.pack_lanes(lanes_a, n).astype(np.int32)
    wb = quant.pack_lanes(lanes_b, n).astype(np.int32)
    got = np.asarray(simd_mac.packed_simd_mac(jnp.asarray(wa), jnp.asarray(wb), n))
    want = ((m * big * big + 2**31) % 2**32) - 2**31
    np.testing.assert_array_equal(got, [want, want])


# ---------------------------------------------------------------------------
# rescale_ref vs numpy contract
# ---------------------------------------------------------------------------


@given(
    shift=st.integers(min_value=0, max_value=20),
    n=st.sampled_from(PRECISIONS),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=30, deadline=None)
def test_rescale_ref_matches_numpy(shift, n, seed):
    rng = np.random.default_rng(seed)
    acc = rng.integers(-(2**40), 2**40, size=64)
    got = np.asarray(kref.rescale_ref(jnp.asarray(acc, dtype=jnp.int64), shift, n))
    want = quant.rescale(acc, shift, n)
    np.testing.assert_array_equal(got, want)
