"""Unit + property tests for the fixed-point contract (compile.quant)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import quant

PRECISIONS = [32, 16, 8, 4]


# ---------------------------------------------------------------------------
# frac_bits / int_bits
# ---------------------------------------------------------------------------


def test_int_bits_basics():
    assert quant.int_bits(0.5) == 0
    assert quant.int_bits(0.999) == 0
    assert quant.int_bits(1.0) == 1
    assert quant.int_bits(1.5) == 1
    assert quant.int_bits(2.0) == 2
    assert quant.int_bits(3.99) == 2
    assert quant.int_bits(4.0) == 3


@given(m=st.floats(min_value=1e-6, max_value=1e6), n=st.sampled_from(PRECISIONS))
def test_frac_bits_in_range(m, n):
    f = quant.frac_bits(m, n)
    assert 0 <= f <= n - 1


@given(m=st.floats(min_value=1e-3, max_value=1e3), n=st.sampled_from(PRECISIONS))
def test_frac_bits_representable(m, n):
    """Quantising +-m at the assigned f must not saturate badly: the value
    scaled by 2^f stays within ~2x of the quantisation range."""
    f = quant.frac_bits(m, n)
    if f == 0 and quant.int_bits(m) >= n:
        return  # magnitude exceeds the format entirely; saturation expected
    assert m * (1 << f) <= (1 << (n - 1))


# ---------------------------------------------------------------------------
# quantize / dequantize
# ---------------------------------------------------------------------------


@given(
    v=st.lists(st.floats(min_value=-4, max_value=4), min_size=1, max_size=64),
    n=st.sampled_from(PRECISIONS),
)
def test_quantize_dequantize_error_bound(v, n):
    v = np.asarray(v)
    f = quant.frac_bits(4.0, n)
    q = quant.quantize(v, f, n)
    qmin, qmax = quant.qlimits(n)
    assert q.min() >= qmin and q.max() <= qmax
    # In-range values round to within half an LSB.
    d = quant.dequantize(q, f)
    in_range = (v >= qmin / (1 << f)) & (v <= qmax / (1 << f))
    assert np.all(np.abs(d[in_range] - v[in_range]) <= 0.5 / (1 << f) + 1e-12)


def test_quantize_round_half_up():
    # floor(x + 0.5): 0.5 LSB rounds up, -0.5 LSB rounds toward zero/up.
    assert quant.quantize(np.array([0.5]), 0, 8)[0] == 1
    assert quant.quantize(np.array([-0.5]), 0, 8)[0] == 0
    assert quant.quantize(np.array([1.5]), 0, 8)[0] == 2
    assert quant.quantize(np.array([-1.5]), 0, 8)[0] == -1


def test_quantize_saturates():
    assert quant.quantize(np.array([1e9]), 4, 8)[0] == 127
    assert quant.quantize(np.array([-1e9]), 4, 8)[0] == -128


# ---------------------------------------------------------------------------
# rescale
# ---------------------------------------------------------------------------


@given(
    acc=st.lists(st.integers(min_value=-(2**40), max_value=2**40), min_size=1, max_size=32),
    shift=st.integers(min_value=0, max_value=24),
    n=st.sampled_from(PRECISIONS),
)
def test_rescale_matches_float_rounding(acc, shift, n):
    acc = np.asarray(acc, dtype=np.int64)
    got = quant.rescale(acc, shift, n)
    want = quant.sat(np.floor(acc / (1 << shift) + 0.5).astype(np.int64), n)
    np.testing.assert_array_equal(got, want)


def test_rescale_zero_shift_saturates_only():
    acc = np.array([300, -300, 5], dtype=np.int64)
    np.testing.assert_array_equal(quant.rescale(acc, 0, 8), [127, -128, 5])


# ---------------------------------------------------------------------------
# lane pack / unpack
# ---------------------------------------------------------------------------


@given(n=st.sampled_from([4, 8, 16, 32]), data=st.data())
@settings(max_examples=200)
def test_pack_unpack_roundtrip(n, data):
    L = quant.lanes(n)
    qmin, qmax = quant.qlimits(n)
    vals = data.draw(
        st.lists(
            st.lists(st.integers(min_value=qmin, max_value=qmax), min_size=L, max_size=L),
            min_size=1,
            max_size=16,
        )
    )
    q = np.asarray(vals, dtype=np.int64)
    word = quant.pack_lanes(q, n)
    # Packed words are valid signed 32-bit values.
    assert word.min() >= -(2**31) and word.max() <= 2**31 - 1
    np.testing.assert_array_equal(quant.unpack_lanes(word, n), q)


def test_pack_lane_order():
    # Lane 0 occupies the least-significant bits.
    w = quant.pack_lanes(np.array([[1, 2]]), 16)
    assert w[0] == (2 << 16) | 1


def test_lanes_counts():
    assert [quant.lanes(n) for n in (32, 16, 8, 4)] == [1, 2, 4, 8]
    # 4-bit TP-ISA datapath: no room to parallelise (paper §IV-A).
    assert quant.lanes(4, datapath=4) == 1


# ---------------------------------------------------------------------------
# layer_quant invariants
# ---------------------------------------------------------------------------


@given(
    n=st.sampled_from(PRECISIONS),
    mx=st.floats(min_value=0.1, max_value=8.0),
    mw=st.floats(min_value=0.01, max_value=8.0),
    my=st.floats(min_value=0.01, max_value=64.0),
    k=st.integers(min_value=1, max_value=64),
)
def test_layer_quant_invariants(n, mx, mw, my, k):
    lq = quant.layer_quant(n, mx, mw, my, k)
    assert lq.shift >= 0
    assert 0 <= lq.fx <= n - 1 and 0 <= lq.fw <= n - 1 and 0 <= lq.fy <= n - 1
    lq.check_no_overflow()


def test_dense_quantized_ref_exact_small():
    """Hand-computed tiny layer."""
    x = np.array([[0.5, 0.25]])
    w = np.array([[1.0], [2.0]])
    b = np.array([0.125])
    lq = quant.layer_quant(8, 1.0, 2.0, 2.0, 2)
    scores, acc = quant.dense_quantized_ref(x, w, b, lq, relu=False, last=True)
    # fx=6, fw=5: qx=[32,16], qw=[32,64], qb=0.125*2^11=256
    assert acc[0, 0] == 32 * 32 + 16 * 64 + 256
    assert scores[0, 0] == pytest.approx(acc[0, 0] / 2**11)
