import os
import sys

# Make `import compile...` work regardless of pytest invocation directory.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import compile  # noqa: F401  (enables jax x64 — required by the n=32 path)
