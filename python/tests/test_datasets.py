"""Dataset generator tests: determinism, shapes, normalisation, split."""

import numpy as np

from compile import datasets as dsets


def test_specs_match_uci_shapes():
    assert dsets.SPECS["cardio"].n_features == 21
    assert dsets.SPECS["cardio"].n_classes == 3
    assert dsets.SPECS["cardio"].n_rows == 2126
    assert dsets.SPECS["redwine"].n_features == 11
    assert dsets.SPECS["redwine"].n_rows == 1599
    assert dsets.SPECS["whitewine"].n_rows == 4898


def test_deterministic():
    a = dsets.generate(dsets.SPECS["cardio"])
    b = dsets.generate(dsets.SPECS["cardio"])
    np.testing.assert_array_equal(a.x_train, b.x_train)
    np.testing.assert_array_equal(a.y_test, b.y_test)


def test_normalised_to_unit_interval():
    for ds in dsets.generate_all().values():
        x = np.concatenate([ds.x_train, ds.x_test])
        assert x.min() >= 0.0 and x.max() <= 1.0
        # Every feature actually spans the interval (min-max normalised).
        assert np.allclose(x.min(axis=0), 0.0, atol=1e-6)
        assert np.allclose(x.max(axis=0), 1.0, atol=1e-6)


def test_split_ratio_and_counts():
    for name, ds in dsets.generate_all().items():
        n = len(ds.x_train) + len(ds.x_test)
        assert n == dsets.SPECS[name].n_rows
        frac = len(ds.x_train) / n
        assert abs(frac - 0.7) < 0.01


def test_labels_in_range():
    for ds in dsets.generate_all().values():
        y = np.concatenate([ds.y_train, ds.y_test])
        lo = ds.spec.label_offset
        hi = lo + ds.spec.n_classes - 1
        assert y.min() >= lo and y.max() <= hi
        # All classes present.
        assert len(np.unique(y)) == ds.spec.n_classes


def test_csv_export_roundtrip(tmp_path):
    ds = dsets.generate(dsets.SPECS["redwine"])
    paths = dsets.export_csv(ds, str(tmp_path))
    assert len(paths) == 2
    rows = open(paths[1]).read().strip().split("\n")
    assert rows[0].endswith(",label")
    assert len(rows) - 1 == len(ds.x_test)
    first = rows[1].split(",")
    assert len(first) == ds.spec.n_features + 1
    np.testing.assert_allclose(
        [float(v) for v in first[:-1]], ds.x_test[0], atol=1e-7
    )
    assert int(first[-1]) == ds.y_test[0]
