"""Build-time Python package (L1 + L2).  Never imported at runtime.

x64 is enabled globally: the n=32 precision configuration of the SIMD MAC
unit needs an exact int64 accumulator model (see compile.quant).
"""

import jax

jax.config.update("jax_enable_x64", True)
