"""Pallas kernels modelling the paper's SIMD MAC unit (Fig. 2).

Two kernels, both lowered with interpret=True (the CPU PJRT plugin cannot
execute Mosaic custom-calls; see /opt/xla-example/README.md):

* `dense_acc` — the *deployment* kernel: the quantised dense-layer MAC grid
  that the L2 model calls for every layer.  Tiled with BlockSpec over
  (batch, out-neuron) so each block's operand slices fit a VMEM-sized
  scratch on a real TPU; the K (fan-in) axis is kept whole per block
  because the paper's models have K <= 21.

* `packed_simd_mac` — the *hardware-faithful* kernel: word-level lane
  packing with wrapping 32-bit accumulators, bit-identical to the printed
  unit and to the rust ISS MAC model (`rust/src/sim/mac_model.rs`).  Used
  for cross-layer validation, not on the model path.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's unit is
a combinational printed-logic block; its core insight — sub-word lane
parallelism with per-lane accumulators — maps to the TPU as (a) BlockSpec
tiles sized for VMEM, (b) lane parallelism as vectorised integer ops on
the VPU (on a real TPU, n=16/8 packs into MXU bf16/int8 passes), (c) the
accumulate register as a carried block accumulator.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block sizes for the dense MAC grid.  The models in the paper are tiny
# (K <= 21, N <= 5), so a whole layer fits one block at batch tiles of 128;
# the grid only materialises for large batches.  VMEM estimate per block:
# (BM*K + K*BN + BM*BN) * 4B  <=  (128*32 + 32*128 + 128*128) * 4 ≈ 96 KiB.
BLOCK_B = 128
BLOCK_N = 128


def _dense_acc_kernel(x_ref, w_ref, b_ref, o_ref, *, acc_dtype):
    """One (BM, BN) tile: acc = x @ w + b, exact integer arithmetic."""
    x = x_ref[...].astype(acc_dtype)
    w = w_ref[...].astype(acc_dtype)
    acc = jnp.dot(x, w, preferred_element_type=acc_dtype)
    o_ref[...] = acc + b_ref[...].astype(acc_dtype)[None, :]


def dense_acc(qx: jnp.ndarray, qw: jnp.ndarray, qb: jnp.ndarray, acc_dtype=jnp.int32) -> jnp.ndarray:
    """Quantised dense-layer accumulator via the SIMD MAC kernel.

    qx: [B, K] int32; qw: [K, N] int32; qb: [N] int32/int64.
    Returns acc [B, N] in acc_dtype.  B and N are padded up to the block
    grid; K stays whole (tiny fan-ins in these models).
    """
    B, K = qx.shape
    K2, N = qw.shape
    assert K == K2 and qb.shape == (N,)
    bm, bn = min(BLOCK_B, B), min(BLOCK_N, N)
    # Pad to block multiples (zeros contribute nothing to the MAC).
    Bp = (B + bm - 1) // bm * bm
    Np = (N + bn - 1) // bn * bn
    if Bp != B:
        qx = jnp.pad(qx, ((0, Bp - B), (0, 0)))
    if Np != N:
        qw = jnp.pad(qw, ((0, 0), (0, Np - N)))
        qb = jnp.pad(qb, (0, Np - N))

    out = pl.pallas_call(
        functools.partial(_dense_acc_kernel, acc_dtype=acc_dtype),
        grid=(Bp // bm, Np // bn),
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Np), acc_dtype),
        interpret=True,
    )(qx, qw, qb)
    return out[:B, :N]


def _packed_kernel(wa_ref, wb_ref, o_ref, *, n: int):
    """Word-level SIMD MAC: all M words, L = 32/n lanes each, wrapping i32
    per-lane accumulators — the printed unit's exact semantics."""
    L = max(1, 32 // n)
    wa = wa_ref[...]
    wb = wb_ref[...]
    sign = jnp.int32(1 << (n - 1)) if n < 32 else None
    mask = jnp.int32((1 << n) - 1) if n < 32 else None
    accs = []
    for i in range(L):
        if n == 32:
            a, b = wa, wb
        else:
            a = ((wa >> (n * i)) & mask ^ sign) - sign
            b = ((wb >> (n * i)) & mask ^ sign) - sign
        accs.append(jnp.sum(a * b, dtype=jnp.int32))
    o_ref[...] = jnp.stack(accs)


def packed_simd_mac(wa: jnp.ndarray, wb: jnp.ndarray, n: int) -> jnp.ndarray:
    """Execute M packed MAC instructions; returns the L lane accumulators.

    wa, wb: [M] int32.  Bit-identical to kernels.ref.packed_simd_mac_ref
    and to the rust `sim::mac_model`.
    """
    assert wa.shape == wb.shape and wa.ndim == 1
    L = max(1, 32 // n)
    return pl.pallas_call(
        functools.partial(_packed_kernel, n=n),
        out_shape=jax.ShapeDtypeStruct((L,), jnp.int32),
        interpret=True,
    )(wa.astype(jnp.int32), wb.astype(jnp.int32))
