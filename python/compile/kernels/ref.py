"""Pure-jnp correctness oracles for the Pallas kernels.

These are the CORE correctness signal of the L1 layer: the pytest suite
asserts bit-equality between each Pallas kernel (interpret=True) and the
oracle here, and the numpy contract in `compile.quant` validates the
oracle itself.  The rust bit-exact model mirrors the same semantics.
"""

from __future__ import annotations

import jax.numpy as jnp


def dense_acc_ref(qx: jnp.ndarray, qw: jnp.ndarray, qb: jnp.ndarray, acc_dtype) -> jnp.ndarray:
    """Exact integer accumulator of one dense layer.

    qx: [B, K] int32 quantised activations
    qw: [K, N] int32 quantised weights
    qb: [N]    int accumulator-scale bias
    Returns acc [B, N] in `acc_dtype` (int32 for n<=16, int64 for n=32).
    """
    return jnp.dot(qx.astype(acc_dtype), qw.astype(acc_dtype)) + qb.astype(acc_dtype)[None, :]


def _sign_extend(lane: jnp.ndarray, n: int) -> jnp.ndarray:
    """Sign-extend the low n bits of an int32 lane value (branch-free;
    the identical identity is used in the rust MAC model)."""
    sign = jnp.int32(1 << (n - 1))
    return (lane ^ sign) - sign


def unpack_lane(word: jnp.ndarray, lane: int, n: int) -> jnp.ndarray:
    """Extract signed lane `lane` (n bits) from a packed 32-bit word."""
    if n == 32:
        return word
    mask = jnp.int32((1 << n) - 1)
    v = (word >> (n * lane)) & mask
    return _sign_extend(v, n)


def packed_simd_mac_ref(wa: jnp.ndarray, wb: jnp.ndarray, n: int) -> jnp.ndarray:
    """Word-level SIMD MAC oracle (paper Fig. 2 / Eq. 1).

    wa, wb: [M] int32 packed operand streams (32/n lanes per word).
    Executes M MAC instructions: for each word, every lane multiplies and
    adds into its private accumulator.  Accumulators are 32-bit and WRAP,
    exactly like the hardware unit.  Returns acc [L] int32.
    """
    L = max(1, 32 // n)
    accs = []
    for i in range(L):
        a = unpack_lane(wa, i, n)
        b = unpack_lane(wb, i, n)
        accs.append(jnp.sum(a * b, dtype=jnp.int32))  # wrapping int32 sum
    return jnp.stack(accs)


def rescale_ref(acc: jnp.ndarray, shift: int, n: int) -> jnp.ndarray:
    """Round-half-up arithmetic shift + saturation to n bits (see quant.py)."""
    if shift > 0:
        acc = (acc + (1 << (shift - 1))) >> shift
    elif shift < 0:
        acc = acc << (-shift)
    qmin, qmax = -(1 << (n - 1)), (1 << (n - 1)) - 1
    return jnp.clip(acc, qmin, qmax)
