"""Synthetic stand-ins for the UCI datasets used by the paper.

The paper trains on Cardiotocography, RedWine and WhiteWine from the UCI
repository.  This image has no network access, so we generate deterministic
synthetic datasets with the *same shapes and statistical regime* as the
real ones (feature count, class count, row count, [0,1] normalised
features, separable-but-noisy class structure).  DESIGN.md documents the
substitution; the paper's accuracy-loss-vs-precision curves depend on the
feature scale and model capacity, both of which are preserved.

All generation is NumPy with fixed seeds so `make artifacts` is
reproducible bit-for-bit.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field

import numpy as np

# ---------------------------------------------------------------------------
# Dataset specs (mirroring the UCI originals)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DatasetSpec:
    """Static description of one dataset."""

    name: str
    n_features: int
    n_rows: int
    task: str  # "classification" | "regression"
    n_classes: int  # classes for classification; distinct quality levels for regression
    label_offset: int  # regression: first quality level (e.g. wine quality 3)
    seed: int
    class_sep: float  # distance between class centroids (pre-normalisation)
    noise: float  # within-class standard deviation


# The three datasets the paper evaluates on.  Row/feature/class counts match
# the UCI originals (Cardiotocography NSP 3-class, wine quality levels).
SPECS: dict[str, DatasetSpec] = {
    "cardio": DatasetSpec(
        name="cardio",
        n_features=21,
        n_rows=2126,
        task="classification",
        n_classes=3,
        label_offset=0,
        seed=0xC0FFEE,
        class_sep=0.55,
        noise=1.2,
    ),
    "redwine": DatasetSpec(
        name="redwine",
        n_features=11,
        n_rows=1599,
        task="regression",
        n_classes=6,  # quality 3..8
        label_offset=3,
        seed=0x7ED,
        class_sep=1.3,
        noise=1.0,
    ),
    "whitewine": DatasetSpec(
        name="whitewine",
        n_features=11,
        n_rows=4898,
        task="regression",
        n_classes=7,  # quality 3..9
        label_offset=3,
        seed=0x3417E,
        class_sep=1.3,
        noise=1.0,
    ),
}

TRAIN_FRACTION = 0.7  # 70/30 split, as in the paper


@dataclass
class Dataset:
    """A generated dataset, already normalised and split."""

    spec: DatasetSpec
    x_train: np.ndarray = field(repr=False)
    y_train: np.ndarray = field(repr=False)
    x_test: np.ndarray = field(repr=False)
    y_test: np.ndarray = field(repr=False)

    @property
    def name(self) -> str:
        return self.spec.name


# ---------------------------------------------------------------------------
# Generation
# ---------------------------------------------------------------------------


def _class_counts(spec: DatasetSpec, rng: np.random.Generator) -> np.ndarray:
    """Imbalanced class sizes (UCI cardio/wine are heavily imbalanced)."""
    if spec.task == "classification":
        # Cardiotocography NSP: roughly 78/14/8.
        props = np.array([0.78, 0.14, 0.08])[: spec.n_classes]
    else:
        # Wine quality: roughly normal around the middle levels.
        levels = np.arange(spec.n_classes)
        mid = (spec.n_classes - 1) / 2.0
        props = np.exp(-0.5 * ((levels - mid) / 1.1) ** 2)
    props = props / props.sum()
    counts = np.floor(props * spec.n_rows).astype(int)
    counts[0] += spec.n_rows - counts.sum()
    return counts


def generate(spec: DatasetSpec) -> Dataset:
    """Generate one dataset: Gaussian class clusters on a random low-rank
    structure, min-max normalised to [0, 1] (as the paper normalises its
    inputs), split 70/30."""
    rng = np.random.default_rng(spec.seed)
    counts = _class_counts(spec, rng)

    # Class centroids along a smooth direction for regression (quality is
    # ordinal) and spread out for classification.
    base_dir = rng.normal(size=spec.n_features)
    base_dir /= np.linalg.norm(base_dir)
    xs, ys = [], []
    for cls, cnt in enumerate(counts):
        if spec.task == "regression":
            # Ordinal: centroids progress along base_dir with per-class jitter.
            centroid = base_dir * spec.class_sep * cls + rng.normal(
                scale=0.35, size=spec.n_features
            )
        else:
            centroid = rng.normal(scale=spec.class_sep, size=spec.n_features)
        pts = centroid + rng.normal(scale=spec.noise, size=(cnt, spec.n_features))
        xs.append(pts)
        ys.append(np.full(cnt, cls + spec.label_offset, dtype=np.int64))
    x = np.concatenate(xs, axis=0)
    y = np.concatenate(ys, axis=0)

    # Shuffle rows.
    perm = rng.permutation(len(x))
    x, y = x[perm], y[perm]

    # Min-max normalise each feature to [0, 1] (paper: "Input features are
    # normalized to the range [0, 1]").
    lo, hi = x.min(axis=0), x.max(axis=0)
    x = (x - lo) / np.maximum(hi - lo, 1e-9)

    n_train = int(round(TRAIN_FRACTION * len(x)))
    return Dataset(
        spec=spec,
        x_train=x[:n_train].astype(np.float32),
        y_train=y[:n_train],
        x_test=x[n_train:].astype(np.float32),
        y_test=y[n_train:],
    )


def generate_all() -> dict[str, Dataset]:
    return {name: generate(spec) for name, spec in SPECS.items()}


# ---------------------------------------------------------------------------
# CSV export (consumed by the rust layer)
# ---------------------------------------------------------------------------


def export_csv(ds: Dataset, out_dir: str) -> list[str]:
    """Write <name>_train.csv / <name>_test.csv: feature columns then label."""
    os.makedirs(out_dir, exist_ok=True)
    paths = []
    for split, xs, ys in (
        ("train", ds.x_train, ds.y_train),
        ("test", ds.x_test, ds.y_test),
    ):
        path = os.path.join(out_dir, f"{ds.name}_{split}.csv")
        header = ",".join(f"f{i}" for i in range(ds.spec.n_features)) + ",label"
        rows = [header]
        for xi, yi in zip(xs, ys):
            rows.append(",".join(f"{v:.8f}" for v in xi) + f",{int(yi)}")
        with open(path, "w") as f:
            f.write("\n".join(rows) + "\n")
        paths.append(path)
    return paths


def export_all(out_dir: str) -> dict[str, list[str]]:
    return {name: export_csv(ds, out_dir) for name, ds in generate_all().items()}
