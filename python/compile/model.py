"""L2 — the paper's ML models (3 MLPs, 3 SVMs) in JAX.

Every model is a stack of dense layers plus a *head*:

* ``argmax``   — MLP-C / classification logits (Cardiotocography).
* ``ovo_vote`` — SVM-C one-vs-one: per-pair linear decisions voted into
                 per-class counts (paper: "one-vs-one classification
                 strategy").
* ``round``    — MLP-R / SVM-R regression on wine quality; the prediction
                 is the rounded scalar output.

Two forward paths:

* ``float_forward``      — f32 reference (training + float accuracy).
* ``quantized_forward``  — the bespoke-core path: in-graph quantisation,
  the L1 Pallas SIMD-MAC kernel per layer, integer rescale, dequantised
  float scores out.  This is what gets AOT-lowered to HLO and executed by
  the rust runtime; it is bit-identical to the rust ISS running the
  code-generated assembly of the same model.

The HLO interface is uniform across heads:  ``f32[B, K] -> (f32[B, C],)``
where C = n_classes (argmax/ovo as per-class scores/votes) or 1 (round).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from . import quant
from .kernels import ref as kref
from .kernels import simd_mac

PRECISIONS = (32, 16, 8, 4)  # the unit's 4 precision configurations


@dataclass
class DenseLayer:
    w: np.ndarray  # [K, N] float32
    b: np.ndarray  # [N] float32
    relu: bool


@dataclass
class Model:
    """One trained model plus the calibration needed for quantisation."""

    name: str
    dataset: str
    task: str  # "classification" | "regression"
    head: str  # "argmax" | "ovo_vote" | "round"
    layers: list[DenseLayer]
    # max-abs of the activation tensor at each layer boundary (len = L+1):
    # calib[0] is the input (1.0 for [0,1]-normalised features).
    calib: list[float]
    n_classes: int
    label_offset: int
    ovo_pairs: list[tuple[int, int]] = field(default_factory=list)
    float_accuracy: float = 0.0

    @property
    def arch(self) -> list[int]:
        return [self.layers[0].w.shape[0]] + [l.w.shape[1] for l in self.layers]

    def layer_quants(self, n: int) -> list[quant.LayerQuant]:
        """Per-layer quantisation parameters for precision n, derived as a
        chain (layer i's fy == layer i+1's fx — they are the same tensor).
        The derived values are baked into the weights JSON for rust."""
        stats = [
            (float(np.max(np.abs(l.w))), self.calib[i + 1], l.w.shape[0])
            for i, l in enumerate(self.layers)
        ]
        return quant.derive_chain(n, self.calib[0], stats)


# ---------------------------------------------------------------------------
# Heads
# ---------------------------------------------------------------------------


def _head_scores(model: Model, raw: jnp.ndarray) -> jnp.ndarray:
    """Map the last layer's float outputs to the uniform [B, C] score tensor."""
    if model.head == "argmax":
        return raw  # logits, C = n_classes
    if model.head == "round":
        return raw  # scalar quality estimate, C = 1
    if model.head == "ovo_vote":
        # raw: [B, P] pair decision values; pair (i, j): >= 0 votes i else j.
        votes = []
        for c in range(model.n_classes):
            v = jnp.zeros(raw.shape[0], dtype=jnp.float32)
            for p, (i, j) in enumerate(model.ovo_pairs):
                if i == c:
                    v = v + (raw[:, p] >= 0.0).astype(jnp.float32)
                elif j == c:
                    v = v + (raw[:, p] < 0.0).astype(jnp.float32)
            votes.append(v)
        return jnp.stack(votes, axis=1)
    raise ValueError(f"unknown head {model.head}")


def predict_from_scores(model: Model, scores: np.ndarray) -> np.ndarray:
    """Scores [B, C] -> integer label predictions (mirrored in rust)."""
    scores = np.asarray(scores)
    if model.head == "round":
        return np.clip(
            np.floor(scores[:, 0] + 0.5).astype(np.int64),
            model.label_offset,
            model.label_offset + model.n_classes - 1,
        )
    return np.argmax(scores, axis=1) + model.label_offset


# ---------------------------------------------------------------------------
# Forward paths
# ---------------------------------------------------------------------------


def float_forward(model: Model, x: jnp.ndarray) -> jnp.ndarray:
    """f32 reference forward; returns the uniform [B, C] score tensor."""
    h = x
    for layer in model.layers:
        h = h @ jnp.asarray(layer.w) + jnp.asarray(layer.b)
        if layer.relu:
            h = jnp.maximum(h, 0.0)
    return _head_scores(model, h)


def quantized_forward(
    model: Model, x: jnp.ndarray, n: int, use_pallas: bool = True
) -> jnp.ndarray:
    """Bespoke-core forward at precision n: quantise -> SIMD MAC (Pallas)
    -> integer rescale -> dequantised float scores.

    ``use_pallas=False`` swaps in the jnp oracle (kernels.ref) — the pytest
    suite asserts both paths are bit-identical.
    """
    lqs = model.layer_quants(n)
    acc_dtype = jnp.int64 if n == 32 else jnp.int32

    # In-graph input quantisation (round-half-up, matching
    # quant.quantize).  f64 arithmetic: at n=32 the scaled values exceed
    # the f32 mantissa and would diverge from the rust/numpy contract.
    lq0 = lqs[0]
    qmin, qmax = quant.qlimits(n)
    h = jnp.clip(
        jnp.floor(x.astype(jnp.float64) * (1 << lq0.fx) + 0.5), qmin, qmax
    ).astype(jnp.int32)

    raw = None
    for i, (layer, lq) in enumerate(zip(model.layers, lqs)):
        qw = jnp.asarray(quant.quantize(layer.w, lq.fw, lq.n), dtype=jnp.int32)
        qb = jnp.asarray(
            quant.quantize(layer.b, lq.fx + lq.fw, 32 if n <= 16 else 64),
            dtype=acc_dtype,
        )
        if use_pallas:
            acc = simd_mac.dense_acc(h, qw, qb, acc_dtype=acc_dtype)
        else:
            acc = kref.dense_acc_ref(h, qw, qb, acc_dtype)
        last = i == len(model.layers) - 1
        if last:
            # Dequantise in f64 (exact for the i64 accumulators), then
            # narrow to the f32 interface.
            raw = (acc.astype(jnp.float64) / np.float64(2.0 ** (lq.fx + lq.fw))).astype(
                jnp.float32
            )
        else:
            y = kref.rescale_ref(acc, lq.shift, lq.n)
            if layer.relu:
                y = jnp.maximum(y, 0)
            h = y.astype(jnp.int32)
    return _head_scores(model, raw)


# ---------------------------------------------------------------------------
# Accuracy helpers (used by train.py and the pytest suite)
# ---------------------------------------------------------------------------


def accuracy(model: Model, scores: np.ndarray, labels: np.ndarray) -> float:
    """Top-1 accuracy (regression: rounded-quality exact match, as the
    paper reports a single 'accuracy' metric for all six models)."""
    pred = predict_from_scores(model, scores)
    return float(np.mean(pred == np.asarray(labels)))


# ---------------------------------------------------------------------------
# (De)serialisation — the weights JSON consumed by the rust layer
# ---------------------------------------------------------------------------


def to_json_dict(model: Model) -> dict:
    return {
        "name": model.name,
        "dataset": model.dataset,
        "task": model.task,
        "head": model.head,
        "arch": model.arch,
        "n_classes": model.n_classes,
        "label_offset": model.label_offset,
        "ovo_pairs": [list(p) for p in model.ovo_pairs],
        "calib": [float(c) for c in model.calib],
        "float_accuracy": model.float_accuracy,
        "layers": [
            {
                "relu": l.relu,
                "w": [[float(v) for v in row] for row in l.w],
                "b": [float(v) for v in l.b],
            }
            for l in model.layers
        ],
    }


def from_json_dict(d: dict) -> Model:
    return Model(
        name=d["name"],
        dataset=d["dataset"],
        task=d["task"],
        head=d["head"],
        layers=[
            DenseLayer(
                w=np.asarray(l["w"], dtype=np.float32),
                b=np.asarray(l["b"], dtype=np.float32),
                relu=bool(l["relu"]),
            )
            for l in d["layers"]
        ],
        calib=[float(c) for c in d["calib"]],
        n_classes=int(d["n_classes"]),
        label_offset=int(d["label_offset"]),
        ovo_pairs=[tuple(p) for p in d["ovo_pairs"]],
        float_accuracy=float(d["float_accuracy"]),
    )
