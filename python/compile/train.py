"""L2 training — JAX stands in for the paper's scikit-learn flow.

The paper trains with scikit-learn (RandomizedSearchCV, 5-fold CV); this
image has no sklearn and no UCI access, so we train the same model
families in JAX on the synthetic datasets (DESIGN.md documents the
substitution).  Hyperparameters respect the paper's envelope: MLPs use a
single hidden layer of at most five ReLU neurons; SVMs are linear, with
one-vs-one classification.

A small hand-rolled Adam (no optax offline) drives full-batch training —
the datasets are tiny.  Everything is seeded and deterministic.
"""

from __future__ import annotations

import itertools

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets as dsets
from .model import DenseLayer, Model, accuracy, float_forward

HIDDEN = 5  # paper: "a single hidden layer with up to five neurons"


# ---------------------------------------------------------------------------
# Hand-rolled Adam
# ---------------------------------------------------------------------------


def adam(params, grads, state, lr, step, b1=0.9, b2=0.999, eps=1e-8):
    m, v = state
    m = jax.tree.map(lambda mi, g: b1 * mi + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda vi, g: b2 * vi + (1 - b2) * g * g, v, grads)
    mh = jax.tree.map(lambda mi: mi / (1 - b1**step), m)
    vh = jax.tree.map(lambda vi: vi / (1 - b2**step), v)
    params = jax.tree.map(lambda p, mi, vi: p - lr * mi / (jnp.sqrt(vi) + eps), params, mh, vh)
    return params, (m, v)


def _fit(loss_fn, params, epochs: int, lr: float):
    """Full-batch Adam on `loss_fn(params)`."""
    state = (
        jax.tree.map(jnp.zeros_like, params),
        jax.tree.map(jnp.zeros_like, params),
    )
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    for step in range(1, epochs + 1):
        _, grads = grad_fn(params)
        params, state = adam(params, grads, state, lr, step)
    return params


# ---------------------------------------------------------------------------
# Model trainers
# ---------------------------------------------------------------------------


def _mlp_params(key, k_in: int, hidden: int, k_out: int):
    k1, k2 = jax.random.split(key)
    scale1 = float(np.sqrt(2.0 / k_in))
    scale2 = float(np.sqrt(2.0 / hidden))
    return {
        "w1": jax.random.normal(k1, (k_in, hidden), jnp.float32) * scale1,
        "b1": jnp.zeros(hidden, jnp.float32),
        "w2": jax.random.normal(k2, (hidden, k_out), jnp.float32) * scale2,
        "b2": jnp.zeros(k_out, jnp.float32),
    }


def _mlp_fwd(p, x):
    h = jnp.maximum(x @ p["w1"] + p["b1"], 0.0)
    return h @ p["w2"] + p["b2"]


def train_mlp_classifier(ds: dsets.Dataset, seed: int = 1) -> Model:
    """MLP-C: 1 hidden ReLU layer, softmax cross-entropy."""
    x = jnp.asarray(ds.x_train)
    y = jnp.asarray(ds.y_train - ds.spec.label_offset)
    params = _mlp_params(jax.random.PRNGKey(seed), ds.spec.n_features, HIDDEN, ds.spec.n_classes)

    def loss(p):
        logits = _mlp_fwd(p, x)
        logp = jax.nn.log_softmax(logits)
        ce = -jnp.mean(logp[jnp.arange(len(y)), y])
        l2 = 1e-4 * (jnp.sum(p["w1"] ** 2) + jnp.sum(p["w2"] ** 2))
        return ce + l2

    params = _fit(loss, params, epochs=800, lr=0.02)
    return _finalize(ds, "mlp_c", "argmax", params)


def train_mlp_regressor(ds: dsets.Dataset, seed: int = 2) -> Model:
    """MLP-R: 1 hidden ReLU layer, MSE on the raw quality value."""
    x = jnp.asarray(ds.x_train)
    y = jnp.asarray(ds.y_train, dtype=jnp.float32)
    params = _mlp_params(jax.random.PRNGKey(seed), ds.spec.n_features, HIDDEN, 1)

    def loss(p):
        pred = _mlp_fwd(p, x)[:, 0]
        l2 = 1e-4 * (jnp.sum(p["w1"] ** 2) + jnp.sum(p["w2"] ** 2))
        return jnp.mean((pred - y) ** 2) + l2

    params = _fit(loss, params, epochs=800, lr=0.02)
    return _finalize(ds, "mlp_r", "round", params)


def train_svm_classifier(ds: dsets.Dataset, seed: int = 3) -> Model:
    """SVM-C: linear one-vs-one with squared hinge loss per pair."""
    pairs = list(itertools.combinations(range(ds.spec.n_classes), 2))
    k = ds.spec.n_features
    ws, bs = [], []
    for p_idx, (ci, cj) in enumerate(pairs):
        mask = np.isin(ds.y_train - ds.spec.label_offset, (ci, cj))
        xp = jnp.asarray(ds.x_train[mask])
        # +1 for class ci, -1 for class cj.
        yp = jnp.asarray(
            np.where((ds.y_train - ds.spec.label_offset)[mask] == ci, 1.0, -1.0),
            dtype=jnp.float32,
        )
        key = jax.random.PRNGKey(seed * 100 + p_idx)
        params = {
            "w": jax.random.normal(key, (k,), jnp.float32) * 0.01,
            "b": jnp.zeros((), jnp.float32),
        }

        def loss(p, xp=xp, yp=yp):
            margin = yp * (xp @ p["w"] + p["b"])
            hinge = jnp.mean(jnp.maximum(0.0, 1.0 - margin) ** 2)
            return hinge + 1e-3 * jnp.sum(p["w"] ** 2)

        params = _fit(loss, params, epochs=600, lr=0.05)
        ws.append(np.asarray(params["w"]))
        bs.append(float(params["b"]))

    w = np.stack(ws, axis=1).astype(np.float32)  # [K, P]
    b = np.asarray(bs, dtype=np.float32)
    layers = [DenseLayer(w=w, b=b, relu=False)]
    return _build_model(ds, "svm_c", "ovo_vote", layers, ovo_pairs=pairs)


def train_svm_regressor(ds: dsets.Dataset, seed: int = 4) -> Model:
    """SVM-R: linear epsilon-insensitive regression (smoothed) + ridge."""
    x = jnp.asarray(ds.x_train)
    y = jnp.asarray(ds.y_train, dtype=jnp.float32)
    key = jax.random.PRNGKey(seed)
    params = {
        "w": jax.random.normal(key, (ds.spec.n_features, 1), jnp.float32) * 0.01,
        "b": jnp.zeros(1, jnp.float32),
    }

    def loss(p):
        pred = (x @ p["w"] + p["b"])[:, 0]
        err = jnp.abs(pred - y)
        eps_ins = jnp.maximum(0.0, err - 0.2) ** 2  # smoothed eps-insensitive
        return jnp.mean(eps_ins) + 1e-3 * jnp.sum(p["w"] ** 2)

    params = _fit(loss, params, epochs=800, lr=0.05)
    layers = [DenseLayer(w=np.asarray(params["w"]), b=np.asarray(params["b"]), relu=False)]
    return _build_model(ds, "svm_r", "round", layers)


# ---------------------------------------------------------------------------
# Calibration + packaging
# ---------------------------------------------------------------------------


def _finalize(ds: dsets.Dataset, kind: str, head: str, params) -> Model:
    layers = [
        DenseLayer(w=np.asarray(params["w1"]), b=np.asarray(params["b1"]), relu=True),
        DenseLayer(w=np.asarray(params["w2"]), b=np.asarray(params["b2"]), relu=False),
    ]
    return _build_model(ds, kind, head, layers)


def _build_model(
    ds: dsets.Dataset,
    kind: str,
    head: str,
    layers: list[DenseLayer],
    ovo_pairs: list[tuple[int, int]] | None = None,
) -> Model:
    model = Model(
        name=f"{kind}_{ds.name}",
        dataset=ds.name,
        task=ds.spec.task,
        head=head,
        layers=layers,
        calib=[],
        n_classes=ds.spec.n_classes,
        label_offset=ds.spec.label_offset,
        ovo_pairs=ovo_pairs or [],
    )
    model.calib = _calibrate(model, ds.x_train)
    scores = np.asarray(float_forward(model, jnp.asarray(ds.x_test)))
    model.float_accuracy = accuracy(model, scores, ds.y_test)
    return model


def _calibrate(model: Model, x_train: np.ndarray) -> list[float]:
    """Max-abs activation at each layer boundary over the training set —
    the statistics that fix the fixed-point formats (quant.layer_quant).
    A 1.10 safety margin covers test-set excursions."""
    calib = [1.0]  # inputs are [0,1]-normalised
    h = jnp.asarray(x_train)
    for layer in model.layers:
        h = h @ jnp.asarray(layer.w) + jnp.asarray(layer.b)
        if layer.relu:
            h = jnp.maximum(h, 0.0)
        calib.append(float(jnp.max(jnp.abs(h))) * 1.10 + 1e-6)
    return calib


# ---------------------------------------------------------------------------
# Entry: train all six models of the paper's evaluation
# ---------------------------------------------------------------------------


def train_all(data: dict[str, dsets.Dataset] | None = None) -> list[Model]:
    """3 MLPs + 3 SVMs: MLP-C/SVM-C on cardio, MLP-R/SVM-R on both wines."""
    data = data or dsets.generate_all()
    return [
        train_mlp_classifier(data["cardio"]),
        train_mlp_regressor(data["redwine"]),
        train_mlp_regressor(data["whitewine"]),
        train_svm_classifier(data["cardio"]),
        train_svm_regressor(data["redwine"]),
        train_svm_regressor(data["whitewine"]),
    ]
