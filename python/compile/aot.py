"""AOT pipeline: datasets -> trained weights -> HLO-text artifacts.

Runs ONCE at build time (`make artifacts`); the rust binary is then fully
self-contained.  Emits:

    artifacts/data/<ds>_{train,test}.csv      datasets for the rust layer
    artifacts/weights/<model>.json            weights + calibration + per-
                                              precision quantised tensors
    artifacts/hlo/<model>_float.hlo.txt       f32 reference forward
    artifacts/hlo/<model>_p{32,16,8,4}.hlo.txt  quantised forward (Pallas
                                              SIMD-MAC kernel inside)
    artifacts/hlo/simd_mac_unit_p{n}.hlo.txt  packed word-level MAC unit
                                              (runtime unit-test artifacts)
    artifacts/manifest.json                   index + python-side accuracy

HLO *text* is the interchange format, not serialised protos: jax >= 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datasets as dsets
from . import quant, train
from .model import Model, PRECISIONS, accuracy, float_forward, quantized_forward, to_json_dict

BATCH = 256  # fixed batch dim of every model executable; rust pads chunks
MAC_UNIT_WORDS = 64  # stream length of the packed-MAC unit-test artifacts


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the 0.5.1-safe path).

    GOTCHA: the default printer elides constants above ~10 elements as
    ``constant({...})`` — which the consumer-side text parser silently
    turns into zeros, wiping the baked-in model weights.  Print with
    ``print_large_constants`` so the artifact is self-contained.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # Modern metadata attributes (source_end_line, ...) are rejected by
    # the consumer-side (xla_extension 0.5.1) HLO text parser.
    opts.print_metadata = False
    text = comp.as_hlo_module().to_string(opts)
    assert "{...}" not in text, "HLO printer still eliding constants"
    return text


def lower_model(model: Model, precision: int | None) -> str:
    """Lower one forward variant at the fixed batch shape to HLO text."""
    k = model.arch[0]
    spec = jax.ShapeDtypeStruct((BATCH, k), jnp.float32)
    if precision is None:
        fn = lambda x: (float_forward(model, x),)
    else:
        fn = lambda x: (quantized_forward(model, x, precision),)
    return to_hlo_text(jax.jit(fn).lower(spec))


def lower_mac_unit(n: int) -> str:
    """Lower the packed word-level SIMD MAC kernel (hardware-faithful)."""
    from .kernels import simd_mac

    spec = jax.ShapeDtypeStruct((MAC_UNIT_WORDS,), jnp.int32)
    fn = lambda a, b: (simd_mac.packed_simd_mac(a, b, n),)
    return to_hlo_text(jax.jit(fn).lower(spec, spec))


def quantized_layer_export(model: Model, n: int) -> list[dict]:
    """Integer weight/bias tensors + formats at precision n — baked into
    the weights JSON so the rust layer shares the exact same numbers."""
    out = []
    for layer, lq in zip(model.layers, model.layer_quants(n)):
        qw = quant.quantize(layer.w, lq.fw, lq.n)
        qb = quant.quantize(layer.b, lq.fx + lq.fw, 32 if n <= 16 else 64)
        out.append(
            {
                "fx": lq.fx,
                "fw": lq.fw,
                "fy": lq.fy,
                "shift": lq.shift,
                "qw": [[int(v) for v in row] for row in qw],
                "qb": [int(v) for v in qb],
            }
        )
    return out


def eval_quantized(model: Model, ds: dsets.Dataset, n: int) -> float:
    """Python-side quantised accuracy (jnp oracle path) — recorded in the
    manifest and cross-checked by the rust coordinator."""
    scores = np.asarray(
        quantized_forward(model, jnp.asarray(ds.x_test), n, use_pallas=False)
    )
    return accuracy(model, scores, ds.y_test)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    args = ap.parse_args()
    out = os.path.abspath(args.out)
    for sub in ("data", "weights", "hlo"):
        os.makedirs(os.path.join(out, sub), exist_ok=True)

    print("[aot] generating datasets")
    data = dsets.generate_all()
    for ds in data.values():
        dsets.export_csv(ds, os.path.join(out, "data"))

    print("[aot] training 6 models (JAX)")
    models = train.train_all(data)

    manifest: dict = {"batch": BATCH, "precisions": list(PRECISIONS), "models": []}

    for model in models:
        ds = data[model.dataset]
        entry = {
            "name": model.name,
            "dataset": model.dataset,
            "head": model.head,
            "arch": model.arch,
            "n_classes": model.n_classes,
            "label_offset": model.label_offset,
            "n_test": int(len(ds.y_test)),
            "float_accuracy": model.float_accuracy,
            "weights": f"weights/{model.name}.json",
            "hlo": {"float": f"hlo/{model.name}_float.hlo.txt"},
            "quant_accuracy": {},
        }

        wj = to_json_dict(model)
        wj["quantized"] = {str(n): quantized_layer_export(model, n) for n in PRECISIONS}
        with open(os.path.join(out, entry["weights"]), "w") as f:
            json.dump(wj, f)

        print(f"[aot] {model.name}: float acc {model.float_accuracy:.4f}; lowering")
        with open(os.path.join(out, entry["hlo"]["float"]), "w") as f:
            f.write(lower_model(model, None))
        for n in PRECISIONS:
            acc = eval_quantized(model, ds, n)
            entry["quant_accuracy"][str(n)] = acc
            rel = f"hlo/{model.name}_p{n}.hlo.txt"
            entry["hlo"][f"p{n}"] = rel
            with open(os.path.join(out, rel), "w") as f:
                f.write(lower_model(model, n))
            print(f"[aot]   p{n}: acc {acc:.4f}")
        manifest["models"].append(entry)

    print("[aot] lowering packed SIMD MAC unit kernels")
    manifest["mac_units"] = {}
    for n in PRECISIONS:
        rel = f"hlo/simd_mac_unit_p{n}.hlo.txt"
        manifest["mac_units"][str(n)] = {"path": rel, "words": MAC_UNIT_WORDS}
        with open(os.path.join(out, rel), "w") as f:
            f.write(lower_mac_unit(n))

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {out}/manifest.json")


if __name__ == "__main__":
    main()
