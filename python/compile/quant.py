"""Fixed-point quantisation contract shared with the rust layer.

This module is the *single source of truth* for the numeric semantics of
the paper's SIMD MAC unit (Fig. 2).  The rust bit-exact model
(`rust/src/ml/quant.rs`, `rust/src/sim/mac_model.rs`), the Pallas kernel
(`kernels/simd_mac.py`) and the jnp oracle (`kernels/ref.py`) all implement
exactly these rules; cross-layer tests assert bit-equality.

Contract
--------
* Precision n ∈ {32, 16, 8, 4}: signed two's-complement n-bit operands,
  qmin = -2^(n-1), qmax = 2^(n-1) - 1.
* A tensor with max-abs M is assigned `f = frac_bits(M, n)` fractional bits:
      int_bits(M) = 0                      if M < 1
                  = floor(log2(M)) + 1     otherwise
      frac_bits(M, n) = clamp(n - 1 - int_bits(M), 0, n - 1)
* quantise(v) = clamp(floor(v * 2^f + 0.5), qmin, qmax)   (round half up)
* MAC: the unit multiplies n-bit lanes and accumulates into the paper's
  per-lane accumulator (Eq. 1): a 32-bit register for n <= 16 (wrapping),
  a 64-bit register pair for n = 32.  Quantisation *guarantees* the
  accumulator never wraps on real workloads by capping the total
  fractional bits:  K * Mx * Mw * 2^(fx+fw) < 2^(acc_bits-2)
  (one headroom bit for the bias, one safety bit), reducing fx/fw if the
  naturally-assigned formats would exceed the cap.
* Rescale to the next layer's activation format fy:
      shift = fa + fw - fy          (always >= 0 for our formats)
      y = sat_n( (acc + (1 << (shift-1))) >> shift )      if shift > 0
        = sat_n( acc )                                    if shift == 0
  (arithmetic shift; round-half-up on the dropped bits; saturate to n bits)
* ReLU (hidden layers): max(0, y) applied after the rescale.
* Output layer: scores are dequantised to float: acc * 2^-(fa + fw).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np


def int_bits(max_abs: float) -> int:
    """Number of integer bits needed for magnitude `max_abs`."""
    if max_abs < 1.0:
        return 0
    return int(math.floor(math.log2(max_abs))) + 1


def frac_bits(max_abs: float, n: int) -> int:
    """Fractional bits assigned to a tensor with the given max-abs."""
    f = n - 1 - int_bits(max_abs)
    return max(0, min(n - 1, f))


def qlimits(n: int) -> tuple[int, int]:
    return -(1 << (n - 1)), (1 << (n - 1)) - 1


def quantize(v: np.ndarray, f: int, n: int) -> np.ndarray:
    """Quantise float array to n-bit signed fixed point, f fractional bits.

    Round-half-up (floor(x + 0.5)) — chosen because it is trivially
    identical across numpy / jnp / rust (no banker's rounding ambiguity).
    """
    qmin, qmax = qlimits(n)
    q = np.floor(np.asarray(v, dtype=np.float64) * (1 << f) + 0.5)
    return np.clip(q, qmin, qmax).astype(np.int64)


def dequantize(q: np.ndarray, f: int) -> np.ndarray:
    return np.asarray(q, dtype=np.float64) / (1 << f)


def sat(v: np.ndarray, n: int) -> np.ndarray:
    qmin, qmax = qlimits(n)
    return np.clip(v, qmin, qmax)


def rescale(acc: np.ndarray, shift: int, n: int) -> np.ndarray:
    """Rescale an exact accumulator to n bits, dropping `shift` frac bits."""
    acc = np.asarray(acc, dtype=np.int64)
    if shift > 0:
        acc = (acc + (1 << (shift - 1))) >> shift
    elif shift < 0:
        acc = acc << (-shift)
    return sat(acc, n)


@dataclass(frozen=True)
class LayerQuant:
    """Quantisation parameters of one dense layer, fully determined by the
    calibration max-abs values and the precision.  Serialised into the
    weights JSON so rust shares the identical parameters."""

    n: int  # precision (bits)
    fx: int  # input activation frac bits
    fw: int  # weight frac bits
    fy: int  # output activation frac bits (next layer's fx)
    k: int  # fan-in
    mx: float = 1.0  # calibrated max-abs of inputs
    mw: float = 1.0  # calibrated max-abs of weights

    @property
    def acc_bits(self) -> int:
        """Accumulator width: the 32-bit datapath register for n <= 16,
        a 64-bit register pair for the n = 32 configuration."""
        return 32 if self.n <= 16 else 64

    @property
    def shift(self) -> int:
        return self.fx + self.fw - self.fy

    def check_no_overflow(self) -> None:
        """Guaranteed bound: |acc| <= K*Mx*Mw*2^(fx+fw) (+1 bias headroom
        bit) must fit the accumulator."""
        worst = self.k * self.mx * self.mw * 2.0 ** (self.fx + self.fw)
        assert worst < 2.0 ** (self.acc_bits - 2), (
            f"MAC accumulator could overflow: worst |acc| ~ 2^{math.log2(max(worst,1)):.1f}"
            f" vs {self.acc_bits}-bit register (n={self.n}, k={self.k})"
        )


def layer_quant(n: int, max_abs_x: float, max_abs_w: float, max_abs_y: float, k: int) -> LayerQuant:
    """Derive one layer's quantisation from calibration statistics.

    * fx/fw start at the natural format for the calibrated magnitudes and
      are reduced (largest first) until the no-overflow cap holds:
      K * Mx * Mw * 2^(fx+fw) < 2^(acc_bits - 2).
    * fy is clamped to fx + fw so the rescale shift is never negative —
      the hardware rescaler only drops fractional bits (right shift).
    """
    fx = frac_bits(max_abs_x, n)
    fw = frac_bits(max_abs_w, n)
    acc_bits = 32 if n <= 16 else 64
    cap = acc_bits - 2 - math.ceil(math.log2(max(1.0, k * max_abs_x * max_abs_w)))
    while fx + fw > cap and (fx > 0 or fw > 0):
        if fx >= fw:
            fx -= 1
        else:
            fw -= 1
    lq = LayerQuant(
        n=n,
        fx=fx,
        fw=fw,
        fy=min(frac_bits(max_abs_y, n), fx + fw),
        k=k,
        mx=max_abs_x,
        mw=max_abs_w,
    )
    lq.check_no_overflow()
    assert lq.shift >= 0, "rescale shift must be non-negative by construction"
    return lq


def derive_chain(
    n: int, max_abs_x0: float, layer_stats: list[tuple[float, float, int]]
) -> list[LayerQuant]:
    """Derive the whole model's layer quants as a *chain*: layer i's output
    format fy IS layer i+1's input format fx (they are the same tensor).

    `layer_stats` is one (max_abs_w, max_abs_y, k) triple per layer.  The
    first layer may reduce both fx and fw to satisfy the no-overflow cap;
    subsequent layers have fx pinned by the chain and reduce only fw.
    """
    lqs: list[LayerQuant] = []
    mx = max_abs_x0
    fx = frac_bits(mx, n)
    for mw, my, k in layer_stats:
        acc_bits = 32 if n <= 16 else 64
        fw = frac_bits(mw, n)
        cap = acc_bits - 2 - math.ceil(math.log2(max(1.0, k * mx * mw)))
        if n == 32:
            # Keep every serialised integer (weights, biases at fx+fw)
            # within f64-exact range (< 2^53) for the JSON interchange;
            # 2^-46 granularity is far below any accuracy effect.
            cap = min(cap, 46)
        if not lqs:
            while fx + fw > cap and (fx > 0 or fw > 0):
                if fx >= fw:
                    fx -= 1
                else:
                    fw -= 1
        else:
            fw = max(0, min(fw, cap - fx))
        fy = min(frac_bits(my, n), fx + fw)
        lq = LayerQuant(n=n, fx=fx, fw=fw, fy=fy, k=k, mx=mx, mw=mw)
        lq.check_no_overflow()
        assert lq.shift >= 0
        lqs.append(lq)
        fx, mx = fy, my
    return lqs


# ---------------------------------------------------------------------------
# Reference (numpy) quantised dense layer — the plain-python oracle used by
# the pytest suite to validate both the jnp ref and the Pallas kernel.
# ---------------------------------------------------------------------------


def dense_quantized_ref(
    x: np.ndarray,
    w: np.ndarray,
    b: np.ndarray,
    lq: LayerQuant,
    relu: bool,
    last: bool,
) -> tuple[np.ndarray, np.ndarray]:
    """Quantised dense layer, numpy oracle.

    x: float [B, K]; w: float [K, N]; b: float [N].
    Returns (q_out or float scores, acc) — if `last`, the first element is
    the dequantised float scores [B, N]; otherwise the n-bit q activations.
    """
    qx = quantize(x, lq.fx, lq.n)
    qw = quantize(w, lq.fw, lq.n)
    qb = quantize(b, lq.fx + lq.fw, 32 if lq.n <= 16 else 64)
    acc = qx @ qw + qb[None, :]
    if last:
        return dequantize(acc, lq.fx + lq.fw), acc
    y = rescale(acc, lq.shift, lq.n)
    if relu:
        y = np.maximum(y, 0)
    return y, acc


# ---------------------------------------------------------------------------
# SIMD lane packing (hardware word-level view, paper Fig. 2)
# ---------------------------------------------------------------------------


def lanes(n: int, datapath: int = 32) -> int:
    """Number of concurrent MAC lanes for precision n on a `datapath`-bit
    register pair (paper: 32/n lanes; the 4-bit TP-ISA gets a single lane)."""
    return max(1, datapath // n)


def pack_lanes(q: np.ndarray, n: int, datapath: int = 32) -> np.ndarray:
    """Pack a [..., L] int lane array into datapath-bit words (two's
    complement, lane 0 in the least-significant bits)."""
    L = lanes(n, datapath)
    assert q.shape[-1] == L
    mask = (1 << n) - 1
    word = np.zeros(q.shape[:-1], dtype=np.int64)
    for i in range(L):
        word |= (q[..., i].astype(np.int64) & mask) << (n * i)
    # Reinterpret as signed datapath-bit value.
    sign = 1 << (datapath - 1)
    word = (word & ((1 << datapath) - 1)).astype(np.int64)
    return np.where(word >= sign, word - (1 << datapath), word)


def unpack_lanes(word: np.ndarray, n: int, datapath: int = 32) -> np.ndarray:
    """Inverse of pack_lanes: [...] words -> [..., L] signed lanes."""
    L = lanes(n, datapath)
    word = np.asarray(word, dtype=np.int64) & ((1 << datapath) - 1)
    out = np.zeros(word.shape + (L,), dtype=np.int64)
    mask = (1 << n) - 1
    for i in range(L):
        lane = (word >> (n * i)) & mask
        sign = 1 << (n - 1)
        out[..., i] = np.where(lane >= sign, lane - (1 << n), lane)
    return out
