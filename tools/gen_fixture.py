#!/usr/bin/env python3
"""Generate the hermetic `artifacts-fixture/` tree.

The fixture is a tiny, fully self-contained stand-in for the real AOT
artifact tree (`make artifacts`, which needs JAX + the Pallas toolchain):
the same manifest schema, the same six model names as the paper's
evaluation (mlp_c/mlp_r/svm_c/svm_r over cardio/redwine/whitewine), but
with miniature shapes and *stub* HLO files that the rust crate's default
(no-`xla`) runtime backend interprets directly.

Exactness contract
------------------
`rust/tests/integration_runtime.rs` asserts that the service reproduces
the manifest's recorded accuracies to 1e-9, and the stub backend computes
scores with `Model::quantized_forward` / `Model::float_forward`.  This
script therefore replicates those two functions *bit-exactly*:

* all dataset values live on a 1/256 grid (exactly representable in both
  f32 and f64, so the CSV -> f32 -> f64 round trip in rust is lossless);
* all weights live on a 1/64 grid (rust reads them as f64 directly);
* `quantize` is round-half-up in f64 (`floor(v * 2^f + 0.5)`), `rescale`
  is an arithmetic right shift with saturation (Python's `>>` on ints is
  the same floor semantics as rust's `>>` on i64);
* the float forward pass uses the identical operation order, so IEEE f64
  rounding is identical even where results are not exact dyadics.

Test sets are filtered so that p32/p16 predictions equal the float
predictions for every model sharing the dataset (the paper: "no loss at
32/16 bits"); p8 and p4 are left unfiltered so the precision-loss curves
emerge naturally (p4 saturates hard, wines worst — as in the paper).

Run from the repo root:  python3 tools/gen_fixture.py
"""

from __future__ import annotations

import json
import math
import os
import random
import struct
import sys

OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "artifacts-fixture")

BATCH = 256
PRECISIONS = [32, 16, 8, 4]
MAC_UNIT_WORDS = 64

# Fixed-point formats per precision: (fx of the input/hidden chain, fw).
# Mirrors the shape of python/compile/quant.py's derived formats.
FORMATS = {32: (12, 8), 16: (8, 6), 8: (6, 5), 4: (2, 2)}


# ---------------------------------------------------------------------------
# Bit-exact replicas of rust/src/ml/{quant,model}.rs
# ---------------------------------------------------------------------------

def f32_exact(v: float) -> float:
    """Assert v survives the f64 -> f32 -> f64 round trip unchanged."""
    r = struct.unpack("f", struct.pack("f", v))[0]
    assert r == v, f"{v} is not exactly representable in f32"
    return v


def quantize(v: float, f: int, n: int) -> int:
    qmin, qmax = -(1 << (n - 1)), (1 << (n - 1)) - 1
    q = math.floor(v * float(1 << f) + 0.5)
    if q < qmin:
        return qmin
    if q > qmax:
        return qmax
    return int(q)


def rescale(acc: int, shift: int, n: int) -> int:
    v = (acc + (1 << (shift - 1))) >> shift if shift > 0 else acc
    qmin, qmax = -(1 << (n - 1)), (1 << (n - 1)) - 1
    return max(qmin, min(qmax, v))


class Layer:
    def __init__(self, w, b, relu):
        self.w = w  # [K][N]
        self.b = b  # [N]
        self.relu = relu


class Model:
    def __init__(self, name, dataset, task, head, layers, n_classes, label_offset, ovo_pairs):
        self.name = name
        self.dataset = dataset
        self.task = task
        self.head = head
        self.layers = layers
        self.n_classes = n_classes
        self.label_offset = label_offset
        self.ovo_pairs = ovo_pairs
        self.quantized = {}  # p -> [qlayer dict]
        self.float_accuracy = 0.0

    @property
    def arch(self):
        return [len(self.layers[0].w)] + [len(l.b) for l in self.layers]

    def derive_quantized(self):
        for p in PRECISIONS:
            fx0, fw = FORMATS[p]
            qls = []
            fx = fx0
            for i, layer in enumerate(self.layers):
                last = i == len(self.layers) - 1
                fy = 0 if last else fx0
                shift = fx + fw - fy
                qw = [[quantize(v, fw, p) for v in row] for row in layer.w]
                qb = [quantize(v, fx + fw, 32) for v in layer.b]
                qls.append({"fx": fx, "fw": fw, "fy": fy, "shift": shift, "qw": qw, "qb": qb})
                fx = fy
            self.quantized[p] = qls

    # -- forward passes (replicas) ----------------------------------------

    def head_scores(self, raw):
        if self.head in ("argmax", "round"):
            return list(raw)
        votes = [0.0] * self.n_classes
        for p_idx, (i, j) in enumerate(self.ovo_pairs):
            if raw[p_idx] >= 0.0:
                votes[i] += 1.0
            else:
                votes[j] += 1.0
        return votes

    def predict(self, scores):
        if self.head == "round":
            v = math.floor(scores[0] + 0.5)
            lo = self.label_offset
            hi = self.label_offset + self.n_classes - 1
            return max(lo, min(hi, v))
        best = 0
        for i in range(len(scores)):
            if scores[i] > scores[best]:
                best = i
        return best + self.label_offset

    def float_forward(self, x):
        h = list(x)
        for layer in self.layers:
            k, n = len(layer.w), len(layer.b)
            nxt = [0.0] * n
            for j in range(n):
                acc = layer.b[j]
                for kk in range(k):
                    acc = acc + h[kk] * layer.w[kk][j]
                nxt[j] = acc if not layer.relu or acc > 0.0 else max(acc, 0.0)
            h = nxt
        return self.head_scores(h)

    def quantized_forward(self, x, p):
        qls = self.quantized[p]
        h = [quantize(v, qls[0]["fx"], p) for v in x]
        raw = None
        for i, (layer, ql) in enumerate(zip(self.layers, qls)):
            k, n = len(ql["qw"]), len(ql["qb"])
            assert len(h) == k
            last = i == len(self.layers) - 1
            nxt = []
            for j in range(n):
                acc = ql["qb"][j]
                for kk in range(k):
                    acc += h[kk] * ql["qw"][kk][j]
                if last:
                    nxt.append(acc)
                else:
                    y = rescale(acc, ql["shift"], p)
                    if layer.relu and y < 0:
                        y = 0
                    nxt.append(y)
            if last:
                scale = float(1 << (ql["fx"] + ql["fw"]))
                raw = [a / scale for a in nxt]
            else:
                h = nxt
        return self.head_scores(raw)


# ---------------------------------------------------------------------------
# Grid helpers
# ---------------------------------------------------------------------------

def snap(v, denom):
    return max(0.0, min(1.0, round(v * denom) / denom))


def snap_w(v, denom=64):
    r = round(v * denom) / denom
    return 0.0 if r == 0.0 else r  # normalise -0.0


# ---------------------------------------------------------------------------
# Dataset + model construction
# ---------------------------------------------------------------------------

def build_cardio(rng):
    """16 features, 3 classes: Gaussian-ish clusters; MLP + OvO SVM."""
    k, n_hidden, n_classes = 16, 8, 3
    while True:  # resample until the class centroids are well separated
        cents = [[snap(0.15 + 0.7 * rng.random(), 32) for _ in range(k)]
                 for _ in range(n_classes)]
        dmin = min(
            math.sqrt(sum((a - b) ** 2 for a, b in zip(cents[i], cents[j])))
            for i in range(n_classes) for j in range(i + 1, n_classes))
        if dmin >= 1.05:
            break
    cbar = [sum(c[i] for c in cents) / n_classes for i in range(k)]

    alpha = 1.5
    w1 = [[0.0] * n_hidden for _ in range(k)]
    b1 = [0.0] * n_hidden
    for j in range(n_classes):
        col = [snap_w(alpha * (cents[j][i] - cbar[i])) for i in range(k)]
        for i in range(k):
            w1[i][j] = col[i]
        b1[j] = snap_w(-sum(col[i] * 0.5 for i in range(k)) + 0.25)
    for j in range(n_classes, n_hidden):
        for i in range(k):
            w1[i][j] = snap_w(rng.uniform(-0.2, 0.2))
        b1[j] = snap_w(rng.uniform(-0.1, 0.1))
    w2 = [[0.0] * n_classes for _ in range(n_hidden)]
    for j in range(n_hidden):
        for c in range(n_classes):
            if j == c:
                w2[j][c] = 1.25
            else:
                w2[j][c] = snap_w(rng.uniform(-0.1, 0.1))
    b2 = [0.0] * n_classes
    mlp = Model("mlp_c_cardio", "cardio", "classification", "argmax",
                [Layer(w1, b1, True), Layer(w2, b2, False)], n_classes, 0, [])

    pairs = [(0, 1), (0, 2), (1, 2)]
    gamma = 1.0
    ws = [[0.0] * len(pairs) for _ in range(k)]
    bs = [0.0] * len(pairs)
    for p_idx, (i, j) in enumerate(pairs):
        col = [snap_w(gamma * (cents[i][t] - cents[j][t])) for t in range(k)]
        for t in range(k):
            ws[t][p_idx] = col[t]
        mid = [(cents[i][t] + cents[j][t]) / 2.0 for t in range(k)]
        bs[p_idx] = snap_w(-sum(col[t] * mid[t] for t in range(k)))
    svm = Model("svm_c_cardio", "cardio", "classification", "ovo_vote",
                [Layer(ws, bs, False)], n_classes, 0, [list(p) for p in pairs])

    def sample(rng):
        cls = rng.choices(range(n_classes), weights=[0.6, 0.25, 0.15])[0]
        x = [snap(cents[cls][i] + rng.uniform(-0.22, 0.22), 256) for i in range(k)]
        lab = cls
        if rng.random() < 0.07:  # label noise (UCI cardio is far from clean)
            lab = rng.choice([c for c in range(n_classes) if c != cls])
        return x, lab

    return [mlp, svm], sample


def build_wine(rng, name, n_classes):
    """10 features, quality regression (round head). MLP + linear SVM-R."""
    k, n_hidden = 10, 6
    offset = 3
    qmid = offset + (n_classes - 1) / 2.0
    span = (n_classes - 1) / 2.0
    d = [snap_w(rng.choice([-1, 1]) * rng.uniform(0.3, 0.6)) for _ in range(k)]
    d2 = sum(v * v for v in d)
    # The samples place the quality signal at XSCALE * d, so the readout
    # gain is span / (d2 * XSCALE) for a unit quality coefficient.
    beta = span / (d2 * XSCALE)

    # SVM-R: score = <g, x> + b ~ q.
    g = [snap_w(beta * v) for v in d]
    b = snap_w(qmid - sum(gi * 0.5 for gi in g), 64)
    svm = Model(f"svm_r_{name}", name, "regression", "round",
                [Layer([[gi] for gi in g], [b], False)], n_classes, offset, [])

    # MLP-R: h0 = relu(<g,x>/4 + shift) stays positive; out = 4*h0 + c.
    w1 = [[0.0] * n_hidden for _ in range(k)]
    b1 = [0.0] * n_hidden
    for i in range(k):
        w1[i][0] = snap_w(g[i] / 4.0, 256)
    b1[0] = snap_w(b / 4.0 - qmid / 4.0 + 1.0, 256)
    for j in range(1, n_hidden):
        for i in range(k):
            w1[i][j] = snap_w(rng.uniform(-0.1, 0.1))
        b1[j] = snap_w(rng.uniform(0.0, 0.2))
    w2 = [[0.0] for _ in range(n_hidden)]
    w2[0][0] = 4.0
    for j in range(1, n_hidden):
        w2[j][0] = snap_w(rng.uniform(-0.03, 0.03))
    b2 = [snap_w(qmid - 4.0, 64)]
    mlp = Model(f"mlp_r_{name}", name, "regression", "round",
                [Layer(w1, b1, True), Layer(w2, b2, False)], n_classes, offset, [])

    def sample(rng):
        q = rng.choices(range(offset, offset + n_classes),
                        weights=[math.exp(-0.5 * ((i - (n_classes - 1) / 2) / 1.1) ** 2)
                                 for i in range(n_classes)])[0]
        x = [snap(0.5 + (q - qmid) / span * d[i] * XSCALE + rng.uniform(-0.04, 0.04), 256)
             for i in range(k)]
        lab = q
        if rng.random() < 0.08:  # label noise: off-by-one quality
            lab = max(offset, min(offset + n_classes - 1, q + rng.choice([-1, 1])))
        return x, lab

    return [mlp, svm], sample


# ---------------------------------------------------------------------------
# Test-set generation with the p32/p16 == float filter
# ---------------------------------------------------------------------------

def make_test_set(rng, models, sample_fn, n_rows, margin_fn=None):
    rows = []
    attempts = 0
    while len(rows) < n_rows:
        attempts += 1
        if attempts > n_rows * 400:
            raise RuntimeError("filter too strict; loosen model/data design")
        x, lab = sample_fn(rng)
        for v in x:
            f32_exact(v)
        ok = True
        for m in models:
            fp = m.predict(m.float_forward(x))
            if m.predict(m.quantized_forward(x, 32)) != fp:
                ok = False
                break
            if m.predict(m.quantized_forward(x, 16)) != fp:
                ok = False
                break
            if margin_fn is not None and not margin_fn(m, x):
                ok = False
                break
        if ok:
            rows.append((x, lab))
    return rows


XSCALE = 0.45  # quality-direction scale inside the wine feature vectors


def wine_margin(m, x):
    """Keep samples whose float score sits away from a rounding boundary."""
    s = m.float_forward(x)[0]
    return abs((s + 0.5) - math.floor(s + 0.5) - 0.5) > 0.2


def accuracy(preds, labels):
    hits = sum(1 for p, y in zip(preds, labels) if p == y)
    return hits / len(labels)


# ---------------------------------------------------------------------------
# Emission
# ---------------------------------------------------------------------------

def jnum(v):
    """JSON-safe float: repr round-trips exactly to the same f64."""
    if isinstance(v, int):
        return v
    if v == 0.0:
        return 0.0
    return v


def write_weights(model):
    d = {
        "name": model.name,
        "dataset": model.dataset,
        "task": model.task,
        "head": model.head,
        "arch": model.arch,
        "n_classes": model.n_classes,
        "label_offset": model.label_offset,
        "ovo_pairs": model.ovo_pairs,
        "calib": [1.0] * (len(model.layers) + 1),
        "float_accuracy": jnum(model.float_accuracy),
        "layers": [
            {"w": [[jnum(v) for v in row] for row in l.w],
             "b": [jnum(v) for v in l.b],
             "relu": l.relu}
            for l in model.layers
        ],
        "quantized": {str(p): model.quantized[p] for p in PRECISIONS},
    }
    path = os.path.join(OUT, "weights", f"{model.name}.json")
    with open(path, "w") as f:
        json.dump(d, f)
    return f"weights/{model.name}.json"


def write_stub(rel, payload):
    path = os.path.join(OUT, rel)
    with open(path, "w") as f:
        json.dump(payload, f)
    return rel


def write_csv(name, rows):
    k = len(rows[0][0])
    path = os.path.join(OUT, "data", f"{name}_test.csv")
    with open(path, "w") as f:
        f.write(",".join([f"f{i}" for i in range(k)] + ["label"]) + "\n")
        for x, lab in rows:
            f.write(",".join(repr(v) for v in x) + f",{lab}\n")


def main():
    rng = random.Random(0xB35)

    for sub in ("data", "weights", "hlo"):
        os.makedirs(os.path.join(OUT, sub), exist_ok=True)

    cardio_models, cardio_sample = build_cardio(rng)
    red_models, red_sample = build_wine(rng, "redwine", 6)
    white_models, white_sample = build_wine(rng, "whitewine", 7)
    for m in cardio_models + red_models + white_models:
        m.derive_quantized()

    sets = {
        "cardio": make_test_set(rng, cardio_models, cardio_sample, 60),
        "redwine": make_test_set(rng, red_models, red_sample, 48, wine_margin),
        "whitewine": make_test_set(rng, white_models, white_sample, 48, wine_margin),
    }

    # Manifest order mirrors python/compile/train.py::train_all.
    models = [cardio_models[0], red_models[0], white_models[0],
              cardio_models[1], red_models[1], white_models[1]]

    manifest = {"fixture": True, "batch": BATCH, "precisions": PRECISIONS, "models": []}
    for m in models:
        rows = sets[m.dataset]
        labels = [lab for _, lab in rows]
        fpreds = [m.predict(m.float_forward(x)) for x, _ in rows]
        m.float_accuracy = accuracy(fpreds, labels)
        quant_acc = {}
        for p in PRECISIONS:
            qpreds = [m.predict(m.quantized_forward(x, p)) for x, _ in rows]
            quant_acc[str(p)] = jnum(accuracy(qpreds, labels))
            if p >= 16:
                assert qpreds == fpreds, f"{m.name} p{p} differs from float"
        weights_rel = write_weights(m)
        hlo = {"float": write_stub(
            f"hlo/{m.name}_float.hlo.txt",
            {"pbsp_hlo_stub": 1, "kind": "model",
             "weights": f"../{weights_rel}", "variant": "float"})}
        for p in PRECISIONS:
            hlo[f"p{p}"] = write_stub(
                f"hlo/{m.name}_p{p}.hlo.txt",
                {"pbsp_hlo_stub": 1, "kind": "model",
                 "weights": f"../{weights_rel}", "variant": f"p{p}"})
        manifest["models"].append({
            "name": m.name,
            "dataset": m.dataset,
            "task": m.task,
            "head": m.head,
            "arch": m.arch,
            "n_classes": m.n_classes,
            "label_offset": m.label_offset,
            "n_test": len(rows),
            "float_accuracy": jnum(m.float_accuracy),
            "weights": weights_rel,
            "hlo": hlo,
            "quant_accuracy": quant_acc,
        })

    manifest["mac_units"] = {}
    for p in PRECISIONS:
        rel = write_stub(
            f"hlo/simd_mac_unit_p{p}.hlo.txt",
            {"pbsp_hlo_stub": 1, "kind": "mac_unit",
             "datapath": 32, "precision": p, "words": MAC_UNIT_WORDS})
        manifest["mac_units"][str(p)] = {"path": rel, "words": MAC_UNIT_WORDS}

    for name, rows in sets.items():
        write_csv(name, rows)

    with open(os.path.join(OUT, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    # -- self-verification: reparse everything and recompute ----------------
    verify(manifest)

    for e in manifest["models"]:
        print(f"{e['name']:<18} arch {e['arch']}  float {e['float_accuracy']:.4f}  "
              + "  ".join(f"p{p} {e['quant_accuracy'][str(p)]:.4f}" for p in PRECISIONS))
    print(f"wrote {OUT}")


def verify(manifest):
    """Round-trip check: reparse emitted JSON/CSV and recompute accuracies."""
    for e in manifest["models"]:
        with open(os.path.join(OUT, e["weights"])) as f:
            wj = json.load(f)
        layers = [Layer(l["w"], l["b"], l["relu"]) for l in wj["layers"]]
        m = Model(wj["name"], wj["dataset"], wj["task"], wj["head"], layers,
                  wj["n_classes"], wj["label_offset"],
                  [tuple(p) for p in wj["ovo_pairs"]])
        m.quantized = {int(p): qls for p, qls in wj["quantized"].items()}
        xs, ys = [], []
        with open(os.path.join(OUT, "data", f"{e['dataset']}_test.csv")) as f:
            header = f.readline()
            assert header.strip().endswith("label")
            for line in f:
                vals = [float(t) for t in line.strip().split(",")]
                x = [f32_exact(v) for v in vals[:-1]]
                xs.append(x)
                ys.append(int(vals[-1]))
        fpreds = [m.predict(m.float_forward(x)) for x in xs]
        assert accuracy(fpreds, ys) == e["float_accuracy"], e["name"]
        for p in PRECISIONS:
            qpreds = [m.predict(m.quantized_forward(x, p)) for x in xs]
            got = accuracy(qpreds, ys)
            want = e["quant_accuracy"][str(p)]
            assert got == want, f"{e['name']} p{p}: {got} != {want}"
    print("self-verification: reparsed accuracies match the manifest")


if __name__ == "__main__":
    sys.exit(main())
