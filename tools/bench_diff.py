#!/usr/bin/env python3
"""Compare a fresh BENCH_iss.json against the committed baseline.

Joins the two result sets on (core, model, variant) and prints a
markdown speedup table (suitable for $GITHUB_STEP_SUMMARY).  The gate:

* the translated-vs-interpreted speedup of every baseline configuration
  must not regress by more than --max-regression (default 20%) — this
  ratio is host-independent, so it is the default CI gate;
* every configuration present in the baseline must be present in the
  fresh results;
* with --absolute, the absolute translated MIPS is additionally gated
  against the baseline at the same tolerance (only meaningful when both
  files come from the same host class).

A baseline carrying `"placeholder": true` (the seed snapshot, whose
numbers are estimates, not measurements) is report-only: the table is
printed, violations are listed as warnings, and the exit status is 0.
Committing a measured BENCH_iss.json as the baseline (which has no
placeholder flag) arms the gate.

Exit status 0 = pass / placeholder report, 1 = regression / missing
configuration, 2 = usage or file error.

Usage:
    tools/bench_diff.py BENCH_iss.baseline.json BENCH_iss.json \
        [--max-regression 0.20] [--absolute]
"""

import argparse
import json
import sys


def load_results(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_diff: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)
    rows = {}
    for r in doc.get("results", []):
        key = (r.get("core", "?"), r.get("model", "?"), r.get("variant", "?"))
        rows[key] = r
    if not rows:
        print(f"bench_diff: {path} contains no results", file=sys.stderr)
        sys.exit(2)
    return doc, rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("fresh")
    ap.add_argument("--max-regression", type=float, default=0.20,
                    help="allowed fractional drop vs baseline (default 0.20)")
    ap.add_argument("--absolute", action="store_true",
                    help="also gate absolute translated MIPS (same-host runs only)")
    args = ap.parse_args()

    base_doc, base = load_results(args.baseline)
    _, fresh = load_results(args.fresh)
    tol = args.max_regression
    placeholder = bool(base_doc.get("placeholder"))

    print("## ISS translated-vs-interpreted speedup\n")
    print("| core | model | variant | interp MIPS | translated MIPS "
          "| speedup (fresh) | speedup (baseline) | fallback rate | status |")
    print("|---|---|---|---:|---:|---:|---:|---:|---|")

    failures = []
    for key in sorted(base):
        b = base[key]
        f = fresh.get(key)
        core, model, variant = key
        b_speed = b.get("speedup_translated_vs_interp", 0.0)
        if f is None:
            failures.append(f"{key}: missing from fresh results")
            print(f"| {core} | {model} | {variant} | — | — | — "
                  f"| {b_speed:.2f}x | — | MISSING |")
            continue
        f_speed = f.get("speedup_translated_vs_interp", 0.0)
        f_interp = f.get("mips_interp_cycles_only", 0.0)
        f_trans = f.get("mips_translated_cycles_only", 0.0)
        fallback = f.get("fallback_rate", 0.0)
        problems = []
        if f_speed < b_speed * (1.0 - tol):
            problems.append("SPEEDUP REGRESSION")
            failures.append(
                f"{key}: translated-vs-interp speedup {f_speed:.2f}x "
                f"< {(1.0 - tol):.2f} * baseline {b_speed:.2f}x")
        if args.absolute:
            b_trans = b.get("mips_translated_cycles_only", 0.0)
            if f_trans < b_trans * (1.0 - tol):
                problems.append("MIPS REGRESSION")
                failures.append(
                    f"{key}: translated MIPS {f_trans:.1f} "
                    f"< {(1.0 - tol):.2f} * baseline {b_trans:.1f}")
        status = " + ".join(problems) if problems else "ok"
        print(f"| {core} | {model} | {variant} | {f_interp:.1f} | {f_trans:.1f} "
              f"| {f_speed:.2f}x | {b_speed:.2f}x | {fallback:.4f} | {status} |")

    extra = sorted(set(fresh) - set(base))
    for key in extra:
        f = fresh[key]
        core, model, variant = key
        print(f"| {core} | {model} | {variant} "
              f"| {f.get('mips_interp_cycles_only', 0.0):.1f} "
              f"| {f.get('mips_translated_cycles_only', 0.0):.1f} "
              f"| {f.get('speedup_translated_vs_interp', 0.0):.2f}x | new | "
              f"{f.get('fallback_rate', 0.0):.4f} | new |")

    print()
    if failures:
        kind = "warning(s) [placeholder baseline, not enforced]" if placeholder \
            else "regression(s)"
        print(f"**{len(failures)} {kind} beyond {tol * 100:.0f}% tolerance:**\n")
        for msg in failures:
            print(f"* {msg}")
        if placeholder:
            print("\nBaseline is a placeholder (estimated numbers): reporting only. "
                  "Commit a measured BENCH_iss.json as BENCH_iss.baseline.json to arm "
                  "the gate.")
            return 0
        return 1
    if placeholder:
        print(f"All {len(base)} placeholder-baseline configurations within "
              f"{tol * 100:.0f}% tolerance (gate unarmed until a measured baseline "
              "is committed).")
    else:
        print(f"All {len(base)} baseline configurations within "
              f"{tol * 100:.0f}% tolerance.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
