# Top-level build entry points.  `make build test` is the repository's
# tier-1 verification and needs nothing beyond a Rust toolchain: the
# checked-in artifacts-fixture/ stands in for `make artifacts` output.

.PHONY: all build test bench bench-diff doc fmt fmt-check serve loadgen artifacts fixture python-test clean

all: build

# -- Rust (tier-1) -----------------------------------------------------------

build:
	cargo build --release

test:
	cargo test -q

# Paper tables/figures + perf counters (see the bench table in README.md).
bench:
	cargo bench

# ISS throughput gate: rerun the perf bench and compare against the
# committed baseline (fails on >20% translated-vs-interpreted speedup
# regression; add --absolute for same-host MIPS comparison).
bench-diff:
	cargo bench --bench perf_iss
	python3 tools/bench_diff.py BENCH_iss.baseline.json BENCH_iss.json

doc:
	cargo doc --no-deps

fmt:
	cargo fmt

fmt-check:
	cargo fmt --check

# HTTP inference frontend on a fixed local port (ctrl-c to stop).
serve:
	cargo run --release --bin pbsp -- serve --addr 127.0.0.1:8080

# Deterministic device-fleet burst against an in-process frontend;
# exits non-zero on any request error (the CI smoke gate).
loadgen:
	cargo run --release --bin pbsp -- loadgen --fleet 8 --requests 50 --seed 1

# -- Artifacts ---------------------------------------------------------------

# Full AOT pipeline (needs JAX): datasets -> trained weights -> HLO text.
# Writes artifacts/, which takes precedence over the checked-in fixture.
artifacts:
	cd python && python3 -m compile.aot --out ../artifacts

# Regenerate the hermetic fixture tree (stdlib Python only, deterministic).
fixture:
	python3 tools/gen_fixture.py

# Python-side unit tests for the AOT pipeline (needs JAX + pytest).
python-test:
	cd python && python3 -m pytest tests -q

clean:
	cargo clean
	rm -rf artifacts
