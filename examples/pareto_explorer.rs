//! Pareto explorer: the Fig. 5 design-space sweep over TP-ISA
//! configurations (datapath width × MAC option × precision), printing
//! the area/speedup scatter with the Pareto front highlighted and an
//! ASCII rendering of the curve.
//!
//! Run: `cargo run --release --example pareto_explorer`
//! Requires `make artifacts`.

use anyhow::Result;
use printed_bespoke::dse::context::EvalContext;
use printed_bespoke::dse::pareto::pareto_flags;
use printed_bespoke::dse::report;

fn main() -> Result<()> {
    let ctx = EvalContext::load(6)?;
    let fig5 = report::fig5(&ctx)?;
    println!("{}", fig5.text);

    // ASCII scatter: x = area (log-ish bins), y = speedup.
    let points = &fig5.points;
    let pareto = pareto_flags(
        &points.iter().map(|p| (p.area_mm2, p.speedup_pct)).collect::<Vec<_>>(),
    );
    let (w, h) = (72usize, 20usize);
    let max_area = points.iter().map(|p| p.area_mm2).fold(0.0, f64::max) * 1.05;
    let mut grid = vec![vec![b' '; w]; h];
    for (i, p) in points.iter().enumerate() {
        let x = ((p.area_mm2 / max_area) * (w - 1) as f64) as usize;
        let y = h - 1 - ((p.speedup_pct.max(0.0) / 100.0) * (h - 1) as f64) as usize;
        grid[y][x] = if pareto[i] { b'*' } else { b'o' };
    }
    println!("speedup%                  (* = Pareto, o = dominated)");
    for (i, row) in grid.iter().enumerate() {
        let label = if i == 0 {
            "100 |"
        } else if i == h - 1 {
            "  0 |"
        } else {
            "    |"
        };
        println!("{label}{}", String::from_utf8_lossy(row));
    }
    println!("    +{}", "-".repeat(w));
    println!("     0{}area [mm²]{:>40.0}", " ".repeat(20), max_area);

    // Table II call-out.
    let t2 = report::table2(&ctx)?;
    println!("\n{}", t2.text);
    Ok(())
}
