//! End-to-end driver: a fleet of printed devices reporting over HTTP.
//!
//! Where `smart_packaging.rs` streams readings through the coordinator
//! *in process*, this example stands up the full network path the
//! paper's §I scenario implies — disposable sensors (smart packaging,
//! healthcare patches) pushing classifications to a backend:
//!
//!   device fleet ── HTTP/1.1 keep-alive ──► server (reactor + pool)
//!       ──► router/dynamic batcher ──► PJRT runtime worker
//!
//! Each simulated device owns one keep-alive connection and a PCG
//! stream that decides which model it reports to and which test-set
//! reading it sends, so a (seed, fleet) pair is fully reproducible.
//! The example prints the manifest as served by `/v1/models`, the fleet
//! latency/throughput report, per-model traffic, and both metric
//! families from `/metrics`.
//!
//! Run: `cargo run --release --example device_fleet -- [--fleet N]`
//! (hermetic: falls back to `artifacts-fixture/` without `make
//! artifacts`).

use std::sync::Arc;

use anyhow::Result;
use printed_bespoke::coordinator::service::{Service, ServiceConfig};
use printed_bespoke::server::http::Client;
use printed_bespoke::server::{loadgen, Server, ServerConfig};
use printed_bespoke::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let fleet: usize = args.parse_or("fleet", 12)?;
    let requests: usize = args.parse_or("requests", 40)?;
    let seed: u64 = args.parse_or("seed", 7)?;
    let think_ms: u64 = args.parse_or("think-ms", 2)?;
    let threads = args.threads()?;
    args.finish()?;

    let svc = Arc::new(Service::start(ServiceConfig { threads, ..ServiceConfig::default() })?);
    // The reactor multiplexes every device connection on one thread;
    // only the admission cap needs fleet headroom (the probe connection
    // below holds a slot too — over-capacity connections are refused
    // with 503 + Retry-After by design).
    let scfg = ServerConfig { max_connections: fleet + 4, ..ServerConfig::default() };
    let mut server = Server::start(Arc::clone(&svc), scfg)?;
    println!("frontend listening on http://{}\n", server.addr());

    // What a device integrator would fetch first: the served manifest.
    let mut probe = Client::connect(server.addr())?;
    let (status, manifest) = probe.get("/v1/models")?;
    println!("GET /v1/models -> {status}\n{manifest}\n");

    // The fleet: closed-loop devices with a little think-time jitter.
    let cfg = loadgen::LoadgenConfig {
        fleet,
        requests_per_device: requests,
        seed,
        think_ms,
        precision: 8,
        ..Default::default()
    };
    let report = loadgen::run(server.addr(), &cfg)?;
    println!("{}\n", report.summary());

    // Per-model traffic mix (the PCG streams spread it evenly).
    let mut per_model = std::collections::BTreeMap::<usize, usize>::new();
    for r in &report.records {
        *per_model.entry(r.model).or_default() += 1;
    }
    println!("traffic mix:");
    for (mi, count) in &per_model {
        println!("  {:<18} {:>5} readings", svc.models[*mi].name, count);
    }

    // Fresh connection: the probe's may have been idle-reaped while
    // the fleet ran (keep-alive budget).
    let mut probe = Client::connect(server.addr())?;
    let (_, metrics) = probe.get("/metrics")?;
    println!("\nGET /metrics\n{metrics}");
    server.shutdown();
    Ok(())
}
