//! Quickstart: the bespoke design flow end to end on the public API.
//!
//! 1. Synthesise the baseline Zero-Riscy in the EGFET printed library.
//! 2. Profile the §III-A workload suite on the ISS.
//! 3. Apply the bespoke reduction and re-synthesise each Table-I variant.
//! 4. Print the resulting area/power trade-offs.
//!
//! Run: `cargo run --release --example quickstart`
//! (No artifacts needed — this example exercises only the hardware
//! model, ISS, and reduction passes.)

use anyhow::Result;
use printed_bespoke::bespoke::profile::profile_suite;
use printed_bespoke::bespoke::reduction::table1_variants;
use printed_bespoke::hw::egfet::egfet;
use printed_bespoke::hw::synth::{synthesize, zero_riscy};

fn main() -> Result<()> {
    let tech = egfet();

    // 1. Baseline synthesis (paper Fig. 1: 67.53 cm², 291.21 mW).
    let base = synthesize(&zero_riscy(), &tech);
    println!(
        "baseline {}: {:.2} cm², {:.2} mW, {:.0} Hz",
        base.name,
        base.area_cm2(),
        base.power_mw,
        base.fmax_hz
    );

    // 2. Profile the workload suite (MLP, decision tree, mul/div,
    //    insertion sort — §III-A).
    let u = profile_suite()?;
    println!(
        "\nprofiled {:?}:\n  {} instructions, {} cycles",
        u.workloads, u.profile.instructions, u.profile.cycles
    );
    println!(
        "  registers used: {}   PC bits: {}   BAR bits: {}",
        u.regs_needed, u.pc_bits_needed, u.bar_bits_needed
    );
    println!("  unused instructions: {}", u.unused_instructions.join(" "));

    // 3. Bespoke variants (Table I rows) re-synthesised.
    println!("\nbespoke variants (gains vs baseline):");
    for (name, spec) in table1_variants(&u) {
        let r = synthesize(&spec, &tech);
        println!(
            "  {:<14} {:.2} cm² ({:+5.1}%)   {:.2} mW ({:+5.1}%)",
            name,
            r.area_cm2(),
            (r.area_mm2 / base.area_mm2 - 1.0) * 100.0,
            r.power_mw,
            (r.power_mw / base.power_mw - 1.0) * 100.0,
        );
    }
    println!("\n(negative = smaller/lower than the baseline core)");
    Ok(())
}
