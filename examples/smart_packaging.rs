//! End-to-end driver: a smart-packaging line (the paper's motivating
//! FMCG scenario, §I) served by the full three-layer stack.
//!
//! Every layer composes here:
//!   L1  the Pallas SIMD-MAC kernel, baked into the HLO artifacts;
//!   L2  the JAX-trained quantised models (AOT, `make artifacts`);
//!   L3  the rust coordinator: router + dynamic batcher + PJRT runtime.
//!
//! The "line" streams sensor readings (test-set vectors) from six
//! product stations — each mapped to one of the paper's six models —
//! through the coordinator as single-sample requests.  We report
//! per-station accuracy, end-to-end latency percentiles and throughput,
//! and close with a bit-exactness crosscheck of the serving path
//! against the Zero-Riscy ISS running the bespoke-core programs.
//!
//! Run: `cargo run --release --example smart_packaging -- [--requests N]`
//! Requires `make artifacts`.  Results are recorded in EXPERIMENTS.md.

use std::time::Instant;

use anyhow::{anyhow, Context, Result};
use printed_bespoke::coordinator::router::Key;
use printed_bespoke::coordinator::service::{Service, ServiceConfig};
use printed_bespoke::ml::dataset::Dataset;
use printed_bespoke::util::cli::Args;
use printed_bespoke::util::stats;

const PRECISION: u32 = 8; // the paper's "suitable compromise" (§IV-B)

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let requests: usize = args.parse_or("requests", 1200)?;
    let batch: usize = args.parse_or("batch", 64)?;
    let threads = args.threads()?;
    args.finish()?;

    let cfg = ServiceConfig { max_batch: batch, linger_ms: 2, threads };
    let svc = Service::start(cfg)?;
    println!(
        "smart-packaging line: {} stations, p{PRECISION} bespoke cores, batch {batch}",
        svc.models.len()
    );

    // Station data = each model's test set.
    let stations: Vec<Dataset> = svc
        .models
        .iter()
        .map(|m| Dataset::load(svc.manifest.data_dir(), &m.dataset, "test"))
        .collect::<Result<_>>()?;

    // Warm up (compile every station executable once).
    for (m, ds) in svc.models.iter().zip(&stations) {
        svc.submit(Key::precision(&m.name, PRECISION), ds.x[0].clone())?
            .recv()
            .context("warmup")?
            .map_err(|e| anyhow!(e))?;
    }

    // Stream the line: round-robin stations, single-sample requests.
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(requests);
    for i in 0..requests {
        let s = i % stations.len();
        let idx = (i / stations.len()) % stations[s].len();
        let key = Key::precision(&svc.models[s].name, PRECISION);
        pending.push((s, idx, Instant::now(), svc.submit(key, stations[s].x[idx].clone())?));
    }
    let mut lat_ms = Vec::with_capacity(requests);
    let mut hits = vec![0usize; stations.len()];
    let mut counts = vec![0usize; stations.len()];
    for (s, idx, t, rx) in pending {
        let scores = rx.recv().context("reply")?.map_err(|e| anyhow!(e))?.scores;
        lat_ms.push(t.elapsed().as_secs_f64() * 1e3);
        let pred = svc.models[s].predict(&scores);
        counts[s] += 1;
        if pred == stations[s].y[idx] {
            hits[s] += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("\nper-station accuracy (p{PRECISION} quantised serving path):");
    for (s, m) in svc.models.iter().enumerate() {
        println!(
            "  {:<18} {:>6.2}%  ({} readings)",
            m.name,
            100.0 * hits[s] as f64 / counts[s] as f64,
            counts[s]
        );
    }
    let l = stats::summarize(&lat_ms);
    println!(
        "\nline throughput: {:.0} readings/s  ({} readings in {:.3}s)",
        requests as f64 / wall,
        requests,
        wall
    );
    println!(
        "end-to-end latency: p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms  max {:.2} ms",
        l.p50, l.p95, l.p99, l.max
    );
    println!("coordinator: {}", svc.metrics.lock().unwrap().summary());

    // Cross-check the serving path against the bespoke-core ISS.
    println!("\ncrosscheck (PJRT vs rust reference vs Zero-Riscy ISS):");
    let report = svc.crosscheck(4)?;
    println!("{}", report.lines().last().unwrap_or(""));
    Ok(())
}
