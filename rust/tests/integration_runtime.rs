//! Runtime + coordinator integration over the real artifacts:
//! * the packed SIMD-MAC unit HLO (pure L1 kernel) vs the rust
//!   functional model, bit-exact;
//! * service bulk evaluation reproducing the manifest's python-side
//!   accuracies exactly;
//! * streaming path == bulk path on identical inputs;
//! * metrics and batching behaviour.
//!
//! Runs against `make artifacts` output when present, else the
//! checked-in `artifacts-fixture/` (the default stub runtime backend
//! interprets its stub executables — see `runtime::pjrt`).  Skips when
//! no tree is found, or when real HLO artifacts are present but the
//! crate was built without `--features xla` (the stub backend cannot
//! execute HLO text).

use printed_bespoke::coordinator::router::Key;
use printed_bespoke::coordinator::service::{Service, ServiceConfig};
use printed_bespoke::hw::mac_unit::MacConfig;
use printed_bespoke::ml::dataset::Dataset;
use printed_bespoke::ml::manifest::Manifest;
use printed_bespoke::runtime::pjrt::Runtime;
use printed_bespoke::sim::mac_model::MacState;
use printed_bespoke::util::rng::Pcg32;

fn manifest() -> Option<Manifest> {
    let dir = printed_bespoke::artifacts_dir().ok()?;
    let man = Manifest::load(&dir).ok()?;
    // Backend/tree mismatch in either direction skips cleanly: the stub
    // backend cannot execute real HLO text, and the xla backend cannot
    // execute the fixture's JSON stub descriptors.
    if Runtime::is_stub() != printed_bespoke::ml::fixtures::manifest_is_stub(&man) {
        eprintln!(
            "skipping: artifact tree does not match the compiled runtime backend \
             (stub backend: {}; real HLO artifacts need --features xla)",
            Runtime::is_stub()
        );
        return None;
    }
    Some(man)
}

#[test]
fn mac_unit_hlo_matches_functional_model() {
    let Some(man) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut rt = Runtime::cpu().unwrap();
    let mut rng = Pcg32::seeded(0xbeef);
    for (&p, (path, words)) in &man.mac_units {
        let lanes = (32 / p).max(1) as usize;
        for _ in 0..5 {
            let wa: Vec<i32> = (0..*words).map(|_| rng.next_u32() as i32).collect();
            let wb: Vec<i32> = (0..*words).map(|_| rng.next_u32() as i32).collect();
            let got = rt.run_mac_unit(path, &wa, &wb, lanes).unwrap();
            // Reference: the rust MAC model executing the same stream.
            let mut m = MacState::new(MacConfig::new(32, p));
            for (a, b) in wa.iter().zip(&wb) {
                m.mac(*a as u32 as u64, *b as u32 as u64);
            }
            let want: Vec<i32> = if p == 32 {
                vec![m.read(0) as i32]
            } else {
                (0..lanes).map(|l| m.read(l) as i32).collect()
            };
            assert_eq!(got, want, "p{p} packed MAC unit mismatch");
        }
    }
}

#[test]
fn service_reproduces_manifest_accuracy() {
    if manifest().is_none() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let svc = Service::start(ServiceConfig::default()).unwrap();
    for entry in svc.manifest.models.clone() {
        let ds = Dataset::load(svc.manifest.data_dir(), &entry.dataset, "test").unwrap();
        for (&p, &py_acc) in &entry.quant_accuracy {
            let r = svc.evaluate(&entry.name, p, &ds.x, &ds.y).unwrap();
            // The PJRT path runs the very computation the python eval
            // ran (same HLO numerics) — accuracies must agree exactly
            // up to the last-digit float formatting of the manifest.
            assert!(
                (r.accuracy - py_acc).abs() < 1e-9,
                "{} p{p}: pjrt {} vs python {}",
                entry.name,
                r.accuracy,
                py_acc
            );
        }
        // Float reference accuracy as well.
        let key = Key::new(&entry.name, "float");
        let scores = svc.scores(&key, &ds.x).unwrap();
        let model = svc.model(&entry.name).unwrap();
        let preds: Vec<i64> = scores.iter().map(|s| model.predict(s)).collect();
        let acc = ds.accuracy(&preds);
        assert!(
            (acc - entry.float_accuracy).abs() < 1e-9,
            "{} float: {} vs {}",
            entry.name,
            acc,
            entry.float_accuracy
        );
    }
}

#[test]
fn streaming_equals_bulk() {
    if manifest().is_none() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let cfg = ServiceConfig { max_batch: 16, linger_ms: 1, ..ServiceConfig::default() };
    let svc = Service::start(cfg).unwrap();
    let model = &svc.models[3];
    let ds = Dataset::load(svc.manifest.data_dir(), &model.dataset, "test").unwrap();
    let xs: Vec<Vec<f32>> = ds.x.iter().take(50).cloned().collect();
    let key = Key::precision(&model.name, 16);
    let bulk = svc.scores(&key, &xs).unwrap();
    let pending: Vec<_> = xs.iter().map(|x| svc.submit(key.clone(), x.clone()).unwrap()).collect();
    for (i, rx) in pending.into_iter().enumerate() {
        let got = rx.recv().unwrap().unwrap();
        assert_eq!(got.scores, bulk[i], "sample {i} differs between streaming and bulk");
        assert!(got.batch >= 1, "reply must carry the batch size it rode in");
    }
    let m = svc.metrics.lock().unwrap().clone();
    assert!(m.batches >= 4, "batching should have occurred: {}", m.summary());
    assert!(m.mean_batch_size() > 1.0, "requests should coalesce: {}", m.summary());
}

#[test]
fn executable_cache_compiles_once() {
    if manifest().is_none() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let svc = Service::start(ServiceConfig::default()).unwrap();
    let model = &svc.models[0];
    let ds = Dataset::load(svc.manifest.data_dir(), &model.dataset, "test").unwrap();
    let key = Key::precision(&model.name, 8);
    let xs: Vec<Vec<f32>> = ds.x.iter().take(8).cloned().collect();
    for _ in 0..4 {
        svc.scores(&key, &xs).unwrap();
    }
    let m = svc.metrics.lock().unwrap().clone();
    assert_eq!(m.compiles, 1, "expected a single compile: {}", m.summary());
    assert_eq!(m.batches, 4);
}

#[test]
fn unknown_model_or_variant_errors_cleanly() {
    if manifest().is_none() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let svc = Service::start(ServiceConfig::default()).unwrap();
    let bad = Key::new("nonexistent_model", "p16");
    assert!(svc.scores(&bad, &[vec![0.0; 4]]).is_err());
    let bad_variant = Key::new(&svc.models[0].name.clone(), "p3");
    assert!(svc.scores(&bad_variant, &[vec![0.0; 21]]).is_err());
    // The service must still work afterwards.
    let ds = Dataset::load(svc.manifest.data_dir(), &svc.models[0].dataset, "test").unwrap();
    let good = Key::precision(&svc.models[0].name, 16);
    assert!(svc.scores(&good, &ds.x[..4].to_vec()).is_ok());
}
