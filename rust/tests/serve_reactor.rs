//! Reactor-specific serving tests (ISSUE 7): connection concurrency
//! beyond the compute pool, non-blocking refusals, pipelining and
//! byte-dripped uploads through the readiness loop, slow-loris
//! eviction, queue-level backpressure, drain-on-shutdown, and the
//! fleet-1k bit-identity gate via `loadgen::verify`; plus the ISSUE 10
//! overload surface — deadline shedding before compute and the
//! brownout precision-downshift hysteresis.
//!
//! Skips cleanly when no artifact tree matches the compiled backend
//! (same policy as `serve_http.rs`), and the fd-hungry fleet tests skip
//! with a note when the OS denies the file-descriptor budget.

use std::io::Write;
use std::net::TcpStream;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use printed_bespoke::coordinator::service::{Service, ServiceConfig};
use printed_bespoke::ml::dataset::Dataset;
use printed_bespoke::ml::manifest::Manifest;
use printed_bespoke::runtime::pjrt::Runtime;
use printed_bespoke::server::http::{Client, HttpConn, Message, Outcome};
use printed_bespoke::server::loadgen::{self, LoadgenConfig};
use printed_bespoke::server::{Server, ServerConfig};
use printed_bespoke::util::json::Value;
use printed_bespoke::util::poll::raise_nofile_limit;

fn manifest() -> Option<Manifest> {
    let dir = printed_bespoke::artifacts_dir().ok()?;
    let man = Manifest::load(&dir).ok()?;
    if Runtime::is_stub() != printed_bespoke::ml::fixtures::manifest_is_stub(&man) {
        eprintln!("skipping: artifact tree does not match the compiled runtime backend");
        return None;
    }
    Some(man)
}

fn start(scfg: ServerConfig) -> (Arc<Service>, Server) {
    start_with(ServiceConfig::default(), scfg)
}

fn start_with(svc_cfg: ServiceConfig, scfg: ServerConfig) -> (Arc<Service>, Server) {
    let svc = Arc::new(Service::start(svc_cfg).unwrap());
    let server = Server::start(Arc::clone(&svc), scfg).unwrap();
    (svc, server)
}

/// Read one server-initiated message off a raw stream (100 ms ticks,
/// bounded total wait).
fn read_unsolicited(conn: &mut HttpConn, budget: Duration) -> Message {
    let t0 = Instant::now();
    loop {
        match conn.read_message().unwrap() {
            Outcome::Message(m) => return m,
            Outcome::Closed => panic!("connection closed before any response arrived"),
            Outcome::Idle => assert!(t0.elapsed() < budget, "no response within {budget:?}"),
        }
    }
}

fn relaxed(c: &std::sync::atomic::AtomicU64) -> u64 {
    c.load(std::sync::atomic::Ordering::Relaxed)
}

/// Satellite 1: a refused client that never reads its 503 must not
/// stall the accept path — later arrivals still get their refusals
/// promptly, and an already-admitted connection keeps working.
#[test]
fn refusals_never_block_the_accept_path() {
    if manifest().is_none() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let scfg = ServerConfig { max_connections: 1, ..ServerConfig::default() };
    let (_svc, mut server) = start(scfg);
    let mut holder = Client::connect(server.addr()).unwrap();
    assert_eq!(holder.get("/healthz").unwrap().0, 200); // admitted for sure

    // 20 over-capacity clients that connect and then never read: their
    // 503s are queued asynchronously, so they can only stall their own
    // eviction timers.
    let silent: Vec<TcpStream> =
        (0..20).map(|_| TcpStream::connect(server.addr()).unwrap()).collect();

    // A well-behaved over-capacity client arriving *after* the silent
    // pack still gets its refusal within a tight bound.
    let t0 = Instant::now();
    let stream = TcpStream::connect(server.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
    let mut conn = HttpConn::new(stream);
    let msg = read_unsolicited(&mut conn, Duration::from_secs(10));
    assert!(t0.elapsed() < Duration::from_secs(2), "refusal stalled behind silent clients");
    assert!(msg.start_line.contains("503"), "want 503, got {:?}", msg.start_line);
    assert_eq!(msg.headers["retry-after"], "1");

    // Every refusal was counted, and the admitted connection is intact.
    assert!(relaxed(&server.metrics.rejected_busy) >= 21);
    assert_eq!(relaxed(&server.metrics.connections), 1, "no silent client was admitted");
    assert_eq!(holder.get("/healthz").unwrap().0, 200);
    drop(silent);
    server.shutdown();
}

/// The tentpole contract: connection concurrency is bounded by
/// `max_connections`, not `http_threads` — 1000 idle keep-alive
/// connections ride a 2-thread compute pool.
#[test]
fn thousand_idle_keepalive_connections_on_two_threads() {
    if manifest().is_none() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    const FLEET: usize = 1_000;
    let need_fds = FLEET as u64 * 2 + 512;
    let have_fds = raise_nofile_limit(8_192);
    if have_fds < need_fds {
        eprintln!("skipping: need ~{need_fds} fds, limit {have_fds}");
        return;
    }
    let scfg = ServerConfig {
        http_threads: 2,
        max_connections: FLEET + 64,
        keep_alive_ms: 60_000,
        ..ServerConfig::default()
    };
    let (_svc, mut server) = start(scfg);
    let mut clients: Vec<Client> = Vec::with_capacity(FLEET);
    for i in 0..FLEET {
        clients.push(Client::connect(server.addr()).unwrap());
        // Periodic round-trips keep the accept backlog drained (a
        // response proves every earlier connection was accepted too).
        if i % 100 == 99 {
            assert_eq!(clients[i].get("/healthz").unwrap().0, 200);
        }
    }
    // The reactor's open-connection gauge reaches the whole fleet
    // (updated once per loop round; give it a few ticks).
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        if relaxed(&server.metrics.open_connections) >= FLEET as u64 {
            break;
        }
        assert!(Instant::now() < deadline, "gauge never saw the full fleet");
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(relaxed(&server.metrics.connections) >= FLEET as u64);
    assert_eq!(relaxed(&server.metrics.rejected_busy), 0);
    // Spot-check liveness across the parked fleet.
    for i in [0usize, FLEET / 2, FLEET - 1] {
        assert_eq!(clients[i].get("/healthz").unwrap().0, 200);
    }
    drop(clients);
    server.shutdown();
}

/// Pipelined requests in one segment and byte-dripped requests both
/// frame correctly through the readiness loop.
#[test]
fn pipelined_and_dripped_requests_frame_correctly() {
    if manifest().is_none() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let (_svc, mut server) = start(ServerConfig::default());
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_millis(100))).unwrap();

    // Two full requests in a single write: the second must be picked up
    // from the connection buffer after the first response drains (the
    // socket may never turn readable again).
    let req = b"GET /healthz HTTP/1.1\r\nhost: pbsp\r\ncontent-length: 0\r\n\r\n";
    let both: Vec<u8> = req.iter().chain(req.iter()).copied().collect();
    stream.write_all(&both).unwrap();
    stream.flush().unwrap();
    let mut conn = HttpConn::new(stream.try_clone().unwrap());
    for i in 0..2 {
        let m = read_unsolicited(&mut conn, Duration::from_secs(10));
        assert!(m.start_line.contains("200"), "pipelined response {i}: {:?}", m.start_line);
        assert_eq!(m.headers["connection"], "keep-alive");
    }

    // Byte-drip a third request on the same connection: every gap sends
    // the reactor back through poll, and framing resumes in place.
    for chunk in req.chunks(7) {
        stream.write_all(chunk).unwrap();
        stream.flush().unwrap();
        std::thread::sleep(Duration::from_millis(2));
    }
    let m = read_unsolicited(&mut conn, Duration::from_secs(10));
    assert!(m.start_line.contains("200"), "dripped response: {:?}", m.start_line);
    assert_eq!(relaxed(&server.metrics.http_requests), 3);
    server.shutdown();
}

/// A peer that goes silent mid-message is evicted at the configured
/// deadline with a best-effort 400 — it cannot pin its slot forever.
#[test]
fn slow_loris_connection_is_evicted() {
    if manifest().is_none() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let scfg = ServerConfig { msg_deadline_ms: 200, ..ServerConfig::default() };
    let (_svc, mut server) = start(scfg);
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
    let t0 = Instant::now();
    stream.write_all(b"POST /v1/score/m/p8 HTTP/1.1\r\ncontent-le").unwrap();
    stream.flush().unwrap();
    let mut conn = HttpConn::new(stream);
    let m = read_unsolicited(&mut conn, Duration::from_secs(10));
    assert!(m.start_line.contains("400"), "want 400, got {:?}", m.start_line);
    assert!(t0.elapsed() < Duration::from_secs(5), "eviction took too long");
    let text = String::from_utf8(m.body).unwrap();
    assert!(text.contains("incomplete"), "unexpected error body {text:?}");
    // ...and the connection is closed right after.
    match conn.read_message().unwrap() {
        Outcome::Closed => {}
        other => panic!("want close after the 400, got {other:?}"),
    }
    assert!(relaxed(&server.metrics.responses_4xx) >= 1);
    assert!(
        relaxed(&server.metrics.evicted_read) >= 1,
        "slow-loris eviction must land in its own counter"
    );
    assert_eq!(relaxed(&server.metrics.evicted_idle), 0, "no idle reap happened here");
    server.shutdown();
}

/// A keep-alive connection that goes idle past its budget is reaped by
/// the deadline sweep — and the reap lands in `evicted_idle`, not one
/// of the failure counters.
#[test]
fn idle_keepalive_is_reaped_and_counted() {
    if manifest().is_none() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let scfg = ServerConfig { keep_alive_ms: 100, ..ServerConfig::default() };
    let (_svc, mut server) = start(scfg);
    let mut c = Client::connect(server.addr()).unwrap();
    assert_eq!(c.get("/healthz").unwrap().0, 200);
    // Go silent: the sweep reaps the connection past 100 ms idle.
    let deadline = Instant::now() + Duration::from_secs(5);
    while relaxed(&server.metrics.evicted_idle) == 0 {
        assert!(Instant::now() < deadline, "idle connection never reaped");
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(relaxed(&server.metrics.evicted_read), 0);
    assert_eq!(relaxed(&server.metrics.evicted_write), 0);
    server.shutdown();
}

/// Queue-level backpressure: past `max_queued` in-flight requests the
/// server answers 503 on the *healthy, kept* connection.
#[test]
fn queue_full_gets_503_and_keeps_the_connection() {
    let Some(man) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    // Long linger makes every scoring request occupy the queue for
    // ~100 ms, so concurrent arrivals deterministically overrun a
    // queue budget of one.
    let svc_cfg = ServiceConfig { linger_ms: 100, max_batch: 1_000, ..ServiceConfig::default() };
    let scfg = ServerConfig { http_threads: 4, max_queued: 1, ..ServerConfig::default() };
    let (_svc, mut server) = start_with(svc_cfg, scfg);
    let model = man.models[0].name.clone();
    let ds = Dataset::load(man.data_dir(), &man.models[0].dataset, "test").unwrap();
    let body = {
        let row = Value::Arr(ds.x[0].iter().map(|&f| Value::Num(f as f64)).collect());
        Value::obj(vec![("x", row)]).to_string()
    };
    let addr = server.addr();
    let barrier = Arc::new(Barrier::new(6));
    let handles: Vec<_> = (0..6)
        .map(|_| {
            let barrier = Arc::clone(&barrier);
            let path = format!("/v1/score/{model}/p8");
            let body = body.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                barrier.wait();
                let (status, text) = c.post(&path, &body).unwrap();
                if status == 503 {
                    assert!(text.contains("queue"), "503 names its cause: {text}");
                }
                // The connection survives the refusal (keep-alive); the
                // queue may still be busy for a while, so keep asking —
                // every interim answer must itself be a clean 503.
                let deadline = Instant::now() + Duration::from_secs(10);
                loop {
                    let (s, _) = c.get("/healthz").unwrap();
                    if s == 200 {
                        break;
                    }
                    assert_eq!(s, 503, "unexpected status while the queue drains");
                    assert!(Instant::now() < deadline, "queue never drained");
                    std::thread::sleep(Duration::from_millis(20));
                }
                status
            })
        })
        .collect();
    let statuses: Vec<u16> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert!(statuses.contains(&200), "someone must be served: {statuses:?}");
    assert!(statuses.contains(&503), "queue overrun must refuse visibly: {statuses:?}");
    assert!(relaxed(&server.metrics.rejected_queue) >= 1);
    server.shutdown();
}

/// Shutdown with a request in flight: the response still arrives (drain
/// with bounded grace), and shutdown stays idempotent.
#[test]
fn shutdown_finishes_in_flight_request() {
    let Some(man) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    // linger >> the shutdown trigger delay below: the request is still
    // in flight when the flag flips.
    let svc_cfg = ServiceConfig { linger_ms: 300, ..ServiceConfig::default() };
    let (_svc, mut server) = start_with(svc_cfg, ServerConfig::default());
    let model = man.models[0].name.clone();
    let ds = Dataset::load(man.data_dir(), &man.models[0].dataset, "test").unwrap();
    let body = {
        let row = Value::Arr(ds.x[0].iter().map(|&f| Value::Num(f as f64)).collect());
        Value::obj(vec![("x", row)]).to_string()
    };
    let addr = server.addr();
    let poster = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.post(&format!("/v1/score/{model}/p8"), &body).unwrap()
    });
    std::thread::sleep(Duration::from_millis(100));
    server.shutdown();
    let (status, text) = poster.join().unwrap();
    assert_eq!(status, 200, "in-flight request must drain through shutdown: {text}");
    assert!(Value::parse(&text).unwrap().get("scores").is_ok());
    server.shutdown(); // idempotent
}

/// The ISSUE 7 acceptance gate at fleet 1k: every byte served over the
/// reactor is bit-identical to direct in-process scoring — in both
/// arrival modes, and (in release builds) on the batched-ISS backend.
#[test]
fn fleet_1k_bit_identical_via_loadgen_verify() {
    if manifest().is_none() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    const FLEET: usize = 1_000;
    let need_fds = FLEET as u64 * 2 + 512;
    let have_fds = raise_nofile_limit(8_192);
    if have_fds < need_fds {
        eprintln!("skipping: need ~{need_fds} fds, limit {have_fds}");
        return;
    }
    // Debug builds keep tier-1 fast on the stub runtime; release runs
    // pin the full HTTP -> reactor -> batcher -> lockstep-ISS chain.
    let svc_cfg = ServiceConfig { iss: !cfg!(debug_assertions), ..ServiceConfig::default() };
    let scfg = ServerConfig {
        max_connections: FLEET + 64,
        keep_alive_ms: 60_000,
        ..ServerConfig::default()
    };
    let (svc, mut server) = start_with(svc_cfg, scfg);

    // Closed-loop at fleet 1k.
    let cfg = LoadgenConfig {
        fleet: FLEET,
        requests_per_device: 1,
        seed: 42,
        ..Default::default()
    };
    let report = loadgen::run(server.addr(), &cfg).unwrap();
    assert_eq!(report.errors, 0, "fleet saw errors: {}", report.summary());
    assert_eq!(report.records.len(), FLEET);
    let checked = loadgen::verify(&svc, &report).unwrap();
    assert_eq!(checked, FLEET, "verify must cover every served request");

    // Open-loop arrivals through the same frontend: identical draws,
    // identical bits (arrival mode cannot change scoring).
    let open = LoadgenConfig {
        fleet: 64,
        requests_per_device: 4,
        seed: 7,
        open_rps: 2_000.0,
        ..Default::default()
    };
    let report = loadgen::run(server.addr(), &open).unwrap();
    assert_eq!(report.errors, 0, "open-loop fleet saw errors: {}", report.summary());
    assert_eq!(report.records.len(), 64 * 4);
    let checked = loadgen::verify(&svc, &report).unwrap();
    assert_eq!(checked, 64 * 4);
    server.shutdown();
}

/// Deadline shedding (ISSUE 10): a request whose `X-Deadline-Ms`
/// budget is spent while it waits for the compute pool is 504'd at
/// pickup — before it ever reaches the coordinator — and the
/// connection survives.
#[test]
fn expired_deadline_is_shed_before_compute() {
    let Some(man) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    // One pool thread + a long batcher linger: the blocker occupies the
    // lone thread long enough that the doomed request's 1 ms budget is
    // certainly spent by the time it is picked up.
    let svc_cfg = ServiceConfig { linger_ms: 300, ..ServiceConfig::default() };
    let scfg = ServerConfig { http_threads: 1, ..ServerConfig::default() };
    let (svc, mut server) = start_with(svc_cfg, scfg);
    let model = man.models[0].name.clone();
    let ds = Dataset::load(man.data_dir(), &man.models[0].dataset, "test").unwrap();
    let body = {
        let row = Value::Arr(ds.x[0].iter().map(|&f| Value::Num(f as f64)).collect());
        Value::obj(vec![("x", row)]).to_string()
    };
    let addr = server.addr();
    let requests_before = svc.metrics.lock().unwrap().requests;

    let blocker = {
        let path = format!("/v1/score/{model}/p8");
        let body = body.clone();
        std::thread::spawn(move || {
            let mut c = Client::connect(addr).unwrap();
            c.post(&path, &body).unwrap().0
        })
    };
    // Let the blocker reach the pool before the doomed request arrives.
    std::thread::sleep(Duration::from_millis(50));

    let mut c = Client::connect(addr).unwrap();
    let (status, _headers, text) = c
        .request_meta(
            "POST",
            &format!("/v1/score/{model}/p8"),
            Some(&body),
            &[("x-deadline-ms", "1".to_string())],
        )
        .unwrap();
    assert_eq!(status, 504, "expired request must be shed: {text}");
    assert!(text.contains("deadline"), "504 names its cause: {text}");
    assert!(relaxed(&server.metrics.deadline_shed) >= 1);

    assert_eq!(blocker.join().unwrap(), 200, "the in-budget request is unaffected");
    // The shed request never touched the coordinator: only the blocker
    // was submitted.
    let requests_after = svc.metrics.lock().unwrap().requests;
    assert_eq!(
        requests_after,
        requests_before + 1,
        "a shed request must not reach the compute path"
    );
    // A 504 is the request's failure, not the connection's.
    assert_eq!(c.get("/healthz").unwrap().0, 200, "connection must survive the 504");
    server.shutdown();
}

/// Brownout (ISSUE 10): past the high watermark of in-flight requests
/// the router serves the next-lower precision — labelled, counted, and
/// bit-identical to a direct low-precision score — and recovers at the
/// low watermark (hysteresis), after which the same request is served
/// at full precision again.
#[test]
fn brownout_downshifts_precision_with_hysteresis() {
    let Some(man) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    use printed_bespoke::coordinator::router::Key;
    // A long linger keeps each scoring request in flight ~500 ms, so 5
    // barrier-synchronized posts hold the gauge over the watermark long
    // enough to probe the browned-out window deterministically.
    let svc_cfg = ServiceConfig { linger_ms: 500, max_batch: 1_000, ..ServiceConfig::default() };
    let scfg = ServerConfig {
        http_threads: 8,
        brownout_high: 3,
        brownout_low: 1,
        ..ServerConfig::default()
    };
    let (svc, mut server) = start_with(svc_cfg, scfg);
    let model = man.models[0].name.clone();
    let ds = Dataset::load(man.data_dir(), &man.models[0].dataset, "test").unwrap();
    let body = {
        let row = Value::Arr(ds.x[0].iter().map(|&f| Value::Num(f as f64)).collect());
        Value::obj(vec![("x", row)]).to_string()
    };
    let addr = server.addr();
    let barrier = Arc::new(Barrier::new(5));
    let blockers: Vec<_> = (0..5)
        .map(|_| {
            let barrier = Arc::clone(&barrier);
            let path = format!("/v1/score/{model}/p8");
            let body = body.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                barrier.wait();
                c.post(&path, &body).unwrap().0
            })
        })
        .collect();
    // The reactor's controller trips within a poll round of the gauge
    // crossing the high watermark.
    let deadline = Instant::now() + Duration::from_secs(5);
    while !server.metrics.brownout.load(std::sync::atomic::Ordering::Relaxed) {
        assert!(
            Instant::now() < deadline,
            "brownout never tripped (inflight {})",
            relaxed(&server.metrics.inflight)
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(relaxed(&server.metrics.brownout_entered) >= 1);

    let mut probe = Client::connect(addr).unwrap();
    // Readiness reflects the brownout (liveness stays green).
    let (s, text) = probe.get("/readyz").unwrap();
    assert_eq!(s, 503, "readyz must refuse under brownout: {text}");
    assert!(text.contains("brownout"), "readyz names its reason: {text}");
    assert_eq!(probe.get("/healthz").unwrap().0, 200);

    // A p8 request is downshifted to p4 — labelled with both variants,
    // and bit-identical to scoring the same sample at p4 directly.
    let (status, text) = probe.post(&format!("/v1/score/{model}/p8"), &body).unwrap();
    assert_eq!(status, 200, "degraded serve still succeeds: {text}");
    let v = Value::parse(&text).unwrap();
    assert_eq!(v.get("variant").unwrap().as_str().unwrap(), "p4");
    assert!(v.get("degraded").unwrap().as_bool().unwrap());
    assert_eq!(v.get("requested").unwrap().as_str().unwrap(), "p8");
    let served = v.get("scores").unwrap().as_f64_vec().unwrap();
    let direct = svc.scores(&Key::precision(&model, 4), &[ds.x[0].clone()]).unwrap();
    assert_eq!(served, direct[0], "degraded response must be bit-identical to the p4 variant");
    assert!(relaxed(&server.metrics.degraded) >= 1);

    for b in blockers {
        assert_eq!(b.join().unwrap(), 200, "browned-out siblings still succeed at p8");
    }
    // Hysteresis: the flag clears once in-flight falls to the low
    // watermark — and the same request is full-precision again.
    let deadline = Instant::now() + Duration::from_secs(5);
    while server.metrics.brownout.load(std::sync::atomic::Ordering::Relaxed) {
        assert!(Instant::now() < deadline, "brownout never cleared after drain");
        std::thread::sleep(Duration::from_millis(10));
    }
    let (status, text) = probe.post(&format!("/v1/score/{model}/p8"), &body).unwrap();
    assert_eq!(status, 200);
    let v = Value::parse(&text).unwrap();
    assert_eq!(v.get("variant").unwrap().as_str().unwrap(), "p8");
    assert!(v.opt("degraded").is_none(), "undegraded responses must not carry the flag");
    assert_eq!(probe.get("/readyz").unwrap().0, 200, "readyz recovers with the flag");
    server.shutdown();
}
