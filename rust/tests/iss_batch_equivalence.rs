//! Satellite: the batched lockstep engine (`sim::batch`) must be
//! **bit-identical** to the scalar translated engine, per lane, on
//! every observable — which (via `tests/iss_equivalence.rs`) also pins
//! it to the per-instruction interpreter and the pre-rework harness.
//!
//! Four contracts, pinned differentially:
//!
//! 1. model fixtures: `run_*_batched` equals `run_*_scalar_traced` on
//!    scores, predictions, cycles, instructions and the complete merged
//!    `FullProfile`, across all six models, both cores, lane counts
//!    {1, 3, 8, 64} including non-divisor tails, in both trace modes;
//! 2. the pool-sharded entry points (what the sweeps use, CI runs this
//!    file under `PBSP_THREADS=1` and `8`) ride the batched path and
//!    still equal the scalar sequential run at 1 and 8 workers;
//! 3. adversarial fuzz: random branch-dense programs (data-dependent
//!    branches, `jalr`s to dynamic mid-block targets, MAC ops) run with
//!    per-lane divergent memory images — every lane must match its own
//!    isolated scalar reference in outcome (halt kind *or* error
//!    message), registers, PC, memory, flags, profile and translated
//!    counters; a poisoned lane (fault, fuel exhaustion) never perturbs
//!    its siblings;
//! 4. directed divergence: per-lane fuel exhaustion mid-batch and
//!    MAC-without-unit faults on a shared in-flight block.
//!
//! Runs against `make artifacts` output when present, else the
//! checked-in `artifacts-fixture/`; the fuzz tests need no artifacts.

use std::sync::Arc;

use printed_bespoke::hw::mac_unit::MacConfig;
use printed_bespoke::isa::rv32;
use printed_bespoke::isa::rv32_asm::Asm;
use printed_bespoke::isa::tpisa;
use printed_bespoke::isa::MacOp;
use printed_bespoke::ml::codegen_rv32::{self, Rv32Variant};
use printed_bespoke::ml::codegen_tpisa::{self, TpVariant};
use printed_bespoke::ml::dataset::Dataset;
use printed_bespoke::ml::harness;
use printed_bespoke::ml::manifest::Manifest;
use printed_bespoke::ml::model::Model;
use printed_bespoke::sim::mem::RAM_BASE;
use printed_bespoke::sim::tpisa::TpIsa;
use printed_bespoke::sim::trace::{CyclesOnly, FullProfile, Profile};
use printed_bespoke::sim::zero_riscy::ZeroRiscy;
use printed_bespoke::sim::{BatchRv32, BatchTpIsa, PreparedRv32, PreparedTpIsa};
use printed_bespoke::util::rng::Pcg32;
use printed_bespoke::util::threadpool::ThreadPool;

fn load() -> Option<(Manifest, Vec<Model>)> {
    let dir = printed_bespoke::artifacts_dir().ok()?;
    let man = Manifest::load(&dir).ok()?;
    let models = man.models.iter().map(|e| Model::load(&e.weights).unwrap()).collect();
    Some((man, models))
}

/// Random in-range inputs: convex combinations of dataset rows.
fn random_samples(man: &Manifest, model: &Model, rng: &mut Pcg32, n: usize) -> Vec<Vec<f32>> {
    let ds = Dataset::load(man.data_dir(), &model.dataset, "test").unwrap();
    (0..n)
        .map(|_| {
            let a = &ds.x[rng.range_usize(0, ds.x.len() - 1)];
            let b = &ds.x[rng.range_usize(0, ds.x.len() - 1)];
            let t = rng.f64() as f32;
            a.iter().zip(b).map(|(&va, &vb)| va + t * (vb - va)).collect()
        })
        .collect()
}

/// Bit-level equality of score matrices.
fn assert_scores_eq(a: &[Vec<f64>], b: &[Vec<f64>], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: sample count");
    for (i, (ra, rb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ra.len(), rb.len(), "{what} sample {i}: score count");
        for (j, (va, vb)) in ra.iter().zip(rb).enumerate() {
            assert_eq!(va.to_bits(), vb.to_bits(), "{what} sample {i} score {j}: {va} vs {vb}");
        }
    }
}

/// Every observable of two full profiles.
fn assert_profiles_eq(a: &Profile, b: &Profile, what: &str) {
    assert_eq!(a.instr_counts(), b.instr_counts(), "{what}: histogram");
    assert_eq!(a.static_mnemonics, b.static_mnemonics, "{what}: static mnemonics");
    assert_eq!(a.regs_used, b.regs_used, "{what}: regs_used");
    assert_eq!(a.max_pc, b.max_pc, "{what}: max_pc");
    assert_eq!(a.csr_used, b.csr_used, "{what}: csr_used");
    assert_eq!(a.syscalls_used, b.syscalls_used, "{what}: syscalls_used");
    assert_eq!(a.cycles, b.cycles, "{what}: cycles");
    assert_eq!(a.instructions, b.instructions, "{what}: instructions");
    assert_eq!(a.loads, b.loads, "{what}: loads");
    assert_eq!(a.stores, b.stores, "{what}: stores");
    assert_eq!(a.mul_ops, b.mul_ops, "{what}: mul_ops");
    assert_eq!(a.mac_ops, b.mac_ops, "{what}: mac_ops");
    assert_eq!(a.branches_taken, b.branches_taken, "{what}: branches_taken");
    assert_eq!(a.max_ram_offset, b.max_ram_offset, "{what}: max_ram_offset");
}

/// Lane widths the model tests sweep: 1 (degenerate scalar), 3 and 8
/// leave non-divisor tails on the 10-sample batches, 64 clamps to the
/// sample count (the serving fleet width).
const LANES: [usize; 4] = [1, 3, 8, 64];

// ---------------------------------------------------------------------------
// (1) + (2): model fixtures, both cores, both trace modes, pools {1, 8}.
// ---------------------------------------------------------------------------

#[test]
fn rv32_batched_matches_scalar_across_lane_counts() {
    let Some((man, models)) = load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut rng = Pcg32::seeded(0x1550_E9_30);
    for model in &models {
        let xs = random_samples(&man, model, &mut rng, 10);
        for variant in [Rv32Variant::Baseline, Rv32Variant::Simd(8)] {
            let prog = codegen_rv32::generate(model, variant).unwrap();
            let scalar_full = harness::run_rv32_scalar_traced::<FullProfile>(model, &prog, &xs)
                .unwrap();
            let scalar_cyc =
                harness::run_rv32_scalar_traced::<CyclesOnly>(model, &prog, &xs).unwrap();
            for lanes in LANES {
                let what = format!("{} {variant:?} lanes {lanes}", model.name);
                let full =
                    harness::run_rv32_batched::<FullProfile>(model, &prog, &xs, lanes).unwrap();
                assert_scores_eq(&full.scores, &scalar_full.scores, &what);
                assert_eq!(full.predictions, scalar_full.predictions, "{what}: predictions");
                assert_profiles_eq(&full.profile, &scalar_full.profile, &what);
                assert_eq!(
                    full.cycles_per_sample.to_bits(),
                    scalar_full.cycles_per_sample.to_bits(),
                    "{what}: cycles/sample"
                );
                let cyc =
                    harness::run_rv32_batched::<CyclesOnly>(model, &prog, &xs, lanes).unwrap();
                assert_scores_eq(&cyc.scores, &scalar_cyc.scores, &what);
                assert_eq!(cyc.predictions, scalar_cyc.predictions, "{what}: cyc predictions");
                assert_eq!(cyc.profile.cycles, scalar_cyc.profile.cycles, "{what}: cyc cycles");
                assert_eq!(
                    cyc.profile.instructions,
                    scalar_cyc.profile.instructions,
                    "{what}: cyc instructions"
                );
                assert!(cyc.profile.instr_counts().is_empty(), "{what}: cyc histogram");
            }
            // The default entry points ride the batched path.
            let deflt = harness::run_rv32(model, &prog, &xs).unwrap();
            let what = format!("{} {variant:?} default", model.name);
            assert_scores_eq(&deflt.scores, &scalar_full.scores, &what);
            assert_profiles_eq(&deflt.profile, &scalar_full.profile, &what);
        }
    }
}

#[test]
fn tpisa_batched_matches_scalar_across_lane_counts() {
    let Some((man, models)) = load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut rng = Pcg32::seeded(0x1550_E9_31);
    for model in &models {
        let xs = random_samples(&man, model, &mut rng, 10);
        let configs =
            [(8u32, TpVariant::Baseline), (8, TpVariant::Mac { precision: 8 }), (32, TpVariant::Mac { precision: 8 })];
        for (d, variant) in configs {
            let p = codegen_tpisa::quant_precision(d, variant);
            if model.qlayers(p).is_err() {
                continue;
            }
            let Ok(prog) = codegen_tpisa::generate(model, d, variant) else {
                continue;
            };
            let scalar_full = harness::run_tpisa_scalar_traced::<FullProfile>(model, &prog, &xs)
                .unwrap();
            let scalar_cyc =
                harness::run_tpisa_scalar_traced::<CyclesOnly>(model, &prog, &xs).unwrap();
            for lanes in LANES {
                let what = format!("{} d{d} {variant:?} lanes {lanes}", model.name);
                let full =
                    harness::run_tpisa_batched::<FullProfile>(model, &prog, &xs, lanes).unwrap();
                assert_scores_eq(&full.scores, &scalar_full.scores, &what);
                assert_eq!(full.predictions, scalar_full.predictions, "{what}: predictions");
                assert_profiles_eq(&full.profile, &scalar_full.profile, &what);
                let cyc =
                    harness::run_tpisa_batched::<CyclesOnly>(model, &prog, &xs, lanes).unwrap();
                assert_scores_eq(&cyc.scores, &scalar_cyc.scores, &what);
                assert_eq!(cyc.profile.cycles, scalar_cyc.profile.cycles, "{what}: cyc cycles");
                assert_eq!(
                    cyc.profile.instructions,
                    scalar_cyc.profile.instructions,
                    "{what}: cyc instructions"
                );
            }
        }
    }
}

#[test]
fn sharded_pool_runs_ride_the_batched_path() {
    let Some((man, models)) = load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut rng = Pcg32::seeded(0x1550_E9_32);
    let pools = [ThreadPool::new(1), ThreadPool::new(8)];
    for model in &models {
        let xs = random_samples(&man, model, &mut rng, 9);
        let prog = codegen_rv32::generate(model, Rv32Variant::Simd(8)).unwrap();
        let scalar = harness::run_rv32_scalar_traced::<FullProfile>(model, &prog, &xs).unwrap();
        let tprog = codegen_tpisa::generate(model, 32, TpVariant::Mac { precision: 8 }).unwrap();
        let tscalar = harness::run_tpisa_scalar_traced::<FullProfile>(model, &tprog, &xs).unwrap();
        for pool in &pools {
            let what = format!("{} ({} workers)", model.name, pool.threads());
            let par = harness::run_rv32_on_traced::<FullProfile>(pool, model, &prog, &xs).unwrap();
            assert_scores_eq(&par.scores, &scalar.scores, &what);
            assert_eq!(par.predictions, scalar.predictions, "{what}: predictions");
            assert_profiles_eq(&par.profile, &scalar.profile, &what);
            let tpar =
                harness::run_tpisa_on_traced::<FullProfile>(pool, model, &tprog, &xs).unwrap();
            assert_scores_eq(&tpar.scores, &tscalar.scores, &what);
            assert_profiles_eq(&tpar.profile, &tscalar.profile, &what);
        }
    }
}

// ---------------------------------------------------------------------------
// (3): adversarial fuzz — shared program, divergent per-lane memory.
// ---------------------------------------------------------------------------

/// Run `code` over `lanes` divergent RAM images both batched and as
/// isolated scalar references, and assert every lane observable agrees
/// — in both trace modes.  `FullProfile` compares the complete per-lane
/// profile; `CyclesOnly` compares architectural state per lane and the
/// folded aggregate profile against the merged scalar ones.
fn compare_batch_rv32(
    code: &[rv32::Instr],
    rams: &[Vec<u8>],
    fuel: u64,
    mac: Option<MacConfig>,
    what: &str,
) {
    let prepared = Arc::new(PreparedRv32::new(code, &[], 0x400, mac));

    // Isolated scalar references, one per lane, on the same engine the
    // batch retires through (`run_translated`).
    let refs: Vec<(ZeroRiscy, Result<printed_bespoke::sim::zero_riscy::Halt, String>)> = rams
        .iter()
        .map(|ram| {
            let mut sim = ZeroRiscy::from_prepared(Arc::clone(&prepared));
            sim.mem.write_ram(0, ram).unwrap();
            let r = sim.run_translated::<FullProfile>(fuel).map_err(|e| e.to_string());
            (sim, r)
        })
        .collect();

    let mut batch = BatchRv32::new(Arc::clone(&prepared), rams.len());
    for (i, ram) in rams.iter().enumerate() {
        batch.lane_mut(i).mem.write_ram(0, ram).unwrap();
    }
    let results = batch.run::<FullProfile>(rams.len(), fuel);
    for (i, (sref, rref)) in refs.iter().enumerate() {
        match (&results[i], rref) {
            (Ok(hb), Ok(hr)) => assert_eq!(hb, hr, "{what} lane {i}: halt kind"),
            (Err(eb), Err(er)) => {
                assert_eq!(&eb.to_string(), er, "{what} lane {i}: error message");
            }
            (rb, rr) => panic!("{what} lane {i}: divergent outcome {rb:?} vs {rr:?}"),
        }
        assert_eq!(batch.lane(i).regs, sref.regs, "{what} lane {i}: regs");
        assert_eq!(batch.lane(i).pc, sref.pc, "{what} lane {i}: pc");
        assert_eq!(batch.lane(i).mem.ram, sref.mem.ram, "{what} lane {i}: ram");
        assert_profiles_eq(
            &batch.lane(i).profile,
            &sref.profile,
            &format!("{what} lane {i}"),
        );
        assert_eq!(
            batch.lane(i).exec_stats.blocks,
            sref.exec_stats.blocks,
            "{what} lane {i}: blocks"
        );
        assert_eq!(
            batch.lane(i).exec_stats.fused_uops,
            sref.exec_stats.fused_uops,
            "{what} lane {i}: fused"
        );
        assert_eq!(
            batch.lane(i).exec_stats.fallback_instrs,
            sref.exec_stats.fallback_instrs,
            "{what} lane {i}: fallback"
        );
    }

    // CyclesOnly: per-lane architectural state plus the folded
    // aggregates (the shared block bookkeeping must sum to exactly the
    // per-lane totals the scalar engine books).
    let crefs: Vec<ZeroRiscy> = rams
        .iter()
        .map(|ram| {
            let mut sim = ZeroRiscy::from_prepared(Arc::clone(&prepared));
            sim.mem.write_ram(0, ram).unwrap();
            let _ = sim.run_translated::<CyclesOnly>(fuel);
            sim
        })
        .collect();
    let mut cbatch = BatchRv32::new(prepared, rams.len());
    for (i, ram) in rams.iter().enumerate() {
        cbatch.lane_mut(i).mem.write_ram(0, ram).unwrap();
    }
    let _ = cbatch.run::<CyclesOnly>(rams.len(), fuel);
    let mut folded = Profile::default();
    cbatch.fold_profile(&mut folded);
    let mut merged = Profile::default();
    for (i, sref) in crefs.iter().enumerate() {
        assert_eq!(cbatch.lane(i).regs, sref.regs, "{what} lane {i}: cyc regs");
        assert_eq!(cbatch.lane(i).mem.ram, sref.mem.ram, "{what} lane {i}: cyc ram");
        merged.merge(&sref.profile);
    }
    assert_eq!(folded.cycles, merged.cycles, "{what}: folded cycles");
    assert_eq!(folded.instructions, merged.instructions, "{what}: folded instructions");
    assert!(folded.instr_counts().is_empty(), "{what}: cyc histogram");
}

/// A random branch-dense RV32 program whose control flow depends on
/// RAM contents (so divergent lane images diverge the lanes): segments
/// of random data ops joined by branches on loaded values, `jalr`s to
/// already-placed labels (dynamic targets landing mid-block), and MAC
/// ops on every third case (poisoned on MAC-less cores).
fn random_divergent_rv32(rng: &mut Pcg32, with_mac: bool) -> Vec<rv32::Instr> {
    use rv32::{AluOp, BranchOp, LoadOp, StoreOp};
    let mut a = Asm::new();
    let segs = rng.range_usize(3, 7);
    a.li(8, RAM_BASE as i32); // s0: RAM base, read-only below
    let mut placed: Vec<usize> = Vec::new();
    let pool: [u8; 7] = [5, 6, 7, 10, 11, 12, 13];
    let reg = |rng: &mut Pcg32| pool[rng.range_usize(0, pool.len() - 1)];
    for s in 0..segs {
        placed.push(a.here());
        a.label(&format!("s{s}"));
        for _ in 0..rng.range_usize(1, 4) {
            match rng.range_usize(0, 6) {
                0 => {
                    let rd = reg(rng);
                    let rs = reg(rng);
                    a.addi(rd, rs, rng.range_i64(-64, 64) as i32);
                }
                1 => {
                    let op = *rng.choice(&[AluOp::Add, AluOp::Sub, AluOp::Xor, AluOp::And]);
                    a.push(rv32::Instr::Op { op, rd: reg(rng), rs1: reg(rng), rs2: reg(rng) });
                }
                2 | 3 => {
                    // Lane-divergent value: load from the per-lane image.
                    let op = *rng.choice(&[LoadOp::Lw, LoadOp::Lbu, LoadOp::Lhu]);
                    a.push(rv32::Instr::Load {
                        op,
                        rd: reg(rng),
                        rs1: 8,
                        offset: rng.range_i64(0, 60) as i32,
                    });
                }
                4 => {
                    let op = *rng.choice(&[StoreOp::Sw, StoreOp::Sb]);
                    a.push(rv32::Instr::Store {
                        op,
                        rs2: reg(rng),
                        rs1: 8,
                        offset: rng.range_i64(64, 120) as i32,
                    });
                }
                5 => {
                    if with_mac {
                        a.mac(reg(rng), reg(rng));
                    } else {
                        a.nop();
                    }
                }
                _ => {
                    a.nop();
                }
            }
        }
        // Terminator: usually a data-dependent branch, sometimes a
        // dynamic jalr to an already-placed (mid-block) target.
        match rng.range_usize(0, 9) {
            0..=5 => {
                let op = *rng.choice(&[
                    BranchOp::Beq,
                    BranchOp::Bne,
                    BranchOp::Blt,
                    BranchOp::Bge,
                    BranchOp::Bltu,
                ]);
                let t = rng.range_usize(0, segs);
                let target = if t == segs { "end".to_string() } else { format!("s{t}") };
                a.branch(op, reg(rng), reg(rng), &target);
            }
            6 => {
                let idx = placed[rng.range_usize(0, placed.len() - 1)];
                a.li(7, (idx * 4) as i32);
                a.push(rv32::Instr::Jalr { rd: 1, rs1: 7, offset: 0 });
            }
            _ => {} // fall through
        }
    }
    a.label("end");
    a.ebreak();
    a.finish().unwrap()
}

#[test]
fn rv32_fuzz_batched_lanes_match_isolated_scalars() {
    let mut rng = Pcg32::seeded(0x1550_E9_33);
    for case in 0..40 {
        // Every third case sprinkles MAC ops; half of those run on a
        // MAC-less core, so whole lanes fault mid-batch and must drain
        // with the exact scalar error while siblings keep retiring.
        let with_mac = case % 3 == 0;
        let mac = if with_mac && case % 6 == 0 { Some(MacConfig::new(32, 32)) } else { None };
        let code = random_divergent_rv32(&mut rng, with_mac);
        let lanes = rng.range_usize(2, 6);
        let rams: Vec<Vec<u8>> = (0..lanes)
            .map(|_| (0..64).map(|_| rng.range_i64(0, 255) as u8).collect())
            .collect();
        // Small fuels exhaust individual lanes mid-batch; larger ones
        // let most lanes halt.
        let fuel = *rng.choice(&[37u64, 150, 600, 2500]);
        compare_batch_rv32(&code, &rams, fuel, mac, &format!("fuzz case {case} fuel {fuel}"));
    }
}

/// Directed: a countdown loop whose trip count is the lane's RAM word.
/// Lanes with huge counters exhaust their fuel mid-batch (`Halt::Fuel`)
/// while small-counter siblings halt cleanly — per-lane fuel is the
/// scalar contract, not a shared pool.
#[test]
fn rv32_per_lane_fuel_exhaustion_mid_batch() {
    use rv32::BranchOp;
    let mut a = Asm::new();
    a.li(8, RAM_BASE as i32);
    a.lw(5, 8, 0);
    a.label("loop");
    a.branch(BranchOp::Beq, 5, 0, "end");
    a.addi(5, 5, -1);
    a.addi(6, 6, 1);
    a.j("loop");
    a.label("end");
    a.sw(6, 8, 4);
    a.ebreak();
    let code = a.finish().unwrap();
    let counters: [u32; 5] = [1, 100_000, 2, 100_000, 0];
    let rams: Vec<Vec<u8>> = counters.iter().map(|c| c.to_le_bytes().to_vec()).collect();
    for fuel in [37u64, 150, 600] {
        compare_batch_rv32(&code, &rams, fuel, None, &format!("fuel partition {fuel}"));
    }
}

/// Directed: lanes whose RAM flag routes them into a MAC instruction on
/// a MAC-less core fault with the scalar error message; clean siblings
/// sharing the in-flight block are untouched.
#[test]
fn rv32_poisoned_mac_lane_matches_scalar_error() {
    use rv32::BranchOp;
    let mut a = Asm::new();
    a.li(8, RAM_BASE as i32);
    a.lw(5, 8, 0);
    a.branch(BranchOp::Beq, 5, 0, "clean");
    a.li(10, 3);
    a.li(11, 4);
    a.mac(10, 11); // faults without a MAC unit
    a.label("clean");
    a.addi(6, 6, 7);
    a.sw(6, 8, 8);
    a.ebreak();
    let code = a.finish().unwrap();
    let rams: Vec<Vec<u8>> =
        [0u32, 1, 0, 1, 0].iter().map(|c| c.to_le_bytes().to_vec()).collect();
    compare_batch_rv32(&code, &rams, 1000, None, "poisoned mac lanes");
    // With a unit the same program is uniform and must also agree.
    compare_batch_rv32(&code, &rams, 1000, Some(MacConfig::new(32, 32)), "mac lanes with unit");
}

/// TP-ISA twin of [`compare_batch_rv32`]: divergent per-lane dmem
/// images, isolated scalar references, both trace modes.
fn compare_batch_tpisa(
    code: &[tpisa::Instr],
    dmems: &[Vec<u64>],
    fuel: u64,
    mac: Option<MacConfig>,
    what: &str,
) {
    let prepared = Arc::new(PreparedTpIsa::with_zero_dmem(8, code, 512, mac));
    let refs: Vec<(TpIsa, Result<printed_bespoke::sim::tpisa::Halt, String>)> = dmems
        .iter()
        .map(|img| {
            let mut sim = TpIsa::from_prepared(Arc::clone(&prepared));
            sim.dmem.write_words(0, img).unwrap();
            let r = sim.run_translated::<FullProfile>(fuel).map_err(|e| e.to_string());
            (sim, r)
        })
        .collect();
    let mut batch = BatchTpIsa::new(Arc::clone(&prepared), dmems.len());
    for (i, img) in dmems.iter().enumerate() {
        batch.lane_mut(i).dmem.write_words(0, img).unwrap();
    }
    let results = batch.run::<FullProfile>(dmems.len(), fuel);
    for (i, (sref, rref)) in refs.iter().enumerate() {
        match (&results[i], rref) {
            (Ok(hb), Ok(hr)) => assert_eq!(hb, hr, "{what} lane {i}: halt kind"),
            (Err(eb), Err(er)) => {
                assert_eq!(&eb.to_string(), er, "{what} lane {i}: error message");
            }
            (rb, rr) => panic!("{what} lane {i}: divergent outcome {rb:?} vs {rr:?}"),
        }
        assert_eq!(batch.lane(i).regs, sref.regs, "{what} lane {i}: regs");
        assert_eq!(batch.lane(i).pc, sref.pc, "{what} lane {i}: pc");
        assert_eq!(batch.lane(i).carry, sref.carry, "{what} lane {i}: carry");
        assert_eq!(batch.lane(i).zero, sref.zero, "{what} lane {i}: zero");
        let n = sref.dmem.len();
        assert_eq!(
            batch.lane(i).dmem.read_words(0, n).unwrap(),
            sref.dmem.read_words(0, n).unwrap(),
            "{what} lane {i}: dmem"
        );
        assert_profiles_eq(
            &batch.lane(i).profile,
            &sref.profile,
            &format!("{what} lane {i}"),
        );
    }

    let crefs: Vec<TpIsa> = dmems
        .iter()
        .map(|img| {
            let mut sim = TpIsa::from_prepared(Arc::clone(&prepared));
            sim.dmem.write_words(0, img).unwrap();
            let _ = sim.run_translated::<CyclesOnly>(fuel);
            sim
        })
        .collect();
    let mut cbatch = BatchTpIsa::new(prepared, dmems.len());
    for (i, img) in dmems.iter().enumerate() {
        cbatch.lane_mut(i).dmem.write_words(0, img).unwrap();
    }
    let _ = cbatch.run::<CyclesOnly>(dmems.len(), fuel);
    let mut folded = Profile::default();
    cbatch.fold_profile(&mut folded);
    let mut merged = Profile::default();
    for (i, sref) in crefs.iter().enumerate() {
        assert_eq!(cbatch.lane(i).regs, sref.regs, "{what} lane {i}: cyc regs");
        merged.merge(&sref.profile);
    }
    assert_eq!(folded.cycles, merged.cycles, "{what}: folded cycles");
    assert_eq!(folded.instructions, merged.instructions, "{what}: folded instructions");
}

/// A random TP-ISA stream whose early loads pull lane-divergent dmem
/// words into the registers that later branches test.
fn random_divergent_tpisa(rng: &mut Pcg32, with_mac: bool) -> Vec<tpisa::Instr> {
    use tpisa::Instr;
    let n = rng.range_usize(20, 50);
    let mut code = Vec::with_capacity(n + 9);
    let r = |rng: &mut Pcg32| rng.range_usize(0, 7) as u8;
    // Prologue: seed every register from the per-lane image.
    for reg in 0u8..8 {
        code.push(Instr::Ld { r1: reg, r2: reg, imm: reg as i8 });
    }
    for i in 0..n {
        let off_to = |rng: &mut Pcg32, i: usize| -> i16 {
            if rng.range_usize(0, 15) == 0 {
                *rng.choice(&[-200i64, 500]) as i16
            } else {
                (rng.range_i64(0, n as i64) - i as i64) as i16
            }
        };
        let ins = match rng.range_usize(0, 12) {
            0 => Instr::Ldi { r1: r(rng), imm: rng.range_i64(-32, 31) as i8 },
            1 => Instr::Add { r1: r(rng), r2: r(rng) },
            2 => Instr::Sub { r1: r(rng), r2: r(rng) },
            3 => Instr::Xor { r1: r(rng), r2: r(rng) },
            4 | 5 => Instr::Ld { r1: r(rng), r2: r(rng), imm: rng.range_i64(0, 63) as i8 },
            6 => Instr::St { r1: r(rng), r2: r(rng), imm: rng.range_i64(0, 63) as i8 },
            7 => Instr::Addi { r1: r(rng), imm: rng.range_i64(-32, 31) as i8 },
            8 => Instr::Bz { off: off_to(rng, i) },
            9 => Instr::Bnz { off: off_to(rng, i) },
            10 => Instr::Jmp { off: off_to(rng, i) },
            _ => {
                if with_mac && rng.range_usize(0, 2) == 0 {
                    *rng.choice(&[
                        Instr::Mac { op: MacOp::Mac, r1: r(rng), r2: r(rng) },
                        Instr::Mac { op: MacOp::MacClr, r1: 0, r2: 0 },
                    ])
                } else {
                    Instr::Halt
                }
            }
        };
        code.push(ins);
    }
    code.push(tpisa::Instr::Halt);
    code
}

#[test]
fn tpisa_fuzz_batched_lanes_match_isolated_scalars() {
    let mut rng = Pcg32::seeded(0x1550_E9_34);
    for case in 0..40 {
        let with_mac = case % 3 == 0;
        let mac = if with_mac && case % 6 == 0 { Some(MacConfig::new(8, 8)) } else { None };
        let code = random_divergent_tpisa(&mut rng, with_mac);
        let lanes = rng.range_usize(2, 5);
        let dmems: Vec<Vec<u64>> = (0..lanes)
            .map(|_| (0..64).map(|_| rng.range_i64(0, 255) as u64).collect())
            .collect();
        let fuel = *rng.choice(&[29u64, 120, 700, 3000]);
        compare_batch_tpisa(&code, &dmems, fuel, mac, &format!("tp fuzz case {case} fuel {fuel}"));
    }
}

/// Directed TP-ISA divergence: an 8-bit countdown whose trip count is
/// the lane's dmem word — a zero counter wraps through 256 iterations,
/// so lanes spread across the whole loop and regroup at the join.
#[test]
fn tpisa_per_lane_counters_diverge_and_rejoin() {
    use tpisa::Instr;
    let code = vec![
        Instr::Ldi { r1: 1, imm: 0 },
        Instr::Ld { r1: 0, r2: 1, imm: 0 }, // r0 = lane counter
        Instr::Ldi { r1: 2, imm: 1 },
        // loop: r0 -= 1; r3 += 1; bnz loop
        Instr::Sub { r1: 0, r2: 2 },
        Instr::Addi { r1: 3, imm: 1 },
        Instr::Bnz { off: -2 },
        Instr::St { r1: 3, r2: 1, imm: 1 },
        Instr::Halt,
    ];
    let dmems: Vec<Vec<u64>> = [3u64, 0, 10, 1, 200].iter().map(|&c| vec![c]).collect();
    for fuel in [29u64, 120, 100_000] {
        compare_batch_tpisa(&code, &dmems, fuel, None, &format!("tp counters fuel {fuel}"));
    }
}
