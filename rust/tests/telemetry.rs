//! End-to-end telemetry acceptance (ISSUE 8): Prometheus exposition
//! shape and counter monotonicity over real sockets, scrape liveness
//! while a device fleet is hammering the same reactor, and sampled
//! request-trace spans carrying every pipeline stage.
//!
//! Skips cleanly when no artifact tree matches the compiled backend
//! (same policy as `serve_http.rs`).

use std::sync::Arc;

use printed_bespoke::coordinator::service::{Service, ServiceConfig};
use printed_bespoke::ml::manifest::Manifest;
use printed_bespoke::runtime::pjrt::Runtime;
use printed_bespoke::server::http::Client;
use printed_bespoke::server::loadgen::{self, LoadgenConfig};
use printed_bespoke::server::{Server, ServerConfig};
use printed_bespoke::util::json::Value;

fn manifest() -> Option<Manifest> {
    let dir = printed_bespoke::artifacts_dir().ok()?;
    let man = Manifest::load(&dir).ok()?;
    if Runtime::is_stub() != printed_bespoke::ml::fixtures::manifest_is_stub(&man) {
        eprintln!("skipping: artifact tree does not match the compiled runtime backend");
        return None;
    }
    Some(man)
}

fn start_frontend(scfg: ServerConfig) -> (Arc<Service>, Server) {
    let svc = Arc::new(Service::start(ServiceConfig::default()).unwrap());
    let server = Server::start(Arc::clone(&svc), scfg).unwrap();
    (svc, server)
}

fn score_body(man: &Manifest) -> (String, String) {
    use printed_bespoke::ml::dataset::Dataset;
    let model = &man.models[0];
    let ds = Dataset::load(man.data_dir(), &model.dataset, "test").unwrap();
    let row = Value::Arr(ds.x[0].iter().map(|&f| Value::Num(f as f64)).collect());
    (format!("/v1/score/{}/p8", model.name), Value::obj(vec![("x", row)]).to_string())
}

/// Pull one sample value out of an exposition body by metric name
/// (label-less series only).
fn sample(text: &str, name: &str) -> Option<f64> {
    text.lines()
        .find(|l| l.starts_with(name) && l.as_bytes().get(name.len()) == Some(&b' '))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse().ok())
}

/// Golden exposition: `/metrics?format=prometheus` is well-formed text
/// exposition (HELP/TYPE headers, parseable samples, no duplicate
/// headers), names every dark-corner series the issue promises, and its
/// counters are monotone across scrapes.
#[test]
fn prometheus_exposition_is_wellformed_and_monotonic() {
    let Some(man) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (_svc, mut server) = start_frontend(ServerConfig::default());
    let mut c = Client::connect(server.addr()).unwrap();
    let (path, body) = score_body(&man);
    for _ in 0..3 {
        let (status, text) = c.post(&path, &body).unwrap();
        assert_eq!(status, 200, "{text}");
    }

    let (status, first) = c.get("/metrics?format=prometheus").unwrap();
    assert_eq!(status, 200);

    // Every promised series is present.
    for name in [
        "pbsp_server_http_requests_total",
        "pbsp_server_evicted_idle_total",
        "pbsp_server_evicted_read_total",
        "pbsp_server_evicted_write_total",
        "pbsp_server_rejected_busy_total",
        "pbsp_pool_queue_depth",
        "pbsp_pool_worker_jobs_total",
        "pbsp_batcher_occupancy",
        "pbsp_coordinator_requests_total",
        "pbsp_coordinator_batches_total",
        "pbsp_iss_blocks_total",
        "pbsp_iss_fused_uops_total",
        "pbsp_iss_fallback_instrs_total",
        "pbsp_reactor_poll_round_us",
        "pbsp_reactor_completions_depth",
    ] {
        assert!(
            first.contains(&format!("# HELP {name} ")),
            "exposition must carry HELP for {name}:\n{first}"
        );
        assert!(
            first.contains(&format!("# TYPE {name} ")),
            "exposition must carry TYPE for {name}:\n{first}"
        );
    }
    // The labelled per-(model,variant) request counter has a real series.
    assert!(
        first.contains("pbsp_coordinator_requests_total{model=\""),
        "labelled coordinator series missing:\n{first}"
    );
    // Histogram shape: buckets, +Inf terminal, sum and count.
    for suffix in ["_bucket{le=\"", "_bucket{le=\"+Inf\"}", "_sum", "_count"] {
        assert!(
            first.contains(&format!("pbsp_reactor_poll_round_us{suffix}")),
            "poll-round histogram missing {suffix}:\n{first}"
        );
    }

    // Line-level well-formedness: comments are HELP/TYPE only, each
    // sample line is `name[{labels}] value` with a finite numeric value,
    // and no metric emits its headers twice.
    let mut headers = std::collections::BTreeSet::new();
    for line in first.lines().filter(|l| !l.is_empty()) {
        if let Some(rest) = line.strip_prefix("# ") {
            assert!(
                rest.starts_with("HELP ") || rest.starts_with("TYPE "),
                "unexpected comment line: {line}"
            );
            assert!(headers.insert(rest.to_string()), "duplicate header: {line}");
            continue;
        }
        let cut = line.rfind(' ').unwrap_or_else(|| panic!("unparseable sample: {line}"));
        let (name, value) = (&line[..cut], &line[cut + 1..]);
        assert!(!name.is_empty() && name.starts_with("pbsp_"), "bad sample name: {line}");
        let v: f64 = value.parse().unwrap_or_else(|_| panic!("bad sample value: {line}"));
        assert!(v.is_finite(), "non-finite sample: {line}");
    }

    // Counter monotonicity across scrapes with traffic in between.
    let before = sample(&first, "pbsp_server_http_requests_total").unwrap();
    let jobs_before = sample(&first, "pbsp_pool_worker_jobs_total").unwrap();
    for _ in 0..2 {
        let (status, _) = c.post(&path, &body).unwrap();
        assert_eq!(status, 200);
    }
    let (status, second) = c.get("/metrics?format=prometheus").unwrap();
    assert_eq!(status, 200);
    let after = sample(&second, "pbsp_server_http_requests_total").unwrap();
    let jobs_after = sample(&second, "pbsp_pool_worker_jobs_total").unwrap();
    assert!(after >= before + 2.0, "http_requests_total must advance: {before} -> {after}");
    assert!(jobs_after >= jobs_before, "pool jobs counter went backwards");

    // JSON stays the default format and now carries the registry too.
    let (status, text) = c.get("/metrics").unwrap();
    assert_eq!(status, 200);
    let v = Value::parse(&text).unwrap();
    assert!(v.get("telemetry").is_ok(), "/metrics JSON must embed the registry");
    server.shutdown();
}

/// Scrapes must stay live while a big fleet saturates the reactor: a
/// scraper thread alternates both `/metrics` formats on its own
/// keep-alive connection for the whole run, and every scrape answers.
#[test]
fn metrics_scrape_survives_fleet_load() {
    if manifest().is_none() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let fleet = 1000usize;
    printed_bespoke::util::poll::raise_nofile_limit(fleet as u64 * 2 + 512);
    let svc = Arc::new(Service::start(ServiceConfig::default()).unwrap());
    let scfg = ServerConfig { max_connections: fleet + 16, ..ServerConfig::default() };
    let mut server = Server::start(Arc::clone(&svc), scfg).unwrap();
    let addr = server.addr();

    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let scraper = {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut c = Client::connect(addr).unwrap();
            let mut scrapes = 0usize;
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                for path in ["/metrics", "/metrics?format=prometheus"] {
                    let (status, text) = c.get(path).unwrap();
                    assert_eq!(status, 200, "scrape {path} failed mid-load: {text}");
                }
                scrapes += 1;
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            scrapes
        })
    };

    let cfg = LoadgenConfig {
        fleet,
        requests_per_device: 2,
        seed: 7,
        client_workers: 32,
        ..LoadgenConfig::default()
    };
    let report = loadgen::run(addr, &cfg).unwrap();
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let scrapes = scraper.join().expect("scraper thread must not die under load");
    assert!(scrapes >= 1, "scraper never completed a pass");
    assert_eq!(report.errors, 0, "fleet saw errors: {}", report.summary());
    assert_eq!(report.records.len(), fleet * 2);
    // The in-run scrape is recorded in the artifact and reconciles.
    let sm = report.server_metrics.as_ref().expect("report must carry scraped metrics");
    let served = sm.get("server").unwrap().get("http_requests").unwrap().as_i64().unwrap();
    assert!(
        served as usize >= report.records.len(),
        "server counted {served} requests for {} fleet successes",
        report.records.len()
    );
    server.shutdown();
}

/// `trace_sample: 1` emits exactly one JSON span per request with every
/// pipeline stage timed (read/queue/parse/batch/execute/write).
#[test]
fn trace_spans_cover_every_stage() {
    let Some(man) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let log = std::env::temp_dir().join(format!("pbsp-trace-{}.jsonl", std::process::id()));
    let scfg = ServerConfig {
        trace_sample: 1,
        trace_log: Some(log.to_str().unwrap().to_string()),
        ..ServerConfig::default()
    };
    let (_svc, mut server) = start_frontend(scfg);
    let mut c = Client::connect(server.addr()).unwrap();
    let (path, body) = score_body(&man);
    let n_scores = 4usize;
    for _ in 0..n_scores {
        let (status, text) = c.post(&path, &body).unwrap();
        assert_eq!(status, 200, "{text}");
    }
    let (status, _) = c.get("/healthz").unwrap();
    assert_eq!(status, 200);
    server.shutdown();

    let text = std::fs::read_to_string(&log).unwrap();
    let _ = std::fs::remove_file(&log);
    let spans: Vec<Value> = text.lines().map(|l| Value::parse(l).unwrap()).collect();
    assert_eq!(spans.len(), n_scores + 1, "one span per request at sample rate 1:\n{text}");
    let model = &man.models[0].name;
    let mut seqs = Vec::new();
    for s in &spans {
        assert_eq!(s.get("span").unwrap().as_str().unwrap(), "request");
        seqs.push(s.get("seq").unwrap().as_i64().unwrap());
        for stage in
            ["read_us", "queue_us", "parse_us", "batch_us", "exec_us", "write_us", "total_us"]
        {
            let v = s.get(stage).unwrap_or_else(|_| panic!("span missing {stage}: {s}"));
            assert!(v.as_i64().unwrap() >= 0, "negative {stage}: {s}");
        }
        assert!(s.get("conn").is_ok() && s.get("status").is_ok());
    }
    // Sequence numbers are the sampler's own: dense from 0 at rate 1.
    seqs.sort_unstable();
    assert_eq!(seqs, (0..=n_scores as i64).collect::<Vec<_>>());
    let scored: Vec<&Value> = spans
        .iter()
        .filter(|s| s.get("model").unwrap().as_str().unwrap() == *model)
        .collect();
    assert_eq!(scored.len(), n_scores, "scored spans must carry the model name");
    for s in scored {
        assert_eq!(s.get("status").unwrap().as_i64().unwrap(), 200);
        assert_eq!(s.get("variant").unwrap().as_str().unwrap(), "p8");
        assert!(s.get("batch").unwrap().as_i64().unwrap() >= 1, "batch size missing: {s}");
    }
}
