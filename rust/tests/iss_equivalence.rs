//! Satellite: the ISS execution-core rework (shared prepared programs,
//! reset-reused simulators, monomorphized tracers) must be
//! **bit-identical** to the pre-rework implementation.
//!
//! Three contracts, pinned differentially across random samples, all
//! six fixture models and both ISAs:
//!
//! 1. `CyclesOnly` and `FullProfile` runs produce bit-identical scores,
//!    predictions, cycle and instruction counts;
//! 2. a `reset()`-reused simulator (what the harness does) equals a
//!    freshly constructed simulator per sample (what the pre-rework
//!    harness did) — including the complete merged `FullProfile`
//!    (histogram, register bitmask, PC/BAR reach, every counter);
//! 3. sharded pool runs at 1 and 8 workers equal the sequential run in
//!    both tracing modes (CI additionally runs this file under
//!    `PBSP_THREADS=1` and `8`).
//!
//! The "legacy" reference below replicates the pre-rework harness
//! line-for-line: one `ZeroRiscy::new`/`TpIsa::new` per sample (full
//! program re-encode), per-byte / per-word preloads, per-sample profile
//! merge.  Runs against `make artifacts` output when present, else the
//! checked-in `artifacts-fixture/`; skips only if both are missing.
//!
//! Since §Perf iteration 4 the harness executes on the **translated**
//! engine (`run_translated`: block dispatch + fused superinstructions),
//! so the model-fixture tests below differentially pin the translated
//! engine against the per-instruction interpreter across all six
//! models, both ISAs, both trace modes and pools {1, 8}.  The fuzz
//! tests at the bottom pin the same equivalence on *adversarial*
//! control flow the codegen never emits: branch-dense random programs,
//! `jalr`s landing mid-block, misaligned branch targets that
//! self-overlap the instruction stream, MAC ops without a MAC unit,
//! and fuel exhaustion inside a block.

use printed_bespoke::ml::codegen_rv32::{
    self, InputFormat, Rv32Program, Rv32Variant, INPUT_OFF, RAM_BYTES, SCORES_OFF,
};
use printed_bespoke::ml::codegen_tpisa::{self, TpIsaProgram, TpVariant};
use printed_bespoke::ml::dataset::Dataset;
use printed_bespoke::ml::harness::{self, BatchRun};
use std::sync::Arc;

use printed_bespoke::hw::mac_unit::MacConfig;
use printed_bespoke::isa::rv32;
use printed_bespoke::isa::rv32_asm::Asm;
use printed_bespoke::isa::tpisa;
use printed_bespoke::isa::MacOp;
use printed_bespoke::ml::manifest::Manifest;
use printed_bespoke::ml::model::Model;
use printed_bespoke::ml::quant::{pack_vec, quantize};
use printed_bespoke::sim::mem::RAM_BASE;
use printed_bespoke::sim::tpisa::TpIsa;
use printed_bespoke::sim::trace::{CyclesOnly, FullProfile, Profile};
use printed_bespoke::sim::zero_riscy::{Halt, ZeroRiscy};
use printed_bespoke::sim::{PreparedRv32, PreparedTpIsa};
use printed_bespoke::util::rng::Pcg32;
use printed_bespoke::util::threadpool::ThreadPool;

fn load() -> Option<(Manifest, Vec<Model>)> {
    let dir = printed_bespoke::artifacts_dir().ok()?;
    let man = Manifest::load(&dir).ok()?;
    let models = man.models.iter().map(|e| Model::load(&e.weights).unwrap()).collect();
    Some((man, models))
}

/// Random in-range inputs: convex combinations of dataset rows (inside
/// the data hull, so fixed-point headroom holds).
fn random_samples(man: &Manifest, model: &Model, rng: &mut Pcg32, n: usize) -> Vec<Vec<f32>> {
    let ds = Dataset::load(man.data_dir(), &model.dataset, "test").unwrap();
    (0..n)
        .map(|_| {
            let a = &ds.x[rng.range_usize(0, ds.x.len() - 1)];
            let b = &ds.x[rng.range_usize(0, ds.x.len() - 1)];
            let t = rng.f64() as f32;
            a.iter().zip(b).map(|(&va, &vb)| va + t * (vb - va)).collect()
        })
        .collect()
}

/// The pre-rework RV32 harness, verbatim: fresh simulator per sample,
/// per-byte input preload, per-word score readout, per-sample merge.
fn legacy_run_rv32(model: &Model, prog: &Rv32Program, xs: &[Vec<f32>]) -> BatchRun {
    let p = prog.variant.quant_precision();
    let fx = model.qlayers(p).unwrap()[0].fx;
    let mut scores = Vec::new();
    let mut predictions = Vec::new();
    let mut profile = Profile::default();
    for x in xs {
        let mut sim =
            ZeroRiscy::new(&prog.code, &prog.rom_data, RAM_BYTES, prog.variant.mac_config());
        let qx: Vec<i64> = x.iter().map(|&v| quantize(v as f64, fx, p)).collect();
        let mut input = Vec::new();
        match prog.input_format {
            InputFormat::I16 => {
                for q in qx {
                    input.extend_from_slice(&(q as i16).to_le_bytes());
                }
            }
            InputFormat::Packed(prec) => {
                for w in pack_vec(&qx, prec, 32) {
                    input.extend_from_slice(&(w as u32).to_le_bytes());
                }
            }
        }
        for (i, b) in input.iter().enumerate() {
            sim.mem.store_u8(RAM_BASE + INPUT_OFF as u32 + i as u32, *b).unwrap();
        }
        assert_eq!(sim.run(50_000_000).unwrap(), Halt::Break);
        let mut raw = Vec::with_capacity(prog.n_scores);
        for j in 0..prog.n_scores {
            let addr = RAM_BASE + SCORES_OFF as u32 + 4 * j as u32;
            let acc = sim.mem.load_u32(addr).unwrap() as i32 as i64;
            raw.push(acc as f64 / prog.score_scale);
        }
        let s = model.head_scores(&raw);
        predictions.push(model.predict(&s));
        scores.push(s);
        profile.merge(&sim.profile);
    }
    let cps = profile.cycles as f64 / xs.len().max(1) as f64;
    let exec_stats = printed_bespoke::sim::ExecStats::default();
    BatchRun { scores, predictions, profile, cycles_per_sample: cps, exec_stats }
}

/// The pre-rework TP-ISA harness, verbatim.
fn legacy_run_tpisa(model: &Model, prog: &TpIsaProgram, xs: &[Vec<f32>]) -> BatchRun {
    let p = prog.quant_precision;
    let fx = model.qlayers(p).unwrap()[0].fx;
    let mut scores = Vec::new();
    let mut predictions = Vec::new();
    let mut profile = Profile::default();
    for x in xs {
        let mut sim = TpIsa::new(prog.datapath, &prog.code, prog.dmem_words, prog.mac_config());
        for (addr, v) in prog.dmem_image.iter().enumerate() {
            sim.dmem.store(addr as i64, *v).unwrap();
        }
        let qx: Vec<i64> = x.iter().map(|&v| quantize(v as f64, fx, p)).collect();
        let words: Vec<u64> = if prog.packed_input {
            pack_vec(&qx, p, prog.datapath)
        } else {
            qx.iter().map(|&q| q as u64).collect()
        };
        for (i, w) in words.iter().enumerate() {
            sim.dmem.store(prog.input_base as i64 + i as i64, *w).unwrap();
        }
        assert_eq!(sim.run(500_000_000).unwrap(), printed_bespoke::sim::tpisa::Halt::Halted);
        let nacc = (32 / prog.datapath).max(1) as usize;
        let mut raw = Vec::with_capacity(prog.n_scores);
        for j in 0..prog.n_scores {
            let mut acc: u64 = 0;
            for wi in 0..nacc {
                let chunk = sim.dmem.load((prog.score_base + j * nacc + wi) as i64).unwrap();
                acc |= chunk << (prog.datapath * wi as u32);
            }
            let acc = printed_bespoke::sim::mac_model::sext(acc, 32);
            raw.push(acc as f64 / prog.score_scale);
        }
        let s = model.head_scores(&raw);
        predictions.push(model.predict(&s));
        scores.push(s);
        profile.merge(&sim.profile);
    }
    let cps = profile.cycles as f64 / xs.len().max(1) as f64;
    let exec_stats = printed_bespoke::sim::ExecStats::default();
    BatchRun { scores, predictions, profile, cycles_per_sample: cps, exec_stats }
}

/// Bit-level equality of score matrices.
fn assert_scores_eq(a: &[Vec<f64>], b: &[Vec<f64>], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: sample count");
    for (i, (ra, rb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ra.len(), rb.len(), "{what} sample {i}: score count");
        for (j, (va, vb)) in ra.iter().zip(rb).enumerate() {
            assert_eq!(va.to_bits(), vb.to_bits(), "{what} sample {i} score {j}: {va} vs {vb}");
        }
    }
}

/// Every observable of two full profiles.
fn assert_profiles_eq(a: &Profile, b: &Profile, what: &str) {
    assert_eq!(a.instr_counts(), b.instr_counts(), "{what}: histogram");
    assert_eq!(a.static_mnemonics, b.static_mnemonics, "{what}: static mnemonics");
    assert_eq!(a.regs_used, b.regs_used, "{what}: regs_used");
    assert_eq!(a.max_pc, b.max_pc, "{what}: max_pc");
    assert_eq!(a.csr_used, b.csr_used, "{what}: csr_used");
    assert_eq!(a.syscalls_used, b.syscalls_used, "{what}: syscalls_used");
    assert_eq!(a.cycles, b.cycles, "{what}: cycles");
    assert_eq!(a.instructions, b.instructions, "{what}: instructions");
    assert_eq!(a.loads, b.loads, "{what}: loads");
    assert_eq!(a.stores, b.stores, "{what}: stores");
    assert_eq!(a.mul_ops, b.mul_ops, "{what}: mul_ops");
    assert_eq!(a.mac_ops, b.mac_ops, "{what}: mac_ops");
    assert_eq!(a.branches_taken, b.branches_taken, "{what}: branches_taken");
    assert_eq!(a.max_ram_offset, b.max_ram_offset, "{what}: max_ram_offset");
}

const RV32_VARIANTS: [Rv32Variant; 5] = [
    Rv32Variant::Baseline,
    Rv32Variant::Mac32,
    Rv32Variant::Simd(16),
    Rv32Variant::Simd(8),
    Rv32Variant::Simd(4),
];

#[test]
fn rv32_reused_and_traced_runs_match_legacy() {
    let Some((man, models)) = load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut rng = Pcg32::seeded(0x1550_E9_01);
    for model in &models {
        let xs = random_samples(&man, model, &mut rng, 5);
        for variant in RV32_VARIANTS {
            let what = format!("{} {variant:?}", model.name);
            let prog = codegen_rv32::generate(model, variant).unwrap();
            let legacy = legacy_run_rv32(model, &prog, &xs);
            let full = harness::run_rv32(model, &prog, &xs).unwrap();
            // (2) reset-reuse == fresh-per-sample, full profile included.
            assert_scores_eq(&full.scores, &legacy.scores, &what);
            assert_eq!(full.predictions, legacy.predictions, "{what}: predictions");
            assert_profiles_eq(&full.profile, &legacy.profile, &what);
            assert_eq!(
                full.cycles_per_sample.to_bits(),
                legacy.cycles_per_sample.to_bits(),
                "{what}: cycles/sample"
            );
            // (1) CyclesOnly == FullProfile on everything it reports.
            let cyc = harness::run_rv32_traced::<CyclesOnly>(model, &prog, &xs).unwrap();
            assert_scores_eq(&cyc.scores, &full.scores, &what);
            assert_eq!(cyc.predictions, full.predictions, "{what}: cyc predictions");
            assert_eq!(cyc.profile.cycles, full.profile.cycles, "{what}: cyc cycles");
            assert_eq!(
                cyc.profile.instructions,
                full.profile.instructions,
                "{what}: cyc instructions"
            );
            assert!(cyc.profile.instr_counts().is_empty(), "{what}: cyc histogram");
        }
    }
}

#[test]
fn tpisa_reused_and_traced_runs_match_legacy() {
    let Some((man, models)) = load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut rng = Pcg32::seeded(0x1550_E9_02);
    for model in &models {
        let xs = random_samples(&man, model, &mut rng, 4);
        let mut configs: Vec<(u32, TpVariant)> = Vec::new();
        for d in [8u32, 16, 32] {
            configs.push((d, TpVariant::Baseline));
            configs.push((d, TpVariant::Mac { precision: d.min(16) }));
        }
        configs.push((4, TpVariant::Baseline));
        configs.push((4, TpVariant::Mac { precision: 4 }));
        for (d, variant) in configs {
            let p = codegen_tpisa::quant_precision(d, variant);
            if model.qlayers(p).is_err() {
                continue;
            }
            let Ok(prog) = codegen_tpisa::generate(model, d, variant) else {
                continue; // e.g. multi-layer models on the 4-bit core
            };
            let what = format!("{} d{d} {variant:?}", model.name);
            let legacy = legacy_run_tpisa(model, &prog, &xs);
            let full = harness::run_tpisa(model, &prog, &xs).unwrap();
            assert_scores_eq(&full.scores, &legacy.scores, &what);
            assert_eq!(full.predictions, legacy.predictions, "{what}: predictions");
            assert_profiles_eq(&full.profile, &legacy.profile, &what);
            let cyc = harness::run_tpisa_traced::<CyclesOnly>(model, &prog, &xs).unwrap();
            assert_scores_eq(&cyc.scores, &full.scores, &what);
            assert_eq!(cyc.predictions, full.predictions, "{what}: cyc predictions");
            assert_eq!(cyc.profile.cycles, full.profile.cycles, "{what}: cyc cycles");
            assert_eq!(
                cyc.profile.instructions,
                full.profile.instructions,
                "{what}: cyc instructions"
            );
        }
    }
}

#[test]
fn sharded_runs_match_sequential_in_both_modes() {
    let Some((man, models)) = load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut rng = Pcg32::seeded(0x1550_E9_03);
    let pools = [ThreadPool::new(1), ThreadPool::new(8)];
    for model in &models {
        let xs = random_samples(&man, model, &mut rng, 9);
        let prog = codegen_rv32::generate(model, Rv32Variant::Simd(8)).unwrap();
        let seq_full = harness::run_rv32(model, &prog, &xs).unwrap();
        let seq_cyc = harness::run_rv32_traced::<CyclesOnly>(model, &prog, &xs).unwrap();
        let tprog = codegen_tpisa::generate(model, 32, TpVariant::Mac { precision: 8 }).unwrap();
        let tseq_full = harness::run_tpisa(model, &tprog, &xs).unwrap();
        let tseq_cyc = harness::run_tpisa_traced::<CyclesOnly>(model, &tprog, &xs).unwrap();
        for pool in &pools {
            let what = format!("{} ({} workers)", model.name, pool.threads());
            let par_full =
                harness::run_rv32_on_traced::<FullProfile>(pool, model, &prog, &xs).unwrap();
            assert_scores_eq(&par_full.scores, &seq_full.scores, &what);
            assert_eq!(par_full.predictions, seq_full.predictions, "{what}: predictions");
            assert_profiles_eq(&par_full.profile, &seq_full.profile, &what);
            let par_cyc =
                harness::run_rv32_on_traced::<CyclesOnly>(pool, model, &prog, &xs).unwrap();
            assert_scores_eq(&par_cyc.scores, &seq_cyc.scores, &what);
            assert_eq!(par_cyc.profile.cycles, seq_cyc.profile.cycles, "{what}: cyc cycles");

            let tpar_full =
                harness::run_tpisa_on_traced::<FullProfile>(pool, model, &tprog, &xs).unwrap();
            assert_scores_eq(&tpar_full.scores, &tseq_full.scores, &what);
            assert_profiles_eq(&tpar_full.profile, &tseq_full.profile, &what);
            let tpar_cyc =
                harness::run_tpisa_on_traced::<CyclesOnly>(pool, model, &tprog, &xs).unwrap();
            assert_scores_eq(&tpar_cyc.scores, &tseq_cyc.scores, &what);
            assert_eq!(tpar_cyc.profile.cycles, tseq_cyc.profile.cycles, "{what}: cyc cycles");
        }
    }
}

// ---------------------------------------------------------------------------
// Adversarial fuzz: translated engine vs per-instruction interpreter on
// control flow the codegen never emits.
// ---------------------------------------------------------------------------

/// Run one RV32 program through the interpreter and the translated
/// engine with the same fuel and assert every observable agrees.
/// On error both must fail with the same message (faults surface
/// through the shared fallback/step helpers).
fn compare_rv32(code: &[rv32::Instr], fuel: u64, mac: Option<MacConfig>, what: &str) {
    let prepared = Arc::new(PreparedRv32::new(code, &[], 0x400, mac));
    let mut interp = ZeroRiscy::from_prepared(Arc::clone(&prepared));
    let ri = interp.run_traced::<FullProfile>(fuel);
    let mut trans = ZeroRiscy::from_prepared(Arc::clone(&prepared));
    let rt = trans.run_translated::<FullProfile>(fuel);
    match (ri, rt) {
        (Ok(hi), Ok(ht)) => {
            assert_eq!(hi, ht, "{what}: halt kind");
            assert_eq!(interp.regs, trans.regs, "{what}: regs");
            assert_eq!(interp.pc, trans.pc, "{what}: pc");
            assert_eq!(interp.mem.ram, trans.mem.ram, "{what}: ram");
            assert_profiles_eq(&interp.profile, &trans.profile, what);
        }
        (Err(ei), Err(et)) => {
            assert_eq!(ei.to_string(), et.to_string(), "{what}: error");
        }
        (ri, rt) => panic!("{what}: divergent outcome {ri:?} vs {rt:?}"),
    }
    // CyclesOnly mode: same architectural state and aggregate counters.
    let mut ci = ZeroRiscy::from_prepared(Arc::clone(&prepared));
    let rci = ci.run_traced::<CyclesOnly>(fuel);
    let mut ct = ZeroRiscy::from_prepared(prepared);
    let rct = ct.run_translated::<CyclesOnly>(fuel);
    match (rci, rct) {
        (Ok(hi), Ok(ht)) => {
            assert_eq!(hi, ht, "{what}: cyc halt kind");
            assert_eq!(ci.regs, ct.regs, "{what}: cyc regs");
            assert_eq!(ci.mem.ram, ct.mem.ram, "{what}: cyc ram");
            assert_eq!(ci.profile.cycles, ct.profile.cycles, "{what}: cyc cycles");
            assert_eq!(
                ci.profile.instructions,
                ct.profile.instructions,
                "{what}: cyc instructions"
            );
            assert!(ct.profile.instr_counts().is_empty(), "{what}: cyc histogram");
        }
        (Err(ei), Err(et)) => {
            assert_eq!(ei.to_string(), et.to_string(), "{what}: cyc error");
        }
        (ri, rt) => panic!("{what}: cyc divergent outcome {ri:?} vs {rt:?}"),
    }
}

/// A random branch-dense RV32 program: segments of random data ops
/// joined by random branches/jumps between segment labels, plus
/// occasional `jalr`s to *already-placed* labels (dynamic targets the
/// translator cannot see — they land mid-block at runtime).  Loads and
/// stores stay inside RAM so the common case halts or burns fuel
/// rather than faulting.
fn random_rv32_program(rng: &mut Pcg32) -> Vec<rv32::Instr> {
    use rv32::{AluOp, BranchOp, LoadOp, MulOp, StoreOp};
    let mut a = Asm::new();
    let segs = rng.range_usize(3, 8);
    a.li(8, RAM_BASE as i32); // s0: RAM base (kept read-only below)
    a.li(9, RAM_BASE as i32 + 128); // s1: second RAM window
    // Labels every segment; jalr targets must already be placed.
    let mut placed: Vec<(String, usize)> = Vec::new();
    let pool: [u8; 10] = [5, 6, 7, 10, 11, 12, 13, 14, 15, 0];
    let reg = |rng: &mut Pcg32| pool[rng.range_usize(0, pool.len() - 1)];
    let wreg = |rng: &mut Pcg32| pool[rng.range_usize(0, pool.len() - 2)]; // no x0 dest
    for s in 0..segs {
        let name = format!("s{s}");
        placed.push((name.clone(), a.here()));
        a.label(&name);
        for _ in 0..rng.range_usize(1, 5) {
            match rng.range_usize(0, 9) {
                0 => {
                    let rd = wreg(rng);
                    let rs = reg(rng);
                    a.addi(rd, rs, rng.range_i64(-64, 64) as i32);
                }
                1 => {
                    let op = *rng.choice(&[
                        AluOp::Add,
                        AluOp::Sub,
                        AluOp::Xor,
                        AluOp::Or,
                        AluOp::And,
                        AluOp::Slt,
                        AluOp::Sltu,
                    ]);
                    a.push(rv32::Instr::Op { op, rd: wreg(rng), rs1: reg(rng), rs2: reg(rng) });
                }
                2 => {
                    let op = *rng.choice(&[AluOp::Sll, AluOp::Srl, AluOp::Sra]);
                    a.push(rv32::Instr::OpImm {
                        op,
                        rd: wreg(rng),
                        rs1: reg(rng),
                        imm: rng.range_i64(0, 31) as i32,
                    });
                }
                3 => {
                    a.push(rv32::Instr::Lui {
                        rd: wreg(rng),
                        imm: (rng.range_i64(0, 0xfffff) as i32) << 12,
                    });
                }
                4 => {
                    let op = *rng.choice(&[
                        MulOp::Mul,
                        MulOp::Mulh,
                        MulOp::Div,
                        MulOp::Rem,
                        MulOp::Divu,
                        MulOp::Remu,
                    ]);
                    a.push(rv32::Instr::MulDiv { op, rd: wreg(rng), rs1: reg(rng), rs2: reg(rng) });
                }
                5 | 6 => {
                    let op = *rng.choice(&[
                        LoadOp::Lw,
                        LoadOp::Lh,
                        LoadOp::Lhu,
                        LoadOp::Lb,
                        LoadOp::Lbu,
                    ]);
                    let base = *rng.choice(&[8u8, 9]);
                    a.push(rv32::Instr::Load {
                        op,
                        rd: wreg(rng),
                        rs1: base,
                        offset: rng.range_i64(0, 120) as i32,
                    });
                }
                7 => {
                    let op = *rng.choice(&[StoreOp::Sw, StoreOp::Sh, StoreOp::Sb]);
                    let base = *rng.choice(&[8u8, 9]);
                    a.push(rv32::Instr::Store {
                        op,
                        rs2: reg(rng),
                        rs1: base,
                        offset: rng.range_i64(0, 120) as i32,
                    });
                }
                8 => {
                    // ROM read: the program is always longer than 16
                    // bytes, so small x0-relative offsets stay in code.
                    a.push(rv32::Instr::Load {
                        op: LoadOp::Lhu,
                        rd: wreg(rng),
                        rs1: 0,
                        offset: rng.range_i64(0, 12) as i32,
                    });
                }
                _ => {
                    a.nop();
                }
            }
        }
        // Segment terminator.
        match rng.range_usize(0, 9) {
            0..=4 => {
                let op = *rng.choice(&[
                    BranchOp::Beq,
                    BranchOp::Bne,
                    BranchOp::Blt,
                    BranchOp::Bge,
                    BranchOp::Bltu,
                    BranchOp::Bgeu,
                ]);
                let t = rng.range_usize(0, segs); // may be "end"
                let target = if t == segs { "end".to_string() } else { format!("s{t}") };
                a.branch(op, reg(rng), reg(rng), &target);
            }
            5 => {
                let t = rng.range_usize(0, segs);
                let target = if t == segs { "end".to_string() } else { format!("s{t}") };
                a.j(&target);
            }
            6 => {
                // jalr to an already-placed label: a dynamic target the
                // translator cannot mark as a leader.
                let (_, idx) = placed[rng.range_usize(0, placed.len() - 1)].clone();
                a.li(7, (idx * 4) as i32);
                a.push(rv32::Instr::Jalr { rd: 1, rs1: 7, offset: 0 });
            }
            _ => {} // fall through
        }
    }
    a.label("end");
    a.ebreak();
    a.finish().unwrap()
}

#[test]
fn rv32_fuzz_translated_matches_interpreted() {
    let mut rng = Pcg32::seeded(0x1550_E9_10);
    for case in 0..60 {
        let code = random_rv32_program(&mut rng);
        let fuel = *rng.choice(&[37u64, 150, 600, 2500]);
        compare_rv32(&code, fuel, None, &format!("fuzz case {case} fuel {fuel}"));
    }
}

/// Misaligned branch targets: a half-word-aligned offset lands the PC
/// between instruction words, so the retired stream self-overlaps —
/// the translator must refuse the block path and single-step.
#[test]
fn rv32_misaligned_and_self_overlapping_streams() {
    use rv32::{AluOp, BranchOp, Instr};
    // beq +6 from pc 4 lands at pc 10: idx floor(10/4) = 2, then
    // pc walks 10, 14, 18 — fetching idx 2, 3, 4 with shifted PCs.
    let code = vec![
        Instr::OpImm { op: AluOp::Add, rd: 5, rs1: 0, imm: 1 },
        Instr::Branch { op: BranchOp::Beq, rs1: 0, rs2: 0, offset: 6 },
        Instr::OpImm { op: AluOp::Add, rd: 6, rs1: 6, imm: 1 },
        Instr::OpImm { op: AluOp::Add, rd: 7, rs1: 7, imm: 2 },
        Instr::Ebreak,
    ];
    compare_rv32(&code, 1000, None, "misaligned beq");

    // jal to a half-word boundary inside a loop body.
    let code = vec![
        Instr::OpImm { op: AluOp::Add, rd: 5, rs1: 0, imm: 40 },
        Instr::Jal { rd: 0, offset: 10 }, // pc 4 -> 14
        Instr::OpImm { op: AluOp::Add, rd: 6, rs1: 6, imm: 3 },
        Instr::OpImm { op: AluOp::Add, rd: 7, rs1: 7, imm: 5 },
        Instr::OpImm { op: AluOp::Add, rd: 10, rs1: 10, imm: 7 },
        Instr::Ebreak,
    ];
    compare_rv32(&code, 1000, None, "misaligned jal");

    // Backward misaligned branch forming a self-overlapping loop that
    // only fuel can stop.
    let code = vec![
        Instr::OpImm { op: AluOp::Add, rd: 5, rs1: 0, imm: 1 },
        Instr::OpImm { op: AluOp::Add, rd: 6, rs1: 5, imm: 2 },
        Instr::Branch { op: BranchOp::Bge, rs1: 6, rs2: 0, offset: -6 },
        Instr::Ebreak,
    ];
    compare_rv32(&code, 333, None, "self-overlapping loop");
}

/// MAC instructions on a MAC-less core error identically (the block is
/// untranslatable, so the fallback interpreter raises the error at the
/// same retire), and with a unit the fused path computes identically.
#[test]
fn rv32_mac_blocks_translated_and_untranslatable() {
    use printed_bespoke::isa::rv32::Instr;
    let mut a = Asm::new();
    a.li(8, RAM_BASE as i32);
    a.li(5, 3);
    a.li(6, 4);
    a.sw(5, 8, 0);
    a.sw(6, 8, 4);
    a.maccl();
    a.lw(5, 8, 0);
    a.lw(6, 8, 4);
    a.mac(5, 6);
    a.macrd(10, 0);
    a.ebreak();
    let code = a.finish().unwrap();
    compare_rv32(&code, 1000, Some(MacConfig::new(32, 32)), "mac with unit");
    compare_rv32(&code, 1000, None, "mac without unit");
    // MacRd/MacClr without a preceding mac, on a bare Mac instr vec.
    let code = vec![Instr::Mac { op: MacOp::MacRd, rd: 5, rs1: 0, rs2: 0 }, Instr::Ebreak];
    compare_rv32(&code, 10, Some(MacConfig::new(32, 16)), "bare macrd");
    compare_rv32(&code, 10, None, "bare macrd no unit");
}

/// Run one TP-ISA program both ways and compare every observable.
fn compare_tpisa(code: &[tpisa::Instr], fuel: u64, mac: Option<MacConfig>, what: &str) {
    let prepared = Arc::new(PreparedTpIsa::with_zero_dmem(8, code, 512, mac));
    let mut interp = TpIsa::from_prepared(Arc::clone(&prepared));
    let ri = interp.run_traced::<FullProfile>(fuel);
    let mut trans = TpIsa::from_prepared(Arc::clone(&prepared));
    let rt = trans.run_translated::<FullProfile>(fuel);
    match (ri, rt) {
        (Ok(hi), Ok(ht)) => {
            assert_eq!(hi, ht, "{what}: halt kind");
            assert_eq!(interp.regs, trans.regs, "{what}: regs");
            assert_eq!(interp.pc, trans.pc, "{what}: pc");
            assert_eq!(interp.carry, trans.carry, "{what}: carry");
            assert_eq!(interp.zero, trans.zero, "{what}: zero");
            let n = interp.dmem.len();
            assert_eq!(
                interp.dmem.read_words(0, n).unwrap(),
                trans.dmem.read_words(0, n).unwrap(),
                "{what}: dmem"
            );
            assert_profiles_eq(&interp.profile, &trans.profile, what);
        }
        (Err(ei), Err(et)) => {
            assert_eq!(ei.to_string(), et.to_string(), "{what}: error");
        }
        (ri, rt) => panic!("{what}: divergent outcome {ri:?} vs {rt:?}"),
    }
    let mut ci = TpIsa::from_prepared(Arc::clone(&prepared));
    let rci = ci.run_traced::<CyclesOnly>(fuel);
    let mut ct = TpIsa::from_prepared(prepared);
    let rct = ct.run_translated::<CyclesOnly>(fuel);
    match (rci, rct) {
        (Ok(hi), Ok(ht)) => {
            assert_eq!(hi, ht, "{what}: cyc halt kind");
            assert_eq!(ci.regs, ct.regs, "{what}: cyc regs");
            assert_eq!(ci.profile.cycles, ct.profile.cycles, "{what}: cyc cycles");
            assert_eq!(
                ci.profile.instructions,
                ct.profile.instructions,
                "{what}: cyc instructions"
            );
        }
        (Err(ei), Err(et)) => {
            assert_eq!(ei.to_string(), et.to_string(), "{what}: cyc error");
        }
        (ri, rt) => panic!("{what}: cyc divergent outcome {ri:?} vs {rt:?}"),
    }
}

/// A random TP-ISA instruction stream: all data/memory ops plus
/// branches with mostly-in-range offsets (occasionally out of range to
/// pin fault equality) and sprinkled Halt/Mac instructions.
fn random_tpisa_program(rng: &mut Pcg32, with_mac: bool) -> Vec<tpisa::Instr> {
    use tpisa::Instr;
    let n = rng.range_usize(20, 60);
    let mut code = Vec::with_capacity(n + 1);
    let r = |rng: &mut Pcg32| rng.range_usize(0, 7) as u8;
    for i in 0..n {
        let off_to = |rng: &mut Pcg32, i: usize| -> i16 {
            // Mostly land inside the program; 1-in-16 shoots outside.
            if rng.range_usize(0, 15) == 0 {
                *rng.choice(&[-200i64, 500]) as i16
            } else {
                (rng.range_i64(0, n as i64) - i as i64) as i16
            }
        };
        let ins = match rng.range_usize(0, 20) {
            0 => Instr::Ldi { r1: r(rng), imm: rng.range_i64(-32, 31) as i8 },
            1 => Instr::Add { r1: r(rng), r2: r(rng) },
            2 => Instr::Adc { r1: r(rng), r2: r(rng) },
            3 => Instr::Sub { r1: r(rng), r2: r(rng) },
            4 => Instr::Sbc { r1: r(rng), r2: r(rng) },
            5 => Instr::And { r1: r(rng), r2: r(rng) },
            6 => Instr::Or { r1: r(rng), r2: r(rng) },
            7 => Instr::Xor { r1: r(rng), r2: r(rng) },
            8 => *rng.choice(&[
                Instr::Shl { r1: r(rng) },
                Instr::Shr { r1: r(rng) },
                Instr::Sra { r1: r(rng) },
                Instr::Slc { r1: r(rng) },
                Instr::Src { r1: r(rng) },
            ]),
            9 | 10 => Instr::Ld { r1: r(rng), r2: r(rng), imm: rng.range_i64(0, 63) as i8 },
            11 => Instr::St { r1: r(rng), r2: r(rng), imm: rng.range_i64(0, 63) as i8 },
            12 => Instr::Addi { r1: r(rng), imm: rng.range_i64(-32, 31) as i8 },
            13 => Instr::Mov { r1: r(rng), r2: r(rng) },
            14 => Instr::Sxt { r1: r(rng), r2: r(rng) },
            15 => Instr::Clc,
            16 => Instr::Bz { off: off_to(rng, i) },
            17 => Instr::Bnz { off: off_to(rng, i) },
            18 => *rng.choice(&[
                Instr::Bc { off: rng.range_i64(-8, 8) as i8 },
                Instr::Bnc { off: rng.range_i64(-8, 8) as i8 },
            ]),
            19 => Instr::Jmp { off: off_to(rng, i) },
            _ => {
                if with_mac && rng.range_usize(0, 3) == 0 {
                    let chunk = rng.range_usize(0, 3) as u8;
                    *rng.choice(&[
                        Instr::Mac { op: MacOp::Mac, r1: r(rng), r2: r(rng) },
                        Instr::Mac { op: MacOp::MacRd, r1: r(rng), r2: chunk },
                        Instr::Mac { op: MacOp::MacClr, r1: 0, r2: 0 },
                    ])
                } else {
                    Instr::Halt
                }
            }
        };
        code.push(ins);
    }
    code.push(tpisa::Instr::Halt);
    code
}

#[test]
fn tpisa_fuzz_translated_matches_interpreted() {
    let mut rng = Pcg32::seeded(0x1550_E9_11);
    for case in 0..60 {
        // Alternate: MAC streams with a unit, MAC streams without one
        // (untranslatable blocks must error at the same retire), and
        // plain data/branch streams.
        let with_mac = case % 3 != 2;
        let code = random_tpisa_program(&mut rng, with_mac);
        let fuel = *rng.choice(&[29u64, 120, 700, 3000]);
        let mac = if case % 3 == 0 { Some(MacConfig::new(8, 8)) } else { None };
        compare_tpisa(&code, fuel, mac, &format!("tp fuzz case {case} fuel {fuel}"));
    }
}

/// MAC stream without a unit: the whole block is untranslatable, so the
/// fallback interpreter raises the identical error at the same retire.
#[test]
fn tpisa_mac_without_unit_errors_identically() {
    use tpisa::Instr;
    let code = vec![
        Instr::Ldi { r1: 0, imm: 3 },
        Instr::Ldi { r1: 1, imm: 4 },
        Instr::Mac { op: MacOp::Mac, r1: 0, r2: 1 },
        Instr::Halt,
    ];
    compare_tpisa(&code, 100, None, "tp mac without unit");
    compare_tpisa(&code, 100, Some(MacConfig::new(8, 8)), "tp mac with unit");
}
