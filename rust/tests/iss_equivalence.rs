//! Satellite: the ISS execution-core rework (shared prepared programs,
//! reset-reused simulators, monomorphized tracers) must be
//! **bit-identical** to the pre-rework implementation.
//!
//! Three contracts, pinned differentially across random samples, all
//! six fixture models and both ISAs:
//!
//! 1. `CyclesOnly` and `FullProfile` runs produce bit-identical scores,
//!    predictions, cycle and instruction counts;
//! 2. a `reset()`-reused simulator (what the harness does) equals a
//!    freshly constructed simulator per sample (what the pre-rework
//!    harness did) — including the complete merged `FullProfile`
//!    (histogram, register bitmask, PC/BAR reach, every counter);
//! 3. sharded pool runs at 1 and 8 workers equal the sequential run in
//!    both tracing modes (CI additionally runs this file under
//!    `PBSP_THREADS=1` and `8`).
//!
//! The "legacy" reference below replicates the pre-rework harness
//! line-for-line: one `ZeroRiscy::new`/`TpIsa::new` per sample (full
//! program re-encode), per-byte / per-word preloads, per-sample profile
//! merge.  Runs against `make artifacts` output when present, else the
//! checked-in `artifacts-fixture/`; skips only if both are missing.

use printed_bespoke::ml::codegen_rv32::{
    self, InputFormat, Rv32Program, Rv32Variant, INPUT_OFF, RAM_BYTES, SCORES_OFF,
};
use printed_bespoke::ml::codegen_tpisa::{self, TpIsaProgram, TpVariant};
use printed_bespoke::ml::dataset::Dataset;
use printed_bespoke::ml::harness::{self, BatchRun};
use printed_bespoke::ml::manifest::Manifest;
use printed_bespoke::ml::model::Model;
use printed_bespoke::ml::quant::{pack_vec, quantize};
use printed_bespoke::sim::mem::RAM_BASE;
use printed_bespoke::sim::tpisa::TpIsa;
use printed_bespoke::sim::trace::{CyclesOnly, FullProfile, Profile};
use printed_bespoke::sim::zero_riscy::{Halt, ZeroRiscy};
use printed_bespoke::util::rng::Pcg32;
use printed_bespoke::util::threadpool::ThreadPool;

fn load() -> Option<(Manifest, Vec<Model>)> {
    let dir = printed_bespoke::artifacts_dir().ok()?;
    let man = Manifest::load(&dir).ok()?;
    let models = man.models.iter().map(|e| Model::load(&e.weights).unwrap()).collect();
    Some((man, models))
}

/// Random in-range inputs: convex combinations of dataset rows (inside
/// the data hull, so fixed-point headroom holds).
fn random_samples(man: &Manifest, model: &Model, rng: &mut Pcg32, n: usize) -> Vec<Vec<f32>> {
    let ds = Dataset::load(man.data_dir(), &model.dataset, "test").unwrap();
    (0..n)
        .map(|_| {
            let a = &ds.x[rng.range_usize(0, ds.x.len() - 1)];
            let b = &ds.x[rng.range_usize(0, ds.x.len() - 1)];
            let t = rng.f64() as f32;
            a.iter().zip(b).map(|(&va, &vb)| va + t * (vb - va)).collect()
        })
        .collect()
}

/// The pre-rework RV32 harness, verbatim: fresh simulator per sample,
/// per-byte input preload, per-word score readout, per-sample merge.
fn legacy_run_rv32(model: &Model, prog: &Rv32Program, xs: &[Vec<f32>]) -> BatchRun {
    let p = prog.variant.quant_precision();
    let fx = model.qlayers(p).unwrap()[0].fx;
    let mut scores = Vec::new();
    let mut predictions = Vec::new();
    let mut profile = Profile::default();
    for x in xs {
        let mut sim =
            ZeroRiscy::new(&prog.code, &prog.rom_data, RAM_BYTES, prog.variant.mac_config());
        let qx: Vec<i64> = x.iter().map(|&v| quantize(v as f64, fx, p)).collect();
        let mut input = Vec::new();
        match prog.input_format {
            InputFormat::I16 => {
                for q in qx {
                    input.extend_from_slice(&(q as i16).to_le_bytes());
                }
            }
            InputFormat::Packed(prec) => {
                for w in pack_vec(&qx, prec, 32) {
                    input.extend_from_slice(&(w as u32).to_le_bytes());
                }
            }
        }
        for (i, b) in input.iter().enumerate() {
            sim.mem.store_u8(RAM_BASE + INPUT_OFF as u32 + i as u32, *b).unwrap();
        }
        assert_eq!(sim.run(50_000_000).unwrap(), Halt::Break);
        let mut raw = Vec::with_capacity(prog.n_scores);
        for j in 0..prog.n_scores {
            let addr = RAM_BASE + SCORES_OFF as u32 + 4 * j as u32;
            let acc = sim.mem.load_u32(addr).unwrap() as i32 as i64;
            raw.push(acc as f64 / prog.score_scale);
        }
        let s = model.head_scores(&raw);
        predictions.push(model.predict(&s));
        scores.push(s);
        profile.merge(&sim.profile);
    }
    let cps = profile.cycles as f64 / xs.len().max(1) as f64;
    BatchRun { scores, predictions, profile, cycles_per_sample: cps }
}

/// The pre-rework TP-ISA harness, verbatim.
fn legacy_run_tpisa(model: &Model, prog: &TpIsaProgram, xs: &[Vec<f32>]) -> BatchRun {
    let p = prog.quant_precision;
    let fx = model.qlayers(p).unwrap()[0].fx;
    let mut scores = Vec::new();
    let mut predictions = Vec::new();
    let mut profile = Profile::default();
    for x in xs {
        let mut sim = TpIsa::new(prog.datapath, &prog.code, prog.dmem_words, prog.mac_config());
        for (addr, v) in prog.dmem_image.iter().enumerate() {
            sim.dmem.store(addr as i64, *v).unwrap();
        }
        let qx: Vec<i64> = x.iter().map(|&v| quantize(v as f64, fx, p)).collect();
        let words: Vec<u64> = if prog.packed_input {
            pack_vec(&qx, p, prog.datapath)
        } else {
            qx.iter().map(|&q| q as u64).collect()
        };
        for (i, w) in words.iter().enumerate() {
            sim.dmem.store(prog.input_base as i64 + i as i64, *w).unwrap();
        }
        assert_eq!(sim.run(500_000_000).unwrap(), printed_bespoke::sim::tpisa::Halt::Halted);
        let nacc = (32 / prog.datapath).max(1) as usize;
        let mut raw = Vec::with_capacity(prog.n_scores);
        for j in 0..prog.n_scores {
            let mut acc: u64 = 0;
            for wi in 0..nacc {
                let chunk = sim.dmem.load((prog.score_base + j * nacc + wi) as i64).unwrap();
                acc |= chunk << (prog.datapath * wi as u32);
            }
            let acc = printed_bespoke::sim::mac_model::sext(acc, 32);
            raw.push(acc as f64 / prog.score_scale);
        }
        let s = model.head_scores(&raw);
        predictions.push(model.predict(&s));
        scores.push(s);
        profile.merge(&sim.profile);
    }
    let cps = profile.cycles as f64 / xs.len().max(1) as f64;
    BatchRun { scores, predictions, profile, cycles_per_sample: cps }
}

/// Bit-level equality of score matrices.
fn assert_scores_eq(a: &[Vec<f64>], b: &[Vec<f64>], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: sample count");
    for (i, (ra, rb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ra.len(), rb.len(), "{what} sample {i}: score count");
        for (j, (va, vb)) in ra.iter().zip(rb).enumerate() {
            assert_eq!(va.to_bits(), vb.to_bits(), "{what} sample {i} score {j}: {va} vs {vb}");
        }
    }
}

/// Every observable of two full profiles.
fn assert_profiles_eq(a: &Profile, b: &Profile, what: &str) {
    assert_eq!(a.instr_counts(), b.instr_counts(), "{what}: histogram");
    assert_eq!(a.static_mnemonics, b.static_mnemonics, "{what}: static mnemonics");
    assert_eq!(a.regs_used, b.regs_used, "{what}: regs_used");
    assert_eq!(a.max_pc, b.max_pc, "{what}: max_pc");
    assert_eq!(a.csr_used, b.csr_used, "{what}: csr_used");
    assert_eq!(a.syscalls_used, b.syscalls_used, "{what}: syscalls_used");
    assert_eq!(a.cycles, b.cycles, "{what}: cycles");
    assert_eq!(a.instructions, b.instructions, "{what}: instructions");
    assert_eq!(a.loads, b.loads, "{what}: loads");
    assert_eq!(a.stores, b.stores, "{what}: stores");
    assert_eq!(a.mul_ops, b.mul_ops, "{what}: mul_ops");
    assert_eq!(a.mac_ops, b.mac_ops, "{what}: mac_ops");
    assert_eq!(a.branches_taken, b.branches_taken, "{what}: branches_taken");
    assert_eq!(a.max_ram_offset, b.max_ram_offset, "{what}: max_ram_offset");
}

const RV32_VARIANTS: [Rv32Variant; 5] = [
    Rv32Variant::Baseline,
    Rv32Variant::Mac32,
    Rv32Variant::Simd(16),
    Rv32Variant::Simd(8),
    Rv32Variant::Simd(4),
];

#[test]
fn rv32_reused_and_traced_runs_match_legacy() {
    let Some((man, models)) = load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut rng = Pcg32::seeded(0x1550_E9_01);
    for model in &models {
        let xs = random_samples(&man, model, &mut rng, 5);
        for variant in RV32_VARIANTS {
            let what = format!("{} {variant:?}", model.name);
            let prog = codegen_rv32::generate(model, variant).unwrap();
            let legacy = legacy_run_rv32(model, &prog, &xs);
            let full = harness::run_rv32(model, &prog, &xs).unwrap();
            // (2) reset-reuse == fresh-per-sample, full profile included.
            assert_scores_eq(&full.scores, &legacy.scores, &what);
            assert_eq!(full.predictions, legacy.predictions, "{what}: predictions");
            assert_profiles_eq(&full.profile, &legacy.profile, &what);
            assert_eq!(
                full.cycles_per_sample.to_bits(),
                legacy.cycles_per_sample.to_bits(),
                "{what}: cycles/sample"
            );
            // (1) CyclesOnly == FullProfile on everything it reports.
            let cyc = harness::run_rv32_traced::<CyclesOnly>(model, &prog, &xs).unwrap();
            assert_scores_eq(&cyc.scores, &full.scores, &what);
            assert_eq!(cyc.predictions, full.predictions, "{what}: cyc predictions");
            assert_eq!(cyc.profile.cycles, full.profile.cycles, "{what}: cyc cycles");
            assert_eq!(
                cyc.profile.instructions,
                full.profile.instructions,
                "{what}: cyc instructions"
            );
            assert!(cyc.profile.instr_counts().is_empty(), "{what}: cyc histogram");
        }
    }
}

#[test]
fn tpisa_reused_and_traced_runs_match_legacy() {
    let Some((man, models)) = load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut rng = Pcg32::seeded(0x1550_E9_02);
    for model in &models {
        let xs = random_samples(&man, model, &mut rng, 4);
        let mut configs: Vec<(u32, TpVariant)> = Vec::new();
        for d in [8u32, 16, 32] {
            configs.push((d, TpVariant::Baseline));
            configs.push((d, TpVariant::Mac { precision: d.min(16) }));
        }
        configs.push((4, TpVariant::Baseline));
        configs.push((4, TpVariant::Mac { precision: 4 }));
        for (d, variant) in configs {
            let p = codegen_tpisa::quant_precision(d, variant);
            if model.qlayers(p).is_err() {
                continue;
            }
            let Ok(prog) = codegen_tpisa::generate(model, d, variant) else {
                continue; // e.g. multi-layer models on the 4-bit core
            };
            let what = format!("{} d{d} {variant:?}", model.name);
            let legacy = legacy_run_tpisa(model, &prog, &xs);
            let full = harness::run_tpisa(model, &prog, &xs).unwrap();
            assert_scores_eq(&full.scores, &legacy.scores, &what);
            assert_eq!(full.predictions, legacy.predictions, "{what}: predictions");
            assert_profiles_eq(&full.profile, &legacy.profile, &what);
            let cyc = harness::run_tpisa_traced::<CyclesOnly>(model, &prog, &xs).unwrap();
            assert_scores_eq(&cyc.scores, &full.scores, &what);
            assert_eq!(cyc.predictions, full.predictions, "{what}: cyc predictions");
            assert_eq!(cyc.profile.cycles, full.profile.cycles, "{what}: cyc cycles");
            assert_eq!(
                cyc.profile.instructions,
                full.profile.instructions,
                "{what}: cyc instructions"
            );
        }
    }
}

#[test]
fn sharded_runs_match_sequential_in_both_modes() {
    let Some((man, models)) = load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut rng = Pcg32::seeded(0x1550_E9_03);
    let pools = [ThreadPool::new(1), ThreadPool::new(8)];
    for model in &models {
        let xs = random_samples(&man, model, &mut rng, 9);
        let prog = codegen_rv32::generate(model, Rv32Variant::Simd(8)).unwrap();
        let seq_full = harness::run_rv32(model, &prog, &xs).unwrap();
        let seq_cyc = harness::run_rv32_traced::<CyclesOnly>(model, &prog, &xs).unwrap();
        let tprog = codegen_tpisa::generate(model, 32, TpVariant::Mac { precision: 8 }).unwrap();
        let tseq_full = harness::run_tpisa(model, &tprog, &xs).unwrap();
        let tseq_cyc = harness::run_tpisa_traced::<CyclesOnly>(model, &tprog, &xs).unwrap();
        for pool in &pools {
            let what = format!("{} ({} workers)", model.name, pool.threads());
            let par_full =
                harness::run_rv32_on_traced::<FullProfile>(pool, model, &prog, &xs).unwrap();
            assert_scores_eq(&par_full.scores, &seq_full.scores, &what);
            assert_eq!(par_full.predictions, seq_full.predictions, "{what}: predictions");
            assert_profiles_eq(&par_full.profile, &seq_full.profile, &what);
            let par_cyc =
                harness::run_rv32_on_traced::<CyclesOnly>(pool, model, &prog, &xs).unwrap();
            assert_scores_eq(&par_cyc.scores, &seq_cyc.scores, &what);
            assert_eq!(par_cyc.profile.cycles, seq_cyc.profile.cycles, "{what}: cyc cycles");

            let tpar_full =
                harness::run_tpisa_on_traced::<FullProfile>(pool, model, &tprog, &xs).unwrap();
            assert_scores_eq(&tpar_full.scores, &tseq_full.scores, &what);
            assert_profiles_eq(&tpar_full.profile, &tseq_full.profile, &what);
            let tpar_cyc =
                harness::run_tpisa_on_traced::<CyclesOnly>(pool, model, &tprog, &xs).unwrap();
            assert_scores_eq(&tpar_cyc.scores, &tseq_cyc.scores, &what);
            assert_eq!(tpar_cyc.profile.cycles, tseq_cyc.profile.cycles, "{what}: cyc cycles");
        }
    }
}
