//! Coordinator shutdown/drain regression (ISSUE 3 satellite): in-flight
//! `submit()` requests during `Service` drop must either complete or
//! return an error — never hang the caller.  The worker's shutdown path
//! drains the router (every queued reply is sent) and dropping the job
//! channel drops any unsent reply senders (receivers see `Err`), so
//! every receiver resolves; these tests pin that contract with bounded
//! waits.

use std::sync::mpsc::channel;
use std::time::Duration;

use printed_bespoke::coordinator::router::Key;
use printed_bespoke::coordinator::service::{Service, ServiceConfig};
use printed_bespoke::ml::dataset::Dataset;
use printed_bespoke::ml::manifest::Manifest;
use printed_bespoke::runtime::pjrt::Runtime;

fn manifest() -> Option<Manifest> {
    let dir = printed_bespoke::artifacts_dir().ok()?;
    let man = Manifest::load(&dir).ok()?;
    if Runtime::is_stub() != printed_bespoke::ml::fixtures::manifest_is_stub(&man) {
        eprintln!("skipping: artifact tree does not match the compiled runtime backend");
        return None;
    }
    Some(man)
}

const RESOLVE_WITHIN: Duration = Duration::from_secs(30);

/// Drop the service with a wall of streaming requests in flight — a
/// slow multi-batch backlog across every model (each first use also
/// compiles).  Every receiver must resolve within the bound.
#[test]
fn inflight_submits_resolve_on_drop() {
    let Some(man) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    // Small batches + long linger: the backlog is cut into many
    // batches and queues linger, so the drop genuinely races execution.
    let cfg = ServiceConfig { max_batch: 4, linger_ms: 50, ..ServiceConfig::default() };
    let svc = Service::start(cfg).unwrap();
    let mut pending = Vec::new();
    for entry in &man.models {
        let ds = Dataset::load(man.data_dir(), &entry.dataset, "test").unwrap();
        for i in 0..24 {
            let key = Key::precision(&entry.name, 8);
            let x = ds.x[i % ds.len()].clone();
            pending.push(svc.submit(key, x).unwrap());
        }
    }
    // Drop on a helper thread so a hanging drop fails the test instead
    // of wedging it.
    let (done_tx, done_rx) = channel();
    let dropper = std::thread::spawn(move || {
        drop(svc);
        let _ = done_tx.send(());
    });
    for (i, rx) in pending.into_iter().enumerate() {
        match rx.recv_timeout(RESOLVE_WITHIN) {
            Ok(_reply) => {} // completed (Ok scores) or error — both fine
            Err(e) => panic!("request {i} hung across Service drop: {e}"),
        }
    }
    done_rx
        .recv_timeout(RESOLVE_WITHIN)
        .expect("Service::drop itself hung");
    dropper.join().unwrap();
}

/// Same contract with a slow bulk job in flight on another thread:
/// streaming submits queued behind it must resolve even though the
/// facade handle is released mid-flight (the last `Arc` owner — the
/// bulk thread — runs the actual drop/drain).
#[test]
fn submits_behind_bulk_work_resolve_on_drop() {
    let Some(man) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let svc = std::sync::Arc::new(Service::start(ServiceConfig::default()).unwrap());
    let entry = &man.models[0];
    let ds = Dataset::load(man.data_dir(), &entry.dataset, "test").unwrap();
    let key = Key::precision(&entry.name, 8);
    // A multi-chunk bulk job keeps the worker busy on its own thread.
    let bulk = {
        let svc = std::sync::Arc::clone(&svc);
        let key = key.clone();
        let xs: Vec<Vec<f32>> = (0..512).map(|i| ds.x[i % ds.len()].clone()).collect();
        std::thread::spawn(move || svc.scores(&key, &xs).map(|s| s.len()))
    };
    // Streaming submits race the bulk job for the worker.
    let pending: Vec<_> = (0..16)
        .map(|i| svc.submit(key.clone(), ds.x[i % ds.len()].clone()).unwrap())
        .collect();
    drop(svc); // release our handle while everything is in flight
    for (i, rx) in pending.into_iter().enumerate() {
        assert!(
            rx.recv_timeout(RESOLVE_WITHIN).is_ok(),
            "streaming request {i} hung behind bulk work across drop"
        );
    }
    let n = bulk.join().unwrap().expect("bulk path failed");
    assert_eq!(n, 512);
}
