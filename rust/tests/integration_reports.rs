//! Report-level integration: every table/figure emitter runs on the
//! artifact tree (`make artifacts` output, or the checked-in
//! `artifacts-fixture/` fallback) and reproduces the paper's qualitative
//! claims (the quantitative bands are asserted by the benches).

use printed_bespoke::dse::context::EvalContext;
use printed_bespoke::dse::report;
use printed_bespoke::hw::synth::UnitKind;

fn ctx() -> Option<EvalContext> {
    EvalContext::load(4).ok()
}

#[test]
fn fig1_anchors_and_shares() {
    let Some(ctx) = ctx() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let f = report::fig1(&ctx);
    assert!((f.zr.area_cm2() - 67.53).abs() < 0.5);
    assert!((f.zr.power_mw - 291.21).abs() < 2.0);
    // "almost half": MUL + RF between 40% and 56%.
    let share = f.zr.area_fraction(&[UnitKind::Mul, UnitKind::RegFile]);
    assert!((0.40..=0.56).contains(&share), "{share}");
    // TP-ISA cores are far smaller and clock faster.
    assert!(f.tp32.area_mm2 < f.zr.area_mm2 / 5.0);
    assert!(f.tp4.fmax_hz > f.zr.fmax_hz);
    assert!(f.text.contains("Fig 1a"));
}

#[test]
fn table1_zero_accuracy_loss_at_16_bits() {
    let Some(ctx) = ctx() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let t = report::table1(&ctx).unwrap();
    // Paper: "Since all the models' parameters are 16-bits ... gaining
    // in all fronts and sacrificing no accuracy."
    let p16 = t.rows.iter().find(|r| r.name == "ZR B MAC P16").unwrap();
    assert!(p16.acc_loss_pct.abs() < 0.5);
    assert!(p16.area_gain_pct > 0.0 && p16.power_gain_pct > 0.0 && p16.speedup_pct > 0.0);
}

#[test]
fn fig5_pareto_includes_8bit_mac_family() {
    let Some(ctx) = ctx() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let f = report::fig5(&ctx).unwrap();
    // The d8 MAC family must be competitive (paper picks d8m as the
    // Table II solution): either on the front or within 10% of a front
    // point's speedup at comparable area.
    let d8m = f.points.iter().position(|p| p.label == "d8m").unwrap();
    let on_front = f.pareto[d8m];
    let competitive = f
        .points
        .iter()
        .zip(&f.pareto)
        .filter(|(_, &on)| on)
        .any(|(p, _)| p.area_mm2 <= f.points[d8m].area_mm2 * 1.2);
    assert!(on_front || competitive);
    // Speedup increases "rapidly when using a MAC unit and then slowly
    // with SIMD" (§IV-B): d8m >> d8, and d8m p4 adds a smaller delta.
    let get = |l: &str| f.points.iter().find(|p| p.label == l).unwrap().speedup_pct;
    assert!(get("d8m") > 50.0);
    assert!((get("d8m p4") - get("d8m")).abs() < 25.0);
}

#[test]
fn report_texts_are_complete() {
    let Some(ctx) = ctx() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    assert!(report::fig4(&ctx).text.contains("mlp_c_cardio"));
    assert!(report::table2(&ctx).unwrap().text.contains("area overhead"));
    let mem = report::mem(&ctx).unwrap();
    assert!(mem.text.contains("ROM"));
    assert_eq!(mem.zr_rom.len(), 6); // baseline + 5 variants
    assert!(mem.tp_rom.len() >= 12); // the Fig 5 configuration set
}
