//! Cross-layer integration: for every model artifact and every core
//! variant, the code-generated program executed on the cycle-approximate
//! ISS must produce *bit-exact* scores against the rust quantised
//! reference (`Model::quantized_forward`) — which the pytest suite in
//! turn pins against the Pallas kernel and the jnp oracle.
//!
//! Runs against `make artifacts` output when present, else the
//! checked-in `artifacts-fixture/` (so a fresh checkout exercises the
//! whole path); skips only if both are missing.

use printed_bespoke::ml::codegen_rv32::{self, Rv32Variant};
use printed_bespoke::ml::codegen_tpisa::{self, TpVariant};
use printed_bespoke::ml::dataset::Dataset;
use printed_bespoke::ml::harness;
use printed_bespoke::ml::manifest::Manifest;
use printed_bespoke::ml::model::Model;
use printed_bespoke::util::rng::Pcg32;
use printed_bespoke::util::threadpool;

fn load() -> Option<(Manifest, Vec<Model>)> {
    let dir = printed_bespoke::artifacts_dir().ok()?;
    let man = Manifest::load(&dir).ok()?;
    let models = man.models.iter().map(|e| Model::load(&e.weights).unwrap()).collect();
    Some((man, models))
}

fn samples(man: &Manifest, model: &Model, n: usize) -> Vec<Vec<f32>> {
    let ds = Dataset::load(man.data_dir(), &model.dataset, "test").unwrap();
    ds.x.into_iter().take(n).collect()
}

#[test]
fn rv32_all_variants_bit_exact() {
    let Some((man, models)) = load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    for model in &models {
        let xs = samples(&man, model, 12);
        for variant in [
            Rv32Variant::Baseline,
            Rv32Variant::Mac32,
            Rv32Variant::Simd(16),
            Rv32Variant::Simd(8),
            Rv32Variant::Simd(4),
        ] {
            let prog = codegen_rv32::generate(model, variant)
                .unwrap_or_else(|e| panic!("{} {variant:?}: {e}", model.name));
            let run = harness::run_rv32(model, &prog, &xs)
                .unwrap_or_else(|e| panic!("{} {variant:?}: {e}", model.name));
            let p = variant.quant_precision();
            for (i, x) in xs.iter().enumerate() {
                let want = model.quantized_forward(x, p).unwrap();
                assert_eq!(
                    run.scores[i], want,
                    "{} {variant:?} sample {i}: ISS {:?} != ref {:?}",
                    model.name, run.scores[i], want
                );
            }
        }
    }
}

#[test]
fn tpisa_looped_variants_bit_exact() {
    let Some((man, models)) = load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    for model in &models {
        let xs = samples(&man, model, 6);
        for d in [8u32, 16, 32] {
            let mut variants = vec![TpVariant::Baseline, TpVariant::Mac { precision: d.min(16) }];
            // One sub-precision SIMD config per width.
            if d > 4 {
                variants.push(TpVariant::Mac { precision: d / 2 });
            }
            for variant in variants {
                let p = codegen_tpisa::quant_precision(d, variant);
                if model.qlayers(p).is_err() {
                    continue;
                }
                let prog = codegen_tpisa::generate(model, d, variant)
                    .unwrap_or_else(|e| panic!("{} d{d} {variant:?}: {e}", model.name));
                let run = harness::run_tpisa(model, &prog, &xs)
                    .unwrap_or_else(|e| panic!("{} d{d} {variant:?}: {e}", model.name));
                for (i, x) in xs.iter().enumerate() {
                    let want = model.quantized_forward(x, p).unwrap();
                    assert_eq!(
                        run.scores[i], want,
                        "{} d{d} {variant:?} sample {i}",
                        model.name
                    );
                }
            }
        }
    }
}

#[test]
fn tpisa_4bit_unrolled_svm() {
    let Some((man, models)) = load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    for model in models.iter().filter(|m| m.name.starts_with("svm_r")) {
        let xs = samples(&man, model, 6);
        for variant in [TpVariant::Baseline, TpVariant::Mac { precision: 4 }] {
            let prog = codegen_tpisa::generate(model, 4, variant)
                .unwrap_or_else(|e| panic!("{} d4 {variant:?}: {e}", model.name));
            let run = harness::run_tpisa(model, &prog, &xs).unwrap();
            for (i, x) in xs.iter().enumerate() {
                let want = model.quantized_forward(x, 4).unwrap();
                assert_eq!(run.scores[i], want, "{} d4 {variant:?} sample {i}", model.name);
            }
        }
    }
    // Multi-layer models must be rejected cleanly on the 4-bit core.
    if let Some(mlp) = models.iter().find(|m| m.layers.len() > 1) {
        assert!(codegen_tpisa::generate(mlp, 4, TpVariant::Baseline).is_err());
    }
}

/// Forward pass whose every multiply-accumulate goes through the
/// `sim::mac_model` functional unit (32-bit datapath, p-bit lanes) —
/// the third rust implementation of the numeric contract, independent
/// of both `Model::quantized_forward` and the ISS-executed programs.
fn mac_model_forward(model: &Model, x: &[f32], p: u32) -> Vec<f64> {
    use printed_bespoke::hw::mac_unit::MacConfig;
    use printed_bespoke::ml::quant::{pack_vec, quantize, rescale};
    use printed_bespoke::sim::mac_model::MacState;
    let qls = model.qlayers(p).unwrap();
    let mut h: Vec<i64> = x.iter().map(|&v| quantize(v as f64, qls[0].fx, p)).collect();
    let mut raw: Vec<f64> = Vec::new();
    for (li, (layer, ql)) in model.layers.iter().zip(qls).enumerate() {
        let k = ql.qw.len();
        let n = ql.qb.len();
        let last = li == model.layers.len() - 1;
        let mut next = Vec::with_capacity(n);
        for j in 0..n {
            let col: Vec<i64> = (0..k).map(|kk| ql.qw[kk][j]).collect();
            let aw = pack_vec(&h, p, 32);
            let bw = pack_vec(&col, p, 32);
            let mut st = MacState::new(MacConfig::new(32, p));
            for (a, b) in aw.iter().zip(&bw) {
                st.mac(*a, *b);
            }
            let acc = ql.qb[j].wrapping_add(st.total());
            if last {
                next.push(acc);
            } else {
                let mut y = rescale(acc, ql.shift, p);
                if layer.relu {
                    y = y.max(0);
                }
                next.push(y);
            }
        }
        if last {
            let scale = (1i64 << (ql.fx + ql.fw)) as f64;
            raw = next.iter().map(|&a| a as f64 / scale).collect();
        } else {
            h = next;
        }
    }
    model.head_scores(&raw)
}

/// Satellite: differential fuzz — for *random* in-range inputs (not the
/// fixed test sets) on every fixture model/precision, three independent
/// implementations agree bit-for-bit: the ISS-executed generated
/// programs (RV32 SIMD and TP-ISA MAC), the `ml::quant`-based reference
/// (`Model::quantized_forward`) and a `sim::mac_model`-driven forward
/// pass.  The sharded harness must match the sequential one exactly.
#[test]
fn fuzz_differential_iss_quant_mac_model() {
    let Some((man, models)) = load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut rng = Pcg32::seeded(0x0D1F_F0A5);
    for model in &models {
        // In-range inputs: random convex combinations of dataset rows
        // (inside the data hull by construction, so the fixed-point
        // headroom calibrated on the dataset still holds).
        let ds = Dataset::load(man.data_dir(), &model.dataset, "test").unwrap();
        for p in [32u32, 16, 8, 4] {
            if model.qlayers(p).is_err() {
                continue;
            }
            let xs: Vec<Vec<f32>> = (0..6)
                .map(|_| {
                    let a = &ds.x[rng.range_usize(0, ds.x.len() - 1)];
                    let b = &ds.x[rng.range_usize(0, ds.x.len() - 1)];
                    let t = rng.f64() as f32;
                    a.iter().zip(b).map(|(&va, &vb)| va + t * (vb - va)).collect()
                })
                .collect();
            let want: Vec<Vec<f64>> =
                xs.iter().map(|x| model.quantized_forward(x, p).unwrap()).collect();
            // Implementation 2: the SIMD MAC functional model.
            for (i, x) in xs.iter().enumerate() {
                assert_eq!(
                    mac_model_forward(model, x, p),
                    want[i],
                    "{} p{p} sample {i}: mac_model vs quant reference",
                    model.name
                );
            }
            // Implementation 3a: RV32 SIMD codegen on the Zero-Riscy ISS
            // (SIMD variants exist for p <= 16), sequential and sharded.
            if p <= 16 {
                let prog = codegen_rv32::generate(model, Rv32Variant::Simd(p)).unwrap();
                let run = harness::run_rv32(model, &prog, &xs).unwrap();
                assert_eq!(run.scores, want, "{} p{p}: rv32 ISS", model.name);
                let par = harness::run_rv32_on(threadpool::global(), model, &prog, &xs).unwrap();
                assert_eq!(par.scores, run.scores, "{} p{p}: sharded rv32", model.name);
                assert_eq!(par.predictions, run.predictions);
                assert_eq!(par.profile.cycles, run.profile.cycles);
            }
            // Implementation 3b: TP-ISA MAC codegen on the TP-ISA ISS
            // (sub-width MAC configs exist for p <= 16 on the d32 core).
            if p <= 16 {
                let variant = TpVariant::Mac { precision: p };
                let prog = codegen_tpisa::generate(model, 32, variant).unwrap();
                let run = harness::run_tpisa(model, &prog, &xs).unwrap();
                assert_eq!(run.scores, want, "{} d32 p{p}: tp-isa ISS", model.name);
                let par = harness::run_tpisa_on(threadpool::global(), model, &prog, &xs).unwrap();
                assert_eq!(par.scores, run.scores, "{} d32 p{p}: sharded tp-isa", model.name);
                assert_eq!(par.profile.cycles, run.profile.cycles);
            }
        }
    }
}

#[test]
fn mac_variants_run_faster() {
    let Some((man, models)) = load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    // Paper Table I ordering: cycles(baseline) > cycles(mac32) >
    // cycles(p16) > cycles(p8) > cycles(p4); and TP-ISA MAC >> baseline.
    let model = &models[0];
    let xs = samples(&man, model, 4);
    let mut cycles = Vec::new();
    for variant in [
        Rv32Variant::Baseline,
        Rv32Variant::Mac32,
        Rv32Variant::Simd(16),
        Rv32Variant::Simd(8),
        Rv32Variant::Simd(4),
    ] {
        let prog = codegen_rv32::generate(model, variant).unwrap();
        let run = harness::run_rv32(model, &prog, &xs).unwrap();
        cycles.push((variant, run.cycles_per_sample));
    }
    for w in cycles.windows(2) {
        assert!(
            w[0].1 > w[1].1,
            "expected {:?} ({}) slower than {:?} ({})",
            w[0].0,
            w[0].1,
            w[1].0,
            w[1].1
        );
    }

    let base = codegen_tpisa::generate(model, 8, TpVariant::Baseline).unwrap();
    let mac = codegen_tpisa::generate(model, 8, TpVariant::Mac { precision: 8 }).unwrap();
    let cb = harness::run_tpisa(model, &base, &xs).unwrap().cycles_per_sample;
    let cm = harness::run_tpisa(model, &mac, &xs).unwrap().cycles_per_sample;
    // Table II: "up to 85.1%" execution-time reduction.
    let reduction = 1.0 - cm / cb;
    assert!(reduction > 0.6, "TP-ISA MAC reduction only {reduction:.3}");
}
