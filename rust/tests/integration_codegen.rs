//! Cross-layer integration: for every model artifact and every core
//! variant, the code-generated program executed on the cycle-approximate
//! ISS must produce *bit-exact* scores against the rust quantised
//! reference (`Model::quantized_forward`) — which the pytest suite in
//! turn pins against the Pallas kernel and the jnp oracle.
//!
//! Runs against `make artifacts` output when present, else the
//! checked-in `artifacts-fixture/` (so a fresh checkout exercises the
//! whole path); skips only if both are missing.

use printed_bespoke::ml::codegen_rv32::{self, Rv32Variant};
use printed_bespoke::ml::codegen_tpisa::{self, TpVariant};
use printed_bespoke::ml::dataset::Dataset;
use printed_bespoke::ml::harness;
use printed_bespoke::ml::manifest::Manifest;
use printed_bespoke::ml::model::Model;

fn load() -> Option<(Manifest, Vec<Model>)> {
    let dir = printed_bespoke::artifacts_dir().ok()?;
    let man = Manifest::load(&dir).ok()?;
    let models = man.models.iter().map(|e| Model::load(&e.weights).unwrap()).collect();
    Some((man, models))
}

fn samples(man: &Manifest, model: &Model, n: usize) -> Vec<Vec<f32>> {
    let ds = Dataset::load(man.data_dir(), &model.dataset, "test").unwrap();
    ds.x.into_iter().take(n).collect()
}

#[test]
fn rv32_all_variants_bit_exact() {
    let Some((man, models)) = load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    for model in &models {
        let xs = samples(&man, model, 12);
        for variant in [
            Rv32Variant::Baseline,
            Rv32Variant::Mac32,
            Rv32Variant::Simd(16),
            Rv32Variant::Simd(8),
            Rv32Variant::Simd(4),
        ] {
            let prog = codegen_rv32::generate(model, variant)
                .unwrap_or_else(|e| panic!("{} {variant:?}: {e}", model.name));
            let run = harness::run_rv32(model, &prog, &xs)
                .unwrap_or_else(|e| panic!("{} {variant:?}: {e}", model.name));
            let p = variant.quant_precision();
            for (i, x) in xs.iter().enumerate() {
                let want = model.quantized_forward(x, p).unwrap();
                assert_eq!(
                    run.scores[i], want,
                    "{} {variant:?} sample {i}: ISS {:?} != ref {:?}",
                    model.name, run.scores[i], want
                );
            }
        }
    }
}

#[test]
fn tpisa_looped_variants_bit_exact() {
    let Some((man, models)) = load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    for model in &models {
        let xs = samples(&man, model, 6);
        for d in [8u32, 16, 32] {
            let mut variants = vec![TpVariant::Baseline, TpVariant::Mac { precision: d.min(16) }];
            // One sub-precision SIMD config per width.
            if d > 4 {
                variants.push(TpVariant::Mac { precision: d / 2 });
            }
            for variant in variants {
                let p = codegen_tpisa::quant_precision(d, variant);
                if model.qlayers(p).is_err() {
                    continue;
                }
                let prog = codegen_tpisa::generate(model, d, variant)
                    .unwrap_or_else(|e| panic!("{} d{d} {variant:?}: {e}", model.name));
                let run = harness::run_tpisa(model, &prog, &xs)
                    .unwrap_or_else(|e| panic!("{} d{d} {variant:?}: {e}", model.name));
                for (i, x) in xs.iter().enumerate() {
                    let want = model.quantized_forward(x, p).unwrap();
                    assert_eq!(
                        run.scores[i], want,
                        "{} d{d} {variant:?} sample {i}",
                        model.name
                    );
                }
            }
        }
    }
}

#[test]
fn tpisa_4bit_unrolled_svm() {
    let Some((man, models)) = load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    for model in models.iter().filter(|m| m.name.starts_with("svm_r")) {
        let xs = samples(&man, model, 6);
        for variant in [TpVariant::Baseline, TpVariant::Mac { precision: 4 }] {
            let prog = codegen_tpisa::generate(model, 4, variant)
                .unwrap_or_else(|e| panic!("{} d4 {variant:?}: {e}", model.name));
            let run = harness::run_tpisa(model, &prog, &xs).unwrap();
            for (i, x) in xs.iter().enumerate() {
                let want = model.quantized_forward(x, 4).unwrap();
                assert_eq!(run.scores[i], want, "{} d4 {variant:?} sample {i}", model.name);
            }
        }
    }
    // Multi-layer models must be rejected cleanly on the 4-bit core.
    if let Some(mlp) = models.iter().find(|m| m.layers.len() > 1) {
        assert!(codegen_tpisa::generate(mlp, 4, TpVariant::Baseline).is_err());
    }
}

#[test]
fn mac_variants_run_faster() {
    let Some((man, models)) = load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    // Paper Table I ordering: cycles(baseline) > cycles(mac32) >
    // cycles(p16) > cycles(p8) > cycles(p4); and TP-ISA MAC >> baseline.
    let model = &models[0];
    let xs = samples(&man, model, 4);
    let mut cycles = Vec::new();
    for variant in [
        Rv32Variant::Baseline,
        Rv32Variant::Mac32,
        Rv32Variant::Simd(16),
        Rv32Variant::Simd(8),
        Rv32Variant::Simd(4),
    ] {
        let prog = codegen_rv32::generate(model, variant).unwrap();
        let run = harness::run_rv32(model, &prog, &xs).unwrap();
        cycles.push((variant, run.cycles_per_sample));
    }
    for w in cycles.windows(2) {
        assert!(
            w[0].1 > w[1].1,
            "expected {:?} ({}) slower than {:?} ({})",
            w[0].0,
            w[0].1,
            w[1].0,
            w[1].1
        );
    }

    let base = codegen_tpisa::generate(model, 8, TpVariant::Baseline).unwrap();
    let mac = codegen_tpisa::generate(model, 8, TpVariant::Mac { precision: 8 }).unwrap();
    let cb = harness::run_tpisa(model, &base, &xs).unwrap().cycles_per_sample;
    let cm = harness::run_tpisa(model, &mac, &xs).unwrap().cycles_per_sample;
    // Table II: "up to 85.1%" execution-time reduction.
    let reduction = 1.0 - cm / cb;
    assert!(reduction > 0.6, "TP-ISA MAC reduction only {reduction:.3}");
}
