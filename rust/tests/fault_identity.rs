//! Differential pins for the fault-injection subsystem.
//!
//! Four contracts:
//!
//! 1. **Zero-rate identity** — plans generated at rate 0 are empty, and
//!    running every fixture model through the plan-carrying harness
//!    entry points with them is bit-identical (scores, predictions,
//!    full profile) to the un-instrumented baseline, on both cores.
//! 2. **Armed-but-empty identity** — a forced `FaultState` holding an
//!    empty plan exercises the fault-clock hooks on every engine
//!    (interpreter, translated, batched) without perturbing a single
//!    architectural or profile observable.
//! 3. **Plan purity** — fault outcomes are a function of the plan set
//!    alone: bit-identical across reruns and across batch lane widths
//!    (lane grouping cannot leak between trials).
//! 4. **Campaign determinism** — `bespoke::resilience::campaign`
//!    renders byte-identical text and JSON at 1 and 8 worker threads
//!    (CI re-runs this under `PBSP_THREADS={1,8}`), and its zero-rate
//!    curve row is 100% masked.
//!
//! Runs against `make artifacts` output when present, else the
//! checked-in `artifacts-fixture/`; skips only if both are missing
//! (contract 2 always runs — it needs no artifacts).

use std::sync::Arc;

use printed_bespoke::bespoke::resilience::{campaign, CampaignConfig};
use printed_bespoke::dse::context::EvalContext;
use printed_bespoke::hw::mac_unit::MacConfig;
use printed_bespoke::isa::rv32_asm::Asm;
use printed_bespoke::isa::{tpisa, MacOp};
use printed_bespoke::ml::codegen_rv32::{self, Rv32Variant};
use printed_bespoke::ml::codegen_tpisa::{self, TpVariant};
use printed_bespoke::ml::dataset::Dataset;
use printed_bespoke::ml::harness::{self, FaultOutcome};
use printed_bespoke::ml::manifest::Manifest;
use printed_bespoke::ml::model::Model;
use printed_bespoke::sim::batch::{BatchRv32, BatchTpIsa};
use printed_bespoke::sim::fault::{FaultPlan, FaultSpec, FaultState, MachineShape, Targets};
use printed_bespoke::sim::mem::RAM_BASE;
use printed_bespoke::sim::tpisa::TpIsa;
use printed_bespoke::sim::trace::{FullProfile, Profile};
use printed_bespoke::sim::zero_riscy::ZeroRiscy;
use printed_bespoke::sim::{PreparedRv32, PreparedTpIsa};
use printed_bespoke::util::rng::Pcg32;

fn load() -> Option<(Manifest, Vec<Model>)> {
    let dir = printed_bespoke::artifacts_dir().ok()?;
    let man = Manifest::load(&dir).ok()?;
    let models = man.models.iter().map(|e| Model::load(&e.weights).unwrap()).collect();
    Some((man, models))
}

fn random_samples(man: &Manifest, model: &Model, rng: &mut Pcg32, n: usize) -> Vec<Vec<f32>> {
    let ds = Dataset::load(man.data_dir(), &model.dataset, "test").unwrap();
    (0..n)
        .map(|_| {
            let a = &ds.x[rng.range_usize(0, ds.x.len() - 1)];
            let b = &ds.x[rng.range_usize(0, ds.x.len() - 1)];
            let t = rng.f64() as f32;
            a.iter().zip(b).map(|(&va, &vb)| va + t * (vb - va)).collect()
        })
        .collect()
}

fn assert_scores_eq(a: &[Vec<f64>], b: &[Vec<f64>], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: sample count");
    for (i, (ra, rb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ra.len(), rb.len(), "{what} sample {i}: score count");
        for (j, (va, vb)) in ra.iter().zip(rb).enumerate() {
            assert_eq!(va.to_bits(), vb.to_bits(), "{what} sample {i} score {j}: {va} vs {vb}");
        }
    }
}

fn assert_profiles_eq(a: &Profile, b: &Profile, what: &str) {
    assert_eq!(a.instr_counts(), b.instr_counts(), "{what}: histogram");
    assert_eq!(a.regs_used, b.regs_used, "{what}: regs_used");
    assert_eq!(a.max_pc, b.max_pc, "{what}: max_pc");
    assert_eq!(a.cycles, b.cycles, "{what}: cycles");
    assert_eq!(a.instructions, b.instructions, "{what}: instructions");
    assert_eq!(a.loads, b.loads, "{what}: loads");
    assert_eq!(a.stores, b.stores, "{what}: stores");
    assert_eq!(a.mul_ops, b.mul_ops, "{what}: mul_ops");
    assert_eq!(a.mac_ops, b.mac_ops, "{what}: mac_ops");
    assert_eq!(a.branches_taken, b.branches_taken, "{what}: branches_taken");
    assert_eq!(a.max_ram_offset, b.max_ram_offset, "{what}: max_ram_offset");
}

fn zero_rate_spec(seed: u64) -> FaultSpec {
    FaultSpec {
        seed,
        rate: 0.0,
        horizon: 100_000,
        mac_rate: 0.0,
        mac_horizon: 100_000,
        targets: Targets::ALL,
    }
}

// ---------------------------------------------------------------------------
// (1) Zero-rate identity: every fixture model, both cores.
// ---------------------------------------------------------------------------

#[test]
fn zero_rate_plans_are_bit_identical_to_baseline() {
    let Some((man, models)) = load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut rng = Pcg32::seeded(0xFA01_7001);
    for model in &models {
        let xs = random_samples(&man, model, &mut rng, 3);

        // Zero-Riscy (SIMD-MAC p8).
        let prog = codegen_rv32::generate(model, Rv32Variant::Simd(8)).unwrap();
        let what = format!("{} zero-riscy", model.name);
        let base = harness::run_rv32(model, &prog, &xs).unwrap();
        let shape = MachineShape::rv32(prog.prepared.ram_bytes, prog.prepared.mac);
        let spec = zero_rate_spec(0xb5);
        let plans: Vec<FaultPlan> =
            (0..xs.len()).map(|t| FaultPlan::generate(&spec, &shape, t as u64)).collect();
        assert!(plans.iter().all(FaultPlan::is_empty), "{what}: zero-rate plan not empty");
        let run = harness::run_rv32_batched_with_plans::<FullProfile>(
            model,
            &prog,
            &xs,
            harness::BATCH_LANES,
            &plans,
        )
        .unwrap();
        assert_scores_eq(&run.scores, &base.scores, &what);
        assert_eq!(run.predictions, base.predictions, "{what}: predictions");
        assert_profiles_eq(&run.profile, &base.profile, &what);
        // The campaign entry point too: zero-rate outcomes are all
        // Scores, each bit-equal to the baseline sample.
        let outs =
            harness::run_rv32_faulted(model, &prog, &prog.prepared, &xs, &plans, 64, 50_000_000)
                .unwrap();
        for (i, o) in outs.iter().enumerate() {
            match o {
                FaultOutcome::Scores(s) => {
                    assert_scores_eq(
                        std::slice::from_ref(s),
                        std::slice::from_ref(&base.scores[i]),
                        &what,
                    );
                }
                other => panic!("{what} sample {i}: zero-rate outcome {other:?}"),
            }
        }

        // TP-ISA (d32 MAC p8 — feasible for every fixture model).
        let tprog = codegen_tpisa::generate(model, 32, TpVariant::Mac { precision: 8 }).unwrap();
        let what = format!("{} tp-isa", model.name);
        let tbase = harness::run_tpisa(model, &tprog, &xs).unwrap();
        let tshape =
            MachineShape::tpisa(tprog.datapath, tprog.prepared.init_dmem.len(), tprog.prepared.mac);
        let tplans: Vec<FaultPlan> =
            (0..xs.len()).map(|t| FaultPlan::generate(&spec, &tshape, t as u64)).collect();
        assert!(tplans.iter().all(FaultPlan::is_empty), "{what}: zero-rate plan not empty");
        let trun = harness::run_tpisa_batched_with_plans::<FullProfile>(
            model,
            &tprog,
            &xs,
            harness::BATCH_LANES,
            &tplans,
        )
        .unwrap();
        assert_scores_eq(&trun.scores, &tbase.scores, &what);
        assert_eq!(trun.predictions, tbase.predictions, "{what}: predictions");
        assert_profiles_eq(&trun.profile, &tbase.profile, &what);
        let touts =
            harness::run_tpisa_faulted(model, &tprog, &tprog.prepared, &xs, &tplans, 64, 500_000_000)
                .unwrap();
        for (i, o) in touts.iter().enumerate() {
            match o {
                FaultOutcome::Scores(s) => {
                    assert_scores_eq(
                        std::slice::from_ref(s),
                        std::slice::from_ref(&tbase.scores[i]),
                        &what,
                    );
                }
                other => panic!("{what} sample {i}: zero-rate outcome {other:?}"),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// (2) Armed-but-empty FaultState: hooks tick, nothing changes.
// ---------------------------------------------------------------------------

fn small_rv32_program() -> Vec<printed_bespoke::isa::rv32::Instr> {
    // Loads, stores, a MAC accumulate and a counted loop: touches every
    // fault-clock site (instruction tick in interpreter and per-block
    // paths, MAC tick after each accumulate).
    let mut a = Asm::new();
    a.li(8, RAM_BASE as i32);
    a.li(5, 3);
    a.li(6, 4);
    a.sw(5, 8, 0);
    a.sw(6, 8, 4);
    a.maccl();
    a.li(10, 5); // loop counter
    a.label("loop");
    a.lw(5, 8, 0);
    a.lw(6, 8, 4);
    a.mac(5, 6);
    a.addi(10, 10, -1);
    a.branch(printed_bespoke::isa::rv32::BranchOp::Bne, 10, 0, "loop");
    a.macrd(11, 0);
    a.sw(11, 8, 8);
    a.ebreak();
    a.finish().unwrap()
}

#[test]
fn armed_empty_fault_state_is_inert_on_every_rv32_engine() {
    let code = small_rv32_program();
    let prepared = Arc::new(PreparedRv32::new(&code, &[], 0x400, Some(MacConfig::new(32, 32))));

    // Interpreter.
    let mut clean = ZeroRiscy::from_prepared(Arc::clone(&prepared));
    let hc = clean.run_traced::<FullProfile>(10_000).unwrap();
    let mut armed = ZeroRiscy::from_prepared(Arc::clone(&prepared));
    armed.fault = Some(FaultState::new(FaultPlan::default()));
    let ha = armed.run_traced::<FullProfile>(10_000).unwrap();
    assert_eq!(hc, ha, "interp: halt");
    assert_eq!(clean.regs, armed.regs, "interp: regs");
    assert_eq!(clean.pc, armed.pc, "interp: pc");
    assert_eq!(clean.mem.ram, armed.mem.ram, "interp: ram");
    assert_profiles_eq(&clean.profile, &armed.profile, "interp");

    // Translated engine.
    let mut clean = ZeroRiscy::from_prepared(Arc::clone(&prepared));
    let hc = clean.run_translated::<FullProfile>(10_000).unwrap();
    let mut armed = ZeroRiscy::from_prepared(Arc::clone(&prepared));
    armed.fault = Some(FaultState::new(FaultPlan::default()));
    let ha = armed.run_translated::<FullProfile>(10_000).unwrap();
    assert_eq!(hc, ha, "translated: halt");
    assert_eq!(clean.regs, armed.regs, "translated: regs");
    assert_eq!(clean.mem.ram, armed.mem.ram, "translated: ram");
    assert_profiles_eq(&clean.profile, &armed.profile, "translated");

    // Batched engine: arm some lanes, leave others bare.
    let mut clean = BatchRv32::new(Arc::clone(&prepared), 4);
    let rc = clean.run::<FullProfile>(4, 10_000);
    let mut armed = BatchRv32::new(prepared, 4);
    armed.lane_mut(1).fault = Some(FaultState::new(FaultPlan::default()));
    armed.lane_mut(3).fault = Some(FaultState::new(FaultPlan::default()));
    let ra = armed.run::<FullProfile>(4, 10_000);
    for i in 0..4 {
        let what = format!("batch lane {i}");
        assert_eq!(
            rc[i].as_ref().unwrap(),
            ra[i].as_ref().unwrap(),
            "{what}: halt"
        );
        assert_eq!(clean.lane(i).regs, armed.lane(i).regs, "{what}: regs");
        assert_eq!(clean.lane(i).mem.ram, armed.lane(i).mem.ram, "{what}: ram");
        assert_profiles_eq(&clean.lane(i).profile, &armed.lane(i).profile, &what);
    }
}

#[test]
fn armed_empty_fault_state_is_inert_on_every_tpisa_engine() {
    use tpisa::Instr;
    let code = vec![
        Instr::Ldi { r1: 0, imm: 3 },
        Instr::Ldi { r1: 1, imm: 4 },
        Instr::St { r1: 0, r2: 1, imm: 2 },
        Instr::Mac { op: MacOp::MacClr, r1: 0, r2: 0 },
        Instr::Mac { op: MacOp::Mac, r1: 0, r2: 1 },
        Instr::Mac { op: MacOp::Mac, r1: 0, r2: 1 },
        Instr::Mac { op: MacOp::MacRd, r1: 2, r2: 0 },
        Instr::Ld { r1: 3, r2: 1, imm: 2 },
        Instr::Halt,
    ];
    let prepared = Arc::new(PreparedTpIsa::with_zero_dmem(8, &code, 64, Some(MacConfig::new(8, 8))));

    let mut clean = TpIsa::from_prepared(Arc::clone(&prepared));
    let hc = clean.run_traced::<FullProfile>(1_000).unwrap();
    let mut armed = TpIsa::from_prepared(Arc::clone(&prepared));
    armed.fault = Some(FaultState::new(FaultPlan::default()));
    let ha = armed.run_traced::<FullProfile>(1_000).unwrap();
    assert_eq!(hc, ha, "tp interp: halt");
    assert_eq!(clean.regs, armed.regs, "tp interp: regs");
    let n = clean.dmem.len();
    assert_eq!(
        clean.dmem.read_words(0, n).unwrap(),
        armed.dmem.read_words(0, n).unwrap(),
        "tp interp: dmem"
    );
    assert_profiles_eq(&clean.profile, &armed.profile, "tp interp");

    let mut clean = TpIsa::from_prepared(Arc::clone(&prepared));
    let hc = clean.run_translated::<FullProfile>(1_000).unwrap();
    let mut armed = TpIsa::from_prepared(Arc::clone(&prepared));
    armed.fault = Some(FaultState::new(FaultPlan::default()));
    let ha = armed.run_translated::<FullProfile>(1_000).unwrap();
    assert_eq!(hc, ha, "tp translated: halt");
    assert_eq!(clean.regs, armed.regs, "tp translated: regs");
    assert_profiles_eq(&clean.profile, &armed.profile, "tp translated");

    let mut clean = BatchTpIsa::new(Arc::clone(&prepared), 3);
    let rc = clean.run::<FullProfile>(3, 1_000);
    let mut armed = BatchTpIsa::new(prepared, 3);
    armed.lane_mut(0).fault = Some(FaultState::new(FaultPlan::default()));
    armed.lane_mut(2).fault = Some(FaultState::new(FaultPlan::default()));
    let ra = armed.run::<FullProfile>(3, 1_000);
    for i in 0..3 {
        let what = format!("tp batch lane {i}");
        assert_eq!(rc[i].as_ref().unwrap(), ra[i].as_ref().unwrap(), "{what}: halt");
        assert_eq!(clean.lane(i).regs, armed.lane(i).regs, "{what}: regs");
        assert_profiles_eq(&clean.lane(i).profile, &armed.lane(i).profile, &what);
    }
}

// ---------------------------------------------------------------------------
// (3) Plan purity: outcomes depend on the plans, not lane grouping or
// rerun order.
// ---------------------------------------------------------------------------

#[test]
fn fault_outcomes_are_lane_width_independent_and_rerun_stable() {
    let Some((man, models)) = load() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let model = &models[0];
    let mut rng = Pcg32::seeded(0xFA01_7003);
    let xs = random_samples(&man, model, &mut rng, 6);
    let prog = codegen_rv32::generate(model, Rv32Variant::Simd(8)).unwrap();
    let base = harness::run_rv32(model, &prog, &xs).unwrap();
    let horizon = (base.profile.instructions / xs.len() as u64).max(1);
    let mac_horizon = (base.profile.mac_ops / xs.len() as u64).max(1);
    let shape = MachineShape::rv32(prog.prepared.ram_bytes, prog.prepared.mac);
    let spec = FaultSpec {
        seed: 0xb5,
        rate: 1e-3,
        horizon,
        mac_rate: 1e-3,
        mac_horizon,
        targets: Targets::ALL,
    };
    let plans: Vec<FaultPlan> =
        (0..xs.len()).map(|t| FaultPlan::generate(&spec, &shape, t as u64)).collect();
    assert!(plans.iter().any(|p| !p.is_empty()), "rate 1e-3 produced no faults at all");
    let fuel = (horizon * 8).max(10_000);
    let wide =
        harness::run_rv32_faulted(model, &prog, &prog.prepared, &xs, &plans, 64, fuel).unwrap();
    let narrow =
        harness::run_rv32_faulted(model, &prog, &prog.prepared, &xs, &plans, 2, fuel).unwrap();
    let again =
        harness::run_rv32_faulted(model, &prog, &prog.prepared, &xs, &plans, 64, fuel).unwrap();
    assert_eq!(format!("{wide:?}"), format!("{narrow:?}"), "lane width changed outcomes");
    assert_eq!(format!("{wide:?}"), format!("{again:?}"), "rerun changed outcomes");
    // Regenerating the plans from the same spec gives the same plans.
    let replans: Vec<FaultPlan> =
        (0..xs.len()).map(|t| FaultPlan::generate(&spec, &shape, t as u64)).collect();
    assert_eq!(plans, replans, "plan generation not reproducible");
}

// ---------------------------------------------------------------------------
// (4) Campaign determinism across worker-thread counts.
// ---------------------------------------------------------------------------

#[test]
fn campaign_report_is_thread_count_invariant() {
    let ctx1 = match EvalContext::load_with_threads(2, 1) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("skipping: artifacts not built ({e:#})");
            return;
        }
    };
    let ctx8 = EvalContext::load_with_threads(2, 8).unwrap();
    let cfg = CampaignConfig {
        trials: 8,
        samples: 2,
        rates: vec![0.0, 1e-4],
        rom_trials: 2,
        models: vec![ctx1.models[0].name.clone()],
        ..CampaignConfig::default()
    };
    let r1 = campaign(&ctx1, &cfg).unwrap();
    let r8 = campaign(&ctx8, &cfg).unwrap();
    assert_eq!(r1.text, r8.text, "campaign text differs across thread counts");
    assert_eq!(
        r1.json.to_string(),
        r8.json.to_string(),
        "campaign JSON differs across thread counts"
    );
    // The zero-rate row is the baseline: every trial must be masked.
    assert!(!r1.configs.is_empty(), "campaign produced no configurations");
    for c in &r1.configs {
        let (rate, oc) = &c.curve[0];
        assert_eq!(*rate, 0.0, "{}/{}: first swept rate", c.model, c.core);
        assert_eq!(
            oc.masked,
            oc.total(),
            "{}/{}: zero-rate trials not all masked",
            c.model,
            c.core
        );
    }
}
