//! Seeded network-chaos e2e (ISSUE 10): the device fleet drives the
//! reactor *through* the fault-injecting TCP proxy (`server::chaos`).
//! The invariants under fire:
//!
//! * nothing hangs — every request resolves as a record, a deadline
//!   miss, or an error (the three-way sum is exact);
//! * every 2xx body stays bit-identical to direct in-process scoring
//!   (`loadgen::verify`), chaos or no chaos;
//! * a clean-profile proxy is a perfect pass-through (zero errors);
//! * byte-dripped uploads spend the request's own deadline budget, so
//!   client deadlines turn into deterministic server-side 504s.
//!
//! Skips cleanly when no artifact tree matches the compiled backend
//! (same policy as `serve_http.rs`).

use std::sync::Arc;
use std::time::{Duration, Instant};

use printed_bespoke::coordinator::service::{Service, ServiceConfig};
use printed_bespoke::ml::manifest::Manifest;
use printed_bespoke::runtime::pjrt::Runtime;
use printed_bespoke::server::chaos::{plan_for, ChaosProxy, Profile};
use printed_bespoke::server::loadgen::{self, LoadgenConfig};
use printed_bespoke::server::{Server, ServerConfig};

fn manifest() -> Option<Manifest> {
    let dir = printed_bespoke::artifacts_dir().ok()?;
    let man = Manifest::load(&dir).ok()?;
    if Runtime::is_stub() != printed_bespoke::ml::fixtures::manifest_is_stub(&man) {
        eprintln!("skipping: artifact tree does not match the compiled runtime backend");
        return None;
    }
    Some(man)
}

fn start(svc_cfg: ServiceConfig, scfg: ServerConfig) -> (Arc<Service>, Server) {
    let svc = Arc::new(Service::start(svc_cfg).unwrap());
    let server = Server::start(Arc::clone(&svc), scfg).unwrap();
    (svc, server)
}

fn relaxed(c: &std::sync::atomic::AtomicU64) -> u64 {
    c.load(std::sync::atomic::Ordering::Relaxed)
}

/// Every request resolves exactly one way; no outcome is double- or
/// un-counted.  This is the "no hung connection" gate in countable form
/// (a hang would leave the sum short — or the test stuck, which the
/// harness timeout catches).
fn assert_outcomes_sum(report: &loadgen::Report, cfg: &LoadgenConfig) {
    let total = cfg.fleet * cfg.requests_per_device;
    assert_eq!(
        report.records.len() + report.deadline_misses + report.errors,
        total,
        "outcome sum mismatch: {} ok + {} misses + {} errors != {total}\n{}",
        report.records.len(),
        report.deadline_misses,
        report.errors,
        report.summary()
    );
}

/// A clean-profile proxy is byte-transparent: the fleet behaves exactly
/// as if it talked to the server directly — zero errors, full verify.
#[test]
fn clean_proxy_is_transparent() {
    if manifest().is_none() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let (svc, mut server) = start(ServiceConfig::default(), ServerConfig::default());
    let mut proxy = ChaosProxy::start(server.addr(), 3, Profile::Clean).unwrap();
    let cfg = LoadgenConfig { fleet: 8, requests_per_device: 4, seed: 11, ..Default::default() };
    let mut report = loadgen::run(proxy.addr(), &cfg).unwrap();
    report.server_metrics = loadgen::scrape_metrics(server.addr());
    assert_eq!(report.errors, 0, "clean proxy must not fail anything: {}", report.summary());
    assert_eq!(report.deadline_misses, 0);
    assert_eq!(report.records.len(), 8 * 4);
    let checked = loadgen::verify(&svc, &report).unwrap();
    assert_eq!(checked, 8 * 4);
    let s = proxy.stats();
    assert_eq!(s.faulted(), 0, "clean profile drew a fault");
    proxy.shutdown();
    server.shutdown();
}

/// The mix profile under retries: residual errors are allowed (faults
/// can outlast the retry budget), but whatever succeeded is
/// bit-identical to in-process scoring, the outcome sum is exact, and
/// the fleet's counters reconcile with the server's.
#[test]
fn mix_chaos_preserves_bit_identity_of_successes() {
    if manifest().is_none() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let (svc, mut server) = start(
        ServiceConfig::default(),
        // Headroom for retry reconnect churn.
        ServerConfig { max_connections: 4096, ..ServerConfig::default() },
    );
    let mut proxy = ChaosProxy::start(server.addr(), 7, Profile::Mix).unwrap();
    let cfg = LoadgenConfig {
        fleet: 24,
        requests_per_device: 2,
        seed: 7,
        attempts: 3,
        ..Default::default()
    };
    let t0 = Instant::now();
    let mut report = loadgen::run(proxy.addr(), &cfg).unwrap();
    // The run's own /metrics scrape rode the proxy and may have been
    // faulted — re-scrape off the direct address for reconciliation.
    report.server_metrics = loadgen::scrape_metrics(server.addr());
    assert_outcomes_sum(&report, &cfg);
    assert!(
        !report.records.is_empty(),
        "mix keeps a clean majority; something must succeed: {}",
        report.summary()
    );
    // Blackholes hold ~3s each and are a minority: the whole run must
    // finish far inside the harness timeout.
    assert!(t0.elapsed() < Duration::from_secs(120), "chaos run took {:?}", t0.elapsed());
    let checked = loadgen::verify(&svc, &report).unwrap();
    assert_eq!(checked, report.records.len(), "every success must verify");
    proxy.shutdown();
    server.shutdown();
}

/// Byte-dripped uploads spend the request's own deadline budget (the
/// budget counts from the request's *first byte*, not from pool
/// pickup): the drip profile forwards at most 64 bytes per 5 ms tick,
/// so a ~400-byte request needs ≥ 30 ms to arrive complete — against a
/// 10 ms `X-Deadline-Ms` every single request is already expired at
/// pickup and sheds as a clean 504, never an error or a hang.
#[test]
fn dripped_uploads_turn_deadlines_into_504s() {
    if manifest().is_none() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    const FLEET: usize = 8;
    const SEED: u64 = 7;
    // Sanity on the profile this test leans on: *every* drip-profile
    // connection is dripped (plans are pure functions of the seed).
    assert!(
        (0..FLEET as u64).all(|i| plan_for(SEED, i, Profile::Drip).drip.is_some()),
        "drip profile must drip every connection"
    );
    let (svc, mut server) = start(ServiceConfig::default(), ServerConfig::default());
    let mut proxy = ChaosProxy::start(server.addr(), SEED, Profile::Drip).unwrap();
    let cfg = LoadgenConfig {
        fleet: FLEET,
        requests_per_device: 2,
        seed: SEED,
        deadline_ms: 10,
        ..Default::default()
    };
    let mut report = loadgen::run(proxy.addr(), &cfg).unwrap();
    report.server_metrics = loadgen::scrape_metrics(server.addr());
    assert_outcomes_sum(&report, &cfg);
    assert_eq!(
        report.deadline_misses,
        FLEET * 2,
        "every dripped upload must be shed as a 504: {}",
        report.summary()
    );
    assert_eq!(report.errors, 0, "a shed is an orderly 504, not an error: {}", report.summary());
    assert!(
        relaxed(&server.metrics.deadline_shed) + relaxed(&server.metrics.deadline_shed_batch)
            >= report.deadline_misses as u64,
        "server shed counters must cover every client-observed 504"
    );
    // Nothing succeeded, so verify() has no records to bit-check — but
    // it must still reconcile the miss counters without complaint.
    assert_eq!(loadgen::verify(&svc, &report).unwrap(), 0);
    proxy.shutdown();
    server.shutdown();
}
