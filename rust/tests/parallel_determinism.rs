//! Satellite: the parallel evaluation engine must be *bit-identical* to
//! sequential execution.  The Table-I and Fig.-5 sweeps run twice —
//! `threads = 1` and `threads = 8` — and every report byte and every
//! underlying f64 bit pattern must match.  This is the contract that
//! lets every future scaling PR parallelise freely: sharded gathers
//! merge in input order, so thread count can never leak into results.
//!
//! Runs against `make artifacts` output when present, else the
//! checked-in `artifacts-fixture/`; skips only if both are missing.

use printed_bespoke::dse::context::EvalContext;
use printed_bespoke::dse::report;

fn ctx(threads: usize) -> Option<EvalContext> {
    EvalContext::load_with_threads(3, threads).ok()
}

#[test]
fn zr_table1_is_thread_count_invariant() {
    let (Some(c1), Some(c8)) = (ctx(1), ctx(8)) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let t1 = report::table1(&c1).unwrap();
    let t8 = report::table1(&c8).unwrap();
    // Byte-identical report text...
    assert_eq!(t1.text, t8.text);
    // ...and bit-identical floats underneath (text formatting rounds).
    assert_eq!(t1.rows.len(), t8.rows.len());
    for (a, b) in t1.rows.iter().zip(&t8.rows) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.area_mm2.to_bits(), b.area_mm2.to_bits(), "{}: area", a.name);
        assert_eq!(a.power_mw.to_bits(), b.power_mw.to_bits(), "{}: power", a.name);
        assert_eq!(a.speedup_pct.to_bits(), b.speedup_pct.to_bits(), "{}: speedup", a.name);
        assert_eq!(a.acc_loss_pct.to_bits(), b.acc_loss_pct.to_bits(), "{}: acc", a.name);
        assert_eq!(a.rom_cells_avg.to_bits(), b.rom_cells_avg.to_bits(), "{}: rom", a.name);
    }
}

#[test]
fn tpisa_sweep_is_thread_count_invariant() {
    let (Some(c1), Some(c8)) = (ctx(1), ctx(8)) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let f1 = report::fig5(&c1).unwrap();
    let f8 = report::fig5(&c8).unwrap();
    assert_eq!(f1.text, f8.text);
    assert_eq!(f1.pareto, f8.pareto);
    assert_eq!(f1.points.len(), f8.points.len());
    for (a, b) in f1.points.iter().zip(&f8.points) {
        assert_eq!(a.label, b.label);
        assert_eq!(a.cycles_avg.to_bits(), b.cycles_avg.to_bits(), "{}: cycles", a.label);
        assert_eq!(a.speedup_pct.to_bits(), b.speedup_pct.to_bits(), "{}: speedup", a.label);
        assert_eq!(a.err_pct.to_bits(), b.err_pct.to_bits(), "{}: err", a.label);
        assert_eq!(
            a.rom_cells_avg.to_bits(),
            b.rom_cells_avg.to_bits(),
            "{}: rom",
            a.label
        );
    }
}

#[test]
fn mem_report_is_thread_count_invariant() {
    let (Some(c1), Some(c8)) = (ctx(1), ctx(8)) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let m1 = report::mem(&c1).unwrap();
    let m8 = report::mem(&c8).unwrap();
    assert_eq!(m1.text, m8.text);
    assert_eq!(m1.mul_saving_pct.to_bits(), m8.mul_saving_pct.to_bits());
    assert_eq!(m1.simd_saving_pct.to_bits(), m8.simd_saving_pct.to_bits());
}
