//! Overload-surface tests (ISSUE 10): the split health endpoints
//! (`/healthz` liveness vs `/readyz` readiness with reasons), the
//! server-side default deadline and its per-request header override,
//! and the coordinator-level guarantee that a deadline-shed request
//! never penalizes its batch siblings.
//!
//! Skips cleanly when no artifact tree matches the compiled backend
//! (same policy as `serve_http.rs`).

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use printed_bespoke::coordinator::router::Key;
use printed_bespoke::coordinator::service::{Service, ServiceConfig, ERR_DEADLINE};
use printed_bespoke::ml::dataset::Dataset;
use printed_bespoke::ml::manifest::Manifest;
use printed_bespoke::runtime::pjrt::Runtime;
use printed_bespoke::server::http::Client;
use printed_bespoke::server::{Server, ServerConfig};
use printed_bespoke::util::json::Value;

fn manifest() -> Option<Manifest> {
    let dir = printed_bespoke::artifacts_dir().ok()?;
    let man = Manifest::load(&dir).ok()?;
    if Runtime::is_stub() != printed_bespoke::ml::fixtures::manifest_is_stub(&man) {
        eprintln!("skipping: artifact tree does not match the compiled runtime backend");
        return None;
    }
    Some(man)
}

fn start_with(svc_cfg: ServiceConfig, scfg: ServerConfig) -> (Arc<Service>, Server) {
    let svc = Arc::new(Service::start(svc_cfg).unwrap());
    let server = Server::start(Arc::clone(&svc), scfg).unwrap();
    (svc, server)
}

/// `/healthz` answers "is the process alive", `/readyz` answers "should
/// a balancer send traffic here" — at the connection cap they diverge:
/// liveness stays 200 while readiness turns 503 and names the reason.
#[test]
fn readyz_reports_connection_capacity() {
    if manifest().is_none() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let (_svc, mut server) =
        start_with(ServiceConfig::default(), ServerConfig { max_connections: 1, ..ServerConfig::default() });
    let mut c = Client::connect(server.addr()).unwrap();
    // This client *is* the capacity: open == limit == 1.
    assert_eq!(c.get("/healthz").unwrap().0, 200, "liveness must not care about capacity");
    let (status, body) = c.get("/readyz").unwrap();
    assert_eq!(status, 503, "at the connection cap readiness must fail: {body}");
    assert!(body.contains("connections at capacity"), "reason missing: {body}");
    // Readiness is a GET-only resource.
    let (status, _, body) = c.request_meta("POST", "/readyz", Some("{}"), &[]).unwrap();
    assert_eq!(status, 405, "POST /readyz must be rejected: {body}");
    server.shutdown();
}

/// Draining flips readiness off (with the reason) without touching
/// liveness, and readiness recovers when the flag clears.  The flag is
/// driven directly because a real drain stops reading new requests —
/// this exercises the `/readyz` decision itself.
#[test]
fn readyz_flags_draining_and_recovers() {
    if manifest().is_none() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let (_svc, mut server) = start_with(ServiceConfig::default(), ServerConfig::default());
    let mut c = Client::connect(server.addr()).unwrap();
    let (status, body) = c.get("/readyz").unwrap();
    assert_eq!(status, 200, "fresh server must be ready: {body}");
    assert!(body.contains("ready"), "body: {body}");

    server.metrics.draining.store(true, Ordering::Relaxed);
    let (status, body) = c.get("/readyz").unwrap();
    assert_eq!(status, 503);
    assert!(body.contains("draining"), "reason missing: {body}");
    assert_eq!(c.get("/healthz").unwrap().0, 200, "liveness must survive a drain");

    server.metrics.draining.store(false, Ordering::Relaxed);
    let (status, _) = c.get("/readyz").unwrap();
    assert_eq!(status, 200, "readiness must recover once the drain flag clears");
    server.shutdown();
}

/// The coordinator contract behind the 504 path: a request whose
/// deadline passes in the dynamic batcher is shed with `ERR_DEADLINE`
/// *before* execution, and its batch sibling scores exactly as if the
/// dead request had never been enqueued.
#[test]
fn batcher_shed_spares_batch_siblings() {
    let Some(man) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let svc =
        Service::start(ServiceConfig { linger_ms: 200, ..ServiceConfig::default() }).unwrap();
    let model = man.models[0].name.clone();
    let ds = Dataset::load(man.data_dir(), &man.models[0].dataset, "test").unwrap();
    let key = Key::precision(&model, 8);

    // Already-expired deadline: dead on arrival at batch dispatch.
    let rx_dead = svc
        .submit_with_deadline(key.clone(), ds.x[0].clone(), Some(Instant::now()))
        .unwrap();
    // Sibling lands in the same linger window, same batch.
    let rx_live = svc.submit(key.clone(), ds.x[1].clone()).unwrap();

    let dead = rx_dead.recv().unwrap();
    assert_eq!(dead.unwrap_err(), ERR_DEADLINE);
    let live = rx_live.recv().unwrap().unwrap();
    assert_eq!(live.batch, 1, "dead sibling must leave the batch before execution");
    let direct = svc.scores(&key, &[ds.x[1].clone()]).unwrap();
    assert_eq!(live.scores, direct[0], "sibling scores must be untouched by the shed");
}

/// `--default-deadline-ms` stamps a budget on header-less requests, and
/// an explicit `X-Deadline-Ms` overrides it: a generous header rides
/// out a pool stall that kills the server-default request behind it.
#[test]
fn server_default_deadline_applies_and_header_overrides() {
    let Some(man) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    // One pool thread + a long linger: the first request occupies the
    // pool deterministically while the second waits, expired, behind it.
    let (_svc, mut server) = start_with(
        ServiceConfig { linger_ms: 300, ..ServiceConfig::default() },
        ServerConfig { http_threads: 1, default_deadline_ms: 50, ..ServerConfig::default() },
    );
    let model = man.models[0].name.clone();
    let ds = Dataset::load(man.data_dir(), &man.models[0].dataset, "test").unwrap();
    let body = {
        let row = Value::Arr(ds.x[0].iter().map(|&f| Value::Num(f as f64)).collect());
        Value::obj(vec![("x", row)]).to_string()
    };
    let addr = server.addr();
    let path = format!("/v1/score/{model}/p8");

    // Blocker: explicit 10s header beats the 50 ms server default, so
    // it survives its own ~300 ms linger on the single pool thread.
    let blocker = {
        let (path, body) = (path.clone(), body.clone());
        std::thread::spawn(move || {
            let mut c = Client::connect(addr).unwrap();
            let (status, _, text) = c
                .request_meta("POST", &path, Some(&body), &[("x-deadline-ms", "10000".to_string())])
                .unwrap();
            (status, text)
        })
    };
    std::thread::sleep(Duration::from_millis(100));

    // No header: the server default (50 ms) applies, and the pool stays
    // busy for ~200 ms more — expired at pickup, shed with a 504.
    let mut c = Client::connect(addr).unwrap();
    let (status, _, text) = c.request_meta("POST", &path, Some(&body), &[]).unwrap();
    assert_eq!(status, 504, "server-default deadline must shed: {text}");
    assert!(text.contains("deadline"), "504 body must name the deadline: {text}");
    assert!(server.metrics.deadline_shed.load(Ordering::Relaxed) >= 1);

    let (status, text) = blocker.join().unwrap();
    assert_eq!(status, 200, "header override must outlive the stall: {text}");
    // The shed kept the connection: same client keeps working.
    assert_eq!(c.get("/healthz").unwrap().0, 200);
    server.shutdown();
}
