//! Serving-layer integration over real sockets (ISSUE 3 acceptance):
//! * the device-fleet load generator (>= 8 concurrent keep-alive
//!   connections) through the HTTP frontend yields scores bit-identical
//!   to direct `Service::submit` on the same service instance;
//! * every endpoint and error path behaves (status codes, JSON shapes);
//! * keep-alive holds one connection across sequential requests and
//!   the server/coordinator metrics both advance.
//!
//! Skips cleanly when no artifact tree matches the compiled backend
//! (same policy as `integration_runtime.rs`).

use std::sync::Arc;

use printed_bespoke::coordinator::router::Key;
use printed_bespoke::coordinator::service::{Service, ServiceConfig};
use printed_bespoke::ml::dataset::Dataset;
use printed_bespoke::ml::manifest::Manifest;
use printed_bespoke::runtime::pjrt::Runtime;
use printed_bespoke::server::http::Client;
use printed_bespoke::server::loadgen::{self, LoadgenConfig};
use printed_bespoke::server::{Server, ServerConfig};
use printed_bespoke::util::json::Value;

fn manifest() -> Option<Manifest> {
    let dir = printed_bespoke::artifacts_dir().ok()?;
    let man = Manifest::load(&dir).ok()?;
    if Runtime::is_stub() != printed_bespoke::ml::fixtures::manifest_is_stub(&man) {
        eprintln!("skipping: artifact tree does not match the compiled runtime backend");
        return None;
    }
    Some(man)
}

fn start_frontend(http_threads: usize) -> (Arc<Service>, Server) {
    let svc = Arc::new(Service::start(ServiceConfig::default()).unwrap());
    let scfg = ServerConfig { http_threads, ..ServerConfig::default() };
    let server = Server::start(Arc::clone(&svc), scfg).unwrap();
    (svc, server)
}

/// The acceptance gate: a seeded fleet of 8 devices over real sockets,
/// then a bit-identity replay of every served request through the very
/// service instance that backed the frontend.
#[test]
fn fleet_scores_bit_identical_to_direct_submit() {
    let Some(man) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (svc, mut server) = start_frontend(8);
    let cfg = LoadgenConfig {
        fleet: 8,
        requests_per_device: 6,
        seed: 42,
        think_ms: 0,
        precision: 8,
        ..Default::default()
    };
    let report = loadgen::run(server.addr(), &cfg).unwrap();
    server.shutdown();
    assert_eq!(report.errors, 0, "fleet saw errors: {}", report.summary());
    assert_eq!(report.records.len(), 48, "every request must be served");
    assert!(report.rps > 0.0);

    // Replay: identical inputs through the in-process streaming path.
    let datasets: Vec<Dataset> = man
        .models
        .iter()
        .map(|m| Dataset::load(man.data_dir(), &m.dataset, "test").unwrap())
        .collect();
    for r in &report.records {
        let name = &man.models[r.model].name;
        let x = datasets[r.model].x[r.sample].clone();
        let want = svc
            .submit(Key::precision(name, cfg.precision), x)
            .unwrap()
            .recv()
            .unwrap()
            .unwrap()
            .scores;
        assert_eq!(
            r.scores, want,
            "device {} seq {} ({} sample {}): HTTP scores differ from direct submit",
            r.device, r.seq, name, r.sample
        );
    }
    // 8 devices, keep-alive: at most one connection each (no reconnect
    // churn when the fleet fits the handler pool).
    let conns = server.metrics.connections.load(std::sync::atomic::Ordering::Relaxed);
    assert!((8..=16).contains(&conns), "unexpected connection count {conns}");
}

#[test]
fn endpoints_and_error_paths() {
    let Some(man) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (svc, mut server) = start_frontend(4);
    let mut c = Client::connect(server.addr()).unwrap();

    let (status, body) = c.get("/healthz").unwrap();
    assert_eq!(status, 200);
    let v = Value::parse(&body).unwrap();
    assert_eq!(v.get("status").unwrap().as_str().unwrap(), "ok");

    let (status, body) = c.get("/v1/models").unwrap();
    assert_eq!(status, 200);
    let v = Value::parse(&body).unwrap();
    let listed = v.get("models").unwrap().as_arr().unwrap();
    assert_eq!(listed.len(), man.models.len());
    for (e, m) in listed.iter().zip(&man.models) {
        assert_eq!(e.get("name").unwrap().as_str().unwrap(), m.name);
    }

    // Single-sample scoring matches direct submit, prediction included.
    let model = &man.models[0];
    let ds = Dataset::load(man.data_dir(), &model.dataset, "test").unwrap();
    let x = &ds.x[0];
    let body = {
        let row = Value::Arr(x.iter().map(|&f| Value::Num(f as f64)).collect());
        Value::obj(vec![("x", row)]).to_string()
    };
    let (status, text) = c.post(&format!("/v1/score/{}/p8", model.name), &body).unwrap();
    assert_eq!(status, 200, "score failed: {text}");
    let v = Value::parse(&text).unwrap();
    let got = v.get("scores").unwrap().as_f64_vec().unwrap();
    let want = svc
        .submit(Key::precision(&model.name, 8), x.clone())
        .unwrap()
        .recv()
        .unwrap()
        .unwrap()
        .scores;
    assert_eq!(got, want);
    let pred = v.get("prediction").unwrap().as_i64().unwrap();
    assert_eq!(pred, svc.model(&model.name).unwrap().predict(&want));

    // Batch form scores both rows.
    let batch_body = {
        let rows = Value::Arr(
            ds.x[..2]
                .iter()
                .map(|r| Value::Arr(r.iter().map(|&f| Value::Num(f as f64)).collect()))
                .collect(),
        );
        Value::obj(vec![("xs", rows)]).to_string()
    };
    let (status, text) = c.post(&format!("/v1/score/{}/p8", model.name), &batch_body).unwrap();
    assert_eq!(status, 200, "batch score failed: {text}");
    let v = Value::parse(&text).unwrap();
    assert_eq!(v.get("scores").unwrap().as_arr().unwrap().len(), 2);
    assert_eq!(v.get("predictions").unwrap().as_i64_vec().unwrap().len(), 2);

    // Error paths: all JSON envelopes on the same keep-alive connection.
    let cases: Vec<(u16, (u16, String))> = vec![
        (404, c.post("/v1/score/nonexistent/p8", &body).unwrap()),
        (404, c.post(&format!("/v1/score/{}/p3", model.name), &body).unwrap()),
        (404, c.get("/nope").unwrap()),
        (405, c.get(&format!("/v1/score/{}/p8", model.name)).unwrap()),
        (405, c.post("/healthz", "{}").unwrap()),
        (400, c.post(&format!("/v1/score/{}/p8", model.name), "not json").unwrap()),
        (400, c.post(&format!("/v1/score/{}/p8", model.name), "{\"y\": 1}").unwrap()),
        (400, c.post(&format!("/v1/score/{}/p8", model.name), "{\"x\": [1]}").unwrap()),
    ];
    for (want, (got, text)) in cases {
        assert_eq!(got, want, "body: {text}");
        assert!(Value::parse(&text).unwrap().get("error").is_ok(), "error envelope: {text}");
    }

    // /metrics reflects both families after the traffic above.
    let (status, text) = c.get("/metrics").unwrap();
    assert_eq!(status, 200);
    let v = Value::parse(&text).unwrap();
    let server_m = v.get("server").unwrap();
    assert_eq!(server_m.get("connections").unwrap().as_i64().unwrap(), 1);
    assert!(server_m.get("http_requests").unwrap().as_i64().unwrap() >= 12);
    assert!(server_m.get("samples_scored").unwrap().as_i64().unwrap() >= 3);
    assert!(server_m.get("responses_4xx").unwrap().as_i64().unwrap() >= 7);
    let coord = v.get("coordinator").unwrap();
    assert!(coord.get("requests").unwrap().as_i64().unwrap() >= 3);
    assert!(coord.get("queue_ms").unwrap().get("count").unwrap().as_i64().unwrap() >= 3);
    server.shutdown();
}

/// Connections past `max_connections` are refused with 503 +
/// `Retry-After` (visible backpressure) while admitted connections keep
/// working — and the compute pool size plays no part in admission.
#[test]
fn over_capacity_connection_gets_503() {
    if manifest().is_none() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    use printed_bespoke::server::http::{HttpConn, Outcome};
    let svc = Arc::new(Service::start(ServiceConfig::default()).unwrap());
    let scfg = ServerConfig { max_connections: 1, ..ServerConfig::default() };
    let mut server = Server::start(Arc::clone(&svc), scfg).unwrap();
    // First connection takes the only admission slot...
    let mut holder = Client::connect(server.addr()).unwrap();
    let (status, _) = holder.get("/healthz").unwrap(); // admitted for sure
    assert_eq!(status, 200);
    // ...so the second is refused at admission: the 503 arrives
    // unsolicited (read it without writing — the server closes right
    // after, so a request write would race the close).
    let stream = std::net::TcpStream::connect(server.addr()).unwrap();
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100))).unwrap();
    let mut conn = HttpConn::new(stream);
    let msg = (0..100)
        .find_map(|_| match conn.read_message().unwrap() {
            Outcome::Message(m) => Some(m),
            Outcome::Idle => None,
            Outcome::Closed => panic!("connection closed before the 503 arrived"),
        })
        .expect("no 503 within 10s");
    assert!(msg.start_line.contains("503"), "want 503, got {:?}", msg.start_line);
    assert_eq!(msg.headers["retry-after"], "1", "refusal must carry Retry-After");
    assert_eq!(msg.headers["connection"], "close");
    let text = String::from_utf8(msg.body).unwrap();
    assert!(Value::parse(&text).unwrap().get("error").is_ok());
    let rejected = server.metrics.rejected_busy.load(std::sync::atomic::Ordering::Relaxed);
    assert!(rejected >= 1, "rejected_busy should count the refusal");
    // The admitted connection is unaffected by the refusal next door.
    let (status, _) = holder.get("/healthz").unwrap();
    assert_eq!(status, 200);
    server.shutdown();
}

/// Concurrent single-sample posts from parallel connections coalesce in
/// the dynamic batcher (mean batch > 1) and all succeed.
#[test]
fn concurrent_connections_batch_in_coordinator() {
    let Some(man) = manifest() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (svc, mut server) = start_frontend(8);
    let model = man.models[0].name.clone();
    let ds = Dataset::load(man.data_dir(), &man.models[0].dataset, "test").unwrap();
    let addr = server.addr();
    let per_thread = 24usize;
    let handles: Vec<_> = (0..8usize)
        .map(|t| {
            let model = model.clone();
            let xs: Vec<Vec<f32>> =
                (0..per_thread).map(|i| ds.x[(t * per_thread + i) % ds.len()].clone()).collect();
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                for x in xs {
                    let row = Value::Arr(x.iter().map(|&f| Value::Num(f as f64)).collect());
                    let body = Value::obj(vec![("x", row)]).to_string();
                    let (status, text) =
                        c.post(&format!("/v1/score/{model}/p8"), &body).unwrap();
                    assert_eq!(status, 200, "{text}");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    server.shutdown();
    let m = svc.metrics.lock().unwrap().clone();
    assert!(m.requests >= (8 * per_thread) as u64);
    assert!(
        m.mean_batch_size() > 1.0,
        "concurrent HTTP requests should coalesce: {}",
        m.summary()
    );
}
