//! Two-pass RV32 assembler: a typed builder API with label resolution,
//! pseudo-instructions (`li`, `mv`, `j`, `nop`, `ret`) and a small text
//! parser for tests/examples.
//!
//! The ML code generator (`ml::codegen_rv32`) drives the builder API;
//! the text syntax exists so programs can also be written by hand:
//!
//! ```text
//!     li   x5, 1000
//! loop:
//!     addi x5, x5, -1
//!     bne  x5, x0, loop
//!     ebreak
//! ```

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use super::rv32::*;
use super::MacOp;

/// A pending label reference.
#[derive(Debug, Clone)]
enum Fixup {
    Branch { idx: usize, label: String },
    Jal { idx: usize, label: String },
}

/// Two-pass assembler/builder.
#[derive(Debug, Default)]
pub struct Asm {
    instrs: Vec<Instr>,
    labels: BTreeMap<String, usize>,
    fixups: Vec<Fixup>,
}

impl Asm {
    pub fn new() -> Asm {
        Asm::default()
    }

    /// Current instruction index (word offset).
    pub fn here(&self) -> usize {
        self.instrs.len()
    }

    pub fn label(&mut self, name: &str) {
        assert!(
            self.labels.insert(name.to_string(), self.instrs.len()).is_none(),
            "duplicate label {name}"
        );
    }

    pub fn push(&mut self, i: Instr) -> &mut Self {
        self.instrs.push(i);
        self
    }

    // -- common instructions ------------------------------------------------

    pub fn addi(&mut self, rd: Reg, rs1: Reg, imm: i32) -> &mut Self {
        assert!((-2048..=2047).contains(&imm), "addi imm {imm} out of range");
        self.push(Instr::OpImm { op: AluOp::Add, rd, rs1, imm })
    }

    pub fn add(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.push(Instr::Op { op: AluOp::Add, rd, rs1, rs2 })
    }

    pub fn sub(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.push(Instr::Op { op: AluOp::Sub, rd, rs1, rs2 })
    }

    pub fn mul(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.push(Instr::MulDiv { op: MulOp::Mul, rd, rs1, rs2 })
    }

    pub fn slli(&mut self, rd: Reg, rs1: Reg, sh: i32) -> &mut Self {
        self.push(Instr::OpImm { op: AluOp::Sll, rd, rs1, imm: sh })
    }

    pub fn srai(&mut self, rd: Reg, rs1: Reg, sh: i32) -> &mut Self {
        self.push(Instr::OpImm { op: AluOp::Sra, rd, rs1, imm: sh })
    }

    pub fn lw(&mut self, rd: Reg, rs1: Reg, off: i32) -> &mut Self {
        self.push(Instr::Load { op: LoadOp::Lw, rd, rs1, offset: off })
    }

    pub fn lh(&mut self, rd: Reg, rs1: Reg, off: i32) -> &mut Self {
        self.push(Instr::Load { op: LoadOp::Lh, rd, rs1, offset: off })
    }

    pub fn lb(&mut self, rd: Reg, rs1: Reg, off: i32) -> &mut Self {
        self.push(Instr::Load { op: LoadOp::Lb, rd, rs1, offset: off })
    }

    pub fn sw(&mut self, rs2: Reg, rs1: Reg, off: i32) -> &mut Self {
        self.push(Instr::Store { op: StoreOp::Sw, rs2, rs1, offset: off })
    }

    pub fn mac(&mut self, rs1: Reg, rs2: Reg) -> &mut Self {
        self.push(Instr::Mac { op: MacOp::Mac, rd: 0, rs1, rs2 })
    }

    pub fn macrd(&mut self, rd: Reg, lane: u8) -> &mut Self {
        self.push(Instr::Mac { op: MacOp::MacRd, rd, rs1: lane, rs2: 0 })
    }

    pub fn maccl(&mut self) -> &mut Self {
        self.push(Instr::Mac { op: MacOp::MacClr, rd: 0, rs1: 0, rs2: 0 })
    }

    pub fn ebreak(&mut self) -> &mut Self {
        self.push(Instr::Ebreak)
    }

    // -- pseudo-instructions --------------------------------------------------

    /// Load immediate: `addi` when it fits, else `lui (+ addi)`.
    pub fn li(&mut self, rd: Reg, imm: i32) -> &mut Self {
        if (-2048..=2047).contains(&imm) {
            return self.addi(rd, 0, imm);
        }
        let lo = (imm << 20) >> 20; // sign-extended low 12
        let hi = imm.wrapping_sub(lo) as u32 & 0xfffff000;
        self.push(Instr::Lui { rd, imm: hi as i32 });
        if lo != 0 {
            self.addi(rd, rd, lo);
        }
        self
    }

    pub fn mv(&mut self, rd: Reg, rs: Reg) -> &mut Self {
        self.addi(rd, rs, 0)
    }

    pub fn nop(&mut self) -> &mut Self {
        self.addi(0, 0, 0)
    }

    // -- control flow with labels ----------------------------------------------

    pub fn branch(&mut self, op: BranchOp, rs1: Reg, rs2: Reg, label: &str) -> &mut Self {
        self.fixups.push(Fixup::Branch { idx: self.instrs.len(), label: label.to_string() });
        self.push(Instr::Branch { op, rs1, rs2, offset: 0 })
    }

    pub fn beq(&mut self, rs1: Reg, rs2: Reg, l: &str) -> &mut Self {
        self.branch(BranchOp::Beq, rs1, rs2, l)
    }

    pub fn bne(&mut self, rs1: Reg, rs2: Reg, l: &str) -> &mut Self {
        self.branch(BranchOp::Bne, rs1, rs2, l)
    }

    pub fn blt(&mut self, rs1: Reg, rs2: Reg, l: &str) -> &mut Self {
        self.branch(BranchOp::Blt, rs1, rs2, l)
    }

    pub fn bge(&mut self, rs1: Reg, rs2: Reg, l: &str) -> &mut Self {
        self.branch(BranchOp::Bge, rs1, rs2, l)
    }

    /// Unconditional jump to label (JAL x0).
    pub fn j(&mut self, label: &str) -> &mut Self {
        self.fixups.push(Fixup::Jal { idx: self.instrs.len(), label: label.to_string() });
        self.push(Instr::Jal { rd: 0, offset: 0 })
    }

    /// Resolve fixups and return the finished instruction stream.
    pub fn finish(mut self) -> Result<Vec<Instr>> {
        for f in &self.fixups {
            match f {
                Fixup::Branch { idx, label } | Fixup::Jal { idx, label } => {
                    let target = *self
                        .labels
                        .get(label)
                        .ok_or_else(|| anyhow!("undefined label {label:?}"))?;
                    let off = (target as i64 - *idx as i64) * 4;
                    match &mut self.instrs[*idx] {
                        Instr::Branch { offset, .. } => {
                            if !(-4096..=4094).contains(&off) {
                                bail!("branch to {label:?} out of range ({off})");
                            }
                            *offset = off as i32;
                        }
                        Instr::Jal { offset, .. } => *offset = off as i32,
                        other => bail!("fixup on non-branch {other:?}"),
                    }
                }
            }
        }
        Ok(self.instrs)
    }
}

// ---------------------------------------------------------------------------
// Text parser (subset; the builder API is the primary interface)
// ---------------------------------------------------------------------------

fn parse_reg(tok: &str) -> Result<Reg> {
    let t = tok.trim().trim_end_matches(',');
    let names = [
        ("zero", 0), ("ra", 1), ("sp", 2), ("gp", 3), ("tp", 4), ("t0", 5), ("t1", 6),
        ("t2", 7), ("s0", 8), ("fp", 8), ("s1", 9), ("a0", 10), ("a1", 11), ("a2", 12),
        ("a3", 13), ("a4", 14), ("a5", 15), ("a6", 16), ("a7", 17), ("s2", 18), ("s3", 19),
        ("s4", 20), ("s5", 21), ("s6", 22), ("s7", 23), ("s8", 24), ("s9", 25), ("s10", 26),
        ("s11", 27), ("t3", 28), ("t4", 29), ("t5", 30), ("t6", 31),
    ];
    if let Some(&(_, n)) = names.iter().find(|(n, _)| *n == t) {
        return Ok(n);
    }
    if let Some(n) = t.strip_prefix('x') {
        let v: u8 = n.parse().with_context(|| format!("bad register {t:?}"))?;
        if v < 32 {
            return Ok(v);
        }
    }
    bail!("bad register {tok:?}")
}

fn parse_imm(tok: &str) -> Result<i32> {
    let t = tok.trim().trim_end_matches(',');
    let (neg, t) = match t.strip_prefix('-') {
        Some(r) => (true, r),
        None => (false, t),
    };
    let v = if let Some(h) = t.strip_prefix("0x") {
        i64::from_str_radix(h, 16)?
    } else {
        t.parse::<i64>()?
    };
    Ok(if neg { -v } else { v } as i32)
}

/// Parse `off(reg)`.
fn parse_mem(tok: &str) -> Result<(i32, Reg)> {
    let t = tok.trim().trim_end_matches(',');
    let open = t.find('(').ok_or_else(|| anyhow!("expected off(reg): {t:?}"))?;
    let off = if open == 0 { 0 } else { parse_imm(&t[..open])? };
    let reg = parse_reg(t[open + 1..].trim_end_matches(')'))?;
    Ok((off, reg))
}

/// Assemble a text program into an instruction stream.
pub fn assemble(text: &str) -> Result<Vec<Instr>> {
    let mut asm = Asm::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let line = if let Some((label, rest)) = line.split_once(':') {
            asm.label(label.trim());
            rest.trim()
        } else {
            line
        };
        if line.is_empty() {
            continue;
        }
        parse_line(&mut asm, line).with_context(|| format!("line {}: {raw:?}", lineno + 1))?;
    }
    asm.finish()
}

fn parse_line(asm: &mut Asm, line: &str) -> Result<()> {
    let mut parts = line.split_whitespace();
    let op = parts.next().unwrap();
    let rest: Vec<&str> = parts.collect();
    let arg = |i: usize| -> Result<&str> {
        rest.get(i).copied().ok_or_else(|| anyhow!("{op}: missing operand {i}"))
    };
    match op {
        "li" => {
            asm.li(parse_reg(arg(0)?)?, parse_imm(arg(1)?)?);
        }
        "mv" => {
            asm.mv(parse_reg(arg(0)?)?, parse_reg(arg(1)?)?);
        }
        "nop" => {
            asm.nop();
        }
        "addi" | "andi" | "ori" | "xori" | "slti" | "sltiu" | "slli" | "srli" | "srai" => {
            let a = match op {
                "addi" => AluOp::Add,
                "andi" => AluOp::And,
                "ori" => AluOp::Or,
                "xori" => AluOp::Xor,
                "slti" => AluOp::Slt,
                "sltiu" => AluOp::Sltu,
                "slli" => AluOp::Sll,
                "srli" => AluOp::Srl,
                _ => AluOp::Sra,
            };
            asm.push(Instr::OpImm {
                op: a,
                rd: parse_reg(arg(0)?)?,
                rs1: parse_reg(arg(1)?)?,
                imm: parse_imm(arg(2)?)?,
            });
        }
        "add" | "sub" | "and" | "or" | "xor" | "sll" | "srl" | "sra" | "slt" | "sltu" => {
            let a = match op {
                "add" => AluOp::Add,
                "sub" => AluOp::Sub,
                "and" => AluOp::And,
                "or" => AluOp::Or,
                "xor" => AluOp::Xor,
                "sll" => AluOp::Sll,
                "srl" => AluOp::Srl,
                "sra" => AluOp::Sra,
                "slt" => AluOp::Slt,
                _ => AluOp::Sltu,
            };
            asm.push(Instr::Op {
                op: a,
                rd: parse_reg(arg(0)?)?,
                rs1: parse_reg(arg(1)?)?,
                rs2: parse_reg(arg(2)?)?,
            });
        }
        "mul" | "mulh" | "mulhu" | "mulhsu" | "div" | "divu" | "rem" | "remu" => {
            let m = match op {
                "mul" => MulOp::Mul,
                "mulh" => MulOp::Mulh,
                "mulhu" => MulOp::Mulhu,
                "mulhsu" => MulOp::Mulhsu,
                "div" => MulOp::Div,
                "divu" => MulOp::Divu,
                "rem" => MulOp::Rem,
                _ => MulOp::Remu,
            };
            asm.push(Instr::MulDiv {
                op: m,
                rd: parse_reg(arg(0)?)?,
                rs1: parse_reg(arg(1)?)?,
                rs2: parse_reg(arg(2)?)?,
            });
        }
        "lb" | "lh" | "lw" | "lbu" | "lhu" => {
            let l = match op {
                "lb" => LoadOp::Lb,
                "lh" => LoadOp::Lh,
                "lw" => LoadOp::Lw,
                "lbu" => LoadOp::Lbu,
                _ => LoadOp::Lhu,
            };
            let (off, rs1) = parse_mem(arg(1)?)?;
            asm.push(Instr::Load { op: l, rd: parse_reg(arg(0)?)?, rs1, offset: off });
        }
        "sb" | "sh" | "sw" => {
            let s = match op {
                "sb" => StoreOp::Sb,
                "sh" => StoreOp::Sh,
                _ => StoreOp::Sw,
            };
            let (off, rs1) = parse_mem(arg(1)?)?;
            asm.push(Instr::Store { op: s, rs2: parse_reg(arg(0)?)?, rs1, offset: off });
        }
        "beq" | "bne" | "blt" | "bge" | "bltu" | "bgeu" => {
            let b = match op {
                "beq" => BranchOp::Beq,
                "bne" => BranchOp::Bne,
                "blt" => BranchOp::Blt,
                "bge" => BranchOp::Bge,
                "bltu" => BranchOp::Bltu,
                _ => BranchOp::Bgeu,
            };
            asm.branch(b, parse_reg(arg(0)?)?, parse_reg(arg(1)?)?, arg(2)?);
        }
        "beqz" => {
            asm.branch(BranchOp::Beq, parse_reg(arg(0)?)?, 0, arg(1)?);
        }
        "bnez" => {
            asm.branch(BranchOp::Bne, parse_reg(arg(0)?)?, 0, arg(1)?);
        }
        "j" => {
            asm.j(arg(0)?);
        }
        "mac" => {
            asm.mac(parse_reg(arg(0)?)?, parse_reg(arg(1)?)?);
        }
        "macrd" => {
            asm.macrd(parse_reg(arg(0)?)?, parse_imm(arg(1)?)? as u8);
        }
        "maccl" => {
            asm.maccl();
        }
        "ebreak" => {
            asm.ebreak();
        }
        "ecall" => {
            asm.push(Instr::Ecall);
        }
        _ => bail!("unknown mnemonic {op:?}"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_labels_resolve() {
        let mut a = Asm::new();
        a.li(5, 3);
        a.label("loop");
        a.addi(5, 5, -1);
        a.bne(5, 0, "loop");
        a.ebreak();
        let prog = a.finish().unwrap();
        match prog[2] {
            Instr::Branch { offset, .. } => assert_eq!(offset, -4),
            ref other => panic!("{other:?}"),
        }
    }

    #[test]
    fn undefined_label_errors() {
        let mut a = Asm::new();
        a.j("nowhere");
        assert!(a.finish().is_err());
    }

    #[test]
    fn li_expansion() {
        let mut a = Asm::new();
        a.li(1, 5);
        a.li(2, 0x12345);
        a.li(3, -70000);
        let prog = a.finish().unwrap();
        // 5 fits addi; 0x12345 needs lui+addi; -70000 needs lui+addi.
        assert_eq!(prog.len(), 5);
        // Verify the expansions compute the right constants by symbolic
        // evaluation.
        let eval = |instrs: &[Instr]| -> i64 {
            let mut regs = [0i64; 32];
            for i in instrs {
                match *i {
                    Instr::Lui { rd, imm } => regs[rd as usize] = imm as i64,
                    Instr::OpImm { op: AluOp::Add, rd, rs1, imm } => {
                        regs[rd as usize] = regs[rs1 as usize] + imm as i64
                    }
                    _ => unreachable!(),
                }
            }
            regs.iter().skip(1).copied().find(|&v| v != 0).unwrap()
        };
        assert_eq!(eval(&prog[0..1]), 5);
        assert_eq!(eval(&prog[1..3]), 0x12345);
        assert_eq!(eval(&prog[3..5]), -70000);
    }

    #[test]
    fn text_assembly_roundtrip() {
        let prog = assemble(
            r#"
            # countdown
                li   t0, 10
            loop:
                addi t0, t0, -1
                bnez t0, loop
                sw   t0, 0(sp)
                ebreak
            "#,
        )
        .unwrap();
        assert_eq!(prog.len(), 5);
        assert_eq!(prog[0], Instr::OpImm { op: AluOp::Add, rd: 5, rs1: 0, imm: 10 });
        assert!(matches!(prog[2], Instr::Branch { op: BranchOp::Bne, offset: -4, .. }));
    }

    #[test]
    fn text_mac_ops() {
        let prog = assemble("maccl\nmac a0, a1\nmacrd a2, 1\nebreak").unwrap();
        assert_eq!(prog.len(), 4);
        assert!(matches!(prog[1], Instr::Mac { op: crate::isa::MacOp::Mac, rs1: 10, rs2: 11, .. }));
        assert!(matches!(prog[2], Instr::Mac { op: crate::isa::MacOp::MacRd, rd: 12, rs1: 1, .. }));
    }

    #[test]
    fn text_rejects_unknown() {
        assert!(assemble("frobnicate x1, x2").is_err());
        assert!(assemble("addi x1").is_err());
    }

    #[test]
    fn mem_operands() {
        let prog = assemble("lw a0, 8(sp)\nsw a0, -4(s0)\nlw a1, (sp)").unwrap();
        assert_eq!(prog[0], Instr::Load { op: LoadOp::Lw, rd: 10, rs1: 2, offset: 8 });
        assert_eq!(prog[1], Instr::Store { op: StoreOp::Sw, rs2: 10, rs1: 8, offset: -4 });
        assert_eq!(prog[2], Instr::Load { op: LoadOp::Lw, rd: 11, rs1: 2, offset: 0 });
    }
}
