//! RV32IM subset + custom SIMD-MAC extension: instruction model,
//! encoder, decoder, disassembler.
//!
//! This is the slice of RV32IM that Zero-Riscy executes in our
//! benchmarks plus the instructions the bespoke profiler must *observe
//! as unused* (SLT/SLTI, CSR ops, ECALL/EBREAK, MULH*) — the reduction
//! pass works from real decode results, not hard-coded lists.
//!
//! The MAC extension lives in the custom-0 opcode (0x0B): funct3 0 =
//! `mac rs1, rs2`, funct3 1 = `macrd rd, lane(rs1 field)`, funct3 2 =
//! `maccl`.  The unit's precision is a *hardware* configuration (one
//! precision option per synthesised core, as in the paper), not an
//! instruction field.

use anyhow::{bail, Result};

use super::MacOp;

/// Register index newtype (x0..x31).
pub type Reg = u8;

/// Decoded instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Instr {
    Lui { rd: Reg, imm: i32 },
    Auipc { rd: Reg, imm: i32 },
    Jal { rd: Reg, offset: i32 },
    Jalr { rd: Reg, rs1: Reg, offset: i32 },
    Branch { op: BranchOp, rs1: Reg, rs2: Reg, offset: i32 },
    Load { op: LoadOp, rd: Reg, rs1: Reg, offset: i32 },
    Store { op: StoreOp, rs2: Reg, rs1: Reg, offset: i32 },
    OpImm { op: AluOp, rd: Reg, rs1: Reg, imm: i32 },
    Op { op: AluOp, rd: Reg, rs1: Reg, rs2: Reg },
    MulDiv { op: MulOp, rd: Reg, rs1: Reg, rs2: Reg },
    Csr { op: CsrOp, rd: Reg, rs1: Reg, csr: u16 },
    Ecall,
    Ebreak,
    Fence,
    /// Custom-0 SIMD MAC extension.
    Mac { op: MacOp, rd: Reg, rs1: Reg, rs2: Reg },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BranchOp {
    Beq,
    Bne,
    Blt,
    Bge,
    Bltu,
    Bgeu,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadOp {
    Lb,
    Lh,
    Lw,
    Lbu,
    Lhu,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreOp {
    Sb,
    Sh,
    Sw,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AluOp {
    Add,
    Sub,
    Sll,
    Slt,
    Sltu,
    Xor,
    Srl,
    Sra,
    Or,
    And,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MulOp {
    Mul,
    Mulh,
    Mulhsu,
    Mulhu,
    Div,
    Divu,
    Rem,
    Remu,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CsrOp {
    Csrrw,
    Csrrs,
    Csrrc,
}

impl Instr {
    /// Dense, stable per-mnemonic id (the profiler's histogram index —
    /// the retire hot path must not hash or compare strings).  Sits on
    /// the `FullProfile` retire path of `sim::zero_riscy`.
    #[inline]
    pub fn mnemonic_id(&self) -> usize {
        match *self {
            Instr::Lui { .. } => 0,
            Instr::Auipc { .. } => 1,
            Instr::Jal { .. } => 2,
            Instr::Jalr { .. } => 3,
            Instr::Branch { op, .. } => 4 + op as usize, // 4..=9
            Instr::Load { op, .. } => 10 + op as usize,  // 10..=14
            Instr::Store { op, .. } => 15 + op as usize, // 15..=17
            Instr::OpImm { op, .. } => 18 + op as usize, // 18..=27
            Instr::Op { op, .. } => 28 + op as usize,    // 28..=37
            Instr::MulDiv { op, .. } => 38 + op as usize, // 38..=45
            Instr::Csr { op, .. } => 46 + op as usize,   // 46..=48
            Instr::Ecall => 49,
            Instr::Ebreak => 50,
            Instr::Fence => 51,
            Instr::Mac { op, .. } => 52 + op as usize,   // 52..=54
        }
    }

    /// Stable mnemonic (profiling histograms key on this).
    #[inline]
    pub fn mnemonic(&self) -> &'static str {
        match self {
            Instr::Lui { .. } => "lui",
            Instr::Auipc { .. } => "auipc",
            Instr::Jal { .. } => "jal",
            Instr::Jalr { .. } => "jalr",
            Instr::Branch { op, .. } => match op {
                BranchOp::Beq => "beq",
                BranchOp::Bne => "bne",
                BranchOp::Blt => "blt",
                BranchOp::Bge => "bge",
                BranchOp::Bltu => "bltu",
                BranchOp::Bgeu => "bgeu",
            },
            Instr::Load { op, .. } => match op {
                LoadOp::Lb => "lb",
                LoadOp::Lh => "lh",
                LoadOp::Lw => "lw",
                LoadOp::Lbu => "lbu",
                LoadOp::Lhu => "lhu",
            },
            Instr::Store { op, .. } => match op {
                StoreOp::Sb => "sb",
                StoreOp::Sh => "sh",
                StoreOp::Sw => "sw",
            },
            Instr::OpImm { op, .. } => match op {
                AluOp::Add => "addi",
                AluOp::Sll => "slli",
                AluOp::Slt => "slti",
                AluOp::Sltu => "sltiu",
                AluOp::Xor => "xori",
                AluOp::Srl => "srli",
                AluOp::Sra => "srai",
                AluOp::Or => "ori",
                AluOp::And => "andi",
                AluOp::Sub => unreachable!("no subi"),
            },
            Instr::Op { op, .. } => match op {
                AluOp::Add => "add",
                AluOp::Sub => "sub",
                AluOp::Sll => "sll",
                AluOp::Slt => "slt",
                AluOp::Sltu => "sltu",
                AluOp::Xor => "xor",
                AluOp::Srl => "srl",
                AluOp::Sra => "sra",
                AluOp::Or => "or",
                AluOp::And => "and",
            },
            Instr::MulDiv { op, .. } => match op {
                MulOp::Mul => "mul",
                MulOp::Mulh => "mulh",
                MulOp::Mulhsu => "mulhsu",
                MulOp::Mulhu => "mulhu",
                MulOp::Div => "div",
                MulOp::Divu => "divu",
                MulOp::Rem => "rem",
                MulOp::Remu => "remu",
            },
            Instr::Csr { op, .. } => match op {
                CsrOp::Csrrw => "csrrw",
                CsrOp::Csrrs => "csrrs",
                CsrOp::Csrrc => "csrrc",
            },
            Instr::Ecall => "ecall",
            Instr::Ebreak => "ebreak",
            Instr::Fence => "fence",
            Instr::Mac { op, .. } => match op {
                MacOp::Mac => "mac",
                MacOp::MacRd => "macrd",
                MacOp::MacClr => "maccl",
            },
        }
    }

    /// Registers read by this instruction.
    pub fn reads(&self) -> Vec<Reg> {
        match *self {
            Instr::Lui { .. } | Instr::Auipc { .. } | Instr::Jal { .. } => vec![],
            Instr::Jalr { rs1, .. } => vec![rs1],
            Instr::Branch { rs1, rs2, .. } => vec![rs1, rs2],
            Instr::Load { rs1, .. } => vec![rs1],
            Instr::Store { rs1, rs2, .. } => vec![rs1, rs2],
            Instr::OpImm { rs1, .. } => vec![rs1],
            Instr::Op { rs1, rs2, .. } | Instr::MulDiv { rs1, rs2, .. } => vec![rs1, rs2],
            Instr::Csr { rs1, .. } => vec![rs1],
            Instr::Ecall | Instr::Ebreak | Instr::Fence => vec![],
            Instr::Mac { op, rs1, rs2, .. } => match op {
                MacOp::Mac => vec![rs1, rs2],
                _ => vec![],
            },
        }
    }

    /// Register written by this instruction (if any).
    pub fn writes(&self) -> Option<Reg> {
        match *self {
            Instr::Lui { rd, .. }
            | Instr::Auipc { rd, .. }
            | Instr::Jal { rd, .. }
            | Instr::Jalr { rd, .. }
            | Instr::Load { rd, .. }
            | Instr::OpImm { rd, .. }
            | Instr::Op { rd, .. }
            | Instr::MulDiv { rd, .. }
            | Instr::Csr { rd, .. } => (rd != 0).then_some(rd),
            Instr::Mac { op: MacOp::MacRd, rd, .. } => (rd != 0).then_some(rd),
            _ => None,
        }
    }
}

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

const OP_LUI: u32 = 0b0110111;
const OP_AUIPC: u32 = 0b0010111;
const OP_JAL: u32 = 0b1101111;
const OP_JALR: u32 = 0b1100111;
const OP_BRANCH: u32 = 0b1100011;
const OP_LOAD: u32 = 0b0000011;
const OP_STORE: u32 = 0b0100011;
const OP_IMM: u32 = 0b0010011;
const OP_OP: u32 = 0b0110011;
const OP_SYSTEM: u32 = 0b1110011;
const OP_FENCE: u32 = 0b0001111;
const OP_CUSTOM0: u32 = 0b0001011; // MAC extension

fn enc_r(op: u32, f3: u32, f7: u32, rd: u8, rs1: u8, rs2: u8) -> u32 {
    op | ((rd as u32) << 7)
        | (f3 << 12)
        | ((rs1 as u32) << 15)
        | ((rs2 as u32) << 20)
        | (f7 << 25)
}

fn enc_i(op: u32, f3: u32, rd: u8, rs1: u8, imm: i32) -> u32 {
    op | ((rd as u32) << 7) | (f3 << 12) | ((rs1 as u32) << 15) | (((imm as u32) & 0xfff) << 20)
}

fn enc_s(op: u32, f3: u32, rs1: u8, rs2: u8, imm: i32) -> u32 {
    let imm = imm as u32;
    op | ((imm & 0x1f) << 7)
        | (f3 << 12)
        | ((rs1 as u32) << 15)
        | ((rs2 as u32) << 20)
        | (((imm >> 5) & 0x7f) << 25)
}

fn enc_b(op: u32, f3: u32, rs1: u8, rs2: u8, off: i32) -> u32 {
    let o = off as u32;
    op | (((o >> 11) & 1) << 7)
        | (((o >> 1) & 0xf) << 8)
        | (f3 << 12)
        | ((rs1 as u32) << 15)
        | ((rs2 as u32) << 20)
        | (((o >> 5) & 0x3f) << 25)
        | (((o >> 12) & 1) << 31)
}

fn enc_j(op: u32, rd: u8, off: i32) -> u32 {
    let o = off as u32;
    op | ((rd as u32) << 7)
        | (((o >> 12) & 0xff) << 12)
        | (((o >> 11) & 1) << 20)
        | (((o >> 1) & 0x3ff) << 21)
        | (((o >> 20) & 1) << 31)
}

impl Instr {
    /// Encode to the 32-bit RISC-V instruction word.
    pub fn encode(&self) -> u32 {
        match *self {
            Instr::Lui { rd, imm } => OP_LUI | ((rd as u32) << 7) | ((imm as u32) & 0xfffff000),
            Instr::Auipc { rd, imm } => {
                OP_AUIPC | ((rd as u32) << 7) | ((imm as u32) & 0xfffff000)
            }
            Instr::Jal { rd, offset } => enc_j(OP_JAL, rd, offset),
            Instr::Jalr { rd, rs1, offset } => enc_i(OP_JALR, 0, rd, rs1, offset),
            Instr::Branch { op, rs1, rs2, offset } => {
                let f3 = match op {
                    BranchOp::Beq => 0,
                    BranchOp::Bne => 1,
                    BranchOp::Blt => 4,
                    BranchOp::Bge => 5,
                    BranchOp::Bltu => 6,
                    BranchOp::Bgeu => 7,
                };
                enc_b(OP_BRANCH, f3, rs1, rs2, offset)
            }
            Instr::Load { op, rd, rs1, offset } => {
                let f3 = match op {
                    LoadOp::Lb => 0,
                    LoadOp::Lh => 1,
                    LoadOp::Lw => 2,
                    LoadOp::Lbu => 4,
                    LoadOp::Lhu => 5,
                };
                enc_i(OP_LOAD, f3, rd, rs1, offset)
            }
            Instr::Store { op, rs2, rs1, offset } => {
                let f3 = match op {
                    StoreOp::Sb => 0,
                    StoreOp::Sh => 1,
                    StoreOp::Sw => 2,
                };
                enc_s(OP_STORE, f3, rs1, rs2, offset)
            }
            Instr::OpImm { op, rd, rs1, imm } => match op {
                AluOp::Add => enc_i(OP_IMM, 0, rd, rs1, imm),
                AluOp::Slt => enc_i(OP_IMM, 2, rd, rs1, imm),
                AluOp::Sltu => enc_i(OP_IMM, 3, rd, rs1, imm),
                AluOp::Xor => enc_i(OP_IMM, 4, rd, rs1, imm),
                AluOp::Or => enc_i(OP_IMM, 6, rd, rs1, imm),
                AluOp::And => enc_i(OP_IMM, 7, rd, rs1, imm),
                AluOp::Sll => enc_r(OP_IMM, 1, 0, rd, rs1, (imm & 31) as u8),
                AluOp::Srl => enc_r(OP_IMM, 5, 0, rd, rs1, (imm & 31) as u8),
                AluOp::Sra => enc_r(OP_IMM, 5, 0b0100000, rd, rs1, (imm & 31) as u8),
                AluOp::Sub => unreachable!("no subi"),
            },
            Instr::Op { op, rd, rs1, rs2 } => {
                let (f3, f7) = match op {
                    AluOp::Add => (0, 0),
                    AluOp::Sub => (0, 0b0100000),
                    AluOp::Sll => (1, 0),
                    AluOp::Slt => (2, 0),
                    AluOp::Sltu => (3, 0),
                    AluOp::Xor => (4, 0),
                    AluOp::Srl => (5, 0),
                    AluOp::Sra => (5, 0b0100000),
                    AluOp::Or => (6, 0),
                    AluOp::And => (7, 0),
                };
                enc_r(OP_OP, f3, f7, rd, rs1, rs2)
            }
            Instr::MulDiv { op, rd, rs1, rs2 } => {
                let f3 = match op {
                    MulOp::Mul => 0,
                    MulOp::Mulh => 1,
                    MulOp::Mulhsu => 2,
                    MulOp::Mulhu => 3,
                    MulOp::Div => 4,
                    MulOp::Divu => 5,
                    MulOp::Rem => 6,
                    MulOp::Remu => 7,
                };
                enc_r(OP_OP, f3, 1, rd, rs1, rs2)
            }
            Instr::Csr { op, rd, rs1, csr } => {
                let f3 = match op {
                    CsrOp::Csrrw => 1,
                    CsrOp::Csrrs => 2,
                    CsrOp::Csrrc => 3,
                };
                enc_i(OP_SYSTEM, f3, rd, rs1, csr as i32)
            }
            Instr::Ecall => OP_SYSTEM,
            Instr::Ebreak => OP_SYSTEM | (1 << 20),
            Instr::Fence => OP_FENCE,
            Instr::Mac { op, rd, rs1, rs2 } => {
                let f3 = match op {
                    MacOp::Mac => 0,
                    MacOp::MacRd => 1,
                    MacOp::MacClr => 2,
                };
                enc_r(OP_CUSTOM0, f3, 0, rd, rs1, rs2)
            }
        }
    }

    /// Decode a 32-bit instruction word.
    pub fn decode(w: u32) -> Result<Instr> {
        let op = w & 0x7f;
        let rd = ((w >> 7) & 0x1f) as u8;
        let f3 = (w >> 12) & 7;
        let rs1 = ((w >> 15) & 0x1f) as u8;
        let rs2 = ((w >> 20) & 0x1f) as u8;
        let f7 = w >> 25;
        let imm_i = (w as i32) >> 20;
        Ok(match op {
            OP_LUI => Instr::Lui { rd, imm: (w & 0xfffff000) as i32 },
            OP_AUIPC => Instr::Auipc { rd, imm: (w & 0xfffff000) as i32 },
            OP_JAL => {
                let off = (((w & 0x8000_0000) as i32 >> 11) as u32 & 0xfff0_0000)
                    | (w & 0x000f_f000)
                    | ((w >> 9) & 0x800)
                    | ((w >> 20) & 0x7fe);
                Instr::Jal { rd, offset: off as i32 }
            }
            OP_JALR => Instr::Jalr { rd, rs1, offset: imm_i },
            OP_BRANCH => {
                let off = (((w & 0x8000_0000) as i32 >> 19) as u32 & 0xffff_f000)
                    | ((w << 4) & 0x800)
                    | ((w >> 20) & 0x7e0)
                    | ((w >> 7) & 0x1e);
                let bop = match f3 {
                    0 => BranchOp::Beq,
                    1 => BranchOp::Bne,
                    4 => BranchOp::Blt,
                    5 => BranchOp::Bge,
                    6 => BranchOp::Bltu,
                    7 => BranchOp::Bgeu,
                    _ => bail!("bad branch funct3 {f3}"),
                };
                Instr::Branch { op: bop, rs1, rs2, offset: off as i32 }
            }
            OP_LOAD => {
                let lop = match f3 {
                    0 => LoadOp::Lb,
                    1 => LoadOp::Lh,
                    2 => LoadOp::Lw,
                    4 => LoadOp::Lbu,
                    5 => LoadOp::Lhu,
                    _ => bail!("bad load funct3 {f3}"),
                };
                Instr::Load { op: lop, rd, rs1, offset: imm_i }
            }
            OP_STORE => {
                let sop = match f3 {
                    0 => StoreOp::Sb,
                    1 => StoreOp::Sh,
                    2 => StoreOp::Sw,
                    _ => bail!("bad store funct3 {f3}"),
                };
                let off = ((imm_i >> 5) << 5) | (((w >> 7) & 0x1f) as i32);
                Instr::Store { op: sop, rs2, rs1, offset: off }
            }
            OP_IMM => {
                let aop = match f3 {
                    0 => AluOp::Add,
                    1 => AluOp::Sll,
                    2 => AluOp::Slt,
                    3 => AluOp::Sltu,
                    4 => AluOp::Xor,
                    5 => {
                        if f7 == 0b0100000 {
                            AluOp::Sra
                        } else {
                            AluOp::Srl
                        }
                    }
                    6 => AluOp::Or,
                    7 => AluOp::And,
                    _ => unreachable!(),
                };
                let imm = if matches!(aop, AluOp::Sll | AluOp::Srl | AluOp::Sra) {
                    rs2 as i32
                } else {
                    imm_i
                };
                Instr::OpImm { op: aop, rd, rs1, imm }
            }
            OP_OP if f7 == 1 => {
                let mop = match f3 {
                    0 => MulOp::Mul,
                    1 => MulOp::Mulh,
                    2 => MulOp::Mulhsu,
                    3 => MulOp::Mulhu,
                    4 => MulOp::Div,
                    5 => MulOp::Divu,
                    6 => MulOp::Rem,
                    _ => MulOp::Remu,
                };
                Instr::MulDiv { op: mop, rd, rs1, rs2 }
            }
            OP_OP => {
                let aop = match (f3, f7) {
                    (0, 0) => AluOp::Add,
                    (0, 0b0100000) => AluOp::Sub,
                    (1, _) => AluOp::Sll,
                    (2, _) => AluOp::Slt,
                    (3, _) => AluOp::Sltu,
                    (4, _) => AluOp::Xor,
                    (5, 0) => AluOp::Srl,
                    (5, _) => AluOp::Sra,
                    (6, _) => AluOp::Or,
                    (7, _) => AluOp::And,
                    _ => bail!("bad OP encoding {w:#010x}"),
                };
                Instr::Op { op: aop, rd, rs1, rs2 }
            }
            OP_SYSTEM => match f3 {
                0 if w == OP_SYSTEM => Instr::Ecall,
                0 if w == OP_SYSTEM | (1 << 20) => Instr::Ebreak,
                1 => Instr::Csr { op: CsrOp::Csrrw, rd, rs1, csr: (w >> 20) as u16 },
                2 => Instr::Csr { op: CsrOp::Csrrs, rd, rs1, csr: (w >> 20) as u16 },
                3 => Instr::Csr { op: CsrOp::Csrrc, rd, rs1, csr: (w >> 20) as u16 },
                _ => bail!("bad SYSTEM encoding {w:#010x}"),
            },
            OP_FENCE => Instr::Fence,
            OP_CUSTOM0 => {
                let mop = match f3 {
                    0 => MacOp::Mac,
                    1 => MacOp::MacRd,
                    2 => MacOp::MacClr,
                    _ => bail!("bad MAC funct3 {f3}"),
                };
                Instr::Mac { op: mop, rd, rs1, rs2 }
            }
            _ => bail!("unknown opcode {op:#09b} in {w:#010x}"),
        })
    }
}

impl std::fmt::Display for Instr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let m = self.mnemonic();
        match *self {
            Instr::Lui { rd, imm } => write!(f, "{m} x{rd}, {:#x}", (imm as u32) >> 12),
            Instr::Auipc { rd, imm } => write!(f, "{m} x{rd}, {:#x}", (imm as u32) >> 12),
            Instr::Jal { rd, offset } => write!(f, "{m} x{rd}, {offset}"),
            Instr::Jalr { rd, rs1, offset } => write!(f, "{m} x{rd}, {offset}(x{rs1})"),
            Instr::Branch { rs1, rs2, offset, .. } => write!(f, "{m} x{rs1}, x{rs2}, {offset}"),
            Instr::Load { rd, rs1, offset, .. } => write!(f, "{m} x{rd}, {offset}(x{rs1})"),
            Instr::Store { rs2, rs1, offset, .. } => write!(f, "{m} x{rs2}, {offset}(x{rs1})"),
            Instr::OpImm { rd, rs1, imm, .. } => write!(f, "{m} x{rd}, x{rs1}, {imm}"),
            Instr::Op { rd, rs1, rs2, .. } | Instr::MulDiv { rd, rs1, rs2, .. } => {
                write!(f, "{m} x{rd}, x{rs1}, x{rs2}")
            }
            Instr::Csr { rd, rs1, csr, .. } => write!(f, "{m} x{rd}, {csr:#x}, x{rs1}"),
            Instr::Ecall | Instr::Ebreak | Instr::Fence => write!(f, "{m}"),
            Instr::Mac { op, rd, rs1, rs2 } => match op {
                MacOp::Mac => write!(f, "{m} x{rs1}, x{rs2}"),
                MacOp::MacRd => write!(f, "{m} x{rd}, {rs1}"),
                MacOp::MacClr => write!(f, "{m}"),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn known_encodings() {
        // addi x1, x0, 5  => 0x00500093
        assert_eq!(
            Instr::OpImm { op: AluOp::Add, rd: 1, rs1: 0, imm: 5 }.encode(),
            0x0050_0093
        );
        // add x3, x1, x2 => 0x002081b3
        assert_eq!(Instr::Op { op: AluOp::Add, rd: 3, rs1: 1, rs2: 2 }.encode(), 0x0020_81b3);
        // mul x5, x6, x7 => 0x027302b3
        assert_eq!(
            Instr::MulDiv { op: MulOp::Mul, rd: 5, rs1: 6, rs2: 7 }.encode(),
            0x0273_02b3
        );
        // lw x10, 8(x2) => 0x00812503
        assert_eq!(
            Instr::Load { op: LoadOp::Lw, rd: 10, rs1: 2, offset: 8 }.encode(),
            0x0081_2503
        );
        // sw x10, 12(x2) => 0x00a12623
        assert_eq!(
            Instr::Store { op: StoreOp::Sw, rs2: 10, rs1: 2, offset: 12 }.encode(),
            0x00a1_2623
        );
        // beq x1, x2, +8 => 0x00208463
        assert_eq!(
            Instr::Branch { op: BranchOp::Beq, rs1: 1, rs2: 2, offset: 8 }.encode(),
            0x0020_8463
        );
        // jal x1, +16 => 0x010000ef
        assert_eq!(Instr::Jal { rd: 1, offset: 16 }.encode(), 0x0100_00ef);
    }

    fn random_instr(rng: &mut Pcg32) -> Instr {
        let r = |rng: &mut Pcg32| rng.range_usize(0, 31) as u8;
        let imm12 = |rng: &mut Pcg32| rng.range_i64(-2048, 2047) as i32;
        match rng.range_usize(0, 13) {
            0 => Instr::Lui { rd: r(rng), imm: (rng.range_i64(0, 0xfffff) as i32) << 12 },
            1 => Instr::Jal { rd: r(rng), offset: (rng.range_i64(-500_000, 500_000) as i32) & !1 },
            2 => Instr::Jalr { rd: r(rng), rs1: r(rng), offset: imm12(rng) },
            3 => {
                let op = *rng.choice(&[
                    BranchOp::Beq,
                    BranchOp::Bne,
                    BranchOp::Blt,
                    BranchOp::Bge,
                    BranchOp::Bltu,
                    BranchOp::Bgeu,
                ]);
                Instr::Branch {
                    op,
                    rs1: r(rng),
                    rs2: r(rng),
                    offset: (rng.range_i64(-4000, 4000) as i32) & !1,
                }
            }
            4 => {
                let op = *rng.choice(&[LoadOp::Lb, LoadOp::Lh, LoadOp::Lw, LoadOp::Lbu, LoadOp::Lhu]);
                Instr::Load { op, rd: r(rng), rs1: r(rng), offset: imm12(rng) }
            }
            5 => {
                let op = *rng.choice(&[StoreOp::Sb, StoreOp::Sh, StoreOp::Sw]);
                Instr::Store { op, rs2: r(rng), rs1: r(rng), offset: imm12(rng) }
            }
            6 => {
                let op = *rng.choice(&[
                    AluOp::Add,
                    AluOp::Slt,
                    AluOp::Sltu,
                    AluOp::Xor,
                    AluOp::Or,
                    AluOp::And,
                ]);
                Instr::OpImm { op, rd: r(rng), rs1: r(rng), imm: imm12(rng) }
            }
            7 => {
                let op = *rng.choice(&[AluOp::Sll, AluOp::Srl, AluOp::Sra]);
                Instr::OpImm { op, rd: r(rng), rs1: r(rng), imm: rng.range_i64(0, 31) as i32 }
            }
            8 => {
                let op = *rng.choice(&[
                    AluOp::Add,
                    AluOp::Sub,
                    AluOp::Sll,
                    AluOp::Slt,
                    AluOp::Sltu,
                    AluOp::Xor,
                    AluOp::Srl,
                    AluOp::Sra,
                    AluOp::Or,
                    AluOp::And,
                ]);
                Instr::Op { op, rd: r(rng), rs1: r(rng), rs2: r(rng) }
            }
            9 => {
                let op = *rng.choice(&[
                    MulOp::Mul,
                    MulOp::Mulh,
                    MulOp::Mulhsu,
                    MulOp::Mulhu,
                    MulOp::Div,
                    MulOp::Divu,
                    MulOp::Rem,
                    MulOp::Remu,
                ]);
                Instr::MulDiv { op, rd: r(rng), rs1: r(rng), rs2: r(rng) }
            }
            10 => {
                let op = *rng.choice(&[CsrOp::Csrrw, CsrOp::Csrrs, CsrOp::Csrrc]);
                Instr::Csr { op, rd: r(rng), rs1: r(rng), csr: rng.range_usize(0, 0xfff) as u16 }
            }
            11 => {
                let op = *rng.choice(&[MacOp::Mac, MacOp::MacRd, MacOp::MacClr]);
                Instr::Mac { op, rd: r(rng), rs1: r(rng), rs2: r(rng) }
            }
            12 => Instr::Auipc { rd: r(rng), imm: (rng.range_i64(0, 0xfffff) as i32) << 12 },
            _ => *rng.choice(&[Instr::Ecall, Instr::Ebreak, Instr::Fence]),
        }
    }

    /// Property: encode -> decode is the identity (modulo don't-care
    /// fields of MAC instructions, which we normalise in construction).
    #[test]
    fn prop_encode_decode_roundtrip() {
        crate::util::prop::check("rv32 encode/decode roundtrip", 2000, |rng| {
            let i = random_instr(rng);
            let w = i.encode();
            let d = Instr::decode(w).map_err(|e| e.to_string())?;
            // MAC don't-care fields: compare mnemonics + encode again.
            if d.encode() != w {
                return Err(format!("{i:?} -> {w:#010x} -> {d:?}"));
            }
            if d.mnemonic() != i.mnemonic() {
                return Err(format!("mnemonic {} != {}", d.mnemonic(), i.mnemonic()));
            }
            Ok(())
        });
    }

    /// Satellite: the round-trip property must exercise the *entire*
    /// mnemonic universe the profiler reasons over (`ALL_MNEMONICS`),
    /// not just a convenient subset — and on generator-normalised
    /// instructions the round trip is the strict identity.
    #[test]
    fn prop_roundtrip_covers_all_mnemonics() {
        use std::collections::BTreeSet;
        let mut seen: BTreeSet<&'static str> = BTreeSet::new();
        crate::util::prop::check("rv32 roundtrip coverage", 4000, |rng| {
            let i = random_instr(rng);
            let w = i.encode();
            let d = Instr::decode(w).map_err(|e| e.to_string())?;
            if d != i {
                return Err(format!("{i:?} -> {w:#010x} -> {d:?}"));
            }
            seen.insert(d.mnemonic());
            Ok(())
        });
        for m in crate::sim::zero_riscy::ALL_MNEMONICS {
            assert!(seen.contains(m), "mnemonic {m} never round-tripped");
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Instr::decode(0x0000_0000).is_err()); // opcode 0
        assert!(Instr::decode(0xffff_ffff).is_err());
    }

    #[test]
    fn reads_writes() {
        let i = Instr::Op { op: AluOp::Add, rd: 3, rs1: 1, rs2: 2 };
        assert_eq!(i.reads(), vec![1, 2]);
        assert_eq!(i.writes(), Some(3));
        // x0 writes are discarded.
        let i = Instr::OpImm { op: AluOp::Add, rd: 0, rs1: 0, imm: 0 };
        assert_eq!(i.writes(), None);
        let i = Instr::Mac { op: MacOp::Mac, rd: 0, rs1: 4, rs2: 5 };
        assert_eq!(i.reads(), vec![4, 5]);
        assert_eq!(i.writes(), None);
    }

    #[test]
    fn disassembly_smoke() {
        let i = Instr::Load { op: LoadOp::Lh, rd: 5, rs1: 2, offset: -4 };
        assert_eq!(i.to_string(), "lh x5, -4(x2)");
        let i = Instr::Mac { op: MacOp::Mac, rd: 0, rs1: 10, rs2: 11 };
        assert_eq!(i.to_string(), "mac x10, x11");
    }
}
