//! Instruction-set architectures.
//!
//! * [`rv32`] — the RV32IM subset Zero-Riscy executes, plus the paper's
//!   custom SIMD-MAC extension (custom-0 opcode space), with encoder,
//!   decoder, disassembler and a two-pass assembler.
//! * [`tpisa`] — the minimal width-configurable printed ISA (Bleier et
//!   al., ISCA'20 lineage): 16-bit instructions, 8 registers, carry/zero
//!   flags, no hardware multiply, optional MAC extension.

pub mod rv32;
pub mod rv32_asm;
pub mod tpisa;

/// The SIMD MAC extension operations, shared by both ISAs.  Semantics
/// live in `sim::mac_model`; encodings are ISA-specific.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MacOp {
    /// `mac rs1, rs2` — multiply-accumulate all lanes of the two packed
    /// operand words into the unit's per-lane accumulators.
    Mac,
    /// `macrd rd, lane` — read lane accumulator into a register (for
    /// p=32, lanes 0/1 read the low/high accumulator halves).
    MacRd,
    /// `maccl` — clear all lane accumulators.
    MacClr,
}
