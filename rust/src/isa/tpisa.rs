//! TP-ISA: the minimal, width-configurable printed ISA (after Bleier et
//! al., "Printed Microprocessors", ISCA'20 — the paper's TP-ISA is not
//! public, so this reconstruction follows its published description: a
//! minimal, highly configurable core with no hardware multiplier, where
//! multiplication is "scheduled to the ALU" as shift-add software).
//!
//! * 16-bit fixed instruction encoding: `[15:12 op][11:9 r1][8:6 r2][5:0 imm]`
//! * 8 registers of `d` bits (d ∈ {4, 8, 16, 32} — the datapath width)
//! * carry (C) and zero (Z) flags; multi-word arithmetic via ADC/SBC/SLC/SRC
//! * data memory of d-bit words, disjoint from instruction ROM
//! * optional SIMD MAC extension in the EXT opcode (`sim::mac_model`)
//!
//! Branch/jump offsets are 12-bit (r1:r2:imm6), relative instruction
//! counts; the EXT-space carry branches (BC/BNC) carry 6-bit offsets in
//! the register fields.

use anyhow::{bail, Result};

use super::MacOp;

pub type Reg = u8; // r0..r7

/// Decoded TP-ISA instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Instr {
    /// r1 = sign-extended imm6.
    Ldi { r1: Reg, imm: i8 },
    /// r1 += r2 (sets C, Z).
    Add { r1: Reg, r2: Reg },
    /// r1 += r2 + C (sets C, Z).
    Adc { r1: Reg, r2: Reg },
    /// r1 -= r2 (C = borrow, Z).
    Sub { r1: Reg, r2: Reg },
    /// r1 -= r2 + C (C = borrow, Z).
    Sbc { r1: Reg, r2: Reg },
    And { r1: Reg, r2: Reg },
    Or { r1: Reg, r2: Reg },
    Xor { r1: Reg, r2: Reg },
    /// Shift r1 left 1; C = bit out (sets Z).
    Shl { r1: Reg },
    /// Logical shift right 1; C = bit out (sets Z).
    Shr { r1: Reg },
    /// Arithmetic shift right 1; C = bit out (sets Z).
    Sra { r1: Reg },
    /// Shift left through carry: r1 = (r1 << 1) | C_in; C = bit out.
    Slc { r1: Reg },
    /// Shift right through carry: r1 = (C_in << (d-1)) | (r1 >> 1).
    Src { r1: Reg },
    /// r1 = mem[r2 + imm6] (imm6 is UNSIGNED 0..63 — memory offsets
    /// only ever reach forward, and narrow datapaths need the reach).
    Ld { r1: Reg, r2: Reg, imm: i8 },
    /// mem[r2 + imm6] = r1 (unsigned imm6).
    St { r1: Reg, r2: Reg, imm: i8 },
    /// r1 += sign-extended imm6 (sets Z; C unchanged).
    Addi { r1: Reg, imm: i8 },
    /// r1 = r2.
    Mov { r1: Reg, r2: Reg },
    /// r1 = sign-fill of r2 (all-ones if r2's MSB set, else 0).
    Sxt { r1: Reg, r2: Reg },
    /// Clear carry.
    Clc,
    /// Relative branches (instruction-count offsets).
    Bz { off: i16 },
    Bnz { off: i16 },
    Bc { off: i8 },
    Bnc { off: i8 },
    Jmp { off: i16 },
    /// SIMD MAC extension.
    Mac { op: MacOp, r1: Reg, r2: Reg },
    Halt,
}

impl Instr {
    /// Dense per-mnemonic id (profiler histogram index; see
    /// `rv32::Instr::mnemonic_id`).  Sits on the `FullProfile` retire
    /// path of `sim::tpisa`.
    #[inline]
    pub fn mnemonic_id(&self) -> usize {
        match self {
            Instr::Ldi { .. } => 0,
            Instr::Add { .. } => 1,
            Instr::Adc { .. } => 2,
            Instr::Sub { .. } => 3,
            Instr::Sbc { .. } => 4,
            Instr::And { .. } => 5,
            Instr::Or { .. } => 6,
            Instr::Xor { .. } => 7,
            Instr::Shl { .. } => 8,
            Instr::Shr { .. } => 9,
            Instr::Sra { .. } => 10,
            Instr::Slc { .. } => 11,
            Instr::Src { .. } => 12,
            Instr::Ld { .. } => 13,
            Instr::St { .. } => 14,
            Instr::Addi { .. } => 15,
            Instr::Mov { .. } => 16,
            Instr::Sxt { .. } => 17,
            Instr::Clc => 18,
            Instr::Bz { .. } => 19,
            Instr::Bnz { .. } => 20,
            Instr::Bc { .. } => 21,
            Instr::Bnc { .. } => 22,
            Instr::Jmp { .. } => 23,
            Instr::Mac { op, .. } => 24 + *op as usize, // 24..=26
            Instr::Halt => 27,
        }
    }

    #[inline]
    pub fn mnemonic(&self) -> &'static str {
        match self {
            Instr::Ldi { .. } => "ldi",
            Instr::Add { .. } => "add",
            Instr::Adc { .. } => "adc",
            Instr::Sub { .. } => "sub",
            Instr::Sbc { .. } => "sbc",
            Instr::And { .. } => "and",
            Instr::Or { .. } => "or",
            Instr::Xor { .. } => "xor",
            Instr::Shl { .. } => "shl",
            Instr::Shr { .. } => "shr",
            Instr::Sra { .. } => "sra",
            Instr::Slc { .. } => "slc",
            Instr::Src { .. } => "src",
            Instr::Ld { .. } => "ld",
            Instr::St { .. } => "st",
            Instr::Addi { .. } => "addi",
            Instr::Mov { .. } => "mov",
            Instr::Sxt { .. } => "sxt",
            Instr::Clc => "clc",
            Instr::Bz { .. } => "bz",
            Instr::Bnz { .. } => "bnz",
            Instr::Bc { .. } => "bc",
            Instr::Bnc { .. } => "bnc",
            Instr::Jmp { .. } => "jmp",
            Instr::Mac { op, .. } => match op {
                MacOp::Mac => "mac",
                MacOp::MacRd => "macrd",
                MacOp::MacClr => "maccl",
            },
            Instr::Halt => "halt",
        }
    }
}

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

fn fields(r1: u8, r2: u8, imm: i8) -> u16 {
    ((r1 as u16 & 7) << 9) | ((r2 as u16 & 7) << 6) | (imm as u16 & 0x3f)
}

fn off12(off: i16) -> u16 {
    (off as u16) & 0xfff
}

const EXT_HALT: i8 = 0;
const EXT_MAC: i8 = 1;
const EXT_MACRD: i8 = 2;
const EXT_MACCL: i8 = 3;
const EXT_SXT: i8 = 8;
const EXT_SBC: i8 = 9;
const EXT_CLC: i8 = 10;
// EXT carry branches put the 6-bit offset in the register fields.
const EXT_BC: i8 = 6;
const EXT_BNC: i8 = 7;

impl Instr {
    pub fn encode(&self) -> u16 {
        match *self {
            Instr::Ldi { r1, imm } => 0x0000 | fields(r1, 0, imm),
            Instr::Add { r1, r2 } => 0x1000 | fields(r1, r2, 0),
            Instr::Adc { r1, r2 } => 0x2000 | fields(r1, r2, 0),
            Instr::Sub { r1, r2 } => 0x3000 | fields(r1, r2, 0),
            Instr::And { r1, r2 } => 0x4000 | fields(r1, r2, 0),
            Instr::Or { r1, r2 } => 0x5000 | fields(r1, r2, 0),
            Instr::Xor { r1, r2 } => 0x6000 | fields(r1, r2, 0),
            Instr::Shl { r1 } => 0x7000 | fields(r1, 0, 0),
            Instr::Shr { r1 } => 0x7000 | fields(r1, 0, 1),
            Instr::Sra { r1 } => 0x7000 | fields(r1, 0, 2),
            Instr::Slc { r1 } => 0x7000 | fields(r1, 0, 3),
            Instr::Src { r1 } => 0x7000 | fields(r1, 0, 4),
            Instr::Ld { r1, r2, imm } => 0x8000 | fields(r1, r2, imm),
            Instr::St { r1, r2, imm } => 0x9000 | fields(r1, r2, imm),
            Instr::Addi { r1, imm } => 0xa000 | fields(r1, 0, imm),
            Instr::Bz { off } => 0xb000 | off12(off),
            Instr::Bnz { off } => 0xc000 | off12(off),
            Instr::Jmp { off } => 0xd000 | off12(off),
            Instr::Mov { r1, r2 } => 0xe000 | fields(r1, r2, 0),
            Instr::Halt => 0xf000 | fields(0, 0, EXT_HALT),
            Instr::Mac { op, r1, r2 } => {
                let f = match op {
                    MacOp::Mac => EXT_MAC,
                    MacOp::MacRd => EXT_MACRD,
                    MacOp::MacClr => EXT_MACCL,
                };
                0xf000 | fields(r1, r2, f)
            }
            Instr::Sxt { r1, r2 } => 0xf000 | fields(r1, r2, EXT_SXT),
            Instr::Sbc { r1, r2 } => 0xf000 | fields(r1, r2, EXT_SBC),
            Instr::Clc => 0xf000 | fields(0, 0, EXT_CLC),
            Instr::Bc { off } => 0xf000 | fields(((off as u8) >> 3) & 7, (off as u8) & 7, EXT_BC),
            Instr::Bnc { off } => {
                0xf000 | fields(((off as u8) >> 3) & 7, (off as u8) & 7, EXT_BNC)
            }
        }
    }

    pub fn decode(w: u16) -> Result<Instr> {
        let op = w >> 12;
        let r1 = ((w >> 9) & 7) as u8;
        let r2 = ((w >> 6) & 7) as u8;
        let imm = ((w & 0x3f) as i8) << 2 >> 2; // sign-extend 6 bits
        let uimm = (w & 0x3f) as i8; // unsigned 6 bits (LD/ST offsets)
        let off = ((w & 0xfff) as i16) << 4 >> 4; // sign-extend 12 bits
        Ok(match op {
            0x0 => Instr::Ldi { r1, imm },
            0x1 => Instr::Add { r1, r2 },
            0x2 => Instr::Adc { r1, r2 },
            0x3 => Instr::Sub { r1, r2 },
            0x4 => Instr::And { r1, r2 },
            0x5 => Instr::Or { r1, r2 },
            0x6 => Instr::Xor { r1, r2 },
            0x7 => match w & 0x3f {
                0 => Instr::Shl { r1 },
                1 => Instr::Shr { r1 },
                2 => Instr::Sra { r1 },
                3 => Instr::Slc { r1 },
                4 => Instr::Src { r1 },
                f => bail!("bad shift funct {f}"),
            },
            0x8 => Instr::Ld { r1, r2, imm: uimm },
            0x9 => Instr::St { r1, r2, imm: uimm },
            0xa => Instr::Addi { r1, imm },
            0xb => Instr::Bz { off },
            0xc => Instr::Bnz { off },
            0xd => Instr::Jmp { off },
            0xe => Instr::Mov { r1, r2 },
            0xf => {
                let f = (w & 0x3f) as i8;
                let off6 = (((r1 << 3) | r2) as i8) << 2 >> 2;
                match f {
                    EXT_HALT => Instr::Halt,
                    EXT_MAC => Instr::Mac { op: MacOp::Mac, r1, r2 },
                    EXT_MACRD => Instr::Mac { op: MacOp::MacRd, r1, r2 },
                    EXT_MACCL => Instr::Mac { op: MacOp::MacClr, r1, r2 },
                    EXT_BC => Instr::Bc { off: off6 },
                    EXT_BNC => Instr::Bnc { off: off6 },
                    EXT_SXT => Instr::Sxt { r1, r2 },
                    EXT_SBC => Instr::Sbc { r1, r2 },
                    EXT_CLC => Instr::Clc,
                    _ => bail!("bad EXT funct {f}"),
                }
            }
            _ => unreachable!(),
        })
    }
}

// ---------------------------------------------------------------------------
// Builder assembler (labels + fixups), mirroring rv32_asm::Asm
// ---------------------------------------------------------------------------

#[derive(Debug, Default)]
pub struct Asm {
    instrs: Vec<Instr>,
    labels: std::collections::BTreeMap<String, usize>,
    fixups: Vec<(usize, String)>,
}

impl Asm {
    pub fn new() -> Asm {
        Asm::default()
    }

    pub fn here(&self) -> usize {
        self.instrs.len()
    }

    pub fn label(&mut self, name: &str) {
        assert!(
            self.labels.insert(name.to_string(), self.instrs.len()).is_none(),
            "duplicate label {name}"
        );
    }

    pub fn push(&mut self, i: Instr) -> &mut Self {
        self.instrs.push(i);
        self
    }

    pub fn ldi(&mut self, r1: Reg, imm: i8) -> &mut Self {
        assert!((-32..=31).contains(&imm), "ldi imm {imm} out of 6-bit range");
        self.push(Instr::Ldi { r1, imm })
    }

    /// Load an arbitrary d-bit constant by LDI + shift/or chunks.
    pub fn ldc(&mut self, r1: Reg, value: i64, datapath: u32) -> &mut Self {
        let masked = (value as u64) & ((1u64 << datapath) - 1).max(1);
        // Fast path: fits a signed 6-bit immediate.
        let sext = ((value << (64 - datapath as i64)) >> (64 - datapath as i64)) as i64;
        if (-32..=31).contains(&sext) {
            return self.ldi(r1, sext as i8);
        }
        // Build from 5-bit unsigned chunks, MSB first: r1 = chunk;
        // then repeatedly r1 = (r1 << 5) | next (via shifts + ADDI).
        let chunks: Vec<u8> = (0..datapath.div_ceil(5))
            .rev()
            .map(|i| ((masked >> (5 * i)) & 0x1f) as u8)
            .collect();
        let mut started = false;
        for &c in &chunks {
            if !started {
                if c == 0 {
                    continue;
                }
                self.ldi(r1, c as i8);
                started = true;
            } else {
                for _ in 0..5 {
                    self.push(Instr::Shl { r1 });
                }
                if c != 0 {
                    self.push(Instr::Addi { r1, imm: c as i8 });
                }
            }
        }
        if !started {
            self.ldi(r1, 0);
        }
        self
    }

    pub fn bz(&mut self, label: &str) -> &mut Self {
        self.fixups.push((self.instrs.len(), label.to_string()));
        self.push(Instr::Bz { off: 0 })
    }

    pub fn bnz(&mut self, label: &str) -> &mut Self {
        self.fixups.push((self.instrs.len(), label.to_string()));
        self.push(Instr::Bnz { off: 0 })
    }

    pub fn bc(&mut self, label: &str) -> &mut Self {
        self.fixups.push((self.instrs.len(), label.to_string()));
        self.push(Instr::Bc { off: 0 })
    }

    pub fn bnc(&mut self, label: &str) -> &mut Self {
        self.fixups.push((self.instrs.len(), label.to_string()));
        self.push(Instr::Bnc { off: 0 })
    }

    pub fn jmp(&mut self, label: &str) -> &mut Self {
        self.fixups.push((self.instrs.len(), label.to_string()));
        self.push(Instr::Jmp { off: 0 })
    }

    pub fn finish(mut self) -> Result<Vec<Instr>> {
        for (idx, label) in &self.fixups {
            let target = *self
                .labels
                .get(label)
                .ok_or_else(|| anyhow::anyhow!("undefined label {label:?}"))?;
            let off = target as i64 - *idx as i64;
            match &mut self.instrs[*idx] {
                Instr::Bz { off: o } | Instr::Bnz { off: o } | Instr::Jmp { off: o } => {
                    if !(-2048..=2047).contains(&off) {
                        bail!("branch to {label:?} out of 12-bit range ({off})");
                    }
                    *o = off as i16;
                }
                Instr::Bc { off: o } | Instr::Bnc { off: o } => {
                    if !(-32..=31).contains(&off) {
                        bail!("carry branch to {label:?} out of 6-bit range ({off})");
                    }
                    *o = off as i8;
                }
                other => bail!("fixup on non-branch {other:?}"),
            }
        }
        Ok(self.instrs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn random_instr(rng: &mut Pcg32) -> Instr {
        let r = |rng: &mut Pcg32| rng.range_usize(0, 7) as u8;
        let imm6 = |rng: &mut Pcg32| rng.range_i64(-32, 31) as i8;
        match rng.range_usize(0, 21) {
            0 => Instr::Ldi { r1: r(rng), imm: imm6(rng) },
            1 => Instr::Add { r1: r(rng), r2: r(rng) },
            2 => Instr::Adc { r1: r(rng), r2: r(rng) },
            3 => Instr::Sub { r1: r(rng), r2: r(rng) },
            4 => Instr::Sbc { r1: r(rng), r2: r(rng) },
            5 => Instr::And { r1: r(rng), r2: r(rng) },
            6 => Instr::Or { r1: r(rng), r2: r(rng) },
            7 => Instr::Xor { r1: r(rng), r2: r(rng) },
            8 => Instr::Shl { r1: r(rng) },
            9 => Instr::Shr { r1: r(rng) },
            10 => Instr::Sra { r1: r(rng) },
            11 => Instr::Slc { r1: r(rng) },
            12 => Instr::Src { r1: r(rng) },
            13 => Instr::Ld { r1: r(rng), r2: r(rng), imm: rng.range_i64(0, 63) as i8 },
            14 => Instr::St { r1: r(rng), r2: r(rng), imm: rng.range_i64(0, 63) as i8 },
            15 => Instr::Addi { r1: r(rng), imm: imm6(rng) },
            16 => Instr::Mov { r1: r(rng), r2: r(rng) },
            17 => Instr::Bz { off: rng.range_i64(-2048, 2047) as i16 },
            18 => Instr::Bnz { off: rng.range_i64(-2048, 2047) as i16 },
            19 => Instr::Jmp { off: rng.range_i64(-2048, 2047) as i16 },
            _ => *rng.choice(&[
                Instr::Halt,
                Instr::Clc,
                Instr::Bc { off: -5 },
                Instr::Bnc { off: 31 },
                Instr::Sxt { r1: 1, r2: 2 },
                Instr::Mac { op: crate::isa::MacOp::Mac, r1: 3, r2: 4 },
                Instr::Mac { op: crate::isa::MacOp::MacRd, r1: 5, r2: 1 },
                Instr::Mac { op: crate::isa::MacOp::MacClr, r1: 0, r2: 0 },
            ]),
        }
    }

    #[test]
    fn prop_encode_decode_roundtrip() {
        crate::util::prop::check("tpisa encode/decode roundtrip", 3000, |rng| {
            let mut i = random_instr(rng);
            // Normalise don't-care fields the decoder zeroes.
            if let Instr::Mac { op: crate::isa::MacOp::MacClr, r1, r2 } = &mut i {
                *r1 = 0;
                *r2 = 0;
            }
            let w = i.encode();
            let d = Instr::decode(w).map_err(|e| e.to_string())?;
            if d != i {
                return Err(format!("{i:?} -> {w:#06x} -> {d:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn branch_offsets_sign_extend() {
        let i = Instr::Bz { off: -100 };
        assert_eq!(Instr::decode(i.encode()).unwrap(), i);
        let i = Instr::Bc { off: -32 };
        assert_eq!(Instr::decode(i.encode()).unwrap(), i);
    }

    #[test]
    fn builder_resolves_labels() {
        let mut a = Asm::new();
        a.ldi(1, 3);
        a.label("loop");
        a.push(Instr::Addi { r1: 1, imm: -1 });
        a.bnz("loop");
        a.push(Instr::Halt);
        let prog = a.finish().unwrap();
        assert_eq!(prog[2], Instr::Bnz { off: -1 });
    }

    #[test]
    fn ldc_builds_wide_constants() {
        // Symbolically evaluate the emitted sequence.
        fn eval(instrs: &[Instr], datapath: u32) -> u64 {
            let mask = (1u64 << datapath) - 1;
            let mut r = [0u64; 8];
            for i in instrs {
                match *i {
                    Instr::Ldi { r1, imm } => r[r1 as usize] = (imm as i64 as u64) & mask,
                    Instr::Shl { r1 } => r[r1 as usize] = (r[r1 as usize] << 1) & mask,
                    Instr::Addi { r1, imm } => {
                        r[r1 as usize] = r[r1 as usize].wrapping_add(imm as i64 as u64) & mask
                    }
                    ref other => panic!("unexpected {other:?}"),
                }
            }
            r[2]
        }
        for (val, d) in [(1000i64, 16u32), (-7, 16), (255, 8), (0x7fff, 16), (0, 32), (12345678, 32)]
        {
            let mut a = Asm::new();
            a.ldc(2, val, d);
            let prog = a.finish().unwrap();
            let want = (val as u64) & ((1u64 << d) - 1);
            assert_eq!(eval(&prog, d), want, "val {val} d {d}");
        }
    }

    #[test]
    fn carry_branch_range_enforced() {
        let mut a = Asm::new();
        a.bc("far");
        for _ in 0..40 {
            a.push(Instr::Halt);
        }
        a.label("far");
        assert!(a.finish().is_err());
    }
}
