//! Report emitters: regenerate every table and figure of the paper's
//! evaluation as formatted text (and structured values for the bench
//! harness).  One function per artifact:
//!
//! * [`fig1`]   — baseline area/power/clock + ZR unit breakdown
//! * [`table1`] — bespoke Zero-Riscy gains/speedup/accuracy
//! * [`fig4`]   — accuracy loss per model per precision
//! * [`fig5`]   — TP-ISA scatter + Pareto front
//! * [`table2`] — the TP-ISA 8-bit MAC Pareto solution
//! * [`mem`]    — §IV-B printed-memory observations

use anyhow::{Context, Result};

use super::context::EvalContext;
use super::pareto::pareto_flags;
use super::sweep::{self, TpPoint, ZrRow};
use crate::hw::synth::{synthesize, tpisa, zero_riscy, SynthReport, UnitKind};

fn fmt_pct(v: f64) -> String {
    format!("{v:6.2}%")
}

/// Fig. 1a/1b: baseline synthesis of Zero-Riscy and both TP-ISA widths
/// in EGFET, plus the ZR functional-unit breakdown.
pub struct Fig1 {
    pub zr: SynthReport,
    pub tp4: SynthReport,
    pub tp32: SynthReport,
    pub text: String,
}

pub fn fig1(ctx: &EvalContext) -> Fig1 {
    let zr = synthesize(&zero_riscy(), &ctx.tech);
    let tp4 = synthesize(&tpisa(4), &ctx.tech);
    let tp32 = synthesize(&tpisa(32), &ctx.tech);
    let mut text = String::from(
        "Fig 1a — Baseline area / power / clock (EGFET)\n\
         core          area [cm^2]   power [mW]   clock [Hz]\n",
    );
    for r in [&zr, &tp4, &tp32] {
        text += &format!(
            "{:<12}  {:>10.2}   {:>9.2}   {:>9.1}\n",
            r.name,
            r.area_cm2(),
            r.power_mw,
            r.fmax_hz
        );
    }
    text += "\nFig 1b — Zero-Riscy unit shares (area% / power%)\n";
    let groups: [(&str, &[UnitKind]); 4] = [
        ("EX", &[UnitKind::Alu]),
        ("MUL", &[UnitKind::Mul]),
        ("RF", &[UnitKind::RegFile]),
        ("IF/ID/Ctl", &[UnitKind::IfStage, UnitKind::Decoder, UnitKind::Controller]),
    ];
    for (name, kinds) in groups {
        text += &format!(
            "{:<10} {} / {}\n",
            name,
            fmt_pct(zr.area_fraction(kinds) * 100.0),
            fmt_pct(zr.power_fraction(kinds) * 100.0)
        );
    }
    text += &format!(
        "MUL+RF     {} / {}   (paper: 46.5% / 46.2%)\n",
        fmt_pct(zr.area_fraction(&[UnitKind::Mul, UnitKind::RegFile]) * 100.0),
        fmt_pct(zr.power_fraction(&[UnitKind::Mul, UnitKind::RegFile]) * 100.0)
    );
    Fig1 { zr, tp4, tp32, text }
}

/// Table I: bespoke Zero-Riscy rows.
pub struct Table1 {
    pub rows: Vec<ZrRow>,
    pub text: String,
}

pub fn table1(ctx: &EvalContext) -> Result<Table1> {
    let (_u, rows) = sweep::zr_table1(ctx)?;
    let mut text = String::from(
        "Table I — Bespoke Zero-Riscy (gains vs baseline)\n\
         config         area-gain  power-gain  speedup   acc-loss\n",
    );
    for r in rows.iter().skip(1) {
        text += &format!(
            "{:<13} {}  {}  {}  {}\n",
            r.name,
            fmt_pct(r.area_gain_pct),
            fmt_pct(r.power_gain_pct),
            fmt_pct(r.speedup_pct),
            fmt_pct(r.acc_loss_pct)
        );
    }
    text += "paper:        B 10.6/11.4/0/0; MAC32 8.2/14.4/23.9/0; \
             P16 22.2/23.6/33.8/0; P8 29.3/28.7/41.7/0.5; P4 36.5/34.1/46.4/15.7\n";
    Ok(Table1 { rows, text })
}

/// Fig. 4: average accuracy loss per model per precision.
pub struct Fig4 {
    /// (model name, [loss% at 32, 16, 8, 4])
    pub losses: Vec<(String, Vec<f64>)>,
    pub text: String,
}

pub fn fig4(ctx: &EvalContext) -> Fig4 {
    let precisions = [32u32, 16, 8, 4];
    let mut losses = Vec::new();
    let mut text = String::from(
        "Fig 4 — Accuracy loss per model per precision (percentage points)\n\
         model               p32      p16      p8       p4\n",
    );
    for (i, e) in ctx.manifest.models.iter().enumerate() {
        let row: Vec<f64> = precisions.iter().map(|&p| ctx.accuracy_loss_pct(i, p)).collect();
        text += &format!(
            "{:<18} {:>7.2}  {:>7.2}  {:>7.2}  {:>7.2}\n",
            e.name, row[0], row[1], row[2], row[3]
        );
        losses.push((e.name.clone(), row));
    }
    text += "(paper: no loss at 32/16, ~0.5% avg at 8, jump at 4 — up to 26% on RedWine)\n";
    Fig4 { losses, text }
}

/// Fig. 5: the TP-ISA scatter with Pareto flags.
pub struct Fig5 {
    pub points: Vec<TpPoint>,
    pub pareto: Vec<bool>,
    pub text: String,
}

pub fn fig5(ctx: &EvalContext) -> Result<Fig5> {
    let points = sweep::tpisa_sweep(ctx)?;
    let xy: Vec<(f64, f64)> = points.iter().map(|p| (p.area_mm2, p.speedup_pct)).collect();
    let pareto = pareto_flags(&xy);
    let mut order: Vec<usize> = (0..points.len()).collect();
    order.sort_by(|&a, &b| points[a].area_mm2.partial_cmp(&points[b].area_mm2).unwrap());
    let mut text = String::from(
        "Fig 5 — TP-ISA configurations (area vs speedup; * = Pareto)\n\
         config      area [mm^2]  power [mW]  speedup    err\n",
    );
    for &i in &order {
        let p = &points[i];
        text += &format!(
            "{}{:<10} {:>10.1}  {:>9.2}  {}  {}\n",
            if pareto[i] { "*" } else { " " },
            p.label,
            p.area_mm2,
            p.power_mw,
            fmt_pct(p.speedup_pct),
            fmt_pct(p.err_pct)
        );
    }
    Ok(Fig5 { points, pareto, text })
}

/// Table II: the 8-bit TP-ISA MAC Pareto solution vs its baseline.
pub struct Table2 {
    pub area_factor: f64,
    pub power_factor: f64,
    pub speedup_pct: f64,
    pub err_pct: f64,
    pub text: String,
}

pub fn table2(ctx: &EvalContext) -> Result<Table2> {
    let points = sweep::tpisa_sweep(ctx)?;
    let base = points.iter().find(|p| p.label == "d8").context("d8 baseline")?;
    let mac = points.iter().find(|p| p.label == "d8m").context("d8m point")?;
    let t = Table2 {
        area_factor: mac.area_mm2 / base.area_mm2,
        power_factor: mac.power_mw / base.power_mw,
        speedup_pct: mac.speedup_pct,
        err_pct: mac.err_pct,
        text: String::new(),
    };
    let text = format!(
        "Table II — Bespoke 8-bit TP-ISA MAC (vs 8-bit baseline)\n\
         area overhead   x{:.2}   (paper: x1.98)\n\
         power overhead  x{:.2}   (paper: x1.82)\n\
         avg err         {:.2}%  (paper: 0.5%)\n\
         est. speedup    {:.1}%  (paper: up to 85.1%)\n",
        t.area_factor, t.power_factor, t.err_pct, t.speedup_pct
    );
    Ok(Table2 { text, ..t })
}

/// §IV-B: printed-memory observations.
pub struct MemReport {
    /// ROM cells per ZR variant (avg across models).
    pub zr_rom: Vec<(String, f64)>,
    /// (label, rom cells) per TP-ISA point.
    pub tp_rom: Vec<(String, f64)>,
    /// Memory saved by hardware multiply (TP-ISA d8: baseline -> MAC).
    pub mul_saving_pct: f64,
    /// Additional saving from SIMD (ZR MAC32 -> P16 code size).
    pub simd_saving_pct: f64,
    pub text: String,
}

pub fn mem(ctx: &EvalContext) -> Result<MemReport> {
    let (_u, rows) = sweep::zr_table1(ctx)?;
    let zr_rom: Vec<(String, f64)> =
        rows.iter().map(|r| (r.name.clone(), r.rom_cells_avg)).collect();
    let points = sweep::tpisa_sweep(ctx)?;
    let tp_rom: Vec<(String, f64)> =
        points.iter().map(|p| (p.label.clone(), p.rom_cells_avg)).collect();

    let d8 = points.iter().find(|p| p.label == "d8").context("d8")?;
    let d8m = points.iter().find(|p| p.label == "d8m").context("d8m")?;
    let mul_saving_pct = (1.0 - d8m.rom_cells_avg / d8.rom_cells_avg) * 100.0;

    // "Up to 1-2%": the saving materialises where whole neurons fit a
    // single pass (few packed words per column) — report the best SIMD
    // configuration, as the paper does.
    let mac32 = rows.iter().find(|r| r.name == "ZR B MAC 32").context("mac32")?;
    let simd_saving_pct = rows
        .iter()
        .filter(|r| r.name.contains("MAC P"))
        .map(|r| (1.0 - r.rom_cells_avg / mac32.rom_cells_avg) * 100.0)
        .fold(f64::NEG_INFINITY, f64::max);

    let mut text = String::from("§IV-B — Printed memory observations\n");
    text += &format!(
        "(b) hardware multiply vs ALU-scheduled: {:.1}% ROM saved (paper: up to 11.1%)\n",
        mul_saving_pct
    );
    text += &format!(
        "(c) SIMD loop removal: {:.1}% additional ROM saved (paper: 1-2%)\n",
        simd_saving_pct
    );
    text += "(a) ROM cells by TP-ISA width (narrower widths -> fewer cells):\n";
    for (label, cells) in &tp_rom {
        text += &format!("    {label:<10} {cells:>8.0} cells\n");
    }
    text += "ZR variants (avg cells):\n";
    for (name, cells) in &zr_rom {
        text += &format!("    {name:<14} {cells:>8.0} cells\n");
    }
    Ok(MemReport { zr_rom, tp_rom, mul_saving_pct, simd_saving_pct, text })
}
