//! Configuration sweeps: the Table I Zero-Riscy variants and the Fig. 5
//! TP-ISA design space.
//!
//! Both sweeps shard their per-model ISS runs across the context's
//! thread pool ([`EvalContext::pool`]).  Results are gathered in model
//! order and aggregated by folding in that order, so every number (and
//! every report byte) is identical at any thread count — see
//! `tests/parallel_determinism.rs`.  Programs come from the context's
//! (model, variant) cache, so codegen runs once per sweep even though
//! several reports share the same configurations.
//!
//! The cycle sweeps only consume `cycles_per_sample` and ROM cells, so
//! they run the ISS in [`CyclesOnly`] mode (no per-retire profiling
//! work; bit-identical cycles — see `tests/iss_equivalence.rs`).  The
//! utilization profile that feeds the bespoke reduction still comes
//! from `bespoke::profile`'s `FullProfile` runs.  Both modes execute on
//! the block-translated engine (`sim::translate` + `run_translated`
//! via `ml::harness`), so every sweep row dispatches per basic block
//! with fused superinstructions instead of per instruction — and since
//! §Perf iteration 5 each shard runs as a lane batch (`sim::batch`), so
//! a row's samples share one block fetch per dispatch.  The numbers are
//! unchanged: per-sample cycles are pinned bit-identical to the scalar
//! engine by `tests/iss_batch_equivalence.rs`.

use anyhow::Result;

use super::context::EvalContext;
use crate::bespoke::profile::{profile_all_on, Utilization};
use crate::bespoke::reduction::table1_variants;
use crate::hw::synth::{synthesize, zero_riscy, MulOption, SynthReport};
use crate::ml::codegen_rv32::Rv32Variant;
use crate::ml::codegen_tpisa::{self, TpVariant};
use crate::ml::harness;
use crate::sim::trace::CyclesOnly;
use crate::util::stats;

/// One Table-I row.
#[derive(Debug, Clone)]
pub struct ZrRow {
    pub name: String,
    pub area_mm2: f64,
    pub power_mw: f64,
    pub area_gain_pct: f64,
    pub power_gain_pct: f64,
    pub speedup_pct: f64,
    pub acc_loss_pct: f64,
    pub rom_cells_avg: f64,
}

/// The variant each Table-I row runs on the ISS.
fn row_variant(name: &str) -> Rv32Variant {
    match name {
        "ZR B" => Rv32Variant::Baseline,
        "ZR B MAC 32" => Rv32Variant::Mac32,
        "ZR B MAC P16" => Rv32Variant::Simd(16),
        "ZR B MAC P8" => Rv32Variant::Simd(8),
        "ZR B MAC P4" => Rv32Variant::Simd(4),
        _ => Rv32Variant::Baseline,
    }
}

/// Measure mean cycles/sample of a variant across all models: one pool
/// job per model, gathered in model order.
fn zr_cycles(ctx: &EvalContext, variant: Rv32Variant) -> Result<(Vec<f64>, f64)> {
    let idx: Vec<usize> = (0..ctx.models.len()).collect();
    let runs: Vec<Result<(f64, f64)>> = ctx.pool().par_map(idx, |i| {
        let prog = ctx.rv32_program(i, variant)?;
        let run =
            harness::run_rv32_traced::<CyclesOnly>(&ctx.models[i], &prog, &ctx.cycle_samples[i])?;
        Ok((run.cycles_per_sample, prog.rom_cells as f64))
    });
    let mut per_model = Vec::new();
    let mut rom = Vec::new();
    for r in runs {
        let (cycles, cells) = r?;
        per_model.push(cycles);
        rom.push(cells);
    }
    let rom_avg = stats::mean(&rom);
    Ok((per_model, rom_avg))
}

/// Profile the workload set and produce the Table-I rows.
pub fn zr_table1(ctx: &EvalContext) -> Result<(Utilization, Vec<ZrRow>)> {
    let u = profile_all_on(ctx.pool(), &ctx.models, &ctx.cycle_samples)?;
    let base_synth = synthesize(&zero_riscy(), &ctx.tech);
    let (base_cycles, base_rom) = zr_cycles(ctx, Rv32Variant::Baseline)?;

    let mut rows = vec![ZrRow {
        name: "ZR baseline".into(),
        area_mm2: base_synth.area_mm2,
        power_mw: base_synth.power_mw,
        area_gain_pct: 0.0,
        power_gain_pct: 0.0,
        speedup_pct: 0.0,
        acc_loss_pct: 0.0,
        rom_cells_avg: base_rom,
    }];

    for (name, spec) in table1_variants(&u) {
        let s = synthesize(&spec, &ctx.tech);
        let variant = row_variant(&name);
        let (cycles, rom_avg) = zr_cycles(ctx, variant)?;
        let speedups: Vec<f64> = base_cycles
            .iter()
            .zip(&cycles)
            .map(|(b, c)| (1.0 - c / b) * 100.0)
            .collect();
        let p = variant.quant_precision();
        let losses: Vec<f64> =
            (0..ctx.models.len()).map(|i| ctx.accuracy_loss_pct(i, p)).collect();
        rows.push(ZrRow {
            name,
            area_mm2: s.area_mm2,
            power_mw: s.power_mw,
            area_gain_pct: (1.0 - s.area_mm2 / base_synth.area_mm2) * 100.0,
            power_gain_pct: (1.0 - s.power_mw / base_synth.power_mw) * 100.0,
            speedup_pct: stats::mean(&speedups),
            acc_loss_pct: stats::mean(&losses),
            rom_cells_avg: rom_avg,
        });
    }
    Ok((u, rows))
}

/// One Fig.-5 scatter point.
#[derive(Debug, Clone)]
pub struct TpPoint {
    /// e.g. "d8", "d8m", "d32m p8".
    pub label: String,
    pub datapath: u32,
    pub variant: TpVariant,
    pub area_mm2: f64,
    pub power_mw: f64,
    /// Mean execution-time reduction vs the same-width baseline (%).
    pub speedup_pct: f64,
    pub err_pct: f64,
    pub rom_cells_avg: f64,
    pub cycles_avg: f64,
    pub synth: SynthReport,
}

/// Mean cycles/sample of a TP-ISA config across the models it can run
/// (one pool job per model, gathered in model order); returns
/// (per-model-index, cycles, rom_cells).
fn tp_cycles(
    ctx: &EvalContext,
    d: u32,
    variant: TpVariant,
) -> Result<Vec<(usize, f64, f64)>> {
    let idx: Vec<usize> = (0..ctx.models.len()).collect();
    let runs = ctx.pool().par_map(idx, |i| -> Result<Option<(usize, f64, f64)>> {
        let model = &ctx.models[i];
        let p = codegen_tpisa::quant_precision(d, variant);
        if model.qlayers(p).is_err() {
            return Ok(None);
        }
        let Ok(prog) = ctx.tpisa_program(i, d, variant) else {
            return Ok(None); // e.g. multi-layer models on the 4-bit core
        };
        let run = harness::run_tpisa_traced::<CyclesOnly>(model, &prog, &ctx.cycle_samples[i])?;
        Ok(Some((i, run.cycles_per_sample, prog.rom_cells as f64)))
    });
    let mut out = Vec::new();
    for r in runs {
        if let Some(entry) = r? {
            out.push(entry);
        }
    }
    Ok(out)
}

/// The Fig.-5 sweep: all TP-ISA configurations.
pub fn tpisa_sweep(ctx: &EvalContext) -> Result<Vec<TpPoint>> {
    let mut points = Vec::new();
    for d in [4u32, 8, 16, 32] {
        let base_runs = tp_cycles(ctx, d, TpVariant::Baseline)?;
        let mut variants: Vec<(String, TpVariant)> =
            vec![(format!("d{d}"), TpVariant::Baseline), (format!("d{d}m"), TpVariant::Mac { precision: d })];
        for p in [16u32, 8, 4] {
            if p < d {
                variants.push((format!("d{d}m p{p}"), TpVariant::Mac { precision: p }));
            }
        }
        for (label, variant) in variants {
            let runs = tp_cycles(ctx, d, variant)?;
            if runs.is_empty() {
                continue;
            }
            // Speedup vs same-width baseline on the common model set.
            let mut speedups = Vec::new();
            let mut cycles = Vec::new();
            for &(i, c, _) in &runs {
                cycles.push(c);
                if let Some(&(_, b, _)) = base_runs.iter().find(|(bi, ..)| *bi == i) {
                    speedups.push((1.0 - c / b) * 100.0);
                }
            }
            let p = codegen_tpisa::quant_precision(d, variant);
            let losses: Vec<f64> =
                runs.iter().map(|&(i, ..)| ctx.accuracy_loss_pct(i, p)).collect();
            let mut spec = crate::hw::synth::tpisa(d);
            if let TpVariant::Mac { precision } = variant {
                spec.mul = MulOption::Mac(crate::hw::mac_unit::MacConfig::new(d, precision));
                spec.name = format!("tp-isa-{label}");
            }
            let s = synthesize(&spec, &ctx.tech);
            points.push(TpPoint {
                label,
                datapath: d,
                variant,
                area_mm2: s.area_mm2,
                power_mw: s.power_mw,
                speedup_pct: stats::mean(&speedups),
                err_pct: stats::mean(&losses),
                rom_cells_avg: stats::mean(
                    &runs.iter().map(|&(_, _, r)| r).collect::<Vec<_>>(),
                ),
                cycles_avg: stats::mean(&cycles),
                synth: s,
            });
        }
    }
    Ok(points)
}
