//! Design-space exploration over core configurations (paper §IV):
//! sweep (core family, datapath, MAC option, precision), evaluate
//! area/power via [`crate::hw::synth`], cycles via the ISSes, accuracy
//! via the manifest's cross-checked quantised evals, and extract the
//! area-speedup Pareto front (Fig. 5).

pub mod context;
pub mod pareto;
pub mod report;
pub mod sweep;
