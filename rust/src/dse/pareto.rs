//! Pareto-front extraction (Fig. 5: minimise area, maximise speedup).

/// Returns a flag per point: true if non-dominated under (minimise
/// `cost`, maximise `gain`).
pub fn pareto_flags(points: &[(f64, f64)]) -> Vec<bool> {
    points
        .iter()
        .map(|&(c, g)| {
            !points.iter().any(|&(c2, g2)| {
                (c2 <= c && g2 >= g) && (c2 < c || g2 > g) // dominates
            })
        })
        .collect()
}

/// Indices of the Pareto front, sorted by cost.
pub fn pareto_indices(points: &[(f64, f64)]) -> Vec<usize> {
    let flags = pareto_flags(points);
    let mut idx: Vec<usize> = (0..points.len()).filter(|&i| flags[i]).collect();
    idx.sort_by(|&a, &b| points[a].0.partial_cmp(&points[b].0).unwrap());
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_front() {
        // (area, speedup)
        let pts = [(1.0, 0.0), (2.0, 5.0), (3.0, 4.0), (4.0, 9.0), (2.5, 5.0)];
        let flags = pareto_flags(&pts);
        assert!(flags[0]); // cheapest
        assert!(flags[1]); // best at its cost
        assert!(!flags[2]); // dominated by (2.0, 5.0)
        assert!(flags[3]); // best speedup
        assert!(!flags[4]); // dominated by (2.0, 5.0)
        assert_eq!(pareto_indices(&pts), vec![0, 1, 3]);
    }

    #[test]
    fn duplicate_points_both_on_front() {
        let pts = [(1.0, 1.0), (1.0, 1.0)];
        assert_eq!(pareto_flags(&pts), vec![true, true]);
    }

    #[test]
    fn property_front_nonempty_and_undominated() {
        crate::util::prop::check("pareto invariants", 200, |rng| {
            let n = rng.range_usize(1, 20);
            let pts: Vec<(f64, f64)> =
                (0..n).map(|_| (rng.range_f64(0.0, 10.0), rng.range_f64(0.0, 10.0))).collect();
            let idx = pareto_indices(&pts);
            if idx.is_empty() {
                return Err("empty front".into());
            }
            // No front point dominates another front point strictly.
            for &i in &idx {
                for &j in &idx {
                    let (ci, gi) = pts[i];
                    let (cj, gj) = pts[j];
                    if ci < cj && gi > gj {
                        // fine: i is strictly better on both — then j
                        // should not be on the front
                        return Err(format!("front point {j} dominated by {i}"));
                    }
                }
            }
            Ok(())
        });
    }
}
