//! Shared evaluation context: artifacts, models, datasets and the
//! technology, loaded once per run — plus the thread pool the sweeps
//! scatter onto and the per-(model, variant) program cache, so codegen
//! runs once per sweep instead of once per row.
//!
//! Each cached program carries its `Arc`-shared prepared execution
//! image (`sim::PreparedRv32` / `sim::PreparedTpIsa`: pre-encoded ROM,
//! initial dmem, static mnemonics, and the pre-translated basic-block
//! cache of `sim::translate`), so every sweep row and every pool
//! worker constructs simulators from the same image — the per-sample
//! encode/preload cost *and* the block translation are paid exactly
//! once per (model, variant).  The harness runs each shard as a lane
//! batch on the same image (`sim::batch`), so a sweep row's samples
//! share block fetch/decode too.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::hw::egfet::{egfet, Technology};
use crate::ml::codegen_rv32::{self, Rv32Program, Rv32Variant};
use crate::ml::codegen_tpisa::{self, TpIsaProgram, TpVariant};
use crate::ml::dataset::Dataset;
use crate::ml::manifest::Manifest;
use crate::ml::model::Model;
use crate::util::threadpool::{self, ThreadPool};

/// Everything a sweep or report needs.
pub struct EvalContext {
    pub manifest: Manifest,
    pub models: Vec<Model>,
    pub tech: Technology,
    /// Per-model cycle-measurement samples (ISS timing is data-dependent
    /// only through ReLU/branch paths; a handful of samples suffices).
    pub cycle_samples: Vec<Vec<Vec<f32>>>,
    /// Per-model full test sets (for end-to-end accuracy runs).
    pub test_sets: Vec<Dataset>,
    /// Worker threads for the sweeps (the `--threads` knob).
    pub threads: usize,
    pool: ThreadPool,
    rv32_programs: Mutex<BTreeMap<(usize, String), Arc<Rv32Program>>>,
    tpisa_programs: Mutex<BTreeMap<(usize, String), Arc<TpIsaProgram>>>,
}

impl EvalContext {
    /// Load from `artifacts/`, taking `n_cycle_samples` per model, with
    /// the default thread count ([`threadpool::default_threads`]).
    pub fn load(n_cycle_samples: usize) -> Result<EvalContext> {
        Self::load_with_threads(n_cycle_samples, threadpool::default_threads())
    }

    /// Load with an explicit sweep-pool size (`--threads`).
    pub fn load_with_threads(n_cycle_samples: usize, threads: usize) -> Result<EvalContext> {
        let dir = crate::artifacts_dir()?;
        let manifest = Manifest::load(&dir)?;
        let models: Vec<Model> =
            manifest.models.iter().map(|e| Model::load(&e.weights)).collect::<Result<_>>()?;
        let mut cycle_samples = Vec::new();
        let mut test_sets = Vec::new();
        for m in &models {
            let ds = Dataset::load(manifest.data_dir(), &m.dataset, "test")?;
            cycle_samples.push(ds.x.iter().take(n_cycle_samples).cloned().collect());
            test_sets.push(ds);
        }
        let threads = threads.max(1);
        Ok(EvalContext {
            manifest,
            models,
            tech: egfet(),
            cycle_samples,
            test_sets,
            threads,
            pool: ThreadPool::new(threads),
            rv32_programs: Mutex::new(BTreeMap::new()),
            tpisa_programs: Mutex::new(BTreeMap::new()),
        })
    }

    /// The pool the sweeps and reports scatter work onto.
    pub fn pool(&self) -> &ThreadPool {
        &self.pool
    }

    /// Code-generate (once) and cache the RV32 program of a
    /// (model, variant) pair.
    pub fn rv32_program(
        &self,
        model_idx: usize,
        variant: Rv32Variant,
    ) -> Result<Arc<Rv32Program>> {
        let key = (model_idx, variant.label());
        if let Some(p) = self.rv32_programs.lock().unwrap().get(&key) {
            return Ok(Arc::clone(p));
        }
        let prog = Arc::new(codegen_rv32::generate(&self.models[model_idx], variant)?);
        let mut cache = self.rv32_programs.lock().unwrap();
        Ok(Arc::clone(cache.entry(key).or_insert(prog)))
    }

    /// Code-generate (once) and cache the TP-ISA program of a
    /// (model, datapath, variant) triple.  Generation failures are not
    /// cached: callers use them to skip infeasible configurations.
    pub fn tpisa_program(
        &self,
        model_idx: usize,
        datapath: u32,
        variant: TpVariant,
    ) -> Result<Arc<TpIsaProgram>> {
        let key = (model_idx, format!("d{datapath}-{}", variant.label()));
        if let Some(p) = self.tpisa_programs.lock().unwrap().get(&key) {
            return Ok(Arc::clone(p));
        }
        let prog = Arc::new(codegen_tpisa::generate(&self.models[model_idx], datapath, variant)?);
        let mut cache = self.tpisa_programs.lock().unwrap();
        Ok(Arc::clone(cache.entry(key).or_insert(prog)))
    }

    /// Accuracy loss (float - quantised, percentage points) of a model
    /// at a precision, from the manifest's cross-checked evals.
    pub fn accuracy_loss_pct(&self, model_idx: usize, precision: u32) -> f64 {
        let e = &self.manifest.models[model_idx];
        let q = e.quant_accuracy.get(&precision).copied().unwrap_or(f64::NAN);
        (e.float_accuracy - q) * 100.0
    }
}
