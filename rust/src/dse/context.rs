//! Shared evaluation context: artifacts, models, datasets and the
//! technology, loaded once per run.

use anyhow::Result;

use crate::hw::egfet::{egfet, Technology};
use crate::ml::dataset::Dataset;
use crate::ml::manifest::Manifest;
use crate::ml::model::Model;

/// Everything a sweep or report needs.
pub struct EvalContext {
    pub manifest: Manifest,
    pub models: Vec<Model>,
    pub tech: Technology,
    /// Per-model cycle-measurement samples (ISS timing is data-dependent
    /// only through ReLU/branch paths; a handful of samples suffices).
    pub cycle_samples: Vec<Vec<Vec<f32>>>,
    /// Per-model full test sets (for end-to-end accuracy runs).
    pub test_sets: Vec<Dataset>,
}

impl EvalContext {
    /// Load from `artifacts/`, taking `n_cycle_samples` per model.
    pub fn load(n_cycle_samples: usize) -> Result<EvalContext> {
        let dir = crate::artifacts_dir()?;
        let manifest = Manifest::load(&dir)?;
        let models: Vec<Model> =
            manifest.models.iter().map(|e| Model::load(&e.weights)).collect::<Result<_>>()?;
        let mut cycle_samples = Vec::new();
        let mut test_sets = Vec::new();
        for m in &models {
            let ds = Dataset::load(manifest.data_dir(), &m.dataset, "test")?;
            cycle_samples.push(ds.x.iter().take(n_cycle_samples).cloned().collect());
            test_sets.push(ds);
        }
        Ok(EvalContext { manifest, models, tech: egfet(), cycle_samples, test_sets })
    }

    /// Accuracy loss (float - quantised, percentage points) of a model
    /// at a precision, from the manifest's cross-checked evals.
    pub fn accuracy_loss_pct(&self, model_idx: usize, precision: u32) -> f64 {
        let e = &self.manifest.models[model_idx];
        let q = e.quant_accuracy.get(&precision).copied().unwrap_or(f64::NAN);
        (e.float_accuracy - q) * 100.0
    }
}
