//! `pbsp` — the bespoke printed-microprocessor framework CLI (L3 leader
//! entrypoint).
//!
//! ```text
//! pbsp synth [--core zero-riscy|tp-isa-dN]     synthesis report
//! pbsp profile                                  §III-A utilization report
//! pbsp report <fig1|table1|fig4|fig5|table2|mem|all>
//! pbsp eval --model <name> [--precision N] [--backend iss|pjrt|both]
//! pbsp serve [--requests N] [--batch N] [--iss]  coordinator demo loop
//! pbsp serve --addr HOST:PORT [--http-threads N] [--duration-s N]
//!            [--max-conns N] [--max-queued N]   HTTP inference frontend
//!            [--trace-sample N] [--log-json FILE] [--stats-interval-s S]
//!            [--default-deadline-ms D] [--brownout-high N] [--brownout-low N]
//! pbsp loadgen --fleet N [--requests N] [--seed S] [--think-ms T]
//!              [--addr HOST:PORT] [--out FILE]   device-fleet load test
//!              [--open-rps R] [--client-workers N] [--iss] [--verify]
//!              [--trace-sample N] [--log-json FILE]
//!              [--deadline-ms D] [--attempts N]
//!              [--chaos-seed S] [--chaos-profile P]
//!              [--default-deadline-ms D] [--brownout-high N] [--brownout-low N]
//! pbsp crosscheck [--samples N]                 ISS vs PJRT bit-exactness
//! pbsp faultsim [--core zero-riscy|tp-isa|both] [--models A,B]
//!               [--precision N] [--datapath N] [--seed S] [--trials N]
//!               [--samples N] [--rates R1,R2,..] [--rom-trials N]
//!               [--out FILE]                    soft-error campaign
//! ```
//!
//! Serving is reactor-based: `--http-threads` sizes the *compute* pool,
//! while `--max-conns` caps concurrently open connections (refusals are
//! `503` + `Retry-After`) and `--max-queued` caps requests in flight on
//! the pool.  `--open-rps R` switches the load generator from
//! closed-loop to an open-loop arrival schedule at R requests/s
//! fleet-wide; `--client-workers` bounds the loadgen's own threads
//! (devices are sharded, so 10k-device fleets don't need 10k threads).
//!
//! Overload handling: requests carry `X-Deadline-Ms` (loadgen:
//! `--deadline-ms`; server fallback: `--default-deadline-ms`) and are
//! shed with a `504` once the budget — counted from the request's first
//! byte — is spent, before they waste compute.  `--brownout-high N` /
//! `--brownout-low N` set the in-flight watermarks of the brownout
//! controller: between them, eligible requests are served at the
//! next-lower precision variant (response carries `degraded: true` and
//! the variant actually served) instead of being 503-shed.
//! `GET /readyz` answers 503 (naming the reason) while draining, over
//! capacity, or in brownout — `GET /healthz` stays pure liveness.
//!
//! Chaos: `--chaos-seed S` mounts a seeded fault-injecting TCP proxy
//! (`server::chaos`) between the fleet and the frontend —
//! `--chaos-profile` picks from clean|latency|drip|resets|truncate|
//! blackhole|mix; every behaviour is a pure function of
//! (seed, connection ordinal), so a chaos run is exactly reproducible.
//! Under chaos the loadgen retries transport faults and 503s with
//! seeded decorrelated-jitter backoff (`--attempts` bounds tries) and
//! its report counts deadline misses, degraded serves and retries.
//!
//! `--iss` scores quantised (`p ≤ 16`) requests on the batched lockstep
//! ISS (`sim::batch`) instead of the PJRT runtime; `--verify` (loadgen,
//! in-process mode) replays every fleet record through direct
//! `Service::scores` and requires bit-identical scores, then reconciles
//! the fleet's counts against the server's `/metrics` counters.
//!
//! Resilience: `faultsim` runs the seeded soft-error campaign
//! (`bespoke::resilience`) — accuracy-vs-fault-rate curves, an AVF
//! breakdown by target class (registers / RAM / MAC accumulators), and
//! stuck-at ROM probes; `--out FILE` writes the JSON artifact.  On the
//! serving side, `--dual-exec F` (serve/loadgen, ISS mode) re-runs a
//! fraction F of batches until two consecutive executions agree
//! byte-for-byte and serves the agreed scores; `--fault-mac R`
//! adversarially injects seeded MAC-accumulator flips (seed
//! `--fault-seed`) into every execution so the guard has something to
//! catch.  Counted in `/metrics` as `pbsp_dual_exec_{checks,mismatches,
//! reruns}_total` and `pbsp_fault_plans_injected_total`.
//!
//! Observability: `--trace-sample N` emits a structured JSON span for
//! every Nth request (accept → parse → queue → batch-cut → execute →
//! write) to `--log-json FILE` (stderr when omitted);
//! `--stats-interval-s S` prints a one-line server + coordinator
//! summary every S seconds; `GET /metrics` serves JSON and
//! `GET /metrics?format=prometheus` the Prometheus text exposition.
//!
//! `report`, `eval`, `serve`, `loadgen` and `crosscheck` all take
//! `--threads N` (default: `PBSP_THREADS`, else the machine's
//! parallelism) — the sweep/evaluation pool size.  Parallel results are
//! bit-identical to `--threads 1`.

use std::net::ToSocketAddrs;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};
use printed_bespoke::bespoke::profile::profile_suite;
use printed_bespoke::coordinator::service::{Service, ServiceConfig};
use printed_bespoke::dse::{context::EvalContext, report};
use printed_bespoke::hw::egfet::egfet;
use printed_bespoke::hw::synth::{synthesize, tpisa, zero_riscy};
use printed_bespoke::server::chaos::{ChaosProxy, Profile};
use printed_bespoke::server::{loadgen, Server, ServerConfig};
use printed_bespoke::sim::trace::CyclesOnly;
use printed_bespoke::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("pbsp: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand() {
        Some("synth") => cmd_synth(&args),
        Some("profile") => cmd_profile(&args),
        Some("report") => cmd_report(&args),
        Some("eval") => cmd_eval(&args),
        Some("serve") => cmd_serve(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some("crosscheck") => cmd_crosscheck(&args),
        Some("faultsim") => cmd_faultsim(&args),
        Some(other) => bail!("unknown subcommand {other:?}\n{USAGE}"),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

const USAGE: &str =
    "usage: pbsp <synth|profile|report|eval|serve|loadgen|crosscheck|faultsim> [options]";

fn cmd_synth(args: &Args) -> Result<()> {
    let core = args.str_or("core", "zero-riscy");
    args.finish()?;
    let tech = egfet();
    let spec = match core.as_str() {
        "zero-riscy" => zero_riscy(),
        s if s.starts_with("tp-isa-d") => {
            let d: u32 = s["tp-isa-d".len()..].parse().context("datapath")?;
            tpisa(d)
        }
        other => bail!("unknown core {other:?}"),
    };
    let r = synthesize(&spec, &tech);
    println!(
        "{}: {:.2} cm^2, {:.2} mW, fmax {:.1} Hz (critical depth {})",
        r.name,
        r.area_cm2(),
        r.power_mw,
        r.fmax_hz,
        r.critical_depth
    );
    println!("unit       GE        area[mm^2]  power[mW]");
    for (kind, ge, a, p) in &r.breakdown {
        println!("{:<9} {:>9.0}  {:>9.1}  {:>9.2}", kind.name(), ge, a, p);
    }
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    args.finish()?;
    let u = profile_suite()?;
    println!("profiling suite: {:?}", u.workloads);
    println!(
        "instructions {} cycles {} (CPI {:.2})",
        u.profile.instructions,
        u.profile.cycles,
        u.profile.cycles as f64 / u.profile.instructions as f64
    );
    println!(
        "registers used: {} / 32; PC bits needed: {}; BAR bits: {}",
        u.regs_needed, u.pc_bits_needed, u.bar_bits_needed
    );
    println!("unused instructions: {}", u.unused_instructions.join(" "));
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let what = args.positionals.get(1).map(String::as_str).unwrap_or("all").to_string();
    let samples = args.parse_or("samples", 8usize)?;
    let threads = args.threads()?;
    args.finish()?;
    let ctx = EvalContext::load_with_threads(samples, threads)?;
    let print = |name: &str| -> Result<()> {
        match name {
            "fig1" => println!("{}", report::fig1(&ctx).text),
            "table1" => println!("{}", report::table1(&ctx)?.text),
            "fig4" => println!("{}", report::fig4(&ctx).text),
            "fig5" => println!("{}", report::fig5(&ctx)?.text),
            "table2" => println!("{}", report::table2(&ctx)?.text),
            "mem" => println!("{}", report::mem(&ctx)?.text),
            other => bail!("unknown report {other:?}"),
        }
        Ok(())
    };
    if what == "all" {
        for name in ["fig1", "table1", "fig4", "fig5", "table2", "mem"] {
            print(name)?;
        }
    } else {
        print(&what)?;
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model = args.require("model")?.to_string();
    let precision = args.parse_or("precision", 16u32)?;
    let backend = args.str_or("backend", "both");
    let threads = args.threads()?;
    args.finish()?;
    let ctx = EvalContext::load_with_threads(4, threads)?;
    let idx = ctx
        .models
        .iter()
        .position(|m| m.name == model)
        .with_context(|| format!("unknown model {model:?}"))?;
    let ds = &ctx.test_sets[idx];
    if backend == "pjrt" || backend == "both" {
        let svc = Service::start(ServiceConfig { threads, ..ServiceConfig::default() })?;
        let r = svc.evaluate(&model, precision, &ds.x, &ds.y)?;
        println!(
            "[pjrt] {} p{} accuracy {:.4} ({} samples, {:.2} ms/batch)",
            model, precision, r.accuracy, r.n, r.batch_ms_mean
        );
    }
    if backend == "iss" || backend == "both" {
        let m = &ctx.models[idx];
        let prog = printed_bespoke::ml::codegen_rv32::generate(
            m,
            printed_bespoke::ml::codegen_rv32::Rv32Variant::Simd(precision.min(16)),
        )?;
        // Accuracy only needs scores + cycle counts: cycles-only trace.
        let run = printed_bespoke::ml::harness::run_rv32_on_traced::<CyclesOnly>(
            ctx.pool(),
            m,
            &prog,
            &ds.x,
        )?;
        println!(
            "[iss ] {} p{} accuracy {:.4} ({:.0} cycles/sample)",
            model,
            precision,
            ds.accuracy(&run.predictions),
            run.cycles_per_sample
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let requests = args.parse_or("requests", 200usize)?;
    let batch = args.parse_or("batch", 64usize)?;
    let addr = args.opt_str("addr").map(String::from);
    let http_threads = args.opt_parse::<usize>("http-threads")?;
    let max_conns = args.opt_parse::<usize>("max-conns")?;
    let max_queued = args.opt_parse::<usize>("max-queued")?;
    let duration_s = args.parse_or("duration-s", 0u64)?;
    let trace_sample = args.parse_or("trace-sample", 0u64)?;
    let trace_log = args.opt_str("log-json").map(String::from);
    let stats_interval_s = args.parse_or("stats-interval-s", 0u64)?;
    let default_deadline_ms = args.parse_or("default-deadline-ms", 0u64)?;
    let brownout_high = args.parse_or("brownout-high", 0usize)?;
    let brownout_low = args.parse_or("brownout-low", 0usize)?;
    let iss = args.flag("iss");
    let dual_exec = args.parse_or("dual-exec", 0.0f64)?;
    let fault_mac_rate = args.parse_or("fault-mac", 0.0f64)?;
    let fault_seed = args.parse_or("fault-seed", 1u64)?;
    let threads = args.threads()?;
    args.finish()?;
    let cfg = ServiceConfig {
        max_batch: batch,
        threads,
        iss,
        dual_exec,
        fault_mac_rate,
        fault_seed,
        ..ServiceConfig::default()
    };
    let Some(addr) = addr else {
        // Legacy in-process demo loop (no network).
        let svc = Service::start(cfg)?;
        let stats = svc.demo_load(requests)?;
        println!("{stats}");
        return Ok(());
    };
    // HTTP frontend mode: bind, serve until killed (or --duration-s).
    // The reactor owns every connection socket; --http-threads only
    // sizes the compute pool, so the default is fine for big fleets.
    let svc = Arc::new(Service::start(cfg)?);
    let mut scfg = ServerConfig {
        addr,
        trace_sample,
        trace_log,
        default_deadline_ms,
        brownout_high,
        brownout_low,
        ..ServerConfig::default()
    };
    if let Some(t) = http_threads {
        scfg.http_threads = t;
    }
    if let Some(c) = max_conns {
        scfg.max_connections = c;
    }
    if let Some(q) = max_queued {
        scfg.max_queued = q;
    }
    // Fleets need fds: one per connection plus slack (best-effort).
    printed_bespoke::util::poll::raise_nofile_limit(scfg.max_connections as u64 + 256);
    let mut server = Server::start(Arc::clone(&svc), scfg)?;
    println!("pbsp-http listening on http://{}", server.addr());
    println!("  curl -s http://{}/healthz", server.addr());
    println!(
        "  curl -s -X POST http://{}/v1/score/{}/p8 -d '{{\"x\": [0.1, 0.2]}}'",
        server.addr(),
        svc.models.first().map(|m| m.name.as_str()).unwrap_or("MODEL")
    );
    if stats_interval_s > 0 {
        // Detached observer: one summary line per interval from the
        // lock-free server counters and the coordinator's metrics.  It
        // only reads shared state, so letting process exit reap it is
        // fine (no join on shutdown).
        let svc = Arc::clone(&svc);
        let metrics = Arc::clone(&server.metrics);
        let _detached = std::thread::Builder::new()
            .name("pbsp-stats".into())
            .spawn(move || {
                use std::sync::atomic::Ordering;
                loop {
                    std::thread::sleep(Duration::from_secs(stats_interval_s));
                    let c = |a: &std::sync::atomic::AtomicU64| a.load(Ordering::Relaxed);
                    println!(
                        "stats: conns {} (open {})  requests {}  2xx {}  4xx {}  5xx {}  \
                         rejected {}/{}  evicted {}/{}/{} (idle/read/write) | {}",
                        c(&metrics.connections),
                        c(&metrics.open_connections),
                        c(&metrics.http_requests),
                        c(&metrics.responses_2xx),
                        c(&metrics.responses_4xx),
                        c(&metrics.responses_5xx),
                        c(&metrics.rejected_busy),
                        c(&metrics.rejected_queue),
                        c(&metrics.evicted_idle),
                        c(&metrics.evicted_read),
                        c(&metrics.evicted_write),
                        svc.metrics.lock().unwrap().summary()
                    );
                }
            })
            .context("spawn stats printer")?;
    }
    if duration_s == 0 {
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(duration_s));
    server.shutdown();
    println!("server: {}", server.metrics.to_json());
    println!("coordinator: {}", svc.metrics.lock().unwrap().summary());
    Ok(())
}

fn cmd_loadgen(args: &Args) -> Result<()> {
    let cfg = loadgen::LoadgenConfig {
        fleet: args.parse_or("fleet", 8usize)?,
        requests_per_device: args.parse_or("requests", 50usize)?,
        seed: args.parse_or("seed", 1u64)?,
        think_ms: args.parse_or("think-ms", 0u64)?,
        precision: args.parse_or("precision", 8u32)?,
        open_rps: args.parse_or("open-rps", 0.0f64)?,
        client_workers: args.parse_or("client-workers", 0usize)?,
        deadline_ms: args.parse_or("deadline-ms", 0u64)?,
        attempts: args.parse_or("attempts", 3usize)?,
    };
    let addr = args.opt_str("addr").map(String::from);
    let out = args.opt_str("out").map(String::from);
    let trace_sample = args.parse_or("trace-sample", 0u64)?;
    let trace_log = args.opt_str("log-json").map(String::from);
    let iss = args.flag("iss");
    let verify = args.flag("verify");
    let dual_exec = args.parse_or("dual-exec", 0.0f64)?;
    let fault_mac_rate = args.parse_or("fault-mac", 0.0f64)?;
    let fault_seed = args.parse_or("fault-seed", 1u64)?;
    let chaos_seed = args.opt_parse::<u64>("chaos-seed")?;
    let chaos_profile: Profile = args.str_or("chaos-profile", "mix").parse()?;
    let default_deadline_ms = args.parse_or("default-deadline-ms", 0u64)?;
    let brownout_high = args.parse_or("brownout-high", 0usize)?;
    let brownout_low = args.parse_or("brownout-low", 0usize)?;
    let threads = args.threads()?;
    args.finish()?;
    // The loadgen holds one socket per device (plus the frontend's own
    // in the self-contained mode) — raise the fd budget up front.
    printed_bespoke::util::poll::raise_nofile_limit(cfg.fleet as u64 * 2 + 512);
    // Mounts the seeded chaos proxy (when asked) between fleet and
    // frontend, runs the fleet through it, then re-scrapes `/metrics`
    // off the *direct* address — the run's own scrape rode the proxy
    // and may itself have been faulted.
    let run_chaos = |target: std::net::SocketAddr,
                     cfg: &loadgen::LoadgenConfig|
     -> Result<loadgen::Report> {
        let Some(seed) = chaos_seed else {
            return loadgen::run(target, cfg);
        };
        let mut proxy = ChaosProxy::start(target, seed, chaos_profile)?;
        println!(
            "chaos: proxy {} -> {} (seed {seed}, profile {chaos_profile:?})",
            proxy.addr(),
            target
        );
        let mut report = loadgen::run(proxy.addr(), cfg)?;
        report.server_metrics = loadgen::scrape_metrics(target);
        let s = proxy.stats();
        let c = |a: &std::sync::atomic::AtomicU64| a.load(std::sync::atomic::Ordering::Relaxed);
        // One greppable line for the chaos-smoke CI job.
        println!(
            "chaos: conns {} clean {} faulted {} (resets {} truncations {} blackholes {} \
             delayed {} dripped {})",
            c(&s.conns),
            c(&s.clean),
            s.faulted(),
            c(&s.resets),
            c(&s.truncations),
            c(&s.blackholes),
            c(&s.delayed),
            c(&s.dripped),
        );
        proxy.shutdown();
        Ok(report)
    };
    let report = match addr {
        // Drive an already-running external frontend.
        Some(a) => {
            if verify {
                bail!("--verify needs the in-process frontend (drop --addr)");
            }
            if trace_sample > 0 || trace_log.is_some() {
                bail!("--trace-sample/--log-json configure the in-process frontend (drop --addr, or pass them to the external `pbsp serve`)");
            }
            if dual_exec > 0.0 || fault_mac_rate > 0.0 {
                bail!("--dual-exec/--fault-mac configure the in-process frontend (drop --addr, or pass them to the external `pbsp serve`)");
            }
            if default_deadline_ms > 0 || brownout_high > 0 || brownout_low > 0 {
                bail!("--default-deadline-ms/--brownout-* configure the in-process frontend (drop --addr, or pass them to the external `pbsp serve`)");
            }
            let target = a
                .to_socket_addrs()
                .with_context(|| format!("resolve {a:?}"))?
                .next()
                .with_context(|| format!("{a:?} resolved to no address"))?;
            run_chaos(target, &cfg)?
        }
        // Self-contained: spin up service + frontend on an ephemeral
        // port, run the fleet, shut down (the CI smoke path).
        None => {
            let svc = Arc::new(Service::start(ServiceConfig {
                threads,
                iss,
                dual_exec,
                fault_mac_rate,
                fault_seed,
                ..ServiceConfig::default()
            })?);
            // The reactor multiplexes every device on one thread — only
            // the admission cap needs fleet-size headroom (reconnect
            // churn from think-time reaping and chaos retries included).
            let scfg = ServerConfig {
                max_connections: cfg.fleet + 16,
                trace_sample,
                trace_log,
                default_deadline_ms,
                brownout_high,
                brownout_low,
                ..ServerConfig::default()
            };
            let mut server = Server::start(Arc::clone(&svc), scfg)?;
            println!("loadgen: in-process frontend on http://{}", server.addr());
            let report = run_chaos(server.addr(), &cfg)?;
            server.shutdown();
            println!("coordinator: {}", svc.metrics.lock().unwrap().summary());
            if verify {
                let checked = loadgen::verify(&svc, &report)?;
                println!("verify ok: {checked} records bit-identical to in-process scoring");
            }
            if dual_exec > 0.0 {
                // One greppable line for the fault-smoke CI job: proves
                // the guard actually caught injected corruption.
                let g = printed_bespoke::util::telemetry::global();
                println!(
                    "dual-exec: checks {} mismatches {} reruns {} faulty-plans {}",
                    g.counter("pbsp_dual_exec_checks_total", "").get(),
                    g.counter("pbsp_dual_exec_mismatches_total", "").get(),
                    g.counter("pbsp_dual_exec_reruns_total", "").get(),
                    g.counter("pbsp_fault_plans_injected_total", "").get(),
                );
            }
            report
        }
    };
    println!("{}", report.summary());
    if let Some(path) = out {
        // `.json` gets the machine-readable artifact (finite numbers
        // only — an all-fail run reports zeros and its first error,
        // never NaN); anything else gets the text histogram.
        let payload = if path.ends_with(".json") {
            report.to_json().to_string()
        } else {
            report.histogram()
        };
        std::fs::write(&path, payload).with_context(|| format!("writing {path}"))?;
        println!("latency report written to {path}");
    }
    if report.records.is_empty() {
        bail!("loadgen completed zero requests");
    }
    if report.errors > 0 {
        // Under chaos, residual errors are the *point* — faults that
        // outlast the retry budget.  Every successful response was
        // still (optionally) verified bit-identical above; a clean run
        // keeps the hard zero-error gate.
        if chaos_seed.is_some() {
            println!("loadgen: {} errors tolerated under chaos injection", report.errors);
        } else {
            bail!("loadgen saw {} errors", report.errors);
        }
    }
    Ok(())
}

fn cmd_faultsim(args: &Args) -> Result<()> {
    use printed_bespoke::bespoke::resilience::{campaign, CampaignConfig};
    let core = args.str_or("core", "both");
    let models: Vec<String> = args
        .opt_str("models")
        .map(|s| {
            s.split(',')
                .map(|m| m.trim().to_string())
                .filter(|m| !m.is_empty())
                .collect()
        })
        .unwrap_or_default();
    let mut cfg = CampaignConfig { models, ..CampaignConfig::default() };
    cfg.precision = args.parse_or("precision", cfg.precision)?;
    cfg.datapath = args.parse_or("datapath", cfg.datapath)?;
    cfg.seed = args.parse_or("seed", cfg.seed)?;
    cfg.trials = args.parse_or("trials", cfg.trials)?;
    cfg.samples = args.parse_or("samples", cfg.samples)?;
    cfg.rom_trials = args.parse_or("rom-trials", cfg.rom_trials)?;
    if let Some(rates) = args.opt_str("rates") {
        cfg.rates = rates
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(|s| s.parse::<f64>().with_context(|| format!("bad rate {s:?}")))
            .collect::<Result<_>>()?;
    }
    match core.as_str() {
        "both" => {}
        "zero-riscy" | "zr" => cfg.tpisa = false,
        "tp-isa" | "tp" => cfg.zero_riscy = false,
        other => bail!("unknown core {other:?} (zero-riscy|tp-isa|both)"),
    }
    let out = args.opt_str("out").map(String::from);
    let threads = args.threads()?;
    args.finish()?;
    let ctx = EvalContext::load_with_threads(cfg.samples.max(1), threads)?;
    let report = campaign(&ctx, &cfg)?;
    print!("{}", report.text);
    if let Some(path) = out {
        std::fs::write(&path, format!("{}\n", report.json))
            .with_context(|| format!("writing {path}"))?;
        println!("resilience report written to {path}");
    }
    Ok(())
}

fn cmd_crosscheck(args: &Args) -> Result<()> {
    let samples = args.parse_or("samples", 16usize)?;
    let threads = args.threads()?;
    args.finish()?;
    let svc = Service::start(ServiceConfig { threads, ..ServiceConfig::default() })?;
    let report = svc.crosscheck(samples)?;
    println!("{report}");
    Ok(())
}
