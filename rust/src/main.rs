//! `pbsp` — the bespoke printed-microprocessor framework CLI (L3 leader
//! entrypoint).
//!
//! ```text
//! pbsp synth [--core zero-riscy|tp-isa-dN]     synthesis report
//! pbsp profile                                  §III-A utilization report
//! pbsp report <fig1|table1|fig4|fig5|table2|mem|all>
//! pbsp eval --model <name> [--precision N] [--backend iss|pjrt|both]
//! pbsp serve [--requests N] [--batch N]         coordinator demo loop
//! pbsp crosscheck [--samples N]                 ISS vs PJRT bit-exactness
//! ```
//!
//! `report`, `eval`, `serve` and `crosscheck` all take `--threads N`
//! (default: `PBSP_THREADS`, else the machine's parallelism) — the
//! sweep/evaluation pool size.  Parallel results are bit-identical to
//! `--threads 1`.

use anyhow::{bail, Context, Result};
use printed_bespoke::bespoke::profile::profile_suite;
use printed_bespoke::coordinator::service::{Service, ServiceConfig};
use printed_bespoke::dse::{context::EvalContext, report};
use printed_bespoke::hw::egfet::egfet;
use printed_bespoke::hw::synth::{synthesize, tpisa, zero_riscy};
use printed_bespoke::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("pbsp: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand() {
        Some("synth") => cmd_synth(&args),
        Some("profile") => cmd_profile(&args),
        Some("report") => cmd_report(&args),
        Some("eval") => cmd_eval(&args),
        Some("serve") => cmd_serve(&args),
        Some("crosscheck") => cmd_crosscheck(&args),
        Some(other) => bail!("unknown subcommand {other:?}\n{USAGE}"),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

const USAGE: &str = "usage: pbsp <synth|profile|report|eval|serve|crosscheck> [options]";

fn cmd_synth(args: &Args) -> Result<()> {
    let core = args.str_or("core", "zero-riscy");
    args.finish()?;
    let tech = egfet();
    let spec = match core.as_str() {
        "zero-riscy" => zero_riscy(),
        s if s.starts_with("tp-isa-d") => {
            let d: u32 = s["tp-isa-d".len()..].parse().context("datapath")?;
            tpisa(d)
        }
        other => bail!("unknown core {other:?}"),
    };
    let r = synthesize(&spec, &tech);
    println!(
        "{}: {:.2} cm^2, {:.2} mW, fmax {:.1} Hz (critical depth {})",
        r.name,
        r.area_cm2(),
        r.power_mw,
        r.fmax_hz,
        r.critical_depth
    );
    println!("unit       GE        area[mm^2]  power[mW]");
    for (kind, ge, a, p) in &r.breakdown {
        println!("{:<9} {:>9.0}  {:>9.1}  {:>9.2}", kind.name(), ge, a, p);
    }
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    args.finish()?;
    let u = profile_suite()?;
    println!("profiling suite: {:?}", u.workloads);
    println!(
        "instructions {} cycles {} (CPI {:.2})",
        u.profile.instructions,
        u.profile.cycles,
        u.profile.cycles as f64 / u.profile.instructions as f64
    );
    println!(
        "registers used: {} / 32; PC bits needed: {}; BAR bits: {}",
        u.regs_needed, u.pc_bits_needed, u.bar_bits_needed
    );
    println!("unused instructions: {}", u.unused_instructions.join(" "));
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let what = args.positionals.get(1).map(String::as_str).unwrap_or("all").to_string();
    let samples = args.parse_or("samples", 8usize)?;
    let threads = args.threads()?;
    args.finish()?;
    let ctx = EvalContext::load_with_threads(samples, threads)?;
    let print = |name: &str| -> Result<()> {
        match name {
            "fig1" => println!("{}", report::fig1(&ctx).text),
            "table1" => println!("{}", report::table1(&ctx)?.text),
            "fig4" => println!("{}", report::fig4(&ctx).text),
            "fig5" => println!("{}", report::fig5(&ctx)?.text),
            "table2" => println!("{}", report::table2(&ctx)?.text),
            "mem" => println!("{}", report::mem(&ctx)?.text),
            other => bail!("unknown report {other:?}"),
        }
        Ok(())
    };
    if what == "all" {
        for name in ["fig1", "table1", "fig4", "fig5", "table2", "mem"] {
            print(name)?;
        }
    } else {
        print(&what)?;
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model = args.require("model")?.to_string();
    let precision = args.parse_or("precision", 16u32)?;
    let backend = args.str_or("backend", "both");
    let threads = args.threads()?;
    args.finish()?;
    let ctx = EvalContext::load_with_threads(4, threads)?;
    let idx = ctx
        .models
        .iter()
        .position(|m| m.name == model)
        .with_context(|| format!("unknown model {model:?}"))?;
    let ds = &ctx.test_sets[idx];
    if backend == "pjrt" || backend == "both" {
        let svc = Service::start(ServiceConfig { threads, ..ServiceConfig::default() })?;
        let r = svc.evaluate(&model, precision, &ds.x, &ds.y)?;
        println!(
            "[pjrt] {} p{} accuracy {:.4} ({} samples, {:.2} ms/batch)",
            model, precision, r.accuracy, r.n, r.batch_ms_mean
        );
    }
    if backend == "iss" || backend == "both" {
        let m = &ctx.models[idx];
        let prog = printed_bespoke::ml::codegen_rv32::generate(
            m,
            printed_bespoke::ml::codegen_rv32::Rv32Variant::Simd(precision.min(16)),
        )?;
        let run = printed_bespoke::ml::harness::run_rv32_on(ctx.pool(), m, &prog, &ds.x)?;
        println!(
            "[iss ] {} p{} accuracy {:.4} ({:.0} cycles/sample)",
            model,
            precision,
            ds.accuracy(&run.predictions),
            run.cycles_per_sample
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let requests = args.parse_or("requests", 200usize)?;
    let batch = args.parse_or("batch", 64usize)?;
    let threads = args.threads()?;
    args.finish()?;
    let cfg = ServiceConfig { max_batch: batch, threads, ..ServiceConfig::default() };
    let svc = Service::start(cfg)?;
    let stats = svc.demo_load(requests)?;
    println!("{stats}");
    Ok(())
}

fn cmd_crosscheck(args: &Args) -> Result<()> {
    let samples = args.parse_or("samples", 16usize)?;
    let threads = args.threads()?;
    args.finish()?;
    let svc = Service::start(ServiceConfig { threads, ..ServiceConfig::default() })?;
    let report = svc.crosscheck(samples)?;
    println!("{report}");
    Ok(())
}
