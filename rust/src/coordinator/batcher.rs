//! Dynamic batcher: accumulates single-sample requests per executable
//! key and flushes when a batch fills or the linger window expires —
//! the standard serving trade-off between latency and throughput.

use std::collections::VecDeque;
use std::time::Instant;

/// One queued request.
#[derive(Debug)]
pub struct Pending<T> {
    pub payload: T,
    pub enqueued: Instant,
}

/// A per-key FIFO with batch-flush policy.
#[derive(Debug)]
pub struct Queue<T> {
    items: VecDeque<Pending<T>>,
    pub max_batch: usize,
    pub linger_ms: u64,
}

impl<T> Queue<T> {
    pub fn new(max_batch: usize, linger_ms: u64) -> Queue<T> {
        assert!(max_batch > 0);
        Queue { items: VecDeque::new(), max_batch, linger_ms }
    }

    pub fn push(&mut self, payload: T) {
        self.items.push_back(Pending { payload, enqueued: Instant::now() });
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Should this queue flush now?  Full batch, or the oldest request
    /// has lingered past the window.
    pub fn ready(&self, now: Instant) -> bool {
        if self.items.len() >= self.max_batch {
            return true;
        }
        match self.items.front() {
            Some(p) => now.duration_since(p.enqueued).as_millis() as u64 >= self.linger_ms,
            None => false,
        }
    }

    /// Pop up to `max_batch` requests.
    pub fn drain_batch(&mut self) -> Vec<Pending<T>> {
        let n = self.items.len().min(self.max_batch);
        self.items.drain(..n).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn flushes_on_full_batch() {
        let mut q = Queue::new(3, 1000);
        q.push(1);
        q.push(2);
        assert!(!q.ready(Instant::now()));
        q.push(3);
        assert!(q.ready(Instant::now()));
        let batch = q.drain_batch();
        assert_eq!(batch.len(), 3);
        assert!(q.is_empty());
    }

    #[test]
    fn flushes_on_linger() {
        let mut q = Queue::new(100, 5);
        q.push(1);
        assert!(!q.ready(Instant::now()));
        std::thread::sleep(Duration::from_millis(8));
        assert!(q.ready(Instant::now()));
    }

    #[test]
    fn drain_caps_at_max_batch() {
        let mut q = Queue::new(2, 0);
        for i in 0..5 {
            q.push(i);
        }
        assert_eq!(q.drain_batch().len(), 2);
        assert_eq!(q.len(), 3);
    }

    /// Satellite: the linger window is inclusive — a queue becomes
    /// ready exactly *at* `linger_ms`, not one tick later.
    #[test]
    fn linger_boundary_is_inclusive() {
        let mut q = Queue::new(10, 5);
        q.push(1);
        let t0 = q.items.front().unwrap().enqueued;
        assert!(!q.ready(t0 + Duration::from_millis(4)));
        assert!(q.ready(t0 + Duration::from_millis(5)));
        assert!(q.ready(t0 + Duration::from_millis(500)));
    }

    /// Satellite: `max_batch = 1` degenerates to immediate per-request
    /// dispatch regardless of the linger window.
    #[test]
    fn max_batch_one_flushes_immediately() {
        let mut q = Queue::new(1, 10_000);
        q.push(7);
        assert!(q.ready(Instant::now()));
        assert_eq!(q.drain_batch().len(), 1);
        assert!(!q.ready(Instant::now())); // empty again
    }

    /// Satellite: an empty queue is never ready (even at linger 0) and
    /// drains to nothing.
    #[test]
    fn empty_queue_never_ready() {
        let mut q: Queue<u8> = Queue::new(3, 0);
        assert!(!q.ready(Instant::now()));
        assert!(q.drain_batch().is_empty());
        // Zero linger + one item: ready at once.
        q.push(1);
        assert!(q.ready(Instant::now()));
    }

    /// Satellite: overfull queue stays ready until drained below a full
    /// batch, and drains split at exactly `max_batch`.
    #[test]
    fn overfull_queue_drains_in_max_batch_steps() {
        let mut q = Queue::new(3, 10_000);
        for i in 0..7 {
            q.push(i);
        }
        assert!(q.ready(Instant::now()));
        assert_eq!(q.drain_batch().len(), 3);
        assert!(q.ready(Instant::now())); // 4 left: still a full batch
        assert_eq!(q.drain_batch().len(), 3);
        assert!(!q.ready(Instant::now())); // 1 left, linger not expired
        assert_eq!(q.drain_batch().len(), 1);
    }

    /// Property: FIFO order is preserved across arbitrary push/drain
    /// interleavings.
    #[test]
    fn prop_fifo_order() {
        crate::util::prop::check("batcher fifo", 200, |rng| {
            let max_batch = rng.range_usize(1, 8);
            let mut q = Queue::new(max_batch, 1000);
            let mut next = 0u64;
            let mut expected = std::collections::VecDeque::new();
            let mut drained = Vec::new();
            for _ in 0..rng.range_usize(1, 40) {
                if rng.bool() {
                    q.push(next);
                    expected.push_back(next);
                    next += 1;
                } else {
                    for p in q.drain_batch() {
                        drained.push(p.payload);
                    }
                }
            }
            for p in q.drain_batch() {
                drained.push(p.payload);
            }
            for (i, v) in drained.iter().enumerate() {
                if expected[i] != *v {
                    return Err(format!("order broken at {i}: {v} != {}", expected[i]));
                }
            }
            Ok(())
        });
    }
}
