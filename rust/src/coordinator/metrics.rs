//! Coordinator metrics: request/batch counters, batch-size and latency
//! distributions.  Shared between the service facade and the worker via
//! `Arc<Mutex<_>>`; snapshots are cheap copies.

use std::sync::{Arc, Mutex};

use crate::util::stats;

#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub requests: u64,
    pub batches: u64,
    pub batch_sizes: Vec<f64>,
    pub exec_ms: Vec<f64>,
    pub queue_ms: Vec<f64>,
    pub compiles: u64,
}

impl Metrics {
    pub fn record_batch(&mut self, size: usize, exec_ms: f64) {
        self.batches += 1;
        self.requests += size as u64;
        self.batch_sizes.push(size as f64);
        self.exec_ms.push(exec_ms);
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            0.0
        } else {
            stats::mean(&self.batch_sizes)
        }
    }

    pub fn mean_exec_ms(&self) -> f64 {
        if self.exec_ms.is_empty() {
            0.0
        } else {
            stats::mean(&self.exec_ms)
        }
    }

    pub fn summary(&self) -> String {
        let lat = if self.exec_ms.is_empty() {
            "n/a".to_string()
        } else {
            let s = stats::summarize(&self.exec_ms);
            format!("{:.2}/{:.2}/{:.2} ms (p50/p95/p99)", s.p50, s.p95, s.p99)
        };
        format!(
            "requests {} batches {} mean-batch {:.1} exec {lat} compiles {}",
            self.requests,
            self.batches,
            self.mean_batch_size(),
            self.compiles
        )
    }
}

/// Shared handle.
pub type Shared = Arc<Mutex<Metrics>>;

pub fn shared() -> Shared {
    Arc::new(Mutex::new(Metrics::default()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarises() {
        let mut m = Metrics::default();
        m.record_batch(4, 1.5);
        m.record_batch(8, 2.5);
        assert_eq!(m.requests, 12);
        assert_eq!(m.batches, 2);
        assert!((m.mean_batch_size() - 6.0).abs() < 1e-12);
        assert!((m.mean_exec_ms() - 2.0).abs() < 1e-12);
        assert!(m.summary().contains("requests 12"));
    }
}
