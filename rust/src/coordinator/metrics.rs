//! Coordinator metrics: request/batch counters, batch-size and latency
//! distributions.  Shared between the service facade and the worker via
//! `Arc<Mutex<_>>`; snapshots are cheap copies.
//!
//! Distributions are fixed-capacity seeded reservoirs ([`Reservoir`]),
//! not unbounded vectors: under sustained
//! serving traffic the old `Vec<f64>` fields grew without limit, while
//! a reservoir keeps memory constant and still reports exact
//! count/sum/mean plus sampled percentiles.  The reservoirs are
//! deterministic for a given request sequence (owned PCG streams).

use std::sync::{Arc, Mutex};

use crate::util::stats::Reservoir;

/// Retained sample size per distribution.  1024 points bound each
/// reservoir to ~8 KiB while keeping p99 estimates stable.
pub const RESERVOIR_CAP: usize = 1024;

#[derive(Debug, Clone)]
pub struct Metrics {
    pub requests: u64,
    pub batches: u64,
    pub batch_sizes: Reservoir,
    pub exec_ms: Reservoir,
    /// Streaming-path queueing delay (enqueue -> dispatch), recorded
    /// per request by the worker when a dynamic batch is cut.
    pub queue_ms: Reservoir,
    pub compiles: u64,
}

impl Default for Metrics {
    fn default() -> Self {
        // Distinct fixed streams per distribution so the three
        // reservoirs stay independent but reproducible.
        Metrics {
            requests: 0,
            batches: 0,
            batch_sizes: Reservoir::new(RESERVOIR_CAP, 0xba7c),
            exec_ms: Reservoir::new(RESERVOIR_CAP, 0xe8ec),
            queue_ms: Reservoir::new(RESERVOIR_CAP, 0x9e0e),
            compiles: 0,
        }
    }
}

impl Metrics {
    pub fn record_batch(&mut self, size: usize, exec_ms: f64) {
        self.batches += 1;
        self.requests += size as u64;
        self.batch_sizes.push(size as f64);
        self.exec_ms.push(exec_ms);
    }

    pub fn record_queue_ms(&mut self, ms: f64) {
        self.queue_ms.push(ms);
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            0.0
        } else {
            self.batch_sizes.mean()
        }
    }

    pub fn mean_exec_ms(&self) -> f64 {
        if self.exec_ms.is_empty() {
            0.0
        } else {
            self.exec_ms.mean()
        }
    }

    pub fn summary(&self) -> String {
        let lat = if self.exec_ms.is_empty() {
            "n/a".to_string()
        } else {
            let p = self.exec_ms.percentiles(&[50.0, 95.0, 99.0]);
            format!("{:.2}/{:.2}/{:.2} ms (p50/p95/p99)", p[0], p[1], p[2])
        };
        format!(
            "requests {} batches {} mean-batch {:.1} exec {lat} compiles {}",
            self.requests,
            self.batches,
            self.mean_batch_size(),
            self.compiles
        )
    }
}

/// Shared handle.
pub type Shared = Arc<Mutex<Metrics>>;

pub fn shared() -> Shared {
    Arc::new(Mutex::new(Metrics::default()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarises() {
        let mut m = Metrics::default();
        m.record_batch(4, 1.5);
        m.record_batch(8, 2.5);
        assert_eq!(m.requests, 12);
        assert_eq!(m.batches, 2);
        assert!((m.mean_batch_size() - 6.0).abs() < 1e-12);
        assert!((m.mean_exec_ms() - 2.0).abs() < 1e-12);
        assert!(m.summary().contains("requests 12"));
    }

    /// Regression (unbounded growth): sustained traffic must not grow
    /// the distributions past the reservoir cap, while counters and
    /// means stay exact.
    #[test]
    fn sustained_traffic_stays_bounded() {
        let mut m = Metrics::default();
        let n = 50_000u64;
        for i in 0..n {
            m.record_batch(2, (i % 10) as f64);
            m.record_queue_ms((i % 5) as f64);
        }
        assert_eq!(m.requests, 2 * n);
        assert_eq!(m.batches, n);
        assert!(m.batch_sizes.samples().len() <= RESERVOIR_CAP);
        assert!(m.exec_ms.samples().len() <= RESERVOIR_CAP);
        assert!(m.queue_ms.samples().len() <= RESERVOIR_CAP);
        assert!((m.mean_batch_size() - 2.0).abs() < 1e-12);
        assert!((m.mean_exec_ms() - 4.5).abs() < 1e-12);
        assert_eq!(m.queue_ms.count(), n);
        // Percentiles remain available and in-range.
        let p99 = m.exec_ms.percentile(99.0);
        assert!((0.0..=9.0).contains(&p99));
    }

    /// Metrics fed the same request sequence are identical (seeded
    /// reservoirs), so metric snapshots are reproducible.
    #[test]
    fn deterministic_for_a_request_sequence() {
        let feed = || {
            let mut m = Metrics::default();
            for i in 0..5000usize {
                m.record_batch(1 + (i % 7), (i % 13) as f64);
            }
            m
        };
        let (a, b) = (feed(), feed());
        assert_eq!(a.exec_ms.samples(), b.exec_ms.samples());
        assert_eq!(a.batch_sizes.samples(), b.batch_sizes.samples());
        assert_eq!(a.summary(), b.summary());
    }
}
