//! The L3 coordinator: a vLLM-router-style evaluation service over the
//! PJRT runtime — request routing, dynamic batching, worker ownership
//! of executables, metrics, and the ISS/PJRT bit-exactness crosscheck.
//!
//! Offline substrate note: no tokio in this environment, so the event
//! loop is a dedicated worker thread over std mpsc channels (PJRT
//! handles are not Send, so the runtime lives entirely on the worker).

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod service;
