//! Request router: maps (model, variant) keys to executable artifacts
//! and drives fair round-robin dispatch over the per-key batch queues.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{Context, Result};

use super::batcher::{Pending, Queue};
use crate::ml::manifest::Manifest;

/// An executable key: model name + variant ("float", "p32", "p16", ...).
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Key {
    pub model: String,
    pub variant: String,
}

impl Key {
    pub fn new(model: &str, variant: &str) -> Key {
        Key { model: model.to_string(), variant: variant.to_string() }
    }

    pub fn precision(model: &str, p: u32) -> Key {
        Key::new(model, &format!("p{p}"))
    }
}

/// Artifact resolution + per-key queues with round-robin fairness.
pub struct Router<T> {
    queues: BTreeMap<Key, Queue<T>>,
    max_batch: usize,
    linger_ms: u64,
    rr_cursor: usize,
}

impl<T> Router<T> {
    pub fn new(max_batch: usize, linger_ms: u64) -> Router<T> {
        Router { queues: BTreeMap::new(), max_batch, linger_ms, rr_cursor: 0 }
    }

    /// Resolve a key to its HLO artifact path + input dim.  (The output
    /// dim — the uniform score width C — comes from the loaded model's
    /// head; the service supplies it.)
    pub fn resolve(manifest: &Manifest, key: &Key) -> Result<(std::path::PathBuf, usize)> {
        let entry = manifest.model(&key.model)?;
        let path = entry
            .hlo
            .get(&key.variant)
            .with_context(|| format!("{}: no variant {:?}", key.model, key.variant))?
            .clone();
        Ok((path, entry.arch[0]))
    }

    pub fn enqueue(&mut self, key: Key, payload: T) {
        self.queues
            .entry(key)
            .or_insert_with(|| Queue::new(self.max_batch, self.linger_ms))
            .push(payload);
    }

    pub fn pending(&self) -> usize {
        self.queues.values().map(Queue::len).sum()
    }

    /// Next ready batch under round-robin fairness across keys.
    pub fn next_batch(&mut self, now: Instant) -> Option<(Key, Vec<Pending<T>>)> {
        let keys: Vec<Key> = self.queues.keys().cloned().collect();
        if keys.is_empty() {
            return None;
        }
        let n = keys.len();
        for i in 0..n {
            let key = &keys[(self.rr_cursor + i) % n];
            let q = self.queues.get_mut(key).unwrap();
            if q.ready(now) {
                self.rr_cursor = (self.rr_cursor + i + 1) % n;
                return Some((key.clone(), q.drain_batch()));
            }
        }
        None
    }

    /// Force-flush the oldest non-empty queue (shutdown path).
    pub fn flush_any(&mut self) -> Option<(Key, Vec<Pending<T>>)> {
        let key = self
            .queues
            .iter()
            .filter(|(_, q)| !q.is_empty())
            .map(|(k, _)| k.clone())
            .next()?;
        let q = self.queues.get_mut(&key).unwrap();
        Some((key, q.drain_batch()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_is_fair() {
        let mut r: Router<u32> = Router::new(2, 0); // linger 0: always ready
        for i in 0..4 {
            r.enqueue(Key::new("a", "p16"), i);
            r.enqueue(Key::new("b", "p16"), 10 + i);
        }
        let now = Instant::now();
        let mut seen = Vec::new();
        while let Some((k, batch)) = r.next_batch(now) {
            seen.push((k.model.clone(), batch.len()));
        }
        // Alternating a/b batches of 2.
        assert_eq!(
            seen,
            vec![
                ("a".to_string(), 2),
                ("b".to_string(), 2),
                ("a".to_string(), 2),
                ("b".to_string(), 2)
            ]
        );
        assert_eq!(r.pending(), 0);
    }

    #[test]
    fn respects_linger() {
        let mut r: Router<u32> = Router::new(10, 1000);
        r.enqueue(Key::new("a", "p16"), 1);
        assert!(r.next_batch(Instant::now()).is_none()); // not full, not lingered
        assert!(r.flush_any().is_some());
    }

    /// Satellite: a flooding key cannot starve the others — sparse keys
    /// are served within the first rotation, and the hog still drains.
    #[test]
    fn round_robin_resists_flooding() {
        let mut r: Router<u32> = Router::new(2, 0);
        for i in 0..20 {
            r.enqueue(Key::new("hog", "p16"), i);
        }
        r.enqueue(Key::new("a", "p16"), 100);
        r.enqueue(Key::new("b", "p16"), 200);
        let now = Instant::now();
        let mut order = Vec::new();
        while let Some((k, _)) = r.next_batch(now) {
            order.push(k.model);
        }
        let a_pos = order.iter().position(|m| m == "a").unwrap();
        let b_pos = order.iter().position(|m| m == "b").unwrap();
        assert!(a_pos <= 2 && b_pos <= 2, "sparse keys starved: {order:?}");
        assert_eq!(order.iter().filter(|m| *m == "hog").count(), 10);
    }

    /// Satellite: round-robin alternation under unequal queue depths —
    /// the cursor advances past served keys and empty queues are
    /// skipped without stalling the rotation.
    #[test]
    fn round_robin_alternates_under_unequal_load() {
        let mut r: Router<u32> = Router::new(1, 0);
        for i in 0..3 {
            r.enqueue(Key::new("x", "p8"), i);
        }
        for i in 0..6 {
            r.enqueue(Key::new("y", "p8"), i);
        }
        let now = Instant::now();
        let mut order = Vec::new();
        while let Some((k, _)) = r.next_batch(now) {
            order.push(k.model);
        }
        assert_eq!(order, vec!["x", "y", "x", "y", "x", "y", "y", "y", "y"]);
    }

    /// Property: every enqueued item is dispatched exactly once.
    #[test]
    fn prop_no_loss_no_duplication() {
        crate::util::prop::check("router delivery", 100, |rng| {
            let mut r: Router<u64> = Router::new(rng.range_usize(1, 5), 0);
            let key_names = ["a", "b", "c"];
            let n = rng.range_usize(1, 60);
            for i in 0..n {
                let name = key_names[rng.range_usize(0, key_names.len() - 1)];
                r.enqueue(Key::new(name, "p8"), i as u64);
            }
            let mut got = Vec::new();
            let now = Instant::now();
            while let Some((_, batch)) = r.next_batch(now) {
                got.extend(batch.into_iter().map(|p| p.payload));
            }
            got.sort();
            let want: Vec<u64> = (0..n as u64).collect();
            if got != want {
                return Err(format!("delivered {got:?}"));
            }
            Ok(())
        });
    }
}
