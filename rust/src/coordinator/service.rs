//! The evaluation service: the synchronous facade plus the worker
//! thread that owns the PJRT runtime (PJRT handles are not `Send`; the
//! runtime never leaves its thread).
//!
//! Two request paths:
//!
//! * **bulk** — `evaluate()` pipelines pre-chunked batches to the
//!   runtime worker (accuracy sweeps, the DSE, benches); `crosscheck`
//!   drives this path from the service's thread pool, one job per
//!   (model, precision), while the runtime worker itself stays
//!   single-threaded;
//! * **streaming** — `submit()` enqueues single samples which the
//!   worker's router + dynamic batcher coalesce (the `serve` demo and
//!   the smart-packaging example), with round-robin fairness across
//!   models.
//!
//! `crosscheck()` is the three-implementation consistency gate: for
//! every (model, precision), PJRT scores (Pallas-kernel HLO) must match
//! the rust quantised reference and the ISS-executed program.  Each
//! (model, precision) pair is one pool job; the report lines gather in
//! pair order, so the output is deterministic at any thread count.
//!
//! With [`ServiceConfig::iss`] set, quantised (`p ≤ 16`) batches score
//! on the batched lockstep ISS (`sim::batch` through `ml::harness`)
//! instead of PJRT: the dynamic batcher's coalesced batch maps one
//! sample per lane, so serving traffic exercises the real generated
//! programs end-to-end.  `crosscheck` pins those scores to the
//! quantised reference bit-exactly, so the switch is observationally
//! transparent for quantised variants.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::metrics;
use super::router::{Key, Router};
use crate::ml::dataset::Dataset;
use crate::ml::manifest::Manifest;
use crate::ml::model::Model;
use crate::runtime::pjrt::Runtime;
use crate::util::telemetry;
use crate::util::threadpool::{self, ThreadPool};

#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub max_batch: usize,
    pub linger_ms: u64,
    /// Pool size for `crosscheck`'s bulk-path fan-out (the `--threads`
    /// knob); the PJRT runtime always stays on its one worker thread.
    pub threads: usize,
    /// Score quantised variants (`p{N}`, N ≤ 16) on the batched
    /// lockstep ISS (`sim::batch` via `ml::harness`) instead of the
    /// PJRT runtime — every coalesced batch executes lane-parallel on
    /// the generated SIMD-MAC program.  Bit-identical to the PJRT path
    /// for those variants because `crosscheck` pins ISS scores to the
    /// quantised reference exactly; float (and p > 16) requests still
    /// go to PJRT.
    pub iss: bool,
    /// Fraction of ISS batches routed through the redundant-execution
    /// guard (0.0 disables it, 1.0 checks every batch).  A sampled
    /// batch re-executes until two consecutive runs produce byte-equal
    /// scores (bounded retries), and only the agreed scores are served
    /// — so under soft-error injection the served results stay
    /// bit-correct while `pbsp_dual_exec_mismatches_total` counts the
    /// catches.
    pub dual_exec: f64,
    /// Test-only soft-error injector for the ISS backend: expected
    /// MAC-accumulator bit flips per MAC op per lane (0.0 disables).
    /// Accumulator flips corrupt scores but never control flow, so a
    /// re-execution always has a chance to come back clean and the
    /// guard converges.
    pub fault_mac_rate: f64,
    /// Seed of the injector's deterministic per-execution fault plans.
    pub fault_seed: u64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            max_batch: 256,
            linger_ms: 2,
            threads: threadpool::default_threads(),
            iss: false,
            dual_exec: 0.0,
            fault_mac_rate: 0.0,
            fault_seed: 1,
        }
    }
}

/// Sentinel error a streaming reply carries when the request's deadline
/// expired while it sat in the dynamic batcher.  The serving layer
/// matches on this exact string to map the shed to a `504` (the reply
/// channel is `Result<_, String>`, so a sentinel value is the contract).
pub const ERR_DEADLINE: &str = "deadline exceeded in batcher";

/// The precision a key must score at for the ISS backend to take it
/// (SIMD-MAC codegen variants exist for p ≤ 16).
fn iss_precision(key: &Key) -> Option<u32> {
    let p = key.variant.strip_prefix('p')?.parse::<u32>().ok()?;
    (p <= 16).then_some(p)
}

type Scores = Vec<Vec<f64>>;

/// Streaming reply: the scores plus the request's trip through the
/// coordinator, so the serving layer can attach batching detail to
/// trace spans without a second metrics round-trip.
#[derive(Debug, Clone)]
pub struct Scored {
    pub scores: Vec<f64>,
    /// Enqueue → batch-cut wait in the dynamic batcher.
    pub queue_us: u64,
    /// Backend execution time of the batch this request rode in.
    pub exec_us: u64,
    /// Size of that batch.
    pub batch: u32,
}

enum Job {
    Bulk { key: Key, xs: Vec<Vec<f32>>, reply: Sender<Result<Scores, String>> },
    One { key: Key, x: Vec<f32>, deadline: Option<Instant>, reply: Sender<Result<Scored, String>> },
    Shutdown,
}

/// The service handle (facade side).
pub struct Service {
    tx: Sender<Job>,
    worker: Option<JoinHandle<()>>,
    pub manifest: Manifest,
    pub models: Vec<Model>,
    pub metrics: metrics::Shared,
    cfg: ServiceConfig,
    /// Facade-side pool: `crosscheck` fans the bulk path out over it.
    pool: ThreadPool,
}

impl Service {
    pub fn start(cfg: ServiceConfig) -> Result<Service> {
        let dir = crate::artifacts_dir()?;
        let manifest = Manifest::load(&dir)?;
        let models: Vec<Model> =
            manifest.models.iter().map(|e| Model::load(&e.weights)).collect::<Result<_>>()?;
        let shared = metrics::shared();
        let (tx, rx) = channel::<Job>();
        let worker = {
            let manifest = manifest.clone();
            let models = models.clone();
            let shared = shared.clone();
            let cfg = cfg.clone();
            std::thread::Builder::new()
                .name("pbsp-runtime".into())
                .spawn(move || worker_loop(rx, manifest, models, shared, cfg))
                .context("spawn runtime worker")?
        };
        let pool = ThreadPool::new(cfg.threads.max(1));
        Ok(Service { tx, worker: Some(worker), manifest, models, metrics: shared, cfg, pool })
    }

    pub fn model(&self, name: &str) -> Result<&Model> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| anyhow!("unknown model {name:?}"))
    }

    /// Bulk scores for a whole sample set at a precision (or "float").
    /// All chunks are pipelined to the runtime worker before any reply
    /// is collected, so the worker's queue stays full; concurrent
    /// callers (e.g. `crosscheck`'s pool jobs) interleave safely.
    pub fn scores(&self, key: &Key, xs: &[Vec<f32>]) -> Result<Scores> {
        let mut out = Vec::with_capacity(xs.len());
        let chunk_size = self.manifest.batch;
        // Pipeline all chunks, then collect in order.
        let replies: Vec<Receiver<Result<Scores, String>>> = xs
            .chunks(chunk_size)
            .map(|chunk| {
                let (rtx, rrx) = channel();
                self.tx
                    .send(Job::Bulk { key: key.clone(), xs: chunk.to_vec(), reply: rtx })
                    .map_err(|_| anyhow!("worker gone"))?;
                Ok(rrx)
            })
            .collect::<Result<_>>()?;
        for rrx in replies {
            let scores = rrx.recv().context("worker reply")?.map_err(|e| anyhow!(e))?;
            out.extend(scores);
        }
        Ok(out)
    }

    /// Submit one streaming request; returns the reply receiver.
    pub fn submit(&self, key: Key, x: Vec<f32>) -> Result<Receiver<Result<Scored, String>>> {
        self.submit_with_deadline(key, x, None)
    }

    /// [`Service::submit`] with an absolute deadline: if it passes while
    /// the request waits in the dynamic batcher, the request is shed
    /// with [`ERR_DEADLINE`] *before* execution — its batch siblings
    /// score without it instead of paying for work nobody awaits.
    pub fn submit_with_deadline(
        &self,
        key: Key,
        x: Vec<f32>,
        deadline: Option<Instant>,
    ) -> Result<Receiver<Result<Scored, String>>> {
        let (rtx, rrx) = channel();
        self.tx
            .send(Job::One { key, x, deadline, reply: rtx })
            .map_err(|_| anyhow!("worker gone"))?;
        Ok(rrx)
    }

    /// Evaluate accuracy of one model at a precision over a labelled set.
    pub fn evaluate(
        &self,
        model_name: &str,
        precision: u32,
        xs: &[Vec<f32>],
        ys: &[i64],
    ) -> Result<EvalResult> {
        let model = self.model(model_name)?.clone();
        let key = Key::precision(model_name, precision);
        let t0 = Instant::now();
        let scores = self.scores(&key, xs)?;
        let elapsed = t0.elapsed().as_secs_f64() * 1e3;
        let preds: Vec<i64> = scores.iter().map(|s| model.predict(s)).collect();
        let hits = preds.iter().zip(ys).filter(|(p, y)| p == y).count();
        let n_batches = xs.len().div_ceil(self.manifest.batch).max(1);
        Ok(EvalResult {
            model: model_name.to_string(),
            precision,
            n: xs.len(),
            accuracy: hits as f64 / ys.len().max(1) as f64,
            batch_ms_mean: elapsed / n_batches as f64,
            predictions: preds,
        })
    }

    /// Streaming demo: fire single-sample requests round-robin across
    /// all models at p16 and report latency/throughput.
    pub fn demo_load(&self, requests: usize) -> Result<String> {
        let mut pending = Vec::new();
        let data: Vec<Dataset> = self
            .models
            .iter()
            .map(|m| Dataset::load(self.manifest.data_dir(), &m.dataset, "test"))
            .collect::<Result<_>>()?;
        // Warm-up: compile each executable once before timing.
        for (mi, m) in self.models.iter().enumerate() {
            let key = Key::precision(&m.name, 16);
            self.submit(key, data[mi].x[0].clone())?.recv().context("warmup")?.
                map_err(|e| anyhow!(e))?;
        }
        let t0 = Instant::now();
        for i in 0..requests {
            let mi = i % self.models.len();
            let x = data[mi].x[i % data[mi].len()].clone();
            let key = Key::precision(&self.models[mi].name, 16);
            pending.push((Instant::now(), self.submit(key, x)?));
        }
        let mut lat = Vec::with_capacity(requests);
        for (t, rrx) in pending {
            rrx.recv().context("reply")?.map_err(|e| anyhow!(e))?;
            lat.push(t.elapsed().as_secs_f64() * 1e3);
        }
        let wall = t0.elapsed().as_secs_f64();
        let s = crate::util::stats::summarize(&lat);
        let m = self.metrics.lock().unwrap().clone();
        Ok(format!(
            "served {requests} requests in {wall:.3}s ({:.0} req/s)\n\
             latency p50 {:.2} ms, p95 {:.2} ms, p99 {:.2} ms\n\
             coordinator: {}",
            requests as f64 / wall,
            s.p50,
            s.p95,
            s.p99,
            m.summary()
        ))
    }

    /// Three-way consistency check over `samples` per (model, precision):
    /// PJRT (Pallas HLO) vs rust quantised reference vs Zero-Riscy ISS.
    /// One pool job per pair; lines gather in pair order.
    pub fn crosscheck(&self, samples: usize) -> Result<String> {
        use crate::ml::codegen_rv32::{self, Rv32Variant};
        use crate::ml::harness;
        use crate::sim::trace::CyclesOnly;
        let mut xs_per_model: Vec<Vec<Vec<f32>>> = Vec::new();
        for model in &self.models {
            let ds = Dataset::load(self.manifest.data_dir(), &model.dataset, "test")?;
            xs_per_model.push(ds.x.into_iter().take(samples).collect());
        }
        let mut pairs: Vec<(usize, u32)> = Vec::new();
        for mi in 0..self.models.len() {
            for &p in &self.manifest.precisions {
                pairs.push((mi, p));
            }
        }
        let checked = pairs.len();
        let results: Vec<Result<String>> = self.pool.par_map(pairs, |(mi, p)| {
            let model = &self.models[mi];
            let xs = &xs_per_model[mi];
            let key = Key::precision(&model.name, p);
            let pjrt = self.scores(&key, xs)?;
            // Rust quantised reference.
            for (i, x) in xs.iter().enumerate() {
                let want = model.quantized_forward(x, p)?;
                for (a, b) in pjrt[i].iter().zip(&want) {
                    // PJRT computes in f32; the reference in f64.
                    let tol = 1e-4 * (1.0 + b.abs());
                    if (a - b).abs() > tol {
                        bail!("{} p{p} sample {i}: PJRT {a} vs ref {b}", model.name);
                    }
                }
            }
            // ISS (SIMD variants exist for p <= 16).  The check only
            // consumes scores, so skip the utilization profiling work;
            // the harness dispatches on the block-translated engine.
            if p <= 16 {
                let prog = codegen_rv32::generate(model, Rv32Variant::Simd(p))?;
                let run = harness::run_rv32_traced::<CyclesOnly>(model, &prog, xs)?;
                for (i, x) in xs.iter().enumerate() {
                    let want = model.quantized_forward(x, p)?;
                    if run.scores[i] != want {
                        bail!("{} p{p} sample {i}: ISS mismatch", model.name);
                    }
                }
            }
            Ok(format!("{} p{p}: ok ({} samples)", model.name, xs.len()))
        });
        let mut lines = Vec::with_capacity(checked + 1);
        for r in results {
            lines.push(r?);
        }
        lines.push(format!(
            "crosscheck OK: {checked} (model, precision) pairs, 3 implementations agree"
        ));
        Ok(lines.join("\n"))
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Result of a bulk accuracy evaluation.
#[derive(Debug, Clone)]
pub struct EvalResult {
    pub model: String,
    pub precision: u32,
    pub n: usize,
    pub accuracy: f64,
    pub batch_ms_mean: f64,
    pub predictions: Vec<i64>,
}

// ---------------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------------

struct StreamReq {
    x: Vec<f32>,
    /// Absolute point past which nobody is waiting for the reply.
    deadline: Option<Instant>,
    reply: Sender<Result<Scored, String>>,
}

/// Worker-side telemetry handles: registered once at worker start, hit
/// lock-free afterwards.  The labelled request counters are cached per
/// (model, variant) so the registry mutex is taken once per key, not
/// per batch.
struct WorkerTel {
    occupancy: std::sync::Arc<telemetry::Gauge>,
    deadline_shed: std::sync::Arc<telemetry::Counter>,
    requests: std::collections::BTreeMap<String, std::sync::Arc<telemetry::Counter>>,
}

impl WorkerTel {
    fn new() -> WorkerTel {
        WorkerTel {
            occupancy: telemetry::global()
                .gauge("pbsp_batcher_occupancy", "streaming requests waiting in the dynamic batcher"),
            deadline_shed: telemetry::global().counter(
                "pbsp_coordinator_deadline_shed_total",
                "streaming requests shed at batch dispatch because their deadline had passed",
            ),
            requests: std::collections::BTreeMap::new(),
        }
    }

    fn requests_for(&mut self, key: &Key) -> &telemetry::Counter {
        self.requests.entry(format!("{}/{}", key.model, key.variant)).or_insert_with(|| {
            telemetry::global().counter_with(
                "pbsp_coordinator_requests_total",
                &[("model", &key.model), ("variant", &key.variant)],
                "streaming requests dispatched, by model and variant",
            )
        })
    }
}

fn worker_loop(
    rx: Receiver<Job>,
    manifest: Manifest,
    models: Vec<Model>,
    shared: metrics::Shared,
    cfg: ServiceConfig,
) {
    let mut runtime = match Runtime::cpu() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("pbsp worker: PJRT init failed: {e:#}");
            return;
        }
    };
    let out_dim = |key: &Key| -> usize {
        models
            .iter()
            .find(|m| m.name == key.model)
            .map(|m| m.n_outputs())
            .unwrap_or(1)
    };
    let mut router: Router<StreamReq> = Router::new(cfg.max_batch, cfg.linger_ms);
    let mut tel = WorkerTel::new();
    // Dark-corner counters for the translated ISS backend: block
    // dispatches, fused-superinstruction uops, and interpreter-fallback
    // instructions (the translation-divergence metric).
    let iss_tel = {
        let t = telemetry::global();
        (
            t.counter("pbsp_iss_blocks_total", "translated blocks dispatched by the ISS backend"),
            t.counter("pbsp_iss_fused_uops_total", "fused superinstruction uops executed by the ISS backend"),
            t.counter(
                "pbsp_iss_fallback_instrs_total",
                "instructions the ISS executed via the per-instruction interpreter fallback",
            ),
            t.counter("pbsp_iss_samples_total", "samples scored on the ISS backend"),
        )
    };
    // Worker-local cache of generated ISS programs (one codegen per
    // (model, precision), Arc-shared prepared image inside).
    let mut iss_progs: std::collections::BTreeMap<
        String,
        std::sync::Arc<crate::ml::codegen_rv32::Rv32Program>,
    > = std::collections::BTreeMap::new();
    // Redundant-execution guard + injector state.  `dual_acc` samples
    // batches at rate `cfg.dual_exec` (error-diffusion, so 0.25 checks
    // exactly every 4th batch); `exec_counter` keys each ISS execution
    // to its own deterministic fault plans; `mac_horizons` learns each
    // program's MAC ops per sample from its first (uninjected) batch so
    // the injector can aim inside the program's real MAC-op window.
    let dual_tel = {
        let t = telemetry::global();
        (
            t.counter(
                "pbsp_dual_exec_checks_total",
                "ISS batches re-executed by the redundant-execution guard",
            ),
            t.counter(
                "pbsp_dual_exec_mismatches_total",
                "score mismatches between redundant ISS executions of one batch",
            ),
            t.counter(
                "pbsp_dual_exec_reruns_total",
                "extra ISS executions performed by the redundant-execution guard",
            ),
            t.counter(
                "pbsp_fault_plans_injected_total",
                "non-empty fault plans armed by the test-only MAC injector",
            ),
        )
    };
    let mut dual_acc: f64 = 0.0;
    let mut exec_counter: u64 = 0;
    let mut mac_horizons: std::collections::BTreeMap<String, u64> =
        std::collections::BTreeMap::new();

    let mut run_batch = |runtime: &mut Runtime,
                         key: &Key,
                         xs: &[Vec<f32>]|
     -> Result<Scores, String> {
        if cfg.iss {
            if let Some(p) = iss_precision(key) {
                use crate::ml::codegen_rv32::{self, Rv32Variant};
                use crate::ml::harness;
                use crate::sim::fault::{FaultPlan, FaultSpec, MachineShape, Targets};
                use crate::sim::trace::CyclesOnly;
                let model = models
                    .iter()
                    .find(|m| m.name == key.model)
                    .ok_or_else(|| format!("unknown model {:?}", key.model))?;
                let cache_key = format!("{}/{}", key.model, key.variant);
                let (prog, fresh) = match iss_progs.get(&cache_key) {
                    Some(prog) => (std::sync::Arc::clone(prog), false),
                    None => {
                        let prog = std::sync::Arc::new(
                            codegen_rv32::generate(model, Rv32Variant::Simd(p))
                                .map_err(|e| format!("{e:#}"))?,
                        );
                        iss_progs.insert(cache_key.clone(), std::sync::Arc::clone(&prog));
                        (prog, true)
                    }
                };
                let t0 = Instant::now();
                // One lane per sample on the lockstep engine; the
                // dynamic batcher's coalesced batch IS the lane batch.
                // Every execution gets its own injector plans (empty
                // until a MAC-op horizon is learned), so redundant
                // executions see *independent* transient faults —
                // exactly the soft-error model the guard exists for.
                let horizon = mac_horizons.get(&cache_key).copied().unwrap_or(0);
                let exec_once = |exec_id: u64| -> Result<harness::BatchRun, String> {
                    let plans: Vec<FaultPlan> = if cfg.fault_mac_rate > 0.0 && horizon > 0 {
                        let shape =
                            MachineShape::rv32(prog.prepared.ram_bytes, prog.prepared.mac);
                        let spec = FaultSpec {
                            seed: cfg.fault_seed,
                            rate: 0.0,
                            horizon: 1,
                            mac_rate: cfg.fault_mac_rate,
                            mac_horizon: horizon,
                            targets: Targets::MAC,
                        };
                        let plans: Vec<FaultPlan> = (0..xs.len())
                            .map(|lane| {
                                FaultPlan::generate(&spec, &shape, exec_id * 4096 + lane as u64)
                            })
                            .collect();
                        dual_tel.3.add(plans.iter().filter(|pl| !pl.is_empty()).count() as u64);
                        plans
                    } else {
                        Vec::new()
                    };
                    harness::run_rv32_batched_with_plans::<CyclesOnly>(
                        model,
                        &prog,
                        xs,
                        harness::BATCH_LANES,
                        &plans,
                    )
                    .map_err(|e| format!("{e:#}"))
                };
                exec_counter += 1;
                let mut run = exec_once(exec_counter)?;
                dual_acc += cfg.dual_exec;
                if dual_acc >= 1.0 {
                    dual_acc -= 1.0;
                    dual_tel.0.add(1);
                    // Re-execute until two consecutive runs agree
                    // byte-for-byte; serve the agreed scores.  Under
                    // MAC injection most plans are empty, so a pair of
                    // clean runs arrives quickly; the cap turns a
                    // fault storm into a served error, never silently
                    // corrupted data.
                    const MAX_RERUNS: u32 = 32;
                    let mut agreed = false;
                    for _ in 0..MAX_RERUNS {
                        exec_counter += 1;
                        let next = exec_once(exec_counter)?;
                        dual_tel.2.add(1);
                        let same = next.scores == run.scores;
                        run = next;
                        if same {
                            agreed = true;
                            break;
                        }
                        dual_tel.1.add(1);
                    }
                    if !agreed {
                        return Err(format!(
                            "redundant execution: no two consecutive runs of {} agreed after {MAX_RERUNS} retries",
                            key.model
                        ));
                    }
                }
                let per_sample = run.profile.mac_ops / xs.len().max(1) as u64;
                if per_sample > 0 {
                    mac_horizons.insert(cache_key, per_sample);
                }
                let ms = t0.elapsed().as_secs_f64() * 1e3;
                let (blocks, fused, fallback, samples) = &iss_tel;
                blocks.add(run.exec_stats.blocks);
                fused.add(run.exec_stats.fused_uops);
                fallback.add(run.exec_stats.fallback_instrs);
                samples.add(xs.len() as u64);
                let mut m = shared.lock().unwrap();
                m.record_batch(xs.len(), ms);
                if fresh {
                    m.compiles += 1;
                }
                return Ok(run.scores);
            }
        }
        let (path, in_dim) = Router::<StreamReq>::resolve(&manifest, key).map_err(|e| e.to_string())?;
        let fresh = runtime.cached_count();
        let exe = runtime
            .load(&path, manifest.batch, in_dim, out_dim(key))
            .map_err(|e| format!("{e:#}"))?;
        let t0 = Instant::now();
        let scores = exe.run(xs).map_err(|e| format!("{e:#}"))?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        let mut m = shared.lock().unwrap();
        m.record_batch(xs.len(), ms);
        if runtime.cached_count() > fresh {
            m.compiles += 1;
        }
        Ok(scores)
    };

    loop {
        // Wait for work, with a timeout so lingering batches flush.
        let timeout = Duration::from_millis(cfg.linger_ms.max(1));
        match rx.recv_timeout(timeout) {
            Ok(Job::Shutdown) => break,
            Ok(Job::Bulk { key, xs, reply }) => {
                let r = run_batch(&mut runtime, &key, &xs);
                let _ = reply.send(r);
            }
            Ok(Job::One { key, x, deadline, reply }) => {
                tel.occupancy.add(1);
                router.enqueue(key, StreamReq { x, deadline, reply });
                // Opportunistically drain everything already queued.
                while let Ok(job) = rx.try_recv() {
                    match job {
                        Job::One { key, x, deadline, reply } => {
                            tel.occupancy.add(1);
                            router.enqueue(key, StreamReq { x, deadline, reply })
                        }
                        Job::Bulk { key, xs, reply } => {
                            let r = run_batch(&mut runtime, &key, &xs);
                            let _ = reply.send(r);
                        }
                        Job::Shutdown => {
                            drain_router(&mut router, &mut runtime, &mut run_batch, &shared, &mut tel);
                            return;
                        }
                    }
                }
                // Perf (§Perf iteration 1): the channel is empty, so no
                // further coalescing is possible right now — flush
                // everything instead of sleeping out the linger window.
                // Burst loads still batch (the try_recv drain above
                // collected them); only a genuinely idle queue flushes
                // early, collapsing single-request latency from
                // ~linger+timeout to ~execute time.
                drain_router(&mut router, &mut runtime, &mut run_batch, &shared, &mut tel);
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
        }
        // Dispatch ready batches (full or past their linger window).
        let now = Instant::now();
        while let Some((key, batch)) = router.next_batch(now) {
            dispatch(&mut runtime, &key, batch, &mut run_batch, &shared, &mut tel);
        }
    }
    drain_router(&mut router, &mut runtime, &mut run_batch, &shared, &mut tel);
}

fn dispatch(
    runtime: &mut Runtime,
    key: &Key,
    batch: Vec<super::batcher::Pending<StreamReq>>,
    run_batch: &mut impl FnMut(&mut Runtime, &Key, &[Vec<f32>]) -> Result<Scores, String>,
    shared: &metrics::Shared,
    tel: &mut WorkerTel,
) {
    let now = Instant::now();
    tel.occupancy.sub(batch.len() as i64);
    // Shed requests whose deadline passed while they waited: nobody is
    // listening for those replies any more, and executing them would
    // only tax their batch siblings.  The shed happens *before* the
    // batch is built, so siblings score exactly as if the dead request
    // had never arrived.
    let (batch, dead): (Vec<_>, Vec<_>) = batch
        .into_iter()
        .partition(|p| p.payload.deadline.map(|d| now < d).unwrap_or(true));
    if !dead.is_empty() {
        tel.deadline_shed.add(dead.len() as u64);
        for p in dead {
            let _ = p.payload.reply.send(Err(ERR_DEADLINE.to_string()));
        }
    }
    if batch.is_empty() {
        return;
    }
    // Streaming queueing delay (enqueue -> dispatch), per request.
    let queue_us: Vec<u64> = batch
        .iter()
        .map(|p| now.duration_since(p.enqueued).as_micros() as u64)
        .collect();
    {
        let mut m = shared.lock().unwrap();
        for us in &queue_us {
            m.record_queue_ms(*us as f64 / 1e3);
        }
    }
    tel.requests_for(key).add(batch.len() as u64);
    let xs: Vec<Vec<f32>> = batch.iter().map(|p| p.payload.x.clone()).collect();
    let t0 = Instant::now();
    match run_batch(runtime, key, &xs) {
        Ok(scores) => {
            let exec_us = t0.elapsed().as_micros() as u64;
            let n = xs.len() as u32;
            for ((p, s), q) in batch.into_iter().zip(scores).zip(queue_us) {
                let _ = p.payload.reply.send(Ok(Scored {
                    scores: s,
                    queue_us: q,
                    exec_us,
                    batch: n,
                }));
            }
        }
        Err(e) => {
            for p in batch {
                let _ = p.payload.reply.send(Err(e.clone()));
            }
        }
    }
}

fn drain_router(
    router: &mut Router<StreamReq>,
    runtime: &mut Runtime,
    run_batch: &mut impl FnMut(&mut Runtime, &Key, &[Vec<f32>]) -> Result<Scores, String>,
    shared: &metrics::Shared,
    tel: &mut WorkerTel,
) {
    while let Some((key, batch)) = router.flush_any() {
        dispatch(runtime, &key, batch, run_batch, shared, tel);
    }
}
