//! The reduction pass: map measured utilization to a bespoke core
//! configuration (§III-A) — remove the Debug / IRQ / compressed-decoder
//! units, trim unused instructions out of the decode/control logic,
//! shrink the register file to the registers actually used, and narrow
//! the PC and base-address registers to the measured reach.

use super::profile::Utilization;
use crate::hw::mac_unit::MacConfig;
use crate::hw::synth::{zero_riscy, CoreSpec, MulOption};
use crate::sim::zero_riscy::ALL_MNEMONICS;

/// Derive the bespoke Zero-Riscy configuration from a utilization
/// report, with the multiplier option of the target variant (paper
/// Table I rows: `B`, `B MAC 32`, `B MAC P16/P8/P4`).
pub fn bespoke_zero_riscy(u: &Utilization, mul: MulOption) -> CoreSpec {
    let mut spec = zero_riscy();
    spec.name = match mul {
        MulOption::Baseline => "zero-riscy-bespoke".into(),
        MulOption::None => "zero-riscy-bespoke-nomul".into(),
        MulOption::Mac(cfg) => format!("zero-riscy-bespoke-mac-p{}", cfg.precision),
    };

    // Register file: keep exactly the registers the workload set
    // touches (paper: "12 registers are sufficient ... allowing for
    // the removal of the rest").
    spec.regs = u.regs_needed.max(8);

    // PC / BAR narrowing to the measured reach (paper: PC 32 -> 10,
    // BAR 32 -> 8).
    spec.pc_bits = u.pc_bits_needed.max(8);
    spec.bar_bits = u.bar_bits_needed.max(8);

    // Unit removal: nothing in the workload set uses debug, interrupts
    // or compressed instructions (paper: "the Debug, Interrupt
    // Controller, and Compressed Decoder Unit are not utilized and are
    // completely removed").
    spec.has_debug = false;
    spec.has_irq = u.profile.syscalls_used; // no traps -> no IRQ ctl
    spec.has_compressed_dec = false;

    // CSR block: trim to a rump of counters unless CSRs are exercised.
    spec.csr_fraction = if u.profile.csr_used { 1.0 } else { 0.15 };

    // Decoder/controller trim proportional to the retained ISA.
    let used = ALL_MNEMONICS.len() - u.unused_instructions.len();
    spec.isa_fraction = used as f64 / ALL_MNEMONICS.len() as f64;

    // Multiplier option.  When a MAC replaces the multiplier, the MUL/
    // MULH decode paths go away with it (already counted in the ISA
    // fraction only if unused; the multi-stage unit itself is swapped).
    spec.mul = mul;
    spec
}

/// The Table I variant list: bespoke core specs for B, MAC32, P16, P8, P4.
pub fn table1_variants(u: &Utilization) -> Vec<(String, CoreSpec)> {
    let mut out = vec![
        ("ZR B".to_string(), bespoke_zero_riscy(u, MulOption::Baseline)),
        (
            "ZR B MAC 32".to_string(),
            bespoke_zero_riscy(u, MulOption::Mac(MacConfig::new(32, 32))),
        ),
    ];
    for p in [16u32, 8, 4] {
        out.push((
            format!("ZR B MAC P{p}"),
            bespoke_zero_riscy(u, MulOption::Mac(MacConfig::new(32, p))),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bespoke::profile::profile_suite;
    use crate::hw::egfet::egfet;
    use crate::hw::synth::synthesize;

    #[test]
    fn bespoke_reduces_area_and_power() {
        let u = profile_suite().unwrap();
        let tech = egfet();
        let base = synthesize(&zero_riscy(), &tech);
        let b = synthesize(&bespoke_zero_riscy(&u, MulOption::Baseline), &tech);
        let area_gain = 1.0 - b.area_mm2 / base.area_mm2;
        let power_gain = 1.0 - b.power_mw / base.power_mw;
        // Paper Table I: 10.6% / 11.4%.  Our analytical RF/CSR model
        // prunes more aggressively than their synthesis (documented in
        // EXPERIMENTS.md); the gain must be positive and meaningful but
        // not implausibly large.
        assert!((0.05..=0.45).contains(&area_gain), "area gain {area_gain}");
        assert!((0.05..=0.45).contains(&power_gain), "power gain {power_gain}");
    }

    #[test]
    fn table1_variant_ordering() {
        // Area gains: MAC32 < B < P16 < P8 < P4 (Table I shape).
        let u = profile_suite().unwrap();
        let tech = egfet();
        let base = synthesize(&zero_riscy(), &tech).area_mm2;
        let gains: Vec<(String, f64)> = table1_variants(&u)
            .into_iter()
            .map(|(name, spec)| (name, 1.0 - synthesize(&spec, &tech).area_mm2 / base))
            .collect();
        let b = gains[0].1;
        let m32 = gains[1].1;
        let p16 = gains[2].1;
        let p8 = gains[3].1;
        let p4 = gains[4].1;
        assert!(m32 < b, "MAC32 gain {m32} should dip below B {b}");
        assert!(p16 > b && p8 > p16 && p4 > p8, "{gains:?}");
    }

    #[test]
    fn bespoke_keeps_core_functional_units() {
        let u = profile_suite().unwrap();
        let spec = bespoke_zero_riscy(&u, MulOption::Baseline);
        // ALU/RF/IF/ID/LSU must remain.
        let kinds: Vec<_> = spec.units().iter().map(|un| un.kind).collect();
        use crate::hw::synth::UnitKind::*;
        for k in [RegFile, Alu, Lsu, IfStage, Decoder, Controller] {
            assert!(kinds.contains(&k), "{k:?} missing");
        }
        assert!(!kinds.contains(&Debug));
        assert!(!kinds.contains(&CompressedDec));
    }
}
