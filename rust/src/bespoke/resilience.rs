//! Seeded soft-error campaigns on the batched ISS (the `pbsp faultsim`
//! subcommand).
//!
//! Printed EGFET parts live with transient upsets and permanently weak
//! cells; this module measures what those faults *do* to the deployed
//! classifier instead of guessing.  For every selected (model, core,
//! precision) configuration it:
//!
//! 1. runs a fault-free baseline over a fixed sample set to learn the
//!    per-sample instruction and MAC-op horizons (and the reference
//!    scores/predictions);
//! 2. sweeps transient fault rates: `trials` Monte Carlo runs per rate,
//!    each trial's [`FaultPlan`] a pure function of `(seed, trial
//!    index)` (`sim::fault`), executed one trial per lane on the
//!    batched lockstep engine — so a 200-trial campaign costs a few
//!    batched dispatches, and the numbers are bit-identical at any
//!    `PBSP_THREADS`;
//! 3. classifies every trial against the baseline: **masked** (scores
//!    bit-equal), **tolerated** (scores differ, prediction survives),
//!    **SDC** (silent data corruption — the prediction flips),
//!    **crash** (execution fault) or **hang** (fuel exhausted);
//! 4. repeats the sweep restricted to one target class at a time
//!    (register file / data RAM / MAC accumulators) at the largest
//!    swept rate — the architectural-vulnerability breakdown;
//! 5. probes `rom_trials` seeded stuck-at bits in the constant/weight
//!    memory ([`sim::fault::rv32_with_stuck_rom`] /
//!    [`sim::fault::tpisa_with_stuck_dmem`]) and ranks the critical
//!    bits by how many sample predictions they break.
//!
//! Configurations fan out over the context's thread pool, one pool job
//! per (model, core); results gather in job order, so the report text
//! and JSON artifact are byte-identical at any thread count.

use std::sync::Arc;

use anyhow::Result;

use crate::dse::context::EvalContext;
use crate::ml::codegen_rv32::Rv32Variant;
use crate::ml::codegen_tpisa::TpVariant;
use crate::ml::harness::{self, FaultOutcome};
use crate::ml::model::Model;
use crate::sim::fault::{self, FaultPlan, FaultSpec, MachineShape, RomStuck, Targets};
use crate::sim::trace::FullProfile;
use crate::util::json::Value;
use crate::util::rng::Pcg32;

/// Campaign parameters (the `faultsim` CLI flags).
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    pub seed: u64,
    /// Monte Carlo trials per swept rate (and per AVF class).
    pub trials: usize,
    /// Test-set samples per configuration; trials cycle through them.
    pub samples: usize,
    /// Transient fault rates swept: expected register/RAM flips per
    /// retired instruction (MAC flips use the same rate per MAC op).
    pub rates: Vec<f64>,
    /// Seeded stuck-at bits probed in the constant/weight memory.
    pub rom_trials: usize,
    /// Quantisation precision of the generated programs.
    pub precision: u32,
    /// Include the Zero-Riscy SIMD-MAC configurations.
    pub zero_riscy: bool,
    /// Include the TP-ISA MAC configurations.
    pub tpisa: bool,
    /// TP-ISA datapath width.
    pub datapath: u32,
    /// Restrict to these model names (empty = all).
    pub models: Vec<String>,
}

impl Default for CampaignConfig {
    fn default() -> Self {
        CampaignConfig {
            seed: 0xb10f,
            trials: 200,
            samples: 8,
            rates: vec![0.0, 1e-7, 1e-6, 1e-5, 1e-4],
            rom_trials: 32,
            precision: 8,
            zero_riscy: true,
            tpisa: true,
            datapath: 8,
            models: Vec::new(),
        }
    }
}

/// Trial classification tallies for one (rate, target-class) sweep.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OutcomeCounts {
    /// Scores bit-equal to the fault-free baseline.
    pub masked: usize,
    /// Scores corrupted but the prediction survived.
    pub tolerated: usize,
    /// Silent data corruption: the prediction flipped.
    pub sdc: usize,
    /// Execution fault (wild PC after a flipped pointer, …).
    pub crash: usize,
    /// Fuel budget exhausted (corrupted loop state livelocked).
    pub hang: usize,
}

impl OutcomeCounts {
    pub fn total(&self) -> usize {
        self.masked + self.tolerated + self.sdc + self.crash + self.hang
    }

    /// Tally one trial against its sample's baseline.  `predict` is the
    /// model's prediction head (injected so the tally logic is testable
    /// without artifacts).
    pub fn classify(
        &mut self,
        outcome: &FaultOutcome,
        base_scores: &[f64],
        base_pred: i64,
        predict: impl Fn(&[f64]) -> i64,
    ) {
        match outcome {
            FaultOutcome::Scores(s) if s[..] == *base_scores => self.masked += 1,
            FaultOutcome::Scores(s) => {
                if predict(s) == base_pred {
                    self.tolerated += 1;
                } else {
                    self.sdc += 1;
                }
            }
            FaultOutcome::Crash(_) => self.crash += 1,
            FaultOutcome::Hang => self.hang += 1,
        }
    }

    /// Fraction of trials whose served prediction survived — the y axis
    /// of the accuracy-vs-fault-rate curve (relative to the fault-free
    /// baseline, so 1.0 means no accuracy lost to faults).
    pub fn pred_survival(&self) -> f64 {
        (self.masked + self.tolerated) as f64 / self.total().max(1) as f64
    }
}

/// One probed stuck-at bit and the damage it did.
#[derive(Debug, Clone, Copy)]
pub struct RomTrialResult {
    /// Offset into the constant/weight region (byte on RV32, word on
    /// TP-ISA).
    pub offset: u32,
    pub bit: u8,
    pub stuck_one: bool,
    /// Sample predictions broken by this bit (crash/hang counts too).
    pub mispredicts: usize,
    pub samples: usize,
}

/// Campaign output for one (model, core, precision) configuration.
#[derive(Debug, Clone)]
pub struct ConfigResult {
    pub model: String,
    pub core: String,
    pub precision: u32,
    /// Fault-free instructions per sample (the transient-flip horizon).
    pub instr_per_sample: u64,
    /// Fault-free MAC accumulates per sample (the MAC-flip horizon).
    pub mac_ops_per_sample: u64,
    /// (rate, tallies) per swept rate, in `rates` order.
    pub curve: Vec<(f64, OutcomeCounts)>,
    /// Per-target-class tallies at the largest swept rate:
    /// ("regs" | "ram" | "mac", tallies).
    pub avf: Vec<(String, OutcomeCounts)>,
    /// Stuck-at probes, worst (most broken predictions) first.
    pub rom: Vec<RomTrialResult>,
}

/// Rendered campaign: human-readable text plus the JSON artifact the CI
/// job uploads.
#[derive(Debug, Clone)]
pub struct ResilienceReport {
    pub text: String,
    pub json: Value,
    pub configs: Vec<ConfigResult>,
}

/// Decorrelate per-configuration PCG streams: FNV-1a over the config
/// label folded into the campaign seed, so two configurations with the
/// same machine shape still draw independent fault sites.
fn config_seed(seed: u64, label: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64 ^ seed;
    for b in label.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Run the campaign over every selected configuration (one pool job per
/// (model, core) pair, gathered in job order).
pub fn campaign(ctx: &EvalContext, cfg: &CampaignConfig) -> Result<ResilienceReport> {
    let mut jobs: Vec<(usize, bool)> = Vec::new();
    for (mi, m) in ctx.models.iter().enumerate() {
        if !cfg.models.is_empty() && !cfg.models.iter().any(|n| n == &m.name) {
            continue;
        }
        if cfg.zero_riscy {
            jobs.push((mi, false));
        }
        if cfg.tpisa {
            jobs.push((mi, true));
        }
    }
    anyhow::ensure!(!jobs.is_empty(), "no (model, core) configurations selected");
    let results: Vec<Result<Option<ConfigResult>>> = ctx.pool().par_map(jobs, |(mi, tp)| {
        if tp {
            run_config_tp(ctx, cfg, mi)
        } else {
            run_config_zr(ctx, cfg, mi).map(Some)
        }
    });
    let mut configs = Vec::new();
    for r in results {
        if let Some(c) = r? {
            configs.push(c);
        }
    }
    Ok(render(cfg, configs))
}

fn run_config_zr(ctx: &EvalContext, cfg: &CampaignConfig, mi: usize) -> Result<ConfigResult> {
    let model = &ctx.models[mi];
    let prog = ctx.rv32_program(mi, Rv32Variant::Simd(cfg.precision))?;
    let xs: Vec<Vec<f32>> =
        ctx.test_sets[mi].x.iter().take(cfg.samples.max(1)).cloned().collect();
    let base = harness::run_rv32_traced::<FullProfile>(model, &prog, &xs)?;
    let shape = MachineShape::rv32(prog.prepared.ram_bytes, prog.prepared.mac);
    let rom_len = prog.prepared.rom.len() as u64 - prog.prepared.data_base() as u64;
    let exec = |txs: &[Vec<f32>],
                plans: &[FaultPlan],
                stuck: Option<RomStuck>,
                fuel: u64|
     -> Result<Vec<FaultOutcome>> {
        let prepared = match stuck {
            Some(s) => fault::rv32_with_stuck_rom(&prog.prepared, s),
            None => Arc::clone(&prog.prepared),
        };
        harness::run_rv32_faulted(model, &prog, &prepared, txs, plans, harness::BATCH_LANES, fuel)
    };
    run_config(cfg, model, "zero-riscy", &xs, &base, &shape, rom_len, 8, exec)
}

/// TP-ISA configurations skip (return `None`) when codegen rejects the
/// (model, datapath, precision) combination — the sweep's notion of an
/// infeasible design point.
fn run_config_tp(
    ctx: &EvalContext,
    cfg: &CampaignConfig,
    mi: usize,
) -> Result<Option<ConfigResult>> {
    let model = &ctx.models[mi];
    let Ok(prog) = ctx.tpisa_program(mi, cfg.datapath, TpVariant::Mac { precision: cfg.precision })
    else {
        return Ok(None);
    };
    let xs: Vec<Vec<f32>> =
        ctx.test_sets[mi].x.iter().take(cfg.samples.max(1)).cloned().collect();
    let base = harness::run_tpisa_traced::<FullProfile>(model, &prog, &xs)?;
    let shape =
        MachineShape::tpisa(prog.datapath, prog.prepared.init_dmem.len(), prog.prepared.mac);
    let core = format!("tp-isa-d{}", cfg.datapath);
    let exec = |txs: &[Vec<f32>],
                plans: &[FaultPlan],
                stuck: Option<RomStuck>,
                fuel: u64|
     -> Result<Vec<FaultOutcome>> {
        let prepared = match stuck {
            Some(s) => fault::tpisa_with_stuck_dmem(&prog.prepared, s),
            None => Arc::clone(&prog.prepared),
        };
        harness::run_tpisa_faulted(model, &prog, &prepared, txs, plans, harness::BATCH_LANES, fuel)
    };
    let rom_len = prog.prepared.init_dmem.len() as u64;
    run_config(cfg, model, &core, &xs, &base, &shape, rom_len, cfg.datapath as u64, exec)
        .map(Some)
}

/// Core-agnostic campaign body: rate sweep, AVF breakdown, stuck-at
/// probe.  `exec` runs one trial batch (one trial per lane); `rom_len` /
/// `rom_bits` bound the stuck-at probe's offset and bit draws.
#[allow(clippy::too_many_arguments)]
fn run_config(
    cfg: &CampaignConfig,
    model: &Model,
    core: &str,
    xs: &[Vec<f32>],
    base: &harness::BatchRun,
    shape: &MachineShape,
    rom_len: u64,
    rom_bits: u64,
    exec: impl Fn(&[Vec<f32>], &[FaultPlan], Option<RomStuck>, u64) -> Result<Vec<FaultOutcome>>,
) -> Result<ConfigResult> {
    let n = xs.len() as u64;
    let instr_per_sample = (base.profile.instructions / n.max(1)).max(1);
    let mac_ops_per_sample = (base.profile.mac_ops / n.max(1)).max(1);
    // Hang horizon: well past the longest clean run, far below the
    // production budget so livelocked trials classify quickly.
    let fuel = (instr_per_sample * 8).max(10_000);
    let label = format!("{}/{}/p{}", model.name, core, cfg.precision);
    let seed = config_seed(cfg.seed, &label);
    let trial_xs: Vec<Vec<f32>> =
        (0..cfg.trials).map(|t| xs[t % xs.len()].clone()).collect();
    let classify_all = |outcomes: &[FaultOutcome]| {
        let mut c = OutcomeCounts::default();
        for (t, o) in outcomes.iter().enumerate() {
            let si = t % xs.len();
            c.classify(o, &base.scores[si], base.predictions[si], |s| model.predict(s));
        }
        c
    };
    // Global trial counter: every sweep consumes a fresh index range,
    // so no two trials anywhere in this configuration share a PCG
    // stream.
    let mut next_trial: u64 = 0;
    let mut sweep = |rate: f64, targets: Targets| -> Result<OutcomeCounts> {
        let spec = FaultSpec {
            seed,
            rate,
            horizon: instr_per_sample,
            mac_rate: rate,
            mac_horizon: mac_ops_per_sample,
            targets,
        };
        let plans: Vec<FaultPlan> = (0..cfg.trials)
            .map(|t| FaultPlan::generate(&spec, shape, next_trial + t as u64))
            .collect();
        next_trial += cfg.trials as u64;
        Ok(classify_all(&exec(&trial_xs, &plans, None, fuel)?))
    };
    let mut curve = Vec::new();
    for &rate in &cfg.rates {
        curve.push((rate, sweep(rate, Targets::ALL)?));
    }
    let probe_rate = cfg.rates.iter().copied().fold(0.0f64, f64::max);
    let mut avf = Vec::new();
    if probe_rate > 0.0 {
        for (name, t) in
            [("regs", Targets::REGS), ("ram", Targets::RAM), ("mac", Targets::MAC)]
        {
            avf.push((name.to_string(), sweep(probe_rate, t)?));
        }
    }
    let mut rom = Vec::new();
    if rom_len > 0 {
        let mut rng = Pcg32::new(seed, 0x526f_6d42);
        for _ in 0..cfg.rom_trials {
            let s = RomStuck {
                offset: rng.below(rom_len) as u32,
                bit: rng.below(rom_bits.max(1)) as u8,
                stuck_one: rng.bool(),
            };
            let outcomes = exec(xs, &[], Some(s), fuel)?;
            let mispredicts = outcomes
                .iter()
                .enumerate()
                .filter(|(i, o)| match o {
                    FaultOutcome::Scores(sc) => model.predict(sc) != base.predictions[*i],
                    _ => true,
                })
                .count();
            rom.push(RomTrialResult {
                offset: s.offset,
                bit: s.bit,
                stuck_one: s.stuck_one,
                mispredicts,
                samples: xs.len(),
            });
        }
        rom.sort_by_key(|r| (std::cmp::Reverse(r.mispredicts), r.offset, r.bit));
    }
    Ok(ConfigResult {
        model: model.name.clone(),
        core: core.to_string(),
        precision: cfg.precision,
        instr_per_sample,
        mac_ops_per_sample,
        curve,
        avf,
        rom,
    })
}

fn num(v: f64) -> Value {
    Value::Num(v)
}

fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn counts_json(oc: &OutcomeCounts) -> Vec<(&'static str, Value)> {
    vec![
        ("masked", num(oc.masked as f64)),
        ("tolerated", num(oc.tolerated as f64)),
        ("sdc", num(oc.sdc as f64)),
        ("crash", num(oc.crash as f64)),
        ("hang", num(oc.hang as f64)),
        ("survival", num(oc.pred_survival())),
    ]
}

fn render(cfg: &CampaignConfig, configs: Vec<ConfigResult>) -> ResilienceReport {
    use std::fmt::Write as _;
    let mut text = String::new();
    let _ = writeln!(
        text,
        "Fault-injection campaign: seed {:#x}, {} trials/rate, {} samples/config",
        cfg.seed, cfg.trials, cfg.samples
    );
    for c in &configs {
        let _ = writeln!(
            text,
            "\n== {} / {} p{} — {} instr, {} mac ops per sample ==",
            c.model, c.core, c.precision, c.instr_per_sample, c.mac_ops_per_sample
        );
        let _ = writeln!(
            text,
            "{:>10}  {:>6} {:>9} {:>5} {:>5} {:>5}  {:>8}",
            "rate", "masked", "tolerated", "sdc", "crash", "hang", "survive"
        );
        for (rate, oc) in &c.curve {
            let _ = writeln!(
                text,
                "{:>10.1e}  {:>6} {:>9} {:>5} {:>5} {:>5}  {:>7.1}%",
                rate,
                oc.masked,
                oc.tolerated,
                oc.sdc,
                oc.crash,
                oc.hang,
                oc.pred_survival() * 100.0
            );
        }
        for (name, oc) in &c.avf {
            let _ = writeln!(
                text,
                "AVF {:>4}: {:>5.1}% vulnerable (sdc {} crash {} hang {} / {})",
                name,
                (1.0 - oc.pred_survival()) * 100.0,
                oc.sdc,
                oc.crash,
                oc.hang,
                oc.total()
            );
        }
        if !c.rom.is_empty() {
            let critical = c.rom.iter().filter(|r| r.mispredicts > 0).count();
            let _ = writeln!(
                text,
                "ROM stuck-at: {critical}/{} probed bits break at least one prediction",
                c.rom.len()
            );
            for r in c.rom.iter().take(3) {
                if r.mispredicts == 0 {
                    break;
                }
                let _ = writeln!(
                    text,
                    "  critical: data+{:#x} bit {} stuck-{} -> {}/{} mispredict",
                    r.offset,
                    r.bit,
                    u8::from(r.stuck_one),
                    r.mispredicts,
                    r.samples
                );
            }
        }
    }
    let json = obj(vec![
        ("seed", num(cfg.seed as f64)),
        ("trials", num(cfg.trials as f64)),
        ("samples", num(cfg.samples as f64)),
        ("rates", Value::Arr(cfg.rates.iter().map(|r| num(*r)).collect())),
        (
            "configs",
            Value::Arr(
                configs
                    .iter()
                    .map(|c| {
                        obj(vec![
                            ("model", Value::Str(c.model.clone())),
                            ("core", Value::Str(c.core.clone())),
                            ("precision", num(c.precision as f64)),
                            ("instr_per_sample", num(c.instr_per_sample as f64)),
                            ("mac_ops_per_sample", num(c.mac_ops_per_sample as f64)),
                            (
                                "curve",
                                Value::Arr(
                                    c.curve
                                        .iter()
                                        .map(|(rate, oc)| {
                                            let mut pairs = vec![("rate", num(*rate))];
                                            pairs.extend(counts_json(oc));
                                            obj(pairs)
                                        })
                                        .collect(),
                                ),
                            ),
                            (
                                "avf",
                                Value::Arr(
                                    c.avf
                                        .iter()
                                        .map(|(name, oc)| {
                                            let mut pairs =
                                                vec![("class", Value::Str(name.clone()))];
                                            pairs.extend(counts_json(oc));
                                            obj(pairs)
                                        })
                                        .collect(),
                                ),
                            ),
                            (
                                "rom",
                                Value::Arr(
                                    c.rom
                                        .iter()
                                        .map(|r| {
                                            obj(vec![
                                                ("offset", num(r.offset as f64)),
                                                ("bit", num(r.bit as f64)),
                                                ("stuck_one", Value::Bool(r.stuck_one)),
                                                ("mispredicts", num(r.mispredicts as f64)),
                                                ("samples", num(r.samples as f64)),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    ResilienceReport { text, json, configs }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argmax(s: &[f64]) -> i64 {
        s.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i64)
            .unwrap_or(0)
    }

    #[test]
    fn classification_covers_every_outcome() {
        let base = vec![0.1, 0.9];
        let mut c = OutcomeCounts::default();
        c.classify(&FaultOutcome::Scores(vec![0.1, 0.9]), &base, 1, argmax);
        c.classify(&FaultOutcome::Scores(vec![0.2, 0.8]), &base, 1, argmax);
        c.classify(&FaultOutcome::Scores(vec![0.9, 0.1]), &base, 1, argmax);
        c.classify(&FaultOutcome::Crash("pc".into()), &base, 1, argmax);
        c.classify(&FaultOutcome::Hang, &base, 1, argmax);
        assert_eq!(
            c,
            OutcomeCounts { masked: 1, tolerated: 1, sdc: 1, crash: 1, hang: 1 }
        );
        assert_eq!(c.total(), 5);
        assert!((c.pred_survival() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn empty_counts_survival_is_safe() {
        assert_eq!(OutcomeCounts::default().pred_survival(), 0.0);
    }

    #[test]
    fn config_seeds_decorrelate_labels() {
        let a = config_seed(7, "mnist/zero-riscy/p8");
        let b = config_seed(7, "mnist/tp-isa-d8/p8");
        assert_ne!(a, b);
        assert_eq!(a, config_seed(7, "mnist/zero-riscy/p8"));
        assert_ne!(a, config_seed(8, "mnist/zero-riscy/p8"));
    }
}
