//! Utilization profiling (workflow step ③): run the application set on
//! the baseline core and record which instructions, registers, CSRs and
//! address ranges are actually exercised.

use anyhow::Result;

use crate::ml::codegen_rv32::{self, Rv32Variant, RAM_BYTES};
use crate::ml::model::Model;
use crate::ml::{harness, microbench};
use crate::sim::trace::Profile;
use crate::sim::zero_riscy::{Halt, ZeroRiscy, ALL_MNEMONICS};

/// A utilization report over a workload set.
#[derive(Debug, Clone)]
pub struct Utilization {
    pub profile: Profile,
    pub unused_instructions: Vec<&'static str>,
    pub regs_needed: u32,
    pub pc_bits_needed: u32,
    pub bar_bits_needed: u32,
    pub workloads: Vec<String>,
}

impl Utilization {
    pub fn from_profile(profile: Profile, workloads: Vec<String>) -> Utilization {
        Utilization {
            unused_instructions: profile.unused_mnemonics(ALL_MNEMONICS),
            regs_needed: profile.reg_count(),
            pc_bits_needed: profile.pc_bits_needed(),
            bar_bits_needed: profile.bar_bits_needed(),
            profile,
            workloads,
        }
    }
}

/// Profile the §III-A suite (MLP, DT, mul/div, insertion sort) on the
/// baseline Zero-Riscy.
pub fn profile_suite() -> Result<Utilization> {
    let mut merged = Profile::default();
    let mut names = Vec::new();
    for (name, prog) in microbench::suite()? {
        let mut sim = ZeroRiscy::new(&prog, &[], RAM_BYTES, None);
        anyhow::ensure!(sim.run(10_000_000)? == Halt::Break, "{name} did not halt");
        merged.merge(&sim.profile);
        names.push(name.to_string());
    }
    Ok(Utilization::from_profile(merged, names))
}

/// Profile the six ML models (baseline codegen) on the baseline core,
/// over a few samples each.
pub fn profile_models(models: &[Model], samples: &[Vec<Vec<f32>>]) -> Result<Utilization> {
    let mut merged = Profile::default();
    let mut names = Vec::new();
    for (model, xs) in models.iter().zip(samples) {
        let prog = codegen_rv32::generate(model, Rv32Variant::Baseline)?;
        let run = harness::run_rv32(model, &prog, xs)?;
        merged.merge(&run.profile);
        names.push(model.name.clone());
    }
    Ok(Utilization::from_profile(merged, names))
}

/// Combined utilization of the suite + models (the paper's workload set).
pub fn profile_all(models: &[Model], samples: &[Vec<Vec<f32>>]) -> Result<Utilization> {
    let mut u = profile_suite()?;
    let m = profile_models(models, samples)?;
    u.profile.merge(&m.profile);
    let mut workloads = u.workloads.clone();
    workloads.extend(m.workloads);
    Ok(Utilization::from_profile(u.profile, workloads))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_utilization_matches_paper_observations() {
        let u = profile_suite().unwrap();
        // §III-A: "the SLT, most CSR, System Calls, and MULH
        // instructions remain unused".
        for m in ["slt", "csrrw", "csrrs", "ecall", "mulh", "mulhsu", "mulhu"] {
            assert!(u.unused_instructions.contains(&m), "{m}");
        }
        // "12 registers are sufficient" — the suite stays in that
        // neighbourhood.
        assert!(u.regs_needed <= 14, "regs {}", u.regs_needed);
        // "reduction of the PC from 32 bits to 10" — code is tiny.
        assert!(u.pc_bits_needed <= 12, "pc bits {}", u.pc_bits_needed);
        assert!(u.bar_bits_needed <= 12, "bar bits {}", u.bar_bits_needed);
        assert!(!u.profile.csr_used);
    }
}
