//! Utilization profiling (workflow step ③): run the application set on
//! the baseline core and record which instructions, registers, CSRs and
//! address ranges are actually exercised.
//!
//! Each workload runs as one thread-pool job; the per-workload profiles
//! are merged in workload order, so the resulting [`Utilization`] is
//! identical at any thread count.
//!
//! This pass is the one consumer that needs the ISS's **full** trace
//! (instruction histogram, register bitmask, PC/BAR reach), so it runs
//! the simulators in `FullProfile` mode — the cycle sweeps and
//! accuracy/crosscheck runs use the `CyclesOnly` fast path instead
//! (see `sim::trace::TraceMode`).  Both tracing modes execute on the
//! block-translated engine (`run_translated`), whose full profiles are
//! bit-identical to the per-instruction interpreter
//! (`tests/iss_equivalence.rs`).

use anyhow::Result;

use crate::ml::codegen_rv32::{self, Rv32Variant, RAM_BYTES};
use crate::ml::model::Model;
use crate::ml::{harness, microbench};
use crate::sim::trace::{FullProfile, Profile};
use crate::sim::zero_riscy::{Halt, ZeroRiscy, ALL_MNEMONICS};
use crate::util::threadpool::{self, ThreadPool};

/// A utilization report over a workload set.
#[derive(Debug, Clone)]
pub struct Utilization {
    pub profile: Profile,
    pub unused_instructions: Vec<&'static str>,
    pub regs_needed: u32,
    pub pc_bits_needed: u32,
    pub bar_bits_needed: u32,
    pub workloads: Vec<String>,
}

impl Utilization {
    pub fn from_profile(profile: Profile, workloads: Vec<String>) -> Utilization {
        Utilization {
            unused_instructions: profile.unused_mnemonics(ALL_MNEMONICS),
            regs_needed: profile.reg_count(),
            pc_bits_needed: profile.pc_bits_needed(),
            bar_bits_needed: profile.bar_bits_needed(),
            profile,
            workloads,
        }
    }
}

/// Profile the §III-A suite (MLP, DT, mul/div, insertion sort) on the
/// baseline Zero-Riscy, using the process-wide pool.
pub fn profile_suite() -> Result<Utilization> {
    profile_suite_on(threadpool::global())
}

/// [`profile_suite`] on an explicit pool: one job per workload,
/// profiles merged in suite order.
pub fn profile_suite_on(pool: &ThreadPool) -> Result<Utilization> {
    let progs = microbench::suite()?;
    let names: Vec<String> = progs.iter().map(|(n, _)| n.to_string()).collect();
    let runs: Vec<Result<Profile>> = pool.par_map(progs, |(name, prog)| {
        let mut sim = ZeroRiscy::new(&prog, &[], RAM_BYTES, None);
        let halt = sim.run_translated::<FullProfile>(10_000_000)?;
        anyhow::ensure!(halt == Halt::Break, "{name} did not halt");
        Ok(sim.profile.clone())
    });
    let mut merged = Profile::default();
    for r in runs {
        merged.merge(&r?);
    }
    Ok(Utilization::from_profile(merged, names))
}

/// Profile the six ML models (baseline codegen) on the baseline core,
/// over a few samples each, using the process-wide pool.
pub fn profile_models(models: &[Model], samples: &[Vec<Vec<f32>>]) -> Result<Utilization> {
    profile_models_on(threadpool::global(), models, samples)
}

/// [`profile_models`] on an explicit pool: one job per model, profiles
/// merged in model order.
pub fn profile_models_on(
    pool: &ThreadPool,
    models: &[Model],
    samples: &[Vec<Vec<f32>>],
) -> Result<Utilization> {
    let n = models.len().min(samples.len());
    let idx: Vec<usize> = (0..n).collect();
    let runs: Vec<Result<Profile>> = pool.par_map(idx, |i| {
        let prog = codegen_rv32::generate(&models[i], Rv32Variant::Baseline)?;
        let run = harness::run_rv32(&models[i], &prog, &samples[i])?;
        Ok(run.profile)
    });
    let mut merged = Profile::default();
    let mut names = Vec::new();
    for (i, r) in runs.into_iter().enumerate() {
        merged.merge(&r?);
        names.push(models[i].name.clone());
    }
    Ok(Utilization::from_profile(merged, names))
}

/// Combined utilization of the suite + models (the paper's workload
/// set), using the process-wide pool.
pub fn profile_all(models: &[Model], samples: &[Vec<Vec<f32>>]) -> Result<Utilization> {
    profile_all_on(threadpool::global(), models, samples)
}

/// [`profile_all`] on an explicit pool (the DSE sweeps pass their
/// context's pool).
pub fn profile_all_on(
    pool: &ThreadPool,
    models: &[Model],
    samples: &[Vec<Vec<f32>>],
) -> Result<Utilization> {
    let mut u = profile_suite_on(pool)?;
    let m = profile_models_on(pool, models, samples)?;
    u.profile.merge(&m.profile);
    let mut workloads = u.workloads.clone();
    workloads.extend(m.workloads);
    Ok(Utilization::from_profile(u.profile, workloads))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_utilization_matches_paper_observations() {
        let u = profile_suite().unwrap();
        // §III-A: "the SLT, most CSR, System Calls, and MULH
        // instructions remain unused".
        for m in ["slt", "csrrw", "csrrs", "ecall", "mulh", "mulhsu", "mulhu"] {
            assert!(u.unused_instructions.contains(&m), "{m}");
        }
        // "12 registers are sufficient" — the suite stays in that
        // neighbourhood.
        assert!(u.regs_needed <= 14, "regs {}", u.regs_needed);
        // "reduction of the PC from 32 bits to 10" — code is tiny.
        assert!(u.pc_bits_needed <= 12, "pc bits {}", u.pc_bits_needed);
        assert!(u.bar_bits_needed <= 12, "bar bits {}", u.bar_bits_needed);
        assert!(!u.profile.csr_used);
    }
}
