//! The bespoke design flow — the paper's core contribution (§III):
//! measure what a deployment actually uses (workflow step ③), remove
//! what it doesn't, and re-synthesise.
//!
//! * [`profile`] — runs the profiling suite (§III-A) and the ML
//!   benchmarks on the baseline ISS and aggregates utilization.
//! * [`reduction`] — maps a utilization profile to a reduced
//!   [`crate::hw::synth::CoreSpec`]: unit removal, ISA trimming,
//!   register-file shrink, PC/BAR narrowing.
//! * [`resilience`] — seeded soft-error campaigns on the batched ISS:
//!   accuracy-vs-fault-rate curves, AVF breakdown by target class, and
//!   stuck-at ROM probes per (model, core, precision).

pub mod profile;
pub mod reduction;
pub mod resilience;
