//! The model-executable runtime behind the coordinator: load the
//! `artifacts/` executables and run them from the L3 hot path (no
//! Python).  Two interchangeable backends expose the identical API
//! (`Runtime`, `Compiled`):
//!
//! * **Stub interpreter** (default — hermetic, no external crates):
//!   artifacts are JSON stub descriptors (`ml::fixtures::StubHlo`, the
//!   checked-in `artifacts-fixture/` tree), and execution delegates to
//!   the in-crate references — `Model::quantized_forward` /
//!   `Model::float_forward` for model executables and the
//!   `sim::mac_model` functional model for the packed MAC unit.  The
//!   fixed-batch contract is preserved: every chunk pays the cost of the
//!   full zero-padded batch, exactly like a compiled executable, so the
//!   coordinator's batching trade-offs stay measurable.
//!
//! * **PJRT** (`--features xla`, plus the vendored `xla` path
//!   dependency — see `rust/Cargo.toml`): parses the HLO *text*
//!   artifacts the JAX AOT pipeline wrote and runs them on the CPU PJRT
//!   client.  Interchange is HLO text because jax >= 0.5 serialises
//!   protos with 64-bit instruction ids that xla_extension 0.5.1
//!   rejects; the text parser reassigns ids (see
//!   `python/compile/aot.py`).
//!
//! In the PJRT backend the handles are not `Send`, so a `Runtime` is
//! constructed and used on a single thread; the stub backend keeps the
//! same single-thread discipline (the coordinator owns one runtime per
//! worker thread — see `coordinator::service`).

#[cfg(not(feature = "xla"))]
pub use stub_backend::{Compiled, Runtime};

#[cfg(feature = "xla")]
pub use xla_backend::{Compiled, Runtime};

// ---------------------------------------------------------------------------
// Default backend: hermetic stub interpreter
// ---------------------------------------------------------------------------

#[cfg(not(feature = "xla"))]
mod stub_backend {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::rc::Rc;

    use anyhow::{bail, ensure, Context, Result};

    use crate::hw::mac_unit::MacConfig;
    use crate::ml::fixtures::StubHlo;
    use crate::ml::model::Model;
    use crate::sim::mac_model::MacState;

    /// What a stub model executable computes per sample.
    enum Program {
        /// f64 reference forward (the "float" variant).
        Float(Model),
        /// Quantised reference forward at a precision.
        Quant(Model, u32),
    }

    /// One loaded model executable with its I/O contract (stub backend).
    pub struct Compiled {
        program: Program,
        /// Fixed batch dimension the artifact was lowered at.
        pub batch: usize,
        pub in_dim: usize,
        pub out_dim: usize,
    }

    impl Compiled {
        /// Execute on up to `batch` samples.  Mirrors the fixed-batch
        /// executable semantics: the whole zero-padded batch is
        /// evaluated (padding rows discarded), so per-flush cost is flat
        /// in the chunk size — the property the dynamic batcher trades
        /// against.
        pub fn run_chunk(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f64>>> {
            ensure!(xs.len() <= self.batch, "chunk {} exceeds batch {}", xs.len(), self.batch);
            let zero = vec![0.0f32; self.in_dim];
            let mut out = Vec::with_capacity(xs.len());
            for i in 0..self.batch {
                let x = xs.get(i).unwrap_or(&zero);
                ensure!(x.len() == self.in_dim, "sample dim {} != {}", x.len(), self.in_dim);
                let scores = match &self.program {
                    Program::Float(m) => m.float_forward(x),
                    Program::Quant(m, p) => m.quantized_forward(x, *p)?,
                };
                ensure!(
                    scores.len() == self.out_dim,
                    "output dim {} != {}",
                    scores.len(),
                    self.out_dim
                );
                if i < xs.len() {
                    out.push(scores);
                }
            }
            Ok(out)
        }

        /// Execute over an arbitrary number of samples, chunking
        /// internally.
        pub fn run(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f64>>> {
            let mut out = Vec::with_capacity(xs.len());
            for chunk in xs.chunks(self.batch) {
                out.extend(self.run_chunk(chunk)?);
            }
            Ok(out)
        }
    }

    /// A single-threaded stub runtime with a load cache (mirroring the
    /// PJRT compile cache so `coordinator::metrics` compile counting
    /// behaves identically).
    pub struct Runtime {
        cache: HashMap<PathBuf, Rc<Compiled>>,
    }

    impl Runtime {
        /// Construct the runtime ("cpu" naming kept for API parity with
        /// the PJRT backend).
        pub fn cpu() -> Result<Runtime> {
            Ok(Runtime { cache: HashMap::new() })
        }

        pub fn platform(&self) -> String {
            "pbsp-stub-interpreter".to_string()
        }

        /// `true`: this build interprets stub descriptors only; real HLO
        /// text needs `--features xla`.  Service-level tests use this to
        /// skip when pointed at real AOT artifacts.
        pub fn is_stub() -> bool {
            true
        }

        /// Load a stub model artifact (cached by path).
        pub fn load(
            &mut self,
            path: impl AsRef<Path>,
            batch: usize,
            in_dim: usize,
            out_dim: usize,
        ) -> Result<Rc<Compiled>> {
            let path = path.as_ref().to_path_buf();
            if let Some(c) = self.cache.get(&path) {
                return Ok(c.clone());
            }
            let StubHlo::Model { weights, variant } = StubHlo::from_file(&path)? else {
                bail!("{}: expected a model artifact, found a MAC unit", path.display());
            };
            let model = Model::load(&weights)
                .with_context(|| format!("loading stub weights {}", weights.display()))?;
            let program = if variant == "float" {
                Program::Float(model)
            } else if let Some(p) = variant.strip_prefix('p') {
                let p: u32 =
                    p.parse().with_context(|| format!("stub variant {variant:?}"))?;
                model.qlayers(p)?; // fail fast on a missing quantised variant
                Program::Quant(model, p)
            } else {
                bail!("{}: unknown stub variant {variant:?}", path.display());
            };
            let compiled = Rc::new(Compiled { program, batch, in_dim, out_dim });
            self.cache.insert(path, compiled.clone());
            Ok(compiled)
        }

        pub fn cached_count(&self) -> usize {
            self.cache.len()
        }

        /// Run a packed SIMD-MAC unit artifact (two `s32[words]` inputs
        /// -> `s32[lanes]` accumulators) via the functional model — the
        /// same contract as the Pallas-kernel HLO the real backend
        /// executes.
        pub fn run_mac_unit(
            &mut self,
            path: impl AsRef<Path>,
            wa: &[i32],
            wb: &[i32],
            lanes: usize,
        ) -> Result<Vec<i32>> {
            ensure!(wa.len() == wb.len(), "operand streams differ in length");
            let path = path.as_ref();
            let StubHlo::MacUnit { datapath, precision, words } = StubHlo::from_file(path)?
            else {
                bail!("{}: expected a mac_unit artifact", path.display());
            };
            // The real backend's compiled s32[words] parameter shape
            // rejects mismatched streams; enforce the same contract here.
            ensure!(wa.len() == words, "operand stream length {} != words {words}", wa.len());
            let mut m = MacState::new(MacConfig::new(datapath, precision));
            for (a, b) in wa.iter().zip(wb) {
                m.mac(*a as u32 as u64, *b as u32 as u64);
            }
            let out: Vec<i32> = if precision >= 32 {
                vec![m.read(0) as i32]
            } else {
                (0..m.lanes()).map(|l| m.read(l) as i32).collect()
            };
            ensure!(out.len() == lanes, "lane count {} != {lanes}", out.len());
            Ok(out)
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use crate::ml::dataset::Dataset;
        use crate::ml::manifest::Manifest;

        #[test]
        fn stub_scores_match_reference() {
            let dir = crate::ml::fixtures::find_fixture_dir()
                .expect("checked-in artifacts-fixture/ missing");
            let man = Manifest::load(&dir).unwrap();
            let entry = man.model("mlp_c_cardio").unwrap();
            let model = Model::load(&entry.weights).unwrap();
            let ds = Dataset::load(man.data_dir(), &entry.dataset, "test").unwrap();
            let xs: Vec<Vec<f32>> = ds.x.iter().take(5).cloned().collect();
            let mut rt = Runtime::cpu().unwrap();

            let exe = rt
                .load(&entry.hlo["p16"], man.batch, entry.arch[0], model.n_outputs())
                .unwrap();
            let scores = exe.run(&xs).unwrap();
            for (i, x) in xs.iter().enumerate() {
                assert_eq!(scores[i], model.quantized_forward(x, 16).unwrap(), "sample {i}");
            }

            let exe = rt
                .load(&entry.hlo["float"], man.batch, entry.arch[0], model.n_outputs())
                .unwrap();
            let scores = exe.run(&xs).unwrap();
            for (i, x) in xs.iter().enumerate() {
                assert_eq!(scores[i], model.float_forward(x), "sample {i}");
            }
        }

        #[test]
        fn load_cache_hits_by_path() {
            let dir = crate::ml::fixtures::find_fixture_dir()
                .expect("checked-in artifacts-fixture/ missing");
            let man = Manifest::load(&dir).unwrap();
            let entry = man.model("svm_c_cardio").unwrap();
            let mut rt = Runtime::cpu().unwrap();
            rt.load(&entry.hlo["p8"], man.batch, entry.arch[0], 3).unwrap();
            rt.load(&entry.hlo["p8"], man.batch, entry.arch[0], 3).unwrap();
            assert_eq!(rt.cached_count(), 1);
            rt.load(&entry.hlo["p4"], man.batch, entry.arch[0], 3).unwrap();
            assert_eq!(rt.cached_count(), 2);
        }

        #[test]
        fn mac_unit_stub_matches_functional_model() {
            let dir = crate::ml::fixtures::find_fixture_dir()
                .expect("checked-in artifacts-fixture/ missing");
            let man = Manifest::load(&dir).unwrap();
            let mut rt = Runtime::cpu().unwrap();
            let (path, words) = &man.mac_units[&8];
            let wa: Vec<i32> = (0..*words as i32).map(|i| i.wrapping_mul(0x1234_567)).collect();
            let wb: Vec<i32> = (0..*words as i32).map(|i| i.wrapping_mul(-0x76_5432)).collect();
            let got = rt.run_mac_unit(path, &wa, &wb, 4).unwrap();
            let mut m = MacState::new(MacConfig::new(32, 8));
            for (a, b) in wa.iter().zip(&wb) {
                m.mac(*a as u32 as u64, *b as u32 as u64);
            }
            let want: Vec<i32> = (0..4).map(|l| m.read(l) as i32).collect();
            assert_eq!(got, want);
        }
    }
}

// ---------------------------------------------------------------------------
// Real backend: PJRT over the vendored `xla` crate
// ---------------------------------------------------------------------------

#[cfg(feature = "xla")]
mod xla_backend {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    use anyhow::{ensure, Context, Result};

    /// One compiled model executable with its I/O contract.
    pub struct Compiled {
        exe: xla::PjRtLoadedExecutable,
        /// Fixed batch dimension the HLO was lowered at.
        pub batch: usize,
        pub in_dim: usize,
        pub out_dim: usize,
    }

    impl Compiled {
        /// Execute on up to `batch` samples (the chunk is zero-padded to
        /// the fixed batch).  Returns one score vector per input sample.
        pub fn run_chunk(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f64>>> {
            ensure!(xs.len() <= self.batch, "chunk {} exceeds batch {}", xs.len(), self.batch);
            let mut flat = vec![0.0f32; self.batch * self.in_dim];
            for (i, x) in xs.iter().enumerate() {
                ensure!(x.len() == self.in_dim, "sample dim {} != {}", x.len(), self.in_dim);
                flat[i * self.in_dim..(i + 1) * self.in_dim].copy_from_slice(x);
            }
            let lit =
                xla::Literal::vec1(&flat).reshape(&[self.batch as i64, self.in_dim as i64])?;
            let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
            // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
            let out = result.to_tuple1()?;
            let values = out.to_vec::<f32>()?;
            ensure!(
                values.len() == self.batch * self.out_dim,
                "output size {} != {}x{}",
                values.len(),
                self.batch,
                self.out_dim
            );
            Ok(xs
                .iter()
                .enumerate()
                .map(|(i, _)| {
                    values[i * self.out_dim..(i + 1) * self.out_dim]
                        .iter()
                        .map(|&v| v as f64)
                        .collect()
                })
                .collect())
        }

        /// Execute over an arbitrary number of samples, chunking
        /// internally.
        pub fn run(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f64>>> {
            let mut out = Vec::with_capacity(xs.len());
            for chunk in xs.chunks(self.batch) {
                out.extend(self.run_chunk(chunk)?);
            }
            Ok(out)
        }
    }

    /// A single-threaded PJRT runtime with a compile cache.
    pub struct Runtime {
        client: xla::PjRtClient,
        cache: HashMap<PathBuf, std::rc::Rc<Compiled>>,
    }

    impl Runtime {
        pub fn cpu() -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
            Ok(Runtime { client, cache: HashMap::new() })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// `false`: this build executes real HLO-text artifacts.
        pub fn is_stub() -> bool {
            false
        }

        /// Load + compile an HLO-text artifact (cached by path).
        pub fn load(
            &mut self,
            path: impl AsRef<Path>,
            batch: usize,
            in_dim: usize,
            out_dim: usize,
        ) -> Result<std::rc::Rc<Compiled>> {
            let path = path.as_ref().to_path_buf();
            if let Some(c) = self.cache.get(&path) {
                return Ok(c.clone());
            }
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            let compiled = std::rc::Rc::new(Compiled { exe, batch, in_dim, out_dim });
            self.cache.insert(path, compiled.clone());
            Ok(compiled)
        }

        pub fn cached_count(&self) -> usize {
            self.cache.len()
        }

        /// Load + run a packed SIMD-MAC unit artifact (two `s32[words]`
        /// inputs -> `s32[lanes]` accumulators) — used by the runtime
        /// integration tests to validate numerics against
        /// `sim::mac_model`.
        pub fn run_mac_unit(
            &mut self,
            path: impl AsRef<Path>,
            wa: &[i32],
            wb: &[i32],
            lanes: usize,
        ) -> Result<Vec<i32>> {
            let path = path.as_ref().to_path_buf();
            let proto = xla::HloModuleProto::from_text_file(&path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            let la = xla::Literal::vec1(wa);
            let lb = xla::Literal::vec1(wb);
            let result = exe.execute::<xla::Literal>(&[la, lb])?[0][0].to_literal_sync()?;
            let out = result.to_tuple1()?;
            let v = out.to_vec::<i32>()?;
            ensure!(v.len() == lanes, "lane count {} != {lanes}", v.len());
            Ok(v)
        }
    }
}
