//! The PJRT executor: one CPU client, a compile cache keyed by artifact
//! path, fixed-batch execution with padding.
//!
//! PJRT handles are not `Send`, so the [`Runtime`] is constructed and
//! used on a single thread — the coordinator owns one runtime per
//! worker thread (see `coordinator::service`).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

/// One compiled model executable with its I/O contract.
pub struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    /// Fixed batch dimension the HLO was lowered at.
    pub batch: usize,
    pub in_dim: usize,
    pub out_dim: usize,
}

impl Compiled {
    /// Execute on up to `batch` samples (the chunk is zero-padded to the
    /// fixed batch).  Returns one score vector per input sample.
    pub fn run_chunk(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f64>>> {
        ensure!(xs.len() <= self.batch, "chunk {} exceeds batch {}", xs.len(), self.batch);
        let mut flat = vec![0.0f32; self.batch * self.in_dim];
        for (i, x) in xs.iter().enumerate() {
            ensure!(x.len() == self.in_dim, "sample dim {} != {}", x.len(), self.in_dim);
            flat[i * self.in_dim..(i + 1) * self.in_dim].copy_from_slice(x);
        }
        let lit = xla::Literal::vec1(&flat).reshape(&[self.batch as i64, self.in_dim as i64])?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        ensure!(
            values.len() == self.batch * self.out_dim,
            "output size {} != {}x{}",
            values.len(),
            self.batch,
            self.out_dim
        );
        Ok(xs
            .iter()
            .enumerate()
            .map(|(i, _)| {
                values[i * self.out_dim..(i + 1) * self.out_dim]
                    .iter()
                    .map(|&v| v as f64)
                    .collect()
            })
            .collect())
    }

    /// Execute over an arbitrary number of samples, chunking internally.
    pub fn run(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f64>>> {
        let mut out = Vec::with_capacity(xs.len());
        for chunk in xs.chunks(self.batch) {
            out.extend(self.run_chunk(chunk)?);
        }
        Ok(out)
    }
}

/// A single-threaded PJRT runtime with a compile cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: HashMap<PathBuf, std::rc::Rc<Compiled>>,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(Runtime { client, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached by path).
    pub fn load(
        &mut self,
        path: impl AsRef<Path>,
        batch: usize,
        in_dim: usize,
        out_dim: usize,
    ) -> Result<std::rc::Rc<Compiled>> {
        let path = path.as_ref().to_path_buf();
        if let Some(c) = self.cache.get(&path) {
            return Ok(c.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let compiled = std::rc::Rc::new(Compiled { exe, batch, in_dim, out_dim });
        self.cache.insert(path, compiled.clone());
        Ok(compiled)
    }

    pub fn cached_count(&self) -> usize {
        self.cache.len()
    }

    /// Load + run a packed SIMD-MAC unit artifact (two s32[words] inputs
    /// -> s32[lanes] accumulators) — used by the runtime unit tests to
    /// validate numerics against `sim::mac_model`.
    pub fn run_mac_unit(
        &mut self,
        path: impl AsRef<Path>,
        wa: &[i32],
        wb: &[i32],
        lanes: usize,
    ) -> Result<Vec<i32>> {
        let path = path.as_ref().to_path_buf();
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let la = xla::Literal::vec1(wa);
        let lb = xla::Literal::vec1(wb);
        let result = exe.execute::<xla::Literal>(&[la, lb])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        let v = out.to_vec::<i32>()?;
        ensure!(v.len() == lanes, "lane count {} != {lanes}", v.len());
        Ok(v)
    }
}
