//! Model-executable runtime: load the AOT artifacts and execute them on
//! the L3 hot path (no Python).
//!
//! [`pjrt`] exposes one API with two backends: the default hermetic
//! stub interpreter (drives the in-crate reference implementations from
//! the checked-in `artifacts-fixture/` stub descriptors), and the real
//! PJRT executor over HLO-text artifacts behind `--features xla`.
//! Interchange with the AOT pipeline is HLO *text*: jax >= 0.5
//! serialises protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see
//! `python/compile/aot.py`).

pub mod pjrt;
