//! PJRT runtime: load the AOT-compiled HLO-text artifacts and execute
//! them on the CPU PJRT client from the L3 hot path (no Python).
//!
//! Interchange is HLO *text*: jax >= 0.5 serialises protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and
//! `python/compile/aot.py`).

pub mod pjrt;
