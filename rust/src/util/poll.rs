//! Readiness polling (offline substitute for mio/epoll crates): a thin
//! safe wrapper over `poll(2)`, a self-pipe waker, and an `RLIMIT_NOFILE`
//! raiser — the substrate the serving reactor (`server::reactor`)
//! multiplexes thousands of non-blocking sockets on.
//!
//! Zero dependencies by design: on unix the three libc entry points
//! (`poll`, `getrlimit`, `setrlimit`) are declared directly — std already
//! links libc, so no crate is pulled in.  On non-unix targets the
//! module degrades to a tick-driven fallback: [`poll`] sleeps a short
//! bounded tick and reports every registered source ready, so callers
//! degenerate into a correct (if busier) non-blocking scan loop.

use std::time::Duration;

use anyhow::Result;

/// Raw fd alias that exists on every target (non-unix callers only ever
/// see the fallback value).
#[cfg(unix)]
pub type Fd = std::os::fd::RawFd;
#[cfg(not(unix))]
pub type Fd = i32;

/// The fd of a pollable source ([`std::net::TcpStream`],
/// [`std::net::TcpListener`], ...).
#[cfg(unix)]
pub fn fd_of<T: std::os::fd::AsRawFd>(s: &T) -> Fd {
    s.as_raw_fd()
}
#[cfg(not(unix))]
pub fn fd_of<T>(_s: &T) -> Fd {
    0
}

/// One registered source: which fd, which readiness we want, and (after
/// [`poll()`]) which readiness we got.
#[derive(Debug, Clone, Copy)]
pub struct PollFd {
    pub fd: Fd,
    pub want_read: bool,
    pub want_write: bool,
    /// Out: data (or a pending accept/EOF) can be read without blocking.
    pub readable: bool,
    /// Out: the socket send buffer can take bytes without blocking.
    pub writable: bool,
    /// Out: error/hangup — the owner should drive the source and let
    /// the resulting io error classify the failure.
    pub error: bool,
}

impl PollFd {
    pub fn new(fd: Fd, want_read: bool, want_write: bool) -> PollFd {
        PollFd { fd, want_read, want_write, readable: false, writable: false, error: false }
    }

    /// Any readiness at all (the owner should be driven this tick).
    pub fn ready(&self) -> bool {
        self.readable || self.writable || self.error
    }
}

#[cfg(unix)]
mod sys {
    use super::PollFd;
    use anyhow::{Context, Result};

    const POLLIN: i16 = 0x001;
    const POLLOUT: i16 = 0x004;
    const POLLERR: i16 = 0x008;
    const POLLHUP: i16 = 0x010;
    const POLLNVAL: i16 = 0x020;

    #[repr(C)]
    struct RawPollFd {
        fd: i32,
        events: i16,
        revents: i16,
    }

    #[cfg(target_os = "macos")]
    type Nfds = u32;
    #[cfg(not(target_os = "macos"))]
    type Nfds = u64;

    extern "C" {
        fn poll(fds: *mut RawPollFd, nfds: Nfds, timeout: i32) -> i32;
    }

    /// Block until a source is ready or `timeout` elapses; fills the
    /// `readable`/`writable`/`error` outputs.  Returns the number of
    /// ready sources (0 on timeout).
    pub fn poll_impl(fds: &mut [PollFd], timeout: std::time::Duration) -> Result<usize> {
        let mut raw: Vec<RawPollFd> = fds
            .iter()
            .map(|p| RawPollFd {
                fd: p.fd,
                events: if p.want_read { POLLIN } else { 0 }
                    | if p.want_write { POLLOUT } else { 0 },
                revents: 0,
            })
            .collect();
        let timeout_ms = timeout.as_millis().min(i32::MAX as u128) as i32;
        let n = loop {
            let r = unsafe { poll(raw.as_mut_ptr(), raw.len() as Nfds, timeout_ms) };
            if r >= 0 {
                break r as usize;
            }
            let e = std::io::Error::last_os_error();
            if e.kind() == std::io::ErrorKind::Interrupted {
                continue; // EINTR: retry with the full timeout (coarse, fine here)
            }
            return Err(e).context("poll");
        };
        for (p, r) in fds.iter_mut().zip(&raw) {
            p.readable = r.revents & (POLLIN | POLLHUP) != 0;
            p.writable = r.revents & POLLOUT != 0;
            p.error = r.revents & (POLLERR | POLLHUP | POLLNVAL) != 0;
        }
        Ok(n)
    }

    // -- RLIMIT_NOFILE ------------------------------------------------

    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }

    #[cfg(target_os = "macos")]
    const RLIMIT_NOFILE: i32 = 8;
    #[cfg(not(target_os = "macos"))]
    const RLIMIT_NOFILE: i32 = 7;

    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    }

    /// Raise the soft fd limit toward `target` (never above the hard
    /// limit unless the process may raise it, which root can).  Returns
    /// the resulting soft limit; best-effort — failures leave the limit
    /// unchanged rather than erroring.
    pub fn raise_nofile_impl(target: u64) -> u64 {
        let mut lim = RLimit { cur: 0, max: 0 };
        if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
            return 0;
        }
        if lim.cur >= target {
            return lim.cur;
        }
        // First try within the hard limit, then (root) raising both.
        for attempt in [
            RLimit { cur: target.min(lim.max), max: lim.max },
            RLimit { cur: target, max: target.max(lim.max) },
        ] {
            if attempt.cur > lim.cur && unsafe { setrlimit(RLIMIT_NOFILE, &attempt) } == 0 {
                lim.cur = attempt.cur;
            }
        }
        lim.cur
    }
}

#[cfg(unix)]
pub fn poll(fds: &mut [PollFd], timeout: Duration) -> Result<usize> {
    sys::poll_impl(fds, timeout)
}

/// Fallback: a bounded sleep that reports everything ready, turning the
/// caller into a tick-driven non-blocking scan (correct, just busier).
#[cfg(not(unix))]
pub fn poll(fds: &mut [PollFd], timeout: Duration) -> Result<usize> {
    std::thread::sleep(timeout.min(Duration::from_millis(20)));
    for p in fds.iter_mut() {
        p.readable = p.want_read;
        p.writable = p.want_write;
        p.error = false;
    }
    Ok(fds.len())
}

/// Current/raised soft `RLIMIT_NOFILE`: call before holding fleets of
/// sockets (10k-connection benches need ~2 fds per in-flight device).
#[cfg(unix)]
pub fn raise_nofile_limit(target: u64) -> u64 {
    sys::raise_nofile_impl(target)
}
#[cfg(not(unix))]
pub fn raise_nofile_limit(_target: u64) -> u64 {
    u64::MAX // no fd limit concept to manage; report "plenty"
}

// ---------------------------------------------------------------------------
// Waker: cross-thread poll interruption (self-pipe idiom)
// ---------------------------------------------------------------------------

/// Wakes a thread blocked in [`poll()`]: the poller registers
/// [`Waker::fd`] for reads; any thread calls [`Waker::wake`].  Built on
/// a non-blocking `UnixStream` pair, so a wake is one `write(2)` and
/// "already pending" simply hits `WouldBlock` (coalesced wakes).
#[cfg(unix)]
pub struct Waker {
    rx: std::os::unix::net::UnixStream,
    tx: std::os::unix::net::UnixStream,
}

#[cfg(unix)]
impl Waker {
    pub fn new() -> Result<Waker> {
        use anyhow::Context;
        let (tx, rx) = std::os::unix::net::UnixStream::pair().context("waker pair")?;
        tx.set_nonblocking(true).context("waker tx nonblocking")?;
        rx.set_nonblocking(true).context("waker rx nonblocking")?;
        Ok(Waker { rx, tx })
    }

    /// The fd the poller registers with `want_read`.
    pub fn fd(&self) -> Fd {
        fd_of(&self.rx)
    }

    /// Interrupt the poller (callable from any thread through `&self`
    /// — `&UnixStream` implements `Write`).  A full pipe means a wake
    /// is already pending, which is exactly as good.
    pub fn wake(&self) {
        use std::io::Write;
        let _ = (&self.tx).write(&[1]);
    }

    /// Drain pending wake bytes (poller side, after a readable tick).
    pub fn drain(&self) {
        use std::io::Read;
        let mut buf = [0u8; 64];
        while matches!((&self.rx).read(&mut buf), Ok(n) if n > 0) {}
    }
}

/// Fallback waker: the fallback [`poll()`] never blocks past its tick,
/// so waking is a no-op.
#[cfg(not(unix))]
pub struct Waker;

#[cfg(not(unix))]
impl Waker {
    pub fn new() -> Result<Waker> {
        Ok(Waker)
    }
    pub fn fd(&self) -> Fd {
        0
    }
    pub fn wake(&self) {}
    pub fn drain(&self) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};
    use std::time::Instant;

    #[test]
    fn poll_times_out_on_quiet_socket() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        let mut fds = [PollFd::new(fd_of(&server), true, false)];
        let n = poll(&mut fds, Duration::from_millis(30)).unwrap();
        if cfg!(unix) {
            assert_eq!(n, 0, "no data was sent");
            assert!(!fds[0].ready());
        }
        drop(client);
    }

    #[test]
    fn poll_sees_readable_data_and_writable_buffer() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        client.write_all(b"ping").unwrap();
        let mut fds =
            [PollFd::new(fd_of(&server), true, true), PollFd::new(fd_of(&client), false, true)];
        let n = poll(&mut fds, Duration::from_millis(1000)).unwrap();
        assert!(n >= 1);
        assert!(fds[0].readable, "sent bytes must mark the server side readable");
        assert!(fds[0].writable && fds[1].writable, "idle sockets are writable");
    }

    #[test]
    fn poll_flags_accept_readiness_on_listener() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _client = TcpStream::connect(addr).unwrap();
        let mut fds = [PollFd::new(fd_of(&listener), true, false)];
        poll(&mut fds, Duration::from_millis(1000)).unwrap();
        assert!(fds[0].readable, "a pending accept is read-readiness");
    }

    #[test]
    fn waker_interrupts_poll() {
        let waker = std::sync::Arc::new(Waker::new().unwrap());
        let w = std::sync::Arc::clone(&waker);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            w.wake();
        });
        let t0 = Instant::now();
        let mut fds = [PollFd::new(waker.fd(), true, false)];
        poll(&mut fds, Duration::from_millis(5_000)).unwrap();
        assert!(
            t0.elapsed() < Duration::from_secs(4),
            "wake must interrupt the poll well before its timeout"
        );
        waker.drain();
        // Drained: the next poll times out instead of spinning.
        if cfg!(unix) {
            let n = poll(&mut fds, Duration::from_millis(20)).unwrap();
            assert_eq!(n, 0, "drain must consume the wake byte(s)");
        }
        t.join().unwrap();
    }

    #[test]
    fn nofile_limit_is_queryable_and_monotonic() {
        let now = raise_nofile_limit(0);
        assert!(now > 0);
        let raised = raise_nofile_limit(now); // idempotent at current
        assert!(raised >= now);
    }

    #[test]
    fn empty_poll_set_is_a_sleep() {
        let t0 = Instant::now();
        poll(&mut [], Duration::from_millis(25)).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(10));
    }
}
