//! Mini property-testing harness (offline substitute for proptest).
//!
//! `check(name, iters, |rng| ...)` runs a closure over seeded PRNG inputs
//! and panics with the failing seed on the first violation, so a failure
//! is reproducible with `check_seed(name, seed, f)`.  No shrinking — the
//! generators used in this crate produce small cases by construction.

use super::rng::Pcg32;

/// Outcome of a single property case.
pub type CaseResult = Result<(), String>;

/// Run `iters` random cases of property `f`.  Panics (with the seed) on
/// the first failing case.
pub fn check<F: FnMut(&mut Pcg32) -> CaseResult>(name: &str, iters: u64, mut f: F) {
    for seed in 0..iters {
        let mut rng = Pcg32::new(seed, 0x9e37_79b9_7f4a_7c15);
        if let Err(msg) = f(&mut rng) {
            panic!("property {name:?} failed at seed {seed}: {msg}");
        }
    }
}

/// Re-run a single seed (for debugging a reported failure).
pub fn check_seed<F: FnMut(&mut Pcg32) -> CaseResult>(name: &str, seed: u64, mut f: F) {
    let mut rng = Pcg32::new(seed, 0x9e37_79b9_7f4a_7c15);
    if let Err(msg) = f(&mut rng) {
        panic!("property {name:?} failed at seed {seed}: {msg}");
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Assert equality helper for property bodies.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!("{a:?} != {b:?}"));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_iters() {
        let mut count = 0;
        check("trivial", 25, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "failed at seed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 5, |_| Err("nope".into()));
    }

    #[test]
    fn macros_work() {
        check("macros", 10, |rng| {
            let v = rng.range_i64(0, 10);
            prop_assert!(v <= 10, "v was {v}");
            prop_assert_eq!(v - v, 0);
            Ok(())
        });
    }
}
