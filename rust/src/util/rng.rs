//! PCG32 pseudo-random number generator (offline substitute for `rand`).
//!
//! Deterministic, seedable, with the helpers the framework needs:
//! uniform ranges, floats, Gaussians (Box–Muller), choice and shuffle.
//! Reference: O'Neill, "PCG: A Family of Simple Fast Space-Efficient
//! Statistically Good Algorithms for Random Number Generation".

/// PCG-XSH-RR 64/32.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
    /// Cached second Gaussian from Box–Muller.
    spare_normal: Option<f64>,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1, spare_normal: None };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire rejection).
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return r % bound;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + self.below(span) as i64
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_i64(lo as i64, hi as i64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (u1, u2) = (self.f64().max(1e-300), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn bool(&mut self) -> bool {
        self.next_u32() & 1 == 1
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let va: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn below_in_bounds_and_covers() {
        let mut rng = Pcg32::seeded(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Pcg32::seeded(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(5);
        let mut v: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn range_inclusive() {
        let mut rng = Pcg32::seeded(9);
        let mut hit_lo = false;
        let mut hit_hi = false;
        for _ in 0..2000 {
            let v = rng.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            hit_lo |= v == -3;
            hit_hi |= v == 3;
        }
        assert!(hit_lo && hit_hi);
    }
}
