//! Tiny CSV reader for the dataset artifacts (`artifacts/data/*.csv`).
//!
//! Format written by `python/compile/datasets.py`: a header row of feature
//! names ending in `label`, then one row per sample of f32 features and an
//! integer label.  No quoting/escaping is used in the artifacts.

use anyhow::{bail, Context, Result};

/// A loaded CSV table: header + numeric rows.
#[derive(Debug, Clone)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<f64>>,
}

impl Table {
    pub fn parse(text: &str) -> Result<Table> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header: Vec<String> = lines
            .next()
            .context("empty CSV")?
            .split(',')
            .map(|s| s.trim().to_string())
            .collect();
        let mut rows = Vec::new();
        for (i, line) in lines.enumerate() {
            let row: Vec<f64> = line
                .split(',')
                .map(|s| s.trim().parse::<f64>().with_context(|| format!("row {}: {s:?}", i + 1)))
                .collect::<Result<_>>()?;
            if row.len() != header.len() {
                bail!("row {} has {} fields, header has {}", i + 1, row.len(), header.len());
            }
            rows.push(row);
        }
        Ok(Table { header, rows })
    }

    pub fn from_file(path: impl AsRef<std::path::Path>) -> Result<Table> {
        let path = path.as_ref();
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    /// Split into (features, labels), assuming the last column is `label`.
    pub fn features_labels(&self) -> Result<(Vec<Vec<f32>>, Vec<i64>)> {
        if self.header.last().map(String::as_str) != Some("label") {
            bail!("last column is not 'label': {:?}", self.header.last());
        }
        let mut xs = Vec::with_capacity(self.rows.len());
        let mut ys = Vec::with_capacity(self.rows.len());
        for row in &self.rows {
            let (label, feats) = row.split_last().unwrap();
            xs.push(feats.iter().map(|&v| v as f32).collect());
            ys.push(*label as i64);
        }
        Ok((xs, ys))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic() {
        let t = Table::parse("a,b,label\n0.5,1.0,2\n0.25,0.75,1\n").unwrap();
        assert_eq!(t.header, vec!["a", "b", "label"]);
        assert_eq!(t.rows.len(), 2);
        let (xs, ys) = t.features_labels().unwrap();
        assert_eq!(xs[0], vec![0.5f32, 1.0]);
        assert_eq!(ys, vec![2, 1]);
    }

    #[test]
    fn rejects_ragged() {
        assert!(Table::parse("a,b\n1\n").is_err());
    }

    #[test]
    fn rejects_non_numeric() {
        assert!(Table::parse("a,label\nx,1\n").is_err());
    }

    #[test]
    fn requires_label_column() {
        let t = Table::parse("a,b\n1,2\n").unwrap();
        assert!(t.features_labels().is_err());
    }

    #[test]
    fn skips_blank_lines() {
        let t = Table::parse("a,label\n\n1,2\n\n").unwrap();
        assert_eq!(t.rows.len(), 1);
    }
}
