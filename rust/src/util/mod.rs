//! Offline substrates.
//!
//! Only the `xla` crate's vendored dependency closure is reachable in this
//! environment (no serde, clap, rand, criterion, proptest, tokio), so the
//! framework owns its own small, well-tested implementations of the
//! utilities it needs: JSON, CSV, a PCG PRNG, a CLI argument parser,
//! statistics helpers, a property-testing harness and a thread pool.

pub mod bench;
pub mod cli;
pub mod csv;
pub mod json;
pub mod poll;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod telemetry;
pub mod threadpool;
