//! Fixed-size thread pool over std channels (offline substitute for tokio
//! / rayon).  The coordinator's event loop and the DSE sweeps run on it.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A simple work-stealing-free pool: one shared queue, N workers.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("pbsp-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Pool sized to the machine (at least 2).
    pub fn default_size() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n.max(2))
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("workers alive");
    }

    /// Map `items` through `f` in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx): (Sender<(usize, R)>, Receiver<(usize, R)>) = channel();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let r = f(item);
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.expect("worker panicked")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the queue
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        drop(tx);
        for _ in rx {}
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect(), |i: i32| i * 2);
        assert_eq!(out, (0..50).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang or panic
    }

    #[test]
    fn map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.map(Vec::<i32>::new(), |i| i);
        assert!(out.is_empty());
    }
}
