//! Fixed-size thread pool over std channels (offline substitute for
//! tokio / rayon), plus the ordered scatter-gather [`ThreadPool::par_map`]
//! the evaluation spine runs on.
//!
//! Who actually runs on the pool (kept in sync with ARCHITECTURE.md):
//!
//! * the DSE sweeps — `dse::sweep::{zr_table1, tpisa_sweep}` shard their
//!   per-model ISS runs across the pool owned by
//!   `dse::context::EvalContext` (the `--threads` knob);
//! * workload profiling — `bespoke::profile::profile_all` and friends;
//! * batch ISS harness runs — `ml::harness::{run_rv32_on, run_tpisa_on}`
//!   shard samples;
//! * the coordinator's bulk path — `coordinator::service::Service::crosscheck`
//!   fans one verification job per (model, precision) out over the
//!   pool, each driving the bulk `scores` path concurrently.
//!
//! The PJRT runtime does **not** run here: its handles are not `Send`,
//! so it stays on the coordinator's dedicated worker thread
//! (`coordinator::service`).
//!
//! Parallel results are gathered in input order and the aggregation
//! types folded over them (`sim::trace::Profile`, `ml::harness::BatchRun`)
//! merge in that order, so every report is bit-identical at any thread
//! count — the determinism tests in `tests/parallel_determinism.rs`
//! enforce this.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::util::telemetry::{self, Counter, Gauge};

type Job = Box<dyn FnOnce() + Send + 'static>;
type CaughtPanic = Box<dyn std::any::Any + Send + 'static>;

/// A simple work-stealing-free pool: one shared queue, N workers.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    /// Shared job queue.  Workers block on it; `par_map` gathers also
    /// steal from it (via `try_lock`) while waiting, so nested
    /// scatter-gathers make progress even with every worker busy.
    queue: Arc<Mutex<Receiver<Job>>>,
    workers: Vec<JoinHandle<()>>,
    /// Telemetry handles (process-global series — every pool in the
    /// process shares them; see `util::telemetry`).
    depth: Arc<Gauge>,
    helped: Arc<Counter>,
    panics: Arc<Counter>,
}

/// Worker count used when no explicit `--threads` is given: the
/// `PBSP_THREADS` environment variable if set to a positive integer,
/// else the machine's available parallelism.
pub fn default_threads() -> usize {
    std::env::var("PBSP_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4))
}

/// The process-wide shared pool, sized by [`default_threads`] — for
/// callers that do not own an `EvalContext` (and its pool).
pub fn global() -> &'static ThreadPool {
    static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();
    GLOBAL.get_or_init(|| ThreadPool::new(default_threads()))
}

/// Record one gathered result, remembering the lowest-indexed panic.
fn record<R>(
    out: &mut [Option<R>],
    first_panic: &mut Option<(usize, CaughtPanic)>,
    i: usize,
    r: Result<R, CaughtPanic>,
) {
    match r {
        Ok(v) => out[i] = Some(v),
        Err(p) => {
            if first_panic.as_ref().map_or(true, |(fi, _)| i < *fi) {
                *first_panic = Some((i, p));
            }
        }
    }
}

/// Pretend a borrowing job is `'static`.  Sound only because `par_map`
/// receives every job's result before returning (see the SAFETY comment
/// at its call site).
unsafe fn erase_lifetime<'a>(job: Box<dyn FnOnce() + Send + 'a>) -> Job {
    std::mem::transmute(job)
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let reg = telemetry::global();
        let depth = reg.gauge("pbsp_pool_queue_depth", "jobs queued but not yet started");
        let jobs =
            reg.counter("pbsp_pool_worker_jobs_total", "jobs completed by pool worker threads");
        let helped = reg.counter(
            "pbsp_pool_help_runs_total",
            "jobs run by gathering threads helping drain the queue (par_map)",
        );
        let panics = reg.counter(
            "pbsp_pool_job_panics_total",
            "pool jobs that panicked and were contained (worker survived)",
        );
        let (tx, rx) = channel::<Job>();
        let queue = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let queue = Arc::clone(&queue);
                let depth = Arc::clone(&depth);
                let jobs = Arc::clone(&jobs);
                let panics = Arc::clone(&panics);
                std::thread::Builder::new()
                    .name(format!("pbsp-worker-{i}"))
                    .spawn(move || loop {
                        let job = { queue.lock().unwrap().recv() };
                        match job {
                            Ok(job) => {
                                depth.sub(1);
                                // Contain handler panics: a panicking job
                                // must not take the worker thread (and the
                                // pool's capacity) down with it.  `par_map`
                                // jobs carry their own catch_unwind and
                                // report panics through their result
                                // channel; this outer catch only sees
                                // panics from bare `execute` jobs.
                                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                                    panics.inc();
                                }
                                jobs.inc();
                            }
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), queue, workers, depth, helped, panics }
    }

    /// Pool sized to the machine (at least 2).
    pub fn default_size() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n.max(2))
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.send_job(Box::new(job));
    }

    fn send_job(&self, job: Job) {
        self.depth.add(1);
        self.tx.as_ref().expect("pool shut down").send(job).expect("workers alive");
    }

    /// Run one queued job on the calling thread if one is immediately
    /// available; returns whether a job was run.  `try_lock` (not
    /// `lock`): an idle worker holds the queue mutex while blocked in
    /// `recv`, and waiting for it here could outlive our own results.
    fn try_run_one(&self) -> bool {
        let job = match self.queue.try_lock() {
            Ok(q) => q.try_recv().ok(),
            Err(_) => None,
        };
        match job {
            Some(job) => {
                self.depth.sub(1);
                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                    self.panics.inc();
                }
                self.helped.inc();
                true
            }
            None => false,
        }
    }

    /// Scatter `items` across the pool and gather the results of `f` in
    /// input order.
    ///
    /// Unlike [`ThreadPool::map`], the closure and the items may borrow
    /// from the caller's stack: the call returns only after every job
    /// has finished.  While gathering, the calling thread helps drain
    /// the shared queue, so `par_map` may be nested (a job may itself
    /// call `par_map` on the same pool) without deadlocking.
    ///
    /// If any job panics, the payload of the lowest-indexed failing
    /// item is re-raised here — after all jobs have completed, so no
    /// borrow escapes and the pool stays usable.
    pub fn par_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        let n = items.len();
        let (rtx, rrx) = channel::<(usize, Result<R, CaughtPanic>)>();
        {
            let f = &f;
            for (i, item) in items.into_iter().enumerate() {
                let rtx = rtx.clone();
                let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    let r = catch_unwind(AssertUnwindSafe(|| f(item)));
                    let _ = rtx.send((i, r));
                });
                // SAFETY: the gather loop below blocks until all `n`
                // results have been received, so every job — and every
                // borrow of `f`, the items and the caller's stack it
                // captures — completes before `par_map` returns.
                self.send_job(unsafe { erase_lifetime(job) });
            }
        }
        drop(rtx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut first_panic: Option<(usize, CaughtPanic)> = None;
        let mut received = 0usize;
        while received < n {
            match rrx.try_recv() {
                Ok((i, r)) => {
                    received += 1;
                    record(&mut out, &mut first_panic, i, r);
                    continue;
                }
                Err(TryRecvError::Empty) => {}
                Err(TryRecvError::Disconnected) => {
                    panic!("pool shut down with {} results outstanding", n - received)
                }
            }
            if self.try_run_one() {
                continue;
            }
            // Nothing runnable right now: park until a result lands.
            match rrx.recv_timeout(Duration::from_millis(1)) {
                Ok((i, r)) => {
                    received += 1;
                    record(&mut out, &mut first_panic, i, r);
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    panic!("pool shut down with {} results outstanding", n - received)
                }
            }
        }
        if let Some((_, p)) = first_panic {
            resume_unwind(p);
        }
        out.into_iter().map(|r| r.expect("result missing")).collect()
    }

    /// Map `items` through `f` in parallel, preserving order —
    /// [`ThreadPool::par_map`] for owned (`'static`) data.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        self.par_map(items, f)
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the queue
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        drop(tx);
        for _ in rx {}
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect(), |i: i32| i * 2);
        assert_eq!(out, (0..50).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang or panic
    }

    #[test]
    fn map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.map(Vec::<i32>::new(), |i| i);
        assert!(out.is_empty());
    }

    /// Satellite: ordered scatter-gather over *borrowed* data.
    #[test]
    fn par_map_borrows_and_preserves_order() {
        let pool = ThreadPool::new(4);
        let base: Vec<i64> = (0..100).collect();
        let out = pool.par_map((0..100usize).collect::<Vec<_>>(), |i| base[i] * 3);
        assert_eq!(out, (0..100).map(|i| i * 3).collect::<Vec<i64>>());
    }

    /// Satellite: panics propagate to the caller (lowest index wins)
    /// and the pool survives them.
    #[test]
    fn par_map_propagates_panics() {
        let pool = ThreadPool::new(2);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.par_map(vec![0, 1, 2, 3], |i| {
                if i % 2 == 1 {
                    panic!("boom {i}");
                }
                i
            })
        }));
        let payload = r.expect_err("panic must propagate");
        let msg = payload.downcast_ref::<String>().cloned().unwrap_or_default();
        assert_eq!(msg, "boom 1");
        // The workers caught the panic, so the pool is still usable.
        assert_eq!(pool.par_map(vec![1, 2], |i| i + 1), vec![2, 3]);
    }

    /// Nested `par_map` on the same pool must not deadlock, even with
    /// fewer workers than outer jobs (the gather loop helps drain the
    /// queue).
    #[test]
    fn par_map_nests_without_deadlock() {
        let pool = ThreadPool::new(2);
        let out = pool.par_map((0..8).collect::<Vec<i32>>(), |i| {
            pool.par_map((0..8).collect::<Vec<i32>>(), |j| i * 10 + j).iter().sum::<i32>()
        });
        let want: Vec<i32> = (0..8).map(|i| (0..8).map(|j| i * 10 + j).sum()).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn par_map_single_thread_matches_sequential() {
        let pool = ThreadPool::new(1);
        let out = pool.par_map((0..20).collect::<Vec<u64>>(), |i| i * i);
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<u64>>());
    }

    /// Satellite (ISSUE 10): a panicking `execute` job is contained by
    /// the worker — the pool keeps serving subsequent jobs at full
    /// capacity.  One worker makes the regression sharp: before the
    /// catch_unwind the panic killed the only worker and the follow-up
    /// jobs hung forever.
    #[test]
    fn execute_survives_panicking_job() {
        let pool = ThreadPool::new(1);
        let before = telemetry::global()
            .counter("pbsp_pool_job_panics_total", "")
            .get();
        pool.execute(|| panic!("handler blew up"));
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        for _ in 0..16 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        drop(tx);
        for _ in rx {}
        assert_eq!(counter.load(Ordering::SeqCst), 16);
        let after = telemetry::global()
            .counter("pbsp_pool_job_panics_total", "")
            .get();
        assert!(after > before, "contained panic must be counted");
    }

    #[test]
    fn default_threads_is_positive() {
        assert!(default_threads() > 0);
        assert!(global().threads() > 0);
    }
}
