//! Small statistics helpers used by the benchmark harness, the
//! coordinator/server metrics and reports.

use super::rng::Pcg32;

/// Summary statistics of a sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile with linear interpolation; `q` in [0, 100].
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (pos - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

/// Nearest-rank percentile (`q` in [0, 100]) over an *unsorted* sample;
/// returns NaN on an empty slice.  Unlike [`percentile`], it never
/// interpolates: the result is always an observed value, which is what
/// latency reporting wants (an interpolated "p99" can be a latency no
/// request ever saw).
pub fn percentile_nearest(xs: &[f64], q: f64) -> f64 {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_nearest_sorted(&sorted, q)
}

/// [`percentile_nearest`] over an already-sorted sample — callers
/// querying several percentiles sort once and index repeatedly.
pub fn percentile_nearest_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let rank = (q / 100.0 * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty(), "empty sample");
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n: xs.len(),
        mean: mean(xs),
        std: std_dev(xs),
        min: sorted[0],
        max: *sorted.last().unwrap(),
        p50: percentile(&sorted, 50.0),
        p95: percentile(&sorted, 95.0),
        p99: percentile(&sorted, 99.0),
    }
}

/// Geometric mean (used for averaged speedups).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Fixed-capacity uniform sample of an unbounded stream (Vitter's
/// Algorithm R) plus exact running count/sum/min/max, so means stay
/// exact and percentiles stay available while memory stays bounded.
///
/// Replacement choices come from an owned PCG stream, so two reservoirs
/// with the same seed fed the same values are identical — metrics built
/// on this stay deterministic for a given request sequence.
#[derive(Debug, Clone)]
pub struct Reservoir {
    cap: usize,
    seen: u64,
    sum: f64,
    min: f64,
    max: f64,
    sample: Vec<f64>,
    rng: Pcg32,
}

impl Reservoir {
    pub fn new(cap: usize, seed: u64) -> Reservoir {
        assert!(cap > 0);
        Reservoir {
            cap,
            seen: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            sample: Vec::new(),
            rng: Pcg32::seeded(seed),
        }
    }

    pub fn push(&mut self, v: f64) {
        self.seen += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        if self.sample.len() < self.cap {
            self.sample.push(v);
        } else {
            // Keep each of the `seen` values with probability cap/seen.
            let j = self.rng.below(self.seen);
            if (j as usize) < self.cap {
                self.sample[j as usize] = v;
            }
        }
    }

    /// Total values observed (not the retained sample size).
    pub fn count(&self) -> u64 {
        self.seen
    }

    pub fn is_empty(&self) -> bool {
        self.seen == 0
    }

    /// Exact running sum over *all* observed values.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact mean over all observed values (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.seen == 0 {
            f64::NAN
        } else {
            self.sum / self.seen as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.seen == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.seen == 0 {
            f64::NAN
        } else {
            self.max
        }
    }

    /// Nearest-rank percentile estimated from the retained sample
    /// (exact while `count() <= cap`; NaN when empty).
    pub fn percentile(&self, q: f64) -> f64 {
        percentile_nearest(&self.sample, q)
    }

    /// Several percentiles from a single sort of the retained sample.
    pub fn percentiles(&self, qs: &[f64]) -> Vec<f64> {
        let mut sorted = self.sample.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        qs.iter().map(|&q| percentile_nearest_sorted(&sorted, q)).collect()
    }

    /// The retained sample (unsorted, insertion/replacement order).
    pub fn samples(&self) -> &[f64] {
        &self.sample
    }

    /// Fold another reservoir into this one (sharded collectors merging
    /// into a global view).  Count, sum, min and max merge **exactly**.
    /// The retained sample merges exactly too while both sides are
    /// still complete (no value has been evicted) and the union fits in
    /// `cap`; past that, each retained slot is drawn from one side with
    /// probability proportional to how many values that side has seen —
    /// an unbiased (with-replacement) estimate of the union stream.
    /// All randomness comes from `self`'s own PCG stream, so merging
    /// the same reservoirs in the same order is deterministic.
    pub fn merge(&mut self, other: &Reservoir) {
        if other.seen == 0 {
            return;
        }
        let complete = self.seen == self.sample.len() as u64
            && other.seen == other.sample.len() as u64
            && self.sample.len() + other.sample.len() <= self.cap;
        if complete {
            self.sample.extend_from_slice(&other.sample);
        } else {
            let total = self.seen + other.seen;
            let k = self.cap.min(self.sample.len() + other.sample.len());
            let mut merged = Vec::with_capacity(k);
            for _ in 0..k {
                let from_self = !self.sample.is_empty()
                    && (other.sample.is_empty() || self.rng.below(total) < self.seen);
                let src = if from_self { &self.sample } else { &other.sample };
                merged.push(src[self.rng.below(src.len() as u64) as usize]);
            }
            self.sample = merged;
        }
        self.seen += other.seen;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = summarize(&xs);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.p50 - 50.5).abs() < 1e-9);
        assert!((s.p95 - 95.05).abs() < 1e-9);
    }

    #[test]
    fn single_element() {
        let s = summarize(&[5.0]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p99, 5.0);
    }

    #[test]
    fn geomean_of_speedups() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn nearest_rank_empty_is_nan() {
        assert!(percentile_nearest(&[], 50.0).is_nan());
    }

    #[test]
    fn nearest_rank_single_element() {
        for q in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile_nearest(&[7.5], q), 7.5);
        }
    }

    #[test]
    fn nearest_rank_never_interpolates() {
        // Interpolation boundaries: on [1, 2, 3, 4] the interpolating
        // percentile would return 2.5 at q=50; nearest-rank must pick
        // an observed value at every boundary.
        let xs = [4.0, 2.0, 1.0, 3.0]; // unsorted on purpose
        assert_eq!(percentile_nearest(&xs, 0.0), 1.0); // rank clamps to 1
        assert_eq!(percentile_nearest(&xs, 25.0), 1.0);
        assert_eq!(percentile_nearest(&xs, 25.1), 2.0);
        assert_eq!(percentile_nearest(&xs, 50.0), 2.0);
        assert_eq!(percentile_nearest(&xs, 50.1), 3.0);
        assert_eq!(percentile_nearest(&xs, 75.0), 3.0);
        assert_eq!(percentile_nearest(&xs, 75.1), 4.0);
        assert_eq!(percentile_nearest(&xs, 100.0), 4.0);
    }

    #[test]
    fn reservoir_exact_below_capacity() {
        let mut r = Reservoir::new(8, 1);
        assert!(r.is_empty() && r.mean().is_nan() && r.percentile(50.0).is_nan());
        for v in [3.0, 1.0, 2.0] {
            r.push(v);
        }
        assert_eq!(r.count(), 3);
        assert_eq!(r.sum(), 6.0);
        assert_eq!(r.mean(), 2.0);
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), 3.0);
        assert_eq!(r.percentile(50.0), 2.0);
        assert_eq!(r.percentiles(&[50.0, 100.0]), vec![2.0, 3.0]);
        assert_eq!(r.samples().len(), 3);
    }

    #[test]
    fn reservoir_bounds_memory_and_keeps_exact_aggregates() {
        let cap = 16;
        let mut r = Reservoir::new(cap, 42);
        let n = 10_000u64;
        for i in 1..=n {
            r.push(i as f64);
        }
        assert_eq!(r.samples().len(), cap);
        assert_eq!(r.count(), n);
        // Sum and mean are exact even though the sample is capped.
        assert_eq!(r.sum(), (n * (n + 1) / 2) as f64);
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), n as f64);
        // Every retained value is from the stream, and the median
        // estimate lands in the bulk of the distribution.
        for &v in r.samples() {
            assert!((1.0..=n as f64).contains(&v));
        }
        let p50 = r.percentile(50.0);
        assert!((1.0..=n as f64).contains(&p50));
    }

    #[test]
    fn reservoir_merge_is_exact_while_complete() {
        // Neither side has evicted and the union fits: the merged
        // sample is the exact union, so percentiles stay exact.
        let mut a = Reservoir::new(8, 1);
        let mut b = Reservoir::new(8, 2);
        for v in [1.0, 5.0, 3.0] {
            a.push(v);
        }
        for v in [4.0, 2.0] {
            b.push(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), 5);
        assert_eq!(a.sum(), 15.0);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 5.0);
        let mut s = a.samples().to_vec();
        s.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert_eq!(s, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(a.percentile(50.0), 3.0);
    }

    #[test]
    fn reservoir_merge_keeps_exact_aggregates_past_overflow() {
        let cap = 8;
        let mut a = Reservoir::new(cap, 3);
        let mut b = Reservoir::new(cap, 4);
        for i in 1..=1000 {
            a.push(i as f64);
        }
        for i in 1001..=1500 {
            b.push(i as f64);
        }
        a.merge(&b);
        // Aggregates are exact even though both samples were evicting.
        assert_eq!(a.count(), 1500);
        assert_eq!(a.sum(), (1500.0 + 1.0) * 1500.0 / 2.0);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 1500.0);
        // The sample stays bounded and inside the union's range.
        assert_eq!(a.samples().len(), cap);
        for &v in a.samples() {
            assert!((1.0..=1500.0).contains(&v));
        }
    }

    #[test]
    fn reservoir_merge_is_deterministic() {
        let build = || {
            let mut a = Reservoir::new(16, 7);
            let mut b = Reservoir::new(16, 8);
            for i in 0..500 {
                a.push((i * 13 % 977) as f64);
                b.push((i * 31 % 977) as f64);
            }
            a.merge(&b);
            a
        };
        let (x, y) = (build(), build());
        assert_eq!(x.samples(), y.samples());
        assert_eq!(x.count(), y.count());
        assert_eq!(x.sum(), y.sum());
    }

    #[test]
    fn reservoir_merge_handles_empty_sides() {
        let mut a = Reservoir::new(4, 1);
        let b = Reservoir::new(4, 2);
        a.merge(&b); // empty into empty: still empty
        assert!(a.is_empty());
        let mut c = Reservoir::new(4, 3);
        for i in 0..100 {
            c.push(i as f64);
        }
        a.merge(&c); // full into empty
        assert_eq!(a.count(), 100);
        assert_eq!(a.samples().len(), 4);
        let before = c.count();
        c.merge(&Reservoir::new(4, 5)); // empty into full: no-op
        assert_eq!(c.count(), before);
    }

    #[test]
    fn reservoir_is_deterministic_for_a_seed() {
        let feed = |seed| {
            let mut r = Reservoir::new(4, seed);
            for i in 0..1000 {
                r.push((i * 7 % 113) as f64);
            }
            r.samples().to_vec()
        };
        assert_eq!(feed(9), feed(9));
        assert_ne!(feed(9), feed(10)); // astronomically unlikely to collide
    }
}
