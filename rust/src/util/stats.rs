//! Small statistics helpers used by the benchmark harness and reports.

/// Summary statistics of a sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile with linear interpolation; `q` in [0, 100].
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (pos - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty(), "empty sample");
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n: xs.len(),
        mean: mean(xs),
        std: std_dev(xs),
        min: sorted[0],
        max: *sorted.last().unwrap(),
        p50: percentile(&sorted, 50.0),
        p95: percentile(&sorted, 95.0),
        p99: percentile(&sorted, 99.0),
    }
}

/// Geometric mean (used for averaged speedups).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = summarize(&xs);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.p50 - 50.5).abs() < 1e-9);
        assert!((s.p95 - 95.05).abs() < 1e-9);
    }

    #[test]
    fn single_element() {
        let s = summarize(&[5.0]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p99, 5.0);
    }

    #[test]
    fn geomean_of_speedups() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
    }
}
