//! Process-wide telemetry: named lock-free counters, gauges, and
//! fixed-bucket log₂ histograms behind one registry, rendered as JSON
//! or Prometheus text exposition (`GET /metrics?format=prometheus`).
//!
//! Design constraints, in order:
//!
//! 1. **No `Mutex` on hot paths.**  Counters are sharded over
//!    cache-line-padded atomics (a thread increments only its own
//!    shard; `get()` sums).  Gauges and histogram buckets are single
//!    relaxed atomics.  The registry's `Mutex` is taken only at
//!    registration (once per metric per subsystem start) and at scrape
//!    time.
//! 2. **Strictly side-channel.**  Nothing here feeds back into
//!    scheduling, scoring, or batching decisions — bit-identity of
//!    every scoring path is unaffected by telemetry being on.
//! 3. **Zero dependencies.**  The Prometheus text format is simple
//!    enough to emit by hand (`# HELP`/`# TYPE` + samples; histograms
//!    as cumulative `_bucket{le=...}` + `_sum` + `_count`).
//!
//! Registration is get-or-create: any subsystem may ask for
//! `pbsp_pool_queue_depth` and all of them share the one series.  That
//! makes the registry process-global (`telemetry::global()`) without
//! ownership plumbing — a deliberate trade: multiple servers in one
//! process (tests) accumulate into shared series, which scrapes must
//! treat as monotone counters, exactly as Prometheus semantics demand.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::json::Value;

/// Counter shard count: enough that a handful of pool workers plus the
/// reactor rarely collide, small enough that `get()` stays trivial.
const SHARDS: usize = 16;

/// One cache line per shard so two threads' increments never false-share.
#[repr(align(64))]
#[derive(Default)]
struct PaddedU64(AtomicU64);

/// Each thread sticks to one shard, assigned round-robin on first use.
fn shard_of_thread() -> usize {
    use std::cell::Cell;
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static SHARD: Cell<usize> = const { Cell::new(usize::MAX) };
    }
    SHARD.with(|s| {
        let mut i = s.get();
        if i == usize::MAX {
            i = NEXT.fetch_add(1, Ordering::Relaxed) % SHARDS;
            s.set(i);
        }
        i
    })
}

/// Monotone counter, sharded per thread.  `add` is one relaxed
/// `fetch_add` on a thread-private cache line.
pub struct Counter {
    shards: [PaddedU64; SHARDS],
}

impl Counter {
    fn new() -> Counter {
        Counter { shards: Default::default() }
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.shards[shard_of_thread()].0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.shards.iter().map(|s| s.0.load(Ordering::Relaxed)).sum()
    }
}

/// Up-down gauge (queue depths, occupancy).  Signed so transient
/// dec-before-inc interleavings can't wrap.
pub struct Gauge {
    v: AtomicI64,
}

impl Gauge {
    fn new() -> Gauge {
        Gauge { v: AtomicI64::new(0) }
    }

    pub fn set(&self, v: i64) {
        self.v.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, n: i64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn sub(&self, n: i64) {
        self.v.fetch_sub(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Bucket count for [`Histogram`]: bucket `i` holds values with ≤ `i`
/// significant bits, i.e. upper bound `2^i - 1`; the last bucket is
/// `+Inf`.  31 finite buckets cover 0 .. ~2^30 µs (~18 minutes) — far
/// past any request this server serves.
pub const HIST_BUCKETS: usize = 32;

/// Fixed-bucket log₂ histogram of `u64` observations (microseconds by
/// convention here).  `observe` is three relaxed `fetch_add`s; no
/// allocation, no lock, no float math.
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    pub fn observe(&self, v: u64) {
        let b = (u64::BITS - v.leading_zeros()) as usize;
        self.buckets[b.min(HIST_BUCKETS - 1)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Per-bucket counts (not cumulative); index = significant bits.
    pub fn snapshot(&self) -> [u64; HIST_BUCKETS] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }
}

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

struct Entry {
    metric: Metric,
    help: &'static str,
}

/// Named metric registry.  The map lock is taken only on
/// register/scrape; handles returned from registration are plain
/// `Arc`s the caller stores and hits lock-free.
#[derive(Default)]
pub struct Registry {
    entries: Mutex<BTreeMap<String, Entry>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get-or-create a counter series.  Panics if the name is already
    /// registered as a different kind (a code bug, not a runtime state).
    pub fn counter(&self, name: &str, help: &'static str) -> Arc<Counter> {
        let mut map = self.entries.lock().unwrap();
        let e = map.entry(name.to_string()).or_insert_with(|| Entry {
            metric: Metric::Counter(Arc::new(Counter::new())),
            help,
        });
        match &e.metric {
            Metric::Counter(c) => Arc::clone(c),
            _ => panic!("telemetry: {name} already registered as a non-counter"),
        }
    }

    /// Labelled counter: the series key is `name{k="v",...}`, with
    /// labels sorted by the caller's order (keep it stable).
    pub fn counter_with(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        help: &'static str,
    ) -> Arc<Counter> {
        self.counter(&series_key(name, labels), help)
    }

    pub fn gauge(&self, name: &str, help: &'static str) -> Arc<Gauge> {
        let mut map = self.entries.lock().unwrap();
        let e = map.entry(name.to_string()).or_insert_with(|| Entry {
            metric: Metric::Gauge(Arc::new(Gauge::new())),
            help,
        });
        match &e.metric {
            Metric::Gauge(g) => Arc::clone(g),
            _ => panic!("telemetry: {name} already registered as a non-gauge"),
        }
    }

    pub fn histogram(&self, name: &str, help: &'static str) -> Arc<Histogram> {
        let mut map = self.entries.lock().unwrap();
        let e = map.entry(name.to_string()).or_insert_with(|| Entry {
            metric: Metric::Histogram(Arc::new(Histogram::new())),
            help,
        });
        match &e.metric {
            Metric::Histogram(h) => Arc::clone(h),
            _ => panic!("telemetry: {name} already registered as a non-histogram"),
        }
    }

    /// Prometheus text exposition for every registered series, grouped
    /// by base name (`# HELP`/`# TYPE` once per name, then samples).
    pub fn render_prometheus(&self, out: &mut String) {
        let map = self.entries.lock().unwrap();
        // Group label variants under their base name so one HELP/TYPE
        // header covers all of them (exposition-format requirement).
        let mut groups: BTreeMap<&str, Vec<(&str, &Entry)>> = BTreeMap::new();
        for (key, entry) in map.iter() {
            let base = key.split('{').next().unwrap_or(key);
            groups.entry(base).or_default().push((key, entry));
        }
        for (base, series) in groups {
            let (kind, help) = match &series[0].1.metric {
                Metric::Counter(_) => ("counter", series[0].1.help),
                Metric::Gauge(_) => ("gauge", series[0].1.help),
                Metric::Histogram(_) => ("histogram", series[0].1.help),
            };
            push_header(out, base, kind, help);
            for (key, entry) in series {
                match &entry.metric {
                    Metric::Counter(c) => {
                        out.push_str(key);
                        out.push(' ');
                        out.push_str(&c.get().to_string());
                        out.push('\n');
                    }
                    Metric::Gauge(g) => {
                        out.push_str(key);
                        out.push(' ');
                        out.push_str(&g.get().to_string());
                        out.push('\n');
                    }
                    Metric::Histogram(h) => push_histogram(out, key, h),
                }
            }
        }
    }

    /// Flat JSON view: counters/gauges as numbers, histograms as
    /// `{count, sum}` objects.  Series keys keep their label suffix.
    pub fn to_json(&self) -> Value {
        let map = self.entries.lock().unwrap();
        let mut obj = std::collections::BTreeMap::new();
        for (key, entry) in map.iter() {
            let v = match &entry.metric {
                Metric::Counter(c) => Value::from(c.get() as i64),
                Metric::Gauge(g) => Value::from(g.get()),
                Metric::Histogram(h) => Value::obj(vec![
                    ("count", Value::from(h.count() as i64)),
                    ("sum", Value::from(h.sum() as i64)),
                ]),
            };
            obj.insert(key.clone(), v);
        }
        Value::Obj(obj)
    }
}

fn series_key(name: &str, labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let mut key = String::with_capacity(name.len() + 16 * labels.len());
    key.push_str(name);
    key.push('{');
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            key.push(',');
        }
        key.push_str(k);
        key.push_str("=\"");
        // Escape per the exposition format (our label values are model
        // and variant names, but stay correct anyway).
        for ch in v.chars() {
            match ch {
                '\\' => key.push_str("\\\\"),
                '"' => key.push_str("\\\""),
                '\n' => key.push_str("\\n"),
                c => key.push(c),
            }
        }
        key.push('"');
    }
    key.push('}');
    key
}

fn push_header(out: &mut String, name: &str, kind: &str, help: &'static str) {
    out.push_str("# HELP ");
    out.push_str(name);
    out.push(' ');
    out.push_str(help);
    out.push_str("\n# TYPE ");
    out.push_str(name);
    out.push(' ');
    out.push_str(kind);
    out.push('\n');
}

fn push_histogram(out: &mut String, key: &str, h: &Histogram) {
    // `key` may carry labels; bucket lines splice `le` into the set.
    let (base, labels) = match key.split_once('{') {
        Some((b, rest)) => (b, rest.trim_end_matches('}')),
        None => (key, ""),
    };
    let snapshot = h.snapshot();
    let mut cumulative = 0u64;
    for (i, n) in snapshot.iter().enumerate() {
        cumulative += n;
        let le = if i == HIST_BUCKETS - 1 {
            "+Inf".to_string()
        } else {
            ((1u64 << i) - 1).to_string()
        };
        out.push_str(base);
        out.push_str("_bucket{");
        if !labels.is_empty() {
            out.push_str(labels);
            out.push(',');
        }
        out.push_str("le=\"");
        out.push_str(&le);
        out.push_str("\"} ");
        out.push_str(&cumulative.to_string());
        out.push('\n');
    }
    for (suffix, v) in [("_sum", h.sum()), ("_count", h.count())] {
        out.push_str(base);
        out.push_str(suffix);
        if !labels.is_empty() {
            out.push('{');
            out.push_str(labels);
            out.push('}');
        }
        out.push(' ');
        out.push_str(&v.to_string());
        out.push('\n');
    }
}

/// Append a hand-rendered counter sample (used by `/metrics` for
/// per-instance `ServerMetrics`/coordinator values that live outside
/// the registry).
pub fn prom_counter(out: &mut String, name: &str, help: &'static str, v: u64) {
    push_header(out, name, "counter", help);
    out.push_str(name);
    out.push(' ');
    out.push_str(&v.to_string());
    out.push('\n');
}

/// Append a hand-rendered gauge sample (see [`prom_counter`]).
pub fn prom_gauge(out: &mut String, name: &str, help: &'static str, v: f64) {
    push_header(out, name, "gauge", help);
    out.push_str(name);
    out.push(' ');
    if v.is_finite() {
        // Integral gauges print as integers (avoids "3.0"-style noise).
        if v == v.trunc() && v.abs() < 1e15 {
            out.push_str(&(v as i64).to_string());
        } else {
            out.push_str(&format!("{v}"));
        }
    } else {
        out.push_str("NaN");
    }
    out.push('\n');
}

/// The process-global registry every subsystem registers into.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_sums_across_threads() {
        let r = Registry::new();
        let c = r.counter("t_counter_total", "help");
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(c.get(), 8000);
    }

    #[test]
    fn get_or_create_returns_the_same_series() {
        let r = Registry::new();
        r.counter("t_shared_total", "help").add(2);
        r.counter("t_shared_total", "help").add(3);
        assert_eq!(r.counter("t_shared_total", "help").get(), 5);
    }

    #[test]
    fn gauge_tracks_up_and_down() {
        let r = Registry::new();
        let g = r.gauge("t_depth", "help");
        g.add(5);
        g.sub(2);
        assert_eq!(g.get(), 3);
        g.set(-1);
        assert_eq!(g.get(), -1);
    }

    #[test]
    fn histogram_buckets_by_log2() {
        let r = Registry::new();
        let h = r.histogram("t_us", "help");
        for v in [0, 1, 2, 3, 1000, u64::MAX] {
            h.observe(v);
        }
        assert_eq!(h.count(), 6);
        let s = h.snapshot();
        assert_eq!(s[0], 1, "0 has zero significant bits");
        assert_eq!(s[1], 1, "1 has one");
        assert_eq!(s[2], 2, "2 and 3 have two");
        assert_eq!(s[10], 1, "1000 has ten");
        assert_eq!(s[HIST_BUCKETS - 1], 1, "u64::MAX lands in +Inf");
    }

    #[test]
    fn histogram_bucket_boundaries_are_exact() {
        // Every finite bucket edge: 2^k - 1 is the last value of bucket
        // k, 2^k the first of bucket k+1, up to the +Inf clamp.
        let r = Registry::new();
        for k in 1..=29usize {
            let h = r.histogram(&format!("t_edge_{k}_us"), "edge");
            h.observe((1u64 << k) - 1);
            h.observe(1u64 << k);
            let s = h.snapshot();
            assert_eq!(s[k], 1, "2^{k}-1 must land in bucket {k}");
            assert_eq!(s[k + 1], 1, "2^{k} must land in bucket {}", k + 1);
        }
        // The clamp: 2^30 - 1 is the last finite-bucketed value; 2^30
        // and everything above (2^62, u64::MAX - 1, u64::MAX) share the
        // +Inf bucket.
        let h = r.histogram("t_clamp_us", "clamp");
        h.observe((1u64 << 30) - 1);
        h.observe(1u64 << 30);
        h.observe(1u64 << 62);
        h.observe(u64::MAX - 1);
        h.observe(u64::MAX);
        let s = h.snapshot();
        assert_eq!(s[HIST_BUCKETS - 2], 1, "2^30-1 fills the last finite bucket");
        assert_eq!(s[HIST_BUCKETS - 1], 4, "everything >= 2^30 clamps to +Inf");
        assert_eq!(h.count(), 5);
    }

    #[test]
    fn histogram_sum_wraps_on_extreme_values_but_count_stays_exact() {
        // `sum` is a relaxed u64 fetch_add: two u64::MAX observations
        // wrap.  Pin the wrapping semantics (the JSON/Prometheus sum is
        // best-effort at these magnitudes) and that count/buckets stay
        // exact regardless.
        let r = Registry::new();
        let h = r.histogram("t_wrap_us", "wrap");
        h.observe(u64::MAX);
        h.observe(u64::MAX);
        h.observe(3);
        assert_eq!(h.count(), 3);
        assert_eq!(h.sum(), u64::MAX.wrapping_add(u64::MAX).wrapping_add(3));
        let s = h.snapshot();
        assert_eq!(s[HIST_BUCKETS - 1], 2);
        assert_eq!(s[2], 1);
    }

    #[test]
    fn prometheus_rendering_is_well_formed() {
        let r = Registry::new();
        r.counter("t_reqs_total", "requests").add(7);
        r.counter_with("t_by_model_total", &[("model", "mlp_c"), ("variant", "p8")], "by model")
            .add(2);
        r.gauge("t_depth", "depth").set(4);
        r.histogram("t_lat_us", "latency").observe(5);
        let mut out = String::new();
        r.render_prometheus(&mut out);
        assert!(out.contains("# HELP t_reqs_total requests\n"));
        assert!(out.contains("# TYPE t_reqs_total counter\n"));
        assert!(out.contains("t_reqs_total 7\n"));
        assert!(out.contains("t_by_model_total{model=\"mlp_c\",variant=\"p8\"} 2\n"));
        assert!(out.contains("# TYPE t_depth gauge\n"));
        assert!(out.contains("t_depth 4\n"));
        assert!(out.contains("# TYPE t_lat_us histogram\n"));
        assert!(out.contains("t_lat_us_bucket{le=\"7\"} 1\n"), "5 lands in the ≤7 bucket:\n{out}");
        assert!(out.contains("t_lat_us_bucket{le=\"+Inf\"} 1\n"));
        assert!(out.contains("t_lat_us_sum 5\n"));
        assert!(out.contains("t_lat_us_count 1\n"));
        // HELP/TYPE precede the first sample of their series.
        let help_at = out.find("# HELP t_reqs_total").unwrap();
        let sample_at = out.find("\nt_reqs_total 7").unwrap();
        assert!(help_at < sample_at);
    }

    #[test]
    fn histogram_bucket_counts_are_cumulative_in_text() {
        let r = Registry::new();
        let h = r.histogram("t_cum_us", "help");
        h.observe(1); // bucket 1 (le 1)
        h.observe(100); // bucket 7 (le 127)
        let mut out = String::new();
        r.render_prometheus(&mut out);
        assert!(out.contains("t_cum_us_bucket{le=\"1\"} 1\n"));
        assert!(out.contains("t_cum_us_bucket{le=\"127\"} 2\n"));
        assert!(out.contains("t_cum_us_bucket{le=\"+Inf\"} 2\n"));
    }

    #[test]
    fn json_view_carries_every_series() {
        let r = Registry::new();
        r.counter("t_a_total", "a").add(1);
        r.gauge("t_b", "b").set(2);
        r.histogram("t_c_us", "c").observe(3);
        let v = r.to_json();
        assert_eq!(v.get("t_a_total").unwrap().as_i64().unwrap(), 1);
        assert_eq!(v.get("t_b").unwrap().as_i64().unwrap(), 2);
        assert_eq!(v.get("t_c_us").unwrap().get("count").unwrap().as_i64().unwrap(), 1);
    }
}
