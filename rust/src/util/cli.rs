//! Hand-rolled CLI argument parser (offline substitute for clap).
//!
//! Supports `binary <subcommand> [positionals] [--flag] [--key value]`.
//! Unknown options are errors; every accessor records the option so
//! `finish()` can reject typos.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line.
#[derive(Debug, Clone)]
pub struct Args {
    pub positionals: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an explicit token list (tests) or `std::env::args`.
    pub fn parse(tokens: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut positionals = Vec::new();
        let mut options = BTreeMap::new();
        let mut flags = Vec::new();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    options.insert(name.to_string(), it.next().unwrap());
                } else {
                    flags.push(name.to_string());
                }
            } else {
                positionals.push(tok);
            }
        }
        Ok(Args { positionals, options, flags, consumed: Default::default() })
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    /// First positional = subcommand.
    pub fn subcommand(&self) -> Option<&str> {
        self.positionals.first().map(String::as_str)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.consumed.borrow_mut().push(name.to_string());
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt_str(&self, name: &str) -> Option<&str> {
        self.consumed.borrow_mut().push(name.to_string());
        self.options.get(name).map(String::as_str)
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.opt_str(name).unwrap_or(default).to_string()
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt_str(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow!("--{name} {s:?}: {e}")),
        }
    }

    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.opt_parse(name)?.unwrap_or(default))
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.opt_str(name).ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    /// The shared `--threads` knob: explicit option, else `PBSP_THREADS`,
    /// else the machine's parallelism
    /// ([`crate::util::threadpool::default_threads`]).
    pub fn threads(&self) -> Result<usize> {
        let t = self.parse_or("threads", crate::util::threadpool::default_threads())?;
        if t == 0 {
            bail!("--threads must be positive");
        }
        Ok(t)
    }

    /// Reject unknown options/flags (call after all accessors).
    pub fn finish(&self) -> Result<()> {
        let seen = self.consumed.borrow();
        for k in self.options.keys() {
            if !seen.iter().any(|s| s == k) {
                bail!("unknown option --{k}");
            }
        }
        for f in &self.flags {
            if !seen.iter().any(|s| s == f) {
                bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = args("report fig1 extra");
        assert_eq!(a.subcommand(), Some("report"));
        assert_eq!(a.positionals, vec!["report", "fig1", "extra"]);
    }

    #[test]
    fn options_and_flags() {
        let a = args("run --iters 10 --verbose --out=x.json");
        assert_eq!(a.parse_or("iters", 0usize).unwrap(), 10);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.opt_str("out"), Some("x.json"));
    }

    #[test]
    fn finish_rejects_unknown() {
        let a = args("run --bogus 1");
        assert!(a.finish().is_err());
        let a = args("run --iters 3");
        let _ = a.parse_or("iters", 0usize).unwrap();
        assert!(a.finish().is_ok());
    }

    #[test]
    fn parse_errors_are_reported() {
        let a = args("run --iters ten");
        assert!(a.parse_or("iters", 0usize).is_err());
    }

    #[test]
    fn require_missing() {
        let a = args("run");
        assert!(a.require("model").is_err());
    }

    #[test]
    fn threads_knob() {
        let a = args("report --threads 3");
        assert_eq!(a.threads().unwrap(), 3);
        assert!(a.finish().is_ok());
        // Zero is rejected; absent falls back to a positive default.
        assert!(args("report --threads 0").threads().is_err());
        assert!(args("report").threads().unwrap() > 0);
    }
}
