//! Mini benchmark harness (offline substitute for criterion): warmup +
//! timed iterations with mean/std/min reporting.  `cargo bench` targets
//! use `harness = false` and drive this directly.

use std::time::Instant;

use super::stats;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub std_ms: f64,
    pub min_ms: f64,
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} {:>10.3} ms/iter (±{:.3}, min {:.3}, n={})",
            self.name, self.mean_ms, self.std_ms, self.min_ms, self.iters
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ms: stats::mean(&samples),
        std_ms: stats::std_dev(&samples),
        min_ms: samples.iter().cloned().fold(f64::INFINITY, f64::min),
    };
    println!("{r}");
    r
}

/// Throughput helper: report items/second from a timed closure that
/// processes `items` per call.
pub fn bench_throughput<F: FnMut()>(
    name: &str,
    items: usize,
    warmup: usize,
    iters: usize,
    f: F,
) -> f64 {
    let r = bench(name, warmup, iters, f);
    let per_sec = items as f64 / (r.min_ms / 1e3);
    println!("{:<40} {:>12.0} items/s (best)", format!("{name} [throughput]"), per_sec);
    per_sec
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench("noop-ish", 1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(r.iters, 5);
        assert!(r.mean_ms >= 0.0 && r.min_ms <= r.mean_ms + 1e-9);
    }
}
