//! Minimal JSON parser / serialiser (offline substitute for serde_json).
//!
//! Supports the full JSON grammar needed by the artifact interchange
//! (manifest.json, weights/*.json): objects, arrays, strings with escape
//! sequences, numbers (parsed as f64 — all integers in the artifacts fit
//! f64 exactly, |v| < 2^53), booleans and null.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    /// Read and parse a JSON file.
    pub fn from_file(path: impl AsRef<std::path::Path>) -> Result<Value> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 || n.abs() >= 2f64.powi(53) {
            bail!("not an exact integer: {n}");
        }
        Ok(n as i64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_i64()?;
        usize::try_from(n).map_err(|_| anyhow!("negative index {n}"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// Array of numbers -> Vec<f64>.
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Array of integers -> Vec<i64>.
    pub fn as_i64_vec(&self) -> Result<Vec<i64>> {
        self.as_arr()?.iter().map(|v| v.as_i64()).collect()
    }

    /// Array of arrays of numbers -> row-major matrix.
    pub fn as_f64_mat(&self) -> Result<Vec<Vec<f64>>> {
        self.as_arr()?.iter().map(|v| v.as_f64_vec()).collect()
    }

    pub fn as_i64_mat(&self) -> Result<Vec<Vec<i64>>> {
        self.as_arr()?.iter().map(|v| v.as_i64_vec()).collect()
    }

    // -- constructors ------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(v: &[f64]) -> Value {
        Value::Arr(v.iter().map(|&x| Value::Num(x)).collect())
    }

    pub fn arr_str(v: &[&str]) -> Value {
        Value::Arr(v.iter().map(|s| Value::Str(s.to_string())).collect())
    }
}

impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Num(v)
    }
}
impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Num(v as f64)
    }
}
impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::Num(v as f64)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}
impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::Str(v)
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek()? as char
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'n' => self.literal("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected byte {:?} at {}", c as char, self.pos),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                c => bail!("expected ',' or '}}', found {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Value::Arr(out));
                }
                c => bail!("expected ',' or ']', found {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                            let cp = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.pos += 4;
                            // Surrogate pairs: only BMP escapes appear in our
                            // artifacts, but handle pairs for completeness.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes.get(self.pos) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .bytes
                                        .get(self.pos + 2..self.pos + 6)
                                        .ok_or_else(|| anyhow!("truncated surrogate"))?;
                                    let lo =
                                        u32::from_str_radix(std::str::from_utf8(hex2)?, 16)?;
                                    self.pos += 6;
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    bail!("lone surrogate");
                                }
                            } else {
                                cp
                            };
                            out.push(
                                char::from_u32(ch).ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence starting at c.
                    let start = self.pos - 1;
                    let len = utf8_len(c)?;
                    let slice = self
                        .bytes
                        .get(start..start + len)
                        .ok_or_else(|| anyhow!("truncated UTF-8"))?;
                    out.push_str(std::str::from_utf8(slice)?);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek()? == b'-' {
            self.pos += 1;
        }
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Value::Num(s.parse::<f64>().with_context(|| format!("bad number {s:?}"))?))
    }
}

fn utf8_len(first: u8) -> Result<usize> {
    match first {
        0x00..=0x7F => Ok(1),
        0xC0..=0xDF => Ok(2),
        0xE0..=0xEF => Ok(3),
        0xF0..=0xF7 => Ok(4),
        _ => bail!("invalid UTF-8 start byte {first:#x}"),
    }
}

// ---------------------------------------------------------------------------
// Serialiser
// ---------------------------------------------------------------------------

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Value::Str(s) => write_escaped(f, s),
            Value::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Value::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse(" false ").unwrap(), Value::Bool(false));
        assert_eq!(Value::parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(Value::parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(Value::parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Value::Null);
    }

    #[test]
    fn parse_escapes() {
        let v = Value::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\Aé");
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Value::parse("\"héllo ∑\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ∑");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("12 34").is_err());
        assert!(Value::parse("{'a': 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"s":"x\"y"},"t":true,"z":null}"#;
        let v = Value::parse(src).unwrap();
        let v2 = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn integers_exact() {
        let v = Value::parse("2147483647").unwrap();
        assert_eq!(v.as_i64().unwrap(), i32::MAX as i64);
        let v = Value::parse("-2147483648").unwrap();
        assert_eq!(v.as_i64().unwrap(), i32::MIN as i64);
        assert!(Value::parse("1.5").unwrap().as_i64().is_err());
    }

    #[test]
    fn matrices() {
        let v = Value::parse("[[1,2],[3,4]]").unwrap();
        assert_eq!(v.as_i64_mat().unwrap(), vec![vec![1, 2], vec![3, 4]]);
    }
}
