//! Core "synthesis": composes a unit inventory into area / power / fmax —
//! the stand-in for the paper's Synopsys DC + EGFET reports (workflow
//! steps ① and ⑤, Fig. 3).
//!
//! Two parametric core generators are provided:
//!
//! * [`zero_riscy`] — the 32-bit 2-stage RISC-V core, parameterised by
//!   everything the bespoke flow trims: register count, PC width, BAR
//!   width, the debug / IRQ / compressed-decoder units, the retained CSR
//!   fraction and the multiplier option (baseline multi-stage MUL, the
//!   SIMD MAC unit, or none).
//! * [`tpisa`] — the minimal width-configurable printed core of Bleier
//!   et al. (ISCA'20), parameterised by datapath width and MAC option.
//!
//! The baseline Zero-Riscy inventory is calibrated against the paper's
//! anchors (67.53 cm², 291.21 mW) via the EGFET per-GE constants.

use super::components as c;
use super::egfet::Technology;
use super::mac_unit::MacConfig;

/// Functional unit classes (Fig. 1b groups: EX, MUL, RF, IF/ID/Ctl, ...).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UnitKind {
    Mul,
    MacUnit,
    RegFile,
    Alu,
    Lsu,
    IfStage,
    Decoder,
    Controller,
    CompressedDec,
    Csr,
    Debug,
    Irq,
    Pipeline,
}

impl UnitKind {
    pub fn name(&self) -> &'static str {
        match self {
            UnitKind::Mul => "MUL",
            UnitKind::MacUnit => "MAC",
            UnitKind::RegFile => "RF",
            UnitKind::Alu => "EX",
            UnitKind::Lsu => "LSU",
            UnitKind::IfStage => "IF",
            UnitKind::Decoder => "ID",
            UnitKind::Controller => "CTL",
            UnitKind::CompressedDec => "CDEC",
            UnitKind::Csr => "CSR",
            UnitKind::Debug => "DEBUG",
            UnitKind::Irq => "IRQ",
            UnitKind::Pipeline => "PIPE",
        }
    }
}

/// One synthesised functional unit.
#[derive(Debug, Clone)]
pub struct Unit {
    pub kind: UnitKind,
    pub ge: f64,
    pub activity: f64,
    /// Critical-path contribution in logic levels.
    pub depth: u32,
}

/// Multiplier option of a core configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MulOption {
    /// No hardware multiply (baseline TP-ISA: software shift-add).
    None,
    /// Zero-Riscy's 3-cycle multi-stage 32x32 multiplier.
    Baseline,
    /// The paper's SIMD MAC unit.
    Mac(MacConfig),
}

/// A core configuration: the input to "synthesis" and to the ISS timing
/// model.  Produced by the baseline generators and transformed by the
/// bespoke reduction pass.
#[derive(Debug, Clone, PartialEq)]
pub struct CoreSpec {
    pub name: String,
    /// "zero-riscy" | "tp-isa" (selects the ISS).
    pub family: CoreFamily,
    pub datapath: u32,
    pub regs: u32,
    pub pc_bits: u32,
    pub bar_bits: u32,
    pub has_debug: bool,
    pub has_irq: bool,
    pub has_compressed_dec: bool,
    /// Fraction of the CSR block retained (1.0 = full, paper trims to a
    /// rump of counters).
    pub csr_fraction: f64,
    /// Fraction of the ISA actually decoded (bespoke trims the decoder
    /// and controller for removed instructions; a base FSM remains).
    pub isa_fraction: f64,
    pub mul: MulOption,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoreFamily {
    ZeroRiscy,
    TpIsa,
}

/// Baseline Zero-Riscy (RV32IM, 2-stage, 32 registers, full units).
pub fn zero_riscy() -> CoreSpec {
    CoreSpec {
        name: "zero-riscy".into(),
        family: CoreFamily::ZeroRiscy,
        datapath: 32,
        regs: 32,
        pc_bits: 32,
        bar_bits: 32,
        has_debug: true,
        has_irq: true,
        has_compressed_dec: true,
        csr_fraction: 1.0,
        isa_fraction: 1.0,
        mul: MulOption::Baseline,
    }
}

/// Baseline TP-ISA at a given datapath width (paper synthesises the
/// 4-bit and 32-bit configurations in Fig. 1a).
pub fn tpisa(datapath: u32) -> CoreSpec {
    assert!(matches!(datapath, 4 | 8 | 16 | 32), "TP-ISA widths: 4/8/16/32");
    CoreSpec {
        name: format!("tp-isa-d{datapath}"),
        family: CoreFamily::TpIsa,
        datapath,
        regs: 8,
        pc_bits: 10,
        bar_bits: 10,
        has_debug: false,
        has_irq: false,
        has_compressed_dec: false,
        csr_fraction: 0.0,
        isa_fraction: 1.0,
        mul: MulOption::None,
    }
}

impl CoreSpec {
    /// Instantiate the unit inventory for this configuration.
    pub fn units(&self) -> Vec<Unit> {
        match self.family {
            CoreFamily::ZeroRiscy => self.zero_riscy_units(),
            CoreFamily::TpIsa => self.tpisa_units(),
        }
    }

    fn mul_units(&self, out: &mut Vec<Unit>) {
        match self.mul {
            MulOption::None => {}
            MulOption::Baseline => out.push(Unit {
                kind: UnitKind::Mul,
                // 32x32 array staged over 3 cycles + two 64-bit staging regs.
                ge: c::array_multiplier(32, 32) + 2.0 * c::dff(64),
                activity: 1.30,
                depth: c::array_multiplier_depth(32, 32) / 3 + 6,
            }),
            MulOption::Mac(cfg) => out.push(Unit {
                kind: UnitKind::MacUnit,
                ge: cfg.ge(),
                activity: cfg.activity(),
                depth: cfg.depth(),
            }),
        }
    }

    fn zero_riscy_units(&self) -> Vec<Unit> {
        let d = self.datapath;
        let mut units = vec![
            Unit {
                kind: UnitKind::RegFile,
                ge: c::regfile(self.regs, d, 2),
                activity: 0.95,
                depth: c::regfile_depth(self.regs),
            },
            Unit {
                // EX: main adder, branch adder, barrel shifter, logic
                // ops, comparator, result muxing, flag logic.
                kind: UnitKind::Alu,
                ge: 2.0 * c::adder(d)
                    + c::barrel_shifter(d)
                    + 4.0 * c::mux2(d)
                    + c::comparator(d)
                    + 3.0 * c::mux2(d)
                    + 450.0,
                activity: 1.15,
                depth: c::adder_depth(d) + 6,
            },
            Unit {
                kind: UnitKind::Lsu,
                ge: 2256.0 + 2.0 * c::dff(self.bar_bits) + c::adder(self.bar_bits),
                activity: 1.0,
                depth: 12,
            },
            Unit {
                kind: UnitKind::IfStage,
                ge: 892.0 + c::dff(self.pc_bits) + 2.0 * c::adder(self.pc_bits),
                activity: 1.10,
                depth: c::adder_depth(self.pc_bits) / 2 + 6,
            },
            Unit {
                kind: UnitKind::Decoder,
                ge: 2200.0 * (0.45 + 0.55 * self.isa_fraction),
                activity: 1.0,
                depth: 8,
            },
            Unit {
                kind: UnitKind::Controller,
                ge: 2600.0 * (0.45 + 0.55 * self.isa_fraction),
                activity: 1.0,
                depth: 10,
            },
            Unit { kind: UnitKind::Pipeline, ge: 900.0, activity: 1.20, depth: 2 },
        ];
        if self.csr_fraction > 0.0 {
            units.push(Unit {
                kind: UnitKind::Csr,
                ge: 2400.0 * self.csr_fraction,
                activity: 0.70,
                depth: 8,
            });
        }
        if self.has_compressed_dec {
            units.push(Unit { kind: UnitKind::CompressedDec, ge: 600.0, activity: 0.8, depth: 6 });
        }
        if self.has_debug {
            units.push(Unit { kind: UnitKind::Debug, ge: 1400.0, activity: 0.30, depth: 6 });
        }
        if self.has_irq {
            units.push(Unit { kind: UnitKind::Irq, ge: 400.0, activity: 0.50, depth: 5 });
        }
        self.mul_units(&mut units);
        units
    }

    fn tpisa_units(&self) -> Vec<Unit> {
        let d = self.datapath;
        let mut units = vec![
            Unit {
                kind: UnitKind::RegFile,
                ge: c::regfile(self.regs, d, 1),
                activity: 0.95,
                depth: c::regfile_depth(self.regs),
            },
            Unit {
                kind: UnitKind::Alu,
                ge: c::adder(d) + c::barrel_shifter(d) + 3.0 * c::mux2(d) + 90.0,
                activity: 1.15,
                depth: c::adder_depth(d) + 4,
            },
            Unit {
                kind: UnitKind::Lsu,
                ge: 90.0 + c::dff(self.bar_bits) + c::adder(self.bar_bits) / 2.0,
                activity: 1.0,
                depth: 8,
            },
            Unit {
                kind: UnitKind::IfStage,
                ge: 60.0 + c::dff(self.pc_bits) + c::adder(self.pc_bits),
                activity: 1.10,
                depth: c::adder_depth(self.pc_bits) / 2 + 4,
            },
            Unit { kind: UnitKind::Decoder, ge: 160.0, activity: 1.0, depth: 5 },
            Unit { kind: UnitKind::Controller, ge: 220.0, activity: 1.0, depth: 6 },
        ];
        self.mul_units(&mut units);
        units
    }
}

/// A synthesis report: the analytical analogue of the DC area/power/fmax
/// tables the paper extracts in workflow steps ① and ⑤.
#[derive(Debug, Clone)]
pub struct SynthReport {
    pub name: String,
    pub total_ge: f64,
    pub area_mm2: f64,
    pub power_mw: f64,
    pub fmax_hz: f64,
    pub critical_depth: u32,
    /// Per-unit (kind, GE, area mm², power mW).
    pub breakdown: Vec<(UnitKind, f64, f64, f64)>,
}

impl SynthReport {
    pub fn area_cm2(&self) -> f64 {
        self.area_mm2 / 100.0
    }

    /// Fraction of total area in the given unit kinds.
    pub fn area_fraction(&self, kinds: &[UnitKind]) -> f64 {
        let part: f64 = self
            .breakdown
            .iter()
            .filter(|(k, ..)| kinds.contains(k))
            .map(|(_, _, a, _)| a)
            .sum();
        part / self.area_mm2
    }

    /// Fraction of total power in the given unit kinds.
    pub fn power_fraction(&self, kinds: &[UnitKind]) -> f64 {
        let part: f64 = self
            .breakdown
            .iter()
            .filter(|(k, ..)| kinds.contains(k))
            .map(|(_, _, _, p)| p)
            .sum();
        part / self.power_mw
    }
}

/// Synthesise a core configuration in a technology.
pub fn synthesize(spec: &CoreSpec, tech: &Technology) -> SynthReport {
    let units = spec.units();
    let mut total_ge = 0.0;
    let mut area = 0.0;
    let mut power_uw = 0.0;
    let mut depth = 0;
    let mut breakdown = Vec::with_capacity(units.len());
    for u in &units {
        let a = tech.area_mm2(u.ge);
        let p = tech.power_uw(u.ge, u.activity);
        total_ge += u.ge;
        area += a;
        power_uw += p;
        depth = depth.max(u.depth);
        breakdown.push((u.kind, u.ge, a, p / 1000.0));
    }
    SynthReport {
        name: spec.name.clone(),
        total_ge,
        area_mm2: area,
        power_mw: power_uw / 1000.0,
        fmax_hz: tech.fmax_hz(depth),
        critical_depth: depth,
        breakdown,
    }
}

#[cfg(test)]
mod tests {
    use super::super::egfet::{egfet, ZERO_RISCY_AREA_CM2, ZERO_RISCY_POWER_MW};
    use super::*;

    #[test]
    fn calibration_anchors() {
        // The EGFET per-GE constants are fixed so baseline Zero-Riscy
        // reproduces the paper's published numbers (§III-A).
        let r = synthesize(&zero_riscy(), &egfet());
        let da = (r.area_cm2() - ZERO_RISCY_AREA_CM2).abs() / ZERO_RISCY_AREA_CM2;
        let dp = (r.power_mw - ZERO_RISCY_POWER_MW).abs() / ZERO_RISCY_POWER_MW;
        assert!(da < 0.005, "area {} cm2 vs anchor {} ({da:.4})", r.area_cm2(), ZERO_RISCY_AREA_CM2);
        assert!(dp < 0.005, "power {} mW vs anchor {} ({dp:.4})", r.power_mw, ZERO_RISCY_POWER_MW);
    }

    #[test]
    fn mul_rf_almost_half() {
        // Paper Fig. 1b: "the multi-stage multiplier unit and the
        // register file ... account for almost half of the total area
        // and power consumption, at 46.5% and 46.2%".
        let r = synthesize(&zero_riscy(), &egfet());
        let af = r.area_fraction(&[UnitKind::Mul, UnitKind::RegFile]);
        let pf = r.power_fraction(&[UnitKind::Mul, UnitKind::RegFile]);
        assert!((0.42..=0.54).contains(&af), "area fraction {af}");
        assert!((0.42..=0.56).contains(&pf), "power fraction {pf}");
    }

    #[test]
    fn tpisa_within_technology_limits() {
        // Fig. 1a: both TP-ISA configurations are far smaller than ZR.
        let t = egfet();
        let zr = synthesize(&zero_riscy(), &t);
        let tp4 = synthesize(&tpisa(4), &t);
        let tp32 = synthesize(&tpisa(32), &t);
        assert!(tp32.area_mm2 < zr.area_mm2 / 5.0);
        assert!(tp4.area_mm2 < tp32.area_mm2);
        assert!(tp4.power_mw < tp32.power_mw);
        // And clock faster (shallower paths).
        assert!(tp4.fmax_hz > zr.fmax_hz);
    }

    #[test]
    fn bespoke_reductions_shrink_core() {
        let t = egfet();
        let base = synthesize(&zero_riscy(), &t);
        let mut b = zero_riscy();
        b.regs = 12;
        b.pc_bits = 10;
        b.bar_bits = 8;
        b.has_debug = false;
        b.has_irq = false;
        b.has_compressed_dec = false;
        b.csr_fraction = 0.15;
        let r = synthesize(&b, &t);
        assert!(r.area_mm2 < base.area_mm2);
        assert!(r.power_mw < base.power_mw);
    }

    #[test]
    fn mac_area_ordering_matches_table1() {
        // Replacing the baseline MUL: MAC32 costs slightly MORE (gain
        // dips, Table I: 10.6% -> 8.2%), P16/P8/P4 progressively less.
        let t = egfet();
        let mk = |mul: MulOption| {
            let mut s = zero_riscy();
            s.mul = mul;
            synthesize(&s, &t).area_mm2
        };
        let base = mk(MulOption::Baseline);
        let m32 = mk(MulOption::Mac(MacConfig::new(32, 32)));
        let m16 = mk(MulOption::Mac(MacConfig::new(32, 16)));
        let m8 = mk(MulOption::Mac(MacConfig::new(32, 8)));
        let m4 = mk(MulOption::Mac(MacConfig::new(32, 4)));
        assert!(m32 > base, "MAC32 {m32} should exceed baseline {base}");
        assert!(m16 < base && m8 < m16 && m4 < m8, "{m16} {m8} {m4}");
    }

    #[test]
    fn tpisa_mac_overhead_factor() {
        // Table II ballpark: TP-ISA 8-bit with an 8-bit MAC costs ~2x
        // area and slightly less in power factor.
        let t = egfet();
        let base = synthesize(&tpisa(8), &t);
        let mut m = tpisa(8);
        m.mul = MulOption::Mac(MacConfig::new(8, 8));
        m.name = "tp-isa-d8-mac".into();
        let r = synthesize(&m, &t);
        let area_x = r.area_mm2 / base.area_mm2;
        let power_x = r.power_mw / base.power_mw;
        assert!((1.5..=2.6).contains(&area_x), "area factor {area_x}");
        assert!((1.4..=2.6).contains(&power_x), "power factor {power_x}");
    }

    #[test]
    fn breakdown_sums_to_totals() {
        let r = synthesize(&zero_riscy(), &egfet());
        let a: f64 = r.breakdown.iter().map(|(_, _, a, _)| a).sum();
        let p: f64 = r.breakdown.iter().map(|(_, _, _, p)| p).sum();
        assert!((a - r.area_mm2).abs() < 1e-9);
        assert!((p - r.power_mw).abs() < 1e-9);
    }
}
