//! EGFET (electrolyte-gated FET) printed-technology constants.
//!
//! Calibrated against the anchors the paper publishes for this library
//! (§III-A): baseline Zero-Riscy synthesises to **67.53 cm²** and
//! **291.21 mW**, and one ROM cell costs **0.84 mm²** / **18.23 µW**.
//! `AREA_PER_GE` / `POWER_PER_GE` are fixed so that the Zero-Riscy unit
//! inventory in [`super::synth`] reproduces those numbers exactly; a unit
//! test guards the calibration.
//!
//! Printed EGFET circuits switch slowly: typical operating frequencies
//! are a few Hz to a few kHz (paper §II), which the per-level gate delay
//! reproduces.

/// Technology parameters for printed EGFET.
#[derive(Debug, Clone, PartialEq)]
pub struct Technology {
    pub name: &'static str,
    /// Area of one gate equivalent (NAND2), mm².
    pub area_per_ge_mm2: f64,
    /// Power of one GE at activity 1.0, µW.
    pub power_per_ge_uw: f64,
    /// Propagation delay of one logic level, µs.
    pub gate_delay_us: f64,
    /// Area of one ROM cell (one stored byte), mm² (paper: 0.84).
    pub rom_cell_area_mm2: f64,
    /// Power of one ROM cell, µW (paper: 18.23).
    pub rom_cell_power_uw: f64,
}

/// Calibration anchors from the paper.
pub const ZERO_RISCY_AREA_CM2: f64 = 67.53;
pub const ZERO_RISCY_POWER_MW: f64 = 291.21;

impl Technology {
    pub fn area_mm2(&self, ge: f64) -> f64 {
        ge * self.area_per_ge_mm2
    }

    pub fn power_uw(&self, ge: f64, activity: f64) -> f64 {
        ge * activity * self.power_per_ge_uw
    }

    /// Maximum clock for a given critical-path depth (logic levels),
    /// including a fixed sequencing overhead (setup + clock skew).
    pub fn fmax_hz(&self, depth_levels: u32) -> f64 {
        let period_us = self.gate_delay_us * (depth_levels as f64 + 6.0);
        1e6 / period_us
    }
}

/// The EGFET library used throughout the evaluation.
///
/// `area_per_ge_mm2` and `power_per_ge_uw` are calibrated in
/// `hw::synth::tests::calibration_anchors` so the baseline Zero-Riscy
/// inventory reproduces the paper's 67.53 cm² / 291.21 mW.
pub fn egfet() -> Technology {
    Technology {
        name: "EGFET",
        area_per_ge_mm2: 0.207_246_412_4,
        power_per_ge_uw: 8.740_850_487,
        gate_delay_us: 220.0,
        rom_cell_area_mm2: 0.84,
        rom_cell_power_uw: 18.23,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmax_in_printed_range() {
        let t = egfet();
        // Deep path (baseline ZR multiplier, ~70 levels) lands in the
        // tens-of-Hz range; shallow paths reach a few hundred Hz — the
        // "few Hz to a few kHz" envelope of §II.
        let slow = t.fmax_hz(70);
        let fast = t.fmax_hz(10);
        assert!(slow > 10.0 && slow < 200.0, "slow {slow}");
        assert!(fast > 100.0 && fast < 5000.0, "fast {fast}");
        assert!(fast > slow);
    }

    #[test]
    fn rom_cell_anchors() {
        let t = egfet();
        assert_eq!(t.rom_cell_area_mm2, 0.84);
        assert_eq!(t.rom_cell_power_uw, 18.23);
    }

    #[test]
    fn monotone_costs() {
        let t = egfet();
        assert!(t.area_mm2(100.0) > t.area_mm2(10.0));
        assert!(t.power_uw(100.0, 1.0) > t.power_uw(100.0, 0.5));
    }
}
