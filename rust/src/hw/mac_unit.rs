//! Hardware cost model of the paper's SIMD MAC unit (Fig. 2).
//!
//! For a `datapath`-bit register pair and lane precision `precision`, the
//! unit instantiates `L = max(1, datapath/precision)` lanes, each with a
//! `p x p` array multiplier feeding a per-lane accumulator (paper Eq. 1).
//! Accumulators are the 32-bit datapath register for p <= 16 on the
//! 32-bit cores, and a register pair for p = 32 — matching the bit-exact
//! functional model in `sim::mac_model` and the Pallas kernel.
//!
//! The functional behaviour lives in `sim::mac_model`; this module only
//! prices the unit.

use super::components as c;

/// Configuration of one SIMD MAC unit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MacConfig {
    /// Core datapath width (32 for Zero-Riscy; 4..32 for TP-ISA).
    pub datapath: u32,
    /// Lane precision n (paper: 32, 16, 8, 4).
    pub precision: u32,
}

impl MacConfig {
    pub fn new(datapath: u32, precision: u32) -> MacConfig {
        assert!(precision <= datapath, "precision wider than datapath");
        MacConfig { datapath, precision }
    }

    /// Number of concurrent lanes (paper: 32/n; the 4-bit TP-ISA cannot
    /// parallelise, §IV-A).
    pub fn lanes(&self) -> u32 {
        (self.datapath / self.precision).max(1)
    }

    /// Per-lane accumulator width of the *functional* model: the 32-bit
    /// datapath register for p <= 16 (wrapping, as in `sim::mac_model`
    /// and the Pallas kernel), a register pair for p = 32.
    pub fn acc_bits(&self) -> u32 {
        if self.precision >= 32 {
            64
        } else {
            self.datapath.max(2 * self.precision)
        }
    }

    /// Accumulator width *priced* in hardware: 2p + 6 guard bits (the
    /// paper's Fig. 2 design).  The quantisation contract keeps every
    /// real workload's |acc| within the functional i32 model, so the two
    /// widths never disagree on executed programs; DESIGN.md documents
    /// the seam.
    pub fn acc_cost_bits(&self) -> u32 {
        (2 * self.precision + 6).min(64)
    }

    /// Gate count of the whole unit: per-lane multiplier + accumulator
    /// adder/register + partial-product staging register, plus operand
    /// lane routing and the shared rescale/saturate output stage.
    pub fn ge(&self) -> f64 {
        let p = self.precision;
        let l = self.lanes() as f64;
        let acc = self.acc_cost_bits();
        let per_lane = c::array_multiplier(p, p)
            + c::adder(acc)
            + c::dff(acc)
            + c::dff(2 * p); // partial-product staging (keeps fmax)
        let unpack = c::mux2(self.datapath) * 2.0; // operand lane routing
        let rescale = c::barrel_shifter(acc) + c::comparator(acc);
        let control = 60.0;
        l * per_lane + unpack + rescale + control
    }

    /// Critical-path depth.  The unit registers its partial-product rows
    /// (see `ge`), so the visible stage is roughly half the combinational
    /// multiplier depth plus the accumulate add — single-cycle *issue*
    /// without dragging the core clock down.
    pub fn depth(&self) -> u32 {
        c::array_multiplier_depth(self.precision, self.precision) / 2 + 4
    }

    /// Switching activity.  Lower than the baseline multi-stage
    /// multiplier (1.30): the staged operand banks give operand
    /// isolation, cutting idle toggling.
    pub fn activity(&self) -> f64 {
        1.05
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_counts_match_paper() {
        // Fig. 2: "for each option, the unit can be split into 1, 2, 4
        // and 8 concurrent operations respectively".
        assert_eq!(MacConfig::new(32, 32).lanes(), 1);
        assert_eq!(MacConfig::new(32, 16).lanes(), 2);
        assert_eq!(MacConfig::new(32, 8).lanes(), 4);
        assert_eq!(MacConfig::new(32, 4).lanes(), 8);
        // 4-bit TP-ISA: single lane (§IV-A).
        assert_eq!(MacConfig::new(4, 4).lanes(), 1);
    }

    #[test]
    fn smaller_precision_smaller_unit() {
        let ge: Vec<f64> =
            [32, 16, 8, 4].iter().map(|&p| MacConfig::new(32, p).ge()).collect();
        assert!(ge[0] > ge[1] && ge[1] > ge[2] && ge[2] > ge[3], "{ge:?}");
        // Paper premise: "replace large multipliers with small ones that
        // have less depth" — P16 should cost roughly half of MAC32.
        assert!(ge[1] / ge[0] < 0.62, "P16/P32 = {}", ge[1] / ge[0]);
    }

    #[test]
    fn smaller_precision_shallower() {
        let d: Vec<u32> =
            [32, 16, 8, 4].iter().map(|&p| MacConfig::new(32, p).depth()).collect();
        assert!(d[0] > d[1] && d[1] > d[2] && d[2] > d[3], "{d:?}");
    }

    #[test]
    fn acc_width_rules() {
        assert_eq!(MacConfig::new(32, 32).acc_bits(), 64);
        assert_eq!(MacConfig::new(32, 16).acc_bits(), 32);
        assert_eq!(MacConfig::new(32, 8).acc_bits(), 32);
        assert_eq!(MacConfig::new(8, 8).acc_bits(), 16);
        assert_eq!(MacConfig::new(4, 4).acc_bits(), 8);
    }

    #[test]
    #[should_panic(expected = "precision wider than datapath")]
    fn rejects_invalid() {
        MacConfig::new(8, 16);
    }
}
