//! Gate-equivalent (GE) and logic-depth formulas for datapath components.
//!
//! One GE = one NAND2.  The formulas are standard structural estimates
//! (ripple-carry adders, AND-array multipliers, DFF = 5 GE, 2:1 mux =
//! 1.25 GE) — coarse, but the evaluation only relies on *relative* cost,
//! and the absolute scale is calibrated against the paper's anchors in
//! [`super::egfet`].

/// GE of a D flip-flop bank.
pub fn dff(bits: u32) -> f64 {
    5.0 * bits as f64
}

/// GE of a ripple-carry adder (full adder ~ 7 GE/bit incl. carry chain).
pub fn adder(bits: u32) -> f64 {
    7.0 * bits as f64
}

/// Logic depth (levels) of a ripple-carry adder.
pub fn adder_depth(bits: u32) -> u32 {
    bits.max(1)
}

/// GE of a bank of 2:1 muxes.
pub fn mux2(bits: u32) -> f64 {
    1.25 * bits as f64
}

/// GE of an `inputs`:1 mux tree over `bits`-wide words.
pub fn mux_tree(inputs: u32, bits: u32) -> f64 {
    if inputs <= 1 {
        return 0.0;
    }
    (inputs - 1) as f64 * mux2(bits)
}

/// Depth of an `inputs`:1 mux tree.
pub fn mux_tree_depth(inputs: u32) -> u32 {
    if inputs <= 1 {
        0
    } else {
        32 - (inputs - 1).leading_zeros()
    }
}

/// GE of an n-to-2^n one-hot decoder (~1.5 GE per output line).
pub fn decoder(out_lines: u32) -> f64 {
    1.5 * out_lines as f64
}

/// GE of an unsigned/signed AND-array multiplier: n*m partial-product
/// AND gates plus (n-1) m-bit carry-save adder rows.
pub fn array_multiplier(n: u32, m: u32) -> f64 {
    (n * m) as f64 + adder(m) * (n.saturating_sub(1)) as f64
}

/// Depth of the array multiplier (carry-save rows then final ripple).
pub fn array_multiplier_depth(n: u32, m: u32) -> u32 {
    n + m
}

/// GE of a logarithmic barrel shifter.
pub fn barrel_shifter(bits: u32) -> f64 {
    mux_tree_depth(bits.max(2)) as f64 * mux2(bits)
}

pub fn barrel_shifter_depth(bits: u32) -> u32 {
    mux_tree_depth(bits.max(2))
}

/// GE of an equality/magnitude comparator.
pub fn comparator(bits: u32) -> f64 {
    3.5 * bits as f64
}

/// Register file cost: DFF storage rows, per-port read mux trees, write
/// decoder and word-line drivers.
pub fn regfile(words: u32, bits: u32, read_ports: u32) -> f64 {
    let storage = dff(bits) * words as f64;
    let read = read_ports as f64 * mux_tree(words, bits);
    let wdec = decoder(words);
    let drivers = 0.6 * (words * read_ports) as f64; // word lines
    storage + read + wdec + drivers
}

/// Register-file read depth: mux tree + output drive.
pub fn regfile_depth(words: u32) -> u32 {
    mux_tree_depth(words) + 2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adder_scales_linearly() {
        assert_eq!(adder(32), 2.0 * adder(16));
        assert_eq!(adder_depth(32), 32);
    }

    #[test]
    fn multiplier_quadratic_scaling() {
        let m32 = array_multiplier(32, 32);
        let m16 = array_multiplier(16, 16);
        let m8 = array_multiplier(8, 8);
        // Roughly 4x per halving (paper's premise: "replace large
        // multipliers with small ones that have less depth").
        assert!(m32 / m16 > 3.5 && m32 / m16 < 4.5, "{}", m32 / m16);
        assert!(m16 / m8 > 3.5 && m16 / m8 < 4.7);
        assert_eq!(array_multiplier_depth(16, 16), 32);
        assert!(array_multiplier_depth(8, 8) < array_multiplier_depth(32, 32));
    }

    #[test]
    fn mux_tree_sizes() {
        assert_eq!(mux_tree(1, 32), 0.0);
        assert_eq!(mux_tree(2, 32), mux2(32));
        assert_eq!(mux_tree_depth(32), 5);
        assert_eq!(mux_tree_depth(12), 4);
        assert_eq!(mux_tree_depth(2), 1);
    }

    #[test]
    fn regfile_shrinks_with_words() {
        let full = regfile(32, 32, 2);
        let trimmed = regfile(12, 32, 2);
        assert!(trimmed < full);
        // Trimming 32 -> 12 registers saves more than 40% of the RF.
        assert!(trimmed / full < 0.60, "ratio {}", trimmed / full);
        assert!(regfile_depth(12) < regfile_depth(32));
    }

    #[test]
    fn barrel_shifter_log_depth() {
        assert_eq!(barrel_shifter_depth(32), 5);
        assert!(barrel_shifter(32) < adder(32) * 4.0);
    }
}
