//! Printed-technology hardware cost model.
//!
//! This module replaces the paper's Synopsys DC + EGFET standard-cell
//! synthesis flow (unavailable here) with an analytical gate-level model:
//!
//! * [`components`] — gate-equivalent (GE) and logic-depth formulas for
//!   the datapath building blocks (adders, array multipliers, register
//!   files, muxes, decoders, barrel shifters).
//! * [`egfet`] — the EGFET technology constants, calibrated to the
//!   paper's published anchors: baseline Zero-Riscy = 67.53 cm² and
//!   291.21 mW; one ROM cell = 0.84 mm² and 18.23 µW (§III-A).
//! * [`mac_unit`] — the paper's SIMD MAC unit (Fig. 2) as a hardware
//!   cost model parameterised by datapath width and precision.
//! * [`rom`] — printed program-memory (ROM) cost model.
//! * [`synth`] — "synthesis": composes a core's unit inventory into
//!   area / power / fmax, the stand-in for Synopsys DC reports.
//!
//! The paper's evaluation is *relative* (% gains, overhead factors,
//! Pareto shape); an analytical model calibrated at the published
//! anchor points reproduces the relative ordering and crossovers, which
//! is what DESIGN.md commits to.

pub mod components;
pub mod egfet;
pub mod mac_unit;
pub mod rom;
pub mod synth;
