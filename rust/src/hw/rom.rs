//! Printed program-memory (ROM) cost model.
//!
//! The paper (§III-A): "Each ROM cell takes up 0.84 mm² and 18.23 µW,
//! favoring designs with narrower bit-widths and smaller code sizes."
//! We model one ROM cell as one stored byte plus an address decoder
//! sized by the address width — so narrower PCs and shorter programs
//! both shrink the memory, reproducing the paper's §IV-B memory
//! observations.

use super::components;
use super::egfet::Technology;

/// A program ROM holding `bytes` of code/data, addressed by `addr_bits`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Rom {
    pub bytes: u32,
    pub addr_bits: u32,
}

impl Rom {
    /// ROM sized exactly for a program image (address width = next power
    /// of two covering the image).
    pub fn for_image(bytes: u32) -> Rom {
        let addr_bits = 32 - bytes.max(2).next_power_of_two().leading_zeros() - 1;
        Rom { bytes, addr_bits }
    }

    /// Address-decoder gate count (amortised into the ROM macro): a
    /// row/column organisation shares the decode across 16-byte rows.
    pub fn decoder_ge(&self) -> f64 {
        let rows = (self.bytes.max(16) / 16).max(1);
        components::decoder(rows)
            + components::mux_tree(16, 8)
            + components::dff(self.addr_bits)
    }

    pub fn area_mm2(&self, tech: &Technology) -> f64 {
        self.bytes as f64 * tech.rom_cell_area_mm2 + tech.area_mm2(self.decoder_ge())
    }

    pub fn power_mw(&self, tech: &Technology) -> f64 {
        (self.bytes as f64 * tech.rom_cell_power_uw + tech.power_uw(self.decoder_ge(), 0.5))
            / 1000.0
    }
}

#[cfg(test)]
mod tests {
    use super::super::egfet::egfet;
    use super::*;

    #[test]
    fn for_image_addr_bits() {
        assert_eq!(Rom::for_image(256).addr_bits, 8);
        assert_eq!(Rom::for_image(257).addr_bits, 9);
        assert_eq!(Rom::for_image(1024).addr_bits, 10);
        assert_eq!(Rom::for_image(2).addr_bits, 1);
    }

    #[test]
    fn area_dominated_by_cells() {
        let t = egfet();
        let r = Rom::for_image(512);
        let cells = 512.0 * t.rom_cell_area_mm2;
        assert!(r.area_mm2(&t) >= cells);
        assert!(r.area_mm2(&t) < cells * 1.2);
    }

    #[test]
    fn smaller_code_smaller_rom() {
        let t = egfet();
        assert!(Rom::for_image(300).area_mm2(&t) < Rom::for_image(600).area_mm2(&t));
        assert!(Rom::for_image(300).power_mw(&t) < Rom::for_image(600).power_mw(&t));
    }
}
