//! TP-ISA ISS: the minimal width-configurable printed core.
//!
//! Timing model (multi-cycle minimal core, no pipeline):
//!
//! * 2 cycles per instruction (fetch, execute);
//! * loads/stores: 3 cycles;
//! * taken branches/jumps: 3 cycles;
//! * MAC extension: 2 cycles (single-cycle unit + fetch);
//! * HALT: 1 cycle.
//!
//! Registers are `d`-bit (the datapath width); C and Z flags support
//! multi-word arithmetic (ADC/SBC/SLC/SRC), which is how the baseline
//! core multiplies — "the whole operation is scheduled to the ALU"
//! (paper §III-B).
//!
//! Hot-loop architecture (§Perf iteration 3): the program and the
//! initial data-memory image live in an `Arc`-shared [`PreparedTpIsa`]
//! (constants preloaded once, not per sample), [`TpIsa::reset`]
//! memcpy-restores that image so one simulator runs a whole batch, and
//! [`TpIsa::run_traced`] is generic over a [`TraceMode`].
//!
//! §Perf iteration 4 adds [`TpIsa::run_translated`]: dispatch per
//! pre-translated basic block (`sim::translate`) with fused
//! superinstructions for the soft-multiply shift-add kernel and the
//! `ld/ld/mac` bodies, falling back to the per-instruction interpreter
//! step for untranslatable blocks, out-of-range PCs and fuel tails —
//! bit-identical in scores, cycles and profiles
//! (`tests/iss_equivalence.rs`).
//!
//! §Perf iteration 5 layers the batched lockstep engine
//! (`sim::batch::BatchTpIsa`) on the same building blocks: N lanes over
//! one shared image, each retiring the crate-visible
//! `exec_uop`/`apply_block`/`apply_term`/`step_traced` primitives in
//! exactly the scalar order — bit-identical per lane to a scalar
//! [`TpIsa::run_translated`] run (`tests/iss_batch_equivalence.rs`).

use std::sync::Arc;

use anyhow::Result;

use super::error::ExecError;
use super::fault::{BitFlip, FaultState, FlipTarget};
use super::mac_model::MacState;
use super::mem::WordMem;
use super::prepared::PreparedTpIsa;
use super::trace::{FullProfile, Profile, TraceMode};
use super::translate::{BlockTpIsa, CondTp, ExecStats, TermTpIsa, UopTpIsa, NO_BLOCK};
use crate::hw::mac_unit::MacConfig;
use crate::isa::tpisa::Instr;
use crate::isa::MacOp;

#[cold]
#[inline(never)]
fn pc_fault(pc: i64, len: usize) -> anyhow::Error {
    ExecError::FetchFaultTpIsa { pc, len }.into()
}

#[cold]
#[inline(never)]
fn mac_unavailable(op: MacOp) -> anyhow::Error {
    ExecError::MacUnavailable { op }.into()
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Halt {
    Halted,
    Fuel,
}

pub const ALL_MNEMONICS: &[&str] = &[
    "ldi", "add", "adc", "sub", "sbc", "and", "or", "xor", "shl", "shr", "sra", "slc", "src",
    "ld", "st", "addi", "mov", "sxt", "clc", "bz", "bnz", "bc", "bnc", "jmp", "mac", "macrd",
    "maccl", "halt",
];

/// The TP-ISA simulator.
pub struct TpIsa {
    pub width: u32,
    pub regs: [u64; 8],
    pub pc: i64,
    pub carry: bool,
    pub zero: bool,
    pub dmem: WordMem,
    pub mac: Option<MacState>,
    /// Shared prepared program image (code + initial dmem image).
    prepared: Arc<PreparedTpIsa>,
    pub profile: Profile,
    /// Translated-engine counters (blocks dispatched, fallback steps).
    /// Accumulates across [`TpIsa::reset`], like the profile.
    pub exec_stats: ExecStats,
    /// Armed soft-error plan (`sim::fault`).  `None` — the default —
    /// costs one pointer-null check per retire; an armed empty plan is
    /// bit-identical to `None` (pinned by `tests/fault_identity.rs`).
    pub fault: Option<Box<FaultState>>,
}

impl TpIsa {
    /// Build a simulator with a zeroed data memory (callers preload
    /// constants themselves).  Batch callers should build one
    /// [`PreparedTpIsa`] — with the constants in its initial dmem
    /// image — and use [`TpIsa::from_prepared`] instead.
    pub fn new(width: u32, code: &[Instr], dmem_words: usize, mac: Option<MacConfig>) -> Self {
        Self::from_prepared(Arc::new(PreparedTpIsa::with_zero_dmem(width, code, dmem_words, mac)))
    }

    /// Build a simulator over a shared prepared image: the data memory
    /// is copied from the image's preloaded constants — no per-word
    /// bounds-checked stores, no `BTreeSet` rebuild (the static
    /// mnemonic set is `Arc`-shared).
    pub fn from_prepared(prepared: Arc<PreparedTpIsa>) -> Self {
        let mut dmem = WordMem::new(prepared.width, prepared.init_dmem.len());
        dmem.restore(&prepared.init_dmem);
        let mut profile = Profile::default();
        profile.static_mnemonics = Arc::clone(&prepared.static_mnemonics);
        TpIsa {
            width: prepared.width,
            regs: [0; 8],
            pc: 0,
            carry: false,
            zero: false,
            dmem,
            mac: prepared.mac.map(MacState::new),
            prepared,
            profile,
            exec_stats: ExecStats::default(),
            fault: None,
        }
    }

    /// Restore the initial machine state (zero registers and flags,
    /// data memory memcpy-restored from the prepared image, cleared
    /// MAC accumulators, pc = 0) so the simulator can run the next
    /// sample without being reconstructed.
    ///
    /// The profile is deliberately **not** cleared: it keeps
    /// accumulating across runs, exactly as if each run's fresh profile
    /// had been folded in with [`Profile::merge`].
    pub fn reset(&mut self) {
        self.regs = [0; 8];
        self.pc = 0;
        self.carry = false;
        self.zero = false;
        self.dmem.restore(&self.prepared.init_dmem);
        if let Some(m) = &mut self.mac {
            m.clear();
        }
        if let Some(f) = &mut self.fault {
            f.rearm();
        }
    }

    /// The shared prepared image this simulator executes.
    pub fn prepared(&self) -> &Arc<PreparedTpIsa> {
        &self.prepared
    }

    pub fn code_len(&self) -> usize {
        self.prepared.code.len()
    }

    /// Program ROM footprint in bytes (2 bytes per instruction).
    pub fn rom_code_bytes(&self) -> usize {
        self.prepared.code.len() * 2
    }

    pub(crate) fn mask(&self) -> u64 {
        if self.width == 64 {
            u64::MAX
        } else {
            (1u64 << self.width) - 1
        }
    }

    #[inline(always)]
    fn set<M: TraceMode>(&mut self, r: u8, v: u64) {
        self.regs[r as usize] = v & self.mask();
        if M::PROFILE {
            self.profile.record_reg(r);
        }
    }

    #[inline(always)]
    fn get<M: TraceMode>(&mut self, r: u8) -> u64 {
        if M::PROFILE {
            self.profile.record_reg(r);
        }
        self.regs[r as usize]
    }

    fn set_z(&mut self, v: u64) {
        self.zero = v & self.mask() == 0;
    }

    /// Run until halt or `fuel` instructions, with full profiling.
    pub fn run(&mut self, fuel: u64) -> Result<Halt> {
        self.run_traced::<FullProfile>(fuel)
    }

    /// [`TpIsa::run`] generic over the tracing mode: with
    /// [`CyclesOnly`](super::trace::CyclesOnly) the per-retire
    /// histogram, register-bitmask and max-PC updates compile away.
    ///
    /// This is the per-instruction *reference* loop; the production hot
    /// path is [`TpIsa::run_translated`], which is bit-identical.
    pub fn run_traced<M: TraceMode>(&mut self, fuel: u64) -> Result<Halt> {
        let prepared = Arc::clone(&self.prepared);
        let code: &[Instr] = &prepared.code;
        let mask = self.mask();
        let msb = 1u64 << (self.width - 1);
        let mut executed = 0u64;
        loop {
            if executed >= fuel {
                return Ok(Halt::Fuel);
            }
            executed += 1;
            if let Some(h) = self.step_traced::<M>(code, mask, msb)? {
                return Ok(h);
            }
        }
    }

    /// Fetch, profile, execute and retire exactly one instruction — the
    /// body of [`TpIsa::run_traced`], shared with the translated
    /// engine's fallback path and the batched engine's masked-lane
    /// drain (`sim::batch`).  Returns `Some` on halt.
    #[inline(always)]
    pub(crate) fn step_traced<M: TraceMode>(
        &mut self,
        code: &[Instr],
        mask: u64,
        msb: u64,
    ) -> Result<Option<Halt>> {
        {
            let instr = match usize::try_from(self.pc).ok().and_then(|i| code.get(i)) {
                Some(&i) => i,
                None => return Err(pc_fault(self.pc, code.len())),
            };
            if M::PROFILE {
                self.profile.record_instr(instr.mnemonic_id(), instr.mnemonic());
                self.profile.max_pc = self.profile.max_pc.max(self.pc as u32 * 2);
            } else {
                self.profile.instructions += 1;
            }
            let mut next = self.pc + 1;
            let mut cost = 2u64;

            match instr {
                Instr::Ldi { r1, imm } => {
                    let v = (imm as i64 as u64) & mask;
                    self.set::<M>(r1, v);
                    self.set_z(v);
                }
                Instr::Add { r1, r2 } => {
                    let (a, b) = (self.get::<M>(r1), self.get::<M>(r2));
                    let s = a + b;
                    self.carry = s > mask;
                    let v = s & mask;
                    self.set::<M>(r1, v);
                    self.set_z(v);
                }
                Instr::Adc { r1, r2 } => {
                    let (a, b) = (self.get::<M>(r1), self.get::<M>(r2));
                    let s = a + b + self.carry as u64;
                    self.carry = s > mask;
                    let v = s & mask;
                    self.set::<M>(r1, v);
                    self.set_z(v);
                }
                Instr::Sub { r1, r2 } => {
                    let (a, b) = (self.get::<M>(r1), self.get::<M>(r2));
                    let s = a.wrapping_sub(b);
                    self.carry = b > a; // borrow
                    let v = s & mask;
                    self.set::<M>(r1, v);
                    self.set_z(v);
                }
                Instr::Sbc { r1, r2 } => {
                    let (a, b) = (self.get::<M>(r1), self.get::<M>(r2));
                    let bb = b + self.carry as u64;
                    let s = a.wrapping_sub(bb);
                    self.carry = bb > a;
                    let v = s & mask;
                    self.set::<M>(r1, v);
                    self.set_z(v);
                }
                Instr::And { r1, r2 } => {
                    let v = self.get::<M>(r1) & self.get::<M>(r2);
                    self.set::<M>(r1, v);
                    self.set_z(v);
                }
                Instr::Or { r1, r2 } => {
                    let v = self.get::<M>(r1) | self.get::<M>(r2);
                    self.set::<M>(r1, v);
                    self.set_z(v);
                }
                Instr::Xor { r1, r2 } => {
                    let v = self.get::<M>(r1) ^ self.get::<M>(r2);
                    self.set::<M>(r1, v);
                    self.set_z(v);
                }
                Instr::Shl { r1 } => {
                    let a = self.get::<M>(r1);
                    self.carry = a & msb != 0;
                    let v = (a << 1) & mask;
                    self.set::<M>(r1, v);
                    self.set_z(v);
                }
                Instr::Shr { r1 } => {
                    let a = self.get::<M>(r1);
                    self.carry = a & 1 != 0;
                    let v = a >> 1;
                    self.set::<M>(r1, v);
                    self.set_z(v);
                }
                Instr::Sra { r1 } => {
                    let a = self.get::<M>(r1);
                    self.carry = a & 1 != 0;
                    let v = ((a >> 1) | (a & msb)) & mask;
                    self.set::<M>(r1, v);
                    self.set_z(v);
                }
                Instr::Slc { r1 } => {
                    let a = self.get::<M>(r1);
                    let cin = self.carry as u64;
                    self.carry = a & msb != 0;
                    let v = ((a << 1) | cin) & mask;
                    self.set::<M>(r1, v);
                    self.set_z(v);
                }
                Instr::Src { r1 } => {
                    let a = self.get::<M>(r1);
                    let cin = self.carry as u64;
                    self.carry = a & 1 != 0;
                    let v = (a >> 1) | (cin * msb);
                    self.set::<M>(r1, v);
                    self.set_z(v);
                }
                Instr::Ld { r1, r2, imm } => {
                    let addr = self.get::<M>(r2) as i64 + imm as i64;
                    let v = self.dmem.load(addr)?;
                    self.set::<M>(r1, v);
                    self.set_z(v);
                    self.profile.loads += 1;
                    self.profile.max_ram_offset =
                        self.profile.max_ram_offset.max(addr.max(0) as u32);
                    cost += 1;
                }
                Instr::St { r1, r2, imm } => {
                    let addr = self.get::<M>(r2) as i64 + imm as i64;
                    let v = self.get::<M>(r1);
                    self.dmem.store(addr, v)?;
                    self.profile.stores += 1;
                    self.profile.max_ram_offset =
                        self.profile.max_ram_offset.max(addr.max(0) as u32);
                    cost += 1;
                }
                Instr::Addi { r1, imm } => {
                    let v = (self.get::<M>(r1).wrapping_add(imm as i64 as u64)) & mask;
                    self.set::<M>(r1, v);
                    self.set_z(v);
                }
                Instr::Mov { r1, r2 } => {
                    let v = self.get::<M>(r2);
                    self.set::<M>(r1, v);
                }
                Instr::Sxt { r1, r2 } => {
                    let v = if self.get::<M>(r2) & msb != 0 { mask } else { 0 };
                    self.set::<M>(r1, v);
                }
                Instr::Clc => self.carry = false,
                Instr::Bz { off } => {
                    if self.zero {
                        next = self.pc + off as i64;
                        cost += 1;
                        self.profile.branches_taken += 1;
                    }
                }
                Instr::Bnz { off } => {
                    if !self.zero {
                        next = self.pc + off as i64;
                        cost += 1;
                        self.profile.branches_taken += 1;
                    }
                }
                Instr::Bc { off } => {
                    if self.carry {
                        next = self.pc + off as i64;
                        cost += 1;
                        self.profile.branches_taken += 1;
                    }
                }
                Instr::Bnc { off } => {
                    if !self.carry {
                        next = self.pc + off as i64;
                        cost += 1;
                        self.profile.branches_taken += 1;
                    }
                }
                Instr::Jmp { off } => {
                    next = self.pc + off as i64;
                    cost += 1;
                    self.profile.branches_taken += 1;
                }
                Instr::Mac { op, r1, r2 } => {
                    let width = self.width;
                    match op {
                        MacOp::Mac => {
                            let a = self.regs[r1 as usize];
                            let b = self.regs[r2 as usize];
                            if M::PROFILE {
                                self.profile.record_reg(r1);
                                self.profile.record_reg(r2);
                            }
                            let mac = match self.mac.as_mut() {
                                Some(m) => m,
                                None => return Err(mac_unavailable(op)),
                            };
                            mac.mac(a, b);
                            self.profile.mac_ops += 1;
                            self.fault_mac_tick();
                        }
                        MacOp::MacRd => {
                            // r2 *field* is an immediate chunk index
                            // into the adder-tree total `acc_total`
                            // (paper Fig. 2: the unit sums lanes in
                            // hardware; software reads d-bit pieces).
                            let mac = match self.mac.as_ref() {
                                Some(m) => m,
                                None => return Err(mac_unavailable(op)),
                            };
                            let v = mac.read_total_chunk(r2 as u32, width);
                            self.set::<M>(r1, v);
                        }
                        MacOp::MacClr => match self.mac.as_mut() {
                            Some(m) => m.clear(),
                            None => return Err(mac_unavailable(op)),
                        },
                    }
                }
                Instr::Halt => {
                    self.profile.cycles += 1;
                    return Ok(Some(Halt::Halted));
                }
            }
            self.profile.cycles += cost;
            self.pc = next;
            self.fault_tick(1);
        }
        Ok(None)
    }

    /// Run until halt or `fuel` instructions, dispatching per
    /// pre-translated basic block (`sim::translate`): one fuel check,
    /// one cycle/instruction add, one histogram delta and one
    /// register-mask OR per block, with the soft-multiply and
    /// `ld/ld/mac` idioms fused into superinstructions.  Falls back to
    /// the per-instruction interpreter step for untranslatable blocks
    /// (MAC on a MAC-less core), out-of-range PCs and fuel tails — so
    /// halts and `Halt::Fuel` states are bit-identical to the
    /// interpreter, and a fault returns the same `Err` with the same
    /// registers/dmem (the profile and `pc` are unspecified after an
    /// `Err`, which every consumer propagates — see `sim::translate`'s
    /// error contract).
    pub fn run_translated<M: TraceMode>(&mut self, fuel: u64) -> Result<Halt> {
        let prepared = Arc::clone(&self.prepared);
        let code: &[Instr] = &prepared.code;
        let trans = &prepared.translated;
        let blocks = trans.blocks.as_slice();
        let leaders: &[u32] = &trans.leaders;
        let mask = self.mask();
        let msb = 1u64 << (self.width - 1);
        let mut executed = 0u64;
        loop {
            let mut bid = NO_BLOCK;
            if let Ok(i) = usize::try_from(self.pc) {
                if let Some(&b) = leaders.get(i) {
                    bid = b;
                }
            }
            if bid != NO_BLOCK {
                let b = &blocks[bid as usize];
                if fuel - executed >= b.n_instrs as u64 {
                    executed += b.n_instrs as u64;
                    self.exec_stats.blocks += 1;
                    self.exec_stats.fused_uops += b.fused as u64;
                    for u in b.uops.iter() {
                        self.exec_uop(u, mask, msb)?;
                    }
                    // Block-granular fault clock — same boundary the
                    // batched engine ticks (see the RV32 twin).
                    self.fault_tick(b.n_instrs as u64);
                    self.apply_block::<M>(b);
                    if let Some(h) = self.apply_term(b) {
                        return Ok(h);
                    }
                    continue;
                }
            }
            // Fallback: one interpreted step (untranslatable block,
            // out-of-range PC, or fuel tail inside a block).
            if executed >= fuel {
                return Ok(Halt::Fuel);
            }
            executed += 1;
            self.exec_stats.fallback_instrs += 1;
            if let Some(h) = self.step_traced::<M>(code, mask, msb)? {
                return Ok(h);
            }
        }
    }

    /// Book a translated block's aggregate counters on this simulator's
    /// profile (see the RV32 twin `ZeroRiscy::apply_block`; the TP-ISA
    /// aggregates carry no `mul_ops`/`csr_used` — the ISA has neither).
    #[inline(always)]
    pub(crate) fn apply_block<M: TraceMode>(&mut self, b: &BlockTpIsa) {
        let p = &mut self.profile;
        p.cycles += b.base_cycles;
        p.instructions += b.n_instrs as u64;
        p.loads += b.loads;
        p.stores += b.stores;
        p.mac_ops += b.mac_ops;
        p.branches_taken += b.branches_taken;
        if M::PROFILE {
            p.regs_used |= b.reg_mask;
            p.max_pc = p.max_pc.max(b.last_pc as u32 * 2);
            p.record_block(&b.counts);
        }
    }

    /// Execute a translated block's terminator: resolve the next PC
    /// (taken-branch costs go to this simulator's own profile) and
    /// report a halt if the block ends the program.  Shared by
    /// [`TpIsa::run_translated`] and the batched lockstep engine.
    #[inline(always)]
    pub(crate) fn apply_term(&mut self, b: &BlockTpIsa) -> Option<Halt> {
        match b.term {
            TermTpIsa::FallThrough => self.pc = b.next_pc,
            TermTpIsa::Jmp { target } => self.pc = target,
            TermTpIsa::Branch { cond, target } => {
                let taken = match cond {
                    CondTp::Z => self.zero,
                    CondTp::Nz => !self.zero,
                    CondTp::C => self.carry,
                    CondTp::Nc => !self.carry,
                };
                if taken {
                    self.profile.cycles += 1;
                    self.profile.branches_taken += 1;
                    self.pc = target;
                } else {
                    self.pc = b.next_pc;
                }
            }
            TermTpIsa::Halt => {
                self.pc = b.last_pc;
                return Some(Halt::Halted);
            }
        }
        None
    }

    /// Execute one register-only data instruction (flag-exact, no
    /// profile bookkeeping — the block aggregates carry it).
    #[inline(always)]
    fn exec_data(&mut self, i: &Instr, mask: u64, msb: u64) {
        match *i {
            Instr::Ldi { r1, imm } => {
                let v = (imm as i64 as u64) & mask;
                self.regs[r1 as usize] = v;
                self.zero = v == 0;
            }
            Instr::Add { r1, r2 } => {
                let (a, b) = (self.regs[r1 as usize], self.regs[r2 as usize]);
                let s = a + b;
                self.carry = s > mask;
                let v = s & mask;
                self.regs[r1 as usize] = v;
                self.zero = v == 0;
            }
            Instr::Adc { r1, r2 } => {
                let (a, b) = (self.regs[r1 as usize], self.regs[r2 as usize]);
                let s = a + b + self.carry as u64;
                self.carry = s > mask;
                let v = s & mask;
                self.regs[r1 as usize] = v;
                self.zero = v == 0;
            }
            Instr::Sub { r1, r2 } => {
                let (a, b) = (self.regs[r1 as usize], self.regs[r2 as usize]);
                let s = a.wrapping_sub(b);
                self.carry = b > a; // borrow
                let v = s & mask;
                self.regs[r1 as usize] = v;
                self.zero = v == 0;
            }
            Instr::Sbc { r1, r2 } => {
                let (a, b) = (self.regs[r1 as usize], self.regs[r2 as usize]);
                let bb = b + self.carry as u64;
                let s = a.wrapping_sub(bb);
                self.carry = bb > a;
                let v = s & mask;
                self.regs[r1 as usize] = v;
                self.zero = v == 0;
            }
            Instr::And { r1, r2 } => {
                let v = self.regs[r1 as usize] & self.regs[r2 as usize];
                self.regs[r1 as usize] = v;
                self.zero = v == 0;
            }
            Instr::Or { r1, r2 } => {
                let v = self.regs[r1 as usize] | self.regs[r2 as usize];
                self.regs[r1 as usize] = v;
                self.zero = v == 0;
            }
            Instr::Xor { r1, r2 } => {
                let v = self.regs[r1 as usize] ^ self.regs[r2 as usize];
                self.regs[r1 as usize] = v;
                self.zero = v == 0;
            }
            Instr::Shl { r1 } => {
                let a = self.regs[r1 as usize];
                self.carry = a & msb != 0;
                let v = (a << 1) & mask;
                self.regs[r1 as usize] = v;
                self.zero = v == 0;
            }
            Instr::Shr { r1 } => {
                let a = self.regs[r1 as usize];
                self.carry = a & 1 != 0;
                let v = a >> 1;
                self.regs[r1 as usize] = v;
                self.zero = v == 0;
            }
            Instr::Sra { r1 } => {
                let a = self.regs[r1 as usize];
                self.carry = a & 1 != 0;
                let v = ((a >> 1) | (a & msb)) & mask;
                self.regs[r1 as usize] = v;
                self.zero = v == 0;
            }
            Instr::Slc { r1 } => {
                let a = self.regs[r1 as usize];
                let cin = self.carry as u64;
                self.carry = a & msb != 0;
                let v = ((a << 1) | cin) & mask;
                self.regs[r1 as usize] = v;
                self.zero = v == 0;
            }
            Instr::Src { r1 } => {
                let a = self.regs[r1 as usize];
                let cin = self.carry as u64;
                self.carry = a & 1 != 0;
                let v = (a >> 1) | (cin * msb);
                self.regs[r1 as usize] = v;
                self.zero = v == 0;
            }
            Instr::Addi { r1, imm } => {
                let v = (self.regs[r1 as usize].wrapping_add(imm as i64 as u64)) & mask;
                self.regs[r1 as usize] = v;
                self.zero = v == 0;
            }
            Instr::Mov { r1, r2 } => {
                self.regs[r1 as usize] = self.regs[r2 as usize];
            }
            Instr::Sxt { r1, r2 } => {
                let v = if self.regs[r2 as usize] & msb != 0 { mask } else { 0 };
                self.regs[r1 as usize] = v;
            }
            Instr::Clc => self.carry = false,
            _ => unreachable!("non-data instruction in Data micro-op"),
        }
    }

    /// Execute one load (data effects + BAR reach; the block aggregates
    /// carry the `loads` counter and cycle cost).
    #[inline(always)]
    fn exec_ld(&mut self, r1: u8, r2: u8, imm: i8) -> Result<()> {
        let addr = self.regs[r2 as usize] as i64 + imm as i64;
        let v = self.dmem.load(addr)?;
        self.regs[r1 as usize] = v;
        self.zero = v == 0;
        self.profile.max_ram_offset = self.profile.max_ram_offset.max(addr.max(0) as u32);
        Ok(())
    }

    /// Execute one store.
    #[inline(always)]
    fn exec_st(&mut self, r1: u8, r2: u8, imm: i8) -> Result<()> {
        let addr = self.regs[r2 as usize] as i64 + imm as i64;
        let v = self.regs[r1 as usize];
        self.dmem.store(addr, v)?;
        self.profile.max_ram_offset = self.profile.max_ram_offset.max(addr.max(0) as u32);
        Ok(())
    }

    /// Execute one MAC-extension op (data effects only).
    #[inline(always)]
    fn exec_mac_op(&mut self, op: MacOp, r1: u8, r2: u8, mask: u64) -> Result<()> {
        let width = self.width;
        match op {
            MacOp::Mac => {
                let a = self.regs[r1 as usize];
                let b = self.regs[r2 as usize];
                let mac = match self.mac.as_mut() {
                    Some(m) => m,
                    None => return Err(mac_unavailable(op)),
                };
                mac.mac(a, b);
                self.fault_mac_tick();
            }
            MacOp::MacRd => {
                let mac = match self.mac.as_ref() {
                    Some(m) => m,
                    None => return Err(mac_unavailable(op)),
                };
                let v = mac.read_total_chunk(r2 as u32, width);
                self.regs[r1 as usize] = v & mask;
            }
            MacOp::MacClr => match self.mac.as_mut() {
                Some(m) => m.clear(),
                None => return Err(mac_unavailable(op)),
            },
        }
        Ok(())
    }

    /// Execute one translated micro-op.  Performs the same
    /// architectural steps in the same order as the interpreter, so
    /// flags, aliasing and fault ordering are preserved.  `pub(crate)`
    /// so the batched lockstep engine can retire one micro-op across
    /// many lanes.
    #[inline(always)]
    pub(crate) fn exec_uop(&mut self, u: &UopTpIsa, mask: u64, msb: u64) -> Result<()> {
        match u {
            UopTpIsa::Data(i) => self.exec_data(i, mask, msb),
            UopTpIsa::Data2(a, b) => {
                self.exec_data(a, mask, msb);
                self.exec_data(b, mask, msb);
            }
            UopTpIsa::Data3(a, b, c) => {
                self.exec_data(a, mask, msb);
                self.exec_data(b, mask, msb);
                self.exec_data(c, mask, msb);
            }
            UopTpIsa::Ld { r1, r2, imm } => self.exec_ld(*r1, *r2, *imm)?,
            UopTpIsa::St { r1, r2, imm } => self.exec_st(*r1, *r2, *imm)?,
            UopTpIsa::Mac { op, r1, r2 } => self.exec_mac_op(*op, *r1, *r2, mask)?,
            UopTpIsa::Ld2Mac { a, b, r1, r2 } => {
                self.exec_ld(a.0, a.1, a.2)?;
                self.exec_ld(b.0, b.1, b.2)?;
                self.exec_mac_op(MacOp::Mac, *r1, *r2, mask)?;
            }
            UopTpIsa::LdOpSt { r1, r2, imm, op } => {
                self.exec_ld(*r1, *r2, *imm)?;
                self.exec_data(op, mask, msb);
                self.exec_st(*r1, *r2, *imm)?;
            }
        }
        Ok(())
    }

    /// Advance the soft-error instruction clock and apply newly due
    /// register/dmem flips (see the RV32 twin `ZeroRiscy::fault_tick`
    /// for the per-engine clock granularity contract).
    #[inline(always)]
    pub(crate) fn fault_tick(&mut self, retired: u64) {
        if self.fault.is_some() {
            self.fault_tick_slow(retired);
        }
    }

    #[cold]
    fn fault_tick_slow(&mut self, retired: u64) {
        let mut f = self.fault.take().unwrap();
        for flip in f.advance(retired) {
            self.apply_flip(flip);
        }
        self.fault = Some(f);
    }

    fn apply_flip(&mut self, flip: &BitFlip) {
        let mask = self.mask();
        match flip.target {
            FlipTarget::Reg(r) => {
                let r = (r as usize) % 8;
                self.regs[r] = (self.regs[r] ^ (1u64 << (flip.bit as u32 % self.width))) & mask;
            }
            FlipTarget::Ram(word) => {
                let n = self.dmem.len();
                if n > 0 {
                    let addr = (word as usize % n) as i64;
                    // In range by construction; `store` re-masks to the
                    // datapath width.
                    let v = self.dmem.load(addr).unwrap_or(0);
                    let _ = self.dmem.store(addr, v ^ (1u64 << (flip.bit as u32 % self.width)));
                }
            }
        }
    }

    /// Advance the MAC-op clock by one accumulate and apply any due
    /// accumulator flips.
    #[inline(always)]
    fn fault_mac_tick(&mut self) {
        if self.fault.is_some() {
            self.fault_mac_slow();
        }
    }

    #[cold]
    fn fault_mac_slow(&mut self) {
        let mut f = self.fault.take().unwrap();
        if let Some(mac) = &mut self.mac {
            for mf in f.advance_mac(1) {
                mac.flip_acc(mf.lane as usize, mf.bit as u32);
            }
        }
        self.fault = Some(f);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::tpisa::Asm;

    fn run(width: u32, build: impl FnOnce(&mut Asm), dmem: usize) -> TpIsa {
        let mut a = Asm::new();
        build(&mut a);
        a.push(Instr::Halt);
        let prog = a.finish().unwrap();
        let mut sim = TpIsa::new(width, &prog, dmem, None);
        assert_eq!(sim.run(1_000_000).unwrap(), Halt::Halted);
        sim
    }

    #[test]
    fn countdown_loop() {
        let sim = run(
            8,
            |a| {
                a.ldi(0, 10);
                a.ldi(1, 0);
                a.label("loop");
                a.push(Instr::Add { r1: 1, r2: 0 });
                a.push(Instr::Addi { r1: 0, imm: -1 });
                a.bnz("loop");
            },
            4,
        );
        assert_eq!(sim.regs[1], 55);
    }

    #[test]
    fn width_masking() {
        let sim = run(
            4,
            |a| {
                a.ldi(0, 15);
                a.push(Instr::Addi { r1: 0, imm: 1 }); // wraps to 0 in 4 bits
            },
            4,
        );
        assert_eq!(sim.regs[0], 0);
        assert!(sim.zero);
    }

    #[test]
    fn carry_chain_multiword_add() {
        // 16-bit addition on an 8-bit datapath: 0x00ff + 0x0001 = 0x0100.
        let sim = run(
            8,
            |a| {
                a.ldc(0, 0xff, 8); // lo a
                a.ldi(1, 0); // hi a
                a.ldi(2, 1); // lo b
                a.ldi(3, 0); // hi b
                a.push(Instr::Add { r1: 0, r2: 2 }); // lo sum, sets carry
                a.push(Instr::Adc { r1: 1, r2: 3 }); // hi sum + carry
            },
            4,
        );
        assert_eq!(sim.regs[0], 0x00);
        assert_eq!(sim.regs[1], 0x01);
    }

    #[test]
    fn borrow_chain_multiword_sub() {
        // 0x0100 - 0x0001 = 0x00ff on 8-bit datapath.
        let sim = run(
            8,
            |a| {
                a.ldi(0, 0); // lo a
                a.ldi(1, 1); // hi a
                a.ldi(2, 1); // lo b
                a.ldi(3, 0); // hi b
                a.push(Instr::Sub { r1: 0, r2: 2 });
                a.push(Instr::Sbc { r1: 1, r2: 3 });
            },
            4,
        );
        assert_eq!(sim.regs[0], 0xff);
        assert_eq!(sim.regs[1], 0x00);
    }

    #[test]
    fn shift_through_carry() {
        // 16-bit left shift on 8-bit datapath: 0x80ff << 1 = 0x01fe
        // (dropping the out-shifted top bit).
        let sim = run(
            8,
            |a| {
                a.ldc(0, 0xff, 8); // lo
                a.ldc(1, 0x80, 8); // hi
                a.push(Instr::Shl { r1: 0 }); // lo <<= 1, C = 1
                a.push(Instr::Slc { r1: 1 }); // hi = (hi<<1)|C
            },
            4,
        );
        assert_eq!(sim.regs[0], 0xfe);
        assert_eq!(sim.regs[1], 0x01);
    }

    #[test]
    fn sxt_fills_sign() {
        let sim = run(
            8,
            |a| {
                a.ldi(0, -5);
                a.push(Instr::Sxt { r1: 1, r2: 0 });
                a.ldi(2, 5);
                a.push(Instr::Sxt { r1: 3, r2: 2 });
            },
            4,
        );
        assert_eq!(sim.regs[1], 0xff);
        assert_eq!(sim.regs[3], 0x00);
    }

    #[test]
    fn load_store() {
        let sim = run(
            16,
            |a| {
                a.ldi(0, 3); // addr base
                a.ldc(1, 1234, 16);
                a.push(Instr::St { r1: 1, r2: 0, imm: 2 }); // mem[5] = 1234
                a.push(Instr::Ld { r1: 2, r2: 0, imm: 2 });
            },
            8,
        );
        assert_eq!(sim.regs[2], 1234);
        assert_eq!(sim.dmem.load(5).unwrap(), 1234);
    }

    #[test]
    fn carry_branches() {
        let sim = run(
            8,
            |a| {
                a.ldc(0, 0xff, 8);
                a.ldi(1, 1);
                a.push(Instr::Add { r1: 0, r2: 1 }); // sets carry
                a.ldi(2, 0);
                a.bnc("skip");
                a.ldi(2, 1); // carry taken path
                a.label("skip");
            },
            4,
        );
        assert_eq!(sim.regs[2], 1);
    }

    #[test]
    fn mac_narrow_readback() {
        // d=8, p=8: 100*100 = 10000 = 0x2710 read back in two chunks.
        let mut a = Asm::new();
        a.push(Instr::Mac { op: MacOp::MacClr, r1: 0, r2: 0 });
        a.ldc(0, 100, 8);
        a.push(Instr::Mac { op: MacOp::Mac, r1: 0, r2: 0 });
        a.push(Instr::Mac { op: MacOp::MacRd, r1: 1, r2: 0 }); // chunk 0
        a.push(Instr::Mac { op: MacOp::MacRd, r1: 2, r2: 1 }); // chunk 1
        a.push(Instr::Halt);
        let prog = a.finish().unwrap();
        let mut sim = TpIsa::new(8, &prog, 4, Some(MacConfig::new(8, 8)));
        sim.run(1000).unwrap();
        assert_eq!(sim.regs[1], 0x10);
        assert_eq!(sim.regs[2], 0x27);
    }

    #[test]
    fn mac_simd_total_wide() {
        // d=32, p=8: 4 lanes in parallel; MACRD reads the adder-tree
        // total (1*1 + 2*1 + 3*1 + 4*1 = 10).
        let mut a = Asm::new();
        a.push(Instr::Mac { op: MacOp::MacClr, r1: 0, r2: 0 });
        a.ldc(0, 0x0403_0201, 32); // lanes [1,2,3,4]
        a.ldc(1, 0x0101_0101, 32); // lanes [1,1,1,1]
        a.push(Instr::Mac { op: MacOp::Mac, r1: 0, r2: 1 });
        a.push(Instr::Mac { op: MacOp::MacRd, r1: 2, r2: 0 });
        a.push(Instr::Halt);
        let prog = a.finish().unwrap();
        let mut sim = TpIsa::new(32, &prog, 4, Some(MacConfig::new(32, 8)));
        sim.run(1000).unwrap();
        assert_eq!(sim.regs[2], 10);
    }

    #[test]
    fn mac_total_chunks_narrow() {
        // d=8, p=4, two lanes: total = 5*3 + 2*4 = 23, read in 4 chunks.
        let mut a = Asm::new();
        a.push(Instr::Mac { op: MacOp::MacClr, r1: 0, r2: 0 });
        a.ldc(0, 0x25, 8); // lanes [5, 2]
        a.ldc(1, 0x43, 8); // lanes [3, 4]
        a.push(Instr::Mac { op: MacOp::Mac, r1: 0, r2: 1 });
        for part in 0..4u8 {
            a.push(Instr::Mac { op: MacOp::MacRd, r1: 2 + part, r2: part });
        }
        a.push(Instr::Halt);
        let prog = a.finish().unwrap();
        let mut sim = TpIsa::new(8, &prog, 4, Some(MacConfig::new(8, 4)));
        sim.run(1000).unwrap();
        assert_eq!(sim.regs[2], 23);
        assert_eq!(sim.regs[3], 0);
        assert_eq!(sim.regs[4], 0);
        assert_eq!(sim.regs[5], 0);
    }

    #[test]
    fn reset_restores_prepared_dmem() {
        // Program reads a constant from dmem, doubles it in place.
        let mut a = Asm::new();
        a.ldi(0, 2); // addr of the constant
        a.push(Instr::Ld { r1: 1, r2: 0, imm: 0 });
        a.push(Instr::Add { r1: 1, r2: 1 });
        a.push(Instr::St { r1: 1, r2: 0, imm: 0 });
        a.push(Instr::Halt);
        let prog = a.finish().unwrap();
        let prepared = Arc::new(PreparedTpIsa::new(8, &prog, vec![0, 0, 21, 0], None));
        let mut sim = TpIsa::from_prepared(Arc::clone(&prepared));
        sim.run(100).unwrap();
        assert_eq!(sim.dmem.load(2).unwrap(), 42);
        let cycles_once = sim.profile.cycles;
        sim.reset();
        // The mutated constant is back, registers and flags cleared.
        assert_eq!(sim.dmem.load(2).unwrap(), 21);
        assert_eq!(sim.regs, [0; 8]);
        assert!(!sim.carry && !sim.zero);
        sim.run(100).unwrap();
        assert_eq!(sim.dmem.load(2).unwrap(), 42);
        assert_eq!(sim.profile.cycles, 2 * cycles_once);
    }

    #[test]
    fn cycles_only_matches_full_profile() {
        let mut a = Asm::new();
        a.ldi(0, 10);
        a.ldi(1, 0);
        a.label("loop");
        a.push(Instr::Add { r1: 1, r2: 0 });
        a.push(Instr::Addi { r1: 0, imm: -1 });
        a.bnz("loop");
        a.push(Instr::St { r1: 1, r2: 0, imm: 1 });
        a.push(Instr::Halt);
        let prog = a.finish().unwrap();
        let prepared = Arc::new(PreparedTpIsa::with_zero_dmem(8, &prog, 4, None));
        let mut full = TpIsa::from_prepared(Arc::clone(&prepared));
        assert_eq!(full.run_traced::<FullProfile>(1000).unwrap(), Halt::Halted);
        let mut cyc = TpIsa::from_prepared(prepared);
        assert_eq!(cyc.run_traced::<crate::sim::trace::CyclesOnly>(1000).unwrap(), Halt::Halted);
        assert_eq!(cyc.regs, full.regs);
        assert_eq!(cyc.profile.cycles, full.profile.cycles);
        assert_eq!(cyc.profile.instructions, full.profile.instructions);
        assert_eq!(cyc.profile.stores, full.profile.stores);
        assert_eq!(cyc.profile.branches_taken, full.profile.branches_taken);
        assert!(cyc.profile.instr_counts().is_empty());
        assert_eq!(cyc.profile.regs_used, 0);
        assert_eq!(cyc.profile.max_pc, 0);
        assert!(full.profile.count("add") > 0);
    }

    /// Interpreted and translated runs of the same prepared image must
    /// agree on every observable, including mid-run `Halt::Fuel` states.
    fn assert_translated_matches(prepared: &Arc<PreparedTpIsa>, fuel: u64) {
        let mut interp = TpIsa::from_prepared(Arc::clone(prepared));
        let hi = interp.run_traced::<FullProfile>(fuel).unwrap();
        let mut trans = TpIsa::from_prepared(Arc::clone(prepared));
        let ht = trans.run_translated::<FullProfile>(fuel).unwrap();
        assert_eq!(hi, ht);
        assert_eq!(interp.regs, trans.regs);
        assert_eq!(interp.pc, trans.pc);
        assert_eq!(interp.carry, trans.carry);
        assert_eq!(interp.zero, trans.zero);
        let n = interp.dmem.len();
        assert_eq!(interp.dmem.read_words(0, n).unwrap(), trans.dmem.read_words(0, n).unwrap());
        assert_eq!(interp.profile.cycles, trans.profile.cycles);
        assert_eq!(interp.profile.instructions, trans.profile.instructions);
        assert_eq!(interp.profile.instr_counts(), trans.profile.instr_counts());
        assert_eq!(interp.profile.regs_used, trans.profile.regs_used);
        assert_eq!(interp.profile.max_pc, trans.profile.max_pc);
        assert_eq!(interp.profile.branches_taken, trans.profile.branches_taken);
        assert_eq!(interp.profile.loads, trans.profile.loads);
        assert_eq!(interp.profile.stores, trans.profile.stores);
        assert_eq!(interp.profile.max_ram_offset, trans.profile.max_ram_offset);
    }

    #[test]
    fn translated_matches_interpreted_softmul_loop() {
        // A countdown loop exercising the shift-add kernel shapes:
        // shl/slc pairs, add/adc accumulate, carry branches.
        let mut a = Asm::new();
        a.ldi(0, 21);
        a.ldi(1, 0);
        a.ldi(5, 6);
        a.label("loop");
        a.push(Instr::Shl { r1: 0 });
        a.push(Instr::Slc { r1: 1 });
        a.bnc("noadd");
        a.push(Instr::Add { r1: 1, r2: 0 });
        a.label("noadd");
        a.push(Instr::St { r1: 1, r2: 2, imm: 3 });
        a.push(Instr::Ld { r1: 3, r2: 2, imm: 3 });
        a.push(Instr::Addi { r1: 5, imm: -1 });
        a.bnz("loop");
        a.push(Instr::Halt);
        let prog = a.finish().unwrap();
        let prepared = Arc::new(PreparedTpIsa::with_zero_dmem(8, &prog, 8, None));
        assert_translated_matches(&prepared, 1_000_000);
        for fuel in [1, 2, 5, 9, 17, 30] {
            assert_translated_matches(&prepared, fuel);
        }
    }

    #[test]
    fn translated_mac_program_runs_on_blocks() {
        let mut a = Asm::new();
        a.push(Instr::Mac { op: MacOp::MacClr, r1: 0, r2: 0 });
        a.ldi(0, 3);
        a.ldi(1, 4);
        a.push(Instr::St { r1: 0, r2: 2, imm: 4 });
        a.push(Instr::St { r1: 1, r2: 2, imm: 5 });
        a.push(Instr::Ld { r1: 0, r2: 2, imm: 4 });
        a.push(Instr::Ld { r1: 1, r2: 2, imm: 5 });
        a.push(Instr::Mac { op: MacOp::Mac, r1: 0, r2: 1 });
        a.push(Instr::Mac { op: MacOp::MacRd, r1: 3, r2: 0 });
        a.push(Instr::Halt);
        let prog = a.finish().unwrap();
        let prepared =
            Arc::new(PreparedTpIsa::with_zero_dmem(8, &prog, 8, Some(MacConfig::new(8, 8))));
        assert!(prepared.translated.stats.fused > 0);
        let mut sim = TpIsa::from_prepared(Arc::clone(&prepared));
        assert_eq!(sim.run_translated::<FullProfile>(100).unwrap(), Halt::Halted);
        assert_eq!(sim.regs[3], 12);
        assert_eq!(sim.profile.mac_ops, 1);
        assert!(sim.exec_stats.blocks > 0);
        assert_eq!(sim.exec_stats.fallback_instrs, 0);
        assert_translated_matches(&prepared, 1000);
    }

    #[test]
    fn timing_model() {
        let mut a = Asm::new();
        a.ldi(0, 1); // 2 cycles
        a.push(Instr::St { r1: 0, r2: 0, imm: 0 }); // 3 cycles
        a.push(Instr::Halt); // 1 cycle
        let prog = a.finish().unwrap();
        let mut sim = TpIsa::new(8, &prog, 4, None);
        sim.run(100).unwrap();
        assert_eq!(sim.profile.cycles, 6);
    }

    #[test]
    fn fuel() {
        let mut a = Asm::new();
        a.label("x");
        a.jmp("x");
        let prog = a.finish().unwrap();
        let mut sim = TpIsa::new(8, &prog, 4, None);
        assert_eq!(sim.run(50).unwrap(), Halt::Fuel);
    }
}
