//! TP-ISA ISS: the minimal width-configurable printed core.
//!
//! Timing model (multi-cycle minimal core, no pipeline):
//!
//! * 2 cycles per instruction (fetch, execute);
//! * loads/stores: 3 cycles;
//! * taken branches/jumps: 3 cycles;
//! * MAC extension: 2 cycles (single-cycle unit + fetch);
//! * HALT: 1 cycle.
//!
//! Registers are `d`-bit (the datapath width); C and Z flags support
//! multi-word arithmetic (ADC/SBC/SLC/SRC), which is how the baseline
//! core multiplies — "the whole operation is scheduled to the ALU"
//! (paper §III-B).

use anyhow::{bail, Context, Result};

use super::mac_model::MacState;
use super::mem::WordMem;
use super::trace::Profile;
use crate::hw::mac_unit::MacConfig;
use crate::isa::tpisa::Instr;
use crate::isa::MacOp;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Halt {
    Halted,
    Fuel,
}

pub const ALL_MNEMONICS: &[&str] = &[
    "ldi", "add", "adc", "sub", "sbc", "and", "or", "xor", "shl", "shr", "sra", "slc", "src",
    "ld", "st", "addi", "mov", "sxt", "clc", "bz", "bnz", "bc", "bnc", "jmp", "mac", "macrd",
    "maccl", "halt",
];

/// The TP-ISA simulator.
pub struct TpIsa {
    pub width: u32,
    pub regs: [u64; 8],
    pub pc: i64,
    pub carry: bool,
    pub zero: bool,
    pub dmem: WordMem,
    pub mac: Option<MacState>,
    program: Vec<Instr>,
    pub profile: Profile,
}

impl TpIsa {
    pub fn new(width: u32, code: &[Instr], dmem_words: usize, mac: Option<MacConfig>) -> Self {
        if let Some(cfg) = &mac {
            assert_eq!(cfg.datapath, width, "MAC datapath must match the core");
        }
        let mut profile = Profile::default();
        for i in code {
            profile.static_mnemonics.insert(i.mnemonic());
        }
        TpIsa {
            width,
            regs: [0; 8],
            pc: 0,
            carry: false,
            zero: false,
            dmem: WordMem::new(width, dmem_words),
            mac: mac.map(MacState::new),
            program: code.to_vec(),
            profile,
        }
    }

    pub fn code_len(&self) -> usize {
        self.program.len()
    }

    /// Program ROM footprint in bytes (2 bytes per instruction).
    pub fn rom_code_bytes(&self) -> usize {
        self.program.len() * 2
    }

    fn mask(&self) -> u64 {
        if self.width == 64 {
            u64::MAX
        } else {
            (1u64 << self.width) - 1
        }
    }

    fn set(&mut self, r: u8, v: u64) {
        self.regs[r as usize] = v & self.mask();
        self.profile.record_reg(r);
    }

    fn get(&mut self, r: u8) -> u64 {
        self.profile.record_reg(r);
        self.regs[r as usize]
    }

    fn set_z(&mut self, v: u64) {
        self.zero = v & self.mask() == 0;
    }

    pub fn run(&mut self, fuel: u64) -> Result<Halt> {
        let mask = self.mask();
        let msb = 1u64 << (self.width - 1);
        let mut executed = 0u64;
        loop {
            if executed >= fuel {
                return Ok(Halt::Fuel);
            }
            executed += 1;
            if self.pc < 0 || self.pc as usize >= self.program.len() {
                bail!("PC {} outside program ({} instrs)", self.pc, self.program.len());
            }
            let instr = self.program[self.pc as usize];
            self.profile.record_instr(instr.mnemonic_id(), instr.mnemonic());
            self.profile.max_pc = self.profile.max_pc.max(self.pc as u32 * 2);
            let mut next = self.pc + 1;
            let mut cost = 2u64;

            match instr {
                Instr::Ldi { r1, imm } => {
                    let v = (imm as i64 as u64) & mask;
                    self.set(r1, v);
                    self.set_z(v);
                }
                Instr::Add { r1, r2 } => {
                    let (a, b) = (self.get(r1), self.get(r2));
                    let s = a + b;
                    self.carry = s > mask;
                    let v = s & mask;
                    self.set(r1, v);
                    self.set_z(v);
                }
                Instr::Adc { r1, r2 } => {
                    let (a, b) = (self.get(r1), self.get(r2));
                    let s = a + b + self.carry as u64;
                    self.carry = s > mask;
                    let v = s & mask;
                    self.set(r1, v);
                    self.set_z(v);
                }
                Instr::Sub { r1, r2 } => {
                    let (a, b) = (self.get(r1), self.get(r2));
                    let s = a.wrapping_sub(b);
                    self.carry = b > a; // borrow
                    let v = s & mask;
                    self.set(r1, v);
                    self.set_z(v);
                }
                Instr::Sbc { r1, r2 } => {
                    let (a, b) = (self.get(r1), self.get(r2));
                    let bb = b + self.carry as u64;
                    let s = a.wrapping_sub(bb);
                    self.carry = bb > a;
                    let v = s & mask;
                    self.set(r1, v);
                    self.set_z(v);
                }
                Instr::And { r1, r2 } => {
                    let v = self.get(r1) & self.get(r2);
                    self.set(r1, v);
                    self.set_z(v);
                }
                Instr::Or { r1, r2 } => {
                    let v = self.get(r1) | self.get(r2);
                    self.set(r1, v);
                    self.set_z(v);
                }
                Instr::Xor { r1, r2 } => {
                    let v = self.get(r1) ^ self.get(r2);
                    self.set(r1, v);
                    self.set_z(v);
                }
                Instr::Shl { r1 } => {
                    let a = self.get(r1);
                    self.carry = a & msb != 0;
                    let v = (a << 1) & mask;
                    self.set(r1, v);
                    self.set_z(v);
                }
                Instr::Shr { r1 } => {
                    let a = self.get(r1);
                    self.carry = a & 1 != 0;
                    let v = a >> 1;
                    self.set(r1, v);
                    self.set_z(v);
                }
                Instr::Sra { r1 } => {
                    let a = self.get(r1);
                    self.carry = a & 1 != 0;
                    let v = ((a >> 1) | (a & msb)) & mask;
                    self.set(r1, v);
                    self.set_z(v);
                }
                Instr::Slc { r1 } => {
                    let a = self.get(r1);
                    let cin = self.carry as u64;
                    self.carry = a & msb != 0;
                    let v = ((a << 1) | cin) & mask;
                    self.set(r1, v);
                    self.set_z(v);
                }
                Instr::Src { r1 } => {
                    let a = self.get(r1);
                    let cin = self.carry as u64;
                    self.carry = a & 1 != 0;
                    let v = (a >> 1) | (cin * msb);
                    self.set(r1, v);
                    self.set_z(v);
                }
                Instr::Ld { r1, r2, imm } => {
                    let addr = self.get(r2) as i64 + imm as i64;
                    let v = self.dmem.load(addr)?;
                    self.set(r1, v);
                    self.set_z(v);
                    self.profile.loads += 1;
                    self.profile.max_ram_offset =
                        self.profile.max_ram_offset.max(addr.max(0) as u32);
                    cost += 1;
                }
                Instr::St { r1, r2, imm } => {
                    let addr = self.get(r2) as i64 + imm as i64;
                    let v = self.get(r1);
                    self.dmem.store(addr, v)?;
                    self.profile.stores += 1;
                    self.profile.max_ram_offset =
                        self.profile.max_ram_offset.max(addr.max(0) as u32);
                    cost += 1;
                }
                Instr::Addi { r1, imm } => {
                    let v = (self.get(r1).wrapping_add(imm as i64 as u64)) & mask;
                    self.set(r1, v);
                    self.set_z(v);
                }
                Instr::Mov { r1, r2 } => {
                    let v = self.get(r2);
                    self.set(r1, v);
                }
                Instr::Sxt { r1, r2 } => {
                    let v = if self.get(r2) & msb != 0 { mask } else { 0 };
                    self.set(r1, v);
                }
                Instr::Clc => self.carry = false,
                Instr::Bz { off } => {
                    if self.zero {
                        next = self.pc + off as i64;
                        cost += 1;
                        self.profile.branches_taken += 1;
                    }
                }
                Instr::Bnz { off } => {
                    if !self.zero {
                        next = self.pc + off as i64;
                        cost += 1;
                        self.profile.branches_taken += 1;
                    }
                }
                Instr::Bc { off } => {
                    if self.carry {
                        next = self.pc + off as i64;
                        cost += 1;
                        self.profile.branches_taken += 1;
                    }
                }
                Instr::Bnc { off } => {
                    if !self.carry {
                        next = self.pc + off as i64;
                        cost += 1;
                        self.profile.branches_taken += 1;
                    }
                }
                Instr::Jmp { off } => {
                    next = self.pc + off as i64;
                    cost += 1;
                    self.profile.branches_taken += 1;
                }
                Instr::Mac { op, r1, r2 } => {
                    let width = self.width;
                    match op {
                        MacOp::Mac => {
                            let a = self.regs[r1 as usize];
                            let b = self.regs[r2 as usize];
                            self.profile.record_reg(r1);
                            self.profile.record_reg(r2);
                            let mac = self
                                .mac
                                .as_mut()
                                .context("MAC instruction on a core without a MAC unit")?;
                            mac.mac(a, b);
                            self.profile.mac_ops += 1;
                        }
                        MacOp::MacRd => {
                            // r2 *field* is an immediate chunk index
                            // into the adder-tree total `acc_total`
                            // (paper Fig. 2: the unit sums lanes in
                            // hardware; software reads d-bit pieces).
                            let mac = self
                                .mac
                                .as_ref()
                                .context("MACRD on a core without a MAC unit")?;
                            let v = mac.read_total_chunk(r2 as u32, width);
                            self.set(r1, v);
                        }
                        MacOp::MacClr => {
                            self.mac
                                .as_mut()
                                .context("MACCL on a core without a MAC unit")?
                                .clear();
                        }
                    }
                }
                Instr::Halt => {
                    self.profile.cycles += 1;
                    return Ok(Halt::Halted);
                }
            }
            self.profile.cycles += cost;
            self.pc = next;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::tpisa::Asm;

    fn run(width: u32, build: impl FnOnce(&mut Asm), dmem: usize) -> TpIsa {
        let mut a = Asm::new();
        build(&mut a);
        a.push(Instr::Halt);
        let prog = a.finish().unwrap();
        let mut sim = TpIsa::new(width, &prog, dmem, None);
        assert_eq!(sim.run(1_000_000).unwrap(), Halt::Halted);
        sim
    }

    #[test]
    fn countdown_loop() {
        let sim = run(
            8,
            |a| {
                a.ldi(0, 10);
                a.ldi(1, 0);
                a.label("loop");
                a.push(Instr::Add { r1: 1, r2: 0 });
                a.push(Instr::Addi { r1: 0, imm: -1 });
                a.bnz("loop");
            },
            4,
        );
        assert_eq!(sim.regs[1], 55);
    }

    #[test]
    fn width_masking() {
        let sim = run(
            4,
            |a| {
                a.ldi(0, 15);
                a.push(Instr::Addi { r1: 0, imm: 1 }); // wraps to 0 in 4 bits
            },
            4,
        );
        assert_eq!(sim.regs[0], 0);
        assert!(sim.zero);
    }

    #[test]
    fn carry_chain_multiword_add() {
        // 16-bit addition on an 8-bit datapath: 0x00ff + 0x0001 = 0x0100.
        let sim = run(
            8,
            |a| {
                a.ldc(0, 0xff, 8); // lo a
                a.ldi(1, 0); // hi a
                a.ldi(2, 1); // lo b
                a.ldi(3, 0); // hi b
                a.push(Instr::Add { r1: 0, r2: 2 }); // lo sum, sets carry
                a.push(Instr::Adc { r1: 1, r2: 3 }); // hi sum + carry
            },
            4,
        );
        assert_eq!(sim.regs[0], 0x00);
        assert_eq!(sim.regs[1], 0x01);
    }

    #[test]
    fn borrow_chain_multiword_sub() {
        // 0x0100 - 0x0001 = 0x00ff on 8-bit datapath.
        let sim = run(
            8,
            |a| {
                a.ldi(0, 0); // lo a
                a.ldi(1, 1); // hi a
                a.ldi(2, 1); // lo b
                a.ldi(3, 0); // hi b
                a.push(Instr::Sub { r1: 0, r2: 2 });
                a.push(Instr::Sbc { r1: 1, r2: 3 });
            },
            4,
        );
        assert_eq!(sim.regs[0], 0xff);
        assert_eq!(sim.regs[1], 0x00);
    }

    #[test]
    fn shift_through_carry() {
        // 16-bit left shift on 8-bit datapath: 0x80ff << 1 = 0x01fe
        // (dropping the out-shifted top bit).
        let sim = run(
            8,
            |a| {
                a.ldc(0, 0xff, 8); // lo
                a.ldc(1, 0x80, 8); // hi
                a.push(Instr::Shl { r1: 0 }); // lo <<= 1, C = 1
                a.push(Instr::Slc { r1: 1 }); // hi = (hi<<1)|C
            },
            4,
        );
        assert_eq!(sim.regs[0], 0xfe);
        assert_eq!(sim.regs[1], 0x01);
    }

    #[test]
    fn sxt_fills_sign() {
        let sim = run(
            8,
            |a| {
                a.ldi(0, -5);
                a.push(Instr::Sxt { r1: 1, r2: 0 });
                a.ldi(2, 5);
                a.push(Instr::Sxt { r1: 3, r2: 2 });
            },
            4,
        );
        assert_eq!(sim.regs[1], 0xff);
        assert_eq!(sim.regs[3], 0x00);
    }

    #[test]
    fn load_store() {
        let sim = run(
            16,
            |a| {
                a.ldi(0, 3); // addr base
                a.ldc(1, 1234, 16);
                a.push(Instr::St { r1: 1, r2: 0, imm: 2 }); // mem[5] = 1234
                a.push(Instr::Ld { r1: 2, r2: 0, imm: 2 });
            },
            8,
        );
        assert_eq!(sim.regs[2], 1234);
        assert_eq!(sim.dmem.load(5).unwrap(), 1234);
    }

    #[test]
    fn carry_branches() {
        let sim = run(
            8,
            |a| {
                a.ldc(0, 0xff, 8);
                a.ldi(1, 1);
                a.push(Instr::Add { r1: 0, r2: 1 }); // sets carry
                a.ldi(2, 0);
                a.bnc("skip");
                a.ldi(2, 1); // carry taken path
                a.label("skip");
            },
            4,
        );
        assert_eq!(sim.regs[2], 1);
    }

    #[test]
    fn mac_narrow_readback() {
        // d=8, p=8: 100*100 = 10000 = 0x2710 read back in two chunks.
        let mut a = Asm::new();
        a.push(Instr::Mac { op: MacOp::MacClr, r1: 0, r2: 0 });
        a.ldc(0, 100, 8);
        a.push(Instr::Mac { op: MacOp::Mac, r1: 0, r2: 0 });
        a.push(Instr::Mac { op: MacOp::MacRd, r1: 1, r2: 0 }); // chunk 0
        a.push(Instr::Mac { op: MacOp::MacRd, r1: 2, r2: 1 }); // chunk 1
        a.push(Instr::Halt);
        let prog = a.finish().unwrap();
        let mut sim = TpIsa::new(8, &prog, 4, Some(MacConfig::new(8, 8)));
        sim.run(1000).unwrap();
        assert_eq!(sim.regs[1], 0x10);
        assert_eq!(sim.regs[2], 0x27);
    }

    #[test]
    fn mac_simd_total_wide() {
        // d=32, p=8: 4 lanes in parallel; MACRD reads the adder-tree
        // total (1*1 + 2*1 + 3*1 + 4*1 = 10).
        let mut a = Asm::new();
        a.push(Instr::Mac { op: MacOp::MacClr, r1: 0, r2: 0 });
        a.ldc(0, 0x0403_0201, 32); // lanes [1,2,3,4]
        a.ldc(1, 0x0101_0101, 32); // lanes [1,1,1,1]
        a.push(Instr::Mac { op: MacOp::Mac, r1: 0, r2: 1 });
        a.push(Instr::Mac { op: MacOp::MacRd, r1: 2, r2: 0 });
        a.push(Instr::Halt);
        let prog = a.finish().unwrap();
        let mut sim = TpIsa::new(32, &prog, 4, Some(MacConfig::new(32, 8)));
        sim.run(1000).unwrap();
        assert_eq!(sim.regs[2], 10);
    }

    #[test]
    fn mac_total_chunks_narrow() {
        // d=8, p=4, two lanes: total = 5*3 + 2*4 = 23, read in 4 chunks.
        let mut a = Asm::new();
        a.push(Instr::Mac { op: MacOp::MacClr, r1: 0, r2: 0 });
        a.ldc(0, 0x25, 8); // lanes [5, 2]
        a.ldc(1, 0x43, 8); // lanes [3, 4]
        a.push(Instr::Mac { op: MacOp::Mac, r1: 0, r2: 1 });
        for part in 0..4u8 {
            a.push(Instr::Mac { op: MacOp::MacRd, r1: 2 + part, r2: part });
        }
        a.push(Instr::Halt);
        let prog = a.finish().unwrap();
        let mut sim = TpIsa::new(8, &prog, 4, Some(MacConfig::new(8, 4)));
        sim.run(1000).unwrap();
        assert_eq!(sim.regs[2], 23);
        assert_eq!(sim.regs[3], 0);
        assert_eq!(sim.regs[4], 0);
        assert_eq!(sim.regs[5], 0);
    }

    #[test]
    fn timing_model() {
        let mut a = Asm::new();
        a.ldi(0, 1); // 2 cycles
        a.push(Instr::St { r1: 0, r2: 0, imm: 0 }); // 3 cycles
        a.push(Instr::Halt); // 1 cycle
        let prog = a.finish().unwrap();
        let mut sim = TpIsa::new(8, &prog, 4, None);
        sim.run(100).unwrap();
        assert_eq!(sim.profile.cycles, 6);
    }

    #[test]
    fn fuel() {
        let mut a = Asm::new();
        a.label("x");
        a.jmp("x");
        let prog = a.finish().unwrap();
        let mut sim = TpIsa::new(8, &prog, 4, None);
        assert_eq!(sim.run(50).unwrap(), Halt::Fuel);
    }
}
