//! Typed execution errors shared by both ISS cores and the batched
//! lockstep engine.
//!
//! The engines' run loops return `anyhow::Result`, but every
//! execution fault they can raise is one of these variants, so
//! consumers (`ml::harness`, the batch divergence drain, the
//! fault-injection campaign classifier) match on
//! `err.downcast_ref::<ExecError>()` instead of message substrings.
//! `Display` is part of the bit-identity contract: the differential
//! suites compare error *strings* across the interpreted, translated
//! and batched paths, so a variant renders identically wherever it is
//! constructed.

use crate::isa::MacOp;

/// Why an ISS execution failed (as opposed to halting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecError {
    /// RV32 fetch outside the program image.
    FetchFaultRv32 { pc: u32 },
    /// TP-ISA fetch outside the program.
    FetchFaultTpIsa { pc: i64, len: usize },
    /// A MAC-extension op retired on a core synthesised without a MAC
    /// unit.
    MacUnavailable { op: MacOp },
    /// The instruction budget ran out before the program halted — the
    /// harness-level rendering of `Halt::Fuel` for callers that treat
    /// non-completion as an error.
    FuelExhausted,
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::FetchFaultRv32 { pc } => write!(f, "PC {pc:#010x} outside program"),
            ExecError::FetchFaultTpIsa { pc, len } => {
                write!(f, "PC {pc} outside program ({len} instrs)")
            }
            ExecError::MacUnavailable { op } => {
                let name = match op {
                    MacOp::Mac => "MAC instruction",
                    MacOp::MacRd => "MACRD",
                    MacOp::MacClr => "MACCL",
                };
                write!(f, "{name} on a core without a MAC unit")
            }
            ExecError::FuelExhausted => {
                write!(f, "instruction budget exhausted before the program halted")
            }
        }
    }
}

impl std::error::Error for ExecError {}

#[cfg(test)]
mod tests {
    use super::*;

    /// The strings are load-bearing: the cross-engine differential
    /// suites compare `e.to_string()` between paths, so pin them.
    #[test]
    fn display_strings_are_stable() {
        assert_eq!(
            ExecError::FetchFaultRv32 { pc: 0x40 }.to_string(),
            "PC 0x00000040 outside program"
        );
        assert_eq!(
            ExecError::FetchFaultTpIsa { pc: -1, len: 12 }.to_string(),
            "PC -1 outside program (12 instrs)"
        );
        assert_eq!(
            ExecError::MacUnavailable { op: MacOp::Mac }.to_string(),
            "MAC instruction on a core without a MAC unit"
        );
        assert_eq!(
            ExecError::MacUnavailable { op: MacOp::MacRd }.to_string(),
            "MACRD on a core without a MAC unit"
        );
        assert_eq!(
            ExecError::MacUnavailable { op: MacOp::MacClr }.to_string(),
            "MACCL on a core without a MAC unit"
        );
    }

    /// Variants survive an `anyhow` context chain (what the harness
    /// wraps around engine errors) — the downcast consumers rely on it.
    #[test]
    fn downcasts_through_anyhow_context() {
        use anyhow::Context;
        let e: anyhow::Error = ExecError::FuelExhausted.into();
        let chained = Err::<(), _>(e).context("ISS run").unwrap_err();
        assert_eq!(chained.downcast_ref::<ExecError>(), Some(&ExecError::FuelExhausted));
    }
}
