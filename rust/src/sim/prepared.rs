//! Prepared program images: everything about a program that is
//! invariant across samples, built **once** per (model, variant) and
//! `Arc`-shared by every simulator instance that runs it.
//!
//! Before this existed, every harness sample paid to re-clone the
//! decoded instruction vector, re-`encode()` the ROM byte image and
//! re-preload the TP-ISA constant data through per-word bounds-checked
//! stores.  A [`PreparedRv32`] / [`PreparedTpIsa`] hoists all of that
//! out of the per-sample path: constructing a simulator from a prepared
//! image is two `Arc` clones plus one RAM allocation, and
//! [`crate::sim::zero_riscy::ZeroRiscy::reset`] /
//! [`crate::sim::tpisa::TpIsa::reset`] restore the initial state with a
//! memcpy so one simulator can run a whole shard of samples.
//!
//! The images live inside the codegen outputs (`ml::codegen_rv32::Rv32Program`,
//! `ml::codegen_tpisa::TpIsaProgram`), which the `dse::context`
//! program cache already Arc-shares across sweep rows and threads.
//!
//! The batched lockstep engine (`sim::batch`) leans on the same
//! sharing structure-of-arrays-wide: one prepared image, N lanes — the
//! image (and its translated block cache) is fetched once per block
//! dispatch while only the per-lane RAM/dmem/register state replicates.

use std::collections::BTreeSet;
use std::sync::Arc;

use super::translate::{self, TranslatedRv32, TranslatedTpIsa};
use crate::hw::mac_unit::MacConfig;
use crate::isa::{rv32, tpisa};

/// Immutable per-program state of the Zero-Riscy ISS: the pre-decoded
/// program, the pre-encoded ROM byte image (code, padding, constant
/// data), the RAM size, the MAC configuration and the static mnemonic
/// set the profiler seeds from.
#[derive(Debug, Clone)]
pub struct PreparedRv32 {
    /// Pre-decoded program (index = pc / 4).
    pub code: Vec<rv32::Instr>,
    /// Encoded ROM image: code, 4-byte-aligned padding, constant data.
    /// `Arc`-shared with every simulator's read-only `Mem::rom`.
    pub rom: Arc<Vec<u8>>,
    pub ram_bytes: usize,
    pub mac: Option<MacConfig>,
    /// Mnemonics present in the program image (static utilization).
    /// `Arc`-shared with every simulator's profile — construction is a
    /// pointer copy, not a `BTreeSet` rebuild.
    pub static_mnemonics: Arc<BTreeSet<&'static str>>,
    /// Pre-translated basic-block cache (built once here, ridden by
    /// [`crate::sim::zero_riscy::ZeroRiscy::run_translated`]).
    pub translated: TranslatedRv32,
}

impl PreparedRv32 {
    /// Encode the ROM image and collect the static mnemonic set.
    /// `code` is placed at ROM address 0; `rom_data` follows 4-byte
    /// aligned; RAM is `ram_bytes`.
    pub fn new(
        code: &[rv32::Instr],
        rom_data: &[u8],
        ram_bytes: usize,
        mac: Option<MacConfig>,
    ) -> PreparedRv32 {
        let mut rom = Vec::with_capacity(code.len() * 4 + rom_data.len());
        for i in code {
            rom.extend_from_slice(&i.encode().to_le_bytes());
        }
        while rom.len() % 4 != 0 {
            rom.push(0);
        }
        rom.extend_from_slice(rom_data);
        let static_mnemonics = Arc::new(code.iter().map(|i| i.mnemonic()).collect());
        let translated = translate::translate_rv32(code, mac.is_some());
        let (code, rom) = (code.to_vec(), Arc::new(rom));
        PreparedRv32 { code, rom, ram_bytes, mac, static_mnemonics, translated }
    }

    /// Byte offset where constant data begins in ROM.
    pub fn data_base(&self) -> u32 {
        (self.code.len() * 4) as u32
    }
}

/// Immutable per-program state of the TP-ISA ISS: the program, the
/// initial data-memory image (constants placed, input region zeroed,
/// every word masked to the datapath width) and the MAC configuration.
#[derive(Debug, Clone)]
pub struct PreparedTpIsa {
    /// Datapath width in bits (d ∈ {4, 8, 16, 32}).
    pub width: u32,
    pub code: Vec<tpisa::Instr>,
    /// Initial data-memory image; `reset()` memcpy-restores it.
    pub init_dmem: Vec<u64>,
    pub mac: Option<MacConfig>,
    /// Mnemonics present in the program image (static utilization).
    /// `Arc`-shared with every simulator's profile.
    pub static_mnemonics: Arc<BTreeSet<&'static str>>,
    /// Pre-translated basic-block cache (built once here, ridden by
    /// [`crate::sim::tpisa::TpIsa::run_translated`]).
    pub translated: TranslatedTpIsa,
}

impl PreparedTpIsa {
    /// Build from a code image and an initial data-memory image
    /// (`init_dmem.len()` is the data-memory size in words; values are
    /// masked to the datapath width here, once, so restores are a
    /// plain copy).
    pub fn new(
        width: u32,
        code: &[tpisa::Instr],
        mut init_dmem: Vec<u64>,
        mac: Option<MacConfig>,
    ) -> PreparedTpIsa {
        assert!(width >= 1 && width <= 64);
        if let Some(cfg) = &mac {
            assert_eq!(cfg.datapath, width, "MAC datapath must match the core");
        }
        let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
        for w in &mut init_dmem {
            *w &= mask;
        }
        let static_mnemonics = Arc::new(code.iter().map(|i| i.mnemonic()).collect());
        let translated = translate::translate_tpisa(code, mac.is_some());
        PreparedTpIsa { width, code: code.to_vec(), init_dmem, mac, static_mnemonics, translated }
    }

    /// Compatibility constructor: a zeroed data memory of `dmem_words`
    /// (the pre-rework `TpIsa::new` contract — callers preload
    /// constants themselves).
    pub fn with_zero_dmem(
        width: u32,
        code: &[tpisa::Instr],
        dmem_words: usize,
        mac: Option<MacConfig>,
    ) -> PreparedTpIsa {
        Self::new(width, code, vec![0; dmem_words], mac)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rv32_rom_image_matches_manual_encode() {
        use crate::isa::rv32_asm::assemble;
        let code = assemble("addi t0, t0, 1\nebreak").unwrap();
        let p = PreparedRv32::new(&code, &[0xaa, 0xbb], 64, None);
        assert_eq!(p.rom.len(), 8 + 2);
        assert_eq!(p.data_base(), 8);
        assert_eq!(&p.rom[8..], &[0xaa, 0xbb]);
        let word = u32::from_le_bytes([p.rom[0], p.rom[1], p.rom[2], p.rom[3]]);
        assert_eq!(word, code[0].encode());
        assert!(p.static_mnemonics.contains("addi"));
        assert!(p.static_mnemonics.contains("ebreak"));
        assert!(!p.static_mnemonics.contains("mul"));
    }

    #[test]
    fn tpisa_init_dmem_is_masked() {
        let code = [tpisa::Instr::Halt];
        let p = PreparedTpIsa::new(8, &code, vec![0x1ff, 0x42, u64::MAX], None);
        assert_eq!(p.init_dmem, vec![0xff, 0x42, 0xff]);
        assert!(p.static_mnemonics.contains("halt"));
    }
}
