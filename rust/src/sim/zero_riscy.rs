//! Zero-Riscy ISS: RV32IM subset with a 2-stage pipeline timing model.
//!
//! Timing (cycle-approximate, matching the core's documented behaviour):
//!
//! * 1 cycle per instruction base cost (2-stage, no load-use hazard
//!   stall on this microarchitecture's single write-back port model);
//! * loads/stores: +1 cycle (memory access);
//! * taken branches / jumps: +2 cycles (prefetch flush);
//! * MUL: 3 cycles (multi-stage multiplier); DIV/REM: 37 cycles;
//! * MAC extension ops: 1 cycle (the paper's single-cycle unit).
//!
//! The simulator optionally carries a [`MacState`] (the synthesised
//! core's MAC configuration) and an execution [`Profile`].
//!
//! Hot-loop architecture (§Perf iteration 3): the program lives in an
//! `Arc`-shared [`PreparedRv32`] (no per-simulator clone or
//! re-encode), [`ZeroRiscy::reset`] memcpy-restores the initial state
//! so one simulator can run a whole batch, and [`ZeroRiscy::run_traced`]
//! is generic over a [`TraceMode`] so profile bookkeeping monomorphizes
//! away when the caller only needs scores and cycles.  All error
//! construction is `#[cold]` and out of line.
//!
//! §Perf iteration 4 adds [`ZeroRiscy::run_translated`]: dispatch per
//! pre-translated basic block (`sim::translate`) — one fuel check, one
//! cycle/instruction add and one histogram delta per *block*, with
//! fused superinstructions for the codegen idioms — falling back to
//! the per-instruction [`ZeroRiscy::run_traced`] step for mid-block
//! entries (dynamic `jalr`, misaligned PCs), untranslatable blocks and
//! fuel tails.  Bit-identical to the interpreter in scores, cycles and
//! profiles (`tests/iss_equivalence.rs`).
//!
//! §Perf iteration 5 layers the batched lockstep engine
//! (`sim::batch::BatchRv32`) on the same building blocks: N lanes over
//! one shared image, each retiring the crate-visible
//! `exec_uop`/`apply_block`/`apply_term`/`step_traced` primitives below
//! in exactly the scalar order — so every lane is bit-identical to a
//! scalar [`ZeroRiscy::run_translated`] run
//! (`tests/iss_batch_equivalence.rs`).

use std::sync::Arc;

use anyhow::Result;

use super::error::ExecError;
use super::fault::{BitFlip, FaultState, FlipTarget};
use super::mac_model::MacState;
use super::mem::{Mem, RAM_BASE};
use super::prepared::PreparedRv32;
use super::trace::{FullProfile, Profile, TraceMode};
use super::translate::{BlockRv32, ExecStats, LoadRv32, SimpleRv32, TermRv32, UopRv32, NO_BLOCK};
use crate::hw::mac_unit::MacConfig;
use crate::isa::rv32::*;
use crate::isa::MacOp;

#[cold]
#[inline(never)]
fn fetch_fault(pc: u32) -> anyhow::Error {
    ExecError::FetchFaultRv32 { pc }.into()
}

#[cold]
#[inline(never)]
fn mac_unavailable(op: MacOp) -> anyhow::Error {
    ExecError::MacUnavailable { op }.into()
}

/// Why execution stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Halt {
    /// `ebreak` — normal program completion in our convention.
    Break,
    /// `ecall` — unused by our programs; profiled as a syscall.
    Ecall,
    /// Instruction budget exhausted.
    Fuel,
}

/// The Zero-Riscy instruction-set simulator.
pub struct ZeroRiscy {
    pub regs: [u32; 32],
    pub pc: u32,
    pub mem: Mem,
    pub mac: Option<MacState>,
    /// Shared prepared program image (pre-decoded code + encoded ROM).
    prepared: Arc<PreparedRv32>,
    pub profile: Profile,
    /// Translated-engine counters (blocks dispatched, fallback steps).
    /// Accumulates across [`ZeroRiscy::reset`], like the profile.
    pub exec_stats: ExecStats,
    /// Armed soft-error plan (`sim::fault`).  `None` — the default —
    /// costs one pointer-null check per retire; an armed empty plan is
    /// bit-identical to `None` (pinned by `tests/fault_identity.rs`).
    pub fault: Option<Box<FaultState>>,
}

/// All mnemonics the decoder can produce — the universe against which
/// the profiler reports unused instructions.
pub const ALL_MNEMONICS: &[&str] = &[
    "lui", "auipc", "jal", "jalr", "beq", "bne", "blt", "bge", "bltu", "bgeu", "lb", "lh", "lw",
    "lbu", "lhu", "sb", "sh", "sw", "addi", "slti", "sltiu", "xori", "ori", "andi", "slli",
    "srli", "srai", "add", "sub", "sll", "slt", "sltu", "xor", "srl", "sra", "or", "and", "mul",
    "mulh", "mulhsu", "mulhu", "div", "divu", "rem", "remu", "csrrw", "csrrs", "csrrc", "ecall",
    "ebreak", "fence", "mac", "macrd", "maccl",
];

impl ZeroRiscy {
    /// Build a simulator for a program image.  `code` is placed at ROM
    /// address 0; `rom_data` follows 4-byte aligned; RAM is `ram_bytes`.
    ///
    /// Prepares the image on the spot; batch callers should build one
    /// [`PreparedRv32`] and use [`ZeroRiscy::from_prepared`] instead.
    pub fn new(code: &[Instr], rom_data: &[u8], ram_bytes: usize, mac: Option<MacConfig>) -> Self {
        Self::from_prepared(Arc::new(PreparedRv32::new(code, rom_data, ram_bytes, mac)))
    }

    /// Build a simulator over a shared prepared image: three `Arc`
    /// clones (ROM, image, static-mnemonic set) plus one RAM allocation
    /// — no program copy, no encode, no `BTreeSet` rebuild.
    pub fn from_prepared(prepared: Arc<PreparedRv32>) -> Self {
        let mut profile = Profile::default();
        profile.static_mnemonics = Arc::clone(&prepared.static_mnemonics);
        ZeroRiscy {
            regs: [0; 32],
            pc: 0,
            mem: Mem::new(Arc::clone(&prepared.rom), prepared.ram_bytes),
            mac: prepared.mac.map(MacState::new),
            prepared,
            profile,
            exec_stats: ExecStats::default(),
            fault: None,
        }
    }

    /// Restore the initial machine state (zero registers and RAM,
    /// cleared MAC accumulators, pc = 0) so the simulator can run the
    /// next sample without being reconstructed.
    ///
    /// The profile is deliberately **not** cleared: it keeps
    /// accumulating across runs, exactly as if each run's fresh profile
    /// had been folded in with [`Profile::merge`].
    pub fn reset(&mut self) {
        self.regs = [0; 32];
        self.pc = 0;
        self.mem.reset();
        if let Some(m) = &mut self.mac {
            m.clear();
        }
        if let Some(f) = &mut self.fault {
            f.rearm();
        }
    }

    /// The shared prepared image this simulator executes.
    pub fn prepared(&self) -> &Arc<PreparedRv32> {
        &self.prepared
    }

    /// Byte offset where constant data begins in ROM.
    pub fn data_base(&self) -> u32 {
        self.prepared.data_base()
    }

    pub fn rom_bytes(&self) -> usize {
        self.mem.rom.len()
    }

    #[inline(always)]
    fn set_reg<M: TraceMode>(&mut self, r: Reg, v: u32) {
        if r != 0 {
            self.regs[r as usize] = v;
        }
        if M::PROFILE {
            self.profile.record_reg(r);
        }
    }

    #[inline(always)]
    fn reg<M: TraceMode>(&mut self, r: Reg) -> u32 {
        if M::PROFILE {
            self.profile.record_reg(r);
        }
        self.regs[r as usize]
    }

    /// Run until halt or `fuel` instructions, with full profiling.
    pub fn run(&mut self, fuel: u64) -> Result<Halt> {
        self.run_traced::<FullProfile>(fuel)
    }

    /// [`ZeroRiscy::run`] generic over the tracing mode: with
    /// [`CyclesOnly`](super::trace::CyclesOnly) the per-retire
    /// histogram, register-bitmask and max-PC updates compile away.
    ///
    /// This is the per-instruction *reference* loop; the production hot
    /// path is [`ZeroRiscy::run_translated`], which is bit-identical.
    pub fn run_traced<M: TraceMode>(&mut self, fuel: u64) -> Result<Halt> {
        let prepared = Arc::clone(&self.prepared);
        let code: &[Instr] = &prepared.code;
        let mut executed = 0u64;
        loop {
            if executed >= fuel {
                return Ok(Halt::Fuel);
            }
            executed += 1;
            if let Some(h) = self.step_traced::<M>(code)? {
                return Ok(h);
            }
        }
    }

    /// Fetch, profile, execute and retire exactly one instruction — the
    /// body of [`ZeroRiscy::run_traced`], shared with the translated
    /// engine's fallback path and the batched engine's masked-lane
    /// drain (`sim::batch`).  Returns `Some` on halt.
    #[inline(always)]
    pub(crate) fn step_traced<M: TraceMode>(&mut self, code: &[Instr]) -> Result<Option<Halt>> {
        {
            let idx = (self.pc / 4) as usize;
            let instr = match code.get(idx) {
                Some(&i) => i,
                None => return Err(fetch_fault(self.pc)),
            };
            if M::PROFILE {
                self.profile.record_instr(instr.mnemonic_id(), instr.mnemonic());
                self.profile.max_pc = self.profile.max_pc.max(self.pc);
            } else {
                self.profile.instructions += 1;
            }
            let mut next_pc = self.pc.wrapping_add(4);
            let mut cost = 1u64;

            match instr {
                Instr::Lui { rd, imm } => self.set_reg::<M>(rd, imm as u32),
                Instr::Auipc { rd, imm } => {
                    self.set_reg::<M>(rd, self.pc.wrapping_add(imm as u32))
                }
                Instr::Jal { rd, offset } => {
                    self.set_reg::<M>(rd, next_pc);
                    next_pc = self.pc.wrapping_add(offset as u32);
                    cost += 2;
                    self.profile.branches_taken += 1;
                }
                Instr::Jalr { rd, rs1, offset } => {
                    let t = self.reg::<M>(rs1).wrapping_add(offset as u32) & !1;
                    self.set_reg::<M>(rd, next_pc);
                    next_pc = t;
                    cost += 2;
                    self.profile.branches_taken += 1;
                }
                Instr::Branch { op, rs1, rs2, offset } => {
                    let (a, b) = (self.reg::<M>(rs1), self.reg::<M>(rs2));
                    let taken = match op {
                        BranchOp::Beq => a == b,
                        BranchOp::Bne => a != b,
                        BranchOp::Blt => (a as i32) < (b as i32),
                        BranchOp::Bge => (a as i32) >= (b as i32),
                        BranchOp::Bltu => a < b,
                        BranchOp::Bgeu => a >= b,
                    };
                    if taken {
                        next_pc = self.pc.wrapping_add(offset as u32);
                        cost += 2;
                        self.profile.branches_taken += 1;
                    }
                }
                Instr::Load { op, rd, rs1, offset } => {
                    let addr = self.reg::<M>(rs1).wrapping_add(offset as u32);
                    let v = match op {
                        LoadOp::Lb => self.mem.load_u8(addr)? as i8 as i32 as u32,
                        LoadOp::Lbu => self.mem.load_u8(addr)? as u32,
                        LoadOp::Lh => self.mem.load_u16(addr)? as i16 as i32 as u32,
                        LoadOp::Lhu => self.mem.load_u16(addr)? as u32,
                        LoadOp::Lw => self.mem.load_u32(addr)?,
                    };
                    self.set_reg::<M>(rd, v);
                    self.note_ram(addr);
                    cost += 1;
                    self.profile.loads += 1;
                }
                Instr::Store { op, rs2, rs1, offset } => {
                    let addr = self.reg::<M>(rs1).wrapping_add(offset as u32);
                    let v = self.reg::<M>(rs2);
                    match op {
                        StoreOp::Sb => self.mem.store_u8(addr, v as u8)?,
                        StoreOp::Sh => self.mem.store_u16(addr, v as u16)?,
                        StoreOp::Sw => self.mem.store_u32(addr, v)?,
                    }
                    self.note_ram(addr);
                    cost += 1;
                    self.profile.stores += 1;
                }
                Instr::OpImm { op, rd, rs1, imm } => {
                    let a = self.reg::<M>(rs1);
                    let v = alu(op, a, imm as u32);
                    self.set_reg::<M>(rd, v);
                }
                Instr::Op { op, rd, rs1, rs2 } => {
                    let (a, b) = (self.reg::<M>(rs1), self.reg::<M>(rs2));
                    self.set_reg::<M>(rd, alu(op, a, b));
                }
                Instr::MulDiv { op, rd, rs1, rs2 } => {
                    let (a, b) = (self.reg::<M>(rs1), self.reg::<M>(rs2));
                    let v = muldiv(op, a, b);
                    self.set_reg::<M>(rd, v);
                    match op {
                        MulOp::Mul | MulOp::Mulh | MulOp::Mulhsu | MulOp::Mulhu => {
                            cost += 2; // 3-cycle multi-stage multiplier
                            self.profile.mul_ops += 1;
                        }
                        _ => cost += 36, // iterative divider
                    }
                }
                Instr::Csr { rd, rs1, .. } => {
                    // Minimal CSR file: reads return 0 (the bespoke flow
                    // only needs to *observe* CSR usage).
                    let _ = self.reg::<M>(rs1);
                    self.set_reg::<M>(rd, 0);
                    self.profile.csr_used = true;
                }
                Instr::Ecall => {
                    self.profile.syscalls_used = true;
                    self.profile.cycles += cost;
                    return Ok(Some(Halt::Ecall));
                }
                Instr::Ebreak => {
                    self.profile.cycles += cost;
                    return Ok(Some(Halt::Break));
                }
                Instr::Fence => {}
                Instr::Mac { op, rd, rs1, rs2 } => {
                    let mac = match self.mac.as_mut() {
                        Some(m) => m,
                        None => return Err(mac_unavailable(op)),
                    };
                    match op {
                        MacOp::Mac => {
                            let a = self.regs[rs1 as usize];
                            let b = self.regs[rs2 as usize];
                            if M::PROFILE {
                                self.profile.record_reg(rs1);
                                self.profile.record_reg(rs2);
                            }
                            mac.mac(a as u64, b as u64);
                            self.profile.mac_ops += 1;
                            self.fault_mac_tick();
                        }
                        MacOp::MacRd => {
                            let v = mac.read(rs1 as usize);
                            self.set_reg::<M>(rd, v);
                        }
                        MacOp::MacClr => mac.clear(),
                    }
                }
            }
            self.profile.cycles += cost;
            self.pc = next_pc;
            self.fault_tick(1);
        }
        Ok(None)
    }

    /// Run until halt or `fuel` instructions, dispatching per
    /// pre-translated basic block (`sim::translate`): one fuel check,
    /// one cycle/instruction add, one histogram delta and one
    /// register-mask OR per block, with the codegen hot idioms fused
    /// into superinstructions.  Falls back to the per-instruction
    /// interpreter step whenever the PC is not a translated
    /// leader (dynamic `jalr` landing mid-block, misaligned PCs from
    /// half-word-aligned branches, MAC blocks on a MAC-less core) or
    /// the remaining fuel cannot cover a whole block — so halts and
    /// `Halt::Fuel` states are bit-identical to the interpreter, and a
    /// fault returns the same `Err` with the same registers/RAM (the
    /// profile and `pc` are unspecified after an `Err`, which every
    /// consumer propagates — see `sim::translate`'s error contract).
    pub fn run_translated<M: TraceMode>(&mut self, fuel: u64) -> Result<Halt> {
        let prepared = Arc::clone(&self.prepared);
        let code: &[Instr] = &prepared.code;
        let trans = &prepared.translated;
        let blocks = trans.blocks.as_slice();
        let leaders: &[u32] = &trans.leaders;
        let mut executed = 0u64;
        loop {
            let mut bid = NO_BLOCK;
            if self.pc & 3 == 0 {
                if let Some(&b) = leaders.get((self.pc >> 2) as usize) {
                    bid = b;
                }
            }
            if bid != NO_BLOCK {
                let b = &blocks[bid as usize];
                if fuel - executed >= b.n_instrs as u64 {
                    executed += b.n_instrs as u64;
                    self.exec_stats.blocks += 1;
                    self.exec_stats.fused_uops += b.fused as u64;
                    for u in b.uops.iter() {
                        self.exec_uop(u)?;
                    }
                    // Block-granular fault clock: flips due anywhere in
                    // the block land at its boundary, before the
                    // terminator resolves — the same point the batched
                    // engine ticks, so translated == batched per lane.
                    self.fault_tick(b.n_instrs as u64);
                    self.apply_block::<M>(b);
                    if let Some(h) = self.apply_term(b) {
                        return Ok(h);
                    }
                    continue;
                }
            }
            // Fallback: one interpreted step (mid-block entry,
            // untranslatable block, or fuel tail inside a block).
            if executed >= fuel {
                return Ok(Halt::Fuel);
            }
            executed += 1;
            self.exec_stats.fallback_instrs += 1;
            if let Some(h) = self.step_traced::<M>(code)? {
                return Ok(h);
            }
        }
    }

    /// Book a translated block's aggregate counters on this simulator's
    /// profile: one cycle/instruction add, one histogram delta and one
    /// register-mask OR per block.  Shared by [`run_translated`] and
    /// the batched lockstep engine (`sim::batch`) — which, under a
    /// [`TraceMode`] with `LANE_PROFILE = false`, books these on a
    /// batch-shared profile instead via
    /// [`apply_block_shared`](super::batch::BatchRv32).
    ///
    /// [`run_translated`]: ZeroRiscy::run_translated
    #[inline(always)]
    pub(crate) fn apply_block<M: TraceMode>(&mut self, b: &BlockRv32) {
        let p = &mut self.profile;
        p.cycles += b.base_cycles;
        p.instructions += b.n_instrs as u64;
        p.loads += b.loads;
        p.stores += b.stores;
        p.mul_ops += b.mul_ops;
        p.mac_ops += b.mac_ops;
        p.branches_taken += b.branches_taken;
        if b.csr_used {
            p.csr_used = true;
        }
        if M::PROFILE {
            p.regs_used |= b.reg_mask;
            p.max_pc = p.max_pc.max(b.last_pc);
            p.record_block(&b.counts);
        }
    }

    /// Execute a translated block's terminator: resolve the next PC
    /// (lane-variant costs — taken-branch flush, syscall flag — go to
    /// this simulator's own profile) and report a halt if the block
    /// ends the program.  Shared by [`run_translated`] and the batched
    /// lockstep engine.
    ///
    /// [`run_translated`]: ZeroRiscy::run_translated
    #[inline(always)]
    pub(crate) fn apply_term(&mut self, b: &BlockRv32) -> Option<Halt> {
        match b.term {
            TermRv32::FallThrough => self.pc = b.next_pc,
            TermRv32::Jal { rd, target, link } => {
                if rd != 0 {
                    self.regs[rd as usize] = link;
                }
                self.pc = target;
            }
            TermRv32::Jalr { rd, rs1, offset, link } => {
                let t = self.regs[rs1 as usize].wrapping_add(offset as u32) & !1;
                if rd != 0 {
                    self.regs[rd as usize] = link;
                }
                self.pc = t;
            }
            TermRv32::Branch { op, rs1, rs2, target } => {
                let (a, v) = (self.regs[rs1 as usize], self.regs[rs2 as usize]);
                let taken = match op {
                    BranchOp::Beq => a == v,
                    BranchOp::Bne => a != v,
                    BranchOp::Blt => (a as i32) < (v as i32),
                    BranchOp::Bge => (a as i32) >= (v as i32),
                    BranchOp::Bltu => a < v,
                    BranchOp::Bgeu => a >= v,
                };
                if taken {
                    self.profile.cycles += 2;
                    self.profile.branches_taken += 1;
                    self.pc = target;
                } else {
                    self.pc = b.next_pc;
                }
            }
            TermRv32::Ebreak => {
                self.pc = b.last_pc;
                return Some(Halt::Break);
            }
            TermRv32::Ecall => {
                self.pc = b.last_pc;
                self.profile.syscalls_used = true;
                return Some(Halt::Ecall);
            }
        }
        None
    }

    /// Register write without profile bookkeeping (the translated
    /// engine applies the block's precomputed register mask instead).
    #[inline(always)]
    fn uset(&mut self, r: Reg, v: u32) {
        if r != 0 {
            self.regs[r as usize] = v;
        }
    }

    /// Execute one load (data effects + BAR reach only; the block
    /// aggregates carry the `loads` counter and cycle cost).
    #[inline(always)]
    fn exec_load(&mut self, l: &LoadRv32) -> Result<()> {
        let addr = self.regs[l.rs1 as usize].wrapping_add(l.offset as u32);
        let v = match l.op {
            LoadOp::Lb => self.mem.load_u8(addr)? as i8 as i32 as u32,
            LoadOp::Lbu => self.mem.load_u8(addr)? as u32,
            LoadOp::Lh => self.mem.load_u16(addr)? as i16 as i32 as u32,
            LoadOp::Lhu => self.mem.load_u16(addr)? as u32,
            LoadOp::Lw => self.mem.load_u32(addr)?,
        };
        self.uset(l.rd, v);
        self.note_ram(addr);
        Ok(())
    }

    /// Execute one register-only micro-op.
    #[inline(always)]
    fn exec_simple(&mut self, s: &SimpleRv32) {
        match *s {
            SimpleRv32::SetReg { rd, v } => self.uset(rd, v),
            SimpleRv32::OpImm { op, rd, rs1, imm } => {
                let a = self.regs[rs1 as usize];
                self.uset(rd, alu(op, a, imm as u32));
            }
            SimpleRv32::Op { op, rd, rs1, rs2 } => {
                let (a, v) = (self.regs[rs1 as usize], self.regs[rs2 as usize]);
                self.uset(rd, alu(op, a, v));
            }
        }
    }

    /// Execute one MAC-extension op (data effects only).
    #[inline(always)]
    fn exec_mac(&mut self, op: MacOp, rd: Reg, rs1: Reg, rs2: Reg) -> Result<()> {
        let mac = match self.mac.as_mut() {
            Some(m) => m,
            None => return Err(mac_unavailable(op)),
        };
        match op {
            MacOp::Mac => {
                let a = self.regs[rs1 as usize];
                let v = self.regs[rs2 as usize];
                mac.mac(a as u64, v as u64);
                self.fault_mac_tick();
            }
            MacOp::MacRd => {
                let v = mac.read(rs1 as usize);
                self.uset(rd, v);
            }
            MacOp::MacClr => mac.clear(),
        }
        Ok(())
    }

    /// Execute one translated micro-op.  Performs exactly the same
    /// architectural steps in the same order as the interpreter, so
    /// register aliasing and fault ordering are preserved; all
    /// per-retire accounting lives in the block aggregates.
    /// `pub(crate)` so the batched lockstep engine can retire one
    /// micro-op across many lanes.
    #[inline(always)]
    pub(crate) fn exec_uop(&mut self, u: &UopRv32) -> Result<()> {
        match u {
            UopRv32::Simple(s) => self.exec_simple(s),
            UopRv32::Alu2(a, b) => {
                self.exec_simple(a);
                self.exec_simple(b);
            }
            UopRv32::Alu3(a, b, c) => {
                self.exec_simple(a);
                self.exec_simple(b);
                self.exec_simple(c);
            }
            UopRv32::Load(l) => self.exec_load(l)?,
            UopRv32::Store { op, rs2, rs1, offset } => {
                let addr = self.regs[*rs1 as usize].wrapping_add(*offset as u32);
                let v = self.regs[*rs2 as usize];
                match op {
                    StoreOp::Sb => self.mem.store_u8(addr, v as u8)?,
                    StoreOp::Sh => self.mem.store_u16(addr, v as u16)?,
                    StoreOp::Sw => self.mem.store_u32(addr, v)?,
                }
                self.note_ram(addr);
            }
            UopRv32::MulDiv { op, rd, rs1, rs2 } => {
                let (a, v) = (self.regs[*rs1 as usize], self.regs[*rs2 as usize]);
                self.uset(*rd, muldiv(*op, a, v));
            }
            UopRv32::Mac { op, rd, rs1, rs2 } => self.exec_mac(*op, *rd, *rs1, *rs2)?,
            UopRv32::Load2Mac { a, b, rs1, rs2 } => {
                self.exec_load(a)?;
                self.exec_load(b)?;
                self.exec_mac(MacOp::Mac, 0, *rs1, *rs2)?;
            }
            UopRv32::Load2MulAdd { a, b, mul, add } => {
                self.exec_load(a)?;
                self.exec_load(b)?;
                let (mrd, mr1, mr2) = *mul;
                let v = muldiv(MulOp::Mul, self.regs[mr1 as usize], self.regs[mr2 as usize]);
                self.uset(mrd, v);
                let (ard, ar1, ar2) = *add;
                let s = self.regs[ar1 as usize].wrapping_add(self.regs[ar2 as usize]);
                self.uset(ard, s);
            }
        }
        Ok(())
    }

    fn note_ram(&mut self, addr: u32) {
        if addr >= RAM_BASE {
            self.profile.max_ram_offset = self.profile.max_ram_offset.max(addr - RAM_BASE);
        }
    }

    /// Advance the soft-error instruction clock by `retired` retires and
    /// apply any newly due register/RAM flips.  The interpreter ticks
    /// per instruction; the translated and batched engines tick once per
    /// block (`b.n_instrs`) at the block boundary, so a plan's landing
    /// site is deterministic *per engine* — and an empty/absent plan is
    /// one predictable branch.
    #[inline(always)]
    pub(crate) fn fault_tick(&mut self, retired: u64) {
        if self.fault.is_some() {
            self.fault_tick_slow(retired);
        }
    }

    #[cold]
    fn fault_tick_slow(&mut self, retired: u64) {
        let mut f = self.fault.take().unwrap();
        for flip in f.advance(retired) {
            self.apply_flip(flip);
        }
        self.fault = Some(f);
    }

    fn apply_flip(&mut self, flip: &BitFlip) {
        match flip.target {
            FlipTarget::Reg(r) => {
                // x0 is hardwired zero — an upset there is masked by
                // construction, exactly like the real register file.
                let r = (r as usize) % 32;
                if r != 0 {
                    self.regs[r] ^= 1u32 << (flip.bit % 32);
                }
            }
            FlipTarget::Ram(off) => {
                let n = self.mem.ram.len();
                if n > 0 {
                    self.mem.ram[(off as usize) % n] ^= 1u8 << (flip.bit % 8);
                }
            }
        }
    }

    /// Advance the MAC-op clock by one accumulate and apply any due
    /// accumulator flips (the transient-upset model for the SIMD MAC
    /// result path).  Called right after every `mac` accumulate on
    /// every engine path, so the clock is engine-invariant.
    #[inline(always)]
    fn fault_mac_tick(&mut self) {
        if self.fault.is_some() {
            self.fault_mac_slow();
        }
    }

    #[cold]
    fn fault_mac_slow(&mut self) {
        let mut f = self.fault.take().unwrap();
        if let Some(mac) = &mut self.mac {
            for mf in f.advance_mac(1) {
                mac.flip_acc(mf.lane as usize, mf.bit as u32);
            }
        }
        self.fault = Some(f);
    }
}

fn alu(op: AluOp, a: u32, b: u32) -> u32 {
    match op {
        AluOp::Add => a.wrapping_add(b),
        AluOp::Sub => a.wrapping_sub(b),
        AluOp::Sll => a.wrapping_shl(b & 31),
        AluOp::Slt => ((a as i32) < (b as i32)) as u32,
        AluOp::Sltu => (a < b) as u32,
        AluOp::Xor => a ^ b,
        AluOp::Srl => a.wrapping_shr(b & 31),
        AluOp::Sra => ((a as i32).wrapping_shr(b & 31)) as u32,
        AluOp::Or => a | b,
        AluOp::And => a & b,
    }
}

fn muldiv(op: MulOp, a: u32, b: u32) -> u32 {
    let (sa, sb) = (a as i32 as i64, b as i32 as i64);
    let (ua, ub) = (a as u64, b as u64);
    match op {
        MulOp::Mul => (sa.wrapping_mul(sb)) as u32,
        MulOp::Mulh => ((sa.wrapping_mul(sb)) >> 32) as u32,
        MulOp::Mulhsu => ((sa.wrapping_mul(ub as i64)) >> 32) as u32,
        MulOp::Mulhu => ((ua.wrapping_mul(ub)) >> 32) as u32,
        MulOp::Div => {
            if b == 0 {
                u32::MAX
            } else if a == 0x8000_0000 && b == u32::MAX {
                a
            } else {
                ((a as i32) / (b as i32)) as u32
            }
        }
        MulOp::Divu => {
            if b == 0 {
                u32::MAX
            } else {
                a / b
            }
        }
        MulOp::Rem => {
            if b == 0 {
                a
            } else if a == 0x8000_0000 && b == u32::MAX {
                0
            } else {
                ((a as i32) % (b as i32)) as u32
            }
        }
        MulOp::Remu => {
            if b == 0 {
                a
            } else {
                a % b
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::rv32_asm::{assemble, Asm};

    fn run_asm(text: &str) -> ZeroRiscy {
        let prog = assemble(text).unwrap();
        let mut sim = ZeroRiscy::new(&prog, &[], 4096, None);
        assert_eq!(sim.run(1_000_000).unwrap(), Halt::Break);
        sim
    }

    #[test]
    fn arithmetic_loop() {
        let sim = run_asm(
            r#"
                li   t0, 10
                li   t1, 0
            loop:
                add  t1, t1, t0
                addi t0, t0, -1
                bnez t0, loop
                ebreak
            "#,
        );
        assert_eq!(sim.regs[6], 55); // 10+9+...+1
    }

    #[test]
    fn memory_roundtrip() {
        let sim = run_asm(&format!(
            r#"
                li  t0, {RAM_BASE}
                li  t1, -1234
                sw  t1, 8(t0)
                lw  t2, 8(t0)
                lh  t3, 8(t0)
                ebreak
            "#
        ));
        assert_eq!(sim.regs[7] as i32, -1234);
        assert_eq!(sim.regs[28] as i32, -1234);
    }

    #[test]
    fn mul_timing_and_value() {
        let mut sim = {
            let prog = assemble("li a0, -7\nli a1, 9\nmul a2, a0, a1\nebreak").unwrap();
            ZeroRiscy::new(&prog, &[], 64, None)
        };
        sim.run(100).unwrap();
        assert_eq!(sim.regs[12] as i32, -63);
        // li + li + mul(3) + ebreak = 1+1+3+1.
        assert_eq!(sim.profile.cycles, 6);
        assert_eq!(sim.profile.mul_ops, 1);
    }

    #[test]
    fn branch_flush_penalty() {
        let mut sim = {
            let prog = assemble("li t0, 1\nbeqz t0, skip\nnop\nskip: ebreak").unwrap();
            ZeroRiscy::new(&prog, &[], 64, None)
        };
        sim.run(100).unwrap();
        // Not-taken branch costs 1: li(1) + beqz(1) + nop(1) + ebreak(1).
        assert_eq!(sim.profile.cycles, 4);

        let mut sim = {
            let prog = assemble("li t0, 0\nbeqz t0, skip\nnop\nskip: ebreak").unwrap();
            ZeroRiscy::new(&prog, &[], 64, None)
        };
        sim.run(100).unwrap();
        // Taken branch costs 3: li(1) + beqz(3) + ebreak(1).
        assert_eq!(sim.profile.cycles, 5);
        assert_eq!(sim.profile.branches_taken, 1);
    }

    #[test]
    fn mac_unit_integration() {
        let prog = assemble(
            r#"
                maccl
                li a0, 3
                li a1, 4
                mac a0, a1
                mac a0, a1
                macrd a2, 0
                ebreak
            "#,
        )
        .unwrap();
        let mut sim = ZeroRiscy::new(&prog, &[], 64, Some(MacConfig::new(32, 32)));
        sim.run(100).unwrap();
        assert_eq!(sim.regs[12], 24);
        assert_eq!(sim.profile.mac_ops, 2);
    }

    #[test]
    fn mac_without_unit_errors() {
        let prog = assemble("mac a0, a1\nebreak").unwrap();
        let mut sim = ZeroRiscy::new(&prog, &[], 64, None);
        let err = sim.run(10).unwrap_err();
        assert_eq!(
            err.downcast_ref::<ExecError>(),
            Some(&ExecError::MacUnavailable { op: MacOp::Mac })
        );
    }

    #[test]
    fn simd_mac_p16_lanes() {
        // Pack lanes [3, -2] and [5, 7]: acc0 = 15, acc1 = -14.
        let mut a = Asm::new();
        a.maccl();
        a.li(10, (3i32 | (-2i32 << 16)) as i32);
        a.li(11, 5 | (7 << 16));
        a.mac(10, 11);
        a.macrd(12, 0);
        a.macrd(13, 1);
        a.ebreak();
        let prog = a.finish().unwrap();
        let mut sim = ZeroRiscy::new(&prog, &[], 64, Some(MacConfig::new(32, 16)));
        sim.run(100).unwrap();
        assert_eq!(sim.regs[12] as i32, 15);
        assert_eq!(sim.regs[13] as i32, -14);
    }

    #[test]
    fn fuel_limit() {
        let prog = assemble("loop: j loop").unwrap();
        let mut sim = ZeroRiscy::new(&prog, &[], 64, None);
        assert_eq!(sim.run(100).unwrap(), Halt::Fuel);
    }

    #[test]
    fn profile_counts() {
        let sim = run_asm(
            r#"
                li   t0, 3
            l:  addi t0, t0, -1
                bnez t0, l
                ebreak
            "#,
        );
        assert_eq!(sim.profile.count("addi"), 4); // li + 3x addi
        assert_eq!(sim.profile.count("bne"), 3);
        assert_eq!(sim.profile.branches_taken, 2);
        assert!(sim.profile.unused_mnemonics(ALL_MNEMONICS).contains(&"mulh"));
        assert!(!sim.profile.csr_used);
    }

    #[test]
    fn reset_reproduces_fresh_run() {
        let prog = assemble(&format!(
            r#"
                li   t0, {RAM_BASE}
                li   t1, 77
                sw   t1, 0(t0)
                lw   t2, 0(t0)
                ebreak
            "#
        ))
        .unwrap();
        let prepared = Arc::new(PreparedRv32::new(&prog, &[], 64, Some(MacConfig::new(32, 32))));
        let mut reused = ZeroRiscy::from_prepared(Arc::clone(&prepared));
        reused.run(1000).unwrap();
        let first_cycles = reused.profile.cycles;
        reused.reset();
        assert_eq!(reused.pc, 0);
        assert_eq!(reused.regs, [0; 32]);
        assert_eq!(reused.mem.load_u32(RAM_BASE).unwrap(), 0);
        reused.run(1000).unwrap();

        let mut fresh = ZeroRiscy::from_prepared(prepared);
        fresh.run(1000).unwrap();
        assert_eq!(reused.regs, fresh.regs);
        assert_eq!(reused.mem.ram, fresh.mem.ram);
        // The accumulated profile equals two merged fresh runs.
        assert_eq!(reused.profile.cycles, 2 * first_cycles);
        assert_eq!(reused.profile.cycles, 2 * fresh.profile.cycles);
        assert_eq!(reused.profile.instructions, 2 * fresh.profile.instructions);
    }

    #[test]
    fn cycles_only_matches_full_profile() {
        let prog = assemble(
            r#"
                li   t0, 6
                li   t1, 0
            l:  add  t1, t1, t0
                addi t0, t0, -1
                bnez t0, l
                mul  t2, t1, t1
                ebreak
            "#,
        )
        .unwrap();
        let prepared = Arc::new(PreparedRv32::new(&prog, &[], 64, None));
        let mut full = ZeroRiscy::from_prepared(Arc::clone(&prepared));
        assert_eq!(full.run_traced::<FullProfile>(1000).unwrap(), Halt::Break);
        let mut cyc = ZeroRiscy::from_prepared(prepared);
        assert_eq!(cyc.run_traced::<crate::sim::trace::CyclesOnly>(1000).unwrap(), Halt::Break);
        // Identical architectural state and cycle/instruction counts...
        assert_eq!(cyc.regs, full.regs);
        assert_eq!(cyc.profile.cycles, full.profile.cycles);
        assert_eq!(cyc.profile.instructions, full.profile.instructions);
        assert_eq!(cyc.profile.mul_ops, full.profile.mul_ops);
        assert_eq!(cyc.profile.branches_taken, full.profile.branches_taken);
        // ...with the per-retire profiling work skipped.
        assert!(cyc.profile.instr_counts().is_empty());
        assert_eq!(cyc.profile.regs_used, 0);
        assert_eq!(cyc.profile.max_pc, 0);
        assert!(full.profile.count("add") > 0);
        assert!(full.profile.max_pc > 0);
    }

    #[test]
    fn prepared_image_is_shared_not_copied() {
        let prog = assemble("ebreak").unwrap();
        let prepared = Arc::new(PreparedRv32::new(&prog, &[1, 2, 3], 64, None));
        let a = ZeroRiscy::from_prepared(Arc::clone(&prepared));
        let b = ZeroRiscy::from_prepared(Arc::clone(&prepared));
        assert!(Arc::ptr_eq(a.prepared(), b.prepared()));
        assert!(Arc::ptr_eq(&a.mem.rom, &b.mem.rom));
        assert_eq!(a.rom_bytes(), 4 + 3);
    }

    /// Interpreted and translated runs of the same prepared image must
    /// agree on every observable, including mid-run `Halt::Fuel` states.
    fn assert_translated_matches(text: &str, fuel: u64) {
        let prog = assemble(text).unwrap();
        let prepared = Arc::new(PreparedRv32::new(&prog, &[], 4096, None));
        let mut interp = ZeroRiscy::from_prepared(Arc::clone(&prepared));
        let hi = interp.run_traced::<FullProfile>(fuel).unwrap();
        let mut trans = ZeroRiscy::from_prepared(prepared);
        let ht = trans.run_translated::<FullProfile>(fuel).unwrap();
        assert_eq!(hi, ht);
        assert_eq!(interp.regs, trans.regs);
        assert_eq!(interp.pc, trans.pc);
        assert_eq!(interp.mem.ram, trans.mem.ram);
        assert_eq!(interp.profile.cycles, trans.profile.cycles);
        assert_eq!(interp.profile.instructions, trans.profile.instructions);
        assert_eq!(interp.profile.instr_counts(), trans.profile.instr_counts());
        assert_eq!(interp.profile.regs_used, trans.profile.regs_used);
        assert_eq!(interp.profile.max_pc, trans.profile.max_pc);
        assert_eq!(interp.profile.branches_taken, trans.profile.branches_taken);
        assert_eq!(interp.profile.loads, trans.profile.loads);
        assert_eq!(interp.profile.stores, trans.profile.stores);
        assert_eq!(interp.profile.max_ram_offset, trans.profile.max_ram_offset);
    }

    #[test]
    fn translated_matches_interpreted_loop_program() {
        let text = format!(
            r#"
                li   t0, 10
                li   t1, 0
                li   s2, {RAM_BASE}
            loop:
                add  t1, t1, t0
                sw   t1, 8(s2)
                lw   t2, 8(s2)
                addi t0, t0, -1
                bnez t0, loop
                mul  t3, t1, t2
                ebreak
            "#
        );
        assert_translated_matches(&text, 1_000_000);
        // Fuel expiring inside the loop body must leave identical state.
        for fuel in [1, 3, 7, 12, 23] {
            assert_translated_matches(&text, fuel);
        }
    }

    #[test]
    fn translated_runs_mac_and_reports_block_stats() {
        let mut a = Asm::new();
        a.li(8, RAM_BASE as i32);
        a.maccl();
        a.li(10, 3);
        a.li(11, 4);
        a.sw(10, 8, 0);
        a.sw(11, 8, 4);
        a.lw(5, 8, 0);
        a.lw(6, 8, 4);
        a.mac(5, 6);
        a.macrd(12, 0);
        a.ebreak();
        let prog = a.finish().unwrap();
        let prepared = Arc::new(PreparedRv32::new(&prog, &[], 64, Some(MacConfig::new(32, 32))));
        assert!(prepared.translated.stats.fused > 0);
        assert_eq!(prepared.translated.stats.untranslatable_blocks, 0);
        let mut sim = ZeroRiscy::from_prepared(prepared);
        assert_eq!(sim.run_translated::<FullProfile>(100).unwrap(), Halt::Break);
        assert_eq!(sim.regs[12], 12);
        assert_eq!(sim.profile.mac_ops, 1);
        assert!(sim.exec_stats.blocks > 0);
        assert_eq!(sim.exec_stats.fallback_instrs, 0);
    }

    #[test]
    fn translated_falls_back_for_mac_without_unit() {
        let prog = assemble("mac a0, a1\nebreak").unwrap();
        let prepared = Arc::new(PreparedRv32::new(&prog, &[], 64, None));
        let mut sim = ZeroRiscy::from_prepared(prepared);
        let err = sim.run_translated::<FullProfile>(10).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<ExecError>(),
                Some(ExecError::MacUnavailable { op: MacOp::Mac })
            ),
            "{err}"
        );
        assert!(sim.exec_stats.fallback_instrs > 0);
    }

    #[test]
    fn rom_data_section() {
        let mut a = Asm::new();
        a.lh(5, 0, 0); // lh x5, 0(x0) — but data base must be used
        a.ebreak();
        let prog = a.finish().unwrap();
        // 2 instructions = 8 bytes of code; data starts at 8.
        let mut sim = ZeroRiscy::new(&prog, &[0x34, 0x12], 64, None);
        assert_eq!(sim.data_base(), 8);
        // Patch the load to point at the data base.
        let mut a = Asm::new();
        a.lh(5, 0, sim.data_base() as i32);
        a.ebreak();
        let prog = a.finish().unwrap();
        sim = ZeroRiscy::new(&prog, &[0x34, 0x12], 64, None);
        sim.run(10).unwrap();
        assert_eq!(sim.regs[5], 0x1234);
    }
}
