//! Deterministic soft-error injection for the ISS cores.
//!
//! Printed EGFET circuits are defined by extreme device variation and
//! transient upsets; this module gives the fault-free simulators a
//! reproducible error model so resilience can be *measured* (the
//! `bespoke::resilience` campaigns, the serving-side dual-execution
//! guard).  Everything is seeded PCG: a trial index maps to a
//! [`FaultPlan`] through [`FaultPlan::generate`] with no other state,
//! so campaigns are bit-reproducible regardless of `PBSP_THREADS` or
//! execution order.
//!
//! Fault models:
//!
//! * **Transient bit flips** ([`BitFlip`]) in the register file or data
//!   RAM, scheduled on a retired-instruction clock.  The interpreter
//!   applies a due flip at the exact instruction; the translated and
//!   batched engines apply it at the enclosing block boundary (the
//!   clock ticks once per block) — deterministic *per engine*, and a
//!   per-lane plan rides the batched engine's SoA lanes so a
//!   thousand-trial Monte Carlo campaign costs one batched dispatch.
//! * **MAC-result upsets** ([`MacFlip`]): accumulator bit flips on a
//!   MAC-op clock, applied right after the accumulate on every engine
//!   path — the model for a transient fault inside the SIMD MAC unit.
//! * **Stuck-at ROM bits** ([`RomStuck`] + [`rv32_with_stuck_rom`] /
//!   [`tpisa_with_stuck_dmem`]): a permanently wrong weight/constant
//!   bit, realised by rebuilding the shared prepared image — one
//!   patched image serves a whole batched accuracy sweep, which is what
//!   makes the critical-bit ranking affordable.
//!
//! The contract pinned by `tests/fault_identity.rs`: an absent plan, an
//! armed *empty* plan and a zero-rate generated plan are all
//! bit-identical (scores, cycles, profiles) to the baseline engines.

use std::sync::Arc;

use crate::hw::mac_unit::MacConfig;
use crate::util::rng::Pcg32;

use super::prepared::{PreparedRv32, PreparedTpIsa};

/// Where a transient [`BitFlip`] lands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlipTarget {
    /// Register file entry (reduced modulo the machine's register
    /// count; RV32 x0 flips are masked like the real hardwired zero).
    Reg(u8),
    /// Data RAM cell — a byte offset on RV32, a word index on TP-ISA
    /// (reduced modulo the memory size).
    Ram(u32),
}

/// One transient state upset, due once the retired-instruction clock
/// passes `at_instr`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BitFlip {
    pub at_instr: u64,
    pub target: FlipTarget,
    /// Bit index, reduced modulo the target cell's width.
    pub bit: u8,
}

/// One MAC accumulator upset, due once the MAC-op clock passes
/// `at_mac_op`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MacFlip {
    pub at_mac_op: u64,
    /// Accumulator register (reduced modulo the configured lane count).
    pub lane: u8,
    /// Bit index, reduced modulo the accumulator width.
    pub bit: u8,
}

/// One permanently stuck ROM bit (a byte offset into the RV32 constant
/// data region, or a word index into the TP-ISA initial data memory).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RomStuck {
    pub offset: u32,
    pub bit: u8,
    pub stuck_one: bool,
}

/// A reproducible set of soft errors for one execution (one lane).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub flips: Vec<BitFlip>,
    pub mac_flips: Vec<MacFlip>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.flips.is_empty() && self.mac_flips.is_empty()
    }

    /// Derive the plan for Monte Carlo trial `trial` of a campaign.
    /// Pure function of `(spec.seed, trial)` — the PCG stream id is the
    /// trial index — so shard boundaries and thread counts cannot
    /// change any trial's faults.
    ///
    /// Event counts follow the expectation `rate × horizon`: the
    /// integer part always happens, the fractional part happens with
    /// matching probability.  Flip sites are uniform over the enabled
    /// target classes weighted by their state-bit counts.
    pub fn generate(spec: &FaultSpec, shape: &MachineShape, trial: u64) -> FaultPlan {
        let mut rng = Pcg32::new(spec.seed, trial);
        let mut plan = FaultPlan::default();

        let reg_bits = if spec.targets.regs { shape.regs as u64 * shape.reg_bits as u64 } else { 0 };
        let ram_bits =
            if spec.targets.ram { shape.ram_cells as u64 * shape.cell_bits as u64 } else { 0 };
        let state_bits = reg_bits + ram_bits;
        let n = sample_count(&mut rng, spec.rate * spec.horizon as f64);
        if state_bits > 0 && spec.horizon > 0 {
            for _ in 0..n {
                let at_instr = rng.below(spec.horizon);
                let site = rng.below(state_bits);
                let (target, bit) = if site < reg_bits {
                    let r = (site / shape.reg_bits as u64) as u8;
                    (FlipTarget::Reg(r), (site % shape.reg_bits as u64) as u8)
                } else {
                    let site = site - reg_bits;
                    let cell = (site / shape.cell_bits as u64) as u32;
                    (FlipTarget::Ram(cell), (site % shape.cell_bits as u64) as u8)
                };
                plan.flips.push(BitFlip { at_instr, target, bit });
            }
        }

        let mac_bits = shape.mac_lanes as u64 * shape.mac_bits as u64;
        let m = sample_count(&mut rng, spec.mac_rate * spec.mac_horizon as f64);
        if spec.targets.mac && mac_bits > 0 && spec.mac_horizon > 0 {
            for _ in 0..m {
                let at_mac_op = rng.below(spec.mac_horizon);
                let site = rng.below(mac_bits);
                plan.mac_flips.push(MacFlip {
                    at_mac_op,
                    lane: (site / shape.mac_bits as u64) as u8,
                    bit: (site % shape.mac_bits as u64) as u8,
                });
            }
        }
        plan
    }
}

/// Expectation-preserving integer event count: `floor(expected)` plus a
/// Bernoulli draw on the fractional part.  Always consumes exactly one
/// uniform so the downstream draw sequence is independent of the rate.
fn sample_count(rng: &mut Pcg32, expected: f64) -> u64 {
    let expected = expected.max(0.0);
    let base = expected.floor();
    let extra = (rng.f64() < (expected - base)) as u64;
    base as u64 + extra
}

/// Which state classes a generated plan may hit — restricting to one
/// class is how the campaign measures per-class architectural
/// vulnerability (AVF).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Targets {
    pub regs: bool,
    pub ram: bool,
    pub mac: bool,
}

impl Targets {
    pub const ALL: Targets = Targets { regs: true, ram: true, mac: true };
    pub const REGS: Targets = Targets { regs: true, ram: false, mac: false };
    pub const RAM: Targets = Targets { regs: false, ram: true, mac: false };
    pub const MAC: Targets = Targets { regs: false, ram: false, mac: true };
}

/// Campaign-level fault description; one spec plus a trial index fully
/// determines a [`FaultPlan`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    pub seed: u64,
    /// Expected transient register/RAM flips per retired instruction.
    pub rate: f64,
    /// Retired-instruction horizon the flips are spread over (a
    /// per-sample instruction count from a baseline run).
    pub horizon: u64,
    /// Expected accumulator flips per MAC accumulate op.
    pub mac_rate: f64,
    pub mac_horizon: u64,
    pub targets: Targets,
}

/// State-space geometry of one simulated machine — the denominator of
/// the uniform fault-site distribution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MachineShape {
    pub regs: u32,
    pub reg_bits: u32,
    pub ram_cells: u32,
    /// Bits per RAM cell (8 on RV32's byte RAM, the datapath width on
    /// TP-ISA's word memory).
    pub cell_bits: u32,
    pub mac_lanes: u32,
    pub mac_bits: u32,
}

impl MachineShape {
    pub fn rv32(ram_bytes: usize, mac: Option<MacConfig>) -> MachineShape {
        let (mac_lanes, mac_bits) = mac_geometry(mac);
        MachineShape {
            regs: 32,
            reg_bits: 32,
            ram_cells: ram_bytes as u32,
            cell_bits: 8,
            mac_lanes,
            mac_bits,
        }
    }

    pub fn tpisa(width: u32, dmem_words: usize, mac: Option<MacConfig>) -> MachineShape {
        let (mac_lanes, mac_bits) = mac_geometry(mac);
        MachineShape {
            regs: 8,
            reg_bits: width,
            ram_cells: dmem_words as u32,
            cell_bits: width,
            mac_lanes,
            mac_bits,
        }
    }
}

/// Accumulator geometry matching `MacState`: one 64-bit register for
/// p = 32, one 32-bit register per lane otherwise.
fn mac_geometry(mac: Option<MacConfig>) -> (u32, u32) {
    match mac {
        None => (0, 0),
        Some(cfg) if cfg.precision >= 32 => (1, 64),
        Some(cfg) => (cfg.lanes(), 32),
    }
}

/// A [`FaultPlan`] armed on one engine instance: events sorted by due
/// time with cursors over the instruction and MAC-op clocks.  The
/// engines hold it as `Option<Box<FaultState>>` so the fault-free fast
/// path pays one null check.
#[derive(Debug, Clone)]
pub struct FaultState {
    flips: Vec<BitFlip>,
    mac_flips: Vec<MacFlip>,
    instr_clock: u64,
    next_flip: usize,
    mac_clock: u64,
    next_mac: usize,
}

impl FaultState {
    pub fn new(plan: FaultPlan) -> Box<FaultState> {
        let FaultPlan { mut flips, mut mac_flips } = plan;
        flips.sort_by_key(|f| f.at_instr);
        mac_flips.sort_by_key(|f| f.at_mac_op);
        Box::new(FaultState {
            flips,
            mac_flips,
            instr_clock: 0,
            next_flip: 0,
            mac_clock: 0,
            next_mac: 0,
        })
    }

    /// `Some(armed)` for a non-empty plan, `None` otherwise — the form
    /// engine callers assign to their `fault` slot.
    pub fn armed(plan: FaultPlan) -> Option<Box<FaultState>> {
        if plan.is_empty() {
            None
        } else {
            Some(FaultState::new(plan))
        }
    }

    /// Reset both clocks so every event fires again — the engines'
    /// `reset()` calls this, keeping plan lifetime aligned with sample
    /// lifetime across batch lane reuse.
    pub fn rearm(&mut self) {
        self.instr_clock = 0;
        self.next_flip = 0;
        self.mac_clock = 0;
        self.next_mac = 0;
    }

    /// Advance the instruction clock by `retired` and return the flips
    /// that just became due (each fires exactly once per arming).
    pub fn advance(&mut self, retired: u64) -> &[BitFlip] {
        self.instr_clock += retired;
        let start = self.next_flip;
        while self.next_flip < self.flips.len()
            && self.flips[self.next_flip].at_instr < self.instr_clock
        {
            self.next_flip += 1;
        }
        &self.flips[start..self.next_flip]
    }

    /// Advance the MAC-op clock by `ops` accumulates and return the
    /// newly due accumulator flips.
    pub fn advance_mac(&mut self, ops: u64) -> &[MacFlip] {
        self.mac_clock += ops;
        let start = self.next_mac;
        while self.next_mac < self.mac_flips.len()
            && self.mac_flips[self.next_mac].at_mac_op < self.mac_clock
        {
            self.next_mac += 1;
        }
        &self.mac_flips[start..self.next_mac]
    }
}

/// Rebuild an RV32 prepared image with one constant-data ROM bit stuck.
/// Only the data region is a target: execution fetches pre-decoded
/// instructions, so a code-byte fault would be invisible — the weights
/// and packed constants are the bits the paper's ROM actually spends
/// area on.  `offset` is reduced modulo the data region size.
pub fn rv32_with_stuck_rom(p: &PreparedRv32, s: RomStuck) -> Arc<PreparedRv32> {
    let base = p.data_base() as usize;
    let mut data: Vec<u8> = p.rom[base..].to_vec();
    if data.is_empty() {
        return Arc::new(p.clone());
    }
    let idx = (s.offset as usize) % data.len();
    let m = 1u8 << (s.bit % 8);
    if s.stuck_one {
        data[idx] |= m;
    } else {
        data[idx] &= !m;
    }
    Arc::new(PreparedRv32::new(&p.code, &data, p.ram_bytes, p.mac))
}

/// Rebuild a TP-ISA prepared image with one initial data-memory bit
/// stuck (`offset` is a word index, reduced modulo the memory size; the
/// bit is reduced modulo the datapath width).
pub fn tpisa_with_stuck_dmem(p: &PreparedTpIsa, s: RomStuck) -> Arc<PreparedTpIsa> {
    let mut dmem = p.init_dmem.clone();
    if dmem.is_empty() {
        return Arc::new(p.clone());
    }
    let idx = (s.offset as usize) % dmem.len();
    let m = 1u64 << (s.bit as u32 % p.width);
    if s.stuck_one {
        dmem[idx] |= m;
    } else {
        dmem[idx] &= !m;
    }
    Arc::new(PreparedTpIsa::new(p.width, &p.code, dmem, p.mac))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(seed: u64, rate: f64) -> FaultSpec {
        FaultSpec {
            seed,
            rate,
            horizon: 10_000,
            mac_rate: rate,
            mac_horizon: 500,
            targets: Targets::ALL,
        }
    }

    fn shape() -> MachineShape {
        MachineShape::rv32(1024, Some(MacConfig::new(32, 8)))
    }

    #[test]
    fn generation_is_a_pure_function_of_seed_and_trial() {
        let s = spec(42, 1e-3);
        let a = FaultPlan::generate(&s, &shape(), 7);
        let b = FaultPlan::generate(&s, &shape(), 7);
        assert_eq!(a, b);
        // Different trial or seed: (overwhelmingly) different plan.
        assert_ne!(a, FaultPlan::generate(&s, &shape(), 8));
        assert_ne!(a, FaultPlan::generate(&spec(43, 1e-3), &shape(), 7));
    }

    #[test]
    fn zero_rate_generates_empty_plans() {
        let s = spec(1, 0.0);
        for trial in 0..64 {
            assert!(FaultPlan::generate(&s, &shape(), trial).is_empty());
        }
    }

    #[test]
    fn event_count_tracks_expectation() {
        // rate 2e-3 over a 10k-instruction horizon => 20 expected flips.
        let s = spec(9, 2e-3);
        let total: usize =
            (0..200).map(|t| FaultPlan::generate(&s, &shape(), t).flips.len()).sum();
        let mean = total as f64 / 200.0;
        assert!((mean - 20.0).abs() < 1.0, "mean flips {mean}");
    }

    #[test]
    fn class_restriction_is_respected() {
        let mut s = spec(5, 1e-3);
        s.targets = Targets::REGS;
        for trial in 0..32 {
            let plan = FaultPlan::generate(&s, &shape(), trial);
            assert!(plan.flips.iter().all(|f| matches!(f.target, FlipTarget::Reg(_))));
            assert!(plan.mac_flips.is_empty());
        }
        s.targets = Targets::MAC;
        for trial in 0..32 {
            let plan = FaultPlan::generate(&s, &shape(), trial);
            assert!(plan.flips.is_empty());
        }
    }

    #[test]
    fn fault_state_fires_each_event_once_and_rearms() {
        let plan = FaultPlan {
            flips: vec![
                BitFlip { at_instr: 5, target: FlipTarget::Reg(3), bit: 0 },
                BitFlip { at_instr: 1, target: FlipTarget::Ram(7), bit: 2 },
            ],
            mac_flips: vec![MacFlip { at_mac_op: 0, lane: 0, bit: 4 }],
        };
        let mut st = FaultState::new(plan);
        // Events sorted by due time; block-sized advances batch them.
        assert_eq!(st.advance(2).len(), 1); // the at_instr=1 flip
        assert_eq!(st.advance(10).len(), 1); // the at_instr=5 flip
        assert_eq!(st.advance(100).len(), 0); // nothing left
        assert_eq!(st.advance_mac(1).len(), 1);
        assert_eq!(st.advance_mac(1).len(), 0);
        st.rearm();
        assert_eq!(st.advance(100).len(), 2);
        assert_eq!(st.advance_mac(5).len(), 1);
    }

    #[test]
    fn armed_empty_plan_is_none() {
        assert!(FaultState::armed(FaultPlan::default()).is_none());
        let plan =
            FaultPlan { flips: vec![], mac_flips: vec![MacFlip { at_mac_op: 0, lane: 0, bit: 0 }] };
        assert!(FaultState::armed(plan).is_some());
    }

    #[test]
    fn rv32_stuck_rom_patches_one_data_bit() {
        use crate::isa::rv32_asm::assemble;
        let code = assemble("ebreak").unwrap();
        let p = PreparedRv32::new(&code, &[0x00, 0xff], 64, None);
        let stuck = rv32_with_stuck_rom(&p, RomStuck { offset: 0, bit: 3, stuck_one: true });
        let base = p.data_base() as usize;
        assert_eq!(stuck.rom[base], 0x08);
        assert_eq!(stuck.rom[base + 1], 0xff);
        // Code bytes untouched; idempotent on an already-set bit.
        assert_eq!(stuck.rom[..base], p.rom[..base]);
        let again = rv32_with_stuck_rom(&stuck, RomStuck { offset: 0, bit: 3, stuck_one: true });
        assert_eq!(again.rom, stuck.rom);
    }

    #[test]
    fn tpisa_stuck_dmem_masks_to_width() {
        use crate::isa::tpisa;
        let p = PreparedTpIsa::new(8, &[tpisa::Instr::Halt], vec![0x0f, 0xf0], None);
        // bit 11 reduces mod width 8 -> bit 3.
        let stuck = tpisa_with_stuck_dmem(&p, RomStuck { offset: 1, bit: 11, stuck_one: false });
        assert_eq!(stuck.init_dmem, vec![0x0f, 0xf0 & !(1 << 3)]);
    }
}
