//! Basic-block pre-translation of prepared program images (§Perf
//! iteration 4).
//!
//! The per-instruction `run_traced` loops pay a fetch bounds check, a
//! full `match` dispatch, cycle/instruction accounting and a PC update
//! for **every retired instruction**, even though `ml::codegen_*` emit
//! a small, known set of straight-line idioms (the paper's §III-B SIMD
//! MAC inner loops, `lw/lw/mac` dot-product steps, TP-ISA soft-multiply
//! shift-add kernels).  This module discovers the basic blocks of a
//! pre-decoded program **once**, at `Prepared{Rv32,TpIsa}` build time,
//! and lowers each into a [`BlockRv32`] / [`BlockTpIsa`]: a
//! straight-line micro-op sequence with *block-level aggregated
//! bookkeeping* — one fuel check, one cycle/instruction add and one
//! per-mnemonic histogram delta per block instead of per instruction —
//! plus peephole-fused superinstructions for the codegen hot idioms.
//!
//! Fusion rules (all purely syntactic; fused execution performs the
//! same architectural steps in the same order, so register aliasing,
//! flags and faults behave identically):
//!
//! * RV32 — `load/load/mac` (the SIMD and MAC32 dot-product step, both
//!   looped and unrolled), `lh/lh/mul/add` (the baseline inner
//!   product), and runs of 2–3 register-only ALU ops (`addi` pointer
//!   stride bumps, `li` pairs, `slli/or` nibble packing).
//! * TP-ISA — `ld/ld/mac` (the MAC kernels, looped and the unrolled
//!   4-bit body), `ld/<alu>/st` read-modify-write on one memory word
//!   (the soft-multiply accumulate and the epilogue shift loops), and
//!   runs of 2–3 register-only ALU ops (the shift-add kernel's
//!   `shl/slc` + `add/adc` pairs, `ldc` constant chains).
//!
//! Bit-identity argument (pinned differentially by
//! `tests/iss_equivalence.rs`):
//!
//! * blocks contain no internal control transfers, and every *static*
//!   branch/jump target is a block leader, so a block either runs to
//!   its terminator or the whole run aborts with the same error;
//! * all per-retire bookkeeping is a sum (cycles, instructions, event
//!   counters, histogram deltas) or a monotone join (`regs_used` OR
//!   mask, `max_pc` max) over the block's instructions, so applying it
//!   once per block yields the same totals;
//! * data-dependent observables (RAM/dmem contents, `max_ram_offset`,
//!   flags, MAC accumulators, taken-branch cycles) stay in the
//!   executed micro-ops;
//! * anything the translator cannot prove static — a dynamic `jalr`
//!   landing mid-block, a misaligned PC from a half-word-aligned RV32
//!   branch, a `mac` on a core without a MAC unit, or fuel expiring
//!   inside a block — falls back to the per-instruction interpreter
//!   (`step_traced`), which *is* the reference loop.
//!
//! Contract on **errors**: a fault aborts the whole run with the same
//! `Err` (same message, same fault address/PC in it) in both engines,
//! and registers/memory match because the micro-ops perform the same
//! architectural steps in the same order — but the *profile aggregates*
//! and the simulator's `pc` field are unspecified after an `Err`
//! (block bookkeeping applies only when a block completes).  Every
//! consumer propagates `Err` and discards the simulator, so only the
//! successful-run observables are pinned bit-identical.
//!
//! Maintenance invariant: the micro-op executors (`exec_*` in
//! `sim::zero_riscy` / `sim::tpisa`) restate each instruction's data
//! semantics without the per-retire bookkeeping.  Any semantic change
//! to an interpreter arm MUST be mirrored there — the differential
//! fuzz in `tests/iss_equivalence.rs` is the tripwire.  The batched
//! lockstep engine (`sim::batch`) is a third consumer of the same
//! blocks — it dispatches one block across N lanes via the shared
//! `exec_uop`/`apply_block`/`apply_term` primitives, so it inherits any
//! fix to them automatically; `tests/iss_batch_equivalence.rs` is its
//! tripwire.
//!
//! The translated image lives inside [`super::prepared::PreparedRv32`]
//! / [`super::prepared::PreparedTpIsa`], so it is built once per
//! (model, variant) and `Arc`-shared through the `dse::context` program
//! cache exactly like the ROM image.

use std::collections::BTreeMap;

use crate::isa::rv32::{self, AluOp, BranchOp, LoadOp, MulOp, StoreOp};
use crate::isa::tpisa;
use crate::isa::MacOp;

/// Sentinel in the leader tables: this instruction index does not start
/// a translated block (mid-block, or its block is untranslatable).
pub const NO_BLOCK: u32 = u32::MAX;

/// Static translation statistics of one prepared image (the bench's
/// block-cache numbers).
#[derive(Debug, Clone, Default)]
pub struct TranslateStats {
    /// Instructions in the program image.
    pub instructions: usize,
    /// Translated basic blocks.
    pub blocks: usize,
    /// Instructions covered by translated blocks.
    pub translated_instructions: usize,
    /// Fused superinstructions emitted across all blocks.
    pub fused: usize,
    /// Blocks left untranslated (e.g. `mac` on a core without a MAC
    /// unit) — executed by the per-instruction fallback.
    pub untranslatable_blocks: usize,
}

/// Runtime counters of the translated engine.  Kept on the simulator —
/// deliberately *not* in [`super::trace::Profile`], so translated and
/// interpreted profiles stay bit-comparable.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecStats {
    /// Translated blocks dispatched.
    pub blocks: u64,
    /// Fused superinstructions executed (one per fused uop per block
    /// dispatch) — the dynamic counterpart of [`TranslateStats::fused`].
    pub fused_uops: u64,
    /// Instructions retired through the per-instruction fallback
    /// (mid-block entries, untranslatable blocks, fuel tails).
    pub fallback_instrs: u64,
}

impl ExecStats {
    /// Fold another run's counters in (shard merges in `ml::harness`).
    pub fn merge(&mut self, other: &ExecStats) {
        self.blocks += other.blocks;
        self.fused_uops += other.fused_uops;
        self.fallback_instrs += other.fallback_instrs;
    }
}

// ---------------------------------------------------------------------------
// RV32
// ---------------------------------------------------------------------------

/// A register-only RV32 micro-op (no memory access, no `Result`).
#[derive(Debug, Clone, Copy)]
pub enum SimpleRv32 {
    /// `lui` / `auipc` (PC folded in at translation time) / CSR reads
    /// (always 0 in our minimal CSR file).
    SetReg { rd: u8, v: u32 },
    OpImm { op: AluOp, rd: u8, rs1: u8, imm: i32 },
    Op { op: AluOp, rd: u8, rs1: u8, rs2: u8 },
}

/// A decoded load, reused by the fused dot-product micro-ops.
#[derive(Debug, Clone, Copy)]
pub struct LoadRv32 {
    pub op: LoadOp,
    pub rd: u8,
    pub rs1: u8,
    pub offset: i32,
}

/// One straight-line micro-op of a translated RV32 block.
#[derive(Debug, Clone)]
pub enum UopRv32 {
    Simple(SimpleRv32),
    /// Fused pair/triple of register-only ops.
    Alu2(SimpleRv32, SimpleRv32),
    Alu3(SimpleRv32, SimpleRv32, SimpleRv32),
    Load(LoadRv32),
    Store { op: StoreOp, rs2: u8, rs1: u8, offset: i32 },
    MulDiv { op: MulOp, rd: u8, rs1: u8, rs2: u8 },
    Mac { op: MacOp, rd: u8, rs1: u8, rs2: u8 },
    /// Fused dot-product step: two loads feeding a `mac rs1, rs2`
    /// (the SIMD `lw/lw/mac` and MAC32 `lh/lh/mac` inner loops).
    Load2Mac { a: LoadRv32, b: LoadRv32, rs1: u8, rs2: u8 },
    /// Fused baseline inner-product step: `lh/lh/mul/add`.
    /// Tuples are `(rd, rs1, rs2)`.
    Load2MulAdd { a: LoadRv32, b: LoadRv32, mul: (u8, u8, u8), add: (u8, u8, u8) },
}

/// How a translated RV32 block ends.
#[derive(Debug, Clone, Copy)]
pub enum TermRv32 {
    /// Straight-line fall-through into the next leader.
    FallThrough,
    Jal { rd: u8, target: u32, link: u32 },
    Jalr { rd: u8, rs1: u8, offset: i32, link: u32 },
    /// Conditional branch; not-taken falls through to the block's
    /// `next_pc`.
    Branch { op: BranchOp, rs1: u8, rs2: u8, target: u32 },
    Ebreak,
    Ecall,
}

/// One translated RV32 basic block: micro-ops plus the block-level
/// aggregated bookkeeping the run loop applies once per execution.
#[derive(Debug, Clone)]
pub struct BlockRv32 {
    /// Instructions the block retires per execution.
    pub n_instrs: u32,
    /// Cycle cost excluding the conditional-branch taken penalty
    /// (unconditional jumps' +2 is included).
    pub base_cycles: u64,
    pub loads: u64,
    pub stores: u64,
    pub mul_ops: u64,
    pub mac_ops: u64,
    /// Unconditional taken transfers (`jal`/`jalr`) per execution.
    pub branches_taken: u64,
    pub csr_used: bool,
    /// OR of every `record_reg` the interpreter would make.
    pub reg_mask: u32,
    /// Byte PC of the last (highest) instruction in the block.
    pub last_pc: u32,
    /// Byte PC after the block (fall-through / branch-not-taken).
    pub next_pc: u32,
    /// Histogram delta: (mnemonic id, mnemonic, count).
    pub counts: Box<[(u16, &'static str, u32)]>,
    /// Fused superinstructions in the body (the per-dispatch
    /// [`ExecStats::fused_uops`] delta).
    pub fused: u32,
    /// Straight-line body, terminator excluded.
    pub uops: Box<[UopRv32]>,
    pub term: TermRv32,
}

/// Block cache of one prepared RV32 program.
#[derive(Debug, Clone)]
pub struct TranslatedRv32 {
    pub blocks: Vec<BlockRv32>,
    /// Instruction index → block id (`NO_BLOCK` when not a leader).
    pub leaders: Box<[u32]>,
    pub stats: TranslateStats,
}

fn rv32_is_control(i: &rv32::Instr) -> bool {
    matches!(
        i,
        rv32::Instr::Jal { .. }
            | rv32::Instr::Jalr { .. }
            | rv32::Instr::Branch { .. }
            | rv32::Instr::Ecall
            | rv32::Instr::Ebreak
    )
}

/// Fixed cycle cost of one instruction, excluding the conditional
/// branch-taken penalty (mirrors `ZeroRiscy::run_traced`).
fn rv32_base_cost(i: &rv32::Instr) -> u64 {
    match i {
        rv32::Instr::Load { .. } | rv32::Instr::Store { .. } => 2,
        rv32::Instr::Jal { .. } | rv32::Instr::Jalr { .. } => 3,
        rv32::Instr::MulDiv { op, .. } => match op {
            MulOp::Mul | MulOp::Mulh | MulOp::Mulhsu | MulOp::Mulhu => 3,
            _ => 37,
        },
        _ => 1,
    }
}

/// OR-mask of every `record_reg` call the interpreter makes for one
/// instruction (`FullProfile` mode).
fn rv32_reg_mask(i: &rv32::Instr) -> u32 {
    let b = |r: u8| 1u32 << r;
    match *i {
        rv32::Instr::Lui { rd, .. } | rv32::Instr::Auipc { rd, .. } => b(rd),
        rv32::Instr::Jal { rd, .. } => b(rd),
        rv32::Instr::Jalr { rd, rs1, .. } => b(rd) | b(rs1),
        rv32::Instr::Branch { rs1, rs2, .. } => b(rs1) | b(rs2),
        rv32::Instr::Load { rd, rs1, .. } => b(rd) | b(rs1),
        rv32::Instr::Store { rs2, rs1, .. } => b(rs1) | b(rs2),
        rv32::Instr::OpImm { rd, rs1, .. } => b(rd) | b(rs1),
        rv32::Instr::Op { rd, rs1, rs2, .. } | rv32::Instr::MulDiv { rd, rs1, rs2, .. } => {
            b(rd) | b(rs1) | b(rs2)
        }
        rv32::Instr::Csr { rd, rs1, .. } => b(rd) | b(rs1),
        rv32::Instr::Ecall | rv32::Instr::Ebreak | rv32::Instr::Fence => 0,
        rv32::Instr::Mac { op, rd, rs1, rs2 } => match op {
            MacOp::Mac => b(rs1) | b(rs2),
            MacOp::MacRd => b(rd),
            MacOp::MacClr => 0,
        },
    }
}

/// Lower a straight-line body to micro-ops, fusing the codegen idioms.
fn lower_rv32_body(body: &[rv32::Instr], base_pc: u32, stats: &mut TranslateStats) -> Vec<UopRv32> {
    // Pass 1: fuse the memory-anchored idioms, lower the rest 1:1.
    let mut uops: Vec<UopRv32> = Vec::with_capacity(body.len());
    let mut k = 0usize;
    while k < body.len() {
        // lw/lw/mac (SIMD + MAC32 dot-product step).
        if k + 2 < body.len() {
            if let (
                rv32::Instr::Load { op: opa, rd: rda, rs1: ra, offset: offa },
                rv32::Instr::Load { op: opb, rd: rdb, rs1: rb, offset: offb },
                rv32::Instr::Mac { op: MacOp::Mac, rs1, rs2, .. },
            ) = (body[k], body[k + 1], body[k + 2])
            {
                uops.push(UopRv32::Load2Mac {
                    a: LoadRv32 { op: opa, rd: rda, rs1: ra, offset: offa },
                    b: LoadRv32 { op: opb, rd: rdb, rs1: rb, offset: offb },
                    rs1,
                    rs2,
                });
                stats.fused += 1;
                k += 3;
                continue;
            }
        }
        // lh/lh/mul/add (baseline inner-product step).
        if k + 3 < body.len() {
            if let (
                rv32::Instr::Load { op: opa, rd: rda, rs1: ra, offset: offa },
                rv32::Instr::Load { op: opb, rd: rdb, rs1: rb, offset: offb },
                rv32::Instr::MulDiv { op: MulOp::Mul, rd: mrd, rs1: mr1, rs2: mr2 },
                rv32::Instr::Op { op: AluOp::Add, rd: ard, rs1: ar1, rs2: ar2 },
            ) = (body[k], body[k + 1], body[k + 2], body[k + 3])
            {
                uops.push(UopRv32::Load2MulAdd {
                    a: LoadRv32 { op: opa, rd: rda, rs1: ra, offset: offa },
                    b: LoadRv32 { op: opb, rd: rdb, rs1: rb, offset: offb },
                    mul: (mrd, mr1, mr2),
                    add: (ard, ar1, ar2),
                });
                stats.fused += 1;
                k += 4;
                continue;
            }
        }
        let pc = base_pc.wrapping_add((k as u32) * 4);
        match body[k] {
            rv32::Instr::Lui { rd, imm } => {
                uops.push(UopRv32::Simple(SimpleRv32::SetReg { rd, v: imm as u32 }));
            }
            rv32::Instr::Auipc { rd, imm } => {
                // PC is static inside a block: fold it in now.
                uops.push(UopRv32::Simple(SimpleRv32::SetReg {
                    rd,
                    v: pc.wrapping_add(imm as u32),
                }));
            }
            rv32::Instr::Csr { rd, .. } => {
                // Minimal CSR file: reads return 0 (csr_used is part of
                // the block's aggregated bookkeeping).
                uops.push(UopRv32::Simple(SimpleRv32::SetReg { rd, v: 0 }));
            }
            rv32::Instr::Fence => {} // no architectural effect
            rv32::Instr::OpImm { op, rd, rs1, imm } => {
                uops.push(UopRv32::Simple(SimpleRv32::OpImm { op, rd, rs1, imm }));
            }
            rv32::Instr::Op { op, rd, rs1, rs2 } => {
                uops.push(UopRv32::Simple(SimpleRv32::Op { op, rd, rs1, rs2 }));
            }
            rv32::Instr::Load { op, rd, rs1, offset } => {
                uops.push(UopRv32::Load(LoadRv32 { op, rd, rs1, offset }));
            }
            rv32::Instr::Store { op, rs2, rs1, offset } => {
                uops.push(UopRv32::Store { op, rs2, rs1, offset });
            }
            rv32::Instr::MulDiv { op, rd, rs1, rs2 } => {
                uops.push(UopRv32::MulDiv { op, rd, rs1, rs2 });
            }
            rv32::Instr::Mac { op, rd, rs1, rs2 } => {
                uops.push(UopRv32::Mac { op, rd, rs1, rs2 });
            }
            rv32::Instr::Jal { .. }
            | rv32::Instr::Jalr { .. }
            | rv32::Instr::Branch { .. }
            | rv32::Instr::Ecall
            | rv32::Instr::Ebreak => {
                unreachable!("control transfer in block body")
            }
        }
        k += 1;
    }
    // Pass 2: coalesce adjacent register-only ops into pairs/triples
    // (addi stride bumps, li chains, slli/or packing).
    let mut out: Vec<UopRv32> = Vec::with_capacity(uops.len());
    let mut k = 0usize;
    while k < uops.len() {
        let simple = |u: &UopRv32| -> Option<SimpleRv32> {
            match u {
                UopRv32::Simple(s) => Some(*s),
                _ => None,
            }
        };
        if let Some(a) = simple(&uops[k]) {
            if k + 2 < uops.len() {
                if let (Some(b), Some(c)) = (simple(&uops[k + 1]), simple(&uops[k + 2])) {
                    out.push(UopRv32::Alu3(a, b, c));
                    stats.fused += 1;
                    k += 3;
                    continue;
                }
            }
            if k + 1 < uops.len() {
                if let Some(b) = simple(&uops[k + 1]) {
                    out.push(UopRv32::Alu2(a, b));
                    stats.fused += 1;
                    k += 2;
                    continue;
                }
            }
        }
        out.push(uops[k].clone());
        k += 1;
    }
    out
}

/// Discover basic blocks in a pre-decoded RV32 program and translate
/// each.  `has_mac` gates translation of MAC-bearing blocks: without a
/// MAC unit they stay on the interpreter so the "MAC instruction on a
/// core without a MAC unit" error surfaces at exactly the same retire.
pub fn translate_rv32(code: &[rv32::Instr], has_mac: bool) -> TranslatedRv32 {
    let n = code.len();
    let mut is_leader = vec![false; n];
    if n > 0 {
        is_leader[0] = true;
    }
    // Wrapped-u32 target arithmetic, exactly like the interpreter's
    // `pc.wrapping_add(offset)`; only aligned, in-range targets become
    // leaders (misaligned PCs single-step at runtime).
    let mark = |is_leader: &mut Vec<bool>, pc: u32, offset: i32| {
        let target = pc.wrapping_add(offset as u32);
        if target % 4 == 0 {
            let idx = (target / 4) as usize;
            if idx < n {
                is_leader[idx] = true;
            }
        }
    };
    for (i, ins) in code.iter().enumerate() {
        let pc = (i as u32) * 4;
        match *ins {
            rv32::Instr::Jal { offset, .. } => mark(&mut is_leader, pc, offset),
            rv32::Instr::Branch { offset, .. } => mark(&mut is_leader, pc, offset),
            _ => {}
        }
        if rv32_is_control(ins) && i + 1 < n {
            is_leader[i + 1] = true;
        }
    }

    let mut blocks = Vec::new();
    let mut leaders = vec![NO_BLOCK; n];
    let mut stats = TranslateStats { instructions: n, ..TranslateStats::default() };
    let mut i = 0usize;
    while i < n {
        debug_assert!(is_leader[i]);
        // The block spans [i ..= j]; `terminated` iff code[j] is a
        // control transfer (else it falls through into the next leader).
        let mut j = i;
        let terminated = loop {
            if rv32_is_control(&code[j]) {
                break true;
            }
            if j + 1 >= n || is_leader[j + 1] {
                break false;
            }
            j += 1;
        };
        let instrs = &code[i..=j];
        if !has_mac && instrs.iter().any(|x| matches!(x, rv32::Instr::Mac { .. })) {
            stats.untranslatable_blocks += 1;
            i = j + 1;
            continue;
        }

        let mut counts: BTreeMap<u16, (&'static str, u32)> = BTreeMap::new();
        let mut block = BlockRv32 {
            n_instrs: instrs.len() as u32,
            base_cycles: 0,
            loads: 0,
            stores: 0,
            mul_ops: 0,
            mac_ops: 0,
            branches_taken: 0,
            csr_used: false,
            reg_mask: 0,
            last_pc: (j as u32) * 4,
            next_pc: ((j as u32) * 4).wrapping_add(4),
            counts: Box::new([]),
            fused: 0,
            uops: Box::new([]),
            term: TermRv32::FallThrough,
        };
        for ins in instrs {
            block.base_cycles += rv32_base_cost(ins);
            block.reg_mask |= rv32_reg_mask(ins);
            match ins {
                rv32::Instr::Load { .. } => block.loads += 1,
                rv32::Instr::Store { .. } => block.stores += 1,
                rv32::Instr::MulDiv { op, .. } => {
                    if matches!(op, MulOp::Mul | MulOp::Mulh | MulOp::Mulhsu | MulOp::Mulhu) {
                        block.mul_ops += 1;
                    }
                }
                rv32::Instr::Mac { op: MacOp::Mac, .. } => block.mac_ops += 1,
                rv32::Instr::Csr { .. } => block.csr_used = true,
                rv32::Instr::Jal { .. } | rv32::Instr::Jalr { .. } => block.branches_taken += 1,
                _ => {}
            }
            let e = counts.entry(ins.mnemonic_id() as u16).or_insert((ins.mnemonic(), 0));
            e.1 += 1;
        }
        block.counts =
            counts.into_iter().map(|(id, (name, c))| (id, name, c)).collect::<Vec<_>>().into();

        let term_pc = (j as u32) * 4;
        let body = if terminated { &code[i..j] } else { instrs };
        let fused_before = stats.fused;
        block.uops = lower_rv32_body(body, (i as u32) * 4, &mut stats).into();
        block.fused = (stats.fused - fused_before) as u32;
        block.term = if terminated {
            match code[j] {
                rv32::Instr::Jal { rd, offset } => TermRv32::Jal {
                    rd,
                    target: term_pc.wrapping_add(offset as u32),
                    link: block.next_pc,
                },
                rv32::Instr::Jalr { rd, rs1, offset } => {
                    TermRv32::Jalr { rd, rs1, offset, link: block.next_pc }
                }
                rv32::Instr::Branch { op, rs1, rs2, offset } => TermRv32::Branch {
                    op,
                    rs1,
                    rs2,
                    target: term_pc.wrapping_add(offset as u32),
                },
                rv32::Instr::Ebreak => TermRv32::Ebreak,
                rv32::Instr::Ecall => TermRv32::Ecall,
                _ => unreachable!("non-control terminator"),
            }
        } else {
            TermRv32::FallThrough
        };

        leaders[i] = blocks.len() as u32;
        stats.blocks += 1;
        stats.translated_instructions += instrs.len();
        blocks.push(block);
        i = j + 1;
    }
    TranslatedRv32 { blocks, leaders: leaders.into(), stats }
}

// ---------------------------------------------------------------------------
// TP-ISA
// ---------------------------------------------------------------------------

/// One straight-line micro-op of a translated TP-ISA block.  Register
/// -only instructions ride along verbatim (`Data*`) — their executor is
/// the flag-exact single-instruction ALU.
#[derive(Debug, Clone)]
pub enum UopTpIsa {
    Data(tpisa::Instr),
    /// Fused pair/triple of register-only ops (shift-add kernel
    /// `shl/slc`, `add/adc`, `ldc` chains).
    Data2(tpisa::Instr, tpisa::Instr),
    Data3(tpisa::Instr, tpisa::Instr, tpisa::Instr),
    Ld { r1: u8, r2: u8, imm: i8 },
    St { r1: u8, r2: u8, imm: i8 },
    Mac { op: MacOp, r1: u8, r2: u8 },
    /// Fused `ld/ld/mac` dot-product step (looped and unrolled MAC
    /// bodies).  Load tuples are `(r1, r2, imm)`.
    Ld2Mac { a: (u8, u8, i8), b: (u8, u8, i8), r1: u8, r2: u8 },
    /// Fused read-modify-write of one memory word:
    /// `ld r1,(r2)imm; <alu on r1>; st r1,(r2)imm` — the soft-multiply
    /// accumulate and epilogue shift-loop idiom.
    LdOpSt { r1: u8, r2: u8, imm: i8, op: tpisa::Instr },
}

#[derive(Debug, Clone, Copy)]
pub enum CondTp {
    Z,
    Nz,
    C,
    Nc,
}

/// How a translated TP-ISA block ends.
#[derive(Debug, Clone, Copy)]
pub enum TermTpIsa {
    FallThrough,
    Jmp { target: i64 },
    Branch { cond: CondTp, target: i64 },
    Halt,
}

/// One translated TP-ISA basic block.
#[derive(Debug, Clone)]
pub struct BlockTpIsa {
    pub n_instrs: u32,
    /// Cycle cost excluding the conditional-branch taken penalty
    /// (`jmp`'s +1 is included).
    pub base_cycles: u64,
    pub loads: u64,
    pub stores: u64,
    pub mac_ops: u64,
    /// Unconditional taken transfers (`jmp`) per execution.
    pub branches_taken: u64,
    pub reg_mask: u32,
    /// Instruction-index PC of the last instruction in the block.
    pub last_pc: i64,
    /// PC after the block (fall-through / branch-not-taken).
    pub next_pc: i64,
    pub counts: Box<[(u16, &'static str, u32)]>,
    /// Fused superinstructions in the body (the per-dispatch
    /// [`ExecStats::fused_uops`] delta).
    pub fused: u32,
    pub uops: Box<[UopTpIsa]>,
    pub term: TermTpIsa,
}

/// Block cache of one prepared TP-ISA program.
#[derive(Debug, Clone)]
pub struct TranslatedTpIsa {
    pub blocks: Vec<BlockTpIsa>,
    pub leaders: Box<[u32]>,
    pub stats: TranslateStats,
}

fn tp_is_control(i: &tpisa::Instr) -> bool {
    matches!(
        i,
        tpisa::Instr::Bz { .. }
            | tpisa::Instr::Bnz { .. }
            | tpisa::Instr::Bc { .. }
            | tpisa::Instr::Bnc { .. }
            | tpisa::Instr::Jmp { .. }
            | tpisa::Instr::Halt
    )
}

/// Fixed cycle cost, excluding the conditional branch-taken penalty
/// (mirrors `TpIsa::run_traced`).
fn tp_base_cost(i: &tpisa::Instr) -> u64 {
    match i {
        tpisa::Instr::Ld { .. } | tpisa::Instr::St { .. } => 3,
        tpisa::Instr::Jmp { .. } => 3,
        tpisa::Instr::Halt => 1,
        _ => 2,
    }
}

/// OR-mask of every `record_reg` call the interpreter makes for one
/// TP-ISA instruction.
fn tp_reg_mask(i: &tpisa::Instr) -> u32 {
    let b = |r: u8| 1u32 << r;
    match *i {
        tpisa::Instr::Ldi { r1, .. } => b(r1),
        tpisa::Instr::Add { r1, r2 }
        | tpisa::Instr::Adc { r1, r2 }
        | tpisa::Instr::Sub { r1, r2 }
        | tpisa::Instr::Sbc { r1, r2 }
        | tpisa::Instr::And { r1, r2 }
        | tpisa::Instr::Or { r1, r2 }
        | tpisa::Instr::Xor { r1, r2 } => b(r1) | b(r2),
        tpisa::Instr::Shl { r1 }
        | tpisa::Instr::Shr { r1 }
        | tpisa::Instr::Sra { r1 }
        | tpisa::Instr::Slc { r1 }
        | tpisa::Instr::Src { r1 } => b(r1),
        tpisa::Instr::Ld { r1, r2, .. } => b(r1) | b(r2),
        tpisa::Instr::St { r1, r2, .. } => b(r1) | b(r2),
        tpisa::Instr::Addi { r1, .. } => b(r1),
        tpisa::Instr::Mov { r1, r2 } | tpisa::Instr::Sxt { r1, r2 } => b(r1) | b(r2),
        tpisa::Instr::Clc
        | tpisa::Instr::Bz { .. }
        | tpisa::Instr::Bnz { .. }
        | tpisa::Instr::Bc { .. }
        | tpisa::Instr::Bnc { .. }
        | tpisa::Instr::Jmp { .. }
        | tpisa::Instr::Halt => 0,
        tpisa::Instr::Mac { op, r1, r2 } => match op {
            MacOp::Mac => b(r1) | b(r2),
            MacOp::MacRd => b(r1),
            MacOp::MacClr => 0,
        },
    }
}

/// Register-only TP-ISA data op (no memory, no MAC, no control)?
fn tp_is_data(i: &tpisa::Instr) -> bool {
    !tp_is_control(i)
        && !matches!(
            i,
            tpisa::Instr::Ld { .. } | tpisa::Instr::St { .. } | tpisa::Instr::Mac { .. }
        )
}

/// Lower a straight-line TP-ISA body to micro-ops, fusing the codegen
/// idioms.
fn lower_tp_body(body: &[tpisa::Instr], stats: &mut TranslateStats) -> Vec<UopTpIsa> {
    let mut uops: Vec<UopTpIsa> = Vec::with_capacity(body.len());
    let mut k = 0usize;
    while k < body.len() {
        // ld/ld/mac (MAC dot-product step).
        if k + 2 < body.len() {
            if let (
                tpisa::Instr::Ld { r1: ra, r2: pa, imm: ia },
                tpisa::Instr::Ld { r1: rb, r2: pb, imm: ib },
                tpisa::Instr::Mac { op: MacOp::Mac, r1, r2 },
            ) = (body[k], body[k + 1], body[k + 2])
            {
                uops.push(UopTpIsa::Ld2Mac { a: (ra, pa, ia), b: (rb, pb, ib), r1, r2 });
                stats.fused += 1;
                k += 3;
                continue;
            }
        }
        // ld/<alu>/st on the same word (soft-mul accumulate, epilogue
        // shift loops).  Sequential execution keeps aliasing and flags
        // exact, so the only conditions are the shapes and the shared
        // (r1, r2, imm).
        if k + 2 < body.len() {
            if let (
                tpisa::Instr::Ld { r1: la, r2: lp, imm: li },
                mid,
                tpisa::Instr::St { r1: sa, r2: sp, imm: si },
            ) = (body[k], body[k + 1], body[k + 2])
            {
                if tp_is_data(&mid) && la == sa && lp == sp && li == si {
                    uops.push(UopTpIsa::LdOpSt { r1: la, r2: lp, imm: li, op: mid });
                    stats.fused += 1;
                    k += 3;
                    continue;
                }
            }
        }
        match body[k] {
            tpisa::Instr::Ld { r1, r2, imm } => uops.push(UopTpIsa::Ld { r1, r2, imm }),
            tpisa::Instr::St { r1, r2, imm } => uops.push(UopTpIsa::St { r1, r2, imm }),
            tpisa::Instr::Mac { op, r1, r2 } => uops.push(UopTpIsa::Mac { op, r1, r2 }),
            ins if tp_is_data(&ins) => uops.push(UopTpIsa::Data(ins)),
            ins => unreachable!("control transfer {ins:?} in block body"),
        }
        k += 1;
    }
    // Coalesce adjacent register-only ops into pairs/triples.
    let mut out: Vec<UopTpIsa> = Vec::with_capacity(uops.len());
    let mut k = 0usize;
    while k < uops.len() {
        let data = |u: &UopTpIsa| -> Option<tpisa::Instr> {
            match u {
                UopTpIsa::Data(i) => Some(*i),
                _ => None,
            }
        };
        if let Some(a) = data(&uops[k]) {
            if k + 2 < uops.len() {
                if let (Some(b), Some(c)) = (data(&uops[k + 1]), data(&uops[k + 2])) {
                    out.push(UopTpIsa::Data3(a, b, c));
                    stats.fused += 1;
                    k += 3;
                    continue;
                }
            }
            if k + 1 < uops.len() {
                if let Some(b) = data(&uops[k + 1]) {
                    out.push(UopTpIsa::Data2(a, b));
                    stats.fused += 1;
                    k += 2;
                    continue;
                }
            }
        }
        out.push(uops[k].clone());
        k += 1;
    }
    out
}

/// Discover and translate the basic blocks of a TP-ISA program.
/// TP-ISA has no dynamic jumps, so every reachable block entry is a
/// static leader; the runtime fallback only handles untranslatable
/// (MAC-without-unit) blocks, out-of-range PCs and fuel tails.
pub fn translate_tpisa(code: &[tpisa::Instr], has_mac: bool) -> TranslatedTpIsa {
    let n = code.len();
    let mut is_leader = vec![false; n];
    if n > 0 {
        is_leader[0] = true;
    }
    let mark = |is_leader: &mut Vec<bool>, i: usize, off: i64| {
        let target = i as i64 + off;
        if target >= 0 && (target as usize) < n {
            is_leader[target as usize] = true;
        }
    };
    for (i, ins) in code.iter().enumerate() {
        match *ins {
            tpisa::Instr::Bz { off } | tpisa::Instr::Bnz { off } | tpisa::Instr::Jmp { off } => {
                mark(&mut is_leader, i, off as i64)
            }
            tpisa::Instr::Bc { off } | tpisa::Instr::Bnc { off } => {
                mark(&mut is_leader, i, off as i64)
            }
            _ => {}
        }
        if tp_is_control(ins) && i + 1 < n {
            is_leader[i + 1] = true;
        }
    }

    let mut blocks = Vec::new();
    let mut leaders = vec![NO_BLOCK; n];
    let mut stats = TranslateStats { instructions: n, ..TranslateStats::default() };
    let mut i = 0usize;
    while i < n {
        debug_assert!(is_leader[i]);
        let mut j = i;
        let terminated = loop {
            if tp_is_control(&code[j]) {
                break true;
            }
            if j + 1 >= n || is_leader[j + 1] {
                break false;
            }
            j += 1;
        };
        let instrs = &code[i..=j];
        if !has_mac && instrs.iter().any(|x| matches!(x, tpisa::Instr::Mac { .. })) {
            stats.untranslatable_blocks += 1;
            i = j + 1;
            continue;
        }

        let mut counts: BTreeMap<u16, (&'static str, u32)> = BTreeMap::new();
        let mut block = BlockTpIsa {
            n_instrs: instrs.len() as u32,
            base_cycles: 0,
            loads: 0,
            stores: 0,
            mac_ops: 0,
            branches_taken: 0,
            reg_mask: 0,
            last_pc: j as i64,
            next_pc: j as i64 + 1,
            counts: Box::new([]),
            fused: 0,
            uops: Box::new([]),
            term: TermTpIsa::FallThrough,
        };
        for ins in instrs {
            block.base_cycles += tp_base_cost(ins);
            block.reg_mask |= tp_reg_mask(ins);
            match ins {
                tpisa::Instr::Ld { .. } => block.loads += 1,
                tpisa::Instr::St { .. } => block.stores += 1,
                tpisa::Instr::Mac { op: MacOp::Mac, .. } => block.mac_ops += 1,
                tpisa::Instr::Jmp { .. } => block.branches_taken += 1,
                _ => {}
            }
            let e = counts.entry(ins.mnemonic_id() as u16).or_insert((ins.mnemonic(), 0));
            e.1 += 1;
        }
        block.counts =
            counts.into_iter().map(|(id, (name, c))| (id, name, c)).collect::<Vec<_>>().into();

        let body = if terminated { &code[i..j] } else { instrs };
        let fused_before = stats.fused;
        block.uops = lower_tp_body(body, &mut stats).into();
        block.fused = (stats.fused - fused_before) as u32;
        block.term = if terminated {
            let pc = j as i64;
            match code[j] {
                tpisa::Instr::Bz { off } => {
                    TermTpIsa::Branch { cond: CondTp::Z, target: pc + off as i64 }
                }
                tpisa::Instr::Bnz { off } => {
                    TermTpIsa::Branch { cond: CondTp::Nz, target: pc + off as i64 }
                }
                tpisa::Instr::Bc { off } => {
                    TermTpIsa::Branch { cond: CondTp::C, target: pc + off as i64 }
                }
                tpisa::Instr::Bnc { off } => {
                    TermTpIsa::Branch { cond: CondTp::Nc, target: pc + off as i64 }
                }
                tpisa::Instr::Jmp { off } => TermTpIsa::Jmp { target: pc + off as i64 },
                tpisa::Instr::Halt => TermTpIsa::Halt,
                _ => unreachable!("non-control terminator"),
            }
        } else {
            TermTpIsa::FallThrough
        };

        leaders[i] = blocks.len() as u32;
        stats.blocks += 1;
        stats.translated_instructions += instrs.len();
        blocks.push(block);
        i = j + 1;
    }
    TranslatedTpIsa { blocks, leaders: leaders.into(), stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::rv32_asm::assemble;

    #[test]
    fn rv32_blocks_tile_the_program() {
        let code = assemble(
            r#"
                li   t0, 10
                li   t1, 0
            loop:
                add  t1, t1, t0
                addi t0, t0, -1
                bnez t0, loop
                ebreak
            "#,
        )
        .unwrap();
        let t = translate_rv32(&code, false);
        // Leaders: 0 (entry), 2 (branch target), 5 (after branch).
        assert_eq!(t.stats.blocks, 3);
        assert_eq!(t.stats.translated_instructions, code.len());
        assert_eq!(t.stats.untranslatable_blocks, 0);
        assert_eq!(t.leaders[0], 0);
        assert_eq!(t.leaders[1], NO_BLOCK);
        assert_eq!(t.leaders[2], 1);
        assert_eq!(t.leaders[5], 2);
        // Loop block: add/addi fuse into an Alu2, bne terminates.
        let b = &t.blocks[1];
        assert_eq!(b.n_instrs, 3);
        assert!(matches!(b.term, TermRv32::Branch { target: 8, .. }));
        // base: add(1) + addi(1) + bne-not-taken(1).
        assert_eq!(b.base_cycles, 3);
        assert_eq!(b.last_pc, 16);
        assert_eq!(b.next_pc, 20);
    }

    #[test]
    fn rv32_fuses_dot_product_idioms() {
        let code = assemble(
            r#"
                lw  t0, 0(s0)
                lw  t1, 0(s1)
                mac t0, t1
                addi s0, s0, 4
                addi s1, s1, 4
                addi a1, a1, -1
                ebreak
            "#,
        )
        .unwrap();
        let t = translate_rv32(&code, true);
        assert_eq!(t.stats.blocks, 1);
        let b = &t.blocks[0];
        assert!(matches!(b.term, TermRv32::Ebreak));
        assert_eq!(b.uops.len(), 2, "{:?}", b.uops);
        assert!(matches!(b.uops[0], UopRv32::Load2Mac { .. }));
        assert!(matches!(b.uops[1], UopRv32::Alu3(..)));
        assert_eq!(b.mac_ops, 1);
        assert_eq!(b.loads, 2);
        // lw(2) + lw(2) + mac(1) + 3*addi(1) + ebreak(1).
        assert_eq!(b.base_cycles, 9);
        // Load2Mac + Alu3 fuse in this one block; the per-block count
        // is the image total (telemetry's per-dispatch fused delta).
        assert_eq!(b.fused as usize, t.stats.fused);
        assert_eq!(b.fused, 2);
    }

    /// Per-block fused counts tile the image total for both ISAs.
    #[test]
    fn per_block_fused_sums_to_stats() {
        let code = assemble(
            r#"
            loop:
                lw  t0, 0(s0)
                lw  t1, 0(s1)
                mac t0, t1
                addi s0, s0, 4
                addi s1, s1, 4
                bnez a1, loop
                ebreak
            "#,
        )
        .unwrap();
        let t = translate_rv32(&code, true);
        let sum: usize = t.blocks.iter().map(|b| b.fused as usize).sum();
        assert_eq!(sum, t.stats.fused);
        assert!(t.stats.fused >= 1);

        use crate::isa::tpisa::Instr;
        let tcode = vec![
            Instr::Ld { r1: 0, r2: 7, imm: 0 },
            Instr::Ld { r1: 1, r2: 6, imm: 0 },
            Instr::Mac { op: MacOp::Mac, r1: 0, r2: 1 },
            Instr::Halt,
        ];
        let tt = translate_tpisa(&tcode, true);
        let tsum: usize = tt.blocks.iter().map(|b| b.fused as usize).sum();
        assert_eq!(tsum, tt.stats.fused);
        assert!(tt.stats.fused >= 1);
    }

    #[test]
    fn rv32_mac_without_unit_is_untranslatable() {
        let code = assemble("mac t0, t1\nebreak").unwrap();
        let t = translate_rv32(&code, false);
        assert_eq!(t.stats.untranslatable_blocks, 1);
        assert_eq!(t.leaders[0], NO_BLOCK);
        let t = translate_rv32(&code, true);
        assert_eq!(t.stats.untranslatable_blocks, 0);
        assert_eq!(t.leaders[0], 0);
    }

    #[test]
    fn rv32_misaligned_targets_do_not_become_leaders() {
        use crate::isa::rv32::{AluOp, BranchOp, Instr};
        let code = vec![
            Instr::OpImm { op: AluOp::Add, rd: 5, rs1: 0, imm: 1 },
            Instr::Branch { op: BranchOp::Beq, rs1: 0, rs2: 0, offset: 6 },
            Instr::OpImm { op: AluOp::Add, rd: 6, rs1: 6, imm: 1 },
            Instr::Ebreak,
        ];
        let t = translate_rv32(&code, false);
        // The branch target (pc 10) is misaligned: no leader at idx 2
        // beyond the fall-through one.
        assert_eq!(t.leaders.iter().filter(|&&b| b != NO_BLOCK).count(), t.stats.blocks);
        assert!(t.blocks.len() >= 2);
    }

    #[test]
    fn tpisa_translates_softmul_shapes() {
        use crate::isa::tpisa::{Asm, Instr};
        let mut a = Asm::new();
        a.ldi(3, 0);
        a.ldi(5, 7);
        a.label("smul");
        a.push(Instr::Shr { r1: 2 });
        a.bnc("skip");
        a.push(Instr::Add { r1: 3, r2: 0 });
        a.push(Instr::Adc { r1: 4, r2: 1 });
        a.label("skip");
        a.push(Instr::Shl { r1: 0 });
        a.push(Instr::Slc { r1: 1 });
        a.push(Instr::Addi { r1: 5, imm: -1 });
        a.bnz("smul");
        a.push(Instr::Halt);
        let code = a.finish().unwrap();
        let t = translate_tpisa(&code, false);
        assert_eq!(t.stats.untranslatable_blocks, 0);
        assert_eq!(t.stats.translated_instructions, code.len());
        // The add/adc pair and shl/slc/addi run must fuse.
        assert!(t.stats.fused >= 2, "fused {}", t.stats.fused);
        let shift_block = &t.blocks[t.leaders[code.len() - 5] as usize];
        assert!(matches!(shift_block.uops[0], UopTpIsa::Data3(..)));
        assert!(matches!(shift_block.term, TermTpIsa::Branch { cond: CondTp::Nz, .. }));
    }

    #[test]
    fn tpisa_fuses_ld_op_st_and_ld2mac() {
        use crate::isa::tpisa::Instr;
        let code = vec![
            Instr::Ld { r1: 0, r2: 2, imm: 1 },
            Instr::Add { r1: 0, r2: 3 },
            Instr::St { r1: 0, r2: 2, imm: 1 },
            Instr::Ld { r1: 0, r2: 7, imm: 0 },
            Instr::Ld { r1: 1, r2: 6, imm: 0 },
            Instr::Mac { op: MacOp::Mac, r1: 0, r2: 1 },
            Instr::Halt,
        ];
        let t = translate_tpisa(&code, true);
        let b = &t.blocks[0];
        assert_eq!(b.uops.len(), 2, "{:?}", b.uops);
        assert!(matches!(b.uops[0], UopTpIsa::LdOpSt { .. }));
        assert!(matches!(b.uops[1], UopTpIsa::Ld2Mac { .. }));
        // 3*(ld/st: 3) + add(2) + ld(3)+ld(3)+mac(2) + halt(1).
        assert_eq!(b.base_cycles, 3 + 2 + 3 + 3 + 3 + 2 + 1);
        assert_eq!(b.loads, 3);
        assert_eq!(b.stores, 1);
        assert_eq!(b.mac_ops, 1);
    }
}
