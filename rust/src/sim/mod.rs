//! Cycle-approximate instruction-set simulators (the stand-in for the
//! paper's Modelsim RTL simulation, workflow step ④).
//!
//! * [`mem`] — ROM/RAM model with the program image layout.
//! * [`mac_model`] — the bit-exact functional model of the SIMD MAC
//!   unit, mirrored by the Pallas kernel (`kernels/simd_mac.py`).
//! * [`trace`] — execution profiles: instruction histograms, register
//!   and CSR utilization, PC reach — the inputs to the bespoke
//!   reduction pass.
//! * [`zero_riscy`] — RV32IM 2-stage pipeline timing model.
//! * [`tpisa`] — the minimal width-configurable printed core.

pub mod mac_model;
pub mod mem;
pub mod tpisa;
pub mod trace;
pub mod zero_riscy;
