//! Cycle-approximate instruction-set simulators (the stand-in for the
//! paper's Modelsim RTL simulation, workflow step ④).
//!
//! * [`mem`] — ROM/RAM model with the program image layout.
//! * [`mac_model`] — the bit-exact functional model of the SIMD MAC
//!   unit, mirrored by the Pallas kernel (`kernels/simd_mac.py`).
//! * [`prepared`] — sample-invariant program images ([`PreparedRv32`],
//!   [`PreparedTpIsa`]): pre-decoded code, pre-encoded ROM bytes and
//!   the initial TP-ISA data-memory image, built once per
//!   (model, variant) and `Arc`-shared by every simulator instance.
//! * [`translate`] — basic-block pre-translation of prepared images:
//!   straight-line micro-op blocks with block-level aggregated
//!   bookkeeping and peephole-fused superinstructions for the codegen
//!   hot idioms (`lw/lw/mac`, `lh/lh/mul/add`, TP-ISA soft-multiply
//!   and `ld/ld/mac` kernels).
//! * [`trace`] — execution profiles: instruction histograms, register
//!   and CSR utilization, PC reach — the inputs to the bespoke
//!   reduction pass — plus the compile-time [`TraceMode`]s
//!   ([`FullProfile`] / [`CyclesOnly`]) the run loops are generic over.
//! * [`zero_riscy`] — RV32IM 2-stage pipeline timing model.
//! * [`tpisa`] — the minimal width-configurable printed core.
//! * [`batch`] — batched lockstep execution ([`BatchRv32`],
//!   [`BatchTpIsa`]): N lanes over one shared prepared image, each
//!   translated block fetched once and retired lane-parallel, with
//!   divergent lanes drained on the scalar path and rejoined.
//! * [`error`] — the typed [`ExecError`] every engine raises, so
//!   consumers match variants instead of message substrings.
//! * [`fault`] — deterministic soft-error injection: seeded
//!   [`FaultPlan`]s (register/RAM/MAC bit flips, stuck-at ROM words)
//!   armed per engine instance — per *lane* in the batched engine —
//!   with zero-rate plans bit-identical to fault-free execution.
//!
//! Both cores expose two run loops over the same prepared image: the
//! per-instruction `run_traced` (the reference interpreter) and the
//! block-dispatching `run_translated` (the production hot path) —
//! bit-identical in scores, cycles and profiles, pinned by
//! `tests/iss_equivalence.rs`.  The batched engine is a third,
//! lane-parallel consumer of the same primitives, pinned against the
//! scalar engines by `tests/iss_batch_equivalence.rs`.

pub mod batch;
pub mod error;
pub mod fault;
pub mod mac_model;
pub mod mem;
pub mod prepared;
pub mod tpisa;
pub mod trace;
pub mod translate;
pub mod zero_riscy;

pub use batch::{BatchRv32, BatchTpIsa};
pub use error::ExecError;
pub use fault::{FaultPlan, FaultState};
pub use prepared::{PreparedRv32, PreparedTpIsa};
pub use trace::{CyclesOnly, FullProfile, TraceMode};
pub use translate::ExecStats;
