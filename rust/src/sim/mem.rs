//! Memory model shared by the simulators.
//!
//! Program-image layout convention (shared with `ml::codegen_*`):
//!
//! * ROM at `0x0000`: code, then 4-byte-aligned constant data (weights).
//!   ROM bytes are what the printed memory analysis (§IV-B) counts.
//! * RAM at [`RAM_BASE`]: mailbox (scores), input vector, scratch.
//!
//! Zero-Riscy addresses bytes (little-endian); TP-ISA uses its own
//! word-addressed data memory (`WordMem`) of d-bit cells.

use anyhow::{bail, Result};

pub const RAM_BASE: u32 = 0x0001_0000;

/// Byte-addressed ROM + RAM for the RV32 core.
#[derive(Debug, Clone)]
pub struct Mem {
    pub rom: Vec<u8>,
    pub ram: Vec<u8>,
}

impl Mem {
    pub fn new(rom: Vec<u8>, ram_bytes: usize) -> Mem {
        Mem { rom, ram: vec![0; ram_bytes] }
    }

    fn slot(&mut self, addr: u32, len: usize) -> Result<&mut [u8]> {
        let a = addr as usize;
        if addr >= RAM_BASE {
            let off = a - RAM_BASE as usize;
            if off + len <= self.ram.len() {
                return Ok(&mut self.ram[off..off + len]);
            }
        }
        bail!("store to invalid address {addr:#010x}")
    }

    fn view(&self, addr: u32, len: usize) -> Result<&[u8]> {
        let a = addr as usize;
        if addr >= RAM_BASE {
            let off = a - RAM_BASE as usize;
            if off + len <= self.ram.len() {
                return Ok(&self.ram[off..off + len]);
            }
        } else if a + len <= self.rom.len() {
            return Ok(&self.rom[a..a + len]);
        }
        bail!("load from invalid address {addr:#010x}")
    }

    pub fn load_u8(&self, addr: u32) -> Result<u8> {
        Ok(self.view(addr, 1)?[0])
    }

    pub fn load_u16(&self, addr: u32) -> Result<u16> {
        let b = self.view(addr, 2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn load_u32(&self, addr: u32) -> Result<u32> {
        let b = self.view(addr, 4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn store_u8(&mut self, addr: u32, v: u8) -> Result<()> {
        self.slot(addr, 1)?[0] = v;
        Ok(())
    }

    pub fn store_u16(&mut self, addr: u32, v: u16) -> Result<()> {
        self.slot(addr, 2)?.copy_from_slice(&v.to_le_bytes());
        Ok(())
    }

    pub fn store_u32(&mut self, addr: u32, v: u32) -> Result<()> {
        self.slot(addr, 4)?.copy_from_slice(&v.to_le_bytes());
        Ok(())
    }
}

/// Word-addressed data memory of `width`-bit cells for TP-ISA.
#[derive(Debug, Clone)]
pub struct WordMem {
    pub width: u32,
    words: Vec<u64>,
}

impl WordMem {
    pub fn new(width: u32, len: usize) -> WordMem {
        assert!(width >= 1 && width <= 64);
        WordMem { width, words: vec![0; len] }
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    fn mask(&self) -> u64 {
        if self.width == 64 {
            u64::MAX
        } else {
            (1u64 << self.width) - 1
        }
    }

    pub fn load(&self, addr: i64) -> Result<u64> {
        if addr < 0 || addr as usize >= self.words.len() {
            bail!("TP-ISA load from invalid word address {addr}");
        }
        Ok(self.words[addr as usize])
    }

    pub fn store(&mut self, addr: i64, v: u64) -> Result<()> {
        if addr < 0 || addr as usize >= self.words.len() {
            bail!("TP-ISA store to invalid word address {addr}");
        }
        let m = self.mask();
        self.words[addr as usize] = v & m;
        Ok(())
    }

    /// Write a signed value (masked to the cell width).
    pub fn store_signed(&mut self, addr: i64, v: i64) -> Result<()> {
        self.store(addr, v as u64)
    }

    /// Read a sign-extended value.
    pub fn load_signed(&self, addr: i64) -> Result<i64> {
        Ok(super::mac_model::sext(self.load(addr)?, self.width))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rom_read_only() {
        let mut m = Mem::new(vec![1, 2, 3, 4], 64);
        assert_eq!(m.load_u32(0).unwrap(), 0x0403_0201);
        assert!(m.store_u32(0, 5).is_err());
    }

    #[test]
    fn ram_rw_little_endian() {
        let mut m = Mem::new(vec![], 64);
        m.store_u32(RAM_BASE, 0x1234_5678).unwrap();
        assert_eq!(m.load_u16(RAM_BASE).unwrap(), 0x5678);
        assert_eq!(m.load_u8(RAM_BASE + 3).unwrap(), 0x12);
        m.store_u16(RAM_BASE + 8, 0xbeef).unwrap();
        assert_eq!(m.load_u32(RAM_BASE + 8).unwrap(), 0x0000_beef);
    }

    #[test]
    fn bounds_checked() {
        let m = Mem::new(vec![0; 8], 16);
        assert!(m.load_u32(6).is_err());
        assert!(m.load_u32(RAM_BASE + 13).is_err());
        assert!(m.load_u32(0x8000).is_err());
    }

    #[test]
    fn word_mem_masks_to_width() {
        let mut m = WordMem::new(8, 16);
        m.store(3, 0x1ff).unwrap();
        assert_eq!(m.load(3).unwrap(), 0xff);
        m.store_signed(4, -2).unwrap();
        assert_eq!(m.load(4).unwrap(), 0xfe);
        assert_eq!(m.load_signed(4).unwrap(), -2);
        assert!(m.load(16).is_err());
        assert!(m.store(-1, 0).is_err());
    }
}
