//! Memory model shared by the simulators.
//!
//! Program-image layout convention (shared with `ml::codegen_*`):
//!
//! * ROM at `0x0000`: code, then 4-byte-aligned constant data (weights).
//!   ROM bytes are what the printed memory analysis (§IV-B) counts.
//! * RAM at [`RAM_BASE`]: mailbox (scores), input vector, scratch.
//!
//! Zero-Riscy addresses bytes (little-endian); TP-ISA uses its own
//! word-addressed data memory (`WordMem`) of d-bit cells.
//!
//! Performance contract (§Perf iteration 3): the ROM is `Arc`-shared
//! with the prepared program image (never copied per simulator), the
//! in-range access path is branch + slice index with **no** error
//! machinery inlined, and all error construction lives in `#[cold]`
//! out-of-line helpers.  Bulk transfers ([`Mem::write_ram`] /
//! [`Mem::read_ram`] / [`WordMem::write_words`] / [`WordMem::read_words`])
//! give the harness one bounds check per batch instead of one `Result`
//! per byte/word.  The block-translated run loops (§Perf iteration 4,
//! `sim::translate`) keep every load/store on these same accessors —
//! fused superinstructions change dispatch, not memory semantics — so
//! fault addresses and messages are identical in both engines.

use std::sync::Arc;

use anyhow::Result;

pub const RAM_BASE: u32 = 0x0001_0000;

#[cold]
#[inline(never)]
fn load_fault(addr: u32) -> anyhow::Error {
    anyhow::anyhow!("load from invalid address {addr:#010x}")
}

#[cold]
#[inline(never)]
fn store_fault(addr: u32) -> anyhow::Error {
    anyhow::anyhow!("store to invalid address {addr:#010x}")
}

#[cold]
#[inline(never)]
fn word_load_fault(addr: i64) -> anyhow::Error {
    anyhow::anyhow!("TP-ISA load from invalid word address {addr}")
}

#[cold]
#[inline(never)]
fn word_store_fault(addr: i64) -> anyhow::Error {
    anyhow::anyhow!("TP-ISA store to invalid word address {addr}")
}

/// Byte-addressed ROM + RAM for the RV32 core.  The ROM is shared
/// (read-only) with the prepared program image; only RAM is owned.
#[derive(Debug, Clone)]
pub struct Mem {
    pub rom: Arc<Vec<u8>>,
    pub ram: Vec<u8>,
}

impl Mem {
    pub fn new(rom: impl Into<Arc<Vec<u8>>>, ram_bytes: usize) -> Mem {
        Mem { rom: rom.into(), ram: vec![0; ram_bytes] }
    }

    /// Zero RAM in place (the ROM is immutable) — the reset path of a
    /// reused simulator.
    pub fn reset(&mut self) {
        self.ram.fill(0);
    }

    #[inline]
    fn slot(&mut self, addr: u32, len: usize) -> Result<&mut [u8]> {
        if addr >= RAM_BASE {
            let off = (addr - RAM_BASE) as usize;
            if let Some(s) = self.ram.get_mut(off..off + len) {
                return Ok(s);
            }
        }
        Err(store_fault(addr))
    }

    #[inline]
    fn view(&self, addr: u32, len: usize) -> Result<&[u8]> {
        let a = addr as usize;
        if addr >= RAM_BASE {
            let off = a - RAM_BASE as usize;
            if let Some(s) = self.ram.get(off..off + len) {
                return Ok(s);
            }
        } else if let Some(s) = self.rom.get(a..a + len) {
            return Ok(s);
        }
        Err(load_fault(addr))
    }

    #[inline]
    pub fn load_u8(&self, addr: u32) -> Result<u8> {
        Ok(self.view(addr, 1)?[0])
    }

    #[inline]
    pub fn load_u16(&self, addr: u32) -> Result<u16> {
        let b = self.view(addr, 2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    #[inline]
    pub fn load_u32(&self, addr: u32) -> Result<u32> {
        let b = self.view(addr, 4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    #[inline]
    pub fn store_u8(&mut self, addr: u32, v: u8) -> Result<()> {
        self.slot(addr, 1)?[0] = v;
        Ok(())
    }

    #[inline]
    pub fn store_u16(&mut self, addr: u32, v: u16) -> Result<()> {
        self.slot(addr, 2)?.copy_from_slice(&v.to_le_bytes());
        Ok(())
    }

    #[inline]
    pub fn store_u32(&mut self, addr: u32, v: u32) -> Result<()> {
        self.slot(addr, 4)?.copy_from_slice(&v.to_le_bytes());
        Ok(())
    }

    /// Bulk write into RAM at `offset` bytes past [`RAM_BASE`] — the
    /// harness's input-preload path.
    pub fn write_ram(&mut self, offset: usize, bytes: &[u8]) -> Result<()> {
        match self.ram.get_mut(offset..offset + bytes.len()) {
            Some(s) => {
                s.copy_from_slice(bytes);
                Ok(())
            }
            None => Err(store_fault(RAM_BASE.wrapping_add(offset as u32))),
        }
    }

    /// Bulk read of `len` bytes of RAM at `offset` bytes past
    /// [`RAM_BASE`] — the harness's score-readout path.
    pub fn read_ram(&self, offset: usize, len: usize) -> Result<&[u8]> {
        self.ram
            .get(offset..offset + len)
            .ok_or_else(|| load_fault(RAM_BASE.wrapping_add(offset as u32)))
    }
}

/// Word-addressed data memory of `width`-bit cells for TP-ISA.
#[derive(Debug, Clone)]
pub struct WordMem {
    pub width: u32,
    words: Vec<u64>,
}

impl WordMem {
    pub fn new(width: u32, len: usize) -> WordMem {
        assert!(width >= 1 && width <= 64);
        WordMem { width, words: vec![0; len] }
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    fn mask(&self) -> u64 {
        if self.width == 64 {
            u64::MAX
        } else {
            (1u64 << self.width) - 1
        }
    }

    #[inline]
    pub fn load(&self, addr: i64) -> Result<u64> {
        match usize::try_from(addr).ok().and_then(|a| self.words.get(a)) {
            Some(&v) => Ok(v),
            None => Err(word_load_fault(addr)),
        }
    }

    #[inline]
    pub fn store(&mut self, addr: i64, v: u64) -> Result<()> {
        let m = self.mask();
        match usize::try_from(addr).ok().and_then(|a| self.words.get_mut(a)) {
            Some(slot) => {
                *slot = v & m;
                Ok(())
            }
            None => Err(word_store_fault(addr)),
        }
    }

    /// Write a signed value (masked to the cell width).
    pub fn store_signed(&mut self, addr: i64, v: i64) -> Result<()> {
        self.store(addr, v as u64)
    }

    /// Read a sign-extended value.
    pub fn load_signed(&self, addr: i64) -> Result<i64> {
        Ok(super::mac_model::sext(self.load(addr)?, self.width))
    }

    /// Bulk masked write of `vals` at word address `base` — the
    /// harness's input-preload path.
    pub fn write_words(&mut self, base: usize, vals: &[u64]) -> Result<()> {
        let m = self.mask();
        match self.words.get_mut(base..base + vals.len()) {
            Some(s) => {
                for (slot, &v) in s.iter_mut().zip(vals) {
                    *slot = v & m;
                }
                Ok(())
            }
            None => Err(word_store_fault(base as i64)),
        }
    }

    /// Bulk read of `len` words at word address `base` — the harness's
    /// score-readout path.
    pub fn read_words(&self, base: usize, len: usize) -> Result<&[u64]> {
        self.words.get(base..base + len).ok_or_else(|| word_load_fault(base as i64))
    }

    /// Memcpy-restore the whole memory from a prepared initial image
    /// (values already masked; `image.len()` must equal [`Self::len`]).
    pub fn restore(&mut self, image: &[u64]) {
        self.words.copy_from_slice(image);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rom_read_only() {
        let mut m = Mem::new(vec![1, 2, 3, 4], 64);
        assert_eq!(m.load_u32(0).unwrap(), 0x0403_0201);
        assert!(m.store_u32(0, 5).is_err());
    }

    #[test]
    fn ram_rw_little_endian() {
        let mut m = Mem::new(vec![], 64);
        m.store_u32(RAM_BASE, 0x1234_5678).unwrap();
        assert_eq!(m.load_u16(RAM_BASE).unwrap(), 0x5678);
        assert_eq!(m.load_u8(RAM_BASE + 3).unwrap(), 0x12);
        m.store_u16(RAM_BASE + 8, 0xbeef).unwrap();
        assert_eq!(m.load_u32(RAM_BASE + 8).unwrap(), 0x0000_beef);
    }

    #[test]
    fn bounds_checked() {
        let m = Mem::new(vec![0; 8], 16);
        assert!(m.load_u32(6).is_err());
        assert!(m.load_u32(RAM_BASE + 13).is_err());
        assert!(m.load_u32(0x8000).is_err());
    }

    #[test]
    fn bulk_ram_roundtrip_and_reset() {
        let mut m = Mem::new(vec![], 16);
        m.write_ram(4, &[1, 2, 3, 4]).unwrap();
        assert_eq!(m.load_u32(RAM_BASE + 4).unwrap(), 0x0403_0201);
        assert_eq!(m.read_ram(4, 4).unwrap(), &[1, 2, 3, 4]);
        assert!(m.write_ram(14, &[0; 4]).is_err());
        assert!(m.read_ram(13, 4).is_err());
        m.reset();
        assert_eq!(m.load_u32(RAM_BASE + 4).unwrap(), 0);
    }

    #[test]
    fn rom_is_shared_not_copied() {
        let rom = Arc::new(vec![7u8; 8]);
        let m = Mem::new(Arc::clone(&rom), 4);
        assert!(Arc::ptr_eq(&m.rom, &rom));
    }

    #[test]
    fn word_mem_masks_to_width() {
        let mut m = WordMem::new(8, 16);
        m.store(3, 0x1ff).unwrap();
        assert_eq!(m.load(3).unwrap(), 0xff);
        m.store_signed(4, -2).unwrap();
        assert_eq!(m.load(4).unwrap(), 0xfe);
        assert_eq!(m.load_signed(4).unwrap(), -2);
        assert!(m.load(16).is_err());
        assert!(m.store(-1, 0).is_err());
    }

    /// Lane-isolation property for the batched engine (`sim::batch`):
    /// lanes share the ROM `Arc` but own their RAM, so writes through
    /// lane `i` must never be observable from lane `j` — including
    /// after either side resets.
    #[test]
    fn ram_lanes_are_isolated() {
        let mut rng = crate::util::rng::Pcg32::seeded(0x1550_e9_20);
        let rom = Arc::new((0..32u8).collect::<Vec<u8>>());
        let lanes = 4;
        let mut mems: Vec<Mem> = (0..lanes).map(|_| Mem::new(Arc::clone(&rom), 64)).collect();
        for m in &mems {
            assert!(Arc::ptr_eq(&m.rom, &rom), "prepared ROM must be shared, not copied");
        }
        let mut shadow = vec![[0u8; 64]; lanes];
        for _ in 0..200 {
            let i = rng.range_usize(0, lanes - 1);
            let off = rng.range_usize(0, 63) as u32;
            let v = rng.range_i64(0, 255) as u8;
            mems[i].store_u8(RAM_BASE + off, v).unwrap();
            shadow[i][off as usize] = v;
            for (j, m) in mems.iter().enumerate() {
                assert_eq!(m.read_ram(0, 64).unwrap(), &shadow[j], "lane {j} after write to {i}");
            }
        }
        // Resetting one lane leaves every sibling's RAM intact.
        mems[1].reset();
        shadow[1] = [0u8; 64];
        for (j, m) in mems.iter().enumerate() {
            assert_eq!(m.read_ram(0, 64).unwrap(), &shadow[j], "lane {j} after reset of 1");
        }
        // ROM stays bit-identical (and shared) throughout.
        for m in &mems {
            assert_eq!(m.load_u8(5).unwrap(), 5);
        }
    }

    /// TP-ISA twin: `WordMem` lanes restored from one prepared image
    /// stay independent through stores and `restore()`.
    #[test]
    fn word_mem_lanes_are_isolated() {
        let mut rng = crate::util::rng::Pcg32::seeded(0x1550_e9_21);
        let image: Vec<u64> = (0..16u64).collect();
        let lanes = 3;
        let mut mems: Vec<WordMem> = (0..lanes)
            .map(|_| {
                let mut m = WordMem::new(8, image.len());
                m.restore(&image);
                m
            })
            .collect();
        let mut shadow = vec![image.clone(); lanes];
        for _ in 0..200 {
            let i = rng.range_usize(0, lanes - 1);
            let addr = rng.range_i64(0, 15);
            let v = rng.range_i64(0, 0x1ff) as u64;
            mems[i].store(addr, v).unwrap();
            shadow[i][addr as usize] = v & 0xff;
            for (j, m) in mems.iter().enumerate() {
                assert_eq!(m.read_words(0, 16).unwrap(), &shadow[j], "lane {j} after store to {i}");
            }
        }
        // Restoring one lane from the shared image leaves siblings as
        // they were.
        mems[0].restore(&image);
        shadow[0] = image.clone();
        for (j, m) in mems.iter().enumerate() {
            assert_eq!(m.read_words(0, 16).unwrap(), &shadow[j], "lane {j} after restore of 0");
        }
    }

    #[test]
    fn word_mem_bulk_and_restore() {
        let mut m = WordMem::new(8, 8);
        m.write_words(2, &[0x1ff, 7]).unwrap();
        assert_eq!(m.read_words(2, 2).unwrap(), &[0xff, 7]);
        assert!(m.write_words(7, &[0, 0]).is_err());
        assert!(m.read_words(7, 2).is_err());
        let image = vec![1u64, 2, 3, 4, 5, 6, 7, 8];
        m.restore(&image);
        assert_eq!(m.read_words(0, 8).unwrap(), image.as_slice());
    }
}
