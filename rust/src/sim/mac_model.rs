//! Bit-exact functional model of the SIMD MAC unit (paper Fig. 2, Eq. 1).
//!
//! This is the third implementation of the same contract — the Pallas
//! kernel (`python/compile/kernels/simd_mac.py`) and the jnp oracle
//! (`kernels/ref.py`) are the others — and the integration tests assert
//! all three agree bit-for-bit:
//!
//! * `L = max(1, datapath / precision)` lanes.
//! * Lane i of a packed word holds bits `[n*i + n-1 : n*i]`, two's
//!   complement.
//! * Each MAC wraps lane products into a per-lane accumulator: a 32-bit
//!   wrapping register for p <= 16, a 64-bit pair for p = 32.
//! * `read(lane)` returns the 32-bit lane accumulator; for p = 32 lanes
//!   0/1 alias the low/high halves.  `read_chunk` exposes d-bit chunks
//!   for narrow TP-ISA datapaths.

use crate::hw::mac_unit::MacConfig;

/// Runtime state of one MAC unit instance.
#[derive(Debug, Clone)]
pub struct MacState {
    pub cfg: MacConfig,
    /// Lane accumulators.  p <= 16: i32 stored sign-extended; p = 32:
    /// a single i64 in `acc[0]`.
    acc: Vec<i64>,
}

/// Sign-extend the low `n` bits of `v`.
#[inline]
pub fn sext(v: u64, n: u32) -> i64 {
    debug_assert!(n >= 1 && n <= 64);
    let sh = 64 - n;
    ((v << sh) as i64) >> sh
}

impl MacState {
    pub fn new(cfg: MacConfig) -> MacState {
        let lanes = if cfg.precision >= 32 { 1 } else { cfg.lanes() as usize };
        MacState { cfg, acc: vec![0; lanes] }
    }

    pub fn lanes(&self) -> usize {
        self.cfg.lanes() as usize
    }

    /// Zero all accumulators — both the `maccl` instruction and the
    /// simulator `reset()` path (the accumulators are the unit's only
    /// mutable state, so clear == full reset).
    pub fn clear(&mut self) {
        self.acc.iter_mut().for_each(|a| *a = 0);
    }

    /// Execute one MAC instruction on packed operand words (masked to
    /// the datapath width).  Sits in the ISS inner loop of every SIMD
    /// variant.
    #[inline]
    pub fn mac(&mut self, a: u64, b: u64) {
        let d = self.cfg.datapath;
        let p = self.cfg.precision;
        let mask = if d == 64 { u64::MAX } else { (1u64 << d) - 1 };
        let (a, b) = (a & mask, b & mask);
        if p >= 32 {
            // Single 64-bit accumulator (register pair).
            let prod = (sext(a, 32) as i64).wrapping_mul(sext(b, 32));
            self.acc[0] = self.acc[0].wrapping_add(prod);
            return;
        }
        for i in 0..self.lanes() {
            let la = sext(a >> (p * i as u32), p) as i32;
            let lb = sext(b >> (p * i as u32), p) as i32;
            let acc = self.acc[i] as i32;
            self.acc[i] = acc.wrapping_add(la.wrapping_mul(lb)) as i64;
        }
    }

    /// Lane index that reads the unit's adder-tree output `acc_total`
    /// (paper Fig. 2 / Eq. 1) instead of a single lane.
    pub const TOTAL_LANE: usize = 31;

    /// Read a lane accumulator as a 32-bit value (p = 32: lane 0 = low
    /// half, lane 1 = high half).  Lane [`Self::TOTAL_LANE`] reads the
    /// hardware-summed `acc_total`.
    pub fn read(&self, lane: usize) -> u32 {
        if lane == Self::TOTAL_LANE && self.cfg.precision < 32 {
            return self.total() as u32;
        }
        if self.cfg.precision >= 32 {
            match lane {
                0 => self.acc[0] as u32,
                1 => (self.acc[0] >> 32) as u32,
                _ => 0,
            }
        } else {
            self.acc.get(lane).map(|&a| a as u32).unwrap_or(0)
        }
    }

    /// Read d-bit chunk `part` of lane `lane` (narrow TP-ISA datapaths
    /// read wide accumulators in several pieces).
    pub fn read_chunk(&self, lane: usize, part: u32, datapath: u32) -> u64 {
        let acc = if self.cfg.precision >= 32 { self.acc[0] as u64 } else { self.read(lane) as u64 };
        let mask = if datapath >= 64 { u64::MAX } else { (1u64 << datapath) - 1 };
        (acc >> (part * datapath)) & mask
    }

    /// Read d-bit chunk `part` of the adder-tree total (the TP-ISA
    /// MACRD semantics: narrow datapaths read `acc_total` in pieces).
    pub fn read_total_chunk(&self, part: u32, datapath: u32) -> u64 {
        let acc = self.total() as u64;
        let mask = if datapath >= 64 { u64::MAX } else { (1u64 << datapath) - 1 };
        (acc >> (part * datapath)) & mask
    }

    /// Number of physical accumulator registers (1 for p = 32, one per
    /// lane otherwise) — the fault injector's target space.
    pub fn acc_regs(&self) -> usize {
        self.acc.len()
    }

    /// Flip one bit of one accumulator register — the soft-error model
    /// for a transient upset in the MAC result path (`sim::fault`).
    /// `lane` and `bit` are reduced modulo the physical state so any
    /// (u8, u8) pair is a valid fault site.
    pub fn flip_acc(&mut self, lane: usize, bit: u32) {
        let n = self.acc.len();
        let bits: u32 = if self.cfg.precision >= 32 { 64 } else { 32 };
        let a = &mut self.acc[lane % n];
        *a ^= 1i64 << (bit % bits);
        if bits == 32 {
            // p <= 16 accumulators are 32-bit registers stored
            // sign-extended in i64; re-normalise so reads stay
            // consistent with the `mac` write path.
            *a = *a as i32 as i64;
        }
    }

    /// Sum of all lane accumulators (paper Eq. 1: acc_total), wrapping
    /// in 32 bits for p <= 16.
    pub fn total(&self) -> i64 {
        if self.cfg.precision >= 32 {
            self.acc[0]
        } else {
            self.acc.iter().fold(0i32, |s, &a| s.wrapping_add(a as i32)) as i64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(d: u32, p: u32) -> MacState {
        MacState::new(MacConfig::new(d, p))
    }

    #[test]
    fn sext_basics() {
        assert_eq!(sext(0xff, 8), -1);
        assert_eq!(sext(0x7f, 8), 127);
        assert_eq!(sext(0x80, 8), -128);
        assert_eq!(sext(0xffff_ffff, 32), -1);
        assert_eq!(sext(0x8, 4), -8);
    }

    #[test]
    fn single_lane_32() {
        let mut m = mk(32, 32);
        m.mac(5u64, 7u64);
        m.mac((-3i32) as u32 as u64, 11);
        assert_eq!(m.total(), 35 - 33);
        // 64-bit accumulate: big products don't wrap at 32 bits.
        let mut m = mk(32, 32);
        m.mac(i32::MAX as u64, i32::MAX as u64);
        assert_eq!(m.total(), (i32::MAX as i64) * (i32::MAX as i64));
        assert_eq!(m.read(0), m.total() as u32);
        assert_eq!(m.read(1), (m.total() >> 32) as u32);
    }

    #[test]
    fn lane_isolation_p16() {
        // Mirrors python test_packed_simd_mac_lane_isolation.
        let mut m = mk(32, 16);
        // lane0 = 3 * 4, lane1 = 0.
        m.mac(3, 4);
        assert_eq!(m.read(0), 12);
        assert_eq!(m.read(1), 0);
        // lane1 only: values in the high half-word.
        let mut m = mk(32, 16);
        m.mac(5u64 << 16, 6u64 << 16);
        assert_eq!(m.read(0), 0);
        assert_eq!(m.read(1), 30);
    }

    #[test]
    fn negative_lanes_p8() {
        // Mirrors python test_packed_simd_mac_negative_lanes:
        // lanes a = [-128, -1, 127, -5], b = [127, -1, -128, 5].
        let pack = |lanes: [i8; 4]| -> u64 {
            lanes.iter().enumerate().fold(0u64, |w, (i, &v)| w | (((v as u8) as u64) << (8 * i)))
        };
        let mut m = mk(32, 8);
        m.mac(pack([-128, -1, 127, -5]), pack([127, -1, -128, 5]));
        assert_eq!(m.read(0) as i32, -128 * 127);
        assert_eq!(m.read(1) as i32, 1);
        assert_eq!(m.read(2) as i32, 127 * -128);
        assert_eq!(m.read(3) as i32, -25);
    }

    #[test]
    fn wraps_like_hardware_p16() {
        // Mirrors python test_packed_simd_mac_wraps_like_hardware.
        let big = 32767u64;
        let word = big | (big << 16);
        let mut m = mk(32, 16);
        for _ in 0..5000 {
            m.mac(word, word);
        }
        let want = ((5000i64 * 32767 * 32767 + (1 << 31)).rem_euclid(1 << 32)) - (1 << 31);
        assert_eq!(m.read(0) as i32 as i64, want);
        assert_eq!(m.read(1) as i32 as i64, want);
    }

    #[test]
    fn narrow_datapath_chunks() {
        let mut m = mk(8, 8);
        m.mac(100, 100); // acc = 10000 = 0x2710
        assert_eq!(m.read_chunk(0, 0, 8), 0x10);
        assert_eq!(m.read_chunk(0, 1, 8), 0x27);
        assert_eq!(m.read_chunk(0, 2, 8), 0);
    }

    #[test]
    fn clear_resets() {
        let mut m = mk(32, 4);
        m.mac(u64::MAX, u64::MAX); // all lanes (-1)*(-1)
        assert_eq!(m.lanes(), 8);
        for i in 0..8 {
            assert_eq!(m.read(i), 1);
        }
        m.clear();
        assert_eq!(m.total(), 0);
    }

    /// Property: for random packed words, the rust model matches a
    /// straightforward unpack-multiply-accumulate oracle.
    #[test]
    fn prop_matches_unpacked_oracle() {
        crate::util::prop::check("mac_model vs oracle", 300, |rng| {
            let p = *rng.choice(&[4u32, 8, 16]);
            let n_ops = rng.range_usize(1, 40);
            let mut m = mk(32, p);
            let lanes = (32 / p) as usize;
            let mut oracle = vec![0i32; lanes];
            for _ in 0..n_ops {
                let a = rng.next_u32() as u64;
                let b = rng.next_u32() as u64;
                m.mac(a, b);
                for (i, acc) in oracle.iter_mut().enumerate() {
                    let la = sext(a >> (p * i as u32), p) as i32;
                    let lb = sext(b >> (p * i as u32), p) as i32;
                    *acc = acc.wrapping_add(la.wrapping_mul(lb));
                }
            }
            for (i, &want) in oracle.iter().enumerate() {
                if m.read(i) as i32 != want {
                    return Err(format!("lane {i}: {} != {want}", m.read(i) as i32));
                }
            }
            Ok(())
        });
    }
}
