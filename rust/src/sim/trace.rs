//! Execution profiles — the measured inputs to the bespoke reduction
//! pass (workflow step ③): which instructions, registers, CSRs and PC
//! range a workload actually uses.

use std::collections::BTreeMap;
use std::sync::Arc;

/// Compile-time tracing mode of the ISS run loops.
///
/// Both simulators' `run_traced` loops are generic over a `TraceMode`,
/// so the per-retire profiling work (histogram update, register
/// bitmask, max-PC tracking) monomorphizes away entirely when the
/// caller only needs scores and cycle counts — there is no runtime
/// branch, no function-pointer call, and no dead profile buffer in the
/// hot loop (§Perf iteration 3).  Cheap aggregate counters (cycles,
/// instructions, loads/stores, mul/mac ops, branches taken, BAR reach)
/// are maintained in every mode, so `cycles_per_sample` and the MIPS
/// metric stay available.
///
/// Who uses which mode:
///
/// * [`FullProfile`] — the bespoke reduction pass
///   (`bespoke::profile`), which needs the complete utilization
///   picture, and every pre-existing `run()` call site.
/// * [`CyclesOnly`] — the DSE cycle sweeps (`dse::sweep`), the
///   coordinator crosscheck, and accuracy/serving runs, which consume
///   only scores, predictions and cycle counts.
pub trait TraceMode {
    /// Whether per-retire profiling (instruction histogram, register
    /// bitmask, max-PC) is compiled into the run loop.
    const PROFILE: bool;
    /// Where the batched lockstep engine (`sim::batch`) books a
    /// translated block's aggregate counters: `true` applies them to
    /// each lane's own profile (so every lane profile equals its scalar
    /// run exactly), `false` applies them **once per block dispatch**,
    /// scaled by the lockstep lane count, to a batch-shared profile —
    /// the aggregates are additive and commutative, so the folded total
    /// (shared + per-lane) is identical either way, but the shared path
    /// touches one profile instead of N in the hot loop.  Lane-variant
    /// costs (taken-branch extras, fallback steps) always stay on the
    /// lane profile.
    const LANE_PROFILE: bool;
}

/// Full utilization tracing — reproduces the pre-rework [`Profile`]
/// exactly (bit-identical histograms, register masks and PC reach).
pub struct FullProfile;

impl TraceMode for FullProfile {
    const PROFILE: bool = true;
    const LANE_PROFILE: bool = true;
}

/// Scores-and-cycles tracing: the retire path skips the histogram,
/// `record_reg` and `max_pc` updates.  The resulting [`Profile`] has
/// exact `cycles`/`instructions`/event counters and an empty
/// histogram/register mask.
pub struct CyclesOnly;

impl TraceMode for CyclesOnly {
    const PROFILE: bool = false;
    const LANE_PROFILE: bool = false;
}

/// Accumulated profile of one or more program executions.
///
/// The dynamic histogram is stored as a flat array indexed by the
/// ISA's per-instruction `mnemonic_id` — the retire path is one add and
/// one array store (§Perf iteration 2; the original BTreeMap lookup per
/// retired instruction dominated the ISS hot loop).
#[derive(Debug, Clone, Default)]
pub struct Profile {
    /// Dynamic instruction counts indexed by mnemonic id.
    counts: Vec<u64>,
    /// id -> mnemonic (recorded on first retire of each id).
    names: Vec<&'static str>,
    /// Static mnemonics present in the program image — `Arc`-shared
    /// with the prepared image, so simulator construction is a pointer
    /// copy instead of a `BTreeSet` rebuild.
    pub static_mnemonics: Arc<std::collections::BTreeSet<&'static str>>,
    /// Bitmask of registers read or written.
    pub regs_used: u32,
    /// Highest PC fetched (byte address).
    pub max_pc: u32,
    /// Any CSR instruction executed or present.
    pub csr_used: bool,
    /// Any ecall/ebreak beyond the final halt.
    pub syscalls_used: bool,
    pub cycles: u64,
    pub instructions: u64,
    pub loads: u64,
    pub stores: u64,
    pub mul_ops: u64,
    pub mac_ops: u64,
    pub branches_taken: u64,
    /// Largest byte offset touched in RAM (BAR reach).
    pub max_ram_offset: u32,
}

impl Profile {
    /// Hot path: one retire.  `id` must be stable per mnemonic within
    /// one ISA (see `Instr::mnemonic_id`).
    #[inline]
    pub fn record_instr(&mut self, id: usize, mnemonic: &'static str) {
        if id >= self.counts.len() {
            self.counts.resize(id + 1, 0);
            self.names.resize(id + 1, "");
        }
        self.counts[id] += 1;
        if self.names[id].is_empty() {
            self.names[id] = mnemonic;
        }
        self.instructions += 1;
    }

    /// Apply a translated block's histogram delta: one add per distinct
    /// mnemonic in the block instead of one per retire (the caller adds
    /// `instructions` separately).  Ids follow `Instr::mnemonic_id`.
    #[inline]
    pub fn record_block(&mut self, counts: &[(u16, &'static str, u32)]) {
        for &(id, mnemonic, n) in counts {
            let id = id as usize;
            if id >= self.counts.len() {
                self.counts.resize(id + 1, 0);
                self.names.resize(id + 1, "");
            }
            self.counts[id] += n as u64;
            if self.names[id].is_empty() {
                self.names[id] = mnemonic;
            }
        }
    }

    /// Cold path: add a count by name (merging, tests).
    pub fn add_count(&mut self, mnemonic: &'static str, n: u64) {
        if let Some(i) = self.names.iter().position(|&m| m == mnemonic) {
            self.counts[i] += n;
        } else {
            self.names.push(mnemonic);
            self.counts.push(n);
        }
    }

    /// Dynamic histogram keyed by mnemonic.
    pub fn instr_counts(&self) -> BTreeMap<&'static str, u64> {
        self.names
            .iter()
            .zip(&self.counts)
            .filter(|(m, &c)| !m.is_empty() && c > 0)
            .map(|(&m, &c)| (m, c))
            .collect()
    }

    /// Count of one mnemonic.
    pub fn count(&self, mnemonic: &str) -> u64 {
        self.names
            .iter()
            .position(|&m| m == mnemonic)
            .map(|i| self.counts[i])
            .unwrap_or(0)
    }

    pub fn record_reg(&mut self, r: u8) {
        self.regs_used |= 1 << r;
    }

    /// Number of distinct registers used.
    pub fn reg_count(&self) -> u32 {
        self.regs_used.count_ones()
    }

    /// PC bits needed to address the executed code (highest byte
    /// address inclusive).
    pub fn pc_bits_needed(&self) -> u32 {
        self.max_pc.saturating_add(1).max(2).next_power_of_two().trailing_zeros()
    }

    /// Address bits needed for the RAM working set.
    pub fn bar_bits_needed(&self) -> u32 {
        self.max_ram_offset.saturating_add(1).max(2).next_power_of_two().trailing_zeros()
    }

    /// Merge another profile into this one (suite-level aggregation).
    /// Merges by *name* — the two ISAs have different id spaces.
    pub fn merge(&mut self, other: &Profile) {
        for (m, c) in other.instr_counts() {
            self.add_count(m, c);
        }
        if !other.static_mnemonics.is_empty() {
            if self.static_mnemonics.is_empty() {
                self.static_mnemonics = Arc::clone(&other.static_mnemonics);
            } else if !Arc::ptr_eq(&self.static_mnemonics, &other.static_mnemonics) {
                Arc::make_mut(&mut self.static_mnemonics)
                    .extend(other.static_mnemonics.iter().copied());
            }
        }
        self.regs_used |= other.regs_used;
        self.max_pc = self.max_pc.max(other.max_pc);
        self.csr_used |= other.csr_used;
        self.syscalls_used |= other.syscalls_used;
        self.cycles += other.cycles;
        self.instructions += other.instructions;
        self.loads += other.loads;
        self.stores += other.stores;
        self.mul_ops += other.mul_ops;
        self.mac_ops += other.mac_ops;
        self.branches_taken += other.branches_taken;
        self.max_ram_offset = self.max_ram_offset.max(other.max_ram_offset);
    }

    /// Mnemonics from `all` that never appear (statically or
    /// dynamically) — the "unused instructions" of §III-A.
    pub fn unused_mnemonics<'a>(&self, all: &[&'a str]) -> Vec<&'a str> {
        all.iter()
            .filter(|m| {
                !self.names.iter().any(|k| k == *m)
                    && !self.static_mnemonics.iter().any(|k| k == *m)
            })
            .copied()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reg_tracking() {
        let mut p = Profile::default();
        p.record_reg(0);
        p.record_reg(5);
        p.record_reg(5);
        p.record_reg(31);
        assert_eq!(p.reg_count(), 3);
        assert_eq!(p.regs_used, 1 | (1 << 5) | (1 << 31));
    }

    #[test]
    fn pc_bits() {
        let mut p = Profile::default();
        p.max_pc = 1000; // needs 10 bits
        assert_eq!(p.pc_bits_needed(), 10);
        p.max_pc = 1023;
        assert_eq!(p.pc_bits_needed(), 10);
        p.max_pc = 1024;
        assert_eq!(p.pc_bits_needed(), 11);
        p.max_pc = 130;
        assert_eq!(p.pc_bits_needed(), 8);
    }

    #[test]
    fn merge_and_unused() {
        let mut a = Profile::default();
        a.record_instr(0, "add");
        a.record_reg(1);
        let mut b = Profile::default();
        b.record_instr(1, "mul");
        b.record_instr(0, "add");
        b.record_reg(2);
        a.merge(&b);
        assert_eq!(a.count("add"), 2);
        assert_eq!(a.count("mul"), 1);
        assert_eq!(a.reg_count(), 2);
        assert_eq!(a.unused_mnemonics(&["add", "mul", "slt", "csrrw"]), vec!["slt", "csrrw"]);
    }
}
