//! Batched lockstep execution of translated blocks (§Perf iteration 5).
//!
//! Inference programs have near-static control flow: every sample of a
//! (model, variant) walks (almost) the same basic-block sequence.  The
//! paper's SIMD MAC exploits that regularity *across vector elements*;
//! this module plays the identical card one level up, *across samples*
//! — structure-of-arrays style: one shared `Prepared*` image, N
//! register files, N RAM/dmem lanes and N fuel counters, with each
//! [`TranslatedRv32`](super::translate::TranslatedRv32) /
//! [`TranslatedTpIsa`](super::translate::TranslatedTpIsa) block fetched
//! and decoded **once** and its micro-ops retired lane-parallel.
//!
//! # Scheduling and divergence
//!
//! Each round picks the **lowest PC among running lanes** as the group
//! leader and executes every lane sitting at that PC (a SIMT-style
//! reconvergence heuristic: lanes that fell behind at a forward branch
//! catch up to the join point before the front advances, so the lockstep
//! group re-forms as soon as control flow reconverges).  Lanes at other
//! PCs simply wait — their state is untouched, so waiting is free and
//! exact.  Within a group:
//!
//! * if the leader PC is a translated block leader, each member passes
//!   the *same per-lane fuel check* as the scalar engine; members with
//!   enough fuel retire the block micro-op-major (one decode, N lanes),
//!   while fuel-starved members take one scalar fallback step;
//! * a lane whose micro-op faults retires with that `Err` and is masked
//!   out of the remaining micro-ops — sibling lanes never observe it;
//! * if the leader PC is not a block leader (dynamic `jalr` landing
//!   mid-block, misaligned PC, untranslatable block), every member
//!   drains one step on the scalar `step_traced` interpreter path.
//!
//! Every member makes progress (or retires) each round, so the loop
//! cannot livelock; divergence and rejoin need no bookkeeping beyond
//! the per-round regrouping.
//!
//! # Bit-identity
//!
//! A lane's state evolution is a pure function of its own state — lanes
//! share only the immutable prepared image — and whenever a lane *is*
//! scheduled, the decision procedure (leader lookup, fuel check, block
//! vs fallback) and the retire primitives (`exec_uop`, `apply_block`,
//! `apply_term`, `step_traced`) are exactly the scalar
//! `run_translated`'s, in the same order.  So every lane is
//! bit-identical to a scalar run of the same sample: registers, memory,
//! halt/error, profile and `ExecStats` (`tests/iss_batch_equivalence.rs`
//! pins this differentially, including on divergence-adversarial fuzz).
//!
//! # Profiles
//!
//! Under a [`TraceMode`] with `LANE_PROFILE = true` (FullProfile) block
//! aggregates go to each lane's own profile — each equals its scalar
//! run exactly.  With `LANE_PROFILE = false` (CyclesOnly) the
//! aggregates are booked **once per dispatch** on a batch-shared
//! profile, scaled by the lockstep member count; lane-variant costs
//! (taken branches, fallback steps) stay on the lane profiles.  The
//! counters are additive and commutative, so [`BatchRv32::fold_profile`]
//! (shared + every lane) equals the scalar totals either way.

use std::sync::Arc;

use anyhow::Result;

use super::prepared::{PreparedRv32, PreparedTpIsa};
use super::tpisa::{Halt as HaltTp, TpIsa};
use super::trace::{Profile, TraceMode};
use super::translate::{BlockRv32, BlockTpIsa, ExecStats, NO_BLOCK};
use super::zero_riscy::{Halt as HaltRv32, ZeroRiscy};
use crate::isa::rv32::Instr as InstrRv32;
use crate::isa::tpisa::Instr as InstrTp;

/// Book a block's aggregates on the batch-shared profile, scaled by the
/// lockstep member count (the `LANE_PROFILE = false` path; such modes
/// never profile per retire, so the histogram/reg-mask/max-PC parts do
/// not apply here).
fn apply_block_rv32_shared(p: &mut Profile, b: &BlockRv32, k: u64) {
    p.cycles += b.base_cycles * k;
    p.instructions += b.n_instrs as u64 * k;
    p.loads += b.loads * k;
    p.stores += b.stores * k;
    p.mul_ops += b.mul_ops * k;
    p.mac_ops += b.mac_ops * k;
    p.branches_taken += b.branches_taken * k;
    if b.csr_used {
        p.csr_used = true;
    }
}

/// TP-ISA twin of [`apply_block_rv32_shared`] (no `mul_ops`/`csr_used`
/// — the ISA has neither).
fn apply_block_tpisa_shared(p: &mut Profile, b: &BlockTpIsa, k: u64) {
    p.cycles += b.base_cycles * k;
    p.instructions += b.n_instrs as u64 * k;
    p.loads += b.loads * k;
    p.stores += b.stores * k;
    p.mac_ops += b.mac_ops * k;
    p.branches_taken += b.branches_taken * k;
}

/// N Zero-Riscy lanes over one shared prepared image, executed in
/// lockstep per translated block.
pub struct BatchRv32 {
    prepared: Arc<PreparedRv32>,
    lanes: Vec<ZeroRiscy>,
    /// Per-lane retired-instruction count for the current run (the
    /// scalar engine's `executed` fuel cursor, one per lane).
    executed: Vec<u64>,
    /// Per-lane outcome; `Some` = retired for the current run.
    done: Vec<Option<Result<HaltRv32>>>,
    /// Batch-shared block aggregates (`LANE_PROFILE = false` modes).
    /// Accumulates across runs, like a simulator profile.
    shared: Profile,
    /// Current lockstep group (lane indices), reused across rounds.
    members: Vec<usize>,
}

impl BatchRv32 {
    /// Build `lanes` simulators over one shared prepared image.
    pub fn new(prepared: Arc<PreparedRv32>, lanes: usize) -> Self {
        assert!(lanes > 0, "batch needs at least one lane");
        BatchRv32 {
            lanes: (0..lanes).map(|_| ZeroRiscy::from_prepared(Arc::clone(&prepared))).collect(),
            executed: vec![0; lanes],
            done: (0..lanes).map(|_| None).collect(),
            shared: Profile::default(),
            members: Vec::with_capacity(lanes),
            prepared,
        }
    }

    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Lane `i`'s simulator (sample readout: registers, RAM, profile).
    pub fn lane(&self, i: usize) -> &ZeroRiscy {
        &self.lanes[i]
    }

    /// Lane `i`'s simulator, mutable (per-sample input preload).
    pub fn lane_mut(&mut self, i: usize) -> &mut ZeroRiscy {
        &mut self.lanes[i]
    }

    /// Reset every lane to the initial machine state (profiles keep
    /// accumulating, exactly like the scalar harness reuse pattern).
    pub fn reset(&mut self) {
        for lane in &mut self.lanes {
            lane.reset();
        }
    }

    /// Run lanes `0..active` in lockstep until each halts, faults or
    /// exhausts its own `fuel`.  Returns per-lane outcomes in lane
    /// order; lanes `active..` are untouched.
    pub fn run<M: TraceMode>(&mut self, active: usize, fuel: u64) -> Vec<Result<HaltRv32>> {
        assert!(active <= self.lanes.len(), "active lanes exceed batch width");
        let prepared = Arc::clone(&self.prepared);
        let code: &[InstrRv32] = &prepared.code;
        let trans = &prepared.translated;
        let blocks = trans.blocks.as_slice();
        let leaders: &[u32] = &trans.leaders;
        for i in 0..active {
            self.executed[i] = 0;
            self.done[i] = None;
        }
        loop {
            // Group leader: the lowest PC among running lanes.
            let mut lead = u32::MAX;
            let mut any = false;
            for i in 0..active {
                if self.done[i].is_none() {
                    any = true;
                    lead = lead.min(self.lanes[i].pc);
                }
            }
            if !any {
                break;
            }
            self.members.clear();
            for i in 0..active {
                if self.done[i].is_none() && self.lanes[i].pc == lead {
                    self.members.push(i);
                }
            }
            let mut bid = NO_BLOCK;
            if lead & 3 == 0 {
                if let Some(&b) = leaders.get((lead >> 2) as usize) {
                    bid = b;
                }
            }
            if bid != NO_BLOCK {
                let b = &blocks[bid as usize];
                let need = b.n_instrs as u64;
                // Per-lane fuel check, exactly the scalar dispatch
                // condition: starved members leave the lockstep group
                // and take one scalar fallback step instead.
                let mut w = 0;
                for mi in 0..self.members.len() {
                    let i = self.members[mi];
                    if fuel - self.executed[i] >= need {
                        self.members[w] = i;
                        w += 1;
                    } else {
                        self.step_lane::<M>(i, code, fuel);
                    }
                }
                self.members.truncate(w);
                // Scalar dispatch order: fuel and block counter first,
                // then micro-ops, then aggregates, then the terminator.
                for mi in 0..self.members.len() {
                    let i = self.members[mi];
                    self.executed[i] += need;
                    self.lanes[i].exec_stats.blocks += 1;
                    self.lanes[i].exec_stats.fused_uops += b.fused as u64;
                }
                // Micro-op-major lockstep: one decode, all lanes.  A
                // faulting lane retires with its `Err` and is masked
                // out; siblings never observe it.
                for u in b.uops.iter() {
                    let mut w = 0;
                    for mi in 0..self.members.len() {
                        let i = self.members[mi];
                        match self.lanes[i].exec_uop(u) {
                            Ok(()) => {
                                self.members[w] = i;
                                w += 1;
                            }
                            Err(e) => self.done[i] = Some(Err(e)),
                        }
                    }
                    self.members.truncate(w);
                    if self.members.is_empty() {
                        break;
                    }
                }
                // Per-lane fault clocks tick at the block boundary —
                // the same point the scalar translated engine ticks, so
                // an armed lane stays bit-identical to its scalar run.
                for mi in 0..self.members.len() {
                    let i = self.members[mi];
                    self.lanes[i].fault_tick(need);
                }
                if M::LANE_PROFILE {
                    for mi in 0..self.members.len() {
                        let i = self.members[mi];
                        self.lanes[i].apply_block::<M>(b);
                    }
                } else {
                    apply_block_rv32_shared(&mut self.shared, b, self.members.len() as u64);
                }
                for mi in 0..self.members.len() {
                    let i = self.members[mi];
                    if let Some(h) = self.lanes[i].apply_term(b) {
                        self.done[i] = Some(Ok(h));
                    }
                }
            } else {
                // Not a block leader (dynamic jalr mid-block target,
                // misaligned PC, untranslatable block): drain one
                // scalar interpreter step per member.
                for mi in 0..self.members.len() {
                    let i = self.members[mi];
                    self.step_lane::<M>(i, code, fuel);
                }
            }
        }
        (0..active).map(|i| self.done[i].take().expect("lane retired")).collect()
    }

    /// One scalar fallback step for lane `i` — the scalar engine's
    /// fallback tail, verbatim: fuel check, fallback counter, one
    /// `step_traced`.
    fn step_lane<M: TraceMode>(&mut self, i: usize, code: &[InstrRv32], fuel: u64) {
        if self.executed[i] >= fuel {
            self.done[i] = Some(Ok(HaltRv32::Fuel));
            return;
        }
        self.executed[i] += 1;
        self.lanes[i].exec_stats.fallback_instrs += 1;
        match self.lanes[i].step_traced::<M>(code) {
            Ok(None) => {}
            Ok(Some(h)) => self.done[i] = Some(Ok(h)),
            Err(e) => self.done[i] = Some(Err(e)),
        }
    }

    /// Merge the batch-shared profile and every lane profile into
    /// `into` — the batch total, equal to the scalar per-sample totals
    /// (the aggregates are additive and commutative).
    pub fn fold_profile(&self, into: &mut Profile) {
        into.merge(&self.shared);
        for lane in &self.lanes {
            into.merge(&lane.profile);
        }
    }

    /// Summed translated-engine counters across lanes; the fallback
    /// share of retired instructions is the batch's divergence rate.
    pub fn exec_stats(&self) -> ExecStats {
        let mut s = ExecStats::default();
        for lane in &self.lanes {
            s.merge(&lane.exec_stats);
        }
        s
    }
}

/// N TP-ISA lanes over one shared prepared image, executed in lockstep
/// per translated block.  Mirrors [`BatchRv32`]; see the module docs.
pub struct BatchTpIsa {
    prepared: Arc<PreparedTpIsa>,
    lanes: Vec<TpIsa>,
    executed: Vec<u64>,
    done: Vec<Option<Result<HaltTp>>>,
    shared: Profile,
    members: Vec<usize>,
}

impl BatchTpIsa {
    /// Build `lanes` simulators over one shared prepared image.
    pub fn new(prepared: Arc<PreparedTpIsa>, lanes: usize) -> Self {
        assert!(lanes > 0, "batch needs at least one lane");
        BatchTpIsa {
            lanes: (0..lanes).map(|_| TpIsa::from_prepared(Arc::clone(&prepared))).collect(),
            executed: vec![0; lanes],
            done: (0..lanes).map(|_| None).collect(),
            shared: Profile::default(),
            members: Vec::with_capacity(lanes),
            prepared,
        }
    }

    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Lane `i`'s simulator (sample readout).
    pub fn lane(&self, i: usize) -> &TpIsa {
        &self.lanes[i]
    }

    /// Lane `i`'s simulator, mutable (per-sample input preload).
    pub fn lane_mut(&mut self, i: usize) -> &mut TpIsa {
        &mut self.lanes[i]
    }

    /// Reset every lane (dmem memcpy-restored from the prepared image;
    /// profiles keep accumulating).
    pub fn reset(&mut self) {
        for lane in &mut self.lanes {
            lane.reset();
        }
    }

    /// Run lanes `0..active` in lockstep until each halts, faults or
    /// exhausts its own `fuel`.  Returns per-lane outcomes in lane
    /// order; lanes `active..` are untouched.
    pub fn run<M: TraceMode>(&mut self, active: usize, fuel: u64) -> Vec<Result<HaltTp>> {
        assert!(active <= self.lanes.len(), "active lanes exceed batch width");
        let prepared = Arc::clone(&self.prepared);
        let code: &[InstrTp] = &prepared.code;
        let trans = &prepared.translated;
        let blocks = trans.blocks.as_slice();
        let leaders: &[u32] = &trans.leaders;
        let mask = if prepared.width == 64 { u64::MAX } else { (1u64 << prepared.width) - 1 };
        let msb = 1u64 << (prepared.width - 1);
        for i in 0..active {
            self.executed[i] = 0;
            self.done[i] = None;
        }
        loop {
            let mut lead = i64::MAX;
            let mut any = false;
            for i in 0..active {
                if self.done[i].is_none() {
                    any = true;
                    lead = lead.min(self.lanes[i].pc);
                }
            }
            if !any {
                break;
            }
            self.members.clear();
            for i in 0..active {
                if self.done[i].is_none() && self.lanes[i].pc == lead {
                    self.members.push(i);
                }
            }
            let mut bid = NO_BLOCK;
            if let Ok(idx) = usize::try_from(lead) {
                if let Some(&b) = leaders.get(idx) {
                    bid = b;
                }
            }
            if bid != NO_BLOCK {
                let b = &blocks[bid as usize];
                let need = b.n_instrs as u64;
                let mut w = 0;
                for mi in 0..self.members.len() {
                    let i = self.members[mi];
                    if fuel - self.executed[i] >= need {
                        self.members[w] = i;
                        w += 1;
                    } else {
                        self.step_lane::<M>(i, code, mask, msb, fuel);
                    }
                }
                self.members.truncate(w);
                for mi in 0..self.members.len() {
                    let i = self.members[mi];
                    self.executed[i] += need;
                    self.lanes[i].exec_stats.blocks += 1;
                    self.lanes[i].exec_stats.fused_uops += b.fused as u64;
                }
                for u in b.uops.iter() {
                    let mut w = 0;
                    for mi in 0..self.members.len() {
                        let i = self.members[mi];
                        match self.lanes[i].exec_uop(u, mask, msb) {
                            Ok(()) => {
                                self.members[w] = i;
                                w += 1;
                            }
                            Err(e) => self.done[i] = Some(Err(e)),
                        }
                    }
                    self.members.truncate(w);
                    if self.members.is_empty() {
                        break;
                    }
                }
                // Per-lane fault clocks tick at the block boundary (see
                // the RV32 twin above).
                for mi in 0..self.members.len() {
                    let i = self.members[mi];
                    self.lanes[i].fault_tick(need);
                }
                if M::LANE_PROFILE {
                    for mi in 0..self.members.len() {
                        let i = self.members[mi];
                        self.lanes[i].apply_block::<M>(b);
                    }
                } else {
                    apply_block_tpisa_shared(&mut self.shared, b, self.members.len() as u64);
                }
                for mi in 0..self.members.len() {
                    let i = self.members[mi];
                    if let Some(h) = self.lanes[i].apply_term(b) {
                        self.done[i] = Some(Ok(h));
                    }
                }
            } else {
                for mi in 0..self.members.len() {
                    let i = self.members[mi];
                    self.step_lane::<M>(i, code, mask, msb, fuel);
                }
            }
        }
        (0..active).map(|i| self.done[i].take().expect("lane retired")).collect()
    }

    /// One scalar fallback step for lane `i` — the scalar engine's
    /// fallback tail, verbatim.
    fn step_lane<M: TraceMode>(
        &mut self,
        i: usize,
        code: &[InstrTp],
        mask: u64,
        msb: u64,
        fuel: u64,
    ) {
        if self.executed[i] >= fuel {
            self.done[i] = Some(Ok(HaltTp::Fuel));
            return;
        }
        self.executed[i] += 1;
        self.lanes[i].exec_stats.fallback_instrs += 1;
        match self.lanes[i].step_traced::<M>(code, mask, msb) {
            Ok(None) => {}
            Ok(Some(h)) => self.done[i] = Some(Ok(h)),
            Err(e) => self.done[i] = Some(Err(e)),
        }
    }

    /// Merge the batch-shared profile and every lane profile into
    /// `into` — the batch total, equal to the scalar per-sample totals.
    pub fn fold_profile(&self, into: &mut Profile) {
        into.merge(&self.shared);
        for lane in &self.lanes {
            into.merge(&lane.profile);
        }
    }

    /// Summed translated-engine counters across lanes.
    pub fn exec_stats(&self) -> ExecStats {
        let mut s = ExecStats::default();
        for lane in &self.lanes {
            s.merge(&lane.exec_stats);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::rv32_asm::assemble;
    use crate::sim::mem::RAM_BASE;
    use crate::sim::trace::{CyclesOnly, FullProfile};

    /// A data-dependent countdown: RAM[0] holds n, the loop trip count,
    /// so every lane diverges at a different iteration.
    fn countdown_rv32() -> Arc<PreparedRv32> {
        let text = format!(
            r#"
                li   s0, {RAM_BASE}
                lw   t0, 0(s0)
                li   t1, 0
            loop:
                beqz t0, done
                add  t1, t1, t0
                addi t0, t0, -1
                j    loop
            done:
                sw   t1, 4(s0)
                ebreak
            "#
        );
        let prog = assemble(&text).unwrap();
        Arc::new(PreparedRv32::new(&prog, &[], 64, None))
    }

    fn scalar_rv32(prepared: &Arc<PreparedRv32>, n: u32, fuel: u64) -> (ZeroRiscy, Result<HaltRv32>) {
        let mut sim = ZeroRiscy::from_prepared(Arc::clone(prepared));
        sim.mem.store_u32(RAM_BASE, n).unwrap();
        let r = sim.run_translated::<FullProfile>(fuel);
        (sim, r)
    }

    #[test]
    fn rv32_divergent_lanes_match_scalar_runs() {
        let prepared = countdown_rv32();
        let inputs = [0u32, 3, 10, 1, 7];
        let mut batch = BatchRv32::new(Arc::clone(&prepared), inputs.len());
        for (i, &n) in inputs.iter().enumerate() {
            batch.lane_mut(i).mem.store_u32(RAM_BASE, n).unwrap();
        }
        let results = batch.run::<FullProfile>(inputs.len(), 10_000);
        for (i, (r, &n)) in results.into_iter().zip(&inputs).enumerate() {
            assert_eq!(r.unwrap(), HaltRv32::Break, "lane {i}");
            let (sref, rref) = scalar_rv32(&prepared, n, 10_000);
            assert_eq!(rref.unwrap(), HaltRv32::Break);
            assert_eq!(batch.lane(i).regs, sref.regs, "lane {i}: regs");
            assert_eq!(batch.lane(i).pc, sref.pc, "lane {i}: pc");
            assert_eq!(batch.lane(i).mem.ram, sref.mem.ram, "lane {i}: ram");
            assert_eq!(batch.lane(i).profile.cycles, sref.profile.cycles, "lane {i}: cycles");
            assert_eq!(
                batch.lane(i).profile.instr_counts(),
                sref.profile.instr_counts(),
                "lane {i}: histogram"
            );
            assert_eq!(batch.lane(i).exec_stats.blocks, sref.exec_stats.blocks, "lane {i}");
            assert_eq!(
                batch.lane(i).exec_stats.fused_uops,
                sref.exec_stats.fused_uops,
                "lane {i}"
            );
            assert_eq!(
                batch.lane(i).exec_stats.fallback_instrs,
                sref.exec_stats.fallback_instrs,
                "lane {i}"
            );
        }
    }

    #[test]
    fn rv32_per_lane_fuel_and_folded_cycles_only_profile() {
        let prepared = countdown_rv32();
        let inputs = [1u32, 200, 2, 150];
        // Small fuel: the long lanes burn out mid-loop, short ones halt.
        let fuel = 60;
        let mut batch = BatchRv32::new(Arc::clone(&prepared), inputs.len());
        for (i, &n) in inputs.iter().enumerate() {
            batch.lane_mut(i).mem.store_u32(RAM_BASE, n).unwrap();
        }
        let results = batch.run::<CyclesOnly>(inputs.len(), fuel);
        let mut want = Profile::default();
        for (i, (r, &n)) in results.into_iter().zip(&inputs).enumerate() {
            let mut sref = ZeroRiscy::from_prepared(Arc::clone(&prepared));
            sref.mem.store_u32(RAM_BASE, n).unwrap();
            let halt = sref.run_translated::<CyclesOnly>(fuel).unwrap();
            assert_eq!(r.unwrap(), halt, "lane {i}: halt kind");
            assert_eq!(batch.lane(i).regs, sref.regs, "lane {i}: regs");
            assert_eq!(batch.lane(i).mem.ram, sref.mem.ram, "lane {i}: ram");
            want.merge(&sref.profile);
        }
        let mut got = Profile::default();
        batch.fold_profile(&mut got);
        assert_eq!(got.cycles, want.cycles);
        assert_eq!(got.instructions, want.instructions);
        assert_eq!(got.loads, want.loads);
        assert_eq!(got.stores, want.stores);
        assert_eq!(got.branches_taken, want.branches_taken);
        assert!(got.instr_counts().is_empty());
    }

    #[test]
    fn rv32_poisoned_lane_does_not_perturb_siblings() {
        // MAC on a MAC-less core: every lane faults identically, but
        // the point is that a faulting lane's siblings still match
        // their scalar runs.  Mix in a lane that never reaches the MAC.
        let text = format!(
            r#"
                li   s0, {RAM_BASE}
                lw   t0, 0(s0)
                beqz t0, done
                mac  t0, t0
            done:
                ebreak
            "#
        );
        let prog = assemble(&text).unwrap();
        let prepared = Arc::new(PreparedRv32::new(&prog, &[], 64, None));
        let inputs = [0u32, 1, 0];
        let mut batch = BatchRv32::new(Arc::clone(&prepared), inputs.len());
        for (i, &n) in inputs.iter().enumerate() {
            batch.lane_mut(i).mem.store_u32(RAM_BASE, n).unwrap();
        }
        let results = batch.run::<FullProfile>(inputs.len(), 1000);
        for (i, (r, &n)) in results.into_iter().zip(&inputs).enumerate() {
            let (sref, rref) = scalar_rv32(&prepared, n, 1000);
            match (r, rref) {
                (Ok(h), Ok(hr)) => {
                    assert_eq!(h, hr, "lane {i}: halt kind");
                    assert_eq!(batch.lane(i).regs, sref.regs, "lane {i}: regs");
                    assert_eq!(batch.lane(i).mem.ram, sref.mem.ram, "lane {i}: ram");
                }
                (Err(e), Err(er)) => {
                    assert_eq!(e.to_string(), er.to_string(), "lane {i}: error");
                }
                (r, rr) => panic!("lane {i}: divergent outcome {r:?} vs {rr:?}"),
            }
        }
    }

    #[test]
    fn tpisa_divergent_lanes_match_scalar_runs() {
        use crate::isa::tpisa::{Asm, Instr};
        // dmem[0] holds n; sum a countdown into r1 (n = 0 wraps through
        // the full 8-bit range, so lanes diverge by hundreds of steps).
        let mut a = Asm::new();
        a.ldi(2, 0);
        a.push(Instr::Ld { r1: 0, r2: 2, imm: 0 });
        a.ldi(1, 0);
        a.label("loop");
        a.push(Instr::Add { r1: 1, r2: 0 });
        a.push(Instr::Addi { r1: 0, imm: -1 });
        a.bnz("loop");
        a.push(Instr::St { r1: 1, r2: 2, imm: 1 });
        a.push(Instr::Halt);
        let prog = a.finish().unwrap();
        let prepared = Arc::new(PreparedTpIsa::with_zero_dmem(8, &prog, 8, None));
        let inputs = [3u64, 0, 10, 1];
        let mut batch = BatchTpIsa::new(Arc::clone(&prepared), inputs.len());
        for (i, &n) in inputs.iter().enumerate() {
            batch.lane_mut(i).dmem.store(0, n).unwrap();
        }
        let results = batch.run::<FullProfile>(inputs.len(), 100_000);
        for (i, (r, &n)) in results.into_iter().zip(&inputs).enumerate() {
            assert_eq!(r.unwrap(), HaltTp::Halted, "lane {i}");
            let mut sref = TpIsa::from_prepared(Arc::clone(&prepared));
            sref.dmem.store(0, n).unwrap();
            assert_eq!(sref.run_translated::<FullProfile>(100_000).unwrap(), HaltTp::Halted);
            assert_eq!(batch.lane(i).regs, sref.regs, "lane {i}: regs");
            assert_eq!(batch.lane(i).pc, sref.pc, "lane {i}: pc");
            assert_eq!(batch.lane(i).carry, sref.carry, "lane {i}: carry");
            assert_eq!(batch.lane(i).zero, sref.zero, "lane {i}: zero");
            let words = sref.dmem.len();
            assert_eq!(
                batch.lane(i).dmem.read_words(0, words).unwrap(),
                sref.dmem.read_words(0, words).unwrap(),
                "lane {i}: dmem"
            );
            assert_eq!(batch.lane(i).profile.cycles, sref.profile.cycles, "lane {i}: cycles");
            assert_eq!(batch.lane(i).exec_stats.blocks, sref.exec_stats.blocks, "lane {i}");
            assert_eq!(
                batch.lane(i).exec_stats.fused_uops,
                sref.exec_stats.fused_uops,
                "lane {i}"
            );
            assert_eq!(
                batch.lane(i).exec_stats.fallback_instrs,
                sref.exec_stats.fallback_instrs,
                "lane {i}"
            );
        }
    }

    #[test]
    fn faulted_lane_matches_scalar_and_leaves_siblings_clean() {
        use crate::sim::fault::{BitFlip, FaultPlan, FaultState, FlipTarget};
        let prepared = countdown_rv32();
        let plan = FaultPlan {
            flips: vec![BitFlip { at_instr: 6, target: FlipTarget::Reg(6), bit: 3 }],
            mac_flips: vec![],
        };
        let inputs = [5u32, 5, 5];
        let mut batch = BatchRv32::new(Arc::clone(&prepared), inputs.len());
        for (i, &n) in inputs.iter().enumerate() {
            batch.lane_mut(i).mem.store_u32(RAM_BASE, n).unwrap();
        }
        // Arm the middle lane only.
        batch.lane_mut(1).fault = FaultState::armed(plan.clone());
        for r in batch.run::<FullProfile>(inputs.len(), 10_000) {
            r.unwrap();
        }
        // The armed lane matches a scalar translated run with the same
        // plan; its clean siblings match the clean scalar run.
        let mut faulted = ZeroRiscy::from_prepared(Arc::clone(&prepared));
        faulted.mem.store_u32(RAM_BASE, 5).unwrap();
        faulted.fault = FaultState::armed(plan);
        faulted.run_translated::<FullProfile>(10_000).unwrap();
        let (clean, _) = scalar_rv32(&prepared, 5, 10_000);
        assert_eq!(batch.lane(1).regs, faulted.regs);
        assert_eq!(batch.lane(1).mem.ram, faulted.mem.ram);
        assert_ne!(batch.lane(1).regs, clean.regs, "flip was masked — pick a livelier site");
        for i in [0, 2] {
            assert_eq!(batch.lane(i).regs, clean.regs, "lane {i} perturbed by sibling fault");
            assert_eq!(batch.lane(i).mem.ram, clean.mem.ram, "lane {i}");
        }
    }

    #[test]
    fn reset_reuses_lanes_like_the_scalar_harness() {
        let prepared = countdown_rv32();
        let mut batch = BatchRv32::new(Arc::clone(&prepared), 2);
        for (i, n) in [4u32, 6].into_iter().enumerate() {
            batch.lane_mut(i).mem.store_u32(RAM_BASE, n).unwrap();
        }
        for r in batch.run::<FullProfile>(2, 10_000) {
            assert_eq!(r.unwrap(), HaltRv32::Break);
        }
        let cycles_once: u64 = batch.lane(0).profile.cycles + batch.lane(1).profile.cycles;
        batch.reset();
        assert_eq!(batch.lane(0).mem.load_u32(RAM_BASE).unwrap(), 0);
        for (i, n) in [4u32, 6].into_iter().enumerate() {
            batch.lane_mut(i).mem.store_u32(RAM_BASE, n).unwrap();
        }
        for r in batch.run::<FullProfile>(2, 10_000) {
            assert_eq!(r.unwrap(), HaltRv32::Break);
        }
        let mut folded = Profile::default();
        batch.fold_profile(&mut folded);
        assert_eq!(folded.cycles, 2 * cycles_once);
    }
}
