//! Seeded fault-injecting TCP proxy (offline substitute for toxiproxy):
//! sits between a device fleet and the serving frontend and injects the
//! network pathologies the robustness layer claims to survive —
//! connection resets mid-body, byte-drip throttling, response
//! truncation, blackhole stalls, and added latency.
//!
//! Determinism is the point: every behaviour is a pure PCG function of
//! `(seed, connection ordinal, profile)` ([`plan_for`]), so a CI chaos
//! run with `--chaos-seed 7` draws exactly the same fault plans every
//! time and the loadgen's end-to-end counters are reproducible (the
//! accept *order* under concurrency may vary, but the multiset of plans
//! over N connections cannot).
//!
//! One thread, `util::poll` readiness — the same substrate as the
//! serving reactor — so a 1k-device fleet costs the proxy two fds per
//! connection and no threads.  Each proxied connection is a [`Pipe`]:
//! two non-blocking sockets and two bounded buffers pumped according to
//! the connection's [`Plan`].

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::util::poll::{fd_of, poll, PollFd};
use crate::util::rng::Pcg32;

/// Event-loop tick: poll timeout and drip replenishment interval.
const TICK: Duration = Duration::from_millis(5);
/// Per-direction buffer cap; reads pause (TCP backpressure) when full.
const BUF_CAP: usize = 64 * 1024;
/// How long a blackholed connection is held open before the proxy
/// closes it — long enough that a correctly-bounded client times out
/// first, short enough that CI runs don't accumulate zombies.
const BLACKHOLE_HOLD: Duration = Duration::from_secs(3);
/// Safety net: no proxied connection outlives this, whatever its plan.
const MAX_CONN_AGE: Duration = Duration::from_secs(60);

/// Which pathology family a run injects.  `Mix` keeps a majority of
/// connections clean so retries converge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Profile {
    /// Pass-through (baseline / control runs).
    Clean,
    /// RST the client side mid-response on a fraction of connections.
    Resets,
    /// Throttle both directions to a few bytes per tick.
    Drip,
    /// Swallow the response entirely and stall (the blackhole the
    /// client read-timeout satellite exists for).
    Stall,
    /// Close the client side cleanly partway through the response.
    Truncate,
    /// Delay response bytes by a few tens of milliseconds.
    Latency,
    /// A weighted blend of all of the above, majority clean.
    Mix,
}

impl std::str::FromStr for Profile {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Profile> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "clean" => Profile::Clean,
            "resets" | "reset" => Profile::Resets,
            "drip" => Profile::Drip,
            "stall" | "blackhole" => Profile::Stall,
            "truncate" => Profile::Truncate,
            "latency" => Profile::Latency,
            "mix" => Profile::Mix,
            other => bail!(
                "unknown chaos profile {other:?} \
                 (want clean|resets|drip|stall|truncate|latency|mix)"
            ),
        })
    }
}

/// The fault plan for one proxied connection — drawn once at accept
/// time by [`plan_for`] and never mutated, so the connection's whole
/// behaviour is fixed by `(seed, conn, profile)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Plan {
    /// Hold response bytes this long after they first arrive.
    pub delay: Option<Duration>,
    /// Forward at most this many bytes per [`TICK`], both directions.
    pub drip: Option<usize>,
    /// RST the client after forwarding this many response bytes.
    pub reset_after: Option<u64>,
    /// FIN the client after forwarding this many response bytes.
    pub truncate_after: Option<u64>,
    /// Never forward the response; hold, then close.
    pub blackhole: bool,
}

impl Plan {
    pub const CLEAN: Plan =
        Plan { delay: None, drip: None, reset_after: None, truncate_after: None, blackhole: false };

    pub fn is_clean(&self) -> bool {
        *self == Plan::CLEAN
    }
}

/// Draw the fault plan for connection ordinal `conn` — a pure function:
/// no global state, no wall clock, one dedicated PCG stream per
/// connection.
pub fn plan_for(seed: u64, conn: u64, profile: Profile) -> Plan {
    let mut rng = Pcg32::new(seed, conn);
    // Response-byte thresholds land mid-body: the smallest score
    // response is ~130 bytes of head plus a JSON body.
    let reset = |rng: &mut Pcg32| Plan {
        reset_after: Some(rng.range_i64(40, 200) as u64),
        ..Plan::CLEAN
    };
    let truncate = |rng: &mut Pcg32| Plan {
        truncate_after: Some(rng.range_i64(40, 200) as u64),
        ..Plan::CLEAN
    };
    let drip = |rng: &mut Pcg32| Plan { drip: Some(rng.range_usize(8, 64)), ..Plan::CLEAN };
    let latency = |rng: &mut Pcg32| Plan {
        delay: Some(Duration::from_millis(rng.range_i64(20, 150) as u64)),
        ..Plan::CLEAN
    };
    match profile {
        Profile::Clean => Plan::CLEAN,
        Profile::Resets => {
            if rng.f64() < 0.6 {
                reset(&mut rng)
            } else {
                Plan::CLEAN
            }
        }
        Profile::Truncate => {
            if rng.f64() < 0.6 {
                truncate(&mut rng)
            } else {
                Plan::CLEAN
            }
        }
        Profile::Drip => drip(&mut rng),
        Profile::Latency => latency(&mut rng),
        Profile::Stall => {
            if rng.f64() < 0.5 {
                Plan { blackhole: true, ..Plan::CLEAN }
            } else {
                Plan::CLEAN
            }
        }
        Profile::Mix => {
            let roll = rng.f64();
            if roll < 0.55 {
                Plan::CLEAN
            } else if roll < 0.65 {
                latency(&mut rng)
            } else if roll < 0.78 {
                drip(&mut rng)
            } else if roll < 0.88 {
                reset(&mut rng)
            } else if roll < 0.95 {
                truncate(&mut rng)
            } else {
                Plan { blackhole: true, ..Plan::CLEAN }
            }
        }
    }
}

/// What the proxy did, for reports and test assertions.
#[derive(Debug, Default)]
pub struct ChaosStats {
    pub conns: AtomicU64,
    pub clean: AtomicU64,
    pub resets: AtomicU64,
    pub truncations: AtomicU64,
    pub blackholes: AtomicU64,
    pub delayed: AtomicU64,
    pub dripped: AtomicU64,
}

impl ChaosStats {
    pub fn faulted(&self) -> u64 {
        self.resets.load(Ordering::Relaxed)
            + self.truncations.load(Ordering::Relaxed)
            + self.blackholes.load(Ordering::Relaxed)
    }
}

/// One proxied connection: client-side socket, upstream socket, the two
/// forwarding buffers and the plan's progress state.
struct Pipe {
    client: TcpStream,
    upstream: TcpStream,
    plan: Plan,
    opened: Instant,
    /// client → upstream bytes awaiting forwarding.
    c2u: Vec<u8>,
    /// upstream → client bytes awaiting forwarding.
    u2c: Vec<u8>,
    /// Response bytes already forwarded to the client (the reset /
    /// truncate trigger input).
    u2c_forwarded: u64,
    /// When the first (not-yet-released) response byte arrived — the
    /// latency plan holds forwarding until `first_resp + delay`.
    first_resp: Option<Instant>,
    /// Shared drip budget for this tick, both directions.
    drip_budget: usize,
    client_eof: bool,
    upstream_eof: bool,
    /// Terminal action decided; sockets are dropped after this tick.
    done: bool,
}

impl Pipe {
    fn new(client: TcpStream, upstream: TcpStream, plan: Plan) -> Pipe {
        Pipe {
            client,
            upstream,
            plan,
            opened: Instant::now(),
            c2u: Vec::new(),
            u2c: Vec::new(),
            u2c_forwarded: 0,
            first_resp: None,
            drip_budget: 0,
            client_eof: false,
            upstream_eof: false,
            done: false,
        }
    }

    /// May the response side release bytes this tick?
    fn response_released(&self, now: Instant) -> bool {
        match (self.plan.delay, self.first_resp) {
            (Some(d), Some(t0)) => now.duration_since(t0) >= d,
            _ => true,
        }
    }

    /// Bytes this direction may forward right now under the drip plan.
    fn budget(&self, want: usize) -> usize {
        match self.plan.drip {
            Some(_) => want.min(self.drip_budget),
            None => want,
        }
    }
}

/// Read as much as fits into `buf` (up to `BUF_CAP`) without blocking.
/// Returns whether the peer reached EOF.
fn pump_in(src: &mut TcpStream, buf: &mut Vec<u8>) -> Result<bool> {
    let mut tmp = [0u8; 4096];
    while buf.len() < BUF_CAP {
        match src.read(&mut tmp) {
            Ok(0) => return Ok(true),
            Ok(n) => buf.extend_from_slice(&tmp[..n]),
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e).context("chaos read"),
        }
    }
    Ok(false)
}

/// Write up to `limit` bytes of `buf` without blocking; drains what was
/// accepted.  Returns bytes written.
fn pump_out(dst: &mut TcpStream, buf: &mut Vec<u8>, limit: usize) -> Result<usize> {
    let mut wrote = 0usize;
    while wrote < limit && wrote < buf.len() {
        let end = limit.min(buf.len());
        match dst.write(&buf[wrote..end]) {
            Ok(0) => bail!("chaos write returned zero"),
            Ok(n) => wrote += n,
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e).context("chaos write"),
        }
    }
    buf.drain(..wrote);
    Ok(wrote)
}

#[cfg(unix)]
mod sys {
    //! `setsockopt(SO_LINGER, {1, 0})` so dropping the socket emits RST
    //! instead of FIN — std's `TcpStream::set_linger` is unstable, so
    //! the one constant pair per platform is declared by hand, in the
    //! same spirit as `util::poll`.

    #[repr(C)]
    struct Linger {
        l_onoff: i32,
        l_linger: i32,
    }

    #[cfg(target_os = "macos")]
    const SOL_SOCKET: i32 = 0xffff;
    #[cfg(not(target_os = "macos"))]
    const SOL_SOCKET: i32 = 1;
    #[cfg(target_os = "macos")]
    const SO_LINGER: i32 = 0x0080;
    #[cfg(not(target_os = "macos"))]
    const SO_LINGER: i32 = 13;

    extern "C" {
        fn setsockopt(
            fd: i32,
            level: i32,
            optname: i32,
            optval: *const core::ffi::c_void,
            optlen: u32,
        ) -> i32;
    }

    /// Arm the socket so the coming `drop` sends RST (best-effort).
    pub fn arm_reset(stream: &std::net::TcpStream) {
        use std::os::fd::AsRawFd;
        let lg = Linger { l_onoff: 1, l_linger: 0 };
        unsafe {
            setsockopt(
                stream.as_raw_fd(),
                SOL_SOCKET,
                SO_LINGER,
                &lg as *const Linger as *const core::ffi::c_void,
                std::mem::size_of::<Linger>() as u32,
            );
        }
    }
}

#[cfg(not(unix))]
mod sys {
    /// Non-unix fallback: a clean close stands in for the RST.
    pub fn arm_reset(_stream: &std::net::TcpStream) {}
}

/// The running proxy: a listener thread pumping [`Pipe`]s until
/// shutdown.  `Drop` shuts it down.
pub struct ChaosProxy {
    addr: SocketAddr,
    stats: Arc<ChaosStats>,
    shutdown: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl ChaosProxy {
    /// Bind an ephemeral local port and start proxying to `upstream`
    /// with faults drawn from `(seed, profile)`.
    pub fn start(upstream: SocketAddr, seed: u64, profile: Profile) -> Result<ChaosProxy> {
        let listener = TcpListener::bind("127.0.0.1:0").context("chaos bind")?;
        let addr = listener.local_addr().context("chaos local_addr")?;
        listener.set_nonblocking(true).context("chaos listener nonblocking")?;
        let stats = Arc::new(ChaosStats::default());
        let shutdown = Arc::new(AtomicBool::new(false));
        let handle = {
            let stats = Arc::clone(&stats);
            let shutdown = Arc::clone(&shutdown);
            std::thread::Builder::new()
                .name("pbsp-chaos".into())
                .spawn(move || run_loop(listener, upstream, seed, profile, &stats, &shutdown))
                .context("spawn chaos thread")?
        };
        Ok(ChaosProxy { addr, stats, shutdown, handle: Some(handle) })
    }

    /// Where the fleet should connect.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stats(&self) -> &ChaosStats {
        &self.stats
    }

    pub fn shutdown(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ChaosProxy {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn run_loop(
    listener: TcpListener,
    upstream: SocketAddr,
    seed: u64,
    profile: Profile,
    stats: &ChaosStats,
    shutdown: &AtomicBool,
) {
    let mut pipes: Vec<Pipe> = Vec::new();
    let mut conn_ordinal = 0u64;
    while !shutdown.load(Ordering::SeqCst) {
        // Accept everything pending; each accept draws the next plan.
        loop {
            match listener.accept() {
                Ok((client, _)) => {
                    let plan = plan_for(seed, conn_ordinal, profile);
                    conn_ordinal += 1;
                    stats.conns.fetch_add(1, Ordering::Relaxed);
                    if plan.is_clean() {
                        stats.clean.fetch_add(1, Ordering::Relaxed);
                    }
                    if plan.delay.is_some() {
                        stats.delayed.fetch_add(1, Ordering::Relaxed);
                    }
                    if plan.drip.is_some() {
                        stats.dripped.fetch_add(1, Ordering::Relaxed);
                    }
                    // The upstream is local and live; a bounded connect
                    // keeps a dead upstream from wedging the loop.
                    let up = match TcpStream::connect_timeout(&upstream, Duration::from_secs(2)) {
                        Ok(s) => s,
                        Err(_) => continue, // drop the client; it will retry
                    };
                    if client.set_nonblocking(true).is_err() || up.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = client.set_nodelay(true);
                    let _ = up.set_nodelay(true);
                    pipes.push(Pipe::new(client, up, plan));
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }

        // Poll: listener + both fds of every pipe.
        let mut fds = Vec::with_capacity(1 + pipes.len() * 2);
        fds.push(PollFd::new(fd_of(&listener), true, false));
        for p in &pipes {
            let budget_open = p.plan.drip.is_none() || p.drip_budget > 0;
            let release = p.response_released(Instant::now());
            fds.push(PollFd::new(
                fd_of(&p.client),
                !p.client_eof && p.c2u.len() < BUF_CAP,
                !p.u2c.is_empty() && budget_open && release && !p.plan.blackhole,
            ));
            fds.push(PollFd::new(
                fd_of(&p.upstream),
                !p.upstream_eof && p.u2c.len() < BUF_CAP,
                !p.c2u.is_empty() && budget_open,
            ));
        }
        let _ = poll(&mut fds, TICK);

        let now = Instant::now();
        for p in pipes.iter_mut() {
            // Replenish the drip budget once per tick.
            if let Some(d) = p.plan.drip {
                p.drip_budget = d;
            }
            if drive(p, now, stats).is_err() {
                p.done = true;
            }
        }
        pipes.retain(|p| !p.done);
    }
    // Shutdown: drop every pipe (blackholes close here at the latest).
}

/// Advance one pipe one tick.  Any io error tears the pipe down.
fn drive(p: &mut Pipe, now: Instant, stats: &ChaosStats) -> Result<()> {
    if now.duration_since(p.opened) > MAX_CONN_AGE {
        p.done = true;
        return Ok(());
    }

    // Ingest both directions (bounded by BUF_CAP).
    if !p.client_eof {
        p.client_eof = pump_in(&mut p.client, &mut p.c2u)?;
    }
    if !p.upstream_eof {
        let had = p.u2c.len();
        p.upstream_eof = pump_in(&mut p.upstream, &mut p.u2c)?;
        if p.u2c.len() > had && p.first_resp.is_none() {
            p.first_resp = Some(now);
        }
    }

    // Blackhole: the request flows upstream, the response never comes
    // back; hold (so the client's own timeout is what ends the wait),
    // then close.
    if p.plan.blackhole {
        p.u2c.clear();
        let limit = p.budget(p.c2u.len());
        pump_out(&mut p.upstream, &mut p.c2u, limit)?;
        if p.first_resp.map(|t| now.duration_since(t) >= BLACKHOLE_HOLD).unwrap_or(false)
            || p.client_eof
        {
            stats.blackholes.fetch_add(1, Ordering::Relaxed);
            p.done = true;
        }
        return Ok(());
    }

    // Request direction (client → upstream).
    let limit = p.budget(p.c2u.len());
    let wrote = pump_out(&mut p.upstream, &mut p.c2u, limit)?;
    if p.plan.drip.is_some() {
        p.drip_budget -= wrote.min(p.drip_budget);
    }
    if p.client_eof && p.c2u.is_empty() {
        let _ = p.upstream.shutdown(std::net::Shutdown::Write);
    }

    // Response direction (upstream → client), where the mid-body
    // triggers live.
    if p.response_released(now) && !p.u2c.is_empty() {
        let mut limit = p.budget(p.u2c.len());
        // Stop exactly at the reset/truncate threshold so the fault
        // lands mid-body, not at a message boundary.
        for threshold in [p.plan.reset_after, p.plan.truncate_after].into_iter().flatten() {
            let left = threshold.saturating_sub(p.u2c_forwarded) as usize;
            limit = limit.min(left);
        }
        let wrote = pump_out(&mut p.client, &mut p.u2c, limit)?;
        p.u2c_forwarded += wrote as u64;
        if p.plan.drip.is_some() {
            p.drip_budget -= wrote.min(p.drip_budget);
        }
        if let Some(t) = p.plan.reset_after {
            if p.u2c_forwarded >= t {
                // Dropping the pipe closes the socket; SO_LINGER{1,0}
                // turns that close into an RST mid-body.
                sys::arm_reset(&p.client);
                stats.resets.fetch_add(1, Ordering::Relaxed);
                p.done = true;
                return Ok(());
            }
        }
        if let Some(t) = p.plan.truncate_after {
            if p.u2c_forwarded >= t {
                stats.truncations.fetch_add(1, Ordering::Relaxed);
                p.done = true;
                return Ok(());
            }
        }
    }

    // Natural end: both sides quiesced.
    if p.upstream_eof && p.u2c.is_empty() {
        p.done = true;
    }
    if p.client_eof && p.c2u.is_empty() && p.u2c.is_empty() {
        p.done = true;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};

    /// A trivial upstream: accepts one connection at a time, reads one
    /// line-sized request chunk, answers with `reply` bytes.
    fn one_shot_upstream(reply: Vec<u8>, times: usize) -> SocketAddr {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            for _ in 0..times {
                let Ok((mut s, _)) = listener.accept() else { return };
                let mut buf = [0u8; 1024];
                let _ = s.set_read_timeout(Some(Duration::from_millis(500)));
                let _ = s.read(&mut buf);
                let _ = s.write_all(&reply);
            }
        });
        addr
    }

    #[test]
    fn plans_are_pure_functions_of_seed_and_conn() {
        for profile in [Profile::Mix, Profile::Resets, Profile::Drip, Profile::Stall] {
            for conn in 0..64 {
                assert_eq!(
                    plan_for(7, conn, profile),
                    plan_for(7, conn, profile),
                    "plan must be deterministic"
                );
            }
        }
        // Different seeds draw different plan sequences (mix profile).
        let a: Vec<Plan> = (0..64).map(|c| plan_for(1, c, Profile::Mix)).collect();
        let b: Vec<Plan> = (0..64).map(|c| plan_for(2, c, Profile::Mix)).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn mix_profile_keeps_a_clean_majority_but_draws_every_fault() {
        let plans: Vec<Plan> = (0..1000).map(|c| plan_for(7, c, Profile::Mix)).collect();
        let clean = plans.iter().filter(|p| p.is_clean()).count();
        assert!(clean > 400 && clean < 700, "clean {clean}/1000");
        assert!(plans.iter().any(|p| p.reset_after.is_some()));
        assert!(plans.iter().any(|p| p.truncate_after.is_some()));
        assert!(plans.iter().any(|p| p.drip.is_some()));
        assert!(plans.iter().any(|p| p.delay.is_some()));
        assert!(plans.iter().any(|p| p.blackhole));
    }

    #[test]
    fn profile_parses_and_rejects() {
        assert_eq!("mix".parse::<Profile>().unwrap(), Profile::Mix);
        assert_eq!("RESETS".parse::<Profile>().unwrap(), Profile::Resets);
        assert_eq!("blackhole".parse::<Profile>().unwrap(), Profile::Stall);
        assert!("tornado".parse::<Profile>().is_err());
    }

    #[test]
    fn clean_profile_passes_bytes_through_unchanged() {
        let reply = b"HTTP/1.1 200 OK\r\ncontent-length: 2\r\n\r\nok".to_vec();
        let upstream = one_shot_upstream(reply.clone(), 1);
        let mut proxy = ChaosProxy::start(upstream, 7, Profile::Clean).unwrap();
        let mut c = TcpStream::connect(proxy.addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
        c.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
        let mut got = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(5);
        while got.len() < reply.len() && Instant::now() < deadline {
            let mut tmp = [0u8; 256];
            match c.read(&mut tmp) {
                Ok(0) => break,
                Ok(n) => got.extend_from_slice(&tmp[..n]),
                Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {}
                Err(e) => panic!("clean read failed: {e}"),
            }
        }
        assert_eq!(got, reply, "clean profile must not alter bytes");
        assert_eq!(proxy.stats().faulted(), 0);
        proxy.shutdown();
    }

    /// A plan with `reset_after`/`truncate_after` kills the connection
    /// partway: the client sees an error or EOF before the full reply.
    #[test]
    fn faulting_profiles_cut_responses_short() {
        // A reply comfortably larger than any mid-body threshold.
        let reply = vec![b'z'; 4096];
        // Find a conn ordinal whose Resets plan actually resets, then
        // drive exactly that many connections so the last one faults.
        let seed = 7u64;
        let target =
            (0..64).find(|&c| plan_for(seed, c, Profile::Resets).reset_after.is_some()).unwrap();
        let upstream = one_shot_upstream(reply.clone(), target as usize + 1);
        let mut proxy = ChaosProxy::start(upstream, seed, Profile::Resets).unwrap();
        let mut cut_short = false;
        for _ in 0..=target {
            let mut c = TcpStream::connect(proxy.addr()).unwrap();
            c.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
            c.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
            let mut got = 0usize;
            let deadline = Instant::now() + Duration::from_secs(5);
            loop {
                let mut tmp = [0u8; 1024];
                match c.read(&mut tmp) {
                    Ok(0) => break,
                    Ok(n) => got += n,
                    Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                        if Instant::now() > deadline {
                            break;
                        }
                    }
                    Err(_) => break, // RST shows up as ECONNRESET
                }
            }
            if got < reply.len() {
                cut_short = true;
            }
        }
        assert!(cut_short, "at least the faulting connection must be cut short");
        assert!(proxy.stats().resets.load(Ordering::Relaxed) > 0, "reset must be counted");
        proxy.shutdown();
    }

    /// Blackhole: request forwarded, response swallowed — a bounded
    /// client errors out in its own time; the proxy survives.
    #[test]
    fn blackhole_swallows_the_response() {
        let reply = b"HTTP/1.1 200 OK\r\ncontent-length: 0\r\n\r\n".to_vec();
        let seed = 7u64;
        let target = (0..64).find(|&c| plan_for(seed, c, Profile::Stall).blackhole).unwrap();
        let upstream = one_shot_upstream(reply, target as usize + 1);
        let mut proxy = ChaosProxy::start(upstream, seed, Profile::Stall).unwrap();
        // Burn the non-blackhole ordinals.
        for _ in 0..target {
            let mut c = TcpStream::connect(proxy.addr()).unwrap();
            c.set_read_timeout(Some(Duration::from_millis(200))).unwrap();
            let _ = c.write_all(b"GET / HTTP/1.1\r\n\r\n");
            let mut tmp = [0u8; 256];
            let _ = c.read(&mut tmp);
        }
        let mut c = TcpStream::connect(proxy.addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_millis(150))).unwrap();
        c.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
        let t0 = Instant::now();
        let mut tmp = [0u8; 256];
        let r = c.read(&mut tmp);
        let bounded = t0.elapsed() < Duration::from_secs(2);
        let starved = matches!(r, Err(ref e)
            if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut))
            || matches!(r, Ok(0));
        assert!(bounded && starved, "blackholed read must starve within its timeout: {r:?}");
        proxy.shutdown();
    }
}
