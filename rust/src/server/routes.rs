//! Request routing: maps the HTTP surface onto the coordinator.
//!
//! | endpoint | method | body | backed by |
//! |---|---|---|---|
//! | `/healthz` | GET | — | service liveness |
//! | `/readyz` | GET | — | readiness: 503 while draining, over capacity, or in brownout |
//! | `/v1/models` | GET | — | the artifact manifest |
//! | `/metrics` | GET | — | coordinator + server counters (JSON; `?format=prometheus` for text exposition) |
//! | `/v1/score/{model}/{precision}` | POST | `{"x": [...]}` or `{"xs": [[...], ...]}` | `Service::submit` (streaming path) |
//!
//! Scoring goes through the *streaming* submit path on purpose: every
//! sample is one router/batcher request, so concurrent connections
//! coalesce into real dynamic batches exactly like in-process callers —
//! and responses are bit-identical to direct `Service::submit` (the
//! JSON number round-trip is exact: shortest-repr f64 both ways).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::http::{Message, Request, Response};
use super::listener::ServerMetrics;
use crate::coordinator::router::Key;
use crate::coordinator::service::{Service, ERR_DEADLINE};
use crate::util::json::Value;
use crate::util::stats::Reservoir;
use crate::util::telemetry::{self, prom_counter, prom_gauge};

/// Handler-side timings of one sampled request, filled in while the
/// request executes; the reactor merges it into the connection's trace
/// span and emits the span once the response has drained.
#[derive(Debug, Default, Clone)]
pub struct HandlerTrace {
    /// Dispatch → pool pickup (compute-pool queue wait).
    pub queue_us: u64,
    /// Request-line + body JSON decode and validation.
    pub parse_us: u64,
    /// Enqueue → batch-cut wait in the coordinator's dynamic batcher
    /// (max across the request's samples).
    pub batch_us: u64,
    /// Backend execution of the batch the request rode in (max across
    /// the request's samples).
    pub exec_us: u64,
    /// Size of that batch.
    pub batch: u32,
    pub status: u16,
    pub model: String,
    pub variant: String,
}

/// The reactor's pool-job entry point: parse a framed message into a
/// request, route it, and report whether the connection should close
/// afterwards (client `Connection: close`, or an unparseable request).
/// `deadline` is the request's absolute compute deadline (from
/// `X-Deadline-Ms` or the server default), propagated into the
/// coordinator so the dynamic batcher can shed the request at dispatch
/// instead of penalising its batch siblings.
/// `trace` is `Some` when the request was sampled for a trace span.
pub fn respond(
    svc: &Service,
    metrics: &ServerMetrics,
    msg: Message,
    deadline: Option<Instant>,
    mut trace: Option<&mut HandlerTrace>,
) -> (Response, bool) {
    let (resp, close) = match Request::from_message(msg) {
        Ok(req) => {
            let close = req.wants_close();
            (route(svc, metrics, &req, deadline, trace.as_deref_mut()), close)
        }
        Err(e) => (Response::error(400, &format!("{e:#}")), true),
    };
    if let Some(t) = trace {
        t.status = resp.status;
    }
    (resp, close)
}

/// Dispatch one request.  Never panics; every outcome is a `Response`.
pub fn route(
    svc: &Service,
    metrics: &ServerMetrics,
    req: &Request,
    deadline: Option<Instant>,
    trace: Option<&mut HandlerTrace>,
) -> Response {
    // The query string only selects representations (`/metrics`), so
    // routing dispatches on the bare path.
    let (path, query) = match req.path.split_once('?') {
        Some((p, q)) => (p, q),
        None => (req.path.as_str(), ""),
    };
    match (req.method.as_str(), path) {
        ("GET", "/healthz") => healthz(svc),
        ("GET", "/readyz") => readyz(metrics),
        ("GET", "/v1/models") => models(svc),
        ("GET", "/metrics") if query.split('&').any(|kv| kv == "format=prometheus") => {
            metrics_prometheus(svc, metrics)
        }
        ("GET", "/metrics") => metrics_snapshot(svc, metrics),
        (_, "/healthz") | (_, "/readyz") | (_, "/v1/models") | (_, "/metrics") => {
            Response::error(405, &format!("{path} expects GET"))
        }
        (method, path) if path.starts_with("/v1/score/") => {
            if method != "POST" {
                return Response::error(405, "scoring expects POST");
            }
            match score(svc, metrics, req, path, deadline, trace) {
                Ok(resp) => resp,
                Err(e) => e,
            }
        }
        (method, path) => Response::error(404, &format!("no route for {method} {path}")),
    }
}

fn healthz(svc: &Service) -> Response {
    Response::json(
        200,
        &Value::obj(vec![
            ("status", Value::from("ok")),
            ("models", Value::from(svc.models.len())),
        ]),
    )
}

/// Readiness is distinct from liveness: the process can be healthy yet
/// unwilling to take traffic.  503s here tell a load balancer to route
/// around this instance; the body says which gate tripped.
fn readyz(metrics: &ServerMetrics) -> Response {
    let g = |a: &AtomicU64| a.load(Ordering::Relaxed);
    let mut reasons: Vec<&str> = Vec::new();
    if metrics.draining.load(Ordering::Relaxed) {
        reasons.push("draining");
    }
    let conn_limit = g(&metrics.limit_connections);
    if conn_limit > 0 && g(&metrics.open_connections) >= conn_limit {
        reasons.push("connections at capacity");
    }
    let queue_limit = g(&metrics.limit_queued);
    if queue_limit > 0 && g(&metrics.inflight) >= queue_limit {
        reasons.push("compute queue full");
    }
    if metrics.brownout.load(Ordering::Relaxed) {
        reasons.push("brownout");
    }
    if reasons.is_empty() {
        Response::json(200, &Value::obj(vec![("status", Value::from("ready"))]))
    } else {
        Response::json(
            503,
            &Value::obj(vec![
                ("status", Value::from("not ready")),
                ("reasons", Value::Arr(reasons.into_iter().map(Value::from).collect())),
            ]),
        )
    }
}

fn models(svc: &Service) -> Response {
    let man = &svc.manifest;
    let models: Vec<Value> = man
        .models
        .iter()
        .map(|e| {
            Value::obj(vec![
                ("name", Value::from(e.name.as_str())),
                ("dataset", Value::from(e.dataset.as_str())),
                ("arch", Value::Arr(e.arch.iter().map(|&a| Value::from(a)).collect())),
                ("n_test", Value::from(e.n_test)),
                ("float_accuracy", Value::from(e.float_accuracy)),
                (
                    "variants",
                    Value::Arr(e.hlo.keys().map(|k| Value::from(k.as_str())).collect()),
                ),
            ])
        })
        .collect();
    Response::json(
        200,
        &Value::obj(vec![
            ("batch", Value::from(man.batch)),
            (
                "precisions",
                Value::Arr(man.precisions.iter().map(|&p| Value::from(p as i64)).collect()),
            ),
            ("models", Value::Arr(models)),
        ]),
    )
}

fn metrics_snapshot(svc: &Service, server: &ServerMetrics) -> Response {
    let m = svc.metrics.lock().unwrap().clone();
    let coordinator = Value::obj(vec![
        ("requests", Value::from(m.requests as i64)),
        ("batches", Value::from(m.batches as i64)),
        ("compiles", Value::from(m.compiles as i64)),
        ("mean_batch", finite(m.mean_batch_size())),
        ("exec_ms", dist_json(&m.exec_ms)),
        ("queue_ms", dist_json(&m.queue_ms)),
        ("batch_size", dist_json(&m.batch_sizes)),
    ]);
    Response::json(
        200,
        &Value::obj(vec![
            ("coordinator", coordinator),
            ("server", server.to_json()),
            ("telemetry", telemetry::global().to_json()),
        ]),
    )
}

/// `/metrics?format=prometheus`: the full registry (pool, batcher, ISS,
/// reactor series) plus hand-rendered samples for the per-instance
/// `ServerMetrics` atomics and the coordinator's lock-guarded counters,
/// which live outside the registry.
fn metrics_prometheus(svc: &Service, server: &ServerMetrics) -> Response {
    let mut out = String::new();
    telemetry::global().render_prometheus(&mut out);
    let c = |a: &AtomicU64| a.load(Ordering::Relaxed);
    prom_counter(&mut out, "pbsp_server_connections_total", "connections accepted and admitted", c(&server.connections));
    prom_gauge(&mut out, "pbsp_server_open_connections", "currently-open connections", c(&server.open_connections) as f64);
    prom_counter(&mut out, "pbsp_server_http_requests_total", "HTTP requests read off connections", c(&server.http_requests));
    prom_counter(&mut out, "pbsp_server_responses_2xx_total", "2xx responses", c(&server.responses_2xx));
    prom_counter(&mut out, "pbsp_server_responses_4xx_total", "4xx responses", c(&server.responses_4xx));
    prom_counter(&mut out, "pbsp_server_responses_5xx_total", "5xx responses", c(&server.responses_5xx));
    prom_counter(&mut out, "pbsp_server_responses_other_total", "1xx/3xx responses", c(&server.responses_other));
    prom_counter(&mut out, "pbsp_server_samples_scored_total", "samples scored over HTTP", c(&server.samples_scored));
    prom_counter(&mut out, "pbsp_server_rejected_busy_total", "connections refused at the max-connections gate", c(&server.rejected_busy));
    prom_counter(&mut out, "pbsp_server_rejected_queue_total", "requests refused at the max-queued gate", c(&server.rejected_queue));
    prom_counter(&mut out, "pbsp_server_evicted_idle_total", "connections reaped past their keep-alive budget", c(&server.evicted_idle));
    prom_counter(&mut out, "pbsp_server_evicted_read_total", "connections cut off mid-message past the slow-loris deadline", c(&server.evicted_read));
    prom_counter(&mut out, "pbsp_server_evicted_write_total", "connections evicted for a stalled response write", c(&server.evicted_write));
    prom_counter(&mut out, "pbsp_server_deadline_shed_total", "requests shed at pool pickup, already past their deadline", c(&server.deadline_shed));
    prom_counter(&mut out, "pbsp_server_deadline_shed_batch_total", "requests shed inside the dynamic batcher past their deadline", c(&server.deadline_shed_batch));
    prom_counter(&mut out, "pbsp_server_degraded_total", "requests served at a lower precision under brownout", c(&server.degraded));
    prom_counter(&mut out, "pbsp_server_brownout_entered_total", "times the brownout controller tripped its high watermark", c(&server.brownout_entered));
    prom_gauge(&mut out, "pbsp_server_brownout", "1 while precision degradation is active", server.brownout.load(Ordering::Relaxed) as u8 as f64);
    prom_gauge(&mut out, "pbsp_server_draining", "1 once graceful shutdown has begun", server.draining.load(Ordering::Relaxed) as u8 as f64);
    prom_gauge(&mut out, "pbsp_server_inflight", "requests queued or executing in the compute pool", c(&server.inflight) as f64);
    let m = svc.metrics.lock().unwrap().clone();
    prom_counter(&mut out, "pbsp_coordinator_batches_total", "dynamic batches executed", m.batches);
    prom_counter(&mut out, "pbsp_coordinator_compiles_total", "executable compiles (PJRT loads + ISS codegens)", m.compiles);
    prom_gauge(&mut out, "pbsp_coordinator_mean_batch", "mean dispatched batch size", m.mean_batch_size());
    Response {
        status: 200,
        content_type: "text/plain; version=0.0.4",
        body: out.into_bytes(),
        retry_after: None,
    }
}

/// Distribution snapshot from a reservoir (nearest-rank percentiles,
/// one sort per distribution).
fn dist_json(r: &Reservoir) -> Value {
    let p = r.percentiles(&[50.0, 95.0, 99.0]);
    Value::obj(vec![
        ("count", Value::from(r.count() as i64)),
        ("mean", finite(r.mean())),
        ("min", finite(r.min())),
        ("max", finite(r.max())),
        ("p50", finite(p[0])),
        ("p95", finite(p[1])),
        ("p99", finite(p[2])),
    ])
}

/// NaN/inf have no JSON encoding; empty distributions report null.
fn finite(v: f64) -> Value {
    if v.is_finite() {
        Value::Num(v)
    } else {
        Value::Null
    }
}

/// `/v1/score/{model}/{precision}` -> (model, variant key).  The
/// precision segment accepts `p8`-style variant keys, bare digits
/// (`8` -> `p8`), and `float`.
pub fn parse_score_path(path: &str) -> Result<(String, String)> {
    let rest = path.strip_prefix("/v1/score/").ok_or_else(|| anyhow!("not a score path"))?;
    let (model, precision) = rest
        .split_once('/')
        .ok_or_else(|| anyhow!("expected /v1/score/{{model}}/{{precision}}"))?;
    if model.is_empty() || precision.is_empty() || precision.contains('/') {
        bail!("expected /v1/score/{{model}}/{{precision}}");
    }
    let variant = if precision == "float" || precision.starts_with('p') {
        precision.to_string()
    } else if precision.bytes().all(|b| b.is_ascii_digit()) {
        format!("p{precision}")
    } else {
        bail!("bad precision segment {precision:?} (want pN, N or float)");
    };
    Ok((model.to_string(), variant))
}

/// The precision ladder the brownout controller walks, highest cost
/// first.  `float` is never degraded (it is the reference
/// representation a caller asked for explicitly), and `p4` is the floor.
const PRECISION_LADDER: [&str; 4] = ["p32", "p16", "p8", "p4"];

/// Under brownout, map a requested variant to the next-lower precision
/// variant the model actually ships.  `None` means the request is not
/// eligible: float, already at the floor, or no lower variant exists.
fn downshift_variant(requested: &str, has: impl Fn(&str) -> bool) -> Option<&'static str> {
    let pos = PRECISION_LADDER.iter().position(|&v| v == requested)?;
    PRECISION_LADDER[pos + 1..].iter().copied().find(|v| has(v))
}

/// Errors are returned as ready-to-send responses so `route` can stay
/// a total function.
fn score(
    svc: &Service,
    metrics: &ServerMetrics,
    req: &Request,
    path: &str,
    deadline: Option<Instant>,
    mut trace: Option<&mut HandlerTrace>,
) -> Result<Response, Response> {
    let t_parse = Instant::now();
    let (model_name, variant) =
        parse_score_path(path).map_err(|e| Response::error(404, &format!("{e:#}")))?;
    let entry = svc
        .manifest
        .model(&model_name)
        .map_err(|_| Response::error(404, &format!("unknown model {model_name:?}")))?;
    if !entry.hlo.contains_key(&variant) {
        return Err(Response::error(
            404,
            &format!("model {model_name:?} has no variant {variant:?}"),
        ));
    }
    // Brownout downshift: while the controller has the flag up, serve
    // eligible requests at the next-lower precision the model ships.
    // The response is labelled with the precision actually served, so a
    // caller verifying bit-identity replays against the served variant.
    let served = if metrics.brownout.load(Ordering::Relaxed) {
        downshift_variant(&variant, |v| entry.hlo.contains_key(v))
            .map(str::to_string)
            .unwrap_or_else(|| variant.clone())
    } else {
        variant.clone()
    };
    let degraded = served != variant;
    let body = req.body_str().map_err(|e| Response::error(400, &format!("{e:#}")))?;
    let v = Value::parse(body).map_err(|e| Response::error(400, &format!("bad JSON: {e:#}")))?;
    let (rows, single) = parse_rows(&v).map_err(|e| Response::error(400, &format!("{e:#}")))?;
    let in_dim = entry.arch[0];
    for (i, row) in rows.iter().enumerate() {
        if row.len() != in_dim {
            return Err(Response::error(
                400,
                &format!("sample {i}: {} features, model takes {in_dim}", row.len()),
            ));
        }
    }
    if let Some(t) = trace.as_deref_mut() {
        t.parse_us = t_parse.elapsed().as_micros() as u64;
        t.model = model_name.clone();
        t.variant = served.clone();
    }
    // Streaming path: submit every sample, then gather — concurrent
    // connections coalesce in the dynamic batcher meanwhile.  The
    // deadline rides along so the batcher can shed this request at
    // dispatch without holding up its batch siblings.
    let key = Key::new(&model_name, &served);
    let mut pending = Vec::with_capacity(rows.len());
    for row in rows {
        let rx = svc
            .submit_with_deadline(key.clone(), row, deadline)
            .map_err(|e| Response::error(500, &format!("{e:#}")))?;
        pending.push(rx);
    }
    let mut scores: Vec<Vec<f64>> = Vec::with_capacity(pending.len());
    for rx in pending {
        match rx.recv() {
            Ok(Ok(s)) => {
                if let Some(t) = trace.as_deref_mut() {
                    t.batch_us = t.batch_us.max(s.queue_us);
                    t.exec_us = t.exec_us.max(s.exec_us);
                    t.batch = t.batch.max(s.batch);
                }
                scores.push(s.scores);
            }
            Ok(Err(e)) if e == ERR_DEADLINE => {
                metrics.deadline_shed_batch.fetch_add(1, Ordering::Relaxed);
                return Err(Response::error(504, ERR_DEADLINE));
            }
            Ok(Err(e)) => return Err(Response::error(500, &e)),
            Err(_) => return Err(Response::error(500, "runtime worker gone")),
        }
    }
    metrics.add_scored(scores.len() as u64);
    if degraded {
        metrics.degraded.fetch_add(1, Ordering::Relaxed);
        telemetry::global()
            .counter_with(
                "pbsp_brownout_degraded_total",
                &[("model", &model_name)],
                "requests served at a lower precision under brownout",
            )
            .inc();
    }
    let model = svc.model(&model_name).map_err(|e| Response::error(500, &format!("{e:#}")))?;
    let preds: Vec<i64> = scores.iter().map(|s| model.predict(s)).collect();
    let mut common = vec![
        ("model", Value::from(model_name.as_str())),
        ("variant", Value::from(served.as_str())),
    ];
    if degraded {
        common.push(("degraded", Value::Bool(true)));
        common.push(("requested", Value::from(variant.as_str())));
    }
    let resp = if single {
        let mut pairs = common;
        pairs.push(("scores", Value::arr_f64(&scores[0])));
        pairs.push(("prediction", Value::from(preds[0])));
        Value::obj(pairs)
    } else {
        let mut pairs = common;
        pairs.push(("scores", Value::Arr(scores.iter().map(|s| Value::arr_f64(s)).collect())));
        pairs.push(("predictions", Value::Arr(preds.iter().map(|&p| Value::from(p)).collect())));
        Value::obj(pairs)
    };
    Ok(Response::json(200, &resp))
}

/// Body decode: `{"x": [...]}` (single) or `{"xs": [[...], ...]}`
/// (batch).  Returns the rows plus whether the request was single-form.
fn parse_rows(v: &Value) -> Result<(Vec<Vec<f32>>, bool)> {
    if let Some(x) = v.opt("x") {
        let row: Vec<f32> = x.as_f64_vec()?.into_iter().map(|f| f as f32).collect();
        return Ok((vec![row], true));
    }
    if let Some(xs) = v.opt("xs") {
        let rows: Vec<Vec<f32>> = xs
            .as_f64_mat()?
            .into_iter()
            .map(|r| r.into_iter().map(|f| f as f32).collect())
            .collect();
        if rows.is_empty() {
            bail!("\"xs\" must contain at least one sample");
        }
        return Ok((rows, false));
    }
    bail!("body must carry \"x\" (single sample) or \"xs\" (batch)");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn score_path_forms() {
        assert_eq!(
            parse_score_path("/v1/score/mlp_c/p8").unwrap(),
            ("mlp_c".to_string(), "p8".to_string())
        );
        assert_eq!(
            parse_score_path("/v1/score/svm_r/16").unwrap(),
            ("svm_r".to_string(), "p16".to_string())
        );
        assert_eq!(
            parse_score_path("/v1/score/mlp_c/float").unwrap(),
            ("mlp_c".to_string(), "float".to_string())
        );
        assert!(parse_score_path("/v1/score/mlp_c").is_err());
        assert!(parse_score_path("/v1/score//p8").is_err());
        assert!(parse_score_path("/v1/score/m/p8/extra").is_err());
        assert!(parse_score_path("/v1/score/m/byte").is_err());
        assert!(parse_score_path("/other").is_err());
    }

    #[test]
    fn row_decode_forms() {
        let single = Value::parse(r#"{"x": [1, 2.5]}"#).unwrap();
        let (rows, is_single) = parse_rows(&single).unwrap();
        assert!(is_single);
        assert_eq!(rows, vec![vec![1.0f32, 2.5]]);

        let batch = Value::parse(r#"{"xs": [[1, 2], [3, 4]]}"#).unwrap();
        let (rows, is_single) = parse_rows(&batch).unwrap();
        assert!(!is_single);
        assert_eq!(rows.len(), 2);

        assert!(parse_rows(&Value::parse(r#"{"xs": []}"#).unwrap()).is_err());
        assert!(parse_rows(&Value::parse(r#"{"y": [1]}"#).unwrap()).is_err());
    }

    #[test]
    fn downshift_walks_the_ladder() {
        let all = |v: &str| ["p32", "p16", "p8", "p4"].contains(&v);
        assert_eq!(downshift_variant("p32", all), Some("p16"));
        assert_eq!(downshift_variant("p16", all), Some("p8"));
        assert_eq!(downshift_variant("p8", all), Some("p4"));
        // The floor and the reference representation are not eligible.
        assert_eq!(downshift_variant("p4", all), None);
        assert_eq!(downshift_variant("float", all), None);
        // Holes in the ladder are skipped, not served blindly.
        let sparse = |v: &str| v == "p32" || v == "p4";
        assert_eq!(downshift_variant("p32", sparse), Some("p4"));
        // No lower variant shipped at all -> not eligible.
        let only32 = |v: &str| v == "p32";
        assert_eq!(downshift_variant("p32", only32), None);
    }

    #[test]
    fn finite_guards_json() {
        assert_eq!(finite(1.5), Value::Num(1.5));
        assert_eq!(finite(f64::NAN), Value::Null);
        assert_eq!(finite(f64::INFINITY), Value::Null);
    }
}
