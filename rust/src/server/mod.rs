//! The serving layer: a zero-dependency HTTP/1.1 inference frontend
//! over the coordinator, plus the device-fleet load generator that
//! drives it.
//!
//! This is the network entry point for the paper's §I deployment shape
//! — fleets of ultra-cheap printed sensors (smart packaging, disposable
//! healthcare) whose readings are classified centrally.  The stack:
//!
//! * [`http`] — owned HTTP/1.1 wire format (`Content-Length` framing,
//!   keep-alive) in the `util/` offline-substrate style, shared by the
//!   server and the client;
//! * [`routes`] — the endpoint surface: `POST
//!   /v1/score/{model}/{precision}` (single sample or batch JSON),
//!   `GET /v1/models`, `GET /metrics`, `GET /healthz`;
//! * [`reactor`] — the event-driven serving core: one thread
//!   multiplexes every connection non-blocking over `util::poll`,
//!   dispatching fully-buffered requests to the `util::threadpool`
//!   compute pool (so `--http-threads` sizes compute, not the
//!   connection cap);
//! * [`listener`] — the `Server` lifecycle handle, configuration and
//!   shared metrics around the reactor;
//! * [`loadgen`] — a deterministic (PCG-per-device) fleet simulator
//!   (closed-loop or open-loop arrivals) with nearest-rank latency
//!   percentiles;
//! * [`chaos`] — a seeded fault-injecting TCP proxy (resets, byte-drip,
//!   truncation, blackholes, latency) that sits between the fleet and
//!   the server so every robustness claim is exercised deterministically.
//!
//! Scoring rides the coordinator's *streaming* `Service::submit` path,
//! so concurrent connections coalesce in the dynamic batcher into real
//! batches, and every HTTP response is bit-identical to an in-process
//! `submit` of the same sample (`tests/serve_http.rs` enforces this
//! over real sockets).

pub mod chaos;
pub mod http;
pub mod listener;
pub mod loadgen;
pub mod reactor;
pub mod routes;

pub use listener::{Server, ServerConfig, ServerMetrics};
