//! The event-driven serving core: one reactor thread multiplexes every
//! connection socket over [`crate::util::poll`], drives the resumable
//! [`HttpConn`] framing state machine per connection, and hands a
//! request to the `util::threadpool` compute pool only once its full
//! body is buffered.
//!
//! This inverts the old thread-per-connection model: `http_threads`
//! sizes the *compute* pool, while connection concurrency is bounded
//! only by `max_connections` — thousands of mostly-idle keep-alive
//! devices (the paper's fleet deployment shape) cost one fd and a map
//! entry each, not a parked worker thread.
//!
//! Per-connection life cycle (the `State` machine):
//!
//! ```text
//!   accept ──> Reading ──(full message)──> InFlight ──(pool done)──> Writing
//!                 ^                                                    │
//!                 └──────────(drained; next pipelined message?)────────┘
//! ```
//!
//! * `Reading` — registered for read readiness; bytes feed
//!   `HttpConn::read_message`, which resumes mid-message in O(new
//!   bytes).
//! * `InFlight` — the request is queued/executing on the pool; the
//!   socket is registered for *nothing* (flow control: at most one
//!   request per connection in flight, so a pipelining client cannot
//!   queue unboundedly).
//! * `Writing` — the response drains through non-blocking
//!   `flush_progress` calls under write readiness; a peer that stops
//!   reading is evicted after `write_stall` without ever blocking the
//!   reactor (or, in the old model's failure mode, the accept path).
//!
//! Backpressure is two-level and always visible: past `max_connections`
//! a new connection gets an asynchronously-written `503 Retry-After`
//! then close (`rejected_busy`); past `max_queued` in-flight requests a
//! parsed request gets `503 Retry-After` on its healthy keep-alive
//! connection (`rejected_queue`).  Pool workers return responses
//! through a mutex'd completion list and a [`Waker`], so the reactor
//! sleeps in `poll(2)` instead of ticking.
//!
//! Shutdown: the listener stops being polled, reads stop, in-flight
//! requests finish and their responses drain (flagged
//! `Connection: close`), bounded by a grace period — then every socket
//! is dropped.

use std::collections::BTreeMap;
use std::io::ErrorKind;
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::http::{HttpConn, Message, Outcome, Response};
use super::listener::ServerMetrics;
use super::routes::{self, HandlerTrace};
use crate::coordinator::service::Service;
use crate::util::json::Value;
use crate::util::poll::{self, PollFd, Waker};
use crate::util::telemetry;
use crate::util::threadpool::ThreadPool;

/// Poll timeout: bounds deadline-sweep latency (keep-alive reaping,
/// slow-loris eviction, write-stall eviction) when no fd turns ready.
const TICK: Duration = Duration::from_millis(20);
/// `Retry-After` seconds suggested on both backpressure 503s.
const RETRY_AFTER_S: u64 = 1;

/// Reactor tuning, resolved from `ServerConfig` by the listener.
#[derive(Debug, Clone)]
pub(crate) struct ReactorConfig {
    pub keep_alive: Duration,
    pub msg_deadline: Duration,
    pub write_stall: Duration,
    pub max_connections: usize,
    pub max_queued: usize,
    pub shutdown_grace: Duration,
    /// Trace every Nth pool-dispatched request (0 = tracing off).
    pub trace_sample: u64,
    /// Deadline budget for requests without an `X-Deadline-Ms` header
    /// (0 = only the header arms a deadline).
    pub default_deadline_ms: u64,
    /// Brownout watermarks on in-flight requests (hysteresis band);
    /// `brownout_high == 0` disables the controller.
    pub brownout_high: usize,
    pub brownout_low: usize,
}

/// Where sampled trace spans go, one JSON line per span.  Shared with
/// pool-worker-free abandon: only the reactor writes, but the sink is
/// behind a mutex anyway so a future writer cannot interleave lines.
pub(crate) struct TraceSink {
    out: Mutex<Box<dyn std::io::Write + Send>>,
}

impl TraceSink {
    /// `None` logs spans to stderr; `Some(path)` truncates and writes
    /// the file.
    pub fn open(path: Option<&str>) -> anyhow::Result<TraceSink> {
        use anyhow::Context;
        let out: Box<dyn std::io::Write + Send> = match path {
            Some(p) => Box::new(
                std::fs::File::create(p).with_context(|| format!("create trace log {p}"))?,
            ),
            None => Box::new(std::io::stderr()),
        };
        Ok(TraceSink { out: Mutex::new(out) })
    }

    fn write_line(&self, line: &str) {
        use std::io::Write;
        let mut o = self.out.lock().unwrap();
        let _ = writeln!(o, "{line}");
        let _ = o.flush();
    }
}

/// State shared between the reactor thread, the pool workers and the
/// `Server` handle.
pub(crate) struct ReactorShared {
    /// Responses finished by pool workers, keyed by connection token;
    /// drained by the reactor after a wake.
    completions: Mutex<Vec<Completion>>,
    /// Interrupts the reactor's `poll` (request completed, shutdown).
    pub waker: Waker,
    /// Requests dispatched to the pool whose completions the reactor
    /// has not yet drained — the `max_queued` backpressure gauge.
    inflight: AtomicU64,
    /// Pool-dispatched request ordinal, the trace-sampling clock (only
    /// advanced while tracing is on).
    trace_seq: AtomicU64,
}

impl ReactorShared {
    pub fn new() -> anyhow::Result<ReactorShared> {
        Ok(ReactorShared {
            completions: Mutex::new(Vec::new()),
            waker: Waker::new()?,
            inflight: AtomicU64::new(0),
            trace_seq: AtomicU64::new(0),
        })
    }
}

struct Completion {
    token: u64,
    resp: Response,
    close: bool,
    /// Handler-side timings when the request was sampled for tracing.
    trace: Option<HandlerTrace>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    Reading,
    InFlight,
    Writing,
}

struct Conn {
    http: HttpConn,
    state: State,
    /// Last forward progress (accept, message, write bytes): the
    /// keep-alive clock in `Reading`, the stall clock in `Writing`.
    last_activity: Instant,
    close_after_write: bool,
    /// The in-flight request's trace span draft, when sampled.  Emitted
    /// once the response fully drains; dropped silently if the
    /// connection dies first.
    span: Option<Span>,
}

/// Reactor-side half of a request trace span (the handler half arrives
/// with the completion).
struct Span {
    seq: u64,
    /// First byte → complete frame, from the framing layer.
    read_us: u64,
    /// When the request was handed to the compute pool.
    dispatched: Instant,
    /// When the finished response was queued onto the connection.
    write_start: Instant,
    handler: Option<HandlerTrace>,
}

/// What to do with a connection after driving it.
enum Drive {
    Keep,
    Evict,
}

/// Everything a connection drive needs, borrowed once per loop round.
struct Ctx<'a> {
    svc: &'a Arc<Service>,
    pool: &'a Arc<ThreadPool>,
    metrics: &'a Arc<ServerMetrics>,
    shared: &'a Arc<ReactorShared>,
    cfg: &'a ReactorConfig,
    sink: Option<&'a TraceSink>,
    draining: bool,
}

/// The reactor body; runs on a dedicated thread until shutdown + drain.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run(
    listener: TcpListener,
    svc: Arc<Service>,
    pool: Arc<ThreadPool>,
    metrics: Arc<ServerMetrics>,
    shared: Arc<ReactorShared>,
    shutdown: Arc<AtomicBool>,
    cfg: ReactorConfig,
    sink: Option<Arc<TraceSink>>,
) {
    let mut conns: BTreeMap<u64, Conn> = BTreeMap::new();
    let mut next_token: u64 = 0;
    let mut drain_deadline: Option<Instant> = None;
    // Reactor dark corners: how long each loop round takes (poll wait
    // included) and how many completions each round delivers.
    let tel = telemetry::global();
    let round_us =
        tel.histogram("pbsp_reactor_poll_round_us", "reactor loop round duration in us, poll wait included");
    let completions_depth =
        tel.gauge("pbsp_reactor_completions_depth", "completions delivered in the latest reactor round");

    loop {
        let round_t0 = Instant::now();
        if shutdown.load(Ordering::SeqCst) && drain_deadline.is_none() {
            drain_deadline = Some(Instant::now() + cfg.shutdown_grace);
            metrics.draining.store(true, Ordering::Relaxed);
        }
        let ctx = Ctx {
            svc: &svc,
            pool: &pool,
            metrics: &metrics,
            shared: &shared,
            cfg: &cfg,
            sink: sink.as_deref(),
            draining: drain_deadline.is_some(),
        };

        // 1. Deliver finished responses onto their connections.
        let done: Vec<Completion> = {
            let mut lock = shared.completions.lock().unwrap();
            lock.drain(..).collect()
        };
        completions_depth.set(done.len() as i64);
        for c in done {
            // Every handler-produced response is counted, even if its
            // connection was evicted meanwhile (the work happened).
            metrics.count_status(c.resp.status);
            if let Some(conn) = conns.get_mut(&c.token) {
                conn.close_after_write |= c.close || ctx.draining;
                conn.http.queue_response(&c.resp, conn.close_after_write);
                conn.state = State::Writing;
                conn.last_activity = Instant::now();
                if let Some(span) = conn.span.as_mut() {
                    span.handler = c.trace;
                    span.write_start = Instant::now();
                }
                // Eager flush: most responses fit the send buffer, so
                // they complete without waiting for a poll round.
                if let Drive::Evict = advance_write(c.token, conn, &ctx) {
                    conns.remove(&c.token);
                }
            }
        }

        // 2. Drained? (Checked after completions so their writes count.)
        if let Some(deadline) = drain_deadline {
            let inflight = shared.inflight.load(Ordering::SeqCst);
            let writing = conns.values().any(|c| c.http.has_pending_write());
            let pending = !shared.completions.lock().unwrap().is_empty();
            if (inflight == 0 && !writing && !pending) || Instant::now() >= deadline {
                break;
            }
        }

        // 3. Deadline sweeps (cheap: one pass over the map per tick).
        sweep_deadlines(&mut conns, &ctx);
        metrics.open_connections.store(conns.len() as u64, Ordering::Relaxed);

        // 3b. Brownout controller: a hysteresis band on the in-flight
        // gauge (the compute-side occupancy signal).  Above the high
        // watermark the routing layer starts downshifting eligible
        // score requests to lower-precision variants; the state clears
        // only once occupancy falls to the low watermark, so the flag
        // cannot flap at the boundary.  Runs once per loop round, which
        // bounds controller lag to one poll tick.
        let inflight = shared.inflight.load(Ordering::SeqCst);
        metrics.inflight.store(inflight, Ordering::Relaxed);
        if cfg.brownout_high > 0 {
            let browned = metrics.brownout.load(Ordering::Relaxed);
            if !browned && inflight >= cfg.brownout_high as u64 {
                metrics.brownout.store(true, Ordering::Relaxed);
                metrics.brownout_entered.fetch_add(1, Ordering::Relaxed);
            } else if browned && inflight <= cfg.brownout_low as u64 {
                metrics.brownout.store(false, Ordering::Relaxed);
            }
        }

        // 4. Build the interest set.
        let mut fds: Vec<PollFd> = Vec::with_capacity(conns.len() + 2);
        let mut owners: Vec<Slot> = Vec::with_capacity(conns.len() + 2);
        fds.push(PollFd::new(shared.waker.fd(), true, false));
        owners.push(Slot::Waker);
        if !ctx.draining {
            fds.push(PollFd::new(poll::fd_of(&listener), true, false));
            owners.push(Slot::Listener);
        }
        for (&token, conn) in &conns {
            let (r, w) = match conn.state {
                State::Reading => (!ctx.draining, false),
                State::InFlight => (false, false),
                State::Writing => (false, true),
            };
            if r || w {
                fds.push(PollFd::new(poll::fd_of(conn.http.stream()), r, w));
                owners.push(Slot::Conn(token));
            }
        }

        // 5. Sleep until readiness, a wake, or the sweep tick.
        if poll::poll(&mut fds, TICK).is_err() {
            // A transient poll failure: tick on — per-connection errors
            // surface through their own drives.
            std::thread::sleep(TICK);
        }

        // 6. Drive every ready source.
        for (pfd, slot) in fds.iter().zip(&owners) {
            if !pfd.ready() {
                continue;
            }
            match *slot {
                Slot::Waker => shared.waker.drain(),
                Slot::Listener => accept_ready(&listener, &mut conns, &mut next_token, &ctx),
                Slot::Conn(token) => {
                    let Some(conn) = conns.get_mut(&token) else { continue };
                    let action = match conn.state {
                        State::Reading if pfd.readable => drive_read(token, conn, &ctx),
                        State::Writing if pfd.writable => advance_write(token, conn, &ctx),
                        // Error/hangup with no usable readiness: the
                        // peer is gone.
                        _ if pfd.error => Drive::Evict,
                        _ => Drive::Keep,
                    };
                    if let Drive::Evict = action {
                        conns.remove(&token);
                    }
                }
            }
        }
        round_us.observe(round_t0.elapsed().as_micros() as u64);
    }

    metrics.open_connections.store(0, Ordering::Relaxed);
    // Dropping `conns` closes every socket; unfinished completions
    // (grace expired) are discarded with them.
}

enum Slot {
    Waker,
    Listener,
    Conn(u64),
}

/// Accept everything pending.  Admission control happens here, but a
/// refusal is just a connection born in `Writing` with a queued 503 —
/// it drains asynchronously under the same write machinery as any
/// response, so a refused client that never reads can stall only its
/// own eviction timer, never the accept path.
fn accept_ready(
    listener: &TcpListener,
    conns: &mut BTreeMap<u64, Conn>,
    next_token: &mut u64,
    ctx: &Ctx<'_>,
) {
    loop {
        let stream = match listener.accept() {
            Ok((s, _peer)) => s,
            Err(e) if e.kind() == ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => {
                // Transient accept failure (e.g. EMFILE): log and let
                // the next poll round retry.
                eprintln!("pbsp-http: accept error: {e}");
                return;
            }
        };
        let token = *next_token;
        *next_token += 1;
        match admit(stream, conns.len(), ctx) {
            Some(mut conn) => {
                if conn.state == State::Writing {
                    // A refusal: try to push the 503 right away; if it
                    // already drained, never even enter the map.
                    if let Drive::Evict = advance_write(token, &mut conn, ctx) {
                        continue;
                    }
                }
                conns.insert(token, conn);
            }
            None => continue,
        }
    }
}

/// Configure a fresh socket and decide admission.  `None` means the
/// socket could not be set up and was dropped.
fn admit(stream: TcpStream, open: usize, ctx: &Ctx<'_>) -> Option<Conn> {
    if stream.set_nonblocking(true).is_err() {
        return None;
    }
    let _ = stream.set_nodelay(true);
    if open >= ctx.cfg.max_connections {
        // Refuse — asynchronously.  Only `rejected_busy` counts this
        // (no request was read, so request/response counters stay
        // reconcilable).
        ctx.metrics.rejected_busy.fetch_add(1, Ordering::Relaxed);
        let mut http = HttpConn::new(stream);
        let resp =
            Response::unavailable("connection capacity reached; raise --max-conns", RETRY_AFTER_S);
        http.queue_response(&resp, true);
        return Some(Conn {
            http,
            state: State::Writing,
            last_activity: Instant::now(),
            close_after_write: true,
            span: None,
        });
    }
    ctx.metrics.connections.fetch_add(1, Ordering::Relaxed);
    let mut http = HttpConn::new(stream);
    http.set_msg_deadline(ctx.cfg.msg_deadline);
    Some(Conn {
        http,
        state: State::Reading,
        last_activity: Instant::now(),
        close_after_write: false,
        span: None,
    })
}

/// Read readiness on a `Reading` connection: pump the framing state
/// machine; dispatch a completed message.
fn drive_read(token: u64, conn: &mut Conn, ctx: &Ctx<'_>) -> Drive {
    match conn.http.read_message() {
        Ok(Outcome::Message(msg)) => {
            conn.last_activity = Instant::now();
            dispatch(token, conn, msg, ctx);
            match conn.state {
                // Queue-level 503 was queued inline: flush it eagerly.
                State::Writing => advance_write(token, conn, ctx),
                _ => Drive::Keep,
            }
        }
        Ok(Outcome::Idle) => Drive::Keep, // partial stays buffered
        Ok(Outcome::Closed) => Drive::Evict,
        Err(e) => {
            queue_request_error(conn, ctx, &format!("{e:#}"));
            advance_write(token, conn, ctx)
        }
    }
}

/// Framing violation or tripped mid-message deadline: best-effort 400,
/// then close.  It counts as a request so responses never outnumber
/// requests in `/metrics`.
fn queue_request_error(conn: &mut Conn, ctx: &Ctx<'_>, msg: &str) {
    ctx.metrics.http_requests.fetch_add(1, Ordering::Relaxed);
    ctx.metrics.count_status(400);
    conn.http.queue_response(&Response::error(400, msg), true);
    conn.state = State::Writing;
    conn.close_after_write = true;
    conn.last_activity = Instant::now();
}

/// A complete request: queue-level backpressure, then hand the routing
/// + scoring work to the compute pool.  Leaves the connection in
/// `InFlight` (dispatched) or `Writing` (backpressure 503 queued).
fn dispatch(token: u64, conn: &mut Conn, msg: Message, ctx: &Ctx<'_>) {
    ctx.metrics.http_requests.fetch_add(1, Ordering::Relaxed);
    if ctx.shared.inflight.load(Ordering::SeqCst) >= ctx.cfg.max_queued as u64 {
        // The compute pool is saturated past its queue budget: tell
        // the device to back off, but keep its (healthy) connection.
        ctx.metrics.rejected_queue.fetch_add(1, Ordering::Relaxed);
        ctx.metrics.count_status(503);
        let resp = Response::unavailable("request queue full; retry shortly", RETRY_AFTER_S);
        conn.close_after_write |= ctx.draining;
        conn.http.queue_response(&resp, conn.close_after_write);
        conn.state = State::Writing;
        conn.last_activity = Instant::now();
        return;
    }
    ctx.shared.inflight.fetch_add(1, Ordering::SeqCst);
    conn.state = State::InFlight;
    // Trace sampling: every Nth pool-dispatched request opens a span
    // draft on its connection; the handler half rides back with the
    // completion and the span is emitted once the response drains.
    let seq = if ctx.cfg.trace_sample > 0 && ctx.sink.is_some() {
        let n = ctx.shared.trace_seq.fetch_add(1, Ordering::Relaxed);
        (n % ctx.cfg.trace_sample == 0).then_some(n)
    } else {
        None
    };
    let sampled = seq.is_some();
    if let Some(seq) = seq {
        conn.span = Some(Span {
            seq,
            read_us: msg.read_age.map(|d| d.as_micros() as u64).unwrap_or(0),
            dispatched: Instant::now(),
            write_start: Instant::now(),
            handler: None,
        });
    }
    let svc = Arc::clone(ctx.svc);
    let metrics = Arc::clone(ctx.metrics);
    let shared = Arc::clone(ctx.shared);
    let enqueued = Instant::now();
    // Deadline stamp: the budget counts from the request's *first byte*
    // (`read_age` spans first byte → complete frame), so a slowly
    // dripped upload spends its own budget, not the server's.  The
    // header overrides the configured default; 0/absent means none.
    let deadline = {
        let ms = msg
            .headers
            .get("x-deadline-ms")
            .and_then(|v| v.trim().parse::<u64>().ok())
            .unwrap_or(ctx.cfg.default_deadline_ms);
        (ms > 0).then(|| {
            let arrival = enqueued - msg.read_age.unwrap_or(Duration::ZERO);
            arrival + Duration::from_millis(ms)
        })
    };
    ctx.pool.execute(move || {
        let mut ht = if sampled { Some(HandlerTrace::default()) } else { None };
        if let Some(t) = ht.as_mut() {
            t.queue_us = enqueued.elapsed().as_micros() as u64;
        }
        // Shed already-expired work before spending any compute on it:
        // the client has given up, so execution would only add queueing
        // delay for everyone else.  Counted separately from handler
        // responses so `/metrics` can tell sheds from slow backends.
        let expired = deadline.map(|d| Instant::now() >= d).unwrap_or(false);
        let (resp, close) = if expired {
            metrics.deadline_shed.fetch_add(1, Ordering::Relaxed);
            (Response::error(504, "deadline expired before execution"), false)
        } else {
            // Panics become a 500 so a handler bug can neither kill the
            // worker nor leak the in-flight slot (or the connection).
            catch_unwind(AssertUnwindSafe(|| {
                routes::respond(&svc, &metrics, msg, deadline, ht.as_mut())
            }))
            .unwrap_or_else(|_| (Response::error(500, "handler panicked"), true))
        };
        // Publish the completion BEFORE dropping the in-flight slot:
        // shutdown exits once inflight hits 0 with nothing pending, so
        // the reverse order could drop a finished response on the
        // floor during drain.
        shared.completions.lock().unwrap().push(Completion { token, resp, close, trace: ht });
        shared.inflight.fetch_sub(1, Ordering::SeqCst);
        shared.waker.wake();
    });
}

/// Push queued bytes, then settle the connection's next state: evict on
/// close, pick up a pipelined request already in the buffer, or return
/// to `Reading`.  Loops because a pipelined request can immediately
/// queue another response (backpressure 503, parse 400).
fn advance_write(token: u64, conn: &mut Conn, ctx: &Ctx<'_>) -> Drive {
    loop {
        match conn.http.flush_progress() {
            Ok((wrote, done)) => {
                if wrote > 0 {
                    conn.last_activity = Instant::now();
                }
                if !done {
                    return Drive::Keep; // wait for write readiness
                }
                // Fully drained: a sampled request's span is complete.
                if let Some(span) = conn.span.take() {
                    emit_span(token, &span, ctx);
                }
                if conn.close_after_write {
                    return Drive::Evict;
                }
            }
            Err(_) => return Drive::Evict,
        }
        // Fully drained and staying open: a pipelined request may
        // already be buffered — it must be picked up here, because the
        // socket may never turn readable again (all bytes were read).
        match conn.http.take_buffered_message() {
            Ok(Some(msg)) => {
                conn.last_activity = Instant::now();
                dispatch(token, conn, msg, ctx);
                match conn.state {
                    State::Writing => continue, // 503 queued inline
                    _ => return Drive::Keep,    // in flight on the pool
                }
            }
            Ok(None) => {
                conn.state = State::Reading;
                return Drive::Keep;
            }
            Err(e) => {
                queue_request_error(conn, ctx, &format!("{e:#}"));
                continue;
            }
        }
    }
}

/// Reap idle keep-alives, evict slow-loris peers past the mid-message
/// deadline (even fully-silent ones a readiness loop would never see
/// readable), and cut off stalled writers.  Each eviction class keeps
/// its own counter so `/metrics` can tell an idle fleet from an attack
/// from a stopped reader.
fn sweep_deadlines(conns: &mut BTreeMap<u64, Conn>, ctx: &Ctx<'_>) {
    let now = Instant::now();
    let mut evict: Vec<u64> = Vec::new();
    for (&token, conn) in conns.iter_mut() {
        match conn.state {
            State::Reading => {
                if let Some(age) = conn.http.msg_age() {
                    if age > ctx.cfg.msg_deadline {
                        ctx.metrics.evicted_read.fetch_add(1, Ordering::Relaxed);
                        queue_request_error(
                            conn,
                            ctx,
                            &format!("message incomplete after {:?}", ctx.cfg.msg_deadline),
                        );
                    }
                } else if ctx.draining
                    || now.duration_since(conn.last_activity) >= ctx.cfg.keep_alive
                {
                    ctx.metrics.evicted_idle.fetch_add(1, Ordering::Relaxed);
                    evict.push(token);
                }
            }
            State::InFlight => {} // governed by the compute pool
            State::Writing => {
                if now.duration_since(conn.last_activity) > ctx.cfg.write_stall {
                    ctx.metrics.evicted_write.fetch_add(1, Ordering::Relaxed);
                    evict.push(token); // peer stopped reading
                }
            }
        }
    }
    for token in evict {
        conns.remove(&token);
    }
}

/// Serialize one finished span as a JSON line.  Six timed stages:
/// read (first byte → full frame), queue (dispatch → pool pickup),
/// parse (request decode), batch (dynamic-batcher wait), exec (backend
/// run), write (response queue → drained).
fn emit_span(token: u64, span: &Span, ctx: &Ctx<'_>) {
    let Some(sink) = ctx.sink else { return };
    let h = span.handler.clone().unwrap_or_default();
    let write_us = span.write_start.elapsed().as_micros() as u64;
    let total_us = span.read_us + span.dispatched.elapsed().as_micros() as u64;
    let v = Value::obj(vec![
        ("span", Value::from("request")),
        ("seq", Value::from(span.seq as i64)),
        ("conn", Value::from(token as i64)),
        ("model", Value::from(h.model.as_str())),
        ("variant", Value::from(h.variant.as_str())),
        ("status", Value::from(h.status as i64)),
        ("batch", Value::from(h.batch as i64)),
        ("read_us", Value::from(span.read_us as i64)),
        ("queue_us", Value::from(h.queue_us as i64)),
        ("parse_us", Value::from(h.parse_us as i64)),
        ("batch_us", Value::from(h.batch_us as i64)),
        ("exec_us", Value::from(h.exec_us as i64)),
        ("write_us", Value::from(write_us as i64)),
        ("total_us", Value::from(total_us as i64)),
    ]);
    sink.write_line(&v.to_string());
}
